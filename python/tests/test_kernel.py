"""L1 kernel correctness: spoga_gemm vs the pure-jnp oracles.

This is the CORE correctness signal of the build path: the Pallas kernel
(and hence every AOT artifact, which lowers through it) must agree bit-for-
bit with the int32 GEMM reference for all INT8 operands and shapes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import spoga_gemm, ref, vmem_bytes, DPU_VECTOR_SIZE


def rand_i8(rng, *shape):
    return rng.integers(-128, 128, shape, dtype=np.int8)


def np_ref(x, w):
    return x.astype(np.int32) @ w.astype(np.int32)


# ---------------------------------------------------------------------------
# Exactness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (8, 8, 8),
        (16, 249, 16),  # one exact DPU pass
        (50, 300, 20),  # padding on every axis
        (128, 498, 32),  # two DPU passes, two column tiles
        (3, 7, 5),  # tiny odd shapes
    ],
)
def test_spoga_gemm_exact(m, k, n):
    rng = np.random.default_rng(42 + m + k + n)
    x, w = rand_i8(rng, m, k), rand_i8(rng, k, n)
    out = spoga_gemm(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(out), np_ref(x, w))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 300),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_spoga_gemm_exact_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = rand_i8(rng, m, k), rand_i8(rng, k, n)
    out = spoga_gemm(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(out), np_ref(x, w))


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([16, 128, DPU_VECTOR_SIZE]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_shape_invariance(bm, bk, seed):
    """The result must not depend on the HBM→VMEM schedule."""
    rng = np.random.default_rng(seed)
    x, w = rand_i8(rng, 33, 130), rand_i8(rng, 130, 17)
    out = spoga_gemm(jnp.asarray(x), jnp.asarray(w), block_m=bm, block_k=bk)
    np.testing.assert_array_equal(np.asarray(out), np_ref(x, w))


def test_extreme_operands():
    """INT8 extremes: -128/127 exercise the signed-MSN corner cases."""
    for xv in (-128, -1, 0, 1, 127):
        for wv in (-128, -1, 0, 1, 127):
            x = np.full((4, 300), xv, dtype=np.int8)
            w = np.full((300, 4), wv, dtype=np.int8)
            out = spoga_gemm(jnp.asarray(x), jnp.asarray(w))
            np.testing.assert_array_equal(np.asarray(out), np_ref(x, w))


def test_bad_shapes_rejected():
    x = jnp.zeros((4, 5), jnp.int8)
    w = jnp.zeros((6, 4), jnp.int8)
    with pytest.raises(ValueError):
        spoga_gemm(x, w)


# ---------------------------------------------------------------------------
# Oracle self-consistency (paper Fig. 2 identities)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lane_decomposition_identity(seed):
    rng = np.random.default_rng(seed)
    x, w = rand_i8(rng, 9, 31), rand_i8(rng, 31, 7)
    hi, mid, lo = ref.gemm_lanes(jnp.asarray(x), jnp.asarray(w))
    combined = ref.pwab_combine(hi, mid, lo)
    np.testing.assert_array_equal(np.asarray(combined), np_ref(x, w))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_prior_work_sliced_identity(seed):
    rng = np.random.default_rng(seed)
    x, w = rand_i8(rng, 6, 50), rand_i8(rng, 50, 6)
    out = ref.gemm_sliced(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(out), np_ref(x, w))


def test_nibble_invariants_exhaustive():
    v = jnp.arange(-128, 128, dtype=jnp.int8)
    msn, lsn = ref.slice_nibbles(v)
    msn, lsn = np.asarray(msn), np.asarray(lsn)
    assert lsn.min() >= 0 and lsn.max() <= 15
    assert msn.min() >= -8 and msn.max() <= 7
    np.testing.assert_array_equal(16 * msn + lsn, np.arange(-128, 128))


def test_lane_bound_holds():
    k = 64
    x = np.full((1, k), -128, dtype=np.int8)
    w = np.full((k, 1), 127, dtype=np.int8)
    hi, mid, lo = ref.gemm_lanes(jnp.asarray(x), jnp.asarray(w))
    bound = ref.lane_accumulator_bound(k)
    for lane in (hi, mid, lo):
        assert abs(int(np.asarray(lane)[0, 0])) <= bound


# ---------------------------------------------------------------------------
# ADC model
# ---------------------------------------------------------------------------


def test_adc_high_resolution_is_lossless_at_small_scale():
    rng = np.random.default_rng(7)
    x, w = rand_i8(rng, 8, 16), rand_i8(rng, 16, 8)
    exact = np_ref(x, w)
    # 24-bit ADC over the worst-case range: quantization step < 1 LSB of
    # the integer result → exact after rounding.
    out = spoga_gemm(jnp.asarray(x), jnp.asarray(w), adc_bits=24)
    np.testing.assert_array_equal(np.asarray(out), exact)


def test_adc_low_resolution_quantizes():
    rng = np.random.default_rng(8)
    x, w = rand_i8(rng, 8, 64), rand_i8(rng, 64, 8)
    exact = np_ref(x, w)
    out = np.asarray(spoga_gemm(jnp.asarray(x), jnp.asarray(w), adc_bits=8))
    # Quantized ≠ exact in general, but bounded by the LSB.
    full_scale = ref.lane_accumulator_bound(64) * 256.0
    lsb = 2 * full_scale / 2**8
    assert np.all(np.abs(out - exact) <= lsb / 2 + 1)


def test_adc_quantize_is_idempotent():
    v = jnp.asarray([[1000, -5000, 123456]], jnp.int32)
    q1 = ref.adc_quantize(v, 8, 2**17)
    q2 = ref.adc_quantize(q1, 8, 2**17)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


# ---------------------------------------------------------------------------
# Resource model
# ---------------------------------------------------------------------------


def test_vmem_estimate_fits_budget():
    """Default tile must fit a real TPU core's ~16 MiB VMEM many times over
    (DESIGN.md §8)."""
    assert vmem_bytes() < 1 << 20  # < 1 MiB
    assert vmem_bytes(256, 16, DPU_VECTOR_SIZE) < 1 << 21
