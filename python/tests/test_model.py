"""L2 model-graph tests: MLP/CNN forward passes and the im2col lowering."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def test_quantize_roundtrip_small_values():
    x = jnp.asarray([[0.5, -0.25, 1.0, -1.984375]])
    q = model.quantize(x, 1.0 / 64.0)
    back = model.dequantize(q, 1.0 / 64.0)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1 / 128)


def test_quantize_clips_to_int8_range():
    x = jnp.asarray([[100.0, -100.0]])
    q = np.asarray(model.quantize(x, 0.01))
    assert q.max() <= 127 and q.min() >= -127


# ---------------------------------------------------------------------------
# im2col
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    kernel=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    pad=st.sampled_from([0, 1]),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_matches_lax_conv(kernel, stride, pad, seed):
    """im2col ∘ GEMM must equal the native convolution (paper Fig. 1)."""
    rng = np.random.default_rng(seed)
    b, h, w, cin, cout = 2, 10, 10, 3, 4
    x = rng.integers(-8, 8, (b, h, w, cin)).astype(np.float32)
    wt = rng.integers(-8, 8, (kernel, kernel, cin, cout)).astype(np.float32)

    patches, (bb, oh, ow) = model.im2col(jnp.asarray(x), kernel, stride, pad)
    # weight layout in im2col: (di, dj, cin) flattened in that order.
    wmat = jnp.asarray(wt).reshape(kernel * kernel * cin, cout)
    got = np.asarray(patches @ wmat).reshape(bb, oh, ow, cout)

    want = jax.lax.conv_general_dilated(
        jnp.asarray(x),
        jnp.asarray(wt),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(got, np.asarray(want), rtol=0, atol=1e-4)


def test_im2col_int8_shapes():
    x = jnp.zeros((1, 28, 28, 1), jnp.int8)
    patches, (b, oh, ow) = model.im2col(x, 3, 2, 1)
    assert (b, oh, ow) == (1, 14, 14)
    assert patches.shape == (196, 9)
    assert patches.dtype == jnp.int8


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def _mlp_ref(x_i32, ws):
    """Reference MLP using the jnp oracle GEMM instead of the kernel."""
    h = x_i32.astype(jnp.int8)
    for i, w in enumerate(ws):
        acc = ref.gemm_i32(h, w)
        if i == len(ws) - 1:
            return acc
        acc = jnp.maximum(acc, 0) >> model.REQUANT_SHIFT
        h = jnp.clip(acc, 0, 127).astype(jnp.int8)
    return acc


def test_mlp_forward_matches_oracle():
    ws = model.mlp_params()
    x = model.example_batch(4)
    got = model.mlp_forward(x, *[w.astype(jnp.int32) for w in ws])
    want = _mlp_ref(x, ws)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mlp_deterministic_params():
    w1 = model.mlp_params(seed=3)
    w2 = model.mlp_params(seed=3)
    for a, b in zip(w1, w2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mlp_quantization_error_bounded():
    """INT8 inference must track the float model (the paper's premise that
    8-bit operands suffice for DNN workloads)."""
    ws = model.mlp_params()
    x = model.example_batch(8)
    got = np.asarray(model.mlp_forward(x, *[w.astype(jnp.int32) for w in ws]))
    want = np.asarray(model.mlp_forward_f32(x, ws))
    # Same top-1 on a clear majority of rows (synthetic weights: loose).
    agree = (got.argmax(-1) == want.argmax(-1)).mean()
    assert agree >= 0.5, f"top-1 agreement {agree}"


@settings(max_examples=8, deadline=None)
@given(batch=st.sampled_from([1, 2, 8]), seed=st.integers(0, 1000))
def test_mlp_batch_consistency(batch, seed):
    """Row i of a batched forward equals forwarding row i alone."""
    ws = [w.astype(jnp.int32) for w in model.mlp_params()]
    x = model.example_batch(batch, seed=seed)
    full = np.asarray(model.mlp_forward(x, *ws))
    row0 = np.asarray(model.mlp_forward(x[:1], *ws))
    np.testing.assert_array_equal(full[:1], row0)


# ---------------------------------------------------------------------------
# CNN
# ---------------------------------------------------------------------------


def test_cnn_forward_shape_and_determinism():
    ws = [w.astype(jnp.int32) for w in model.cnn_params()]
    x = jnp.ones((2, 28, 28, 1), jnp.int32)
    a = np.asarray(model.cnn_forward(x, *ws))
    b = np.asarray(model.cnn_forward(x, *ws))
    assert a.shape == (2, 10)
    np.testing.assert_array_equal(a, b)


def test_cnn_zero_input_zero_logits():
    ws = [w.astype(jnp.int32) for w in model.cnn_params()]
    x = jnp.zeros((1, 28, 28, 1), jnp.int32)
    out = np.asarray(model.cnn_forward(x, *ws))
    np.testing.assert_array_equal(out, np.zeros((1, 10), np.int32))


def test_cnn_respects_input_range():
    # int8 wire values outside [-128,127] would alias; the contract is that
    # callers pass int8-valued int32. Check an in-range extreme works.
    ws = [w.astype(jnp.int32) for w in model.cnn_params()]
    x = jnp.full((1, 28, 28, 1), 127, jnp.int32)
    out = model.cnn_forward(x, *ws)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# Quantization study (paper §I premise: INT8 suffices, INT4 does not)
# ---------------------------------------------------------------------------


def _quantized_forward(x, ws_f32, bits):
    """Forward with weights quantized to `bits` (symmetric)."""
    qmax = 2 ** (bits - 1) - 1
    h = x.astype(jnp.float32)
    for i, w in enumerate(ws_f32):
        scale = float(jnp.max(jnp.abs(w))) / qmax
        wq = jnp.clip(jnp.round(w / scale), -qmax, qmax) * scale
        h = h @ wq
        if i < len(ws_f32) - 1:
            h = jnp.maximum(h, 0)
    return h


def test_int8_tracks_float_better_than_int4():
    """The paper's premise: byte-size operands are needed — INT4-quantized
    weights lose much more fidelity than INT8 on the same model."""
    rng = np.random.default_rng(0)
    ws = [jnp.asarray(rng.normal(0, 1 / np.sqrt(d), (d, o)).astype(np.float32))
          for d, o in [(784, 256), (256, 256), (256, 10)]]
    x = jnp.asarray(rng.integers(0, 128, (32, 784)).astype(np.float32))
    ref_out = _quantized_forward(x, ws, 32)  # effectively float
    err8 = float(jnp.abs(_quantized_forward(x, ws, 8) - ref_out).mean())
    err4 = float(jnp.abs(_quantized_forward(x, ws, 4) - ref_out).mean())
    assert err4 > 5 * err8, f"int4 err {err4} vs int8 err {err8}"
    # And INT8 top-1 agreement with float is near-perfect.
    agree8 = float((_quantized_forward(x, ws, 8).argmax(-1) == ref_out.argmax(-1)).mean())
    assert agree8 >= 0.9, f"int8 top-1 agreement {agree8}"
