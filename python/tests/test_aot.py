"""AOT pipeline tests: HLO text emission and manifest consistency."""

import os
import tempfile

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_to_hlo_text_produces_parseable_module():
    text = aot.to_hlo_text(
        lambda x, w: model.gemm_int8(x, w),
        aot._i32(8, 16),
        aot._i32(16, 8),
    )
    assert "HloModule" in text
    assert "ENTRY" in text
    # int32 wire format everywhere at the boundary.
    assert "s32[8,16]" in text
    assert "s32[8,8]" in text


def test_entries_cover_expected_artifacts():
    names = [name for name, _, _ in aot.build_entries()]
    assert "gemm_128x249x16" in names  # DPU-native shape
    assert "mlp_b1" in names and "mlp_b32" in names
    assert "cnn_b1" in names
    assert len(names) == len(set(names)), "duplicate artifact names"


def test_emit_writes_manifest_and_files():
    with tempfile.TemporaryDirectory() as d:
        # Restrict to the smallest entry for speed.
        entries = [e for e in aot.build_entries() if e[0] == "gemm_64x64x64"]
        orig = aot.build_entries
        aot.build_entries = lambda: entries
        try:
            aot.emit(d)
        finally:
            aot.build_entries = orig
        manifest = open(os.path.join(d, "manifest.txt")).read().strip().splitlines()
        assert len(manifest) == 1
        name, fname, ins, outs = manifest[0].split(" ")
        assert name == "gemm_64x64x64"
        assert os.path.exists(os.path.join(d, fname))
        assert ins == "i32:64x64,i32:64x64"
        assert outs == "i32:64x64"


def test_spec_format():
    assert aot._spec(aot._i32(3, 4)) == "i32:3x4"
    assert aot._spec(jnp.zeros((2,), jnp.float32)) == "f32:2"


def test_mlp_artifact_semantics_match_model():
    """The lowered-and-reloaded computation must equal the eager model.

    (Full PJRT round-trip happens on the rust side; here we check the
    lowering stage is semantics-preserving via jax's own executor.)
    """
    import jax

    ws = [w.astype(jnp.int32) for w in model.mlp_params()]
    fn = lambda x: model.mlp_forward(x, *ws)
    x = model.example_batch(1)
    eager = np.asarray(fn(x))
    compiled = jax.jit(fn).lower(x).compile()
    np.testing.assert_array_equal(np.asarray(compiled(x)), eager)


def test_no_elided_constants_in_hlo_text():
    """Regression: the default HLO printer elides big literals as '{...}',
    which silently drops baked weights (caught by the rust golden model)."""
    import jax

    ws = [w.astype(jnp.int32) for w in model.mlp_params()]
    text = aot.to_hlo_text(lambda x: model.mlp_forward(x, *ws), aot._i32(1, 784))
    assert "{...}" not in text, "weights were elided from the HLO text"
    # The 784x256 weight constant must be materialized.
    assert "s32[784,256]" in text or "s8[784,256]" in text
