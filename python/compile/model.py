"""L2 — quantized model graphs built on the SPOGA kernel.

All entry points exported by :mod:`compile.aot` take/return **int32** (the
rust ``xla`` crate has no int8 literal support); values are converted to
int8 at the graph boundary and all GEMMs run through
:func:`compile.kernels.spoga_gemm` so they lower into the same HLO module.

Graphs provided:

* :func:`gemm_int8` — a single INT8 GEMM (the paper's kernel-level unit).
* :func:`mlp_forward` — 784→256→256→10 quantized MLP (MNIST-class), the
  e2e serving model.
* :func:`cnn_forward` — a small conv net on 28×28 images: conv layers are
  lowered to GEMM via im2col exactly like the paper's Fig. 1 mapping.
* :func:`quantize` / :func:`dequantize` — symmetric per-tensor INT8.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import spoga_gemm


def quantize(x, scale):
    """Symmetric per-tensor quantization to int8: ``round(x/scale)``."""
    q = jnp.round(x / scale)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequantize(q, scale):
    """Inverse of :func:`quantize` (int32 accumulators welcome)."""
    return q.astype(jnp.float32) * scale


def _as_i8(x_i32):
    """Boundary cast: int32 wire format -> int8 operands (values must
    already be in int8 range; the rust side guarantees this)."""
    return x_i32.astype(jnp.int8)


def gemm_int8(x_i32, w_i32, *, block_m=128, adc_bits=None):
    """INT8 GEMM entry point (int32 wire format)."""
    return spoga_gemm(_as_i8(x_i32), _as_i8(w_i32), block_m=block_m, adc_bits=adc_bits)


# ---------------------------------------------------------------------------
# MLP (the e2e serving model)
# ---------------------------------------------------------------------------

#: Layer widths of the e2e MLP.
MLP_DIMS = (784, 256, 256, 10)

#: Fixed-point shift applied between INT8 layers (re-quantization).
REQUANT_SHIFT = 8


def mlp_params(seed=0):
    """Deterministic int8 weights for the e2e MLP (synthetic 'trained'
    model — the paper's workloads are inference-only and weight values do
    not affect any performance metric)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), len(MLP_DIMS) - 1)
    ws = []
    for key, (din, dout) in zip(keys, zip(MLP_DIMS[:-1], MLP_DIMS[1:])):
        w = jax.random.normal(key, (din, dout)) / jnp.sqrt(din)
        ws.append(quantize(w, 1.0 / 64.0))
    return ws


def mlp_forward(x_i32, *ws_i32):
    """Quantized MLP forward: int8 GEMM → ReLU → requantize per layer.

    ``x_i32``: (batch, 784) int8-valued activations in int32 wire format.
    Returns (batch, 10) int32 logits (last layer un-requantized).
    """
    h = _as_i8(x_i32)
    n_layers = len(ws_i32)
    for i, w in enumerate(ws_i32):
        # Serving tiling (§Perf): fuse the whole layer into one grid cell —
        # bit-identical to the DPU-native (16, 249) tiling (tests prove it),
        # but ~2.3x faster under the Pallas interpreter on CPU.
        acc = spoga_gemm(
            h,
            _as_i8(w),
            block_n=min(int(w.shape[1]), 256),
            block_k=min(int(w.shape[0]), 1024),
        )
        if i == n_layers - 1:
            return acc
        # ReLU then fixed-point re-quantization back to int8 range.
        acc = jnp.maximum(acc, 0) >> REQUANT_SHIFT
        h = jnp.clip(acc, 0, 127).astype(jnp.int8)
    return acc


# ---------------------------------------------------------------------------
# CNN (im2col lowering, paper Fig. 1)
# ---------------------------------------------------------------------------


def im2col(x, kernel, stride=1, pad=0):
    """Extract convolution patches: (B,H,W,C) -> (B*OH*OW, k*k*C).

    This is the input-matrix construction of the paper's Fig. 1(a) — the
    Toeplitz/im2col transform that turns a conv layer into a GEMM.
    """
    b, h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kernel) // stride + 1
    ow = (w + 2 * pad - kernel) // stride + 1
    # Gather k×k patches; int8-safe (pure indexing).
    rows = []
    for di in range(kernel):
        for dj in range(kernel):
            rows.append(
                jax.lax.dynamic_slice_in_dim(
                    jax.lax.dynamic_slice_in_dim(x, di, oh * stride - (stride - 1), axis=1),
                    dj,
                    ow * stride - (stride - 1),
                    axis=2,
                )[:, ::stride, ::stride, :]
            )
    patches = jnp.concatenate(rows, axis=-1)  # (B, OH, OW, k*k*C)
    return patches.reshape(b * oh * ow, kernel * kernel * c), (b, oh, ow)


#: CNN layout: two conv layers then a classifier head.
CNN_CFG = (
    # (kernel, stride, pad, in_ch, out_ch)
    (3, 1, 1, 1, 8),
    (3, 2, 1, 8, 16),
)
CNN_FC = (14 * 14 * 16, 10)


def cnn_params(seed=0):
    """Deterministic int8 weights for the small CNN."""
    keys = jax.random.split(jax.random.PRNGKey(seed), len(CNN_CFG) + 1)
    ws = []
    for key, (kk, _, _, cin, cout) in zip(keys[:-1], CNN_CFG):
        w = jax.random.normal(key, (kk * kk * cin, cout)) / jnp.sqrt(kk * kk * cin)
        ws.append(quantize(w, 1.0 / 64.0))
    wfc = jax.random.normal(keys[-1], CNN_FC) / jnp.sqrt(CNN_FC[0])
    ws.append(quantize(wfc, 1.0 / 64.0))
    return ws


def cnn_forward(x_i32, *ws_i32):
    """Quantized CNN forward on (B, 28, 28, 1) int8 images (int32 wire).

    Each conv layer = im2col → :func:`spoga_gemm` → ReLU → requantize,
    mirroring how the photonic accelerator executes it (Fig. 1 mapping).
    Returns (B, 10) int32 logits.
    """
    x = _as_i8(x_i32)
    b = x.shape[0]
    h = x
    for (kk, stride, pad, _, cout), w in zip(CNN_CFG, ws_i32[: len(CNN_CFG)]):
        patches, (bb, oh, ow) = im2col(h, kk, stride, pad)
        # Serving tiling (§Perf) — see mlp_forward.
        acc = spoga_gemm(
            patches,
            _as_i8(w),
            block_n=min(int(w.shape[1]), 256),
            block_k=min(int(w.shape[0]), 1024),
        )
        acc = jnp.maximum(acc, 0) >> REQUANT_SHIFT
        h = jnp.clip(acc, 0, 127).astype(jnp.int8).reshape(bb, oh, ow, cout)
    flat = h.reshape(b, -1)
    return spoga_gemm(
        flat,
        _as_i8(ws_i32[-1]),
        block_n=min(int(ws_i32[-1].shape[1]), 256),
        block_k=min(int(ws_i32[-1].shape[0]), 1024),
    )


# ---------------------------------------------------------------------------
# Float reference heads (used by tests to check quantization error only)
# ---------------------------------------------------------------------------


def mlp_forward_f32(x, ws):
    """Float mirror of :func:`mlp_forward` for quantization-error tests."""
    h = x.astype(jnp.float32)
    for i, w in enumerate(ws):
        h = h @ w.astype(jnp.float32)
        if i < len(ws) - 1:
            h = jnp.maximum(h, 0) / float(1 << REQUANT_SHIFT)
            h = jnp.clip(h, 0, 127)
    return h


@functools.cache
def example_batch(batch=8, seed=1):
    """Deterministic int8 example batch for the MLP, int32 wire format."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.randint(key, (batch, MLP_DIMS[0]), 0, 128, dtype=jnp.int32)
    return x
