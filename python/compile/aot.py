"""AOT compilation: lower L2 graphs to HLO **text** artifacts.

Runs once at build time (``make artifacts``); the rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client. Text — not ``.serialize()`` — is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Every entry point takes/returns int32 (the rust ``xla`` crate has no int8
literals); MLP/CNN weights are baked into the module as constants so the
request path only ships activations.

Artifacts + a line-oriented ``manifest.txt`` land in ``--out-dir``::

    <name> <file> <in0>,<in1>,... <out0>,...      # spec = dtype:dim 'x' dim
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, *example_args):
    """Lower a jittable function to HLO text (return_tuple=True)."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big literals as
    # "{...}", which would silently drop the baked model weights.
    return comp.as_hlo_text(True)


def _spec(shape_dtype):
    dt = {"int32": "i32", "float32": "f32"}[str(shape_dtype.dtype)]
    return f"{dt}:{'x'.join(str(d) for d in shape_dtype.shape)}"


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_entries():
    """(name, fn, example_args) for every artifact we ship."""
    entries = []

    # --- plain INT8 GEMM kernels at serving shapes -------------------------
    for m, k, n in [(64, 64, 64), (128, 249, 16), (256, 512, 256)]:
        name = f"gemm_{m}x{k}x{n}"
        fn = lambda x, w: model.gemm_int8(x, w)
        entries.append((name, fn, (_i32(m, k), _i32(k, n))))

    # --- MLP with baked weights, several batch sizes ------------------------
    ws = [w.astype(jnp.int32) for w in model.mlp_params()]
    for b in (1, 8, 32):
        entries.append(
            (f"mlp_b{b}", lambda x, ws=ws: model.mlp_forward(x, *ws), (_i32(b, model.MLP_DIMS[0]),))
        )

    # --- CNN with baked weights ---------------------------------------------
    cw = [w.astype(jnp.int32) for w in model.cnn_params()]
    for b in (1, 8):
        entries.append(
            (f"cnn_b{b}", lambda x, cw=cw: model.cnn_forward(x, *cw), (_i32(b, 28, 28, 1),))
        )
    return entries


def emit(out_dir):
    """Lower all entries and write artifacts + manifest."""
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, fn, args in build_entries():
        text = to_hlo_text(fn, *args)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_aval = jax.eval_shape(fn, *args)
        outs = jax.tree_util.tree_leaves(out_aval)
        manifest_lines.append(
            " ".join(
                [
                    name,
                    fname,
                    ",".join(_spec(a) for a in args),
                    ",".join(_spec(o) for o in outs),
                ]
            )
        )
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(manifest_lines)} artifacts to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    emit(args.out_dir)


if __name__ == "__main__":
    main()
