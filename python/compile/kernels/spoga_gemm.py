"""L1 — the SPOGA dataflow as a Pallas kernel.

The kernel computes an INT8 GEMM the way a SPOGA GEMM core does
(paper §III-A):

* operands are nibble-sliced in transit (the OAME's four OAMUs),
* the three radix lanes are accumulated **separately** across the reduction
  dimension — these are the three BPCA charge accumulators; a K longer than
  one DPU pass (``block_k`` = the DPU's ≤249-element vector) accumulates
  across grid steps exactly like the BPCA integrates charge across passes,
* the positional weights (16², 16¹, 16⁰ — capacitor selection) and the
  analog-adder sum are applied once, in the epilogue, when the last
  K-chunk has been integrated (the PWAB),
* optionally the result is passed through the PWAB's output ADC model.

Hardware adaptation (DESIGN.md §4): the photonic dataflow maps onto the
TPU abstraction as ``block_k = DPU vector size`` (HBM→VMEM schedule plays
the role of the OAME fan-in) and ``block_n = 16`` (one output column per
DPU). ``interpret=True`` is mandatory on CPU: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

#: Maximum OAMEs (vector elements) per DPU pass — paper Table I, MWA row at
#: 10 dBm / 1 GS/s.
DPU_VECTOR_SIZE = 249

#: DPUs per SPOGA GEMM core (= output columns per grid cell).
DPUS_PER_CORE = 16


def _spoga_kernel(x_ref, w_ref, o_ref, hi_ref, mid_ref, lo_ref, *, adc_bits, full_scale):
    """Grid cell: one (M-tile, N-tile) pair integrating one K-chunk."""
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    # New output tile: BPCA capacitors reset (charge cleared).
    @pl.when(k == 0)
    def _reset():
        hi_ref[...] = jnp.zeros_like(hi_ref)
        mid_ref[...] = jnp.zeros_like(mid_ref)
        lo_ref[...] = jnp.zeros_like(lo_ref)

    # OAME: nibble-slice both operands (msn signed, lsn unsigned).
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    xm, xl = x >> 4, x & 0xF
    wm, wl = w >> 4, w & 0xF

    def dot(a, b):
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )

    # Homodyne superposition on the shared aggregation lanes: each lane's
    # photocurrents from all OAMEs integrate onto its BPCA capacitor.
    hi_ref[...] += dot(xm, wm)
    mid_ref[...] += dot(xm, wl) + dot(xl, wm)
    lo_ref[...] += dot(xl, wl)

    # PWAB: after the last K-pass, select capacitors (×256/×16/×1), sum in
    # the analog adder, and digitize once.
    @pl.when(k == nk - 1)
    def _pwab():
        out = 256 * hi_ref[...] + 16 * mid_ref[...] + lo_ref[...]
        if adc_bits is not None:
            lsb = (2.0 * full_scale) / (2**adc_bits)
            clipped = jnp.clip(out.astype(jnp.float32), -full_scale, full_scale)
            out = jnp.round(jnp.round(clipped / lsb) * lsb).astype(jnp.int32)
        o_ref[...] = out


def _pad_to(a, rows, cols):
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "adc_bits", "interpret"),
)
def spoga_gemm(
    x,
    w,
    *,
    block_m=128,
    block_n=DPUS_PER_CORE,
    block_k=DPU_VECTOR_SIZE,
    adc_bits=None,
    interpret=True,
):
    """INT8 GEMM ``x (m,k) @ w (k,n) -> int32 (m,n)`` via the SPOGA dataflow.

    Args:
      x, w: int8 operand matrices.
      block_m: rows per grid cell (temporal batching of input vectors).
      block_n: output columns per grid cell — one per DPU (default 16).
      block_k: reduction elements per pass — the DPU vector size (≤249).
      adc_bits: if set, model the PWAB output ADC at this resolution
        (full-scale sized from the worst-case lane magnitude for this K).
      interpret: run the Pallas interpreter (required on CPU).

    Inputs of arbitrary shape are zero-padded up to block multiples (exact
    for GEMM) and the result is sliced back.
    """
    if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
        raise ValueError(f"bad GEMM shapes {x.shape} @ {w.shape}")
    m, k = x.shape
    _, n = w.shape

    bm, bn, bk = min(block_m, max(m, 8)), block_n, block_k
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    xp = _pad_to(x, mp, kp)
    wp = _pad_to(w, kp, np_)

    full_scale = float(ref.lane_accumulator_bound(k)) * 256.0
    kernel = functools.partial(
        _spoga_kernel, adc_bits=adc_bits, full_scale=full_scale
    )
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32) for _ in range(3)],
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def vmem_bytes(block_m=128, block_n=DPUS_PER_CORE, block_k=DPU_VECTOR_SIZE):
    """Estimated VMEM footprint of one grid cell, bytes (DESIGN.md §8).

    x tile (int8) + w tile (int8) + out tile + 3 lane accumulators (int32).
    """
    return (
        block_m * block_k  # x, int8
        + block_k * block_n  # w, int8
        + 4 * block_m * block_n * 4  # out + 3 accumulators, int32
    )
