"""L1 Pallas kernels: the SPOGA dataflow + pure-jnp oracles."""

from . import ref
from .spoga_gemm import DPU_VECTOR_SIZE, DPUS_PER_CORE, spoga_gemm, vmem_bytes

__all__ = [
    "DPU_VECTOR_SIZE",
    "DPUS_PER_CORE",
    "ref",
    "spoga_gemm",
    "vmem_bytes",
]
