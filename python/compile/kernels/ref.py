"""Pure-jnp correctness oracles for the SPOGA kernel.

Everything numeric in the repo cross-checks against these functions (the
rust side has an equivalent golden model in ``rust/src/bitslice``):

* :func:`gemm_i32` — direct int32 GEMM, the digital ground truth.
* :func:`slice_nibbles` — the paper's §II-C decomposition
  ``x = 16·msn + lsn`` with a *signed* MSN and *unsigned* LSN.
* :func:`gemm_lanes` — the SPOGA dataflow at matrix level: the three radix
  lanes (Hi = MSN·MSN, Mid = both cross terms, Lo = LSN·LSN) accumulated
  separately, then positionally weighted (16², 16¹, 16⁰) and summed —
  exactly what the three BPCAs + PWAB of a DPU do (paper Fig. 2(b/c)).
* :func:`adc_quantize` — the PWAB output ADC model.
"""

import jax.numpy as jnp


def gemm_i32(x, w):
    """Direct int32 GEMM reference: ``x (m,k) @ w (k,n) -> int32 (m,n)``."""
    return jnp.matmul(
        x.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32
    )


def slice_nibbles(v):
    """Split int8 values into (signed MSN, unsigned LSN), both as int32.

    Invariant: ``16 * msn + lsn == v`` with ``lsn in [0, 15]`` and
    ``msn in [-8, 7]``.
    """
    v32 = v.astype(jnp.int32)
    return v32 >> 4, v32 & 0xF


def gemm_lanes(x, w):
    """SPOGA-dataflow GEMM: returns the three *unweighted* lane matrices.

    ``hi = MSNx·MSNw``, ``mid = MSNx·LSNw + LSNx·MSNw``, ``lo = LSNx·LSNw``.
    The final result is ``256*hi + 16*mid + lo`` (see :func:`pwab_combine`).
    """
    xm, xl = slice_nibbles(x)
    wm, wl = slice_nibbles(w)

    def dot(a, b):
        return jnp.matmul(a, b, preferred_element_type=jnp.int32)

    hi = dot(xm, wm)
    mid = dot(xm, wl) + dot(xl, wm)
    lo = dot(xl, wl)
    return hi, mid, lo


def pwab_combine(hi, mid, lo):
    """PWAB epilogue: capacitor positional weighting + analog adder."""
    return 256 * hi + 16 * mid + lo


def gemm_sliced(x, w):
    """Prior-work dataflow (paper Fig. 2(a)): four INT4 GEMMs + DEAS.

    Returns the same values as :func:`gemm_i32`; exists so tests can assert
    the *decomposition* (not just the final numbers) is exact.
    """
    xm, xl = slice_nibbles(x)
    wm, wl = slice_nibbles(w)

    def dot(a, b):
        return jnp.matmul(a, b, preferred_element_type=jnp.int32)

    mm, ml = dot(xm, wm), dot(xm, wl)
    lm, ll = dot(xl, wm), dot(xl, wl)
    # DEAS: shift-add recombination of the four intermediate matrices.
    return 256 * mm + 16 * (ml + lm) + ll


def adc_quantize(v, bits, full_scale):
    """Model the PWAB output ADC: clip to ±full_scale, quantize to 2^bits
    uniform levels, return the *dequantized* integer value (what the digital
    side sees after scaling back).
    """
    lsb = (2.0 * full_scale) / (2**bits)
    clipped = jnp.clip(v.astype(jnp.float32), -full_scale, full_scale)
    return jnp.round(jnp.round(clipped / lsb) * lsb).astype(jnp.int32)


def lane_accumulator_bound(k):
    """Worst-case |lane| magnitude after a K-length reduction (the Mid lane
    dominates: 2 × 8 × 15 = 240 per element). Sizes ADC full-scale."""
    return 240 * k
