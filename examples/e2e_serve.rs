//! END-TO-END driver: the full system on a real serving workload.
//!
//! Proves all layers compose: a quantized MLP authored in JAX, its GEMMs
//! running through the SPOGA Pallas kernel (L1), AOT-lowered to HLO text
//! (L2), loaded and served by the rust coordinator (L3) with dynamic
//! batching over PJRT — while the transaction-level simulator projects what
//! the same workload would cost on the photonic accelerator.
//!
//! Reports: serving latency percentiles + throughput, batching occupancy,
//! numerical cross-check vs the direct engine, and the projected
//! SPOGA-vs-baseline FPS for the same model. Recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serve [requests]`

use std::sync::atomic::Ordering;
use std::time::Instant;

use spoga::coordinator::{Coordinator, CoordinatorConfig};
use spoga::runtime::Engine;
use spoga::testing::SplitMix64;

fn main() {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(192);

    println!("== SPOGA e2e serving driver ==");
    let cfg = CoordinatorConfig { workers: 2, max_batch_wait_s: 0.003, ..Default::default() };
    let t0 = Instant::now();
    let c = Coordinator::start(cfg).expect("run `make artifacts` first");
    let h = c.handle();
    println!("coordinator up (workers warm) in {:.2}s", t0.elapsed().as_secs_f64());

    // ---- generate a synthetic digit-like workload --------------------------
    let mut rng = SplitMix64::new(2024);
    let rows: Vec<Vec<i32>> = (0..requests)
        .map(|_| (0..784).map(|_| rng.below(128) as i32).collect())
        .collect();

    // Ground truth for a sample of rows via a direct engine.
    let mut eng = Engine::new("artifacts").unwrap();
    let sample: Vec<usize> = (0..requests).step_by((requests / 8).max(1)).collect();
    let expected: Vec<(usize, Vec<i32>)> = sample
        .iter()
        .map(|&i| (i, eng.execute_i32_single("mlp_b1", &[&rows[i]]).unwrap()))
        .collect();

    // ---- fire the open-loop load from 8 client threads ---------------------
    let clients = 8usize;
    let t1 = Instant::now();
    let mut joins = Vec::new();
    for cid in 0..clients {
        let h = h.clone();
        let my_rows: Vec<(usize, Vec<i32>)> = rows
            .iter()
            .enumerate()
            .skip(cid)
            .step_by(clients)
            .map(|(i, r)| (i, r.clone()))
            .collect();
        joins.push(std::thread::spawn(move || {
            my_rows
                .into_iter()
                .map(|(i, row)| (i, h.infer_mlp(row).expect("infer")))
                .collect::<Vec<_>>()
        }));
    }
    let mut results: Vec<(usize, Vec<i32>)> =
        joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
    let wall = t1.elapsed().as_secs_f64();
    results.sort_by_key(|(i, _)| *i);

    // ---- verify -------------------------------------------------------------
    for (i, exp) in &expected {
        assert_eq!(&results[*i].1, exp, "request {i}: batched != direct");
    }
    println!("numerics: {} sampled rows match direct engine ✓", expected.len());

    // ---- serving report -------------------------------------------------------
    let s = h.stats();
    println!("\n-- serving metrics --");
    println!("requests          : {}", requests);
    println!("wall time         : {wall:.3} s");
    println!("throughput        : {:.1} req/s", requests as f64 / wall);
    println!("latency mean      : {:.2} ms", s.latency_mean() * 1e3);
    println!("latency p50 / p99 : {:.2} / {:.2} ms", s.latency_percentile(0.5) * 1e3, s.latency_percentile(0.99) * 1e3);
    println!("micro-batches     : {}", s.batches.load(Ordering::Relaxed));
    println!("batch occupancy   : {:.2} rows/batch", s.mean_batch_occupancy());
    println!("padding overhead  : {:.1}%", s.padding_fraction() * 100.0);

    // ---- photonic projection: what would this cost on SPOGA? -----------------
    use spoga::arch::accel::Accelerator;
    use spoga::dnn::layer::{GemmShape, Layer};
    use spoga::dnn::models::CnnModel;
    use spoga::optics::link_budget::ArchClass;
    use spoga::sim::engine::simulate_frame;
    use spoga::units::DataRate;

    let mlp = CnnModel {
        name: "ServeMLP",
        layers: vec![
            Layer::fc("fc1", 784, 256),
            Layer::fc("fc2", 256, 256),
            Layer::fc("fc3", 256, 10),
        ],
    };
    let _ = GemmShape { t: 1, k: 1, c: 1, groups: 1 };
    println!("\n-- photonic projection (64-core accelerators, batch 1) --");
    for arch in [ArchClass::Mwa, ArchClass::Maw, ArchClass::Amw] {
        let accel = Accelerator::equal_cores(arch, DataRate::Gs10, 64).unwrap();
        let f = simulate_frame(&accel, &mlp.workload());
        println!(
            "  {:13} {:>12.0} inferences/s   {:>9.3} µJ/inference",
            f.accelerator,
            f.fps(),
            f.energy.total_j() * 1e6
        );
    }

    c.shutdown();
    println!("\ne2e driver complete.");
}
