//! Simulate the paper's §IV-C evaluation: the four benchmark CNNs on
//! SPOGA vs HOLYLIGHT vs DEAPCNN, with a per-layer drill-down.
//!
//! Run: `cargo run --release --example cnn_inference [model]`
//! where `model` ∈ {mobilenet, shufflenet, resnet, googlenet} (default
//! resnet).

use spoga::arch::accel::Accelerator;
use spoga::dnn::models::{googlenet, mobilenet_v2, resnet50, shufflenet_v2, CnnModel};
use spoga::metrics::FIG5_CORES;
use spoga::optics::link_budget::ArchClass;
use spoga::report::{fmt_sig, Table};
use spoga::sim::engine::simulate_frame;
use spoga::units::DataRate;

fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "resnet".into());
    let model: CnnModel = match which.as_str() {
        "mobilenet" => mobilenet_v2(),
        "shufflenet" => shufflenet_v2(),
        "googlenet" => googlenet(),
        _ => resnet50(),
    };
    println!(
        "{}: {} GEMM layers, {:.2} GMACs/frame\n",
        model.name,
        model.layers.len(),
        model.total_macs() as f64 / 1e9
    );

    // ---- cross-architecture comparison -------------------------------------
    let mut t = Table::new(vec![
        "Accelerator",
        "FPS",
        "FPS/W",
        "FPS/W/mm2 (CMOS)",
        "avg W",
        "utilization",
    ]);
    for arch in [ArchClass::Mwa, ArchClass::Maw, ArchClass::Amw] {
        for dr in [DataRate::Gs5, DataRate::Gs10] {
            let accel = Accelerator::equal_cores(arch, dr, FIG5_CORES).unwrap();
            let f = simulate_frame(&accel, &model.workload());
            t.row(vec![
                f.accelerator.clone(),
                fmt_sig(f.fps(), 3),
                fmt_sig(f.fps_per_w(), 3),
                fmt_sig(f.fps_per_w_per_mm2(accel.electronic_area_mm2()), 3),
                fmt_sig(f.avg_power_w(), 3),
                format!("{:.1}%", f.utilization() * 100.0),
            ]);
        }
    }
    println!("{}", t.render());

    // ---- per-layer drill-down on SPOGA_10 -----------------------------------
    let accel = Accelerator::equal_cores(ArchClass::Mwa, DataRate::Gs10, FIG5_CORES).unwrap();
    let f = simulate_frame(&accel, &model.workload());
    let mut layers = f.layers.clone();
    layers.sort_by(|a, b| b.latency_s.total_cmp(&a.latency_s));
    let mut t = Table::new(vec!["Layer (top 10 by latency)", "latency µs", "energy µJ", "util %"]);
    for l in layers.iter().take(10) {
        t.row(vec![
            l.layer.clone(),
            fmt_sig(l.latency_s * 1e6, 3),
            fmt_sig(l.energy.total_j() * 1e6, 3),
            format!("{:.1}", l.utilization * 100.0),
        ]);
    }
    println!("SPOGA_10 hotspots:\n{}", t.render());

    // ---- energy breakdown ----------------------------------------------------
    let e = &f.energy;
    println!(
        "SPOGA_10 energy/frame: laser {:.1}µJ, tuning+bias {:.1}µJ, DAC {:.1}µJ, ADC {:.1}µJ, BPCA {:.1}µJ (DEAS/SRAM: none)",
        e.laser_j * 1e6,
        e.standing_j * 1e6,
        e.dac_j * 1e6,
        e.adc_j * 1e6,
        e.bpca_j * 1e6
    );
}
