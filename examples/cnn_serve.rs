//! Compile-once / stream-many CNN serving walkthrough.
//!
//! Compiles a `CnnPlan` per (model, backend) — surrogate weights packed
//! into `PackedB` planes at compile time — then streams a request burst
//! through the persistent scratch arena and the backends' direct-i8 entry.
//! Demonstrates: plan-cache reuse, bit-equality with the retained legacy
//! wire path, cross-backend logit agreement, and per-request photonic
//! telemetry riding the compiled path unchanged.
//!
//! Run: `cargo run --release --example cnn_serve [stream_len]`
//! (`stream_len` defaults to 64 frames.)

use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::fidelity::NoiseParams;
use spoga::report::{fmt_sig, Table};
use spoga::runtime::{
    run_cnn_batch_keyed, run_cnn_batch_keyed_reference, BackendKind, Engine, PhotonicConfig,
};

fn edge_model() -> CnnModel {
    CnnModel {
        name: "serve_edge",
        layers: vec![
            Layer::conv("stem", 12, 12, 3, 8, 3, 2, 1),
            Layer::dwconv("dw1", 6, 6, 8, 3, 1, 1),
            Layer::conv("pw1", 6, 6, 8, 16, 1, 1, 0),
            Layer::fc("head", 6 * 6 * 16, 10),
        ],
    }
}

fn main() {
    let stream_len: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(64);
    let dir = std::env::temp_dir().join(format!("spoga-cnn-serve-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "mlp_b1 m i32:1x16 i32:1x4\n").unwrap();

    let model = edge_model();
    let input_len = 12 * 12 * 3;
    let frames: Vec<Vec<i32>> = (0..stream_len)
        .map(|f| (0..input_len).map(|v| (((v * 31) + f * 97) % 251) as i32 - 125).collect())
        .collect();

    let backends = [
        ("software", BackendKind::Software),
        ("photonic", BackendKind::Photonic(PhotonicConfig::spoga())),
        (
            "photonic+noise",
            BackendKind::Photonic(
                PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), 0x5E2E),
            ),
        ),
    ];

    let mut t = Table::new(vec!["backend", "frames", "frames/s (plan)", "noise events"]);
    let mut logits_by_backend: Vec<Vec<i32>> = Vec::new();
    for (label, kind) in &backends {
        let mut eng = Engine::with_backend(&dir, kind.clone()).unwrap();
        // Compile once: the first request pays weight packing, the rest hit
        // the cached plan (full-model-equality revalidated).
        let plan = eng.cnn_plan(&model).unwrap();
        println!(
            "{label}: compiled plan for {} ({} layers, {} packed weight matrices)",
            model.name,
            model.layers.len(),
            plan.packed_matrices()
        );

        // Stream the burst in mixed batch sizes, like a coordinator would.
        let t0 = std::time::Instant::now();
        let mut served = 0usize;
        let mut noise_events = 0u64;
        let mut last_logits = Vec::new();
        for chunk in frames.chunks(5) {
            let refs: Vec<&[i32]> = chunk.iter().map(|f| f.as_slice()).collect();
            let runs = run_cnn_batch_keyed(&mut eng, &model, &refs, &[]).unwrap();
            served += runs.len();
            for r in &runs {
                if let Some(rep) = &r.report {
                    noise_events += rep.noise_events;
                }
            }
            last_logits = runs.last().unwrap().logits.clone();
        }
        let secs = t0.elapsed().as_secs_f64();

        // The retained legacy path must agree bit for bit on this stream's
        // final frame (the oracle `tests/cnn_plan.rs` pins exhaustively).
        let mut legacy_eng = Engine::with_backend(&dir, kind.clone()).unwrap();
        let last = vec![frames.last().unwrap().as_slice()];
        let legacy = run_cnn_batch_keyed_reference(&mut legacy_eng, &model, &last, &[]).unwrap();
        assert_eq!(legacy[0].logits, last_logits, "{label}: plan diverged from legacy path");

        t.row(vec![
            label.to_string(),
            served.to_string(),
            fmt_sig(served as f64 / secs, 3),
            noise_events.to_string(),
        ]);
        logits_by_backend.push(last_logits);
    }
    println!("{}", t.render());

    // Exact backends agree bit for bit; the noisy backend serves the analog
    // observation (decorrelated by design at 0 dB link margin).
    assert_eq!(logits_by_backend[0], logits_by_backend[1], "software vs photonic logits");
    println!(
        "software == photonic logits (bit-exact); noisy backend diverged on {} of {} outputs",
        logits_by_backend[0]
            .iter()
            .zip(&logits_by_backend[2])
            .filter(|(a, b)| a != b)
            .count(),
        logits_by_backend[0].len()
    );
    let _ = std::fs::remove_dir_all(&dir);
}
