//! Chaos walkthrough: mid-flight shard death, retained-payload retry,
//! revival and autoscaling — the serving fleet's resilience layer, live.
//!
//! Part 1 — mid-flight failover: a 2-shard fleet accepts a burst of async
//! `submit_*_retrying` requests, then shard 0's worker pool is killed while
//! its batching window still holds accepted jobs. Every slot must resolve
//! on the survivor with outputs bit-identical to an undisturbed 1-shard
//! run (`FleetTelemetry.resubmits` counts the rescued requests).
//!
//! Part 2 — revival: the dead shard's leader survives, so the fleet
//! respawns its worker pool, health-probes it, and routes traffic to it
//! again (`live_workers` gauge recovers).
//!
//! Part 3 — autoscaling: queue-depth pressure spawns a fresh shard from
//! the template config, up to the configured cap.
//!
//! Self-contained: synthesizes its artifact manifest in a temp directory.
//!
//! Run: `cargo run --release --example chaos_failover [requests]`

use std::sync::atomic::Ordering;
use std::time::Duration;

use spoga::coordinator::{
    CoordinatorConfig, Fleet, FleetAutoscale, FleetConfig, FleetHandle, RetryingSlot,
    RoutePolicy,
};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::runtime::BackendKind;
use spoga::testing::SplitMix64;

fn synthetic_artifacts() -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-chaos-example-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp artifact dir");
    std::fs::write(
        dir.join("manifest.txt"),
        "gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8\n\
         mlp_b1 m1.hlo.txt i32:1x16 i32:1x4\n\
         mlp_b8 m8.hlo.txt i32:8x16 i32:8x4\n",
    )
    .expect("write manifest");
    dir
}

fn tiny_cnn() -> CnnModel {
    CnnModel {
        name: "edge_probe",
        layers: vec![
            Layer::conv("stem", 6, 6, 3, 4, 3, 1, 1),
            Layer::fc("head", 6 * 6 * 4, 5),
        ],
    }
}

fn shard_cfg(artifact_dir: &str, window_s: f64) -> CoordinatorConfig {
    CoordinatorConfig {
        artifact_dir: artifact_dir.to_string(),
        workers: 2,
        backend: BackendKind::Software,
        max_batch_wait_s: window_s,
        ..Default::default()
    }
}

/// Deterministic mixed burst of retrying slots: GEMMs dispatch at once,
/// MLP rows and CNN frames gather in the batching window (the mid-flight
/// exposure).
fn submit_burst(h: &FleetHandle, requests: usize) -> Vec<RetryingSlot> {
    let mut rng = SplitMix64::new(11);
    let model = tiny_cnn();
    let mut slots = Vec::new();
    for _ in 0..requests / 3 {
        let a: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
        let b: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
        slots.push(h.submit_gemm_retrying("gemm_8x8x8", a, b).expect("submit gemm"));
    }
    for t in 0..requests / 3 {
        let row: Vec<i32> = (0..16).map(|v| ((v * 13 + t * 7) % 100) as i32).collect();
        slots.push(h.submit_mlp_retrying(row).expect("submit mlp"));
    }
    for f in 0..requests / 3 {
        let seed = f as i32;
        let input: Vec<i32> =
            (0..6 * 6 * 3).map(|v| ((v * 17 + seed * 71) % 251) - 125).collect();
        slots.push(h.submit_cnn_retrying(model.clone(), input).expect("submit cnn"));
    }
    slots
}

fn recv_all(slots: Vec<RetryingSlot>) -> Vec<Vec<i32>> {
    slots
        .into_iter()
        .map(|s| {
            s.recv_timeout(Duration::from_secs(30)).expect("slot resolves across chaos").outputs
        })
        .collect()
}

fn main() {
    let requests: usize =
        std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(24).max(9);
    let dir = synthetic_artifacts();
    let artifact_dir = dir.to_string_lossy().into_owned();

    // ---- part 1: kill a shard mid-flight, lose nothing --------------------
    println!("== chaos: {requests} retrying requests, shard 0 killed mid-window ==\n");

    let single = Fleet::single(shard_cfg(&artifact_dir, 0.0)).expect("reference fleet");
    let reference = recv_all(submit_burst(&single.handle(), requests));
    single.shutdown();

    let cfg = shard_cfg(&artifact_dir, 0.5);
    let fleet = Fleet::start(FleetConfig {
        shards: vec![cfg.clone(), cfg],
        policy: RoutePolicy::RoundRobin,
        labels: Vec::new(),
        ..Default::default()
    })
    .expect("2-shard fleet");
    let h = fleet.handle();
    let slots = submit_burst(&h, requests);
    // The burst is accepted; now the pool under half of it dies.
    h.shard(0).retire_workers().expect("retire shard 0");
    let served = recv_all(slots);
    assert_eq!(served, reference, "mid-flight retry changed served integers");

    let t = h.telemetry();
    assert!(t.resubmits > 0, "chaos case not exercised — no mid-flight resubmission");
    println!(
        "all {} slots resolved bit-identically to the undisturbed run ✓\n\
         mid-flight resubmissions: {} (shard 0 now out of rotation: {} live)\n",
        served.len(),
        t.resubmits,
        h.live_shard_count()
    );

    // ---- part 2: revive the dead shard ------------------------------------
    assert!(h.revive_shard(0), "revival must succeed — the leader is still alive");
    assert_eq!(h.shard_stats(0).live_workers.load(Ordering::Relaxed), 2);
    println!(
        "shard 0 revived: live_workers gauge back to {}, {} shards in rotation",
        h.shard_stats(0).live_workers.load(Ordering::Relaxed),
        h.live_shard_count()
    );
    let before = h.shard_stats(0).completed.load(Ordering::Relaxed);
    for i in 0..8 {
        h.infer_mlp(vec![i as i32; 16]).expect("revived fleet serves");
    }
    assert!(
        h.shard_stats(0).completed.load(Ordering::Relaxed) > before,
        "revived shard must take routed traffic"
    );
    println!("revived shard served routed traffic again ✓\n");
    fleet.shutdown();

    // ---- part 3: autoscale under pressure ----------------------------------
    // A long janitor interval keeps the demo deterministic: the explicit
    // maybe_scale_up below must not race a janitor tick for the cap.
    let auto = Fleet::start(FleetConfig::single(shard_cfg(&artifact_dir, 0.0)).with_autoscale(
        FleetAutoscale {
            revive: true,
            max_shards: 2,
            pressure_per_shard: 8,
            interval_s: 60.0,
            ..Default::default()
        },
    ))
    .expect("autoscale fleet");
    let ah = auto.handle();
    ah.shard_stats(0).requests.fetch_add(64, Ordering::Relaxed); // backlog
    assert!(ah.maybe_scale_up().expect("scale decision"), "pressure must spawn a shard");
    assert!(!ah.maybe_scale_up().expect("scale decision"), "cap must hold");
    for i in 0..8 {
        ah.infer_mlp(vec![i as i32; 16]).expect("scaled fleet serves");
    }
    let at = ah.telemetry();
    println!(
        "autoscale: {} shards (spawned {}), labels {:?}",
        at.shards.len(),
        at.shards_spawned,
        ah.shard_labels()
    );
    println!("\nfleet rollup:\n{}", at.summary());
    auto.shutdown();

    let _ = std::fs::remove_dir_all(&dir);
    println!("\nchaos_failover complete.");
}
