//! Fleet serving walkthrough: shard router, mixed backends, stats rollup,
//! and the noise-aware serving sweep.
//!
//! Part 1 — the acceptance demo: a 2-shard software|photonic fleet serves
//! a mixed GEMM / MLP / whole-CNN burst and must return exactly the same
//! integers as a 1-shard fleet over the same traffic (sharding and
//! t-stacked CNN batching never change served results). Per-shard stats
//! roll up into a `FleetTelemetry` whose totals equal the sum of the
//! shards.
//!
//! Part 2 — the noise-aware serving sweep over *link margins*: a fleet
//! built by `FleetConfig::noise_sweep` puts one photonic shard per link
//! margin, each injecting analog noise at that margin. Identical traffic
//! against every shard yields the served-accuracy vs sim-FPS/W trade
//! table — the serving-path counterpart of the offline `fidelity::study`.
//!
//! Part 3 — the full noise frontier over **K × ADC bits**
//! (`NoiseSweepGrid` → `FleetConfig::noise_grid`): one noise-injecting
//! shard per grid cell serves t-stacked CNN probe frames of its own
//! K-length dot products — batching stays ON under noise because the
//! backend attributes noise per output row — and the table reads served
//! accuracy against projected sim-FPS/W across the paper's
//! spatial-parallelism / ADC-resolution plane.
//!
//! Self-contained: synthesizes its artifact manifest in a temp directory.
//!
//! Run: `cargo run --release --example fleet_serve [requests] [grid]`
//! where `grid` is a `NoiseSweepGrid` spec like `K=74,249,adc=6,12`.

use std::sync::atomic::Ordering;
use std::time::Instant;

use spoga::coordinator::{
    CoordinatorConfig, Fleet, FleetConfig, FleetHandle, NoiseSweepGrid, Response, RoutePolicy,
};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::report::{fmt_sig, Table};
use spoga::runtime::{BackendKind, PhotonicConfig};
use spoga::testing::SplitMix64;

fn synthetic_artifacts() -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-fleet-serve-example-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp artifact dir");
    std::fs::write(
        dir.join("manifest.txt"),
        "gemm_64x64x64 gemm.hlo.txt i32:64x64,i32:64x64 i32:64x64\n\
         mlp_b1 mlp_b1.hlo.txt i32:1x784 i32:1x10\n\
         mlp_b8 mlp_b8.hlo.txt i32:8x784 i32:8x10\n",
    )
    .expect("write manifest");
    dir
}

fn edge_cnn() -> CnnModel {
    CnnModel {
        name: "edge_net",
        layers: vec![
            Layer::conv("stem", 16, 16, 3, 16, 3, 2, 1),
            Layer::dwconv("dw1", 8, 8, 16, 3, 1, 1),
            Layer::conv("pw1", 8, 8, 16, 32, 1, 1, 0),
            Layer::fc("head", 8 * 8 * 32, 10),
        ],
    }
}

fn shard_cfg(artifact_dir: &str, backend: BackendKind) -> CoordinatorConfig {
    CoordinatorConfig {
        artifact_dir: artifact_dir.to_string(),
        workers: 2,
        backend,
        max_batch_wait_s: 0.002,
        ..Default::default()
    }
}

/// Deterministic mixed burst (slot-based so CNN frames co-batch); returns
/// every reply's outputs in submission order.
fn mixed_burst(h: &FleetHandle, requests: usize) -> Vec<Vec<i32>> {
    let mut rng = SplitMix64::new(42);
    let model = edge_cnn();
    let mut slots: Vec<Response> = Vec::new();
    for _ in 0..requests {
        let row: Vec<i32> = (0..784).map(|_| rng.below(128) as i32).collect();
        slots.push(h.submit_mlp(row).expect("submit mlp"));
    }
    for _ in 0..requests.div_ceil(4) {
        let a: Vec<i32> = (0..64 * 64).map(|_| rng.i8() as i32).collect();
        let b: Vec<i32> = (0..64 * 64).map(|_| rng.i8() as i32).collect();
        slots.push(h.submit_gemm("gemm_64x64x64", a, b).expect("submit gemm"));
    }
    let input: Vec<i32> = (0..16 * 16 * 3).map(|v| (v % 251) - 125).collect();
    for _ in 0..requests.div_ceil(8) {
        slots.push(h.submit_cnn(model.clone(), input.clone()).expect("submit cnn"));
    }
    slots
        .into_iter()
        .map(|rx| rx.recv().expect("slot resolves").expect("request ok").outputs)
        .collect()
}

fn main() {
    let requests: usize =
        std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(32);
    let dir = synthetic_artifacts();
    let artifact_dir = dir.to_string_lossy().into_owned();

    // ---- part 1: 2-shard mixed-backend fleet vs 1-shard reference ----------
    println!("== fleet serve: {requests} MLP rows + GEMMs + CNN frames ==\n");

    let single = Fleet::single(shard_cfg(&artifact_dir, BackendKind::Software))
        .expect("single-shard fleet");
    let reference = mixed_burst(&single.handle(), requests);
    single.shutdown();

    let fleet = Fleet::start(FleetConfig {
        shards: vec![
            shard_cfg(&artifact_dir, BackendKind::Software),
            shard_cfg(&artifact_dir, BackendKind::Photonic(PhotonicConfig::spoga())),
        ],
        policy: RoutePolicy::Weighted(vec![1, 1]),
        labels: Vec::new(),
        ..Default::default()
    })
    .expect("2-shard fleet");
    let h = fleet.handle();
    let t0 = Instant::now();
    let served = mixed_burst(&h, requests);
    let wall = t0.elapsed().as_secs_f64();

    assert_eq!(served, reference, "sharded fleet diverged from single-shard serving");
    println!(
        "2-shard software|photonic fleet: {} replies in {wall:.3}s — bit-identical to 1-shard ✓",
        served.len()
    );

    let t = h.telemetry();
    let per_shard_total: u64 = t.shards.iter().map(|s| s.requests).sum();
    assert_eq!(t.requests(), per_shard_total, "rollup must equal sum of shards");
    let stacked: u64 = (0..h.shard_count())
        .map(|i| h.shard_stats(i).cnn_batches.load(Ordering::Relaxed))
        .sum();
    println!("stacked CNN micro-batches across shards: {stacked}");
    println!("\nfleet rollup:\n{}\n", t.summary());
    fleet.shutdown();

    // ---- part 2: noise-aware serving sweep ---------------------------------
    // Margins chosen to span the exactness transition for K≈64..288 GEMMs:
    // the PWAB weighting amplifies per-lane noise by up to 256×, so served
    // integers only go exact once the link margin is far above the 4-bit
    // sensitivity floor (the serving-path restatement of the paper's
    // byte-size-operand premise).
    let margins = [0.0, 40.0, 80.0, 100.0, 120.0];
    println!("== noise-aware serving sweep (SPOGA_10, link margins) ==\n");
    let sweep = Fleet::start(FleetConfig::noise_sweep(
        shard_cfg(&artifact_dir, BackendKind::Photonic(PhotonicConfig::spoga())),
        &margins,
    ))
    .expect("noise-sweep fleet");
    let sh = sweep.handle();

    let model = edge_cnn();
    let cnn_input: Vec<i32> = (0..16 * 16 * 3).map(|v| (v % 251) - 125).collect();
    // Identical traffic at every margin shard, bypassing routing: the sweep
    // is a controlled experiment, not a load balancer.
    for i in 0..sh.shard_count() {
        let shard = sh.shard(i);
        let mut rng = SplitMix64::new(7);
        for _ in 0..requests.div_ceil(4) {
            let a: Vec<i32> = (0..64 * 64).map(|_| rng.i8() as i32).collect();
            let b: Vec<i32> = (0..64 * 64).map(|_| rng.i8() as i32).collect();
            shard
                .gemm_reply("gemm_64x64x64", a, b)
                .expect("noisy gemm serves (noisily) without failing");
        }
        for _ in 0..requests.div_ceil(8).max(2) {
            shard.infer_cnn(model.clone(), cnn_input.clone()).expect("noisy cnn");
        }
    }

    let mut table = Table::new(vec![
        "link margin",
        "lanes",
        "noise events",
        "served-exact",
        "sim FPS",
        "sim FPS/W",
    ]);
    let sweep_t = sh.telemetry();
    for shard in &sweep_t.shards {
        table.row(vec![
            shard.label.clone(),
            shard.lanes.to_string(),
            shard.noise_events.to_string(),
            format!("{:.6}", shard.served_exact_fraction()),
            fmt_sig(shard.sim_fps(), 3),
            fmt_sig(shard.sim_fps_per_w(), 3),
        ]);
    }
    println!("{}", table.render());

    // Sanity: the sweep really trades accuracy — the 0 dB shard must see
    // no fewer noise events than the widest-margin shard.
    let first = &sweep_t.shards[0];
    let last = &sweep_t.shards[sweep_t.shards.len() - 1];
    assert!(
        first.noise_events >= last.noise_events,
        "noise events should not increase with link margin ({} vs {})",
        first.noise_events,
        last.noise_events
    );
    assert!(first.noise_events > 0, "0 dB margin must perturb served outputs");
    println!(
        "\nReading: served-exact is 1 − noise_events/lanes for the traffic actually\n\
         served; sim FPS / FPS/W are the projected figures for the same traffic on\n\
         the simulated accelerator. More link margin buys accuracy at constant\n\
         projected throughput — the serving-path view of the fidelity study."
    );

    sweep.shutdown();

    // ---- part 3: K × ADC-bits noise frontier -------------------------------
    // The full trade *curves* the ROADMAP's noise-aware study calls for:
    // served accuracy vs projected efficiency over the paper's
    // spatial-parallelism range and ADC resolutions, on the serving path.
    // Probe traffic is t-stacked CNN frames — stacking stays enabled under
    // noise because per-row attribution slices each frame's events exactly.
    let grid = match std::env::args().nth(2) {
        Some(spec) => NoiseSweepGrid::parse(&spec).expect("grid spec (e.g. K=74,249,adc=6,12)"),
        None => NoiseSweepGrid::parse("K=74,249,adc=6,12").expect("default grid"),
    };
    println!(
        "\n== noise frontier: K ∈ {:?} × adc bits ∈ {:?} (margin +{:.0} dB) ==\n",
        grid.ks, grid.adc_bits, grid.margin_db
    );
    let frontier = Fleet::start(FleetConfig::noise_grid(
        shard_cfg(&artifact_dir, BackendKind::Photonic(PhotonicConfig::spoga())),
        &grid,
    ))
    .expect("noise-grid fleet");
    let fh = frontier.handle();
    let frames = requests.div_ceil(2).max(8);
    let served_frames = grid.drive(&fh, frames).expect("grid probe traffic");
    assert_eq!(served_frames, frames * grid.cells().len());

    println!("{}", grid.frontier_table(&fh).render());
    let ft = fh.telemetry();

    // Acceptance: CNN stacking must stay on under noise injection — before
    // per-row attribution the coordinator forced these frames unbatched.
    let stacks: u64 = (0..fh.shard_count())
        .map(|i| fh.shard_stats(i).cnn_batches.load(Ordering::Relaxed))
        .sum();
    assert!(stacks > 0, "noisy shards served no stacked CNN batches");
    // ... and the frontier really trades: the easiest cell (smallest K,
    // most ADC bits) must serve at least as exactly as the hardest one.
    let cells = grid.cells();
    let cell_exact = |k: usize, bits: u32| {
        let i = cells.iter().position(|&c| c == (k, bits)).expect("cell present");
        ft.shards[i].served_exact_fraction()
    };
    let best = cell_exact(
        *grid.ks.iter().min().unwrap(),
        *grid.adc_bits.iter().max().unwrap(),
    );
    let worst = cell_exact(
        *grid.ks.iter().max().unwrap(),
        *grid.adc_bits.iter().min().unwrap(),
    );
    assert!(
        best >= worst,
        "frontier inverted: best cell {best} vs worst cell {worst}"
    );
    println!(
        "Reading: each cell serves its own K-length dot products through a noisy\n\
         photonic shard; served-exact is per-request-attributed (stacked CNN batches\n\
         included), so the table is the live accuracy-vs-efficiency frontier over\n\
         the paper's K × ADC plane."
    );

    frontier.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    println!("\nfleet_serve complete.");
}
