//! Live backend matrix: the same traffic served by every execution backend.
//!
//! Starts one coordinator per backend — software interpreter, then the
//! photonic-in-the-loop simulator configured as SPOGA, HOLYLIGHT and
//! DEAPCNN — fires an identical GEMM + MLP + whole-CNN workload at each,
//! verifies all backends return bit-identical integers, and prints the
//! wall-clock serving numbers next to the *projected* photonic FPS and
//! FPS/W each design point would deliver for exactly this traffic.
//!
//! Self-contained: synthesizes its artifact manifest in a temp directory
//! (backends plan from manifest signatures), so no `make artifacts` needed.
//!
//! Run: `cargo run --release --example backend_matrix [requests]`

use std::sync::atomic::Ordering;
use std::time::Instant;

use spoga::coordinator::{Coordinator, CoordinatorConfig};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::metrics::LiveTelemetry;
use spoga::report::{fmt_sig, Table};
use spoga::runtime::{BackendKind, PhotonicConfig};
use spoga::testing::SplitMix64;

fn synthetic_artifacts() -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("spoga-backend-matrix-example-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp artifact dir");
    std::fs::write(
        dir.join("manifest.txt"),
        "gemm_64x64x64 gemm.hlo.txt i32:64x64,i32:64x64 i32:64x64\n\
         mlp_b1 mlp_b1.hlo.txt i32:1x784 i32:1x10\n\
         mlp_b8 mlp_b8.hlo.txt i32:8x784 i32:8x10\n",
    )
    .expect("write manifest");
    dir
}

fn edge_cnn() -> CnnModel {
    CnnModel {
        name: "edge_net",
        layers: vec![
            Layer::conv("stem", 16, 16, 3, 16, 3, 2, 1),
            Layer::dwconv("dw1", 8, 8, 16, 3, 1, 1),
            Layer::conv("pw1", 8, 8, 16, 32, 1, 1, 0),
            Layer::fc("head", 8 * 8 * 32, 10),
        ],
    }
}

fn main() {
    let requests: usize =
        std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(32);
    let dir = synthetic_artifacts();
    let artifact_dir = dir.to_string_lossy().into_owned();
    let model = edge_cnn();
    println!(
        "== backend matrix: {requests} MLP rows + {requests}/4 GEMMs + {requests}/8 CNN frames per backend ==\n"
    );

    let backends: Vec<(&str, BackendKind)> = vec![
        ("software", BackendKind::Software),
        ("SPOGA_10", BackendKind::Photonic(PhotonicConfig::spoga())),
        ("HOLYLIGHT_10", BackendKind::Photonic(PhotonicConfig::holylight())),
        ("DEAPCNN_10", BackendKind::Photonic(PhotonicConfig::deapcnn())),
    ];

    let mut table = Table::new(vec![
        "Backend",
        "wall req/s",
        "service µs",
        "CNN sim FPS",
        "CNN sim FPS/W",
        "lanes",
    ]);
    let mut reference: Option<(Vec<i32>, Vec<i32>, Vec<i32>)> = None;

    for (label, kind) in backends {
        let c = Coordinator::start(CoordinatorConfig {
            artifact_dir: artifact_dir.clone(),
            workers: 2,
            backend: kind,
            max_batch_wait_s: 0.002,
            ..Default::default()
        })
        .expect("coordinator");
        let h = c.handle();

        let mut rng = SplitMix64::new(7);
        let t0 = Instant::now();

        // MLP rows (batchable traffic).
        let mut last_mlp = Vec::new();
        for _ in 0..requests {
            let row: Vec<i32> = (0..784).map(|_| rng.below(128) as i32).collect();
            last_mlp = h.infer_mlp(row).expect("mlp");
        }

        // Raw GEMMs.
        let mut last_gemm = Vec::new();
        for _ in 0..requests.div_ceil(4) {
            let a: Vec<i32> = (0..64 * 64).map(|_| rng.i8() as i32).collect();
            let b: Vec<i32> = (0..64 * 64).map(|_| rng.i8() as i32).collect();
            last_gemm = h.gemm("gemm_64x64x64", a, b).expect("gemm");
        }

        // Whole-CNN frames, collecting the live photonic projection.
        let mut live = LiveTelemetry::default();
        let mut last_cnn = Vec::new();
        let input: Vec<i32> = (0..16 * 16 * 3).map(|v| (v % 251) - 125).collect();
        for _ in 0..requests.div_ceil(8) {
            let reply = h.infer_cnn(model.clone(), input.clone()).expect("cnn");
            if let Some(r) = &reply.report {
                live.add(r);
            }
            last_cnn = reply.outputs;
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = requests + requests.div_ceil(4) + requests.div_ceil(8);

        // Every backend must serve the same integers.
        match &reference {
            None => reference = Some((last_mlp, last_gemm, last_cnn)),
            Some((m, g, cnn)) => {
                assert_eq!(&last_mlp, m, "{label}: MLP outputs diverged");
                assert_eq!(&last_gemm, g, "{label}: GEMM outputs diverged");
                assert_eq!(&last_cnn, cnn, "{label}: CNN logits diverged");
            }
        }

        let s = h.stats();
        table.row(vec![
            label.to_string(),
            fmt_sig(total as f64 / wall, 3),
            format!("{:.1}", s.service_mean() * 1e6),
            if live.frames > 0 { fmt_sig(live.fps(), 3) } else { "-".into() },
            if live.frames > 0 { fmt_sig(live.fps_per_w(), 3) } else { "-".into() },
            format!("{}", live.lanes),
        ]);
        println!(
            "{label:>12}: {} (completed {})",
            s.summary(),
            s.completed.load(Ordering::Relaxed)
        );
        c.shutdown();
    }

    println!("\nAll backends returned bit-identical outputs ✓\n");
    println!("{}", table.render());
    println!(
        "\nReading: wall req/s is this host's serving throughput; the sim columns are\n\
         the projected performance of the same CNN traffic on each photonic design\n\
         point (per-request ExecReport telemetry aggregated by metrics::LiveTelemetry)."
    );

    let _ = std::fs::remove_dir_all(&dir);
}
