//! Reproduce **Table I** and explore the scalability space beyond it.
//!
//! Prints the paper's table (solved vs published), then two sweeps the
//! paper's text discusses but does not tabulate: required laser power vs
//! vector size, and the N×M parallelism frontier per architecture.
//!
//! Run: `cargo run --release --example scalability_table`

use spoga::optics::link_budget::{ArchClass, LinkBudget};
use spoga::optics::{paper_table1, solve_table1};
use spoga::report::{fmt_sig, Table};
use spoga::units::DataRate;

fn main() {
    // ---- Table I ------------------------------------------------------------
    let solved = solve_table1();
    let paper = paper_table1();
    let mut t = Table::new(vec!["Architecture", "1 GS/s", "5 GS/s", "10 GS/s", "paper (1/5/10)"]);
    let mut exact = true;
    for (s, p) in solved.rows.iter().zip(paper.rows.iter()) {
        let c = |nm: (usize, usize)| format!("{}x{}", nm.0, nm.1);
        exact &= s.nm == p.nm;
        t.row(vec![
            s.label.clone(),
            c(s.nm[0]),
            c(s.nm[1]),
            c(s.nm[2]),
            format!("{} / {} / {}", c(p.nm[0]), c(p.nm[1]), c(p.nm[2])),
        ]);
    }
    println!("Table I — scalability (solved from the link-budget model):\n{}", t.render());
    println!("cell-for-cell match with the paper: {}\n", if exact { "YES" } else { "NO" });

    // ---- Required laser power vs N (MWA, the paper's §IV-A trade-off) ------
    let lb = LinkBudget::spoga();
    let mut t = Table::new(vec!["N (OAMEs/DPU)", "P @1GS/s (dBm)", "P @5GS/s", "P @10GS/s"]);
    for n in [16, 32, 64, 94, 128, 163, 187, 249] {
        let p = |dr| {
            lb.required_laser_dbm(n, 16, dr)
                .map(|v| fmt_sig(v, 3))
                .unwrap_or_else(|_| "-".into())
        };
        t.row(vec![
            n.to_string(),
            p(DataRate::Gs1),
            p(DataRate::Gs5),
            p(DataRate::Gs10),
        ]);
    }
    println!("Required per-λ laser power to close the SPOGA budget:\n{}", t.render());

    // ---- Parallelism frontier ----------------------------------------------
    let mut t = Table::new(vec!["Architecture", "BR", "N×M (4-bit ops/step)", "INT8 MACs/step"]);
    for arch in [ArchClass::Maw, ArchClass::Amw, ArchClass::Mwa] {
        let lb = LinkBudget::for_arch(arch);
        for dr in DataRate::ALL {
            let (n, m) = match arch {
                ArchClass::Mwa => (lb.max_n_given_m(16, dr, 10.0), 16),
                _ => {
                    let s = lb.max_square(dr, 10.0);
                    (s, s)
                }
            };
            // Baselines do INT4 ops; an INT8 MAC costs a quadruplet of them.
            let int8 = match arch {
                ArchClass::Mwa => n * m,
                _ => n * m / 4,
            };
            t.row(vec![
                lb.arch.name().to_string(),
                format!("{dr}"),
                format!("{}", n * m),
                int8.to_string(),
            ]);
        }
    }
    println!("Parallelism frontier at 10 dBm lasers:\n{}", t.render());
}
