//! Cross-host fleet walkthrough: a shard server fronting a local fleet
//! over TCP, a client fleet mixing local and remote shards, and graceful
//! degradation when the remote side goes away.
//!
//! Part 1 — serve over the wire: a 2-shard "backend" fleet is exposed on a
//! loopback socket by a `ShardServer` (spoga wire protocol: checksummed,
//! length-prefixed frames over std TCP — no tokio, no serde). A client
//! fleet with one *local* shard and one *remote* slot pointing at that
//! socket serves a mixed GEMM/MLP/CNN burst bit-identically to an all-local
//! reference: the transport is invisible to served integers (the
//! local-vs-remote equivalence contract in `coordinator::router`).
//!
//! Part 2 — degradation: the server is shut down, so the remote slot's
//! next submit fails with a retirable `Error::Remote` kind. The router
//! marks the slot dead, reroutes the retained payload to the surviving
//! local shard (`submit_reroutes` counts it), and the burst still resolves
//! bit-identically. No request is lost; the fleet just got smaller.
//!
//! Self-contained: synthesizes its artifact manifest in a temp directory
//! and binds port 0 (the OS picks a free port).
//!
//! Run: `cargo run --release --example remote_fleet [requests]`

use std::time::Duration;

use spoga::coordinator::{
    CoordinatorConfig, Fleet, FleetConfig, FleetHandle, RemoteShardConfig, RoutePolicy,
};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::net::{NetConfig, ServeTarget, ShardServer};
use spoga::runtime::BackendKind;
use spoga::testing::SplitMix64;

fn synthetic_artifacts() -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-remote-example-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp artifact dir");
    std::fs::write(
        dir.join("manifest.txt"),
        "gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8\n\
         mlp_b1 m1.hlo.txt i32:1x16 i32:1x4\n\
         mlp_b8 m8.hlo.txt i32:8x16 i32:8x4\n",
    )
    .expect("write manifest");
    dir
}

fn tiny_cnn() -> CnnModel {
    CnnModel {
        name: "edge_probe",
        layers: vec![
            Layer::conv("stem", 6, 6, 3, 4, 3, 1, 1),
            Layer::fc("head", 6 * 6 * 4, 5),
        ],
    }
}

fn shard_cfg(artifact_dir: &str) -> CoordinatorConfig {
    CoordinatorConfig {
        artifact_dir: artifact_dir.to_string(),
        workers: 2,
        backend: BackendKind::Software,
        max_batch_wait_s: 0.0,
        ..Default::default()
    }
}

/// Deterministic mixed burst through retrying slots (the failover-capable
/// submit path), resolved in submission order.
fn mixed_burst(h: &FleetHandle, requests: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = SplitMix64::new(seed);
    let model = tiny_cnn();
    let mut slots = Vec::new();
    for i in 0..requests {
        match i % 3 {
            0 => {
                let a: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
                let b: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
                slots.push(h.submit_gemm_retrying("gemm_8x8x8", a, b).expect("gemm"));
            }
            1 => {
                let row: Vec<i32> = (0..16).map(|v| ((v + i) % 100) as i32).collect();
                slots.push(h.submit_mlp_retrying(row).expect("mlp"));
            }
            _ => {
                let input: Vec<i32> = (0..6 * 6 * 3)
                    .map(|v| ((v * 17 + (i as i32) * 7) % 251) - 125)
                    .collect();
                slots.push(h.submit_cnn_retrying(model.clone(), input).expect("cnn"));
            }
        }
    }
    slots
        .into_iter()
        .map(|s| s.recv_timeout(Duration::from_secs(30)).expect("slot resolves").outputs)
        .collect()
}

fn main() {
    let requests: usize =
        std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(24).max(6);
    let dir = synthetic_artifacts();
    let artifact_dir = dir.to_string_lossy().into_owned();

    // Reference: an all-local 2-shard fleet over the same artifacts.
    let reference_fleet = Fleet::start(FleetConfig {
        shards: vec![shard_cfg(&artifact_dir), shard_cfg(&artifact_dir)],
        ..Default::default()
    })
    .expect("reference fleet");
    let reference = mixed_burst(&reference_fleet.handle(), requests, 0xBEE5);
    reference_fleet.shutdown();

    // ---- part 1: one fleet behind a socket, one mixed fleet in front ------
    let backend = Fleet::start(FleetConfig {
        shards: vec![shard_cfg(&artifact_dir), shard_cfg(&artifact_dir)],
        ..Default::default()
    })
    .expect("backend fleet");
    let server =
        ShardServer::start("127.0.0.1:0", ServeTarget::Fleet(backend.handle()), NetConfig::default())
            .expect("shard server");
    let addr = server.local_addr().to_string();
    println!("== shard server listening on {addr} ==\n");

    let mixed = Fleet::start(FleetConfig {
        shards: vec![shard_cfg(&artifact_dir)],
        remotes: vec![RemoteShardConfig::new(addr.clone())],
        policy: RoutePolicy::RoundRobin,
        ..Default::default()
    })
    .expect("mixed local+remote fleet");
    let h = mixed.handle();
    println!("client fleet shards: {:?}", h.shard_labels());
    h.ping(Duration::from_secs(5)).expect("fleet pongs (local or remote)");

    let served = mixed_burst(&h, requests, 0xBEE5);
    assert_eq!(
        served, reference,
        "remote transport changed served integers — equivalence contract broken"
    );
    println!(
        "{} mixed requests served bit-identically across 1 local + 1 remote shard ✓",
        served.len()
    );
    let t = h.telemetry();
    for s in &t.shards {
        println!("  {}: {} completed", s.label, s.completed);
    }

    // ---- part 2: the remote side goes away ---------------------------------
    println!("\n== shutting the server down; traffic must drain to the local shard ==");
    server.shutdown();
    backend.shutdown();

    let served = mixed_burst(&h, requests, 0xD1ED);
    let reference_fleet = Fleet::single(shard_cfg(&artifact_dir)).expect("reference");
    let reference = mixed_burst(&reference_fleet.handle(), requests, 0xD1ED);
    reference_fleet.shutdown();
    assert_eq!(served, reference, "degraded serving changed served integers");

    let t = h.telemetry();
    assert_eq!(h.live_shard_count(), 1, "dead remote slot must leave the rotation");
    assert!(
        t.submit_reroutes + t.resubmits > 0,
        "degradation path not exercised — no payload moved shards"
    );
    println!(
        "served {} requests with the remote shard dead ✓ (reroutes={} resubmits={}, \
         {} of {} shards live)",
        served.len(),
        t.submit_reroutes,
        t.resubmits,
        h.live_shard_count(),
        t.shards.len()
    );
    println!("\nfleet rollup:\n{}", t.summary());

    mixed.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    println!("\nremote_fleet complete.");
}
