//! Quickstart: the three-layer stack in one page.
//!
//! 1. The exact integer semantics of SPOGA's nibble-sliced dataflow
//!    (`spoga::bitslice`) — no hardware needed.
//! 2. An AOT artifact (Pallas kernel → JAX → HLO text) executed through the
//!    PJRT runtime and checked against the golden model.
//! 3. The analytical models: one Table I row and one simulated CNN frame.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use spoga::arch::accel::Accelerator;
use spoga::bitslice::{gemm_i32, gemm_lanes};
use spoga::dnn::models::resnet50;
use spoga::optics::link_budget::{ArchClass, LinkBudget};
use spoga::sim::engine::simulate_frame;
use spoga::units::DataRate;

fn main() {
    // ---- 1. the SPOGA dataflow, exactly -----------------------------------
    let a: Vec<i8> = vec![-128, 127, 3, -4, 55, -66]; // 2×3
    let b: Vec<i8> = vec![9, -8, 127]; // 3×1
    let direct = gemm_i32(&a, &b, 2, 3, 1).unwrap();
    let lanes = gemm_lanes(&a, &b, 2, 3, 1).unwrap();
    println!("lanes (unweighted BPCA charges): hi={:?} mid={:?} lo={:?}", lanes.hi, lanes.mid, lanes.lo);
    println!("PWAB output  : {:?}", lanes.weight_and_add());
    println!("digital gemm : {direct:?}");
    assert_eq!(lanes.weight_and_add(), direct);

    // ---- 2. AOT artifact through PJRT --------------------------------------
    match spoga::runtime::Engine::new("artifacts") {
        Ok(mut eng) => {
            let m = 128;
            let k = 249; // one full DPU vector
            let n = 16; // one DPU per output column
            let a: Vec<i32> = (0..m * k).map(|i| (i % 255) as i32 - 127).collect();
            let b: Vec<i32> = (0..k * n).map(|i| (i % 253) as i32 - 126).collect();
            let out = eng.execute_i32_single("gemm_128x249x16", &[&a, &b]).unwrap();
            let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
            let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
            assert_eq!(out, gemm_i32(&a8, &b8, m, k, n).unwrap());
            println!("\nPJRT artifact gemm_128x249x16 == golden model ✓ (platform {})", eng.platform());
        }
        Err(e) => println!("\n(skipping PJRT demo — {e})"),
    }

    // ---- 3. analytical models ----------------------------------------------
    let lb = LinkBudget::spoga();
    let n = lb.max_n_given_m(16, DataRate::Gs10, 10.0);
    println!("\nSPOGA DPU vector size at 10 GS/s, 10 dBm: N = {n} (paper: 160)");

    let accel = Accelerator::equal_cores(ArchClass::Mwa, DataRate::Gs10, 64).unwrap();
    let frame = simulate_frame(&accel, &resnet50().workload());
    println!(
        "ResNet-50 on {}×64 cores: {:.0} FPS, {:.1} W avg, {:.3} J/frame",
        accel.name,
        frame.fps(),
        frame.avg_power_w(),
        frame.energy.total_j()
    );
}
