//! Request/response types flowing through the coordinator.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::Instant;

use crate::Result;

/// Response slot: a bounded(1) channel the worker fulfils exactly once.
pub type Response = Receiver<Result<Vec<i32>>>;

pub(crate) type ResponseTx = SyncSender<Result<Vec<i32>>>;

/// Create a response slot pair.
pub(crate) fn response_slot() -> (ResponseTx, Response) {
    sync_channel(1)
}

/// A raw GEMM request against a named GEMM artifact.
#[derive(Debug)]
pub struct GemmJob {
    /// Artifact name (e.g. "gemm_64x64x64").
    pub artifact: String,
    /// Flat row-major A operand (int8 values in i32 wire format).
    pub a: Vec<i32>,
    /// Flat row-major B operand.
    pub b: Vec<i32>,
    /// Where to deliver the result.
    pub(crate) reply: ResponseTx,
    /// Enqueue timestamp (latency accounting).
    pub(crate) enqueued: Instant,
}

/// A single-row MLP inference request (the batchable kind).
#[derive(Debug)]
pub struct MlpJob {
    /// One activation row (784 int8 values in i32 wire format).
    pub row: Vec<i32>,
    /// Where to deliver the logits (10 × i32).
    pub(crate) reply: ResponseTx,
    /// Enqueue timestamp.
    pub(crate) enqueued: Instant,
}

/// Anything the leader thread can route.
#[derive(Debug)]
pub enum Job {
    /// Unbatched GEMM execution.
    Gemm(GemmJob),
    /// Batchable MLP row.
    Mlp(MlpJob),
    /// Drain and stop (sent by [`super::Coordinator::shutdown`]).
    Shutdown,
}

impl Job {
    /// Age of the job since enqueue, seconds (Shutdown has no age).
    pub fn age_s(&self, now: Instant) -> f64 {
        match self {
            Job::Gemm(g) => now.duration_since(g.enqueued).as_secs_f64(),
            Job::Mlp(m) => now.duration_since(m.enqueued).as_secs_f64(),
            Job::Shutdown => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_slot_roundtrip() {
        let (tx, rx) = response_slot();
        tx.send(Ok(vec![1, 2, 3])).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn job_age_increases() {
        let (tx, _rx) = response_slot();
        let j = Job::Mlp(MlpJob { row: vec![0; 4], reply: tx, enqueued: Instant::now() });
        let a1 = j.age_s(Instant::now());
        std::thread::sleep(std::time::Duration::from_millis(2));
        let a2 = j.age_s(Instant::now());
        assert!(a2 > a1);
        assert_eq!(Job::Shutdown.age_s(Instant::now()), 0.0);
    }
}
