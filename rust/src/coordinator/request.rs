//! Request/response types flowing through the coordinator.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::{Duration, Instant};

use crate::dnn::models::CnnModel;
use crate::runtime::backend::ExecReport;
use crate::runtime::cnnrun::LayerReport;
use crate::Result;

/// A fulfilled request: the outputs plus any photonic telemetry the
/// executing backend attached.
#[derive(Debug, Clone)]
pub struct Reply {
    /// Flat row-major int32 outputs (logits for MLP/CNN jobs).
    pub outputs: Vec<i32>,
    /// Aggregate photonic projection for this request (`None` when served
    /// by a digital backend). Batched MLP rows share their micro-batch's
    /// projected cost, but under noise injection each member carries *its
    /// own* row's `noise_events`/`lanes`/`row_noise` (see
    /// [`crate::runtime::backend::ExecReport::for_row`]).
    pub report: Option<ExecReport>,
    /// Per-layer telemetry — populated for [`Job::Cnn`] on reporting
    /// backends, empty otherwise.
    pub layers: Vec<LayerReport>,
}

impl Reply {
    /// A reply with outputs only (digital backends).
    pub fn bare(outputs: Vec<i32>) -> Self {
        Reply { outputs, report: None, layers: Vec::new() }
    }
}

/// Per-request service class. The default is [`Priority::High`] so every
/// pre-QoS caller keeps first-class semantics; [`Priority::BestEffort`] is
/// the opt-in degraded class that sheds first under overload (see
/// [`CoordinatorConfig::best_effort_watermark`](super::CoordinatorConfig))
/// and drains after high-priority jobs within a gathering window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// First-class traffic: drained first, shed last.
    #[default]
    High,
    /// Degraded class: shed first at the admission watermark, drained
    /// after every high-priority member of the same window.
    BestEffort,
}

/// Per-request quality-of-service envelope: a service class plus an
/// optional deadline measured from enqueue. `Qos::default()` is
/// high-priority with no deadline — exactly the pre-QoS behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Qos {
    /// Service class (drain order + shed order under overload).
    pub priority: Priority,
    /// Deadline measured from the enqueue timestamp. The leader fails a
    /// job typed ([`crate::Error::DeadlineExceeded`]) once
    /// `enqueued.elapsed() >= deadline`, *before* dispatch, and flushes a
    /// gathering window early when its oldest member would otherwise miss
    /// its deadline. `None` = wait indefinitely.
    pub deadline: Option<Duration>,
}

impl Qos {
    /// Best-effort class, no deadline.
    pub fn best_effort() -> Self {
        Qos { priority: Priority::BestEffort, deadline: None }
    }

    /// This QoS with a deadline attached.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// Response slot: a bounded(1) channel the worker fulfils exactly once.
pub type Response = Receiver<Result<Reply>>;

pub(crate) type ResponseTx = SyncSender<Result<Reply>>;

/// Create a response slot pair.
pub(crate) fn response_slot() -> (ResponseTx, Response) {
    sync_channel(1)
}

/// A raw GEMM request against a named GEMM artifact.
#[derive(Debug)]
pub struct GemmJob {
    /// Artifact name (e.g. "gemm_64x64x64").
    pub artifact: String,
    /// Flat row-major A operand (int8 values in i32 wire format).
    pub a: Vec<i32>,
    /// Flat row-major B operand.
    pub b: Vec<i32>,
    /// Where to deliver the result.
    pub(crate) reply: ResponseTx,
    /// Enqueue timestamp (latency accounting).
    pub(crate) enqueued: Instant,
    /// Per-request noise nonce (0 = content-keyed default; nonzero only
    /// when [`CoordinatorConfig::noise_nonce`](super::CoordinatorConfig)
    /// opts into the time-indexed counter mode).
    pub(crate) nonce: u64,
    /// Priority + optional deadline (see [`Qos`]).
    pub(crate) qos: Qos,
}

/// A single-row MLP inference request (the batchable kind).
#[derive(Debug)]
pub struct MlpJob {
    /// One activation row (784 int8 values in i32 wire format).
    pub row: Vec<i32>,
    /// Where to deliver the logits (10 × i32).
    pub(crate) reply: ResponseTx,
    /// Enqueue timestamp.
    pub(crate) enqueued: Instant,
    /// Per-request noise nonce (0 = content-keyed default).
    pub(crate) nonce: u64,
    /// Priority + optional deadline (see [`Qos`]).
    pub(crate) qos: Qos,
}

/// A whole-CNN inference request: the model runs im2col layer-by-layer
/// through the worker's backend ([`crate::runtime::cnnrun::run_cnn`]).
#[derive(Debug)]
pub struct CnnJob {
    /// The network to run (built-in model or parsed trace).
    pub model: CnnModel,
    /// First-layer activation tensor, HWC wire format.
    pub input: Vec<i32>,
    /// Where to deliver the logits + per-layer telemetry.
    pub(crate) reply: ResponseTx,
    /// Enqueue timestamp.
    pub(crate) enqueued: Instant,
    /// Per-request noise nonce (0 = content-keyed default).
    pub(crate) nonce: u64,
    /// Priority + optional deadline (see [`Qos`]).
    pub(crate) qos: Qos,
}

/// A health probe: the leader routes it to a worker like any other item and
/// the worker answers with an empty [`Reply`] — proving the whole
/// leader→dispatch→worker path is alive without touching artifacts. Pings
/// deliberately stay out of the request/completed counters so probing a
/// shard never skews its routing or serving stats.
#[derive(Debug)]
pub struct PingJob {
    /// Where to deliver the pong.
    pub(crate) reply: ResponseTx,
}

/// Anything the leader thread can route.
#[derive(Debug)]
pub enum Job {
    /// Unbatched GEMM execution.
    Gemm(GemmJob),
    /// Batchable MLP row.
    Mlp(MlpJob),
    /// Whole-CNN inference (same-model frames co-batch along the
    /// t-dimension when the backend serves exact integers).
    Cnn(CnnJob),
    /// Retire every worker from the rotation (maintenance drain / fault
    /// injection): workers finish their queued items and exit; later jobs
    /// fail with a "no live workers" error so a fleet router fails over.
    RetireWorkers,
    /// Respawn workers until the pool holds `target` again (revival after
    /// [`Job::RetireWorkers`] or worker deaths — the leader survives both,
    /// so the shard can re-enter a fleet's rotation without restarting).
    ReviveWorkers {
        /// Desired worker-pool size after revival.
        target: usize,
    },
    /// Health probe routed through the worker pool (see [`PingJob`]).
    Ping(PingJob),
    /// Drain and stop (sent by [`super::Coordinator::shutdown`]).
    Shutdown,
}

impl Job {
    /// Age of the job since enqueue, seconds (control jobs have no age).
    pub fn age_s(&self, now: Instant) -> f64 {
        match self {
            Job::Gemm(g) => now.duration_since(g.enqueued).as_secs_f64(),
            Job::Mlp(m) => now.duration_since(m.enqueued).as_secs_f64(),
            Job::Cnn(c) => now.duration_since(c.enqueued).as_secs_f64(),
            Job::RetireWorkers | Job::ReviveWorkers { .. } | Job::Ping(_) | Job::Shutdown => 0.0,
        }
    }

    /// Service class (control jobs are high-priority: they must never shed).
    pub fn priority(&self) -> Priority {
        match self {
            Job::Gemm(g) => g.qos.priority,
            Job::Mlp(m) => m.qos.priority,
            Job::Cnn(c) => c.qos.priority,
            Job::RetireWorkers | Job::ReviveWorkers { .. } | Job::Ping(_) | Job::Shutdown => {
                Priority::High
            }
        }
    }
}

/// The instant a job's deadline lands, `None` when it has none.
/// Shared by request jobs; control jobs never expire.
pub(crate) fn deadline_at(enqueued: Instant, qos: &Qos) -> Option<Instant> {
    qos.deadline.map(|d| enqueued + d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_slot_roundtrip() {
        let (tx, rx) = response_slot();
        tx.send(Ok(Reply::bare(vec![1, 2, 3]))).unwrap();
        let reply = rx.recv().unwrap().unwrap();
        assert_eq!(reply.outputs, vec![1, 2, 3]);
        assert!(reply.report.is_none() && reply.layers.is_empty());
    }

    #[test]
    fn job_age_increases() {
        let (tx, _rx) = response_slot();
        let j = Job::Mlp(MlpJob {
            row: vec![0; 4],
            reply: tx,
            enqueued: Instant::now(),
            nonce: 0,
            qos: Qos::default(),
        });
        let a1 = j.age_s(Instant::now());
        std::thread::sleep(std::time::Duration::from_millis(2));
        let a2 = j.age_s(Instant::now());
        assert!(a2 > a1);
        assert_eq!(Job::Shutdown.age_s(Instant::now()), 0.0);
        assert_eq!(Job::ReviveWorkers { target: 2 }.age_s(Instant::now()), 0.0);
        let (ptx, _prx) = response_slot();
        assert_eq!(Job::Ping(PingJob { reply: ptx }).age_s(Instant::now()), 0.0);
    }

    #[test]
    fn cnn_job_age_tracked() {
        let (tx, _rx) = response_slot();
        let j = Job::Cnn(CnnJob {
            model: crate::dnn::models::CnnModel { name: "t", layers: vec![] },
            input: vec![],
            reply: tx,
            enqueued: Instant::now(),
            nonce: 0,
            qos: Qos::default(),
        });
        assert!(j.age_s(Instant::now()) >= 0.0);
    }

    #[test]
    fn qos_defaults_are_pre_qos_behaviour() {
        let q = Qos::default();
        assert_eq!(q.priority, Priority::High);
        assert!(q.deadline.is_none());
        let be = Qos::best_effort().with_deadline(Duration::from_millis(5));
        assert_eq!(be.priority, Priority::BestEffort);
        assert_eq!(be.deadline, Some(Duration::from_millis(5)));
        // Control jobs are pinned high-priority so they never shed.
        assert_eq!(Job::Shutdown.priority(), Priority::High);
        let t0 = Instant::now();
        assert_eq!(deadline_at(t0, &q), None);
        assert_eq!(deadline_at(t0, &be), Some(t0 + Duration::from_millis(5)));
    }
}
