//! Worker threads: each owns an execution engine and executes dispatched
//! work. With the software backend every GEMM a worker runs routes through
//! the packed bit-sliced fast path (see [`crate::runtime::software`]).

use std::sync::atomic::Ordering;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::batcher::MicroBatch;
use crate::coordinator::request::GemmJob;
use crate::coordinator::stats::CoordinatorStats;
use crate::runtime::Engine;

/// Work items dispatched by the leader to a worker.
#[derive(Debug)]
pub enum WorkItem {
    /// A packed MLP micro-batch.
    Batch(MicroBatch),
    /// An unbatched GEMM.
    Gemm(GemmJob),
    /// Stop the worker.
    Shutdown,
}

/// Worker main loop: construct the engine *inside* the thread (the software
/// engine is `Send`, but a PJRT backend's handles would not be — the
/// per-thread construction keeps both correct), then serve work items until
/// shutdown.
pub fn run_worker(
    id: usize,
    artifact_dir: String,
    warmup: bool,
    ready: std::sync::mpsc::SyncSender<()>,
    rx: Receiver<WorkItem>,
    stats: Arc<CoordinatorStats>,
) {
    let engine_init = Engine::new(&artifact_dir).and_then(|mut e| {
        if warmup {
            // Compile every artifact before serving so first requests do not
            // pay PJRT compilation latency.
            e.warmup_all()?;
        }
        Ok(e)
    });
    // Signal readiness (successful or not) so Coordinator::start can block
    // until the fleet is warm.
    let _ = ready.send(());
    let mut engine = match engine_init {
        Ok(e) => e,
        Err(e) => {
            // Fail every item we receive; the handle surfaces the error.
            eprintln!("worker {id}: engine init failed: {e}");
            for item in rx {
                match item {
                    WorkItem::Batch(b) => b.fail(&format!("worker {id} has no engine: {e}")),
                    WorkItem::Gemm(g) => {
                        let _ = g
                            .reply
                            .send(Err(crate::Error::Coordinator(format!("no engine: {e}"))));
                    }
                    WorkItem::Shutdown => break,
                }
            }
            return;
        }
    };

    for item in rx {
        match item {
            WorkItem::Shutdown => break,
            WorkItem::Gemm(job) => {
                let t0 = job.enqueued;
                let res = engine
                    .execute_i32_single(&job.artifact, &[&job.a, &job.b])
                    .map_err(|e| crate::Error::Coordinator(e.to_string()));
                match &res {
                    Ok(_) => {
                        stats.completed.fetch_add(1, Ordering::Relaxed);
                        stats.record_latency(t0.elapsed().as_secs_f64());
                    }
                    Err(_) => {
                        stats.failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let _ = job.reply.send(res);
            }
            WorkItem::Batch(batch) => {
                let members = batch.jobs.len() as u64;
                let padding = (batch.batch - batch.jobs.len()) as u64;
                let row_len = batch.jobs.first().map(|j| j.row.len()).unwrap_or(0);
                let input = batch.build_input(row_len);
                let started = Instant::now();
                match engine.execute_i32_single(&batch.artifact, &[&input]) {
                    Ok(out) => {
                        stats.batches.fetch_add(1, Ordering::Relaxed);
                        stats.batched_rows.fetch_add(members, Ordering::Relaxed);
                        stats.padded_rows.fetch_add(padding, Ordering::Relaxed);
                        stats.completed.fetch_add(members, Ordering::Relaxed);
                        let now = Instant::now();
                        for j in &batch.jobs {
                            stats.record_latency(now.duration_since(j.enqueued).as_secs_f64());
                        }
                        let _ = started;
                        batch.deliver(&out);
                    }
                    Err(e) => {
                        stats.failed.fetch_add(members, Ordering::Relaxed);
                        batch.fail(&format!("worker {id} execute failed: {e}"));
                    }
                }
            }
        }
    }
}
