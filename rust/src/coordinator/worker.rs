//! Worker threads: each owns an execution engine (over the configured
//! [`BackendKind`]) and executes dispatched work. With the software backend
//! every GEMM a worker runs routes through the packed bit-sliced fast path;
//! with the photonic backend every execution additionally carries a
//! simulated-accelerator [`crate::runtime::ExecReport`] that is folded into
//! [`CoordinatorStats`] and returned on the [`Reply`].

use std::sync::atomic::Ordering;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::batcher::{CnnMicroBatch, MicroBatch};
use crate::coordinator::request::{CnnJob, GemmJob, PingJob, Reply};
use crate::coordinator::stats::CoordinatorStats;
use crate::runtime::backend::{BackendKind, RowNonce};
use crate::runtime::cnnrun::run_cnn_batch_keyed;
use crate::runtime::Engine;

/// Work items dispatched by the leader to a worker.
#[derive(Debug)]
pub enum WorkItem {
    /// A packed MLP micro-batch.
    Batch(MicroBatch),
    /// An unbatched GEMM.
    Gemm(GemmJob),
    /// A whole-CNN inference. Served through the engine's compiled-plan
    /// cache ([`crate::runtime::Engine::cnn_plan`]): the first request per
    /// model pays weight packing once, the rest stream through the
    /// persistent scratch arena.
    Cnn(CnnJob),
    /// A stack of same-model CNN frames (t-dimension batching), served
    /// through the same compiled-plan cache as [`WorkItem::Cnn`].
    CnnBatch(CnnMicroBatch),
    /// A health probe: answered with an empty reply, never counted into
    /// request stats (see [`PingJob`]).
    Ping(PingJob),
    /// Stop the worker.
    Shutdown,
}

impl WorkItem {
    /// Fail every reply slot this item owns (dead-worker / no-worker path).
    /// Uses [`crate::Error::ShardDown`]: these failures mean the shard's
    /// worker pool is gone, which is exactly the fleet router's failover
    /// signal — unlike per-request execute errors, which stay
    /// [`crate::Error::Coordinator`].
    pub(crate) fn fail(self, msg: &str) {
        let err = || crate::Error::ShardDown(msg.to_string());
        match self {
            WorkItem::Batch(b) => b.fail_with(&err),
            WorkItem::Gemm(g) => {
                let _ = g.reply.send(Err(err()));
            }
            WorkItem::Cnn(c) => {
                let _ = c.reply.send(Err(err()));
            }
            WorkItem::CnnBatch(b) => b.fail_with(&err),
            WorkItem::Ping(p) => {
                let _ = p.reply.send(Err(err()));
            }
            WorkItem::Shutdown => {}
        }
    }

    /// Reply slots this item owns — what `fail` will resolve, and what the
    /// failure paths outside a worker must add to `stats.failed` so
    /// `queue_depth()` (requests − completed − failed) stays truthful.
    /// Pings resolve a slot too but were never counted as requests, so they
    /// contribute zero here.
    pub(crate) fn reply_slots(&self) -> u64 {
        match self {
            WorkItem::Batch(b) => b.jobs.len() as u64,
            WorkItem::Gemm(_) | WorkItem::Cnn(_) => 1,
            WorkItem::CnnBatch(b) => b.jobs.len() as u64,
            WorkItem::Ping(_) | WorkItem::Shutdown => 0,
        }
    }
}

/// Worker main loop: construct the engine *inside* the thread (the in-tree
/// backends are `Send`, but a PJRT backend's handles would not be — the
/// per-thread construction keeps both correct), then serve work items until
/// shutdown.
pub fn run_worker(
    id: usize,
    artifact_dir: String,
    backend: BackendKind,
    warmup: bool,
    ready: Option<std::sync::mpsc::SyncSender<()>>,
    rx: Receiver<WorkItem>,
    stats: Arc<CoordinatorStats>,
) {
    let engine_init = Engine::with_backend(&artifact_dir, backend).and_then(|mut e| {
        if warmup {
            // Compile every artifact before serving so first requests do not
            // pay plan/compilation latency.
            e.warmup_all()?;
        }
        Ok(e)
    });
    // Signal readiness (successful or not) so Coordinator::start can block
    // until the fleet is warm. Revived workers spawn without the handshake
    // (the leader must not block mid-serving; their queue buffers work
    // until init completes).
    if let Some(ready) = ready {
        let _ = ready.send(());
    }
    let mut engine = match engine_init {
        Ok(e) => e,
        Err(e) => {
            // Exit immediately: dropping `rx` makes the leader's next
            // dispatch to this worker fail with `SendError`, which retires
            // it from the rotation and reroutes the item to a healthy
            // worker. One bad init must cost the shard a worker, not fail
            // 1/N of its traffic (or, behind a fleet, retire the whole
            // shard).
            eprintln!("worker {id}: engine init failed, exiting: {e}");
            drop(rx);
            return;
        }
    };

    // Stacked-input scratch for WorkItem::Batch: one buffer per worker,
    // refilled per batch (build_input_into re-zeroes padding), so the batch
    // hot path stops allocating a fresh Vec per micro-batch.
    let mut batch_input: Vec<i32> = Vec::new();
    for item in rx {
        match item {
            WorkItem::Shutdown => break,
            WorkItem::Ping(p) => {
                // A pong proves leader→dispatch→worker liveness; it carries
                // no outputs and touches no stats.
                let _ = p.reply.send(Ok(Reply::bare(Vec::new())));
            }
            WorkItem::Gemm(job) => {
                let started = Instant::now();
                let res = engine
                    .execute_reported_keyed(
                        &job.artifact,
                        &[&job.a, &job.b],
                        &RowNonce::Request(job.nonce),
                    )
                    .map_err(|e| crate::Error::Coordinator(e.to_string()));
                stats.record_service(started.elapsed().as_secs_f64());
                match res {
                    Ok((outputs, report)) => {
                        stats.completed.fetch_add(1, Ordering::Relaxed);
                        stats.record_latency(job.enqueued.elapsed().as_secs_f64());
                        if let Some(r) = &report {
                            stats.record_report(r);
                        }
                        let _ = job.reply.send(Ok(Reply { outputs, report, layers: Vec::new() }));
                    }
                    Err(e) => {
                        stats.failed.fetch_add(1, Ordering::Relaxed);
                        let _ = job.reply.send(Err(e));
                    }
                }
            }
            WorkItem::Cnn(job) => {
                let started = Instant::now();
                let nonces = if job.nonce == 0 { vec![] } else { vec![job.nonce] };
                let res = run_cnn_batch_keyed(&mut engine, &job.model, &[&job.input], &nonces)
                    .map(|mut runs| runs.pop().expect("batch of one yields one run"))
                    .map_err(|e| crate::Error::Coordinator(e.to_string()));
                stats.record_service(started.elapsed().as_secs_f64());
                match res {
                    Ok(run) => {
                        stats.completed.fetch_add(1, Ordering::Relaxed);
                        stats.cnn_frames.fetch_add(1, Ordering::Relaxed);
                        stats.record_latency(job.enqueued.elapsed().as_secs_f64());
                        if let Some(r) = &run.report {
                            stats.record_report(r);
                        }
                        let _ = job.reply.send(Ok(Reply {
                            outputs: run.logits,
                            report: run.report,
                            layers: run.layers,
                        }));
                    }
                    Err(e) => {
                        stats.failed.fetch_add(1, Ordering::Relaxed);
                        let _ = job.reply.send(Err(e));
                    }
                }
            }
            WorkItem::CnnBatch(batch) => {
                let frames = batch.jobs.len() as u64;
                let inputs: Vec<&[i32]> =
                    batch.jobs.iter().map(|j| j.input.as_slice()).collect();
                let nonces = batch.frame_nonces();
                let started = Instant::now();
                let res = run_cnn_batch_keyed(&mut engine, &batch.model, &inputs, &nonces)
                    .map_err(|e| crate::Error::Coordinator(e.to_string()));
                stats.record_service(started.elapsed().as_secs_f64());
                match res {
                    Ok(runs) if runs.len() != batch.jobs.len() => {
                        // A short/long run set means the engine and batcher
                        // disagree about membership — deliver() fails every
                        // member with the typed mismatch error; count them
                        // failed, not completed.
                        stats.failed.fetch_add(frames, Ordering::Relaxed);
                        let _ = batch.deliver(runs);
                    }
                    Ok(runs) => {
                        stats.cnn_batches.fetch_add(1, Ordering::Relaxed);
                        stats.cnn_frames.fetch_add(frames, Ordering::Relaxed);
                        stats.completed.fetch_add(frames, Ordering::Relaxed);
                        let now = Instant::now();
                        for j in &batch.jobs {
                            stats.record_latency(now.duration_since(j.enqueued).as_secs_f64());
                        }
                        // Each frame's aggregate report prices that frame's
                        // own layer shapes, so folding every one into the
                        // stats matches unbatched accounting exactly.
                        for run in &runs {
                            if let Some(r) = &run.report {
                                stats.record_report(r);
                            }
                        }
                        let _ = batch.deliver(runs);
                    }
                    Err(e) => {
                        stats.failed.fetch_add(frames, Ordering::Relaxed);
                        batch.fail(&format!("worker {id} cnn batch failed: {e}"));
                    }
                }
            }
            WorkItem::Batch(batch) => {
                let members = batch.jobs.len() as u64;
                let padding = (batch.batch - batch.jobs.len()) as u64;
                let row_len = batch.jobs.first().map(|j| j.row.len()).unwrap_or(0);
                batch.build_input_into(row_len, &mut batch_input);
                let nonces = batch.row_nonces();
                // Per-batch service time: the execute duration alone, as
                // opposed to the members' enqueue-to-done latencies below.
                let started = Instant::now();
                let res =
                    engine.execute_reported_keyed(&batch.artifact, &[&batch_input], &nonces);
                stats.record_service(started.elapsed().as_secs_f64());
                match res {
                    Ok((out, report)) => {
                        stats.batches.fetch_add(1, Ordering::Relaxed);
                        stats.batched_rows.fetch_add(members, Ordering::Relaxed);
                        stats.padded_rows.fetch_add(padding, Ordering::Relaxed);
                        stats.completed.fetch_add(members, Ordering::Relaxed);
                        if let Some(r) = &report {
                            // Under noise, fold only the member rows'
                            // attribution into the stats: padding rows were
                            // never served to a request, and their noise
                            // would skew served_exact_fraction below what
                            // any reply carried (`deliver` below slices the
                            // same per-member views into the replies).
                            let out_len = (out.len() / batch.batch) as u64;
                            stats.record_report(&r.served_rows(members as usize, out_len));
                        }
                        let now = Instant::now();
                        for j in &batch.jobs {
                            stats.record_latency(now.duration_since(j.enqueued).as_secs_f64());
                        }
                        batch.deliver(&out, report);
                    }
                    Err(e) => {
                        stats.failed.fetch_add(members, Ordering::Relaxed);
                        batch.fail(&format!("worker {id} execute failed: {e}"));
                    }
                }
            }
        }
    }
}
