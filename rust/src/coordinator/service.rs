//! The coordinator service: leader thread, routing, lifecycle.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SendError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, CnnMicroBatch, MicroBatch};
use crate::coordinator::request::{
    response_slot, CnnJob, GemmJob, Job, MlpJob, PingJob, Reply, Response,
};
use crate::coordinator::stats::CoordinatorStats;
use crate::coordinator::worker::{run_worker, WorkItem};
use crate::dnn::models::CnnModel;
use crate::runtime::backend::BackendKind;
use crate::runtime::Manifest;
use crate::{Error, Result};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Directory with `manifest.txt` + HLO artifacts.
    pub artifact_dir: String,
    /// Worker threads (each owns its own engine + backend).
    pub workers: usize,
    /// Execution backend every worker builds its engine with — swap
    /// [`BackendKind::Software`] for [`BackendKind::Photonic`] to serve the
    /// same traffic with photonic-in-the-loop telemetry.
    pub backend: BackendKind,
    /// Dynamic-batching window, seconds.
    pub max_batch_wait_s: f64,
    /// Largest number of same-model CNN frames stacked into one
    /// t-dimension batch (1 disables CNN batching). Like MLP dynamic
    /// batching, stacking trades latency for throughput: a sparse CNN
    /// stream pays up to [`CoordinatorConfig::max_batch_wait_s`] per frame
    /// waiting for co-batchable traffic — set this to 1 for
    /// latency-critical single-stream serving. Batching stays enabled
    /// under analog noise injection: the backend attributes noise per
    /// output row (the per-row contract in [`crate::runtime::backend`]),
    /// so every stacked frame's reply carries exactly the noise events an
    /// unbatched run would have observed.
    pub max_cnn_batch: usize,
    /// Ingress queue depth (backpressure bound).
    pub queue_depth: usize,
    /// Compile all artifacts at worker start (first-request latency vs
    /// startup time trade).
    pub warmup: bool,
    /// Time-indexed counter mode for analog noise: when `true`, every
    /// request is stamped with a per-coordinator counter nonce that noise-
    /// injecting backends fold into each output row's sub-stream key
    /// ([`crate::runtime::RowNonce`]) — byte-identical rows served under
    /// different nonces then observe *decorrelated* noise, while each
    /// `(seed, content, nonce)` draw stays deterministic. Default `false`:
    /// the pure content-keyed streams, bit-identical to historical serving
    /// (and required for bit-identical cross-shard resubmission of noisy
    /// traffic, since a resubmitted request draws a fresh nonce on the
    /// survivor).
    pub noise_nonce: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: "artifacts".into(),
            workers: 2,
            backend: BackendKind::Software,
            max_batch_wait_s: 0.002,
            max_cnn_batch: 8,
            queue_depth: 1024,
            warmup: true,
            noise_nonce: false,
        }
    }
}

/// A submission the coordinator could not accept, with the moved payload
/// recovered from the channel's `SendError` — so callers that fail over
/// (the fleet router) can resubmit elsewhere *without cloning the payload
/// up front*. Submit-time failures never consume the payload; only a shard
/// dying after acceptance does (which is what the retained-payload
/// [`RetryingSlot`](crate::coordinator::RetryingSlot) exists for).
#[derive(Debug)]
pub struct Rejected<P> {
    /// Why the submission was refused.
    pub error: Error,
    /// The payload, returned intact.
    pub payload: P,
}

/// Cloneable client handle for submitting requests.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: SyncSender<Job>,
    stats: Arc<CoordinatorStats>,
    mlp_row_len: usize,
    /// Configured worker-pool size — the target `revive_workers` restores.
    workers: usize,
    /// Time-indexed noise-nonce counter (0 is never handed out; it means
    /// "content-keyed"). `None` when [`CoordinatorConfig::noise_nonce`] is
    /// off, so default serving stamps every job with nonce 0.
    nonce_counter: Option<Arc<AtomicU64>>,
}

impl CoordinatorHandle {
    /// Next per-request noise nonce (0 when the counter mode is off).
    fn next_nonce(&self) -> u64 {
        match &self.nonce_counter {
            None => 0,
            Some(c) => c.fetch_add(1, Ordering::Relaxed) + 1,
        }
    }

    /// Enqueue a job, recovering it from the channel on failure. The
    /// accepted-request counter only sticks for accepted jobs, so a
    /// rejected submission never leaks `queue_depth()`.
    fn send_job(&self, job: Job) -> std::result::Result<(), Job> {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        match self.tx.send(job) {
            Ok(()) => Ok(()),
            Err(SendError(returned)) => {
                self.stats.requests.fetch_sub(1, Ordering::Relaxed);
                Err(returned)
            }
        }
    }

    /// Submit a GEMM against a named artifact; returns the response slot.
    pub fn submit_gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Response> {
        self.try_submit_gemm(artifact, a, b).map_err(|r| r.error)
    }

    /// Payload-recovering GEMM submission: a refused submit (the
    /// coordinator stopped) hands `(a, b)` back inside the [`Rejected`] so
    /// a failover layer can resubmit elsewhere without having cloned.
    pub fn try_submit_gemm(
        &self,
        artifact: &str,
        a: Vec<i32>,
        b: Vec<i32>,
    ) -> std::result::Result<Response, Rejected<(Vec<i32>, Vec<i32>)>> {
        let (reply, rx) = response_slot();
        let job = Job::Gemm(GemmJob {
            artifact: artifact.to_string(),
            a,
            b,
            reply,
            enqueued: Instant::now(),
            nonce: self.next_nonce(),
        });
        match self.send_job(job) {
            Ok(()) => Ok(rx),
            Err(Job::Gemm(g)) => Err(Rejected {
                error: Error::ShardDown("coordinator stopped".into()),
                payload: (g.a, g.b),
            }),
            Err(_) => unreachable!("send returns the job it was given"),
        }
    }

    /// Submit one MLP row; returns the response slot.
    pub fn submit_mlp(&self, row: Vec<i32>) -> Result<Response> {
        self.try_submit_mlp(row).map_err(|r| r.error)
    }

    /// Payload-recovering MLP submission (see [`CoordinatorHandle::try_submit_gemm`]).
    /// Shape rejections return the row too — nothing consumed it.
    pub fn try_submit_mlp(
        &self,
        row: Vec<i32>,
    ) -> std::result::Result<Response, Rejected<Vec<i32>>> {
        if row.len() != self.mlp_row_len {
            let error = Error::Shape(format!(
                "mlp row has {} elements, expected {}",
                row.len(),
                self.mlp_row_len
            ));
            return Err(Rejected { error, payload: row });
        }
        let (reply, rx) = response_slot();
        let job =
            Job::Mlp(MlpJob { row, reply, enqueued: Instant::now(), nonce: self.next_nonce() });
        match self.send_job(job) {
            Ok(()) => Ok(rx),
            Err(Job::Mlp(m)) => Err(Rejected {
                error: Error::ShardDown("coordinator stopped".into()),
                payload: m.row,
            }),
            Err(_) => unreachable!("send returns the job it was given"),
        }
    }

    /// Submit a whole-CNN inference; validates the layer chain against the
    /// input length up front. Returns the response slot.
    pub fn submit_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Response> {
        self.try_submit_cnn(model, input).map_err(|r| r.error)
    }

    /// Payload-recovering CNN submission (see [`CoordinatorHandle::try_submit_gemm`]).
    pub fn try_submit_cnn(
        &self,
        model: CnnModel,
        input: Vec<i32>,
    ) -> std::result::Result<Response, Rejected<(CnnModel, Vec<i32>)>> {
        if let Err(error) = crate::runtime::cnnrun::validate_cnn_input(&model, input.len()) {
            return Err(Rejected { error, payload: (model, input) });
        }
        let (reply, rx) = response_slot();
        let job = Job::Cnn(CnnJob {
            model,
            input,
            reply,
            enqueued: Instant::now(),
            nonce: self.next_nonce(),
        });
        match self.send_job(job) {
            Ok(()) => Ok(rx),
            Err(Job::Cnn(c)) => Err(Rejected {
                error: Error::ShardDown("coordinator stopped".into()),
                payload: (c.model, c.input),
            }),
            Err(_) => unreachable!("send returns the job it was given"),
        }
    }

    /// Submit a CNN described as trace text (see [`crate::dnn::trace`]).
    ///
    /// Prefer parsing once with [`crate::dnn::parse_trace`] and reusing the
    /// [`CnnModel`] across submissions: trace parsing leaks the model name
    /// (the name is `&'static`).
    pub fn submit_cnn_trace(&self, trace: &str, input: Vec<i32>) -> Result<Response> {
        self.submit_cnn(crate::dnn::parse_trace(trace)?, input)
    }

    /// Blocking MLP inference convenience.
    pub fn infer_mlp(&self, row: Vec<i32>) -> Result<Vec<i32>> {
        Ok(self
            .submit_mlp(row)?
            .recv()
            .map_err(|_| Error::Coordinator("response dropped (worker crashed mid-request?)".into()))??
            .outputs)
    }

    /// Blocking GEMM convenience.
    pub fn gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Vec<i32>> {
        Ok(self.gemm_reply(artifact, a, b)?.outputs)
    }

    /// Blocking GEMM returning the full [`Reply`] (outputs + telemetry).
    pub fn gemm_reply(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Reply> {
        self.submit_gemm(artifact, a, b)?
            .recv()
            .map_err(|_| Error::Coordinator("response dropped (worker crashed mid-request?)".into()))?
    }

    /// Blocking CNN inference returning the full [`Reply`] (logits +
    /// per-layer telemetry).
    pub fn infer_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Reply> {
        self.submit_cnn(model, input)?
            .recv()
            .map_err(|_| Error::Coordinator("response dropped (worker crashed mid-request?)".into()))?
    }

    /// Retire every worker from the rotation (maintenance drain / fault
    /// injection): workers finish their queued items and exit, after which
    /// jobs on this coordinator fail with a "no live workers" error — the
    /// signal a [`FleetHandle`](crate::coordinator::FleetHandle) uses to
    /// fail the shard over. The leader stays alive so every reply slot
    /// still resolves.
    pub fn retire_workers(&self) -> Result<()> {
        self.tx
            .send(Job::RetireWorkers)
            .map_err(|_| Error::ShardDown("coordinator stopped".into()))
    }

    /// Respawn workers until the pool holds `target` again (the leader
    /// survives [`CoordinatorHandle::retire_workers`] and worker deaths, so
    /// a shard can rebuild its pool in place). Fire-and-forget: follow with
    /// [`CoordinatorHandle::ping`] to confirm the revived pool serves.
    pub fn revive_workers(&self, target: usize) -> Result<()> {
        self.tx
            .send(Job::ReviveWorkers { target: target.max(1) })
            .map_err(|_| Error::ShardDown("coordinator stopped".into()))
    }

    /// Configured worker-pool size (the default revival target).
    pub fn configured_workers(&self) -> usize {
        self.workers
    }

    /// Health probe: routes a ping through leader dispatch to a worker and
    /// waits up to `timeout` for the pong. `Ok` proves the shard serves end
    /// to end; errors mean the coordinator is stopped, the pool is dead, or
    /// the probe timed out. Pings never touch request/completed stats, so
    /// probing cannot skew routing.
    pub fn ping(&self, timeout: Duration) -> Result<()> {
        let (reply, rx) = response_slot();
        self.tx
            .send(Job::Ping(PingJob { reply }))
            .map_err(|_| Error::ShardDown("coordinator stopped".into()))?;
        match rx.recv_timeout(timeout) {
            Ok(Ok(_)) => Ok(()),
            Ok(Err(e)) => Err(e),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                Err(Error::ShardDown("health probe timed out".into()))
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::ShardDown("health probe slot dropped".into()))
            }
        }
    }

    /// Shared metrics.
    pub fn stats(&self) -> &CoordinatorStats {
        &self.stats
    }

    /// The shared stats behind their `Arc` (fleet rollups hold these across
    /// the router's interior-mutable slot table).
    pub fn stats_arc(&self) -> Arc<CoordinatorStats> {
        self.stats.clone()
    }
}

/// The worker-spawn recipe, shared by [`Coordinator::start`] and the
/// leader's revival path ([`Job::ReviveWorkers`]): everything a fresh
/// worker thread needs to build its engine and join the pool.
struct WorkerSpawner {
    artifact_dir: String,
    backend: BackendKind,
    warmup: bool,
    queue_depth: usize,
    stats: Arc<CoordinatorStats>,
}

impl WorkerSpawner {
    /// Spawn worker `id`; `ready` is `Some` only at coordinator start
    /// (revived workers must not block the serving leader on engine init).
    fn spawn(
        &self,
        id: usize,
        ready: Option<SyncSender<()>>,
    ) -> Result<(SyncSender<WorkItem>, JoinHandle<()>)> {
        let (wtx, wrx) = sync_channel::<WorkItem>(self.queue_depth);
        let dir = self.artifact_dir.clone();
        let backend = self.backend.clone();
        let st = self.stats.clone();
        let warm = self.warmup;
        let join = std::thread::Builder::new()
            .name(format!("spoga-worker-{id}"))
            .spawn(move || run_worker(id, dir, backend, warm, ready, wrx, st))
            .map_err(|e| Error::Coordinator(format!("spawn worker: {e}")))?;
        Ok((wtx, join))
    }
}

/// The running coordinator (leader + workers). Dropping it shuts down.
pub struct Coordinator {
    handle: CoordinatorHandle,
    leader: Option<JoinHandle<()>>,
    tx: SyncSender<Job>,
}

impl Coordinator {
    /// Start the service: validates the manifest, spawns workers + leader.
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        // Validate the manifest up front (fail fast with a good error).
        let manifest = Manifest::load(&cfg.artifact_dir)?;
        let variants = manifest.mlp_batch_variants();
        if variants.is_empty() {
            return Err(Error::Config("no mlp_b* artifacts in manifest".into()));
        }
        let mlp_row_len = manifest.get(&variants[0].0)?.inputs[0].elements() / variants[0].1;
        let policy = BatchPolicy::new(variants, cfg.max_batch_wait_s)?;
        // Batching stays at full strength under noise injection: backends
        // attribute noise per output row (content-keyed sub-streams — see
        // the per-row contract in `runtime::backend`), so the batcher hands
        // every MLP member its own row's events and the CNN runtime slices
        // stacked frames exactly. No noise→batch=1 clamp is needed.
        let cnn_batch_cap = cfg.max_cnn_batch.max(1);
        let workers = cfg.workers.max(1);

        let stats = Arc::new(CoordinatorStats::default());
        stats.live_workers.store(workers as u64, Ordering::Relaxed);
        let (tx, rx) = sync_channel::<Job>(cfg.queue_depth);

        let spawner = WorkerSpawner {
            artifact_dir: cfg.artifact_dir.clone(),
            backend: cfg.backend.clone(),
            warmup: cfg.warmup,
            queue_depth: cfg.queue_depth,
            stats: stats.clone(),
        };

        // Workers.
        let mut worker_txs = Vec::with_capacity(workers);
        let mut joins = Vec::new();
        let (ready_tx, ready_rx) = sync_channel::<()>(workers);
        for id in 0..workers {
            let (wtx, join) = spawner.spawn(id, Some(ready_tx.clone()))?;
            worker_txs.push(wtx);
            joins.push(join);
        }
        drop(ready_tx);
        // Block until every worker finished (possibly warm) engine init.
        for _ in 0..workers {
            let _ = ready_rx.recv();
        }

        // Leader.
        let leader = {
            let leader_stats = stats.clone();
            std::thread::Builder::new()
                .name("spoga-leader".into())
                .spawn(move || {
                    run_leader(rx, worker_txs, policy, cnn_batch_cap, leader_stats, joins, spawner)
                })
                .map_err(|e| Error::Coordinator(format!("spawn leader: {e}")))?
        };

        let nonce_counter = cfg.noise_nonce.then(|| Arc::new(AtomicU64::new(0)));
        let handle =
            CoordinatorHandle { tx: tx.clone(), stats, mlp_row_len, workers, nonce_counter };
        Ok(Coordinator { handle, leader: Some(leader), tx })
    }

    /// A cloneable submission handle.
    pub fn handle(&self) -> CoordinatorHandle {
        self.handle.clone()
    }

    /// Graceful shutdown: drain queues, stop workers, join threads.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.leader.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.leader.take() {
            let _ = j.join();
        }
    }
}

/// Round-robin dispatch with dead-worker failover: a `send` only fails when
/// the worker's receiver is gone (thread died), in which case the worker is
/// retired from the rotation and the item retries on the next one. Only
/// when no workers remain does the job fail — with a real error on its
/// reply slot (counted in `stats.failed`, so `queue_depth()` stays
/// truthful), never silently.
fn dispatch(
    mut item: WorkItem,
    worker_txs: &mut Vec<SyncSender<WorkItem>>,
    next: &mut usize,
    stats: &CoordinatorStats,
) {
    loop {
        if worker_txs.is_empty() {
            stats.failed.fetch_add(item.reply_slots(), Ordering::Relaxed);
            item.fail("no live workers (all worker threads exited)");
            return;
        }
        let idx = *next % worker_txs.len();
        match worker_txs[idx].send(item) {
            Ok(()) => {
                *next = (idx + 1) % worker_txs.len();
                return;
            }
            Err(SendError(returned)) => {
                // Dead worker: retire it and retry the item elsewhere.
                worker_txs.remove(idx);
                stats.live_workers.store(worker_txs.len() as u64, Ordering::Relaxed);
                *next = idx; // same slot now holds the next worker
                item = returned;
            }
        }
    }
}

/// Retire every worker from the rotation: each one drains its queued items
/// and exits when it reaches the Shutdown marker. Threads join at leader
/// exit (the leader keeps their `JoinHandle`s).
fn retire_all_workers(worker_txs: &mut Vec<SyncSender<WorkItem>>, stats: &CoordinatorStats) {
    for tx in worker_txs.drain(..) {
        let _ = tx.send(WorkItem::Shutdown);
    }
    stats.live_workers.store(0, Ordering::Relaxed);
}

/// Revive the pool to `target` workers: spawn the shortfall through the
/// leader's [`WorkerSpawner`] (fresh engines, no readiness handshake — the
/// leader keeps serving while revived engines warm; their channels buffer
/// dispatched work meanwhile). A worker whose engine init fails exits
/// immediately and is retired by the next dispatch, exactly like at start.
///
/// Stale senders of workers that already died (crashed, or exited on a
/// failed engine init) are pruned *first* — counting them toward `target`
/// would under-provision the revived pool and inflate the `live_workers`
/// gauge until the next dispatch happened to hit them.
fn revive_workers_to(
    target: usize,
    worker_txs: &mut Vec<SyncSender<WorkItem>>,
    worker_joins: &mut Vec<JoinHandle<()>>,
    next_worker_id: &mut usize,
    spawner: &WorkerSpawner,
    stats: &CoordinatorStats,
) {
    worker_txs.retain(|tx| {
        let (reply, pong) = response_slot();
        match tx.try_send(WorkItem::Ping(PingJob { reply })) {
            // Accepted: the worker will pong into the dropped slot — cheap
            // and harmless. A full queue also proves the receiver is alive
            // (a dropped receiver reports Disconnected even when full).
            Ok(()) => {
                drop(pong);
                true
            }
            Err(std::sync::mpsc::TrySendError::Full(_)) => true,
            Err(std::sync::mpsc::TrySendError::Disconnected(_)) => false,
        }
    });
    // Drop join handles of threads that already exited, so repeated revive
    // cycles (e.g. a janitor retrying a persistently failing artifact dir)
    // do not accumulate handles without bound. Finished threads need no
    // join for correctness — only still-running workers are joined at
    // leader exit.
    worker_joins.retain(|j| !j.is_finished());
    let mut spawned = false;
    while worker_txs.len() < target {
        match spawner.spawn(*next_worker_id, None) {
            Ok((wtx, join)) => {
                worker_txs.push(wtx);
                worker_joins.push(join);
                *next_worker_id += 1;
                spawned = true;
            }
            Err(e) => {
                eprintln!("revive: could not spawn worker {next_worker_id}: {e}");
                break;
            }
        }
    }
    stats.live_workers.store(worker_txs.len() as u64, Ordering::Relaxed);
    if spawned {
        stats.revivals.fetch_add(1, Ordering::Relaxed);
    }
}

/// Extract up to `cap` pending frames of `model`, in arrival order.
fn extract_cnn_group(pending: &mut Vec<CnnJob>, model: &CnnModel, cap: usize) -> Vec<CnnJob> {
    let mut jobs = Vec::new();
    let mut i = 0;
    while i < pending.len() && jobs.len() < cap {
        if pending[i].model == *model {
            jobs.push(pending.remove(i));
        } else {
            i += 1;
        }
    }
    jobs
}

/// Flush every pending CNN frame as t-stacked micro-batches, in arrival
/// order (head model first), at most `cap` frames per batch. Used when the
/// batching window closes — partial groups go out as-is.
fn flush_cnn_batches(
    pending: &mut Vec<CnnJob>,
    cap: usize,
    worker_txs: &mut Vec<SyncSender<WorkItem>>,
    next_worker: &mut usize,
    stats: &CoordinatorStats,
) {
    while !pending.is_empty() {
        let model = pending[0].model.clone();
        let jobs = extract_cnn_group(pending, &model, cap);
        dispatch(WorkItem::CnnBatch(CnnMicroBatch { model, jobs }), worker_txs, next_worker, stats);
    }
}

/// Mid-window flush of exactly one *full* same-model stack, if the model of
/// the most recently gathered frame just reached `cap` members. Partial
/// groups — including minority models in mixed traffic — keep gathering
/// until the window deadline; a full stack gains nothing by waiting.
fn flush_full_cnn_group(
    pending: &mut Vec<CnnJob>,
    cap: usize,
    worker_txs: &mut Vec<SyncSender<WorkItem>>,
    next_worker: &mut usize,
    stats: &CoordinatorStats,
) {
    let model = match pending.last() {
        Some(j) => j.model.clone(),
        None => return,
    };
    if pending.iter().filter(|j| j.model == model).count() >= cap {
        let jobs = extract_cnn_group(pending, &model, cap);
        dispatch(WorkItem::CnnBatch(CnnMicroBatch { model, jobs }), worker_txs, next_worker, stats);
    }
}

/// Leader loop: route GEMMs round-robin (with dead-worker failover); gather
/// MLP rows and same-model CNN frames into micro-batches bounded by the
/// batching window, the largest MLP variant, and the CNN stacking cap.
fn run_leader(
    rx: Receiver<Job>,
    mut worker_txs: Vec<SyncSender<WorkItem>>,
    policy: BatchPolicy,
    cnn_batch_cap: usize,
    stats: Arc<CoordinatorStats>,
    mut worker_joins: Vec<JoinHandle<()>>,
    spawner: WorkerSpawner,
) {
    let mut next_worker = 0usize;
    let mut next_worker_id = worker_txs.len();
    let window = Duration::from_secs_f64(policy.max_wait_s);
    let mut pending: Vec<MlpJob> = Vec::new();
    let mut pending_cnn: Vec<CnnJob> = Vec::new();
    let mut shutdown = false;

    while !shutdown {
        // Phase 1: block for the first batchable job.
        match rx.recv() {
            Err(_) => break,
            Ok(Job::Shutdown) => break,
            Ok(Job::RetireWorkers) => {
                retire_all_workers(&mut worker_txs, &stats);
                continue;
            }
            Ok(Job::ReviveWorkers { target }) => {
                revive_workers_to(
                    target,
                    &mut worker_txs,
                    &mut worker_joins,
                    &mut next_worker_id,
                    &spawner,
                    &stats,
                );
                continue;
            }
            Ok(Job::Ping(p)) => {
                dispatch(WorkItem::Ping(p), &mut worker_txs, &mut next_worker, &stats);
                continue;
            }
            Ok(Job::Gemm(g)) => {
                dispatch(WorkItem::Gemm(g), &mut worker_txs, &mut next_worker, &stats);
                continue;
            }
            Ok(Job::Cnn(c)) if cnn_batch_cap <= 1 => {
                dispatch(WorkItem::Cnn(c), &mut worker_txs, &mut next_worker, &stats);
                continue;
            }
            Ok(Job::Cnn(c)) => pending_cnn.push(c),
            Ok(Job::Mlp(m)) => pending.push(m),
        }

        // Phase 2: batching window — gather more batchable jobs until the
        // deadline. *Full* batches flush inline (they gain nothing by
        // waiting) while the window stays open, so heavy traffic in one
        // class never truncates the other's gathering; partial batches —
        // including minority models in mixed CNN traffic — wait for the
        // deadline.
        let deadline = Instant::now() + window;
        loop {
            while pending.len() >= policy.max_batch() {
                let (artifact, batch) = policy.pick_variant(policy.max_batch()).clone();
                let jobs: Vec<MlpJob> = pending.drain(..batch).collect();
                dispatch(
                    WorkItem::Batch(MicroBatch { artifact, batch, jobs }),
                    &mut worker_txs,
                    &mut next_worker,
                    &stats,
                );
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Job::Mlp(m)) => pending.push(m),
                Ok(Job::Gemm(g)) => {
                    dispatch(WorkItem::Gemm(g), &mut worker_txs, &mut next_worker, &stats)
                }
                Ok(Job::Cnn(c)) if cnn_batch_cap <= 1 => {
                    dispatch(WorkItem::Cnn(c), &mut worker_txs, &mut next_worker, &stats)
                }
                Ok(Job::Cnn(c)) => {
                    pending_cnn.push(c);
                    flush_full_cnn_group(
                        &mut pending_cnn,
                        cnn_batch_cap,
                        &mut worker_txs,
                        &mut next_worker,
                        &stats,
                    );
                }
                Ok(Job::RetireWorkers) => retire_all_workers(&mut worker_txs, &stats),
                Ok(Job::ReviveWorkers { target }) => revive_workers_to(
                    target,
                    &mut worker_txs,
                    &mut worker_joins,
                    &mut next_worker_id,
                    &spawner,
                    &stats,
                ),
                Ok(Job::Ping(p)) => {
                    dispatch(WorkItem::Ping(p), &mut worker_txs, &mut next_worker, &stats)
                }
                Ok(Job::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }

        // Phase 3: the window closed — flush what gathered (possibly
        // several batches if a burst exceeded the caps).
        while !pending.is_empty() {
            let take = pending.len().min(policy.max_batch());
            let (artifact, batch) = policy.pick_variant(take).clone();
            let jobs: Vec<MlpJob> = pending.drain(..take.min(batch)).collect();
            dispatch(
                WorkItem::Batch(MicroBatch { artifact, batch, jobs }),
                &mut worker_txs,
                &mut next_worker,
                &stats,
            );
        }
        flush_cnn_batches(
            &mut pending_cnn,
            cnn_batch_cap,
            &mut worker_txs,
            &mut next_worker,
            &stats,
        );
    }

    // Drain-and-stop: explicitly fail everything still queued (batched rows
    // gathered this cycle AND jobs still buffered in the ingress channel) so
    // every reply slot resolves — each counted in `failed` so the stats
    // invariant (requests = completed + failed + unresolved) closes out.
    let fail_one = |stats: &CoordinatorStats, reply: &crate::coordinator::request::ResponseTx| {
        stats.failed.fetch_add(1, Ordering::Relaxed);
        let _ = reply.send(Err(Error::ShardDown("shutdown".into())));
    };
    for j in pending {
        fail_one(&stats, &j.reply);
    }
    for j in pending_cnn {
        fail_one(&stats, &j.reply);
    }
    while let Ok(job) = rx.try_recv() {
        match job {
            Job::Gemm(g) => fail_one(&stats, &g.reply),
            Job::Mlp(m) => fail_one(&stats, &m.reply),
            Job::Cnn(c) => fail_one(&stats, &c.reply),
            // Pings are not counted as requests, so only the slot resolves.
            Job::Ping(p) => {
                let _ = p.reply.send(Err(Error::ShardDown("shutdown".into())));
            }
            Job::RetireWorkers | Job::ReviveWorkers { .. } | Job::Shutdown => {}
        }
    }
    for tx in &worker_txs {
        let _ = tx.send(WorkItem::Shutdown);
    }
    drop(worker_txs);
    for j in worker_joins {
        let _ = j.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::response_slot;

    fn gemm_item(tag: i32) -> (WorkItem, Response) {
        let (reply, rx) = response_slot();
        let job = GemmJob {
            artifact: format!("g{tag}"),
            a: vec![tag],
            b: vec![tag],
            reply,
            enqueued: Instant::now(),
            nonce: 0,
        };
        (WorkItem::Gemm(job), rx)
    }

    #[test]
    fn dispatch_skips_dead_workers() {
        let stats = CoordinatorStats::default();
        let (live_tx, live_rx) = sync_channel::<WorkItem>(4);
        let (dead_tx, dead_rx) = sync_channel::<WorkItem>(4);
        drop(dead_rx); // worker 0 died
        let mut txs = vec![dead_tx, live_tx];
        let mut next = 0usize;

        let (item, _rx) = gemm_item(1);
        dispatch(item, &mut txs, &mut next, &stats);
        assert_eq!(txs.len(), 1, "dead worker retired from rotation");
        match live_rx.try_recv().unwrap() {
            WorkItem::Gemm(g) => assert_eq!(g.artifact, "g1"),
            other => panic!("wrong item routed: {other:?}"),
        }
        assert_eq!(stats.failed.load(Ordering::Relaxed), 0, "rerouted, not failed");
    }

    #[test]
    fn dispatch_fails_job_when_no_workers_remain() {
        let stats = CoordinatorStats::default();
        let (dead_tx, dead_rx) = sync_channel::<WorkItem>(4);
        drop(dead_rx);
        let mut txs = vec![dead_tx];
        let mut next = 0usize;
        let (item, rx) = gemm_item(2);
        dispatch(item, &mut txs, &mut next, &stats);
        assert!(txs.is_empty());
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("no live workers"), "{err}");
        assert!(matches!(err, Error::ShardDown(_)), "fleet failover signal");
        // The failure is counted, so queue_depth() does not leak.
        assert_eq!(stats.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dispatch_round_robins_over_live_workers() {
        let stats = CoordinatorStats::default();
        let (tx_a, rx_a) = sync_channel::<WorkItem>(8);
        let (tx_b, rx_b) = sync_channel::<WorkItem>(8);
        let mut txs = vec![tx_a, tx_b];
        let mut next = 0usize;
        let mut slots = Vec::new();
        for i in 0..4 {
            let (item, rx) = gemm_item(i);
            dispatch(item, &mut txs, &mut next, &stats);
            slots.push(rx);
        }
        assert_eq!(rx_a.try_iter().count(), 2);
        assert_eq!(rx_b.try_iter().count(), 2);
    }
}
