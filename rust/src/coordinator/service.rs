//! The coordinator service: leader thread, routing, lifecycle.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SendError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, MicroBatch};
use crate::coordinator::request::{response_slot, CnnJob, GemmJob, Job, MlpJob, Reply, Response};
use crate::coordinator::stats::CoordinatorStats;
use crate::coordinator::worker::{run_worker, WorkItem};
use crate::dnn::models::CnnModel;
use crate::runtime::backend::BackendKind;
use crate::runtime::Manifest;
use crate::{Error, Result};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Directory with `manifest.txt` + HLO artifacts.
    pub artifact_dir: String,
    /// Worker threads (each owns its own engine + backend).
    pub workers: usize,
    /// Execution backend every worker builds its engine with — swap
    /// [`BackendKind::Software`] for [`BackendKind::Photonic`] to serve the
    /// same traffic with photonic-in-the-loop telemetry.
    pub backend: BackendKind,
    /// Dynamic-batching window, seconds.
    pub max_batch_wait_s: f64,
    /// Ingress queue depth (backpressure bound).
    pub queue_depth: usize,
    /// Compile all artifacts at worker start (first-request latency vs
    /// startup time trade).
    pub warmup: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: "artifacts".into(),
            workers: 2,
            backend: BackendKind::Software,
            max_batch_wait_s: 0.002,
            queue_depth: 1024,
            warmup: true,
        }
    }
}

/// Cloneable client handle for submitting requests.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: SyncSender<Job>,
    stats: Arc<CoordinatorStats>,
    mlp_row_len: usize,
}

impl CoordinatorHandle {
    /// Submit a GEMM against a named artifact; returns the response slot.
    pub fn submit_gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Response> {
        let (reply, rx) = response_slot();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Job::Gemm(GemmJob {
                artifact: artifact.to_string(),
                a,
                b,
                reply,
                enqueued: Instant::now(),
            }))
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        Ok(rx)
    }

    /// Submit one MLP row; returns the response slot.
    pub fn submit_mlp(&self, row: Vec<i32>) -> Result<Response> {
        if row.len() != self.mlp_row_len {
            return Err(Error::Shape(format!(
                "mlp row has {} elements, expected {}",
                row.len(),
                self.mlp_row_len
            )));
        }
        let (reply, rx) = response_slot();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Job::Mlp(MlpJob { row, reply, enqueued: Instant::now() }))
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        Ok(rx)
    }

    /// Submit a whole-CNN inference; validates the layer chain against the
    /// input length up front. Returns the response slot.
    pub fn submit_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Response> {
        crate::runtime::cnnrun::validate_cnn_input(&model, input.len())?;
        let (reply, rx) = response_slot();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Job::Cnn(CnnJob { model, input, reply, enqueued: Instant::now() }))
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        Ok(rx)
    }

    /// Submit a CNN described as trace text (see [`crate::dnn::trace`]).
    ///
    /// Prefer parsing once with [`crate::dnn::parse_trace`] and reusing the
    /// [`CnnModel`] across submissions: trace parsing leaks the model name
    /// (the name is `&'static`).
    pub fn submit_cnn_trace(&self, trace: &str, input: Vec<i32>) -> Result<Response> {
        self.submit_cnn(crate::dnn::parse_trace(trace)?, input)
    }

    /// Blocking MLP inference convenience.
    pub fn infer_mlp(&self, row: Vec<i32>) -> Result<Vec<i32>> {
        Ok(self
            .submit_mlp(row)?
            .recv()
            .map_err(|_| Error::Coordinator("response dropped".into()))??
            .outputs)
    }

    /// Blocking GEMM convenience.
    pub fn gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Vec<i32>> {
        Ok(self.gemm_reply(artifact, a, b)?.outputs)
    }

    /// Blocking GEMM returning the full [`Reply`] (outputs + telemetry).
    pub fn gemm_reply(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Reply> {
        self.submit_gemm(artifact, a, b)?
            .recv()
            .map_err(|_| Error::Coordinator("response dropped".into()))?
    }

    /// Blocking CNN inference returning the full [`Reply`] (logits +
    /// per-layer telemetry).
    pub fn infer_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Reply> {
        self.submit_cnn(model, input)?
            .recv()
            .map_err(|_| Error::Coordinator("response dropped".into()))?
    }

    /// Shared metrics.
    pub fn stats(&self) -> &CoordinatorStats {
        &self.stats
    }
}

/// The running coordinator (leader + workers). Dropping it shuts down.
pub struct Coordinator {
    handle: CoordinatorHandle,
    leader: Option<JoinHandle<()>>,
    tx: SyncSender<Job>,
}

impl Coordinator {
    /// Start the service: validates the manifest, spawns workers + leader.
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        // Validate the manifest up front (fail fast with a good error).
        let manifest = Manifest::load(&cfg.artifact_dir)?;
        let variants = manifest.mlp_batch_variants();
        if variants.is_empty() {
            return Err(Error::Config("no mlp_b* artifacts in manifest".into()));
        }
        let mlp_row_len = manifest.get(&variants[0].0)?.inputs[0].elements() / variants[0].1;
        let policy = BatchPolicy::new(variants, cfg.max_batch_wait_s);

        let stats = Arc::new(CoordinatorStats::default());
        let (tx, rx) = sync_channel::<Job>(cfg.queue_depth);

        // Workers.
        let mut worker_txs = Vec::with_capacity(cfg.workers.max(1));
        let mut joins = Vec::new();
        let (ready_tx, ready_rx) = sync_channel::<()>(cfg.workers.max(1));
        for id in 0..cfg.workers.max(1) {
            let (wtx, wrx) = sync_channel::<WorkItem>(cfg.queue_depth);
            let dir = cfg.artifact_dir.clone();
            let backend = cfg.backend.clone();
            let st = stats.clone();
            let warm = cfg.warmup;
            let rtx = ready_tx.clone();
            joins.push(std::thread::Builder::new()
                .name(format!("spoga-worker-{id}"))
                .spawn(move || run_worker(id, dir, backend, warm, rtx, wrx, st))
                .map_err(|e| Error::Coordinator(format!("spawn worker: {e}")))?);
            worker_txs.push(wtx);
        }
        drop(ready_tx);
        // Block until every worker finished (possibly warm) engine init.
        for _ in 0..cfg.workers.max(1) {
            let _ = ready_rx.recv();
        }

        // Leader.
        let leader = {
            std::thread::Builder::new()
                .name("spoga-leader".into())
                .spawn(move || run_leader(rx, worker_txs, policy, joins))
                .map_err(|e| Error::Coordinator(format!("spawn leader: {e}")))?
        };

        let handle = CoordinatorHandle { tx: tx.clone(), stats, mlp_row_len };
        Ok(Coordinator { handle, leader: Some(leader), tx })
    }

    /// A cloneable submission handle.
    pub fn handle(&self) -> CoordinatorHandle {
        self.handle.clone()
    }

    /// Graceful shutdown: drain queues, stop workers, join threads.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.leader.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.leader.take() {
            let _ = j.join();
        }
    }
}

/// Round-robin dispatch with dead-worker failover: a `send` only fails when
/// the worker's receiver is gone (thread died), in which case the worker is
/// retired from the rotation and the item retries on the next one. Only
/// when no workers remain does the job fail — with a real error on its
/// reply slot, never silently.
fn dispatch(mut item: WorkItem, worker_txs: &mut Vec<SyncSender<WorkItem>>, next: &mut usize) {
    loop {
        if worker_txs.is_empty() {
            item.fail("no live workers (all worker threads exited)");
            return;
        }
        let idx = *next % worker_txs.len();
        match worker_txs[idx].send(item) {
            Ok(()) => {
                *next = (idx + 1) % worker_txs.len();
                return;
            }
            Err(SendError(returned)) => {
                // Dead worker: retire it and retry the item elsewhere.
                worker_txs.remove(idx);
                *next = idx; // same slot now holds the next worker
                item = returned;
            }
        }
    }
}

/// Leader loop: route GEMMs/CNNs round-robin (with dead-worker failover);
/// gather MLP rows into micro-batches bounded by the batching window and
/// the largest variant.
fn run_leader(
    rx: Receiver<Job>,
    mut worker_txs: Vec<SyncSender<WorkItem>>,
    policy: BatchPolicy,
    worker_joins: Vec<JoinHandle<()>>,
) {
    let mut next_worker = 0usize;
    let window = Duration::from_secs_f64(policy.max_wait_s);
    let mut pending: Vec<MlpJob> = Vec::new();
    let mut shutdown = false;

    while !shutdown {
        // Phase 1: block for the first job.
        match rx.recv() {
            Err(_) => break,
            Ok(Job::Shutdown) => break,
            Ok(Job::Gemm(g)) => {
                dispatch(WorkItem::Gemm(g), &mut worker_txs, &mut next_worker);
                continue;
            }
            Ok(Job::Cnn(c)) => {
                dispatch(WorkItem::Cnn(c), &mut worker_txs, &mut next_worker);
                continue;
            }
            Ok(Job::Mlp(m)) => pending.push(m),
        }

        // Phase 2: batching window — gather more rows until it expires or
        // the largest variant fills.
        let deadline = Instant::now() + window;
        while pending.len() < policy.max_batch() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Job::Mlp(m)) => pending.push(m),
                Ok(Job::Gemm(g)) => {
                    dispatch(WorkItem::Gemm(g), &mut worker_txs, &mut next_worker)
                }
                Ok(Job::Cnn(c)) => {
                    dispatch(WorkItem::Cnn(c), &mut worker_txs, &mut next_worker)
                }
                Ok(Job::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }

        // Phase 3: form + dispatch micro-batches (possibly several if a
        // burst exceeded the largest variant).
        while !pending.is_empty() {
            let take = pending.len().min(policy.max_batch());
            let (artifact, batch) = policy.pick_variant(take).clone();
            let jobs: Vec<MlpJob> = pending.drain(..take.min(batch)).collect();
            dispatch(
                WorkItem::Batch(MicroBatch { artifact, batch, jobs }),
                &mut worker_txs,
                &mut next_worker,
            );
        }
    }

    // Drain-and-stop: explicitly fail everything still queued (batched rows
    // gathered this cycle AND jobs still buffered in the ingress channel) so
    // every reply slot resolves, then stop workers and join.
    for j in pending {
        let _ = j.reply.send(Err(Error::Coordinator("shutdown".into())));
    }
    while let Ok(job) = rx.try_recv() {
        match job {
            Job::Gemm(g) => {
                let _ = g.reply.send(Err(Error::Coordinator("shutdown".into())));
            }
            Job::Mlp(m) => {
                let _ = m.reply.send(Err(Error::Coordinator("shutdown".into())));
            }
            Job::Cnn(c) => {
                let _ = c.reply.send(Err(Error::Coordinator("shutdown".into())));
            }
            Job::Shutdown => {}
        }
    }
    for tx in &worker_txs {
        let _ = tx.send(WorkItem::Shutdown);
    }
    drop(worker_txs);
    for j in worker_joins {
        let _ = j.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::response_slot;

    fn gemm_item(tag: i32) -> (WorkItem, Response) {
        let (reply, rx) = response_slot();
        let job = GemmJob {
            artifact: format!("g{tag}"),
            a: vec![tag],
            b: vec![tag],
            reply,
            enqueued: Instant::now(),
        };
        (WorkItem::Gemm(job), rx)
    }

    #[test]
    fn dispatch_skips_dead_workers() {
        let (live_tx, live_rx) = sync_channel::<WorkItem>(4);
        let (dead_tx, dead_rx) = sync_channel::<WorkItem>(4);
        drop(dead_rx); // worker 0 died
        let mut txs = vec![dead_tx, live_tx];
        let mut next = 0usize;

        let (item, _rx) = gemm_item(1);
        dispatch(item, &mut txs, &mut next);
        assert_eq!(txs.len(), 1, "dead worker retired from rotation");
        match live_rx.try_recv().unwrap() {
            WorkItem::Gemm(g) => assert_eq!(g.artifact, "g1"),
            other => panic!("wrong item routed: {other:?}"),
        }
    }

    #[test]
    fn dispatch_fails_job_when_no_workers_remain() {
        let (dead_tx, dead_rx) = sync_channel::<WorkItem>(4);
        drop(dead_rx);
        let mut txs = vec![dead_tx];
        let mut next = 0usize;
        let (item, rx) = gemm_item(2);
        dispatch(item, &mut txs, &mut next);
        assert!(txs.is_empty());
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("no live workers"), "{err}");
    }

    #[test]
    fn dispatch_round_robins_over_live_workers() {
        let (tx_a, rx_a) = sync_channel::<WorkItem>(8);
        let (tx_b, rx_b) = sync_channel::<WorkItem>(8);
        let mut txs = vec![tx_a, tx_b];
        let mut next = 0usize;
        let mut slots = Vec::new();
        for i in 0..4 {
            let (item, rx) = gemm_item(i);
            dispatch(item, &mut txs, &mut next);
            slots.push(rx);
        }
        assert_eq!(rx_a.try_iter().count(), 2);
        assert_eq!(rx_b.try_iter().count(), 2);
    }
}
