//! The coordinator service: leader thread, routing, lifecycle.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SendError, SyncSender, TrySendError,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, CnnMicroBatch, MicroBatch};
use crate::coordinator::request::{
    deadline_at, response_slot, CnnJob, GemmJob, Job, MlpJob, PingJob, Priority, Qos, Reply,
    Response, ResponseTx,
};
use crate::coordinator::stats::CoordinatorStats;
use crate::coordinator::worker::{run_worker, WorkItem};
use crate::dnn::models::CnnModel;
use crate::runtime::backend::BackendKind;
use crate::runtime::Manifest;
use crate::{Error, Result};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Directory with `manifest.txt` + HLO artifacts.
    pub artifact_dir: String,
    /// Worker threads (each owns its own engine + backend).
    pub workers: usize,
    /// Execution backend every worker builds its engine with — swap
    /// [`BackendKind::Software`] for [`BackendKind::Photonic`] to serve the
    /// same traffic with photonic-in-the-loop telemetry.
    pub backend: BackendKind,
    /// Dynamic-batching window, seconds.
    pub max_batch_wait_s: f64,
    /// Largest number of same-model CNN frames stacked into one
    /// t-dimension batch (1 disables CNN batching). Like MLP dynamic
    /// batching, stacking trades latency for throughput: a sparse CNN
    /// stream pays up to [`CoordinatorConfig::max_batch_wait_s`] per frame
    /// waiting for co-batchable traffic — set this to 1 for
    /// latency-critical single-stream serving. Batching stays enabled
    /// under analog noise injection: the backend attributes noise per
    /// output row (the per-row contract in [`crate::runtime::backend`]),
    /// so every stacked frame's reply carries exactly the noise events an
    /// unbatched run would have observed.
    pub max_cnn_batch: usize,
    /// Ingress queue depth — the admission-control bound. A submit against
    /// a full queue is *shed* (typed [`Error::Overloaded`], payload
    /// recovered through [`Rejected`], counted in
    /// [`CoordinatorStats::shed`]) instead of blocking the caller: queues
    /// absorb jitter, shedding absorbs spikes, and autoscaling absorbs
    /// sustained pressure.
    pub queue_depth: usize,
    /// Early-shed watermark for best-effort traffic: when `Some(w)`, a
    /// [`Priority::BestEffort`] submit is refused with [`Error::Overloaded`]
    /// once the shard's outstanding depth ([`CoordinatorStats::queue_depth`])
    /// reaches `w` — reserving the remaining queue slots for high-priority
    /// traffic so its completion holds through a mixed burst. `None`
    /// (default) sheds best-effort only when the queue is actually full,
    /// exactly like high-priority.
    pub best_effort_watermark: Option<usize>,
    /// Compile all artifacts at worker start (first-request latency vs
    /// startup time trade).
    pub warmup: bool,
    /// Time-indexed counter mode for analog noise: when `true`, every
    /// request is stamped with a per-coordinator counter nonce that noise-
    /// injecting backends fold into each output row's sub-stream key
    /// ([`crate::runtime::RowNonce`]) — byte-identical rows served under
    /// different nonces then observe *decorrelated* noise, while each
    /// `(seed, content, nonce)` draw stays deterministic. Default `false`:
    /// the pure content-keyed streams, bit-identical to historical serving.
    /// Cross-shard resubmission stays bit-identical in *both* modes: the
    /// fleet's [`RetryingSlot`](crate::coordinator::RetryingSlot) retains
    /// the nonce assigned at first acceptance and replays it on the
    /// survivor instead of drawing a fresh one.
    pub noise_nonce: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: "artifacts".into(),
            workers: 2,
            backend: BackendKind::Software,
            max_batch_wait_s: 0.002,
            max_cnn_batch: 8,
            queue_depth: 1024,
            best_effort_watermark: None,
            warmup: true,
            noise_nonce: false,
        }
    }
}

/// A submission the coordinator could not accept, with the moved payload
/// recovered from the channel's `SendError` — so callers that fail over
/// (the fleet router) can resubmit elsewhere *without cloning the payload
/// up front*. Submit-time failures never consume the payload; only a shard
/// dying after acceptance does (which is what the retained-payload
/// [`RetryingSlot`](crate::coordinator::RetryingSlot) exists for).
#[derive(Debug)]
pub struct Rejected<P> {
    /// Why the submission was refused.
    pub error: Error,
    /// The payload, returned intact.
    pub payload: P,
}

/// Cloneable client handle for submitting requests.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: SyncSender<Job>,
    stats: Arc<CoordinatorStats>,
    mlp_row_len: usize,
    /// Configured worker-pool size — the target `revive_workers` restores.
    workers: usize,
    /// Configured ingress bound (admission-refusal diagnostics).
    queue_depth: usize,
    /// Early-shed depth for best-effort traffic (see
    /// [`CoordinatorConfig::best_effort_watermark`]).
    best_effort_watermark: Option<usize>,
    /// Time-indexed noise-nonce counter (0 is never handed out; it means
    /// "content-keyed"). `None` when [`CoordinatorConfig::noise_nonce`] is
    /// off, so default serving stamps every job with nonce 0.
    nonce_counter: Option<Arc<AtomicU64>>,
}

impl CoordinatorHandle {
    /// Next per-request noise nonce (0 when the counter mode is off).
    fn next_nonce(&self) -> u64 {
        match &self.nonce_counter {
            None => 0,
            Some(c) => c.fetch_add(1, Ordering::Relaxed) + 1,
        }
    }

    /// The nonce for a submission: the retained one when a failover layer
    /// replays a request (bit-identical noisy resubmission), a fresh draw
    /// otherwise.
    fn pick_nonce(&self, retained: Option<u64>) -> u64 {
        retained.unwrap_or_else(|| self.next_nonce())
    }

    /// Non-blocking admission: enqueue a job against the bounded ingress
    /// queue, recovering it (with the refusal reason) on failure. A full
    /// queue — or a tripped best-effort watermark — *sheds* the job with
    /// typed [`Error::Overloaded`] instead of blocking the submitting
    /// thread; a disconnected channel is [`Error::ShardDown`]. The
    /// accepted-request counter only sticks for accepted jobs and sheds
    /// never enter it, so a rejected submission never leaks
    /// [`CoordinatorStats::queue_depth`].
    fn send_job(&self, job: Job) -> std::result::Result<(), (Error, Job)> {
        if let Some(w) = self.best_effort_watermark {
            if job.priority() == Priority::BestEffort {
                let depth = self.stats.queue_depth();
                if depth >= w as u64 {
                    self.stats.shed.fetch_add(1, Ordering::Relaxed);
                    self.stats.shed_best_effort.fetch_add(1, Ordering::Relaxed);
                    let error = Error::Overloaded(format!(
                        "best-effort watermark: {depth} outstanding >= {w}"
                    ));
                    return Err((error, job));
                }
            }
        }
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(returned)) => {
                self.stats.requests.fetch_sub(1, Ordering::Relaxed);
                self.stats.shed.fetch_add(1, Ordering::Relaxed);
                if returned.priority() == Priority::BestEffort {
                    self.stats.shed_best_effort.fetch_add(1, Ordering::Relaxed);
                }
                let error = Error::Overloaded(format!(
                    "ingress queue full ({} slots)",
                    self.queue_depth
                ));
                Err((error, returned))
            }
            Err(TrySendError::Disconnected(returned)) => {
                self.stats.requests.fetch_sub(1, Ordering::Relaxed);
                Err((Error::ShardDown("coordinator stopped".into()), returned))
            }
        }
    }

    /// Submit a GEMM against a named artifact; returns the response slot.
    pub fn submit_gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Response> {
        self.try_submit_gemm(artifact, a, b).map_err(|r| r.error)
    }

    /// [`CoordinatorHandle::submit_gemm`] with an explicit QoS envelope.
    pub fn submit_gemm_qos(
        &self,
        artifact: &str,
        a: Vec<i32>,
        b: Vec<i32>,
        qos: Qos,
    ) -> Result<Response> {
        self.try_submit_gemm_opts(artifact, a, b, qos, None)
            .map(|(rx, _)| rx)
            .map_err(|r| r.error)
    }

    /// Payload-recovering GEMM submission: a refused submit (full queue →
    /// [`Error::Overloaded`], stopped coordinator → [`Error::ShardDown`])
    /// hands `(a, b)` back inside the [`Rejected`] so a failover layer can
    /// resubmit elsewhere without having cloned.
    pub fn try_submit_gemm(
        &self,
        artifact: &str,
        a: Vec<i32>,
        b: Vec<i32>,
    ) -> std::result::Result<Response, Rejected<(Vec<i32>, Vec<i32>)>> {
        self.try_submit_gemm_opts(artifact, a, b, Qos::default(), None).map(|(rx, _)| rx)
    }

    /// [`CoordinatorHandle::try_submit_gemm`] with an explicit QoS envelope
    /// (payload-recovering, non-blocking).
    pub fn try_submit_gemm_qos(
        &self,
        artifact: &str,
        a: Vec<i32>,
        b: Vec<i32>,
        qos: Qos,
    ) -> std::result::Result<Response, Rejected<(Vec<i32>, Vec<i32>)>> {
        self.try_submit_gemm_opts(artifact, a, b, qos, None).map(|(rx, _)| rx)
    }

    /// Full-control GEMM submission: explicit [`Qos`] plus an optional
    /// retained noise nonce (failover replay). `Ok` carries the nonce the
    /// job was stamped with, so a retrying layer can retain it.
    pub(crate) fn try_submit_gemm_opts(
        &self,
        artifact: &str,
        a: Vec<i32>,
        b: Vec<i32>,
        qos: Qos,
        retained_nonce: Option<u64>,
    ) -> std::result::Result<(Response, u64), Rejected<(Vec<i32>, Vec<i32>)>> {
        let (reply, rx) = response_slot();
        let nonce = self.pick_nonce(retained_nonce);
        let job = Job::Gemm(GemmJob {
            artifact: artifact.to_string(),
            a,
            b,
            reply,
            enqueued: Instant::now(),
            nonce,
            qos,
        });
        match self.send_job(job) {
            Ok(()) => Ok((rx, nonce)),
            Err((error, Job::Gemm(g))) => Err(Rejected { error, payload: (g.a, g.b) }),
            Err(_) => unreachable!("send returns the job it was given"),
        }
    }

    /// Submit one MLP row; returns the response slot.
    pub fn submit_mlp(&self, row: Vec<i32>) -> Result<Response> {
        self.try_submit_mlp(row).map_err(|r| r.error)
    }

    /// [`CoordinatorHandle::submit_mlp`] with an explicit QoS envelope.
    pub fn submit_mlp_qos(&self, row: Vec<i32>, qos: Qos) -> Result<Response> {
        self.try_submit_mlp_opts(row, qos, None).map(|(rx, _)| rx).map_err(|r| r.error)
    }

    /// Payload-recovering MLP submission (see [`CoordinatorHandle::try_submit_gemm`]).
    /// Shape rejections return the row too — nothing consumed it.
    pub fn try_submit_mlp(
        &self,
        row: Vec<i32>,
    ) -> std::result::Result<Response, Rejected<Vec<i32>>> {
        self.try_submit_mlp_opts(row, Qos::default(), None).map(|(rx, _)| rx)
    }

    /// [`CoordinatorHandle::try_submit_mlp`] with an explicit QoS envelope
    /// (payload-recovering, non-blocking).
    pub fn try_submit_mlp_qos(
        &self,
        row: Vec<i32>,
        qos: Qos,
    ) -> std::result::Result<Response, Rejected<Vec<i32>>> {
        self.try_submit_mlp_opts(row, qos, None).map(|(rx, _)| rx)
    }

    /// Full-control MLP submission (explicit [`Qos`] + retained nonce; see
    /// [`CoordinatorHandle::try_submit_gemm_opts`]).
    pub(crate) fn try_submit_mlp_opts(
        &self,
        row: Vec<i32>,
        qos: Qos,
        retained_nonce: Option<u64>,
    ) -> std::result::Result<(Response, u64), Rejected<Vec<i32>>> {
        if row.len() != self.mlp_row_len {
            let error = Error::Shape(format!(
                "mlp row has {} elements, expected {}",
                row.len(),
                self.mlp_row_len
            ));
            return Err(Rejected { error, payload: row });
        }
        let (reply, rx) = response_slot();
        let nonce = self.pick_nonce(retained_nonce);
        let job = Job::Mlp(MlpJob { row, reply, enqueued: Instant::now(), nonce, qos });
        match self.send_job(job) {
            Ok(()) => Ok((rx, nonce)),
            Err((error, Job::Mlp(m))) => Err(Rejected { error, payload: m.row }),
            Err(_) => unreachable!("send returns the job it was given"),
        }
    }

    /// Submit a whole-CNN inference; validates the layer chain against the
    /// input length up front. Returns the response slot.
    pub fn submit_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Response> {
        self.try_submit_cnn(model, input).map_err(|r| r.error)
    }

    /// [`CoordinatorHandle::submit_cnn`] with an explicit QoS envelope.
    pub fn submit_cnn_qos(&self, model: CnnModel, input: Vec<i32>, qos: Qos) -> Result<Response> {
        self.try_submit_cnn_opts(model, input, qos, None)
            .map(|(rx, _)| rx)
            .map_err(|r| r.error)
    }

    /// Payload-recovering CNN submission (see [`CoordinatorHandle::try_submit_gemm`]).
    pub fn try_submit_cnn(
        &self,
        model: CnnModel,
        input: Vec<i32>,
    ) -> std::result::Result<Response, Rejected<(CnnModel, Vec<i32>)>> {
        self.try_submit_cnn_opts(model, input, Qos::default(), None).map(|(rx, _)| rx)
    }

    /// [`CoordinatorHandle::try_submit_cnn`] with an explicit QoS envelope
    /// (payload-recovering, non-blocking).
    pub fn try_submit_cnn_qos(
        &self,
        model: CnnModel,
        input: Vec<i32>,
        qos: Qos,
    ) -> std::result::Result<Response, Rejected<(CnnModel, Vec<i32>)>> {
        self.try_submit_cnn_opts(model, input, qos, None).map(|(rx, _)| rx)
    }

    /// Full-control CNN submission (explicit [`Qos`] + retained nonce; see
    /// [`CoordinatorHandle::try_submit_gemm_opts`]).
    pub(crate) fn try_submit_cnn_opts(
        &self,
        model: CnnModel,
        input: Vec<i32>,
        qos: Qos,
        retained_nonce: Option<u64>,
    ) -> std::result::Result<(Response, u64), Rejected<(CnnModel, Vec<i32>)>> {
        if let Err(error) = crate::runtime::cnnrun::validate_cnn_input(&model, input.len()) {
            return Err(Rejected { error, payload: (model, input) });
        }
        let (reply, rx) = response_slot();
        let nonce = self.pick_nonce(retained_nonce);
        let job = Job::Cnn(CnnJob { model, input, reply, enqueued: Instant::now(), nonce, qos });
        match self.send_job(job) {
            Ok(()) => Ok((rx, nonce)),
            Err((error, Job::Cnn(c))) => Err(Rejected { error, payload: (c.model, c.input) }),
            Err(_) => unreachable!("send returns the job it was given"),
        }
    }

    /// Submit a CNN described as trace text (see [`crate::dnn::trace`]).
    ///
    /// Prefer parsing once with [`crate::dnn::parse_trace`] and reusing the
    /// [`CnnModel`] across submissions: trace parsing leaks the model name
    /// (the name is `&'static`).
    pub fn submit_cnn_trace(&self, trace: &str, input: Vec<i32>) -> Result<Response> {
        self.submit_cnn(crate::dnn::parse_trace(trace)?, input)
    }

    /// Blocking MLP inference convenience.
    pub fn infer_mlp(&self, row: Vec<i32>) -> Result<Vec<i32>> {
        Ok(self
            .submit_mlp(row)?
            .recv()
            .map_err(|_| Error::Coordinator("response dropped (worker crashed mid-request?)".into()))??
            .outputs)
    }

    /// Blocking GEMM convenience.
    pub fn gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Vec<i32>> {
        Ok(self.gemm_reply(artifact, a, b)?.outputs)
    }

    /// Blocking GEMM returning the full [`Reply`] (outputs + telemetry).
    pub fn gemm_reply(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Reply> {
        self.submit_gemm(artifact, a, b)?
            .recv()
            .map_err(|_| Error::Coordinator("response dropped (worker crashed mid-request?)".into()))?
    }

    /// Blocking CNN inference returning the full [`Reply`] (logits +
    /// per-layer telemetry).
    pub fn infer_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Reply> {
        self.submit_cnn(model, input)?
            .recv()
            .map_err(|_| Error::Coordinator("response dropped (worker crashed mid-request?)".into()))?
    }

    /// Retire every worker from the rotation (maintenance drain / fault
    /// injection): workers finish their queued items and exit, after which
    /// jobs on this coordinator fail with a "no live workers" error — the
    /// signal a [`FleetHandle`](crate::coordinator::FleetHandle) uses to
    /// fail the shard over. The leader stays alive so every reply slot
    /// still resolves.
    pub fn retire_workers(&self) -> Result<()> {
        self.send_maintenance(Job::RetireWorkers)
    }

    /// Respawn workers until the pool holds `target` again (the leader
    /// survives [`CoordinatorHandle::retire_workers`] and worker deaths, so
    /// a shard can rebuild its pool in place). Fire-and-forget: follow with
    /// [`CoordinatorHandle::ping`] to confirm the revived pool serves.
    pub fn revive_workers(&self, target: usize) -> Result<()> {
        self.send_maintenance(Job::ReviveWorkers { target: target.max(1) })
    }

    /// Enqueue a maintenance job without ever blocking on the bounded
    /// ingress queue — a bare `send` here is exactly the full-queue
    /// deadlock class the typed-shedding rework removed from submission
    /// (`no-blocking-ingress`). Maintenance is rarer and smaller than
    /// request traffic, so instead of refusing immediately it retries a
    /// bounded window (the shorter cousin of `stop_leader`'s drain loop)
    /// and then refuses typed: busy-not-dead [`Error::Overloaded`] when the
    /// queue never drained, [`Error::ShardDown`] when the leader is gone.
    fn send_maintenance(&self, mut job: Job) -> Result<()> {
        for _ in 0..500 {
            match self.tx.try_send(job) {
                Ok(()) => return Ok(()),
                Err(TrySendError::Full(returned)) => {
                    job = returned;
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(TrySendError::Disconnected(_)) => {
                    return Err(Error::ShardDown("coordinator stopped".into()))
                }
            }
        }
        Err(Error::Overloaded(
            "ingress queue full; maintenance job refused after bounded retry".into(),
        ))
    }

    /// Configured worker-pool size (the default revival target).
    pub fn configured_workers(&self) -> usize {
        self.workers
    }

    /// Health probe: routes a ping through leader dispatch to a worker and
    /// waits up to `timeout` for the pong. `Ok` proves the shard serves end
    /// to end; errors mean the coordinator is stopped, the pool is dead, or
    /// the probe timed out. Pings never touch request/completed stats, so
    /// probing cannot skew routing.
    pub fn ping(&self, timeout: Duration) -> Result<()> {
        let (reply, rx) = response_slot();
        match self.tx.try_send(Job::Ping(PingJob { reply })) {
            Ok(()) => {}
            // A full ingress queue proves the leader is alive (a dropped
            // receiver reports Disconnected even when full): the shard is
            // busy-not-dead, and a probe must never block behind the very
            // backlog it is checking on.
            Err(TrySendError::Full(_)) => return Ok(()),
            Err(TrySendError::Disconnected(_)) => {
                return Err(Error::ShardDown("coordinator stopped".into()))
            }
        }
        match rx.recv_timeout(timeout) {
            Ok(Ok(_)) => Ok(()),
            Ok(Err(e)) => Err(e),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                Err(Error::ShardDown("health probe timed out".into()))
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::ShardDown("health probe slot dropped".into()))
            }
        }
    }

    /// Shared metrics.
    pub fn stats(&self) -> &CoordinatorStats {
        &self.stats
    }

    /// The shared stats behind their `Arc` (fleet rollups hold these across
    /// the router's interior-mutable slot table).
    pub fn stats_arc(&self) -> Arc<CoordinatorStats> {
        self.stats.clone()
    }
}

/// The worker-spawn recipe, shared by [`Coordinator::start`] and the
/// leader's revival path ([`Job::ReviveWorkers`]): everything a fresh
/// worker thread needs to build its engine and join the pool.
struct WorkerSpawner {
    artifact_dir: String,
    backend: BackendKind,
    warmup: bool,
    queue_depth: usize,
    stats: Arc<CoordinatorStats>,
}

impl WorkerSpawner {
    /// Spawn worker `id`; `ready` is `Some` only at coordinator start
    /// (revived workers must not block the serving leader on engine init).
    fn spawn(
        &self,
        id: usize,
        ready: Option<SyncSender<()>>,
    ) -> Result<(SyncSender<WorkItem>, JoinHandle<()>)> {
        let (wtx, wrx) = sync_channel::<WorkItem>(self.queue_depth);
        let dir = self.artifact_dir.clone();
        let backend = self.backend.clone();
        let st = self.stats.clone();
        let warm = self.warmup;
        let join = std::thread::Builder::new()
            .name(format!("spoga-worker-{id}"))
            .spawn(move || run_worker(id, dir, backend, warm, ready, wrx, st))
            .map_err(|e| Error::Coordinator(format!("spawn worker: {e}")))?;
        Ok((wtx, join))
    }
}

/// The running coordinator (leader + workers). Dropping it shuts down.
pub struct Coordinator {
    handle: CoordinatorHandle,
    leader: Option<JoinHandle<()>>,
    tx: SyncSender<Job>,
}

impl Coordinator {
    /// Start the service: validates the manifest, spawns workers + leader.
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        // Validate the manifest up front (fail fast with a good error).
        let manifest = Manifest::load(&cfg.artifact_dir)?;
        let variants = manifest.mlp_batch_variants();
        if variants.is_empty() {
            return Err(Error::Config("no mlp_b* artifacts in manifest".into()));
        }
        let mlp_row_len = manifest.get(&variants[0].0)?.inputs[0].elements() / variants[0].1;
        let policy = BatchPolicy::new(variants, cfg.max_batch_wait_s)?;
        // Batching stays at full strength under noise injection: backends
        // attribute noise per output row (content-keyed sub-streams — see
        // the per-row contract in `runtime::backend`), so the batcher hands
        // every MLP member its own row's events and the CNN runtime slices
        // stacked frames exactly. No noise→batch=1 clamp is needed.
        let cnn_batch_cap = cfg.max_cnn_batch.max(1);
        let workers = cfg.workers.max(1);

        let stats = Arc::new(CoordinatorStats::default());
        stats.live_workers.store(workers as u64, Ordering::Relaxed);
        let (tx, rx) = sync_channel::<Job>(cfg.queue_depth);

        let spawner = WorkerSpawner {
            artifact_dir: cfg.artifact_dir.clone(),
            backend: cfg.backend.clone(),
            warmup: cfg.warmup,
            queue_depth: cfg.queue_depth,
            stats: stats.clone(),
        };

        // Workers.
        let mut worker_txs = Vec::with_capacity(workers);
        let mut joins = Vec::new();
        let (ready_tx, ready_rx) = sync_channel::<()>(workers);
        for id in 0..workers {
            let (wtx, join) = spawner.spawn(id, Some(ready_tx.clone()))?;
            worker_txs.push(wtx);
            joins.push(join);
        }
        drop(ready_tx);
        // Block until every worker finished (possibly warm) engine init.
        for _ in 0..workers {
            let _ = ready_rx.recv();
        }

        // Leader.
        let leader = {
            let leader_stats = stats.clone();
            std::thread::Builder::new()
                .name("spoga-leader".into())
                .spawn(move || {
                    run_leader(rx, worker_txs, policy, cnn_batch_cap, leader_stats, joins, spawner)
                })
                .map_err(|e| Error::Coordinator(format!("spawn leader: {e}")))?
        };

        let nonce_counter = cfg.noise_nonce.then(|| Arc::new(AtomicU64::new(0)));
        let handle = CoordinatorHandle {
            tx: tx.clone(),
            stats,
            mlp_row_len,
            workers,
            queue_depth: cfg.queue_depth,
            best_effort_watermark: cfg.best_effort_watermark,
            nonce_counter,
        };
        Ok(Coordinator { handle, leader: Some(leader), tx })
    }

    /// A cloneable submission handle.
    pub fn handle(&self) -> CoordinatorHandle {
        self.handle.clone()
    }

    /// Deliver `Job::Shutdown` without blocking on a full ingress queue,
    /// then join the leader. A live leader drains the queue, so `Full`
    /// clears within a bounded retry; `Disconnected` means the leader is
    /// already gone (it exits on channel disconnect too). If the queue
    /// stays full past the bound the leader is wedged — we skip the join
    /// (leaking the thread) rather than hang teardown forever.
    fn stop_leader(&mut self) {
        let mut delivered = false;
        for _ in 0..5000 {
            match self.tx.try_send(Job::Shutdown) {
                Ok(()) | Err(TrySendError::Disconnected(_)) => {
                    delivered = true;
                    break;
                }
                Err(TrySendError::Full(_)) => {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        if delivered {
            if let Some(j) = self.leader.take() {
                let _ = j.join();
            }
        } else {
            self.leader.take();
        }
    }

    /// Graceful shutdown: drain queues, stop workers, join threads.
    /// Always completes — even against an ingress queue kept full by a
    /// burst of submitters (see [`Coordinator::stop_leader`]).
    pub fn shutdown(mut self) {
        self.stop_leader();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop_leader();
    }
}

/// Round-robin dispatch with dead-worker failover: a `send` only fails when
/// the worker's receiver is gone (thread died), in which case the worker is
/// retired from the rotation and the item retries on the next one. Only
/// when no workers remain does the job fail — with a real error on its
/// reply slot (counted in `stats.failed`, so `queue_depth()` stays
/// truthful), never silently.
fn dispatch(
    mut item: WorkItem,
    worker_txs: &mut Vec<SyncSender<WorkItem>>,
    next: &mut usize,
    stats: &CoordinatorStats,
) {
    loop {
        if worker_txs.is_empty() {
            stats.failed.fetch_add(item.reply_slots(), Ordering::Relaxed);
            item.fail("no live workers (all worker threads exited)");
            return;
        }
        let idx = *next % worker_txs.len();
        match worker_txs[idx].send(item) {
            Ok(()) => {
                *next = (idx + 1) % worker_txs.len();
                return;
            }
            Err(SendError(returned)) => {
                // Dead worker: retire it and retry the item elsewhere.
                worker_txs.remove(idx);
                stats.live_workers.store(worker_txs.len() as u64, Ordering::Relaxed);
                *next = idx; // same slot now holds the next worker
                item = returned;
            }
        }
    }
}

/// Retire every worker from the rotation: each one drains its queued items
/// and exits when it reaches the Shutdown marker. Threads join at leader
/// exit (the leader keeps their `JoinHandle`s).
fn retire_all_workers(worker_txs: &mut Vec<SyncSender<WorkItem>>, stats: &CoordinatorStats) {
    for tx in worker_txs.drain(..) {
        let _ = tx.send(WorkItem::Shutdown);
    }
    stats.live_workers.store(0, Ordering::Relaxed);
}

/// Revive the pool to `target` workers: spawn the shortfall through the
/// leader's [`WorkerSpawner`] (fresh engines, no readiness handshake — the
/// leader keeps serving while revived engines warm; their channels buffer
/// dispatched work meanwhile). A worker whose engine init fails exits
/// immediately and is retired by the next dispatch, exactly like at start.
///
/// Stale senders of workers that already died (crashed, or exited on a
/// failed engine init) are pruned *first* — counting them toward `target`
/// would under-provision the revived pool and inflate the `live_workers`
/// gauge until the next dispatch happened to hit them.
fn revive_workers_to(
    target: usize,
    worker_txs: &mut Vec<SyncSender<WorkItem>>,
    worker_joins: &mut Vec<JoinHandle<()>>,
    next_worker_id: &mut usize,
    spawner: &WorkerSpawner,
    stats: &CoordinatorStats,
) {
    worker_txs.retain(|tx| {
        let (reply, pong) = response_slot();
        match tx.try_send(WorkItem::Ping(PingJob { reply })) {
            // Accepted: the worker will pong into the dropped slot — cheap
            // and harmless. A full queue also proves the receiver is alive
            // (a dropped receiver reports Disconnected even when full).
            Ok(()) => {
                drop(pong);
                true
            }
            Err(std::sync::mpsc::TrySendError::Full(_)) => true,
            Err(std::sync::mpsc::TrySendError::Disconnected(_)) => false,
        }
    });
    // Drop join handles of threads that already exited, so repeated revive
    // cycles (e.g. a janitor retrying a persistently failing artifact dir)
    // do not accumulate handles without bound. Finished threads need no
    // join for correctness — only still-running workers are joined at
    // leader exit.
    worker_joins.retain(|j| !j.is_finished());
    let mut spawned = false;
    while worker_txs.len() < target {
        match spawner.spawn(*next_worker_id, None) {
            Ok((wtx, join)) => {
                worker_txs.push(wtx);
                worker_joins.push(join);
                *next_worker_id += 1;
                spawned = true;
            }
            Err(e) => {
                eprintln!("revive: could not spawn worker {next_worker_id}: {e}");
                break;
            }
        }
    }
    stats.live_workers.store(worker_txs.len() as u64, Ordering::Relaxed);
    if spawned {
        stats.revivals.fetch_add(1, Ordering::Relaxed);
    }
}

/// Extract up to `cap` pending frames of `model`, in arrival order, with a
/// single order-preserving partition pass (`Vec::remove` in a loop is
/// O(n²) per flush under large windows).
fn extract_cnn_group(pending: &mut Vec<CnnJob>, model: &CnnModel, cap: usize) -> Vec<CnnJob> {
    let mut jobs = Vec::new();
    let mut rest = Vec::with_capacity(pending.len());
    for j in pending.drain(..) {
        if jobs.len() < cap && j.model == *model {
            jobs.push(j);
        } else {
            rest.push(j);
        }
    }
    *pending = rest;
    jobs
}

/// How close to a pending job's deadline the leader closes a gathering
/// window early: flushing *at* the deadline would already have missed it.
/// Sized well above `recv_timeout` wake-up jitter on a loaded host — an
/// over-tight margin would let the timer overshoot expire the very job the
/// early flush exists to save.
const DEADLINE_FLUSH_MARGIN: Duration = Duration::from_millis(25);

/// Whether a job's deadline has passed.
fn job_expired(enqueued: Instant, qos: &Qos, now: Instant) -> bool {
    matches!(qos.deadline, Some(d) if now.duration_since(enqueued) >= d)
}

/// Fail one job's reply slot with typed [`Error::DeadlineExceeded`] —
/// before dispatch, so no worker execute is wasted on a reply nobody
/// wants. Counted in `failed` (the stats invariant closes out) and
/// attributed in `deadline_expired`.
fn fail_deadline(stats: &CoordinatorStats, reply: &ResponseTx, enqueued: Instant, qos: &Qos) {
    stats.failed.fetch_add(1, Ordering::Relaxed);
    stats.deadline_expired.fetch_add(1, Ordering::Relaxed);
    let _ = reply.send(Err(Error::DeadlineExceeded(format!(
        "queued {:.1} ms, deadline {:.1} ms",
        enqueued.elapsed().as_secs_f64() * 1e3,
        qos.deadline.unwrap_or_default().as_secs_f64() * 1e3,
    ))));
}

/// Drop every already-expired job from the gathering buffers, failing each
/// typed. Runs before every flush so an expired job never reaches a worker.
fn reap_expired(pending: &mut Vec<MlpJob>, pending_cnn: &mut Vec<CnnJob>, stats: &CoordinatorStats) {
    let now = Instant::now();
    pending.retain(|j| {
        if job_expired(j.enqueued, &j.qos, now) {
            fail_deadline(stats, &j.reply, j.enqueued, &j.qos);
            false
        } else {
            true
        }
    });
    pending_cnn.retain(|j| {
        if job_expired(j.enqueued, &j.qos, now) {
            fail_deadline(stats, &j.reply, j.enqueued, &j.qos);
            false
        } else {
            true
        }
    });
}

/// The earliest deadline instant across both gathering buffers.
fn earliest_deadline(pending: &[MlpJob], pending_cnn: &[CnnJob]) -> Option<Instant> {
    let mlp = pending.iter().filter_map(|j| deadline_at(j.enqueued, &j.qos)).min();
    let cnn = pending_cnn.iter().filter_map(|j| deadline_at(j.enqueued, &j.qos)).min();
    match (mlp, cnn) {
        (Some(a), Some(b)) => Some(a.min(b)),
        (a, None) => a,
        (None, b) => b,
    }
}

/// Take up to `n` MLP jobs for the next micro-batch, high-priority first:
/// every [`Priority::High`] job (in arrival order) is selected before any
/// [`Priority::BestEffort`] one. Jobs left behind keep their arrival order,
/// and the taken set is returned in arrival order too — priority decides
/// *which* jobs board the earliest batch, not their position inside it.
fn take_by_priority(pending: &mut Vec<MlpJob>, n: usize) -> Vec<MlpJob> {
    if pending.len() <= n {
        return std::mem::take(pending);
    }
    let mut take = vec![false; pending.len()];
    let mut left = n;
    for class in [Priority::High, Priority::BestEffort] {
        for (i, j) in pending.iter().enumerate() {
            if left == 0 {
                break;
            }
            if j.qos.priority == class && !take[i] {
                take[i] = true;
                left -= 1;
            }
        }
        if left == 0 {
            break;
        }
    }
    let mut taken = Vec::with_capacity(n);
    let mut rest = Vec::with_capacity(pending.len() - n);
    for (i, j) in pending.drain(..).enumerate() {
        if take[i] {
            taken.push(j);
        } else {
            rest.push(j);
        }
    }
    *pending = rest;
    taken
}

/// Flush every pending CNN frame as t-stacked micro-batches, in arrival
/// order (head model first), at most `cap` frames per batch. Used when the
/// batching window closes — partial groups go out as-is.
fn flush_cnn_batches(
    pending: &mut Vec<CnnJob>,
    cap: usize,
    worker_txs: &mut Vec<SyncSender<WorkItem>>,
    next_worker: &mut usize,
    stats: &CoordinatorStats,
) {
    while !pending.is_empty() {
        let model = pending[0].model.clone();
        let jobs = extract_cnn_group(pending, &model, cap);
        dispatch(WorkItem::CnnBatch(CnnMicroBatch { model, jobs }), worker_txs, next_worker, stats);
    }
}

/// Mid-window flush of exactly one *full* same-model stack, if the model of
/// the most recently gathered frame just reached `cap` members. Partial
/// groups — including minority models in mixed traffic — keep gathering
/// until the window deadline; a full stack gains nothing by waiting.
fn flush_full_cnn_group(
    pending: &mut Vec<CnnJob>,
    cap: usize,
    worker_txs: &mut Vec<SyncSender<WorkItem>>,
    next_worker: &mut usize,
    stats: &CoordinatorStats,
) {
    let model = match pending.last() {
        Some(j) => j.model.clone(),
        None => return,
    };
    if pending.iter().filter(|j| j.model == model).count() >= cap {
        let jobs = extract_cnn_group(pending, &model, cap);
        dispatch(WorkItem::CnnBatch(CnnMicroBatch { model, jobs }), worker_txs, next_worker, stats);
    }
}

/// Leader loop: route GEMMs round-robin (with dead-worker failover); gather
/// MLP rows and same-model CNN frames into micro-batches bounded by the
/// batching window, the largest MLP variant, and the CNN stacking cap.
fn run_leader(
    rx: Receiver<Job>,
    mut worker_txs: Vec<SyncSender<WorkItem>>,
    policy: BatchPolicy,
    cnn_batch_cap: usize,
    stats: Arc<CoordinatorStats>,
    mut worker_joins: Vec<JoinHandle<()>>,
    spawner: WorkerSpawner,
) {
    let mut next_worker = 0usize;
    let mut next_worker_id = worker_txs.len();
    let window = Duration::from_secs_f64(policy.max_wait_s);
    let mut pending: Vec<MlpJob> = Vec::new();
    let mut pending_cnn: Vec<CnnJob> = Vec::new();
    let mut shutdown = false;

    while !shutdown {
        // Phase 1: block for the first batchable job.
        match rx.recv() {
            Err(_) => break,
            Ok(Job::Shutdown) => break,
            Ok(Job::RetireWorkers) => {
                retire_all_workers(&mut worker_txs, &stats);
                continue;
            }
            Ok(Job::ReviveWorkers { target }) => {
                revive_workers_to(
                    target,
                    &mut worker_txs,
                    &mut worker_joins,
                    &mut next_worker_id,
                    &spawner,
                    &stats,
                );
                continue;
            }
            Ok(Job::Ping(p)) => {
                dispatch(WorkItem::Ping(p), &mut worker_txs, &mut next_worker, &stats);
                continue;
            }
            Ok(Job::Gemm(g)) => {
                if job_expired(g.enqueued, &g.qos, Instant::now()) {
                    fail_deadline(&stats, &g.reply, g.enqueued, &g.qos);
                } else {
                    dispatch(WorkItem::Gemm(g), &mut worker_txs, &mut next_worker, &stats);
                }
                continue;
            }
            Ok(Job::Cnn(c)) if cnn_batch_cap <= 1 => {
                if job_expired(c.enqueued, &c.qos, Instant::now()) {
                    fail_deadline(&stats, &c.reply, c.enqueued, &c.qos);
                } else {
                    dispatch(WorkItem::Cnn(c), &mut worker_txs, &mut next_worker, &stats);
                }
                continue;
            }
            Ok(Job::Cnn(c)) => pending_cnn.push(c),
            Ok(Job::Mlp(m)) => pending.push(m),
        }

        // Phase 2: batching window — gather more batchable jobs until the
        // deadline. *Full* batches flush inline (they gain nothing by
        // waiting) while the window stays open, so heavy traffic in one
        // class never truncates the other's gathering; partial batches —
        // including minority models in mixed CNN traffic — wait for the
        // deadline. The window closes *early* when the tightest pending
        // per-job deadline would otherwise be missed waiting for the full
        // window, and already-expired members fail typed before any flush.
        let window_end = Instant::now() + window;
        loop {
            reap_expired(&mut pending, &mut pending_cnn, &stats);
            while pending.len() >= policy.max_batch() {
                let (artifact, batch) = policy.pick_variant(policy.max_batch()).clone();
                let jobs = take_by_priority(&mut pending, batch);
                dispatch(
                    WorkItem::Batch(MicroBatch { artifact, batch, jobs }),
                    &mut worker_txs,
                    &mut next_worker,
                    &stats,
                );
            }
            let now = Instant::now();
            let gather_until = match earliest_deadline(&pending, &pending_cnn) {
                Some(d) => window_end.min(d.checked_sub(DEADLINE_FLUSH_MARGIN).unwrap_or(now)),
                None => window_end,
            };
            if now >= gather_until {
                break;
            }
            match rx.recv_timeout(gather_until - now) {
                Ok(Job::Mlp(m)) => pending.push(m),
                Ok(Job::Gemm(g)) => {
                    if job_expired(g.enqueued, &g.qos, Instant::now()) {
                        fail_deadline(&stats, &g.reply, g.enqueued, &g.qos);
                    } else {
                        dispatch(WorkItem::Gemm(g), &mut worker_txs, &mut next_worker, &stats)
                    }
                }
                Ok(Job::Cnn(c)) if cnn_batch_cap <= 1 => {
                    if job_expired(c.enqueued, &c.qos, Instant::now()) {
                        fail_deadline(&stats, &c.reply, c.enqueued, &c.qos);
                    } else {
                        dispatch(WorkItem::Cnn(c), &mut worker_txs, &mut next_worker, &stats)
                    }
                }
                Ok(Job::Cnn(c)) => {
                    pending_cnn.push(c);
                    flush_full_cnn_group(
                        &mut pending_cnn,
                        cnn_batch_cap,
                        &mut worker_txs,
                        &mut next_worker,
                        &stats,
                    );
                }
                Ok(Job::RetireWorkers) => retire_all_workers(&mut worker_txs, &stats),
                Ok(Job::ReviveWorkers { target }) => revive_workers_to(
                    target,
                    &mut worker_txs,
                    &mut worker_joins,
                    &mut next_worker_id,
                    &spawner,
                    &stats,
                ),
                Ok(Job::Ping(p)) => {
                    dispatch(WorkItem::Ping(p), &mut worker_txs, &mut next_worker, &stats)
                }
                Ok(Job::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }

        // Phase 3: the window closed — flush what gathered (possibly
        // several batches if a burst exceeded the caps), expired members
        // failed typed first, high-priority jobs boarding ahead of
        // best-effort.
        reap_expired(&mut pending, &mut pending_cnn, &stats);
        while !pending.is_empty() {
            let take = pending.len().min(policy.max_batch());
            let (artifact, batch) = policy.pick_variant(take).clone();
            let jobs = take_by_priority(&mut pending, take.min(batch));
            dispatch(
                WorkItem::Batch(MicroBatch { artifact, batch, jobs }),
                &mut worker_txs,
                &mut next_worker,
                &stats,
            );
        }
        // Stable partition: high-priority CNN frames flush ahead of
        // best-effort; arrival order holds within each class (the default
        // all-high case is untouched).
        pending_cnn.sort_by_key(|j| matches!(j.qos.priority, Priority::BestEffort));
        flush_cnn_batches(
            &mut pending_cnn,
            cnn_batch_cap,
            &mut worker_txs,
            &mut next_worker,
            &stats,
        );
    }

    // Drain-and-stop: explicitly fail everything still queued (batched rows
    // gathered this cycle AND jobs still buffered in the ingress channel) so
    // every reply slot resolves — each counted in `failed` so the stats
    // invariant (requests = completed + failed + unresolved) closes out.
    let fail_one = |stats: &CoordinatorStats, reply: &crate::coordinator::request::ResponseTx| {
        stats.failed.fetch_add(1, Ordering::Relaxed);
        let _ = reply.send(Err(Error::ShardDown("shutdown".into())));
    };
    for j in pending {
        fail_one(&stats, &j.reply);
    }
    for j in pending_cnn {
        fail_one(&stats, &j.reply);
    }
    while let Ok(job) = rx.try_recv() {
        match job {
            Job::Gemm(g) => fail_one(&stats, &g.reply),
            Job::Mlp(m) => fail_one(&stats, &m.reply),
            Job::Cnn(c) => fail_one(&stats, &c.reply),
            // Pings are not counted as requests, so only the slot resolves.
            Job::Ping(p) => {
                let _ = p.reply.send(Err(Error::ShardDown("shutdown".into())));
            }
            Job::RetireWorkers | Job::ReviveWorkers { .. } | Job::Shutdown => {}
        }
    }
    for tx in &worker_txs {
        let _ = tx.send(WorkItem::Shutdown);
    }
    drop(worker_txs);
    for j in worker_joins {
        let _ = j.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::response_slot;

    fn gemm_item(tag: i32) -> (WorkItem, Response) {
        let (reply, rx) = response_slot();
        let job = GemmJob {
            artifact: format!("g{tag}"),
            a: vec![tag],
            b: vec![tag],
            reply,
            enqueued: Instant::now(),
            nonce: 0,
            qos: Qos::default(),
        };
        (WorkItem::Gemm(job), rx)
    }

    fn mlp_job(tag: i32, qos: Qos) -> (MlpJob, Response) {
        let (reply, rx) = response_slot();
        (MlpJob { row: vec![tag], reply, enqueued: Instant::now(), nonce: 0, qos }, rx)
    }

    fn cnn_job(name: &'static str, tag: i32) -> CnnJob {
        let (reply, _rx) = response_slot();
        CnnJob {
            model: CnnModel { name, layers: vec![] },
            input: vec![tag],
            reply,
            enqueued: Instant::now(),
            nonce: 0,
            qos: Qos::default(),
        }
    }

    /// A handle over a bare bounded channel with no leader draining it —
    /// the deterministic way to exercise admission control.
    fn loose_handle(
        depth: usize,
        watermark: Option<usize>,
    ) -> (CoordinatorHandle, Receiver<Job>) {
        let (tx, rx) = sync_channel::<Job>(depth);
        let handle = CoordinatorHandle {
            tx,
            stats: Arc::new(CoordinatorStats::default()),
            mlp_row_len: 1,
            workers: 1,
            queue_depth: depth,
            best_effort_watermark: watermark,
            nonce_counter: None,
        };
        (handle, rx)
    }

    #[test]
    fn dispatch_skips_dead_workers() {
        let stats = CoordinatorStats::default();
        let (live_tx, live_rx) = sync_channel::<WorkItem>(4);
        let (dead_tx, dead_rx) = sync_channel::<WorkItem>(4);
        drop(dead_rx); // worker 0 died
        let mut txs = vec![dead_tx, live_tx];
        let mut next = 0usize;

        let (item, _rx) = gemm_item(1);
        dispatch(item, &mut txs, &mut next, &stats);
        assert_eq!(txs.len(), 1, "dead worker retired from rotation");
        match live_rx.try_recv().unwrap() {
            WorkItem::Gemm(g) => assert_eq!(g.artifact, "g1"),
            other => panic!("wrong item routed: {other:?}"),
        }
        assert_eq!(stats.failed.load(Ordering::Relaxed), 0, "rerouted, not failed");
    }

    #[test]
    fn dispatch_fails_job_when_no_workers_remain() {
        let stats = CoordinatorStats::default();
        let (dead_tx, dead_rx) = sync_channel::<WorkItem>(4);
        drop(dead_rx);
        let mut txs = vec![dead_tx];
        let mut next = 0usize;
        let (item, rx) = gemm_item(2);
        dispatch(item, &mut txs, &mut next, &stats);
        assert!(txs.is_empty());
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("no live workers"), "{err}");
        assert!(matches!(err, Error::ShardDown(_)), "fleet failover signal");
        // The failure is counted, so queue_depth() does not leak.
        assert_eq!(stats.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn dispatch_round_robins_over_live_workers() {
        let stats = CoordinatorStats::default();
        let (tx_a, rx_a) = sync_channel::<WorkItem>(8);
        let (tx_b, rx_b) = sync_channel::<WorkItem>(8);
        let mut txs = vec![tx_a, tx_b];
        let mut next = 0usize;
        let mut slots = Vec::new();
        for i in 0..4 {
            let (item, rx) = gemm_item(i);
            dispatch(item, &mut txs, &mut next, &stats);
            slots.push(rx);
        }
        assert_eq!(rx_a.try_iter().count(), 2);
        assert_eq!(rx_b.try_iter().count(), 2);
    }

    #[test]
    fn full_ingress_queue_sheds_typed_instead_of_blocking() {
        let (h, _rx) = loose_handle(1, None);
        let started = Instant::now();
        // First submit fills the only slot (nothing drains it).
        h.try_submit_mlp(vec![1]).expect("first submit fits the queue");
        // Second must come back immediately: typed, payload recovered.
        let rejected = h.try_submit_mlp(vec![2]).expect_err("queue is full");
        assert!(matches!(rejected.error, Error::Overloaded(_)), "{}", rejected.error);
        assert_eq!(rejected.payload, vec![2], "payload recovered intact");
        assert!(
            started.elapsed() < Duration::from_secs(1),
            "admission must never block the submitter"
        );
        // Counters: the shed never entered `requests`, depth stays truthful.
        assert_eq!(h.stats().shed.load(Ordering::Relaxed), 1);
        assert_eq!(h.stats().shed_best_effort.load(Ordering::Relaxed), 0);
        assert_eq!(h.stats().requests.load(Ordering::Relaxed), 1);
        assert_eq!(h.stats().queue_depth(), 1);
        // GEMM and CNN paths shed the same way, payloads intact.
        let g = h.try_submit_gemm("g", vec![3], vec![4]).expect_err("full");
        assert!(matches!(g.error, Error::Overloaded(_)));
        assert_eq!(g.payload, (vec![3], vec![4]));
        assert_eq!(h.stats().shed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn best_effort_watermark_sheds_before_queue_full() {
        let (h, _rx) = loose_handle(8, Some(1));
        // One outstanding high-priority request reaches the watermark.
        h.try_submit_mlp(vec![1]).expect("accepted");
        // Best-effort sheds at the watermark even though the queue has room…
        let r = h
            .try_submit_mlp_opts(vec![2], Qos::best_effort(), None)
            .expect_err("watermark trips");
        assert!(matches!(r.error, Error::Overloaded(_)), "{}", r.error);
        assert_eq!(r.payload, vec![2]);
        assert_eq!(h.stats().shed.load(Ordering::Relaxed), 1);
        assert_eq!(h.stats().shed_best_effort.load(Ordering::Relaxed), 1);
        // …while high-priority traffic keeps boarding.
        h.try_submit_mlp(vec![3]).expect("high priority unaffected by watermark");
        assert_eq!(h.stats().requests.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn stopped_coordinator_still_rejects_shard_down() {
        let (h, rx) = loose_handle(4, None);
        drop(rx);
        let r = h.try_submit_mlp(vec![9]).expect_err("disconnected");
        assert!(matches!(r.error, Error::ShardDown(_)), "{}", r.error);
        assert_eq!(r.payload, vec![9]);
        // A disconnect is not a shed.
        assert_eq!(h.stats().shed.load(Ordering::Relaxed), 0);
        assert_eq!(h.stats().requests.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn retained_nonce_replays_instead_of_redrawing() {
        let (mut h, _rx) = loose_handle(8, None);
        h.nonce_counter = Some(Arc::new(AtomicU64::new(0)));
        let (_slot, first) = h.try_submit_mlp_opts(vec![1], Qos::default(), None).unwrap();
        assert_eq!(first, 1, "counter mode hands out 1-based nonces");
        // A failover replay supplies the retained nonce: no fresh draw.
        let (_slot, replayed) =
            h.try_submit_mlp_opts(vec![1], Qos::default(), Some(first)).unwrap();
        assert_eq!(replayed, first);
        let (_slot, next) = h.try_submit_mlp_opts(vec![2], Qos::default(), None).unwrap();
        assert_eq!(next, 2, "the counter advanced exactly once per logical request");
    }

    #[test]
    fn extract_cnn_group_preserves_arrival_order() {
        // Mixed-model queue: a0 b1 a2 b3 a4 (inputs tag arrival order).
        let mut pending =
            vec![cnn_job("a", 0), cnn_job("b", 1), cnn_job("a", 2), cnn_job("b", 3), cnn_job("a", 4)];
        let model = pending[0].model.clone();
        let group = extract_cnn_group(&mut pending, &model, 2);
        let tags = |jobs: &[CnnJob]| jobs.iter().map(|j| j.input[0]).collect::<Vec<_>>();
        assert_eq!(tags(&group), vec![0, 2], "cap-bounded, arrival order");
        assert_eq!(tags(&pending), vec![1, 3, 4], "remainder keeps arrival order");
        // Second extraction drains the leftover member of `a`.
        let group = extract_cnn_group(&mut pending, &model, 2);
        assert_eq!(tags(&group), vec![4]);
        assert_eq!(tags(&pending), vec![1, 3]);
    }

    #[test]
    fn take_by_priority_boards_high_first() {
        let mk = |tag, qos| mlp_job(tag, qos).0;
        let mut pending = vec![
            mk(0, Qos::best_effort()),
            mk(1, Qos::default()),
            mk(2, Qos::best_effort()),
            mk(3, Qos::default()),
        ];
        let taken = take_by_priority(&mut pending, 2);
        let tags = |jobs: &[MlpJob]| jobs.iter().map(|j| j.row[0]).collect::<Vec<_>>();
        assert_eq!(tags(&taken), vec![1, 3], "both high jobs board first");
        assert_eq!(tags(&pending), vec![0, 2], "best-effort waits, order kept");
        // With room to spare, best-effort backfills in arrival order.
        let mut pending = vec![mk(0, Qos::best_effort()), mk(1, Qos::default()), mk(2, Qos::best_effort())];
        let taken = take_by_priority(&mut pending, 2);
        assert_eq!(tags(&taken), vec![0, 1], "high + earliest best-effort, arrival order");
        assert_eq!(tags(&pending), vec![2]);
    }

    #[test]
    fn reap_expired_fails_typed_before_dispatch() {
        let stats = CoordinatorStats::default();
        let (expired, expired_rx) = mlp_job(0, Qos::default().with_deadline(Duration::ZERO));
        let (alive, _alive_rx) = mlp_job(1, Qos::default().with_deadline(Duration::from_secs(60)));
        let mut pending = vec![expired, alive];
        let mut pending_cnn: Vec<CnnJob> = Vec::new();
        reap_expired(&mut pending, &mut pending_cnn, &stats);
        assert_eq!(pending.len(), 1, "only the expired job was reaped");
        assert_eq!(pending[0].row, vec![1]);
        let err = expired_rx.recv().unwrap().unwrap_err();
        assert!(matches!(err, Error::DeadlineExceeded(_)), "{err}");
        assert_eq!(stats.failed.load(Ordering::Relaxed), 1);
        assert_eq!(stats.deadline_expired.load(Ordering::Relaxed), 1);
    }
}
