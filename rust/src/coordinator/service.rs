//! The coordinator service: leader thread, routing, lifecycle.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, MicroBatch};
use crate::coordinator::request::{response_slot, GemmJob, Job, MlpJob, Response};
use crate::coordinator::stats::CoordinatorStats;
use crate::coordinator::worker::{run_worker, WorkItem};
use crate::runtime::Manifest;
use crate::{Error, Result};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Directory with `manifest.txt` + HLO artifacts.
    pub artifact_dir: String,
    /// Worker threads (each owns a PJRT engine).
    pub workers: usize,
    /// Dynamic-batching window, seconds.
    pub max_batch_wait_s: f64,
    /// Ingress queue depth (backpressure bound).
    pub queue_depth: usize,
    /// Compile all artifacts at worker start (first-request latency vs
    /// startup time trade).
    pub warmup: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: "artifacts".into(),
            workers: 2,
            max_batch_wait_s: 0.002,
            queue_depth: 1024,
            warmup: true,
        }
    }
}

/// Cloneable client handle for submitting requests.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: SyncSender<Job>,
    stats: Arc<CoordinatorStats>,
    mlp_row_len: usize,
}

impl CoordinatorHandle {
    /// Submit a GEMM against a named artifact; returns the response slot.
    pub fn submit_gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Response> {
        let (reply, rx) = response_slot();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Job::Gemm(GemmJob {
                artifact: artifact.to_string(),
                a,
                b,
                reply,
                enqueued: Instant::now(),
            }))
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        Ok(rx)
    }

    /// Submit one MLP row; returns the response slot.
    pub fn submit_mlp(&self, row: Vec<i32>) -> Result<Response> {
        if row.len() != self.mlp_row_len {
            return Err(Error::Shape(format!(
                "mlp row has {} elements, expected {}",
                row.len(),
                self.mlp_row_len
            )));
        }
        let (reply, rx) = response_slot();
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Job::Mlp(MlpJob { row, reply, enqueued: Instant::now() }))
            .map_err(|_| Error::Coordinator("coordinator stopped".into()))?;
        Ok(rx)
    }

    /// Blocking MLP inference convenience.
    pub fn infer_mlp(&self, row: Vec<i32>) -> Result<Vec<i32>> {
        self.submit_mlp(row)?
            .recv()
            .map_err(|_| Error::Coordinator("response dropped".into()))?
    }

    /// Blocking GEMM convenience.
    pub fn gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Vec<i32>> {
        self.submit_gemm(artifact, a, b)?
            .recv()
            .map_err(|_| Error::Coordinator("response dropped".into()))?
    }

    /// Shared metrics.
    pub fn stats(&self) -> &CoordinatorStats {
        &self.stats
    }
}

/// The running coordinator (leader + workers). Dropping it shuts down.
pub struct Coordinator {
    handle: CoordinatorHandle,
    leader: Option<JoinHandle<()>>,
    tx: SyncSender<Job>,
}

impl Coordinator {
    /// Start the service: validates the manifest, spawns workers + leader.
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        // Validate the manifest up front (fail fast with a good error).
        let manifest = Manifest::load(&cfg.artifact_dir)?;
        let variants = manifest.mlp_batch_variants();
        if variants.is_empty() {
            return Err(Error::Config("no mlp_b* artifacts in manifest".into()));
        }
        let mlp_row_len = manifest.get(&variants[0].0)?.inputs[0].elements() / variants[0].1;
        let policy = BatchPolicy::new(variants, cfg.max_batch_wait_s);

        let stats = Arc::new(CoordinatorStats::default());
        let (tx, rx) = sync_channel::<Job>(cfg.queue_depth);

        // Workers.
        let mut worker_txs = Vec::with_capacity(cfg.workers.max(1));
        let mut joins = Vec::new();
        let (ready_tx, ready_rx) = sync_channel::<()>(cfg.workers.max(1));
        for id in 0..cfg.workers.max(1) {
            let (wtx, wrx) = sync_channel::<WorkItem>(cfg.queue_depth);
            let dir = cfg.artifact_dir.clone();
            let st = stats.clone();
            let warm = cfg.warmup;
            let rtx = ready_tx.clone();
            joins.push(std::thread::Builder::new()
                .name(format!("spoga-worker-{id}"))
                .spawn(move || run_worker(id, dir, warm, rtx, wrx, st))
                .map_err(|e| Error::Coordinator(format!("spawn worker: {e}")))?);
            worker_txs.push(wtx);
        }
        drop(ready_tx);
        // Block until every worker finished (possibly warm) engine init.
        for _ in 0..cfg.workers.max(1) {
            let _ = ready_rx.recv();
        }

        // Leader.
        let leader = {
            std::thread::Builder::new()
                .name("spoga-leader".into())
                .spawn(move || run_leader(rx, worker_txs, policy, joins))
                .map_err(|e| Error::Coordinator(format!("spawn leader: {e}")))?
        };

        let handle = CoordinatorHandle { tx: tx.clone(), stats, mlp_row_len };
        Ok(Coordinator { handle, leader: Some(leader), tx })
    }

    /// A cloneable submission handle.
    pub fn handle(&self) -> CoordinatorHandle {
        self.handle.clone()
    }

    /// Graceful shutdown: drain queues, stop workers, join threads.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.leader.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.leader.take() {
            let _ = j.join();
        }
    }
}

/// Leader loop: route GEMMs round-robin; gather MLP rows into micro-batches
/// bounded by the batching window and the largest variant.
fn run_leader(
    rx: Receiver<Job>,
    worker_txs: Vec<SyncSender<WorkItem>>,
    policy: BatchPolicy,
    worker_joins: Vec<JoinHandle<()>>,
) {
    let mut next_worker = 0usize;
    let dispatch = |item: WorkItem, next: &mut usize| {
        let n = worker_txs.len();
        let _ = worker_txs[*next % n].send(item);
        *next = (*next + 1) % n;
    };

    let window = Duration::from_secs_f64(policy.max_wait_s);
    let mut pending: Vec<MlpJob> = Vec::new();
    let mut shutdown = false;

    while !shutdown {
        // Phase 1: block for the first job.
        match rx.recv() {
            Err(_) => break,
            Ok(Job::Shutdown) => break,
            Ok(Job::Gemm(g)) => {
                dispatch(WorkItem::Gemm(g), &mut next_worker);
                continue;
            }
            Ok(Job::Mlp(m)) => pending.push(m),
        }

        // Phase 2: batching window — gather more rows until it expires or
        // the largest variant fills.
        let deadline = Instant::now() + window;
        while pending.len() < policy.max_batch() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Job::Mlp(m)) => pending.push(m),
                Ok(Job::Gemm(g)) => dispatch(WorkItem::Gemm(g), &mut next_worker),
                Ok(Job::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }

        // Phase 3: form + dispatch micro-batches (possibly several if a
        // burst exceeded the largest variant).
        while !pending.is_empty() {
            let take = pending.len().min(policy.max_batch());
            let (artifact, batch) = policy.pick_variant(take).clone();
            let jobs: Vec<MlpJob> = pending.drain(..take.min(batch)).collect();
            dispatch(WorkItem::Batch(MicroBatch { artifact, batch, jobs }), &mut next_worker);
        }
    }

    // Drain-and-stop: fail anything still queued, stop workers, join.
    for j in pending {
        let _ = j.reply.send(Err(Error::Coordinator("shutdown".into())));
    }
    for tx in &worker_txs {
        let _ = tx.send(WorkItem::Shutdown);
    }
    drop(worker_txs);
    for j in worker_joins {
        let _ = j.join();
    }
}
