//! L3 request coordinator: router + dynamic batcher + worker pool.
//!
//! The serving-side contribution layer: GEMM / inference requests enter
//! through a [`CoordinatorHandle`], a leader thread routes them and packs
//! same-model requests into the largest AOT batch variant available within
//! a bounded batching window (dynamic batching, vLLM-router style), and a
//! pool of worker threads — each owning its *own* [`Engine`](crate::runtime::Engine)
//! (per-thread engines, as a thread-affine PJRT backend would force; the
//! software backend routes every GEMM through the packed bit-sliced fast
//! path) — executes them. Backpressure comes from bounded queues end to
//! end.
//!
//! No tokio in the vendored dependency set: the pool is `std::thread` +
//! `std::sync::mpsc`, which for a CPU-bound backend is also the honest
//! design — there is no I/O to overlap.

pub mod batcher;
pub mod request;
pub mod service;
pub mod stats;
pub mod worker;

pub use batcher::{BatchPolicy, MicroBatch};
pub use request::{GemmJob, Job, MlpJob, Response};
pub use service::{Coordinator, CoordinatorConfig, CoordinatorHandle};
pub use stats::CoordinatorStats;
