//! L3 request coordinator: fleet router + dynamic batcher + worker pools.
//!
//! The serving-side contribution layer, now two tiers deep:
//!
//! * **Shard tier** — a [`Coordinator`] is one serving shard: GEMM / MLP /
//!   whole-CNN requests enter through a [`CoordinatorHandle`], a leader
//!   thread routes them (round-robin with dead-worker failover), packs
//!   same-model MLP rows into the largest AOT batch variant available
//!   within a bounded batching window (dynamic batching, vLLM-router
//!   style), stacks same-model CNN frames along the t-dimension
//!   ([`batcher::CnnMicroBatch`] →
//!   [`run_cnn_batch`](crate::runtime::cnnrun::run_cnn_batch)) so conv
//!   im2col GEMMs amortize across requests, and a pool of worker threads —
//!   each owning its *own* [`Engine`](crate::runtime::Engine) over the
//!   configured [`BackendKind`](crate::runtime::BackendKind) — executes
//!   them. Backpressure comes from bounded queues end to end — and at the
//!   ingress edge it is *typed shedding*, not blocking: a full ingress
//!   queue (or a tripped [`CoordinatorConfig::best_effort_watermark`])
//!   refuses the submission with [`crate::Error::Overloaded`] and hands
//!   the payload back, so no submitting thread ever parks on a saturated
//!   shard. Each request carries a [`Qos`] envelope ([`Priority`] class +
//!   optional deadline); the leader drains high-priority jobs first within
//!   a gathering window, flushes a window early when its oldest member
//!   would miss its deadline, and fails already-expired jobs typed
//!   ([`crate::Error::DeadlineExceeded`]) *before* burning a worker
//!   execute.
//! * **Fleet tier** ([`router`]) — a [`Fleet`] fronts N coordinators
//!   (possibly heterogeneous backends / photonic design points) behind one
//!   cloneable [`FleetHandle`] with pluggable [`RoutePolicy`]s
//!   (round-robin, least-queue-depth, weighted A/B split) and automatic
//!   failover when a shard's workers die. The historical single-coordinator
//!   path is the 1-shard fleet ([`Fleet::single`]), so there is one serving
//!   path. Slots may also front coordinators in *other processes* over TCP
//!   ([`RemoteShardConfig`] → [`crate::net::RemoteShard`]); see
//!   [`router`]'s local-vs-remote equivalence contract.
//!
//! ## Resilience: what happens to an in-flight request
//!
//! Requests never vanish; each ends in exactly one of three states (the
//! retry/revival state machine, detailed in [`router`]'s module docs):
//!
//! * **request-level failed** — shape/artifact/execute errors and dropped
//!   reply slots (worker crash mid-request) resolve the slot with an error
//!   and are never retried (a poisonous payload must not cascade across
//!   shards);
//! * **resubmitted** — a shard that accepted a request and then died fails
//!   the slot with [`crate::Error::ShardDown`]; a [`RetryingSlot`] (what
//!   [`FleetHandle::submit_gemm_retrying`] returns and every blocking
//!   helper uses) owns a retained copy of the payload and resubmits on a
//!   survivor, resolving bit-identically to an undisturbed run. Submit-time
//!   refusals fail over *without cloning*: the payload-recovering
//!   [`CoordinatorHandle::try_submit_gemm`]-family takes it back from the
//!   channel's `SendError`;
//! * **shard-retired** — the observing handle marks the shard dead; it
//!   stays out of the rotation until a revival probe
//!   ([`FleetHandle::revive_shard`]: leader respawns the pool, then a
//!   [`CoordinatorHandle::ping`] must pong) brings it back. Under
//!   queue-depth pressure an autoscaling fleet ([`FleetAutoscale`]) spawns
//!   fresh shards instead of just waiting, and every lifecycle transition
//!   counts into [`FleetLifecycle`] / [`crate::metrics::FleetTelemetry`].
//!
//! Backends are per-shard: [`CoordinatorConfig::backend`] selects the
//! software interpreter (default) or the photonic-in-the-loop simulator;
//! with the latter, every [`Reply`] carries an
//! [`ExecReport`](crate::runtime::ExecReport) (projected latency/energy on
//! the simulated accelerator), [`CoordinatorStats`] aggregates live
//! sim-FPS / FPS-per-watt per shard, and
//! [`FleetTelemetry`](crate::metrics::FleetTelemetry) rolls the shards up
//! fleet-wide — run a software|SPOGA|HOLYLIGHT fleet over the same
//! artifacts to A/B design points on identical live traffic, a
//! [`FleetConfig::noise_sweep`] to trade served accuracy against sim-FPS/W
//! across link margins, or a [`FleetConfig::noise_grid`] over a
//! [`NoiseSweepGrid`] (K × ADC bits) for the full accuracy-vs-efficiency
//! frontier — all with batching *on*, since noise attributes per output
//! row (see [`crate::runtime::backend`]'s per-row contract).
//!
//! No tokio in the vendored dependency set: the pool is `std::thread` +
//! `std::sync::mpsc`, which for a CPU-bound backend is also the honest
//! design — there is no I/O to overlap.

pub mod batcher;
pub mod request;
pub mod router;
pub mod service;
pub mod stats;
pub mod worker;

pub use batcher::{BatchPolicy, CnnMicroBatch, MicroBatch};
pub use request::{CnnJob, GemmJob, Job, MlpJob, PingJob, Priority, Qos, Reply, Response};
pub use router::{
    Fleet, FleetAutoscale, FleetConfig, FleetHandle, FleetLifecycle, NoiseSweepGrid,
    RemoteShardConfig, RetryPayload, RetryingSlot, RoutePolicy,
};
pub use service::{Coordinator, CoordinatorConfig, CoordinatorHandle, Rejected};
pub use stats::CoordinatorStats;
