//! L3 request coordinator: router + dynamic batcher + worker pool.
//!
//! The serving-side contribution layer: GEMM / MLP / whole-CNN requests
//! enter through a [`CoordinatorHandle`], a leader thread routes them
//! (round-robin with dead-worker failover) and packs same-model MLP
//! requests into the largest AOT batch variant available within a bounded
//! batching window (dynamic batching, vLLM-router style), and a pool of
//! worker threads — each owning its *own* [`Engine`](crate::runtime::Engine)
//! over the configured [`BackendKind`](crate::runtime::BackendKind) —
//! executes them. Backpressure comes from bounded queues end to end.
//!
//! Backends are per-coordinator: [`CoordinatorConfig::backend`] selects the
//! software interpreter (default) or the photonic-in-the-loop simulator;
//! with the latter, every [`Reply`] carries an
//! [`ExecReport`](crate::runtime::ExecReport) (projected latency/energy on
//! the simulated accelerator) and [`CoordinatorStats`] aggregates live
//! sim-FPS / FPS-per-watt for the traffic actually served — run two
//! coordinators over the same artifacts to A/B SPOGA vs HOLYLIGHT on
//! identical load.
//!
//! No tokio in the vendored dependency set: the pool is `std::thread` +
//! `std::sync::mpsc`, which for a CPU-bound backend is also the honest
//! design — there is no I/O to overlap.

pub mod batcher;
pub mod request;
pub mod service;
pub mod stats;
pub mod worker;

pub use batcher::{BatchPolicy, MicroBatch};
pub use request::{CnnJob, GemmJob, Job, MlpJob, Reply, Response};
pub use service::{Coordinator, CoordinatorConfig, CoordinatorHandle};
pub use stats::CoordinatorStats;
