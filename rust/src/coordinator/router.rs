//! Fleet layer: a shard router over N coordinators.
//!
//! One process, many [`Coordinator`]s — each shard owns its own worker
//! pool, batcher and [`BackendKind`](crate::runtime::BackendKind), so a
//! fleet can mix photonic design points (SPOGA vs HOLYLIGHT vs DEAPCNN vs
//! the software interpreter) behind a single cloneable [`FleetHandle`] and
//! A/B them under identical live traffic — the fleet-level apparatus behind
//! the paper's headline numbers (many tiles serving inference concurrently,
//! not one engine).
//!
//! ## Routing
//!
//! [`RoutePolicy`] picks the shard per request:
//!
//! * [`RoutePolicy::RoundRobin`] — uniform rotation over live shards.
//! * [`RoutePolicy::LeastQueueDepth`] — the live shard with the fewest
//!   unresolved requests ([`CoordinatorStats::queue_depth`]).
//! * [`RoutePolicy::Weighted`] — deterministic proportional split (e.g.
//!   `software:photonic = 1:3` for a photonic-design experiment); over any
//!   `sum(weights)` consecutive picks the split is exact.
//!
//! ## Failover
//!
//! A shard whose worker pool died answers every job with a "no live
//! workers" error (and a stopped shard rejects submission). The handle
//! recognizes those as *shard-down* signals, marks the shard dead, and
//! retries the request on the next live shard — requests only fail once no
//! shards remain. Reply slots always resolve either way: the shard's
//! leader fails its queued jobs explicitly, never silently.
//!
//! ## Telemetry
//!
//! [`FleetHandle::telemetry`] snapshots every shard's
//! [`CoordinatorStats`] into a [`FleetTelemetry`] rollup — fleet-wide
//! sim-FPS / FPS-per-watt / noise events, each request counted exactly once
//! on the shard that served it.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::coordinator::request::{Reply, Response};
use crate::coordinator::service::{Coordinator, CoordinatorConfig, CoordinatorHandle};
use crate::coordinator::stats::CoordinatorStats;
use crate::dnn::models::CnnModel;
use crate::fidelity::NoiseParams;
use crate::metrics::{FleetTelemetry, ShardTelemetry};
use crate::runtime::backend::BackendKind;
use crate::runtime::photonic::PhotonicConfig;
use crate::{Error, Result};

/// How the fleet picks the shard that serves the next request.
#[derive(Debug, Clone, Default)]
pub enum RoutePolicy {
    /// Uniform rotation over live shards.
    #[default]
    RoundRobin,
    /// The live shard with the fewest unresolved requests.
    LeastQueueDepth,
    /// Deterministic proportional split: shard `i` receives
    /// `weights[i] / sum(weights)` of the traffic (dead shards drop out and
    /// the remainder re-normalizes). One weight per shard.
    Weighted(Vec<u32>),
}

/// Fleet configuration: one [`CoordinatorConfig`] per shard plus the
/// routing policy.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Per-shard coordinator configurations (possibly heterogeneous
    /// backends — that is the point).
    pub shards: Vec<CoordinatorConfig>,
    /// Shard selection policy.
    pub policy: RoutePolicy,
    /// Optional display labels, one per shard; missing entries fall back to
    /// `shard<i>:<backend label>`.
    pub labels: Vec<String>,
}

impl FleetConfig {
    /// A single-shard fleet — the compatibility spelling of the historical
    /// one-coordinator serving path.
    pub fn single(shard: CoordinatorConfig) -> Self {
        FleetConfig { shards: vec![shard], policy: RoutePolicy::RoundRobin, labels: Vec::new() }
    }

    /// `n` identical shards behind round-robin (horizontal scaling).
    pub fn replicated(shard: CoordinatorConfig, n: usize) -> Self {
        FleetConfig {
            shards: vec![shard; n.max(1)],
            policy: RoutePolicy::RoundRobin,
            labels: Vec::new(),
        }
    }

    /// Weighted two-shard A/B split — the photonic-design-experiment
    /// shape: identical artifacts, different backends, traffic split
    /// `wa:wb`.
    pub fn ab_split(a: CoordinatorConfig, b: CoordinatorConfig, wa: u32, wb: u32) -> Self {
        FleetConfig {
            shards: vec![a, b],
            policy: RoutePolicy::Weighted(vec![wa, wb]),
            labels: Vec::new(),
        }
    }

    /// Noise-aware serving sweep: one photonic shard per link margin, each
    /// injecting analog noise at that margin with its own deterministic
    /// stream. `base`'s backend supplies the design point (non-photonic
    /// bases sweep SPOGA_10). Drive identical traffic at every shard via
    /// [`FleetHandle::shard`] and read served-accuracy vs sim-FPS/W off
    /// [`FleetHandle::telemetry`] — the serving-path slice of the offline
    /// fidelity study.
    pub fn noise_sweep(base: CoordinatorConfig, margins_db: &[f64]) -> Self {
        let pc = match &base.backend {
            BackendKind::Photonic(p) => p.clone(),
            _ => PhotonicConfig::spoga(),
        };
        let mut shards = Vec::with_capacity(margins_db.len());
        let mut labels = Vec::with_capacity(margins_db.len());
        for (i, &margin) in margins_db.iter().enumerate() {
            let mut cfg = base.clone();
            cfg.backend = BackendKind::Photonic(pc.clone().with_noise(
                NoiseParams::from_link_margin(margin),
                0x5EED_F1EE + ((i as u64) << 16),
            ));
            shards.push(cfg);
            labels.push(format!("margin+{margin:.0}dB"));
        }
        FleetConfig { shards, policy: RoutePolicy::RoundRobin, labels }
    }
}

struct ShardSlot {
    label: String,
    handle: CoordinatorHandle,
    dead: AtomicBool,
}

struct FleetInner {
    slots: Vec<ShardSlot>,
    policy: RoutePolicy,
    /// Routing cursor: round-robin rotation / weighted tick counter.
    cursor: AtomicUsize,
}

/// Cloneable client handle over the whole fleet: routes each request to a
/// shard per the policy, fails over when shards die, and rolls per-shard
/// stats up into fleet telemetry.
#[derive(Clone)]
pub struct FleetHandle {
    inner: Arc<FleetInner>,
}

/// Does this error mean the shard (not the request) is broken? Only the
/// typed [`Error::ShardDown`] variant counts — worker-pool death, a stopped
/// coordinator and shutdown drains construct it. Request-level errors
/// (shape, artifact, execute failures — and a dropped reply slot, which
/// means a worker crashed *on this request* and must not send a possibly
/// poisonous payload marching across every shard) carry other variants and
/// never burn a failover.
fn is_shard_down(e: &Error) -> bool {
    matches!(e, Error::ShardDown(_))
}

impl FleetHandle {
    /// Shards still worth routing to: not marked dead AND with a live
    /// worker pool. The second check matters for slot-based traffic — a
    /// shard whose leader fast-fails every job keeps a near-zero queue
    /// depth and would otherwise *attract* least-queue-depth routing
    /// without ever tripping the dead flag.
    fn live(&self) -> Vec<usize> {
        self.inner
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                !s.dead.load(Ordering::Relaxed)
                    && s.handle.stats().live_workers.load(Ordering::Relaxed) > 0
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// Pick one of the `live` shard indices (non-empty) per the policy.
    fn pick(&self, live: &[usize]) -> usize {
        match &self.inner.policy {
            RoutePolicy::RoundRobin => {
                live[self.inner.cursor.fetch_add(1, Ordering::Relaxed) % live.len()]
            }
            RoutePolicy::LeastQueueDepth => {
                // Snapshot depths once (they move under us), then rotate
                // among the minima so an all-idle fleet still balances
                // instead of pinning shard 0.
                let depths: Vec<(usize, u64)> = live
                    .iter()
                    .map(|&i| (i, self.inner.slots[i].handle.stats().queue_depth()))
                    .collect();
                let min = depths.iter().map(|&(_, d)| d).min().expect("non-empty live set");
                let ties: Vec<usize> =
                    depths.iter().filter(|&&(_, d)| d == min).map(|&(i, _)| i).collect();
                ties[self.inner.cursor.fetch_add(1, Ordering::Relaxed) % ties.len()]
            }
            RoutePolicy::Weighted(weights) => {
                let total: u64 =
                    live.iter().map(|&i| u64::from(*weights.get(i).unwrap_or(&0))).sum();
                if total == 0 {
                    // All live weights zero: degrade to round-robin rather
                    // than starve the fleet.
                    return live[self.inner.cursor.fetch_add(1, Ordering::Relaxed) % live.len()];
                }
                let mut tick =
                    (self.inner.cursor.fetch_add(1, Ordering::Relaxed) as u64) % total;
                for &i in live {
                    let w = u64::from(*weights.get(i).unwrap_or(&0));
                    if tick < w {
                        return i;
                    }
                    tick -= w;
                }
                live[live.len() - 1]
            }
        }
    }

    /// Run `op` against policy-picked shards, failing over (and marking the
    /// shard dead) on shard-down errors until a live shard answers or none
    /// remain. Request-level errors (bad shape, unknown artifact, execute
    /// failure) return immediately.
    ///
    /// The payload moves into the attempt once no other shard could take a
    /// retry and is cloned otherwise — a clone per attempt is the price of
    /// reply-time failover, because a payload consumed by a shard that then
    /// dies is unrecoverable (its leader fails the reply slot; nothing
    /// hands the buffers back).
    fn with_failover<T, P: Clone>(
        &self,
        payload: P,
        mut op: impl FnMut(&CoordinatorHandle, P) -> Result<T>,
    ) -> Result<T> {
        let mut payload = Some(payload);
        let mut last_err: Option<Error> = None;
        for _ in 0..self.inner.slots.len() {
            let live = self.live();
            if live.is_empty() {
                break;
            }
            let idx = self.pick(&live);
            let p = (if live.len() == 1 { payload.take() } else { payload.clone() })
                .expect("payload present while attempts remain");
            match op(&self.inner.slots[idx].handle, p) {
                Ok(v) => return Ok(v),
                Err(e) if is_shard_down(&e) => {
                    self.inner.slots[idx].dead.store(true, Ordering::Relaxed);
                    last_err = Some(e);
                    if payload.is_none() {
                        break;
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Err(last_err.unwrap_or_else(|| Error::ShardDown("fleet has no live shards".into())))
    }

    /// Submit a GEMM to a policy-picked shard; returns the response slot.
    /// Failover covers submission; a shard dying *after* accepting resolves
    /// the slot with an error instead (use [`FleetHandle::gemm_reply`] for
    /// full retry semantics).
    pub fn submit_gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Response> {
        self.with_failover((a, b), |h, (a, b)| h.submit_gemm(artifact, a, b))
    }

    /// Submit one MLP row to a policy-picked shard; returns the response
    /// slot.
    pub fn submit_mlp(&self, row: Vec<i32>) -> Result<Response> {
        self.with_failover(row, |h, row| h.submit_mlp(row))
    }

    /// Submit a whole-CNN inference to a policy-picked shard; returns the
    /// response slot. Same-model frames co-pending on that shard stack into
    /// one t-dimension batch.
    pub fn submit_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Response> {
        self.with_failover((model, input), |h, (model, input)| h.submit_cnn(model, input))
    }

    /// Blocking GEMM returning the full [`Reply`]; retries on another shard
    /// if the serving shard turns out to be dead.
    pub fn gemm_reply(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Reply> {
        self.with_failover((a, b), |h, (a, b)| h.gemm_reply(artifact, a, b))
    }

    /// Blocking GEMM convenience.
    pub fn gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Vec<i32>> {
        Ok(self.gemm_reply(artifact, a, b)?.outputs)
    }

    /// Blocking MLP inference with shard failover.
    pub fn infer_mlp(&self, row: Vec<i32>) -> Result<Vec<i32>> {
        self.with_failover(row, |h, row| h.infer_mlp(row))
    }

    /// Blocking CNN inference (full [`Reply`]) with shard failover.
    pub fn infer_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Reply> {
        self.with_failover((model, input), |h, (model, input)| h.infer_cnn(model, input))
    }

    /// Number of shards (live and dead).
    pub fn shard_count(&self) -> usize {
        self.inner.slots.len()
    }

    /// Number of shards still in the rotation.
    pub fn live_shard_count(&self) -> usize {
        self.live().len()
    }

    /// Per-shard display labels, shard order.
    pub fn shard_labels(&self) -> Vec<String> {
        self.inner.slots.iter().map(|s| s.label.clone()).collect()
    }

    /// Direct handle to shard `i` — for per-shard drains
    /// ([`CoordinatorHandle::retire_workers`]) and sweep harnesses that
    /// must drive identical traffic at every shard, bypassing routing.
    pub fn shard(&self, i: usize) -> &CoordinatorHandle {
        &self.inner.slots[i].handle
    }

    /// Shard `i`'s live stats.
    pub fn shard_stats(&self, i: usize) -> &CoordinatorStats {
        self.inner.slots[i].handle.stats()
    }

    /// Take shard `i` out of the rotation (ops drain; also flipped
    /// automatically when a request observes the shard down).
    pub fn mark_dead(&self, i: usize) {
        self.inner.slots[i].dead.store(true, Ordering::Relaxed);
    }

    /// Snapshot every shard's stats into the fleet rollup. Each shard's
    /// counters are read once per snapshot, so totals equal the sum of the
    /// per-shard stats with nothing double-counted.
    pub fn telemetry(&self) -> FleetTelemetry {
        FleetTelemetry::new(
            self.inner
                .slots
                .iter()
                .map(|s| ShardTelemetry::capture(&s.label, s.handle.stats()))
                .collect(),
        )
    }
}

/// The running fleet: N coordinators behind one [`FleetHandle`]. Dropping
/// it shuts every shard down.
pub struct Fleet {
    shards: Vec<Coordinator>,
    handle: FleetHandle,
}

impl Fleet {
    /// Start every shard (workers warm per [`CoordinatorConfig::warmup`])
    /// and wire the router. Fails fast if any shard fails to start —
    /// already-started shards shut down via drop.
    pub fn start(cfg: FleetConfig) -> Result<Self> {
        if cfg.shards.is_empty() {
            return Err(Error::Config("fleet needs at least one shard".into()));
        }
        if let RoutePolicy::Weighted(w) = &cfg.policy {
            if w.len() != cfg.shards.len() {
                return Err(Error::Config(format!(
                    "weighted policy has {} weights for {} shards",
                    w.len(),
                    cfg.shards.len()
                )));
            }
            if w.iter().all(|&x| x == 0) {
                return Err(Error::Config("weighted policy needs a nonzero weight".into()));
            }
        }
        let mut shards = Vec::with_capacity(cfg.shards.len());
        let mut slots = Vec::with_capacity(cfg.shards.len());
        for (i, shard_cfg) in cfg.shards.iter().enumerate() {
            let label = cfg
                .labels
                .get(i)
                .cloned()
                .unwrap_or_else(|| format!("shard{}:{}", i, shard_cfg.backend.label()));
            let c = Coordinator::start(shard_cfg.clone())?;
            slots.push(ShardSlot { label, handle: c.handle(), dead: AtomicBool::new(false) });
            shards.push(c);
        }
        let handle = FleetHandle {
            inner: Arc::new(FleetInner {
                slots,
                policy: cfg.policy,
                cursor: AtomicUsize::new(0),
            }),
        };
        Ok(Fleet { shards, handle })
    }

    /// Convenience: the historical single-coordinator serving path as a
    /// 1-shard fleet.
    pub fn single(shard: CoordinatorConfig) -> Result<Self> {
        Self::start(FleetConfig::single(shard))
    }

    /// A cloneable fleet handle.
    pub fn handle(&self) -> FleetHandle {
        self.handle.clone()
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Graceful shutdown: drain and join every shard.
    pub fn shutdown(self) {
        for c in self.shards {
            c.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(label: &str, handle: CoordinatorHandle) -> ShardSlot {
        ShardSlot { label: label.into(), handle, dead: AtomicBool::new(false) }
    }

    fn synthetic_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("spoga-router-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "mlp_b1 m i32:1x16 i32:1x4\n").unwrap();
        dir
    }

    fn two_shard_handle(tag: &str, policy: RoutePolicy) -> (FleetHandle, Vec<Coordinator>) {
        let dir = synthetic_dir(tag);
        let cfg = CoordinatorConfig {
            artifact_dir: dir.to_string_lossy().into_owned(),
            workers: 1,
            max_batch_wait_s: 0.0,
            ..Default::default()
        };
        let a = Coordinator::start(cfg.clone()).unwrap();
        let b = Coordinator::start(cfg).unwrap();
        let handle = FleetHandle {
            inner: Arc::new(FleetInner {
                slots: vec![slot("a", a.handle()), slot("b", b.handle())],
                policy,
                cursor: AtomicUsize::new(0),
            }),
        };
        (handle, vec![a, b])
    }

    #[test]
    fn weighted_policy_splits_exactly_over_a_period() {
        let (h, shards) = two_shard_handle("weighted", RoutePolicy::Weighted(vec![1, 3]));
        let live = h.live();
        let mut counts = [0usize; 2];
        for _ in 0..8 {
            counts[h.pick(&live)] += 1;
        }
        assert_eq!(counts, [2, 6], "1:3 split over two periods");
        for c in shards {
            c.shutdown();
        }
    }

    #[test]
    fn least_queue_depth_prefers_the_idle_shard() {
        let (h, shards) = two_shard_handle("lqd", RoutePolicy::LeastQueueDepth);
        // Fake a backlog on shard 0 (requests accepted, never resolved).
        h.shard_stats(0).requests.fetch_add(50, Ordering::Relaxed);
        let live = h.live();
        for _ in 0..4 {
            assert_eq!(h.pick(&live), 1);
        }
        for c in shards {
            c.shutdown();
        }
    }

    #[test]
    fn dead_shards_leave_the_rotation() {
        let (h, shards) = two_shard_handle("dead", RoutePolicy::RoundRobin);
        assert_eq!(h.live_shard_count(), 2);
        h.mark_dead(0);
        assert_eq!(h.live_shard_count(), 1);
        let live = h.live();
        for _ in 0..4 {
            assert_eq!(h.pick(&live), 1);
        }
        for c in shards {
            c.shutdown();
        }
    }

    #[test]
    fn shard_down_classifier_spares_request_errors() {
        assert!(is_shard_down(&Error::ShardDown("no live workers (all dead)".into())));
        assert!(is_shard_down(&Error::ShardDown("coordinator stopped".into())));
        assert!(is_shard_down(&Error::ShardDown("shutdown".into())));
        // Request-level errors never retire a shard — even when their
        // caller-controlled text mentions shutdown-ish words.
        assert!(!is_shard_down(&Error::Coordinator("worker 0 execute failed: boom".into())));
        assert!(!is_shard_down(&Error::Coordinator(
            "artifact error: unknown artifact \"gemm_shutdown_probe\"".into()
        )));
        assert!(!is_shard_down(&Error::Shape("mlp row has 3 elements".into())));
        assert!(!is_shard_down(&Error::Artifact("unknown artifact".into())));
    }

    #[test]
    fn fleet_config_validation() {
        assert!(Fleet::start(FleetConfig {
            shards: Vec::new(),
            policy: RoutePolicy::RoundRobin,
            labels: Vec::new(),
        })
        .is_err());
        let shard = CoordinatorConfig::default();
        assert!(Fleet::start(FleetConfig {
            shards: vec![shard.clone(), shard.clone()],
            policy: RoutePolicy::Weighted(vec![1]),
            labels: Vec::new(),
        })
        .is_err());
        assert!(Fleet::start(FleetConfig {
            shards: vec![shard.clone(), shard],
            policy: RoutePolicy::Weighted(vec![0, 0]),
            labels: Vec::new(),
        })
        .is_err());
    }

    #[test]
    fn noise_sweep_builds_one_photonic_shard_per_margin() {
        let cfg = FleetConfig::noise_sweep(CoordinatorConfig::default(), &[0.0, 20.0, 40.0]);
        assert_eq!(cfg.shards.len(), 3);
        assert_eq!(cfg.labels, vec!["margin+0dB", "margin+20dB", "margin+40dB"]);
        for (i, s) in cfg.shards.iter().enumerate() {
            match &s.backend {
                BackendKind::Photonic(p) => {
                    let noise = p.noise.expect("sweep shard injects noise");
                    let margin = [0.0, 20.0, 40.0][i];
                    assert!((noise.snr_db - (24.1 + margin)).abs() < 1e-9);
                }
                other => panic!("sweep shard {i} is not photonic: {other:?}"),
            }
        }
        // Distinct deterministic noise streams per shard.
        let seeds: Vec<u64> = cfg
            .shards
            .iter()
            .map(|s| match &s.backend {
                BackendKind::Photonic(p) => p.noise_seed,
                _ => unreachable!(),
            })
            .collect();
        assert_ne!(seeds[0], seeds[1]);
        assert_ne!(seeds[1], seeds[2]);
    }
}
