//! Fleet layer: a shard router over N coordinators.
//!
//! One process, many [`Coordinator`]s — each shard owns its own worker
//! pool, batcher and [`BackendKind`](crate::runtime::BackendKind), so a
//! fleet can mix photonic design points (SPOGA vs HOLYLIGHT vs DEAPCNN vs
//! the software interpreter) behind a single cloneable [`FleetHandle`] and
//! A/B them under identical live traffic — the fleet-level apparatus behind
//! the paper's headline numbers (many tiles serving inference concurrently,
//! not one engine).
//!
//! ## Routing
//!
//! [`RoutePolicy`] picks the shard per request:
//!
//! * [`RoutePolicy::RoundRobin`] — uniform rotation over live shards.
//! * [`RoutePolicy::LeastQueueDepth`] — the live shard with the fewest
//!   unresolved requests ([`CoordinatorStats::queue_depth`]).
//! * [`RoutePolicy::Weighted`] — deterministic proportional split (e.g.
//!   `software:photonic = 1:3` for a photonic-design experiment); over any
//!   `sum(weights)` consecutive picks the split is exact.
//!
//! ## Failover and the request state machine
//!
//! A shard whose worker pool died answers every job with a "no live
//! workers" error (and a stopped shard rejects submission). The handle
//! recognizes those as *shard-down* signals ([`Error::ShardDown`] — the
//! only failover trigger), marks the shard dead, and retries the request on
//! the next live shard. Per request, exactly one of three things happens:
//!
//! * **Request-level failure** — shape/artifact/execute errors, and a
//!   *dropped* reply slot (a worker crashed mid-request; retrying a
//!   possibly poisonous payload across shards would cascade-retire the
//!   fleet). These return immediately and never burn a failover.
//! * **Submit-time failover** — the picked shard refused the submission.
//!   The payload is recovered from the channel's `SendError`
//!   ([`CoordinatorHandle::try_submit_gemm`] and friends), so the retry
//!   moves it to the next shard *without ever cloning*.
//! * **Reply-time resubmission** — the shard accepted, then died before
//!   resolving (its leader fails the queued slot with
//!   [`Error::ShardDown`]). Only a retained payload can survive this:
//!   [`RetryingSlot`] (from [`FleetHandle::submit_gemm_retrying`] etc.)
//!   owns a copy, marks the serving shard dead, resubmits on a survivor
//!   and resolves with outputs bit-identical to an undisturbed run (the
//!   backends are deterministic; content-keyed noise is shard-independent
//!   at equal seeds). The blocking helpers are retrying slots under the
//!   hood, so slot-based clients now get exactly the blocking helpers'
//!   semantics. Requests are idempotent by construction (stateless
//!   deterministic execution), and each carries a fleet-unique
//!   [`RetryingSlot::request_id`] naming the logical request across
//!   attempts.
//!
//! ## Overload is busy, not dead
//!
//! [`Error::Overloaded`] — a shard's bounded ingress queue is full, or its
//! best-effort watermark tripped — is explicitly *not* a failover signal:
//! the shard is alive and draining, and retiring it would amplify a load
//! spike into a capacity collapse. Submit-time overload routes around the
//! busy shard (bounded by the live-set size) *without* marking it dead and
//! without counting [`FleetLifecycle::submit_reroutes`]; when every live
//! shard is shedding, the typed error surfaces to the caller, so a
//! saturated fleet degrades with typed refusals instead of a retired-shard
//! cascade. A *reply-time* `Overloaded` (a remote peer accepted the frame,
//! then its own admission shed the request) grants a [`RetryingSlot`] at
//! most one bounded resubmission on a survivor; a second shed is terminal.
//! [`Error::DeadlineExceeded`] is likewise request-level — the deadline was
//! the caller's budget expiring, not the shard failing — and never retries.
//!
//! ## Revival and autoscaling
//!
//! A retired shard's *leader* survives ([`CoordinatorHandle::retire_workers`]
//! keeps it draining), so the fleet can heal instead of shrinking forever:
//! [`FleetHandle::revive_shard`] asks the leader to respawn its worker pool
//! ([`CoordinatorHandle::revive_workers`]), health-probes it end to end
//! ([`CoordinatorHandle::ping`]), and clears the dead flag only on a
//! successful pong — the shard then re-enters the routing rotation. Under
//! sustained queue-depth pressure (or with every shard down),
//! [`FleetHandle::maybe_scale_up`] spawns a fresh shard from the template
//! config, up to [`FleetAutoscale::max_shards`]. With
//! [`FleetConfig::autoscale`] set, a janitor thread runs both on a cadence;
//! every transition counts into [`FleetLifecycle`] and surfaces through
//! [`FleetHandle::telemetry`].
//!
//! ## Telemetry
//!
//! [`FleetHandle::telemetry`] snapshots every shard's
//! [`CoordinatorStats`] into a [`FleetTelemetry`] rollup — fleet-wide
//! sim-FPS / FPS-per-watt / noise events — plus the fleet lifecycle
//! counters (resubmissions, revivals, spawns, failed probes). Counting is
//! per *submission attempt* on the shard that took it: a mid-flight
//! resubmission therefore appears as one `failed` on the dead shard and
//! one fresh `requests`/`completed` pair on the survivor, with
//! `FleetTelemetry::resubmits` recording exactly how many logical requests
//! are double-counted that way (requests − resubmits = logical requests).
//!
//! ## Remote shards and the local-vs-remote equivalence contract
//!
//! A slot may front a coordinator in *another process* through a
//! [`RemoteShard`](crate::net::RemoteShard) client (see [`crate::net`]).
//! The contract: a remote slot is indistinguishable from a local one at the
//! router layer. Concretely —
//!
//! * **Same submit surface.** `try_submit_gemm/mlp/cnn` return the same
//!   payload-recovering `Result<Response, Rejected<P>>`, and the reply
//!   arrives through the same [`Response`] slot (the remote client's reader
//!   thread fulfils it), so [`RetryingSlot`] resubmission, the blocking
//!   helpers, and every [`RoutePolicy`] work unchanged over the wire.
//! * **Same health surface.** `ping` probes end to end (socket → server →
//!   worker pool pong), `stats` feeds queue-depth routing from a
//!   client-side mirror, and [`FleetHandle::revive_shard`] heals a dead
//!   remote slot by *reconnecting* (bounded backoff) instead of respawning
//!   a worker pool — the janitor needs no special case.
//! * **Error mapping.** [`Error::Remote`] carries a typed
//!   [`RemoteErrorKind`](crate::error::RemoteErrorKind); only kinds with
//!   `retires_shard()` — `ConnRefused`, `PeerGone` — act as failover
//!   signals alongside [`Error::ShardDown`]. A corrupt frame, a version
//!   skew, or one slow reply (`FrameCorrupt` / `VersionMismatch` /
//!   `Timeout`) stays request-level: the peer process is demonstrably
//!   alive, so one bad exchange never retires a healthy shard (the same
//!   poison-payload discipline that keeps dropped reply slots non-retried).
//!   A server-side `ShardDown` crossing the wire stays `ShardDown`, which
//!   is exactly right: the remote fleet exhausted its own failover, so the
//!   client fleet should fail over elsewhere.
//! * **Graceful degradation.** When every remote shard is down, routing
//!   drains to surviving local shards (they are just slots in the same
//!   table); [`FleetLifecycle::submit_reroutes`] and
//!   [`FleetLifecycle::resubmits`] count the traffic that moved.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::request::{Qos, Reply, Response};
use crate::coordinator::service::{Coordinator, CoordinatorConfig, CoordinatorHandle, Rejected};
use crate::coordinator::stats::CoordinatorStats;
use crate::dnn::models::CnnModel;
use crate::fidelity::NoiseParams;
use crate::metrics::{FleetTelemetry, ShardTelemetry};
use crate::net::{NetConfig, RemoteShard};
use crate::runtime::backend::BackendKind;
use crate::runtime::photonic::PhotonicConfig;
use crate::{Error, Result};

/// How the fleet picks the shard that serves the next request.
#[derive(Debug, Clone, Default)]
pub enum RoutePolicy {
    /// Uniform rotation over live shards.
    #[default]
    RoundRobin,
    /// The live shard with the fewest unresolved requests.
    LeastQueueDepth,
    /// Deterministic proportional split: shard `i` receives
    /// `weights[i] / sum(weights)` of the traffic (dead shards drop out and
    /// the remainder re-normalizes). One weight per shard.
    Weighted(Vec<u32>),
}

/// Shard revival + dynamic-spawn policy for a fleet (see the module docs'
/// revival section). Carried on [`FleetConfig::autoscale`]; when set, the
/// fleet runs a janitor thread applying it on a cadence, and the on-demand
/// entry points ([`FleetHandle::revive_dead_shards`],
/// [`FleetHandle::maybe_scale_up`]) use its thresholds.
#[derive(Debug, Clone)]
pub struct FleetAutoscale {
    /// Probe dead shards and respawn their worker pools (the leader
    /// survives retirement, so revival is in-place).
    pub revive: bool,
    /// Hard cap on total shards (initial + dynamically spawned). Values at
    /// or below the initial shard count disable spawning.
    pub max_shards: usize,
    /// Mean queue depth per live shard at which a new shard spawns.
    pub pressure_per_shard: u64,
    /// How long a revival health probe waits for its pong, seconds.
    pub probe_timeout_s: f64,
    /// Janitor cadence, seconds.
    pub interval_s: f64,
}

impl Default for FleetAutoscale {
    fn default() -> Self {
        FleetAutoscale {
            revive: true,
            max_shards: 0,
            pressure_per_shard: 16,
            probe_timeout_s: FleetAutoscale::DEFAULT_PROBE_TIMEOUT_S,
            interval_s: 0.05,
        }
    }
}

impl FleetAutoscale {
    /// Default health-probe wait, seconds (also used by on-demand revival
    /// on fleets configured without autoscale).
    pub const DEFAULT_PROBE_TIMEOUT_S: f64 = 5.0;

    /// Revival only (no dynamic spawning).
    pub fn revive_only() -> Self {
        FleetAutoscale { revive: true, max_shards: 0, ..Default::default() }
    }
}

/// One remote shard to join the fleet: where to dial and how patient to be
/// (see [`crate::net::NetConfig`]). Remote slots are appended to the table
/// *after* every local shard, in declaration order.
#[derive(Debug, Clone)]
pub struct RemoteShardConfig {
    /// Server address, `host:port`.
    pub addr: String,
    /// Optional display label; defaults to `remote<i>@<addr>`.
    pub label: Option<String>,
    /// Timeouts, backoff and frame limits for every call to this peer.
    pub net: NetConfig,
}

impl RemoteShardConfig {
    /// A remote shard at `addr` with default [`NetConfig`] deadlines.
    pub fn new(addr: impl Into<String>) -> Self {
        RemoteShardConfig { addr: addr.into(), label: None, net: NetConfig::default() }
    }
}

/// Fleet configuration: one [`CoordinatorConfig`] per local shard (plus any
/// [`RemoteShardConfig`] peers) and the routing policy.
#[derive(Debug, Clone, Default)]
pub struct FleetConfig {
    /// Per-shard coordinator configurations (possibly heterogeneous
    /// backends — that is the point).
    pub shards: Vec<CoordinatorConfig>,
    /// Shard selection policy.
    pub policy: RoutePolicy,
    /// Optional display labels, one per shard; missing entries fall back to
    /// `shard<i>:<backend label>`.
    pub labels: Vec<String>,
    /// Revival/autoscaling policy; `None` (the default everywhere) keeps
    /// the historical fixed-fleet behavior with no janitor thread.
    pub autoscale: Option<FleetAutoscale>,
    /// Remote shard servers to dial at start ([`crate::net::ShardServer`]
    /// peers); their slots follow the local ones. A weighted policy's
    /// weight list covers local shards first, then remotes in this order.
    pub remotes: Vec<RemoteShardConfig>,
}

impl FleetConfig {
    /// A single-shard fleet — the compatibility spelling of the historical
    /// one-coordinator serving path.
    pub fn single(shard: CoordinatorConfig) -> Self {
        FleetConfig { shards: vec![shard], ..Default::default() }
    }

    /// `n` identical shards behind round-robin (horizontal scaling).
    pub fn replicated(shard: CoordinatorConfig, n: usize) -> Self {
        FleetConfig { shards: vec![shard; n.max(1)], ..Default::default() }
    }

    /// Weighted two-shard A/B split — the photonic-design-experiment
    /// shape: identical artifacts, different backends, traffic split
    /// `wa:wb`.
    pub fn ab_split(a: CoordinatorConfig, b: CoordinatorConfig, wa: u32, wb: u32) -> Self {
        FleetConfig {
            shards: vec![a, b],
            policy: RoutePolicy::Weighted(vec![wa, wb]),
            ..Default::default()
        }
    }

    /// Attach a revival/autoscaling policy.
    pub fn with_autoscale(mut self, autoscale: FleetAutoscale) -> Self {
        self.autoscale = Some(autoscale);
        self
    }

    /// Add a remote shard server to dial at start.
    pub fn with_remote(mut self, remote: RemoteShardConfig) -> Self {
        self.remotes.push(remote);
        self
    }

    /// Noise-aware serving sweep: one photonic shard per link margin, each
    /// injecting analog noise at that margin with its own deterministic
    /// stream. `base`'s backend supplies the design point (non-photonic
    /// bases sweep SPOGA_10). Drive identical traffic at every shard via
    /// [`FleetHandle::shard`] and read served-accuracy vs sim-FPS/W off
    /// [`FleetHandle::telemetry`] — the serving-path slice of the offline
    /// fidelity study.
    pub fn noise_sweep(base: CoordinatorConfig, margins_db: &[f64]) -> Self {
        let pc = match &base.backend {
            BackendKind::Photonic(p) => p.clone(),
            _ => PhotonicConfig::spoga(),
        };
        let mut shards = Vec::with_capacity(margins_db.len());
        let mut labels = Vec::with_capacity(margins_db.len());
        for (i, &margin) in margins_db.iter().enumerate() {
            let mut cfg = base.clone();
            cfg.backend = BackendKind::Photonic(pc.clone().with_noise(
                NoiseParams::from_link_margin(margin),
                0x5EED_F1EE + ((i as u64) << 16),
            ));
            shards.push(cfg);
            labels.push(format!("margin+{margin:.0}dB"));
        }
        FleetConfig { shards, labels, ..Default::default() }
    }

    /// Noise-aware serving *grid*: one noise-injecting photonic shard per
    /// [`NoiseSweepGrid`] cell (K × ADC bits, shared link margin), labelled
    /// `K{k}/adc{bits}`. `base`'s backend supplies the design point
    /// (non-photonic bases study SPOGA_10). Shards share the same base
    /// noise seed per K — the Gaussian stage of two cells that differ only
    /// in ADC resolution then draws identically on identical traffic, so
    /// the ADC axis of the trade table isolates quantization.
    ///
    /// Drive each cell's K-shaped traffic with [`NoiseSweepGrid::drive`]
    /// (or [`NoiseSweepGrid::drive_cell`]) and read the served-accuracy vs
    /// sim-FPS/W frontier off [`FleetHandle::telemetry`] — the full trade
    /// *curves* the ROADMAP's noise-aware study calls for, where
    /// [`FleetConfig::noise_sweep`] covers only the link-margin axis.
    pub fn noise_grid(base: CoordinatorConfig, grid: &NoiseSweepGrid) -> Self {
        let pc = match &base.backend {
            BackendKind::Photonic(p) => p.clone(),
            _ => PhotonicConfig::spoga(),
        };
        let cells = grid.cells();
        let mut shards = Vec::with_capacity(cells.len());
        let mut labels = Vec::with_capacity(cells.len());
        for (k, bits) in cells {
            let mut cfg = base.clone();
            cfg.backend = BackendKind::Photonic(pc.clone().with_noise(
                NoiseParams::from_link_margin(grid.margin_db).with_adc(bits),
                0xADC0_5EED ^ ((k as u64) << 16),
            ));
            shards.push(cfg);
            labels.push(format!("K{k}/adc{bits}"));
        }
        FleetConfig { shards, labels, ..Default::default() }
    }
}

/// The K × ADC-bits noise-study grid (PAPER §IV–V: link margin vs spatial
/// parallelism K and ADC resolution, here on the *serving* path).
///
/// Each cell `(k, adc_bits)` names one noise-injecting photonic shard of a
/// [`FleetConfig::noise_grid`] fleet; the cell's probe traffic is K-length
/// dot products (a single-FC CNN layer, so frames exercise the t-stacked
/// batching path that per-row noise attribution keeps exact under noise).
/// Reading served-exact fraction against projected sim-FPS/W across the
/// cells yields the accuracy-vs-efficiency frontier that HOLYLIGHT and
/// DEAP-CNN report only at fixed design points.
#[derive(Debug, Clone, PartialEq)]
pub struct NoiseSweepGrid {
    /// GEMM reduction lengths — the paper's spatial-parallelism axis K.
    pub ks: Vec<usize>,
    /// PWAB ADC resolutions, bits.
    pub adc_bits: Vec<u32>,
    /// Link margin above the 4-bit receiver sensitivity floor shared by
    /// every cell, dB.
    pub margin_db: f64,
}

impl NoiseSweepGrid {
    /// Link margin the grid defaults to: high enough that receiver noise
    /// does not drown the ADC axis, low enough that it still moves the K
    /// axis.
    pub const DEFAULT_MARGIN_DB: f64 = 40.0;

    /// Outputs per probe dot-product row (the `c` of the `1×K×c` probe
    /// GEMM each frame executes).
    pub const PROBE_OUTPUTS: usize = 8;

    /// The paper's spatial-parallelism range crossed with ADC resolutions
    /// around the design point: Table I solves the MWA rows to N = 74
    /// (5 dBm @ 10 GS/s), 160 (10 dBm @ 10 GS/s) and 249 (10 dBm @ 1 GS/s)
    /// — the K range over which the paper argues byte-size integer GEMM
    /// survives — × {4, 6, 8}-bit PWAB ADCs.
    pub fn paper_range() -> Self {
        NoiseSweepGrid {
            ks: vec![74, 160, 249],
            adc_bits: vec![4, 6, 8],
            margin_db: Self::DEFAULT_MARGIN_DB,
        }
    }

    /// Parse a grid spec such as `K=74,160,adc=6,8` (optionally with a
    /// trailing `margin=40`): comma-separated tokens where `K=` / `adc=` /
    /// `margin=` prefixes switch which list subsequent bare numbers extend.
    pub fn parse(spec: &str) -> Result<Self> {
        #[derive(Clone, Copy, PartialEq)]
        enum Axis {
            K,
            Adc,
            Margin,
        }
        let bad = |msg: String| Error::Config(format!("noise grid {spec:?}: {msg}"));
        let mut grid = NoiseSweepGrid {
            ks: Vec::new(),
            adc_bits: Vec::new(),
            margin_db: Self::DEFAULT_MARGIN_DB,
        };
        let mut axis: Option<Axis> = None;
        let mut margin_set = false;
        for raw in spec.split(',') {
            let tok = raw.trim();
            if tok.is_empty() {
                continue;
            }
            let value = if let Some(v) = tok.strip_prefix("K=").or_else(|| tok.strip_prefix("k=")) {
                axis = Some(Axis::K);
                v
            } else if let Some(v) = tok.strip_prefix("adc=") {
                axis = Some(Axis::Adc);
                v
            } else if let Some(v) = tok.strip_prefix("margin=") {
                axis = Some(Axis::Margin);
                v
            } else {
                tok
            };
            match axis {
                None => return Err(bad(format!("token {tok:?} before any K=/adc= prefix"))),
                Some(Axis::K) => {
                    let k = value
                        .parse::<usize>()
                        .ok()
                        .filter(|&k| k >= 1)
                        .ok_or_else(|| bad(format!("bad K value {value:?}")))?;
                    if grid.ks.contains(&k) {
                        return Err(bad(format!("duplicate K value {k}")));
                    }
                    grid.ks.push(k);
                }
                Some(Axis::Adc) => {
                    let bits = value
                        .parse::<u32>()
                        .ok()
                        .filter(|&b| (1..=16).contains(&b))
                        .ok_or_else(|| bad(format!("bad adc bits {value:?} (want 1..=16)")))?;
                    if grid.adc_bits.contains(&bits) {
                        return Err(bad(format!("duplicate adc value {bits}")));
                    }
                    grid.adc_bits.push(bits);
                }
                Some(Axis::Margin) => {
                    if margin_set {
                        return Err(bad(format!(
                            "margin given more than once (second value {value:?})"
                        )));
                    }
                    margin_set = true;
                    grid.margin_db = value
                        .parse::<f64>()
                        .ok()
                        .filter(|m| m.is_finite() && *m >= 0.0)
                        .ok_or_else(|| bad(format!("bad margin {value:?}")))?;
                }
            }
        }
        if grid.ks.is_empty() || grid.adc_bits.is_empty() {
            return Err(bad("need at least one K and one adc value".into()));
        }
        Ok(grid)
    }

    /// Grid cells `(k, adc_bits)` in fleet-shard order (K-major), matching
    /// [`FleetConfig::noise_grid`]'s shard layout.
    pub fn cells(&self) -> Vec<(usize, u32)> {
        let mut cells = Vec::with_capacity(self.ks.len() * self.adc_bits.len());
        for &k in &self.ks {
            for &bits in &self.adc_bits {
                cells.push((k, bits));
            }
        }
        cells
    }

    /// Drive `frames` probe CNN frames (each a `1×K×PROBE_OUTPUTS` GEMM
    /// through a single-FC model, deterministic per-K inputs) at cell
    /// `cell`'s shard, slot-based so same-model frames stack in the
    /// batching window — exercising t-stacked CNN serving under noise.
    /// Returns the number of replies served.
    pub fn drive_cell(&self, handle: &FleetHandle, cell: usize, frames: usize) -> Result<usize> {
        let cells = self.cells();
        if handle.shard_count() != cells.len() {
            return Err(Error::Config(format!(
                "fleet has {} shards but the grid has {} cells — build it with \
                 FleetConfig::noise_grid over this grid",
                handle.shard_count(),
                cells.len()
            )));
        }
        let (k, _bits) = cells[cell];
        let model = CnnModel {
            name: "noise_grid_probe",
            layers: vec![crate::dnn::Layer::fc("dot", k, Self::PROBE_OUTPUTS)],
        };
        let shard = handle.shard(cell);
        let mut rng = crate::testing::SplitMix64::new(0x6_B1D ^ ((k as u64) << 20));
        let slots: Vec<Response> = (0..frames)
            .map(|_| {
                let input: Vec<i32> = (0..k).map(|_| rng.i8() as i32).collect();
                shard.submit_cnn(model.clone(), input)
            })
            .collect::<Result<_>>()?;
        let mut served = 0usize;
        for rx in slots {
            rx.recv()
                .map_err(|_| Error::Coordinator("noise-grid reply slot dropped".into()))??;
            served += 1;
        }
        Ok(served)
    }

    /// Drive every cell's probe traffic ([`NoiseSweepGrid::drive_cell`]) in
    /// shard order; returns total replies served (`frames × cells`).
    pub fn drive(&self, handle: &FleetHandle, frames: usize) -> Result<usize> {
        let mut served = 0;
        for cell in 0..self.cells().len() {
            served += self.drive_cell(handle, cell, frames)?;
        }
        Ok(served)
    }

    /// Render the frontier readout for a fleet built over this grid: one
    /// row per cell — stacked-batch count, lanes, noise events,
    /// served-exact fraction, projected sim-FPS and sim-FPS/W. Shared by
    /// `spoga serve --noise-grid` and `examples/fleet_serve.rs` so the
    /// study's table cannot drift between surfaces.
    pub fn frontier_table(&self, handle: &FleetHandle) -> crate::report::Table {
        let telemetry = handle.telemetry();
        let mut table = crate::report::Table::new(vec![
            "cell",
            "cnn stacks",
            "lanes",
            "noise events",
            "served-exact",
            "sim FPS",
            "sim FPS/W",
        ]);
        for (i, shard) in telemetry.shards.iter().enumerate() {
            table.row(vec![
                shard.label.clone(),
                handle.shard_stats(i).cnn_batches.load(Ordering::Relaxed).to_string(),
                shard.lanes.to_string(),
                shard.noise_events.to_string(),
                format!("{:.6}", shard.served_exact_fraction()),
                crate::report::fmt_sig(shard.sim_fps(), 3),
                crate::report::fmt_sig(shard.sim_fps_per_w(), 3),
            ]);
        }
        table
    }
}

/// What a slot routes to: an in-process coordinator or a cross-host peer.
/// The two arms expose the same submit/ping/stats/revive surface (the
/// module docs' equivalence contract), so everything above this enum —
/// policies, failover, retrying slots, telemetry — is transport-blind.
enum ShardLink {
    Local {
        handle: CoordinatorHandle,
        /// The running coordinator, parked here so dynamically spawned
        /// shards have an owner; `Fleet::shutdown` (or the last drop)
        /// takes it.
        coordinator: Mutex<Option<Coordinator>>,
    },
    Remote(RemoteShard),
}

struct ShardSlot {
    label: String,
    link: ShardLink,
    dead: AtomicBool,
}

impl ShardSlot {
    fn new(label: String, coordinator: Coordinator) -> Arc<Self> {
        Arc::new(ShardSlot {
            label,
            link: ShardLink::Local {
                handle: coordinator.handle(),
                coordinator: Mutex::new(Some(coordinator)),
            },
            dead: AtomicBool::new(false),
        })
    }

    fn remote(label: String, shard: RemoteShard) -> Arc<Self> {
        Arc::new(ShardSlot { label, link: ShardLink::Remote(shard), dead: AtomicBool::new(false) })
    }

    /// Live stats: the coordinator's own counters for a local slot, the
    /// client-side mirror (kept by the remote reader thread) for a remote
    /// one — so queue-depth routing and telemetry never block on a socket.
    fn stats(&self) -> &CoordinatorStats {
        match &self.link {
            ShardLink::Local { handle, .. } => handle.stats(),
            ShardLink::Remote(r) => r.stats(),
        }
    }

    fn stats_arc(&self) -> Arc<CoordinatorStats> {
        match &self.link {
            ShardLink::Local { handle, .. } => handle.stats_arc(),
            ShardLink::Remote(r) => r.stats_arc(),
        }
    }

    /// Submit with an explicit QoS envelope plus an optional retained noise
    /// nonce. `Ok` carries the nonce the accepting *local* coordinator
    /// stamped (so retrying layers can replay it bit-identically across
    /// failover); a remote peer draws its nonce server-side, so the remote
    /// arm reports `None` and noisy replay determinism is a local-fleet
    /// guarantee.
    fn try_submit_gemm(
        &self,
        artifact: &str,
        a: Vec<i32>,
        b: Vec<i32>,
        qos: Qos,
        nonce: Option<u64>,
    ) -> std::result::Result<(Response, Option<u64>), Rejected<(Vec<i32>, Vec<i32>)>> {
        match &self.link {
            ShardLink::Local { handle, .. } => handle
                .try_submit_gemm_opts(artifact, a, b, qos, nonce)
                .map(|(rx, n)| (rx, Some(n))),
            ShardLink::Remote(r) => {
                r.try_submit_gemm_qos(artifact, a, b, qos).map(|rx| (rx, None))
            }
        }
    }

    fn try_submit_mlp(
        &self,
        row: Vec<i32>,
        qos: Qos,
        nonce: Option<u64>,
    ) -> std::result::Result<(Response, Option<u64>), Rejected<Vec<i32>>> {
        match &self.link {
            ShardLink::Local { handle, .. } => {
                handle.try_submit_mlp_opts(row, qos, nonce).map(|(rx, n)| (rx, Some(n)))
            }
            ShardLink::Remote(r) => r.try_submit_mlp_qos(row, qos).map(|rx| (rx, None)),
        }
    }

    fn try_submit_cnn(
        &self,
        model: CnnModel,
        input: Vec<i32>,
        qos: Qos,
        nonce: Option<u64>,
    ) -> std::result::Result<(Response, Option<u64>), Rejected<(CnnModel, Vec<i32>)>> {
        match &self.link {
            ShardLink::Local { handle, .. } => handle
                .try_submit_cnn_opts(model, input, qos, nonce)
                .map(|(rx, n)| (rx, Some(n))),
            ShardLink::Remote(r) => {
                r.try_submit_cnn_qos(model, input, qos).map(|rx| (rx, None))
            }
        }
    }

    fn ping(&self, timeout: Duration) -> Result<()> {
        match &self.link {
            ShardLink::Local { handle, .. } => handle.ping(timeout),
            ShardLink::Remote(r) => r.ping(timeout),
        }
    }

    /// Try to bring the shard's serving capacity back: respawn the worker
    /// pool for a local slot, reconnect (bounded, jittered backoff) for a
    /// remote one. Health is then proven the same way for both — an
    /// end-to-end pong.
    fn try_restore(&self) -> bool {
        match &self.link {
            ShardLink::Local { handle, .. } => {
                handle.revive_workers(handle.configured_workers()).is_ok()
            }
            ShardLink::Remote(r) => r.reconnect().is_ok(),
        }
    }

    /// Shut the link down: drain a local coordinator, disconnect (and join
    /// the reader/heartbeat threads of) a remote client.
    fn shutdown_link(&self) {
        match &self.link {
            ShardLink::Local { coordinator, .. } => {
                let taken = crate::sync::lock_recovered(coordinator).take();
                if let Some(c) = taken {
                    c.shutdown();
                }
            }
            ShardLink::Remote(r) => r.disconnect(),
        }
    }
}

/// Fleet lifecycle counters — the resilience layer's telemetry, rolled into
/// [`FleetTelemetry`] by [`FleetHandle::telemetry`].
#[derive(Debug, Default)]
pub struct FleetLifecycle {
    /// Accepted-then-orphaned requests resubmitted on a survivor by a
    /// [`RetryingSlot`].
    pub resubmits: AtomicU64,
    /// Dead shards successfully probed back into the rotation.
    pub shards_revived: AtomicU64,
    /// Shards dynamically spawned under pressure.
    pub shards_spawned: AtomicU64,
    /// Revival probes that failed (pool did not come back / pong timed out).
    pub failed_probes: AtomicU64,
    /// Submit-time reroutes: submissions a refusing (down) shard pushed to
    /// the next live shard. When every remote shard is unreachable this is
    /// where the drain-to-local traffic shows up.
    pub submit_reroutes: AtomicU64,
    /// Retrying submissions that exhausted the fleet — terminal
    /// [`Error::ShardDown`] dispositions, counted exactly once per logical
    /// request (never once per resubmit attempt).
    pub terminal_failures: AtomicU64,
}

struct FleetInner {
    /// Interior-mutable so autoscaling can append shards while handles
    /// route; indices are stable (slots are only ever appended).
    slots: RwLock<Vec<Arc<ShardSlot>>>,
    policy: RoutePolicy,
    /// Routing cursor: round-robin rotation / weighted tick counter.
    cursor: AtomicUsize,
    /// Fleet-unique logical request ids for retrying submissions.
    next_request_id: AtomicU64,
    lifecycle: FleetLifecycle,
    autoscale: Option<FleetAutoscale>,
    /// Config cloned for dynamically spawned shards (the first configured
    /// *local* shard's — replicate what the operator scaled first). `None`
    /// on a pure-remote fleet, which therefore cannot autoscale-spawn.
    spawn_template: Option<CoordinatorConfig>,
}

/// Cloneable client handle over the whole fleet: routes each request to a
/// shard per the policy, fails over when shards die, and rolls per-shard
/// stats up into fleet telemetry.
#[derive(Clone)]
pub struct FleetHandle {
    inner: Arc<FleetInner>,
}

/// Does this error mean the shard (not the request) is broken? The typed
/// [`Error::ShardDown`] variant counts — worker-pool death, a stopped
/// coordinator and shutdown drains construct it — plus the [`Error::Remote`]
/// kinds whose peer is truly unreachable
/// ([`RemoteErrorKind::retires_shard`](crate::error::RemoteErrorKind::retires_shard):
/// `ConnRefused`, `PeerGone`). Request-level errors — shape, artifact,
/// execute failures, a dropped reply slot (a worker crashed *on this
/// request* and must not send a possibly poisonous payload marching across
/// every shard), and the remaining remote kinds (one corrupt frame, a
/// version skew, one slow reply: the peer is demonstrably alive) — never
/// burn a failover.
fn is_shard_down(e: &Error) -> bool {
    match e {
        Error::ShardDown(_) => true,
        Error::Remote { kind, .. } => kind.retires_shard(),
        _ => false,
    }
}

/// The typed error serving threads see when the slot-table lock is poisoned
/// (a shard spawner panicked mid-append). A panic there must surface as an
/// error on each request, not cascade panics into every serving thread.
fn poisoned_slots() -> Error {
    Error::Coordinator(
        "fleet slot table lock poisoned (a shard spawner panicked); \
         serving is halted until the fleet restarts"
            .into(),
    )
}

impl FleetHandle {
    /// Snapshot the slot table (cheap `Arc` clones; indices are stable).
    /// Infallible: ops/telemetry reads recover a poisoned lock — the table
    /// itself is always valid (slots are append-only `Arc`s) and dashboards
    /// must keep working while serving reports [`poisoned_slots`] errors.
    fn slots(&self) -> Vec<Arc<ShardSlot>> {
        match self.inner.slots.read() {
            Ok(g) => g.clone(),
            Err(p) => p.into_inner().clone(),
        }
    }

    /// [`FleetHandle::slots`] for serving paths: a poisoned lock becomes a
    /// typed [`Error::Coordinator`] instead of a panic.
    fn try_slots(&self) -> Result<Vec<Arc<ShardSlot>>> {
        match self.inner.slots.read() {
            Ok(g) => Ok(g.clone()),
            Err(_) => Err(poisoned_slots()),
        }
    }

    /// Slot `i` (panics on out-of-range, like the historical indexing).
    fn slot(&self, i: usize) -> Arc<ShardSlot> {
        self.slots()[i].clone()
    }

    /// Shards still worth routing to within one slot-table snapshot: not
    /// marked dead AND with a live worker pool. The second check matters
    /// for slot-based traffic — a shard whose leader fast-fails every job
    /// keeps a near-zero queue depth and would otherwise *attract*
    /// least-queue-depth routing without ever tripping the dead flag.
    fn live_in(slots: &[Arc<ShardSlot>]) -> Vec<usize> {
        slots
            .iter()
            .enumerate()
            .filter(|(_, s)| !Self::is_down(s))
            .map(|(i, _)| i)
            .collect()
    }

    fn live(&self) -> Vec<usize> {
        Self::live_in(&self.slots())
    }

    /// Pick one of the `live` shard indices (non-empty) per the policy.
    fn pick(&self, live: &[usize]) -> usize {
        self.pick_in(&self.slots(), live)
    }

    /// [`FleetHandle::pick`] over an existing slot snapshot — the hot
    /// routing path takes one snapshot per attempt and reuses it here.
    fn pick_in(&self, slots: &[Arc<ShardSlot>], live: &[usize]) -> usize {
        match &self.inner.policy {
            RoutePolicy::RoundRobin => {
                live[self.inner.cursor.fetch_add(1, Ordering::Relaxed) % live.len()]
            }
            RoutePolicy::LeastQueueDepth => {
                // Snapshot depths once (they move under us), then rotate
                // among the minima so an all-idle fleet still balances
                // instead of pinning shard 0.
                let depths: Vec<(usize, u64)> = live
                    .iter()
                    .map(|&i| (i, slots[i].stats().queue_depth()))
                    .collect();
                let min = depths.iter().map(|&(_, d)| d).min().expect("non-empty live set");
                let ties: Vec<usize> =
                    depths.iter().filter(|&&(_, d)| d == min).map(|&(i, _)| i).collect();
                ties[self.inner.cursor.fetch_add(1, Ordering::Relaxed) % ties.len()]
            }
            RoutePolicy::Weighted(weights) => {
                // Shards beyond the configured weights (dynamically
                // spawned) default to weight 1 so autoscaled capacity
                // actually takes traffic.
                let weight_of =
                    |i: usize| u64::from(weights.get(i).copied().unwrap_or(1));
                let total: u64 = live.iter().map(|&i| weight_of(i)).sum();
                if total == 0 {
                    // All live weights zero: degrade to round-robin rather
                    // than starve the fleet.
                    return live[self.inner.cursor.fetch_add(1, Ordering::Relaxed) % live.len()];
                }
                let mut tick =
                    (self.inner.cursor.fetch_add(1, Ordering::Relaxed) as u64) % total;
                for &i in live {
                    let w = weight_of(i);
                    if tick < w {
                        return i;
                    }
                    tick -= w;
                }
                live[live.len() - 1]
            }
        }
    }

    /// Submit-time failover: run the payload-recovering `op` against
    /// policy-picked shards (local or remote — the op dispatches through
    /// [`ShardSlot`]), marking refusers dead and *moving* the recovered
    /// payload to the next attempt — no clone, ever. Returns the accepted
    /// value plus the index of the shard that took it. Request-level
    /// rejections (bad shape, unknown artifact) return immediately. An
    /// [`Error::Overloaded`] refusal is busy-not-dead (module docs): the
    /// payload routes around the shedding shard — which stays live and
    /// counts no reroute — until every live shard has refused once, then
    /// the typed overload surfaces.
    fn with_submit_failover<T, P>(
        &self,
        payload: P,
        mut op: impl FnMut(&ShardSlot, P) -> std::result::Result<T, Rejected<P>>,
    ) -> Result<(T, usize)> {
        let mut payload = Some(payload);
        let mut last_err: Option<Error> = None;
        let mut rerouted = false;
        let mut overload_bounces = 0usize;
        // Each shard-down attempt retires a shard, so the loop terminates;
        // the cap only guards against a pathological revive/fail cycle
        // (overload bounces are separately bounded by the live-set size).
        let attempt_cap = 2 * self.shard_count() + 2;
        for _ in 0..attempt_cap {
            // One slot-table snapshot per attempt covers live-set, pick and
            // the slot — the hot path pays one lock, not four.
            let slots = self.try_slots()?;
            let live = Self::live_in(&slots);
            if live.is_empty() {
                break;
            }
            let idx = self.pick_in(&slots, &live);
            match op(&slots[idx], payload.take().expect("payload present while attempts remain"))
            {
                Ok(v) => return Ok((v, idx)),
                Err(Rejected { error, payload: recovered }) if is_shard_down(&error) => {
                    slots[idx].dead.store(true, Ordering::Relaxed);
                    if !rerouted {
                        // Count the logical submission that moved, not
                        // every shard it bounced off along the way.
                        rerouted = true;
                        self.inner.lifecycle.submit_reroutes.fetch_add(1, Ordering::Relaxed);
                    }
                    last_err = Some(error);
                    payload = Some(recovered);
                }
                Err(Rejected { error: error @ Error::Overloaded(_), payload: recovered }) => {
                    // Shedding shard: alive and draining. Never retire it,
                    // never count a reroute; try the rest of the live set
                    // once each, then report the overload typed.
                    overload_bounces += 1;
                    if overload_bounces >= live.len() {
                        return Err(error);
                    }
                    last_err = Some(error);
                    payload = Some(recovered);
                }
                Err(Rejected { error, .. }) => return Err(error),
            }
        }
        Err(last_err.unwrap_or_else(|| Error::ShardDown("fleet has no live shards".into())))
    }

    /// Route one retained payload to a shard (the [`RetryingSlot`] submit /
    /// resubmit primitive). `nonce` is the retained noise nonce from a
    /// prior accept (replayed verbatim on a local survivor so noisy
    /// failover stays bit-identical); the returned `Option<u64>` is the
    /// nonce this accept stamped (`None` when a remote peer took it — the
    /// server draws its own).
    fn submit_payload(
        &self,
        payload: RetryPayload,
        qos: Qos,
        nonce: Option<u64>,
    ) -> Result<(Response, usize, Option<u64>)> {
        let ((rx, stamped), shard) = match payload {
            RetryPayload::Gemm { artifact, a, b } => self.with_submit_failover(
                (a, b),
                |s, (a, b)| s.try_submit_gemm(&artifact, a, b, qos, nonce),
            )?,
            RetryPayload::Mlp { row } => {
                self.with_submit_failover(row, |s, row| s.try_submit_mlp(row, qos, nonce))?
            }
            RetryPayload::Cnn { model, input } => self.with_submit_failover(
                (model, input),
                |s, (model, input)| s.try_submit_cnn(model, input, qos, nonce),
            )?,
        };
        Ok((rx, shard, stamped))
    }

    /// Submit a GEMM to a policy-picked shard; returns the raw response
    /// slot. Failover covers submission (clone-free); a shard dying *after*
    /// accepting resolves the slot with an error — use
    /// [`FleetHandle::submit_gemm_retrying`] for full mid-flight retry
    /// semantics.
    pub fn submit_gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Response> {
        self.submit_gemm_qos(artifact, a, b, Qos::default())
    }

    /// [`FleetHandle::submit_gemm`] with an explicit QoS envelope
    /// (priority class + optional deadline).
    pub fn submit_gemm_qos(
        &self,
        artifact: &str,
        a: Vec<i32>,
        b: Vec<i32>,
        qos: Qos,
    ) -> Result<Response> {
        Ok(self
            .with_submit_failover((a, b), |s, (a, b)| {
                s.try_submit_gemm(artifact, a, b, qos, None)
            })?
            .0
             .0)
    }

    /// Submit one MLP row to a policy-picked shard; returns the raw
    /// response slot (submit-time failover only, clone-free).
    pub fn submit_mlp(&self, row: Vec<i32>) -> Result<Response> {
        self.submit_mlp_qos(row, Qos::default())
    }

    /// [`FleetHandle::submit_mlp`] with an explicit QoS envelope.
    pub fn submit_mlp_qos(&self, row: Vec<i32>, qos: Qos) -> Result<Response> {
        Ok(self
            .with_submit_failover(row, |s, row| s.try_submit_mlp(row, qos, None))?
            .0
             .0)
    }

    /// Submit a whole-CNN inference to a policy-picked shard; returns the
    /// raw response slot (submit-time failover only, clone-free).
    /// Same-model frames co-pending on that shard stack into one
    /// t-dimension batch.
    pub fn submit_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Response> {
        self.submit_cnn_qos(model, input, Qos::default())
    }

    /// [`FleetHandle::submit_cnn`] with an explicit QoS envelope.
    pub fn submit_cnn_qos(&self, model: CnnModel, input: Vec<i32>, qos: Qos) -> Result<Response> {
        Ok(self
            .with_submit_failover((model, input), |s, (model, input)| {
                s.try_submit_cnn(model, input, qos, None)
            })?
            .0
             .0)
    }

    fn submit_retrying(&self, payload: RetryPayload, qos: Qos) -> Result<RetryingSlot> {
        let request_id = self.inner.next_request_id.fetch_add(1, Ordering::Relaxed) + 1;
        // Always retain: even a 1-shard fleet with no autoscale policy can
        // gain a survivor at any time (public [`FleetHandle::spawn_shard`],
        // an on-demand revival), so a submit-time "no other shard exists"
        // check would bake in an invariant those APIs break. One payload
        // clone per retrying submit is the price of never losing an
        // accepted request that something could still serve.
        let (rx, shard, nonce) = self.submit_payload(payload.clone(), qos, None)?;
        let resubmits_left = 2 * self.shard_count() + 2;
        Ok(RetryingSlot {
            handle: self.clone(),
            rx,
            shard,
            request_id,
            payload,
            qos,
            nonce,
            resubmits_left,
            overload_retried: false,
        })
    }

    /// Submit a GEMM with *mid-flight* retry semantics: the returned
    /// [`RetryingSlot`] owns a copy of the payload, and if the serving
    /// shard dies after accepting, resubmits on a survivor and resolves
    /// with outputs bit-identical to an undisturbed run (including under
    /// counter-mode noise: the slot retains the originally-stamped nonce
    /// and replays it).
    pub fn submit_gemm_retrying(
        &self,
        artifact: &str,
        a: Vec<i32>,
        b: Vec<i32>,
    ) -> Result<RetryingSlot> {
        self.submit_gemm_retrying_qos(artifact, a, b, Qos::default())
    }

    /// [`FleetHandle::submit_gemm_retrying`] with an explicit QoS envelope.
    pub fn submit_gemm_retrying_qos(
        &self,
        artifact: &str,
        a: Vec<i32>,
        b: Vec<i32>,
        qos: Qos,
    ) -> Result<RetryingSlot> {
        self.submit_retrying(RetryPayload::Gemm { artifact: artifact.to_string(), a, b }, qos)
    }

    /// Submit one MLP row with mid-flight retry semantics (see
    /// [`FleetHandle::submit_gemm_retrying`]).
    pub fn submit_mlp_retrying(&self, row: Vec<i32>) -> Result<RetryingSlot> {
        self.submit_mlp_retrying_qos(row, Qos::default())
    }

    /// [`FleetHandle::submit_mlp_retrying`] with an explicit QoS envelope.
    pub fn submit_mlp_retrying_qos(&self, row: Vec<i32>, qos: Qos) -> Result<RetryingSlot> {
        self.submit_retrying(RetryPayload::Mlp { row }, qos)
    }

    /// Submit a whole-CNN inference with mid-flight retry semantics (see
    /// [`FleetHandle::submit_gemm_retrying`]).
    pub fn submit_cnn_retrying(&self, model: CnnModel, input: Vec<i32>) -> Result<RetryingSlot> {
        self.submit_cnn_retrying_qos(model, input, Qos::default())
    }

    /// [`FleetHandle::submit_cnn_retrying`] with an explicit QoS envelope.
    pub fn submit_cnn_retrying_qos(
        &self,
        model: CnnModel,
        input: Vec<i32>,
        qos: Qos,
    ) -> Result<RetryingSlot> {
        self.submit_retrying(RetryPayload::Cnn { model, input }, qos)
    }

    /// Blocking GEMM returning the full [`Reply`]; a retrying slot under
    /// the hood, so it survives shard death before *and* after acceptance.
    pub fn gemm_reply(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Reply> {
        self.submit_gemm_retrying(artifact, a, b)?.recv()
    }

    /// Blocking GEMM convenience.
    pub fn gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Vec<i32>> {
        Ok(self.gemm_reply(artifact, a, b)?.outputs)
    }

    /// Blocking MLP inference with full shard failover.
    pub fn infer_mlp(&self, row: Vec<i32>) -> Result<Vec<i32>> {
        Ok(self.submit_mlp_retrying(row)?.recv()?.outputs)
    }

    /// Blocking CNN inference (full [`Reply`]) with full shard failover.
    pub fn infer_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Reply> {
        self.submit_cnn_retrying(model, input)?.recv()
    }

    /// Number of shards (live and dead, local and remote).
    pub fn shard_count(&self) -> usize {
        match self.inner.slots.read() {
            Ok(g) => g.len(),
            Err(p) => p.into_inner().len(),
        }
    }

    /// Number of shards still in the rotation.
    pub fn live_shard_count(&self) -> usize {
        self.live().len()
    }

    /// Per-shard display labels, shard order.
    pub fn shard_labels(&self) -> Vec<String> {
        self.slots().iter().map(|s| s.label.clone()).collect()
    }

    /// Direct handle to *local* shard `i` — for per-shard drains
    /// ([`CoordinatorHandle::retire_workers`]) and sweep harnesses that
    /// must drive identical traffic at every shard, bypassing routing.
    ///
    /// # Panics
    ///
    /// On a remote slot: a cross-host shard has no in-process coordinator
    /// handle. Check [`FleetHandle::is_remote_shard`] first when the fleet
    /// may mix transports (sweep harnesses are local-only by construction).
    pub fn shard(&self, i: usize) -> CoordinatorHandle {
        match &self.slot(i).link {
            ShardLink::Local { handle, .. } => handle.clone(),
            ShardLink::Remote(r) => panic!(
                "shard {i} is remote ({}); FleetHandle::shard only exposes local coordinators",
                r.addr()
            ),
        }
    }

    /// Whether slot `i` fronts a cross-host peer.
    pub fn is_remote_shard(&self, i: usize) -> bool {
        matches!(self.slot(i).link, ShardLink::Remote(_))
    }

    /// Shard `i`'s live stats (the client-side mirror for remote slots).
    pub fn shard_stats(&self, i: usize) -> Arc<CoordinatorStats> {
        self.slot(i).stats_arc()
    }

    /// End-to-end health probe through routing: pings policy-visible live
    /// shards in table order and succeeds on the first pong. Errs with
    /// [`Error::ShardDown`] when nothing answers — the fleet cannot serve.
    pub fn ping(&self, timeout: Duration) -> Result<()> {
        let slots = self.try_slots()?;
        let live = Self::live_in(&slots);
        let mut last: Option<Error> = None;
        for &i in &live {
            match slots[i].ping(timeout) {
                Ok(()) => return Ok(()),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| Error::ShardDown("fleet has no live shards".into())))
    }

    /// Dial a new remote shard and append it to the rotation; returns its
    /// index. The connection must establish within the config's deadlines —
    /// a dead address fails here rather than poisoning the table.
    pub fn add_remote_shard(&self, remote: RemoteShardConfig) -> Result<usize> {
        let idx_hint = self.shard_count();
        let label = remote
            .label
            .clone()
            .unwrap_or_else(|| format!("remote{idx_hint}@{}", remote.addr));
        let shard = RemoteShard::connect(&remote.addr, &label, remote.net.clone())?;
        let mut slots = self.inner.slots.write().map_err(|_| poisoned_slots())?;
        let idx = slots.len();
        slots.push(ShardSlot::remote(label, shard));
        Ok(idx)
    }

    /// Take shard `i` out of the rotation (ops drain; also flipped
    /// automatically when a request observes the shard down). Revival
    /// ([`FleetHandle::revive_shard`]) is the only way back in.
    pub fn mark_dead(&self, i: usize) {
        self.slot(i).dead.store(true, Ordering::Relaxed);
    }

    /// Fleet lifecycle counters (live, not a snapshot).
    pub fn lifecycle(&self) -> &FleetLifecycle {
        &self.inner.lifecycle
    }

    /// Try to bring shard `i` back into the rotation: respawn its worker
    /// pool (local) or reconnect with bounded backoff (remote), health-probe
    /// it end to end, and clear the dead flag only on a successful pong.
    /// Returns `true` when the shard is serving afterwards (including "was
    /// never down"); a failed probe counts into
    /// [`FleetLifecycle::failed_probes`] and leaves the shard out.
    pub fn revive_shard(&self, i: usize) -> bool {
        let slot = self.slot(i);
        if !Self::is_down(&slot) {
            return true;
        }
        // Keep the shard flagged out of the rotation for the whole revival:
        // a local leader's respawn raises the live_workers gauge *before*
        // the fresh engines finish initializing (and a remote reconnect
        // flips reachability before the far pool proves healthy); routed
        // traffic buffered into a worker whose init then fails would drop
        // its reply slots terminally (the poison-payload rule keeps dropped
        // slots non-retried). Only a successful end-to-end pong re-admits.
        slot.dead.store(true, Ordering::Relaxed);
        let timeout = self
            .inner
            .autoscale
            .as_ref()
            .map(|a| a.probe_timeout_s)
            .unwrap_or(FleetAutoscale::DEFAULT_PROBE_TIMEOUT_S);
        let ok =
            slot.try_restore() && slot.ping(Duration::from_secs_f64(timeout)).is_ok();
        if ok {
            slot.dead.store(false, Ordering::Relaxed);
            self.inner.lifecycle.shards_revived.fetch_add(1, Ordering::Relaxed);
        } else {
            self.inner.lifecycle.failed_probes.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }

    /// Out of the rotation: flagged dead, or its worker pool is gone (for a
    /// remote slot `live_workers` is the client's reachability gauge).
    fn is_down(slot: &ShardSlot) -> bool {
        slot.dead.load(Ordering::Relaxed)
            || slot.stats().live_workers.load(Ordering::Relaxed) == 0
    }

    /// Probe every out-of-rotation shard ([`FleetHandle::revive_shard`]);
    /// returns how many came back. The janitor calls this on a cadence when
    /// [`FleetAutoscale::revive`] is set; ops can call it on demand on any
    /// fleet.
    pub fn revive_dead_shards(&self) -> usize {
        (0..self.shard_count())
            .filter(|&i| Self::is_down(&self.slot(i)) && self.revive_shard(i))
            .count()
    }

    /// Spawn a fresh shard from the template config (the first configured
    /// shard's). `cap` bounds the post-spawn shard count, re-checked under
    /// the slot write lock so concurrent spawners (janitor tick + on-demand
    /// ops call) cannot overshoot it; the losing coordinator shuts straight
    /// back down. Returns the new index, or `None` when the cap held.
    fn spawn_shard_under(&self, cap: usize) -> Result<Option<usize>> {
        let Some(cfg) = self.inner.spawn_template.clone() else {
            return Err(Error::Config(
                "pure-remote fleet has no local shard template to spawn from".into(),
            ));
        };
        let label_backend = cfg.backend.label();
        // Start before taking the write lock: warmup can be slow and
        // routing must not stall behind it.
        let c = Coordinator::start(cfg)?;
        let overshoot = {
            let mut slots = self.inner.slots.write().map_err(|_| {
                // Shut the freshly started coordinator down via drop.
                poisoned_slots()
            })?;
            if slots.len() >= cap {
                Some(c)
            } else {
                let idx = slots.len();
                slots.push(ShardSlot::new(format!("shard{idx}:{label_backend}:auto"), c));
                self.inner.lifecycle.shards_spawned.fetch_add(1, Ordering::Relaxed);
                return Ok(Some(idx));
            }
        };
        if let Some(c) = overshoot {
            c.shutdown();
        }
        Ok(None)
    }

    /// Spawn a fresh shard from the template config unconditionally (an
    /// explicit ops action — the autoscale cap applies only to
    /// [`FleetHandle::maybe_scale_up`]), append it to the rotation, and
    /// return its index.
    pub fn spawn_shard(&self) -> Result<usize> {
        Ok(self.spawn_shard_under(usize::MAX)?.expect("uncapped spawn never overshoots"))
    }

    /// Scale up if the autoscale policy says so: under
    /// [`FleetAutoscale::max_shards`], spawn a shard when mean queue depth
    /// per live shard reaches the pressure threshold — or when no live
    /// shard remains at all (spawning is then the only path back to
    /// serving). Returns whether a shard was spawned.
    pub fn maybe_scale_up(&self) -> Result<bool> {
        let Some(a) = &self.inner.autoscale else {
            return Ok(false);
        };
        if self.shard_count() >= a.max_shards {
            return Ok(false);
        }
        let live = self.live();
        let spawn = if live.is_empty() {
            true
        } else {
            let depth: u64 =
                live.iter().map(|&i| self.slot(i).stats().queue_depth()).sum();
            depth / live.len() as u64 >= a.pressure_per_shard
        };
        if !spawn {
            return Ok(false);
        }
        Ok(self.spawn_shard_under(a.max_shards)?.is_some())
    }

    /// Snapshot every shard's stats into the fleet rollup (plus the fleet
    /// lifecycle counters). Each shard's counters are read once per
    /// snapshot, so totals equal the sum of the per-shard stats. Counting
    /// is per submission attempt: a mid-flight resubmission contributes a
    /// `failed` on the dead shard *and* a `requests`/`completed` pair on
    /// the survivor — `resubmits` says how many logical requests did so
    /// (see the module docs' telemetry section).
    pub fn telemetry(&self) -> FleetTelemetry {
        let mut t = FleetTelemetry::new(
            self.slots()
                .iter()
                .map(|s| ShardTelemetry::capture(&s.label, s.stats()))
                .collect(),
        );
        t.resubmits = self.inner.lifecycle.resubmits.load(Ordering::Relaxed);
        t.shards_revived = self.inner.lifecycle.shards_revived.load(Ordering::Relaxed);
        t.shards_spawned = self.inner.lifecycle.shards_spawned.load(Ordering::Relaxed);
        t.failed_probes = self.inner.lifecycle.failed_probes.load(Ordering::Relaxed);
        t.submit_reroutes = self.inner.lifecycle.submit_reroutes.load(Ordering::Relaxed);
        t.terminal_failures = self.inner.lifecycle.terminal_failures.load(Ordering::Relaxed);
        t
    }
}

/// A retained payload for mid-flight retry — what a [`RetryingSlot`] owns
/// so an accepted-then-orphaned request can be resubmitted verbatim.
#[derive(Debug, Clone)]
pub enum RetryPayload {
    /// A GEMM against a named artifact.
    Gemm {
        /// Artifact name.
        artifact: String,
        /// Flat row-major A operand.
        a: Vec<i32>,
        /// Flat row-major B operand.
        b: Vec<i32>,
    },
    /// One MLP activation row.
    Mlp {
        /// The activation row.
        row: Vec<i32>,
    },
    /// A whole-CNN inference.
    Cnn {
        /// The network to run.
        model: CnnModel,
        /// First-layer activation tensor.
        input: Vec<i32>,
    },
}

/// A response slot that survives mid-flight shard death: owns a retained
/// copy of the request payload plus a fleet-unique request id, and on a
/// reply-time [`Error::ShardDown`] marks the serving shard dead, resubmits
/// on a survivor (policy-picked, submit-failover included) and keeps
/// waiting — so the caller's one `recv` resolves with outputs bit-identical
/// to an undisturbed run. Request-level errors and dropped reply slots
/// (worker crash mid-request — a possibly poisonous payload) resolve
/// immediately without retry, exactly like the raw [`Response`].
pub struct RetryingSlot {
    handle: FleetHandle,
    rx: Response,
    /// Index of the shard currently holding the request.
    shard: usize,
    request_id: u64,
    /// Retained payload for resubmission across shard deaths.
    payload: RetryPayload,
    /// QoS envelope replayed on every resubmission (the logical request's
    /// class and deadline do not change because a shard died).
    qos: Qos,
    /// The noise nonce the first accepting *local* coordinator stamped;
    /// resubmissions replay it so counter-mode noise draws identically
    /// across failover (`None` until a local shard accepts).
    nonce: Option<u64>,
    resubmits_left: usize,
    /// A reply-time [`Error::Overloaded`] grants at most one bounded
    /// resubmission (module docs: overload is busy-not-dead); this latches
    /// after it is spent.
    overload_retried: bool,
}

impl RetryingSlot {
    /// Fleet-unique id of this logical request, stable across
    /// resubmissions.
    pub fn request_id(&self) -> u64 {
        self.request_id
    }

    /// Index of the shard currently holding the request.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Block until the request resolves, resubmitting across shard deaths.
    pub fn recv(self) -> Result<Reply> {
        self.wait(None)
    }

    /// [`RetryingSlot::recv`] with an overall deadline spanning the reply
    /// waits of every attempt. Resubmission itself never blocks: admission
    /// is non-blocking `try_send` everywhere, so a survivor whose ingress
    /// queue is full refuses typed ([`Error::Overloaded`]) instead of
    /// stalling this deadline.
    pub fn recv_timeout(self, timeout: Duration) -> Result<Reply> {
        self.wait(Some(Instant::now() + timeout))
    }

    fn wait(mut self, deadline: Option<Instant>) -> Result<Reply> {
        loop {
            let received = match deadline {
                None => self.rx.recv().map_err(|_| None),
                Some(d) => {
                    let left = d.saturating_duration_since(Instant::now());
                    self.rx.recv_timeout(left).map_err(|e| match e {
                        std::sync::mpsc::RecvTimeoutError::Timeout => Some(()),
                        std::sync::mpsc::RecvTimeoutError::Disconnected => None,
                    })
                }
            };
            match received {
                Ok(Ok(reply)) => return Ok(reply),
                Ok(Err(e)) if is_shard_down(&e) => {
                    // The shard accepted and then died under the request.
                    self.handle.mark_dead(self.shard);
                    if self.resubmits_left == 0 {
                        return Err(self.terminal(e));
                    }
                    self.resubmits_left -= 1;
                    let (rx, shard, nonce) =
                        match self.handle.submit_payload(self.payload.clone(), self.qos, self.nonce)
                        {
                            Ok(v) => v,
                            // Resubmission found no live shard at all — the
                            // other terminal disposition of a retained payload.
                            Err(e) if is_shard_down(&e) => return Err(self.terminal(e)),
                            Err(e) => return Err(e),
                        };
                    self.handle
                        .inner
                        .lifecycle
                        .resubmits
                        .fetch_add(1, Ordering::Relaxed);
                    self.rx = rx;
                    self.shard = shard;
                    if self.nonce.is_none() {
                        self.nonce = nonce;
                    }
                }
                Ok(Err(e @ Error::Overloaded(_))) if !self.overload_retried => {
                    // A remote peer accepted the frame, then its own
                    // admission shed the request. Busy, not dead: the shard
                    // stays in rotation, and the retained payload earns
                    // exactly one bounded retry (the fleet routes it around
                    // shedding shards); a second shed is terminal.
                    self.overload_retried = true;
                    let (rx, shard, nonce) =
                        match self.handle.submit_payload(self.payload.clone(), self.qos, self.nonce)
                        {
                            Ok(v) => v,
                            // Retry found no capacity either — surface the
                            // original typed overload, not the probe error.
                            Err(_) => return Err(e),
                        };
                    self.rx = rx;
                    self.shard = shard;
                    if self.nonce.is_none() {
                        self.nonce = nonce;
                    }
                }
                Ok(Err(e)) => return Err(e),
                Err(Some(())) => {
                    return Err(Error::Coordinator(format!(
                        "request {} timed out awaiting its reply",
                        self.request_id
                    )))
                }
                Err(None) => {
                    return Err(Error::Coordinator(
                        "response dropped (worker crashed mid-request?)".into(),
                    ))
                }
            }
        }
    }

    /// Record this logical request's terminal shard-down disposition —
    /// called exactly once per [`RetryingSlot`], on the single `return`
    /// that ends it, so resubmit-then-fail cannot double-count.
    fn terminal(&self, e: Error) -> Error {
        self.handle.inner.lifecycle.terminal_failures.fetch_add(1, Ordering::Relaxed);
        e
    }
}

/// The running fleet: N coordinators behind one [`FleetHandle`], plus (when
/// [`FleetConfig::autoscale`] is set) a janitor thread that revives dead
/// shards and scales under pressure. Dropping it shuts every shard down.
pub struct Fleet {
    handle: FleetHandle,
    janitor: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

impl Fleet {
    /// Start every shard (workers warm per [`CoordinatorConfig::warmup`])
    /// and wire the router. Fails fast if any shard fails to start —
    /// already-started shards shut down via drop.
    pub fn start(cfg: FleetConfig) -> Result<Self> {
        if cfg.shards.is_empty() && cfg.remotes.is_empty() {
            return Err(Error::Config("fleet needs at least one shard".into()));
        }
        let total = cfg.shards.len() + cfg.remotes.len();
        if let RoutePolicy::Weighted(w) = &cfg.policy {
            if w.len() != total {
                return Err(Error::Config(format!(
                    "weighted policy has {} weights for {} shards ({} local + {} remote)",
                    w.len(),
                    total,
                    cfg.shards.len(),
                    cfg.remotes.len()
                )));
            }
            if w.iter().all(|&x| x == 0) {
                return Err(Error::Config("weighted policy needs a nonzero weight".into()));
            }
        }
        let mut slots = Vec::with_capacity(total);
        for (i, shard_cfg) in cfg.shards.iter().enumerate() {
            let label = cfg
                .labels
                .get(i)
                .cloned()
                .unwrap_or_else(|| format!("shard{}:{}", i, shard_cfg.backend.label()));
            slots.push(ShardSlot::new(label, Coordinator::start(shard_cfg.clone())?));
        }
        // Remote slots follow the local ones; a refused dial fails the whole
        // start (already-started local shards shut down via drop).
        for (j, remote) in cfg.remotes.iter().enumerate() {
            let i = cfg.shards.len() + j;
            let label = remote
                .label
                .clone()
                .or_else(|| cfg.labels.get(i).cloned())
                .unwrap_or_else(|| format!("remote{j}@{}", remote.addr));
            let shard = RemoteShard::connect(&remote.addr, &label, remote.net.clone())?;
            slots.push(ShardSlot::remote(label, shard));
        }
        let initial = total;
        let spawn_template = cfg.shards.first().cloned();
        let handle = FleetHandle {
            inner: Arc::new(FleetInner {
                slots: RwLock::new(slots),
                policy: cfg.policy,
                cursor: AtomicUsize::new(0),
                next_request_id: AtomicU64::new(0),
                lifecycle: FleetLifecycle::default(),
                autoscale: cfg.autoscale.clone(),
                spawn_template,
            }),
        };

        let stop = Arc::new(AtomicBool::new(false));
        let janitor = match &cfg.autoscale {
            Some(a) if a.revive || a.max_shards > initial => {
                let h = handle.clone();
                let stop = stop.clone();
                let a = a.clone();
                Some(
                    std::thread::Builder::new()
                        .name("spoga-fleet-janitor".into())
                        .spawn(move || run_janitor(h, a, stop))
                        .map_err(|e| Error::Coordinator(format!("spawn janitor: {e}")))?,
                )
            }
            _ => None,
        };
        Ok(Fleet { handle, janitor, stop })
    }

    /// Convenience: the historical single-coordinator serving path as a
    /// 1-shard fleet.
    pub fn single(shard: CoordinatorConfig) -> Result<Self> {
        Self::start(FleetConfig::single(shard))
    }

    /// A cloneable fleet handle.
    pub fn handle(&self) -> FleetHandle {
        self.handle.clone()
    }

    /// Number of shards (initial + dynamically spawned).
    pub fn shard_count(&self) -> usize {
        self.handle.shard_count()
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.janitor.take() {
            let _ = j.join();
        }
        for slot in self.handle.slots() {
            slot.shutdown_link();
        }
    }

    /// Graceful shutdown: stop the janitor, then drain and join every
    /// shard (including shards spawned by autoscaling).
    pub fn shutdown(mut self) {
        self.halt();
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Janitor loop: on each cadence tick, revive dead shards (when the policy
/// says so) and apply the pressure-based scale-up check. Sleeps in slices
/// no longer than 50 ms (or the interval itself, if shorter) so
/// `Fleet::shutdown` joins promptly without the thread busy-waking at long
/// cadences.
fn run_janitor(handle: FleetHandle, policy: FleetAutoscale, stop: Arc<AtomicBool>) {
    let interval = Duration::from_secs_f64(policy.interval_s.max(0.001));
    let slice = interval.min(Duration::from_millis(50));
    let mut since_tick = Duration::ZERO;
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(slice);
        since_tick += slice;
        if since_tick < interval {
            continue;
        }
        since_tick = Duration::ZERO;
        if policy.revive {
            let _ = handle.revive_dead_shards();
        }
        let _ = handle.maybe_scale_up();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("spoga-router-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "mlp_b1 m i32:1x16 i32:1x4\n").unwrap();
        dir
    }

    fn two_shard_handle(tag: &str, policy: RoutePolicy) -> (FleetHandle, Fleet) {
        let dir = synthetic_dir(tag);
        let cfg = CoordinatorConfig {
            artifact_dir: dir.to_string_lossy().into_owned(),
            workers: 1,
            max_batch_wait_s: 0.0,
            ..Default::default()
        };
        let fleet = Fleet::start(FleetConfig {
            shards: vec![cfg.clone(), cfg],
            policy,
            labels: vec!["a".into(), "b".into()],
            ..Default::default()
        })
        .unwrap();
        (fleet.handle(), fleet)
    }

    #[test]
    fn weighted_policy_splits_exactly_over_a_period() {
        let (h, fleet) = two_shard_handle("weighted", RoutePolicy::Weighted(vec![1, 3]));
        let live = h.live();
        let mut counts = [0usize; 2];
        for _ in 0..8 {
            counts[h.pick(&live)] += 1;
        }
        assert_eq!(counts, [2, 6], "1:3 split over two periods");
        fleet.shutdown();
    }

    #[test]
    fn least_queue_depth_prefers_the_idle_shard() {
        let (h, fleet) = two_shard_handle("lqd", RoutePolicy::LeastQueueDepth);
        // Fake a backlog on shard 0 (requests accepted, never resolved).
        h.shard_stats(0).requests.fetch_add(50, Ordering::Relaxed);
        let live = h.live();
        for _ in 0..4 {
            assert_eq!(h.pick(&live), 1);
        }
        fleet.shutdown();
    }

    #[test]
    fn dead_shards_leave_the_rotation() {
        let (h, fleet) = two_shard_handle("dead", RoutePolicy::RoundRobin);
        assert_eq!(h.live_shard_count(), 2);
        h.mark_dead(0);
        assert_eq!(h.live_shard_count(), 1);
        let live = h.live();
        for _ in 0..4 {
            assert_eq!(h.pick(&live), 1);
        }
        fleet.shutdown();
    }

    #[test]
    fn shard_down_classifier_spares_request_errors() {
        assert!(is_shard_down(&Error::ShardDown("no live workers (all dead)".into())));
        assert!(is_shard_down(&Error::ShardDown("coordinator stopped".into())));
        assert!(is_shard_down(&Error::ShardDown("shutdown".into())));
        // Request-level errors never retire a shard — even when their
        // caller-controlled text mentions shutdown-ish words.
        assert!(!is_shard_down(&Error::Coordinator("worker 0 execute failed: boom".into())));
        assert!(!is_shard_down(&Error::Coordinator(
            "artifact error: unknown artifact \"gemm_shutdown_probe\"".into()
        )));
        assert!(!is_shard_down(&Error::Shape("mlp row has 3 elements".into())));
        assert!(!is_shard_down(&Error::Artifact("unknown artifact".into())));
        // QoS refusals are busy-not-dead: a shedding shard is alive and
        // draining, and an expired deadline was the caller's budget — a
        // failover (worse: a failover storm of retained payloads) on
        // either would amplify overload into capacity collapse.
        assert!(!is_shard_down(&Error::Overloaded("ingress queue full (64 slots)".into())));
        assert!(!is_shard_down(&Error::DeadlineExceeded("queued 12.0 ms".into())));
        // Remote kinds follow retires_shard(): truly-unreachable peers
        // fail over, one bad exchange with a live peer does not.
        use crate::error::RemoteErrorKind as K;
        let remote = |kind| Error::Remote { kind, detail: "peer".into() };
        assert!(is_shard_down(&remote(K::ConnRefused)));
        assert!(is_shard_down(&remote(K::PeerGone)));
        assert!(!is_shard_down(&remote(K::Timeout)));
        assert!(!is_shard_down(&remote(K::FrameCorrupt)));
        assert!(!is_shard_down(&remote(K::VersionMismatch)));
    }

    #[test]
    fn overloaded_shard_is_routed_around_not_retired() {
        // Shard 0 sheds every best-effort submission (watermark 0); shard 1
        // accepts. Overload must route around without retiring shard 0 and
        // without counting a submit reroute (that counter means "a down
        // shard pushed traffic away").
        let dir = synthetic_dir("overload-route");
        let mut shed_cfg = CoordinatorConfig {
            artifact_dir: dir.to_string_lossy().into_owned(),
            workers: 1,
            max_batch_wait_s: 0.0,
            ..Default::default()
        };
        let open_cfg = shed_cfg.clone();
        shed_cfg.best_effort_watermark = Some(0);
        let fleet = Fleet::start(FleetConfig {
            shards: vec![shed_cfg.clone(), open_cfg],
            policy: RoutePolicy::RoundRobin,
            labels: vec!["shedder".into(), "open".into()],
            ..Default::default()
        })
        .unwrap();
        let h = fleet.handle();
        // Round-robin from cursor 0: the first pick is the shedding shard.
        let rx = h.submit_mlp_qos(vec![0; 16], Qos::best_effort()).unwrap();
        assert!(rx.recv().unwrap().is_ok(), "rerouted submission must serve");
        assert_eq!(h.live_shard_count(), 2, "shedding shard stays in rotation");
        assert_eq!(h.lifecycle().submit_reroutes.load(Ordering::Relaxed), 0);
        assert!(h.shard_stats(0).shed.load(Ordering::Relaxed) >= 1);

        // With every shard shedding, the typed overload surfaces (and
        // still retires nothing).
        let all_full = FleetConfig {
            shards: vec![shed_cfg.clone(), shed_cfg],
            policy: RoutePolicy::RoundRobin,
            ..Default::default()
        };
        let saturated = Fleet::start(all_full).unwrap();
        let sh = saturated.handle();
        match sh.submit_mlp_qos(vec![0; 16], Qos::best_effort()) {
            Err(Error::Overloaded(msg)) => assert!(msg.contains("watermark"), "{msg}"),
            other => panic!("expected typed Overloaded, got {other:?}"),
        }
        assert_eq!(sh.live_shard_count(), 2);
        saturated.shutdown();
        fleet.shutdown();
    }

    #[test]
    fn poisoned_slot_lock_yields_typed_errors_not_panics() {
        let (h, fleet) = two_shard_handle("poison", RoutePolicy::RoundRobin);
        // Poison the slot-table lock the way a panicking spawner would.
        let inner = h.inner.clone();
        let _ = std::thread::spawn(move || {
            let _guard = inner.slots.write().unwrap();
            panic!("spawner panicked mid-append");
        })
        .join();
        // Serving paths surface a typed Coordinator error...
        match h.submit_mlp(vec![0; 16]) {
            Err(Error::Coordinator(msg)) => assert!(msg.contains("poisoned"), "{msg}"),
            other => panic!("expected poisoned-lock Coordinator error, got {other:?}"),
        }
        // ...while ops/telemetry reads recover and keep working.
        assert_eq!(h.shard_count(), 2);
        assert_eq!(h.telemetry().shards.len(), 2);
        fleet.shutdown();
    }

    #[test]
    fn fleet_config_validation() {
        assert!(Fleet::start(FleetConfig::default()).is_err(), "no shards at all");
        let shard = CoordinatorConfig::default();
        assert!(Fleet::start(FleetConfig {
            shards: vec![shard.clone(), shard.clone()],
            policy: RoutePolicy::Weighted(vec![1]),
            ..Default::default()
        })
        .is_err());
        assert!(Fleet::start(FleetConfig {
            shards: vec![shard.clone(), shard.clone()],
            policy: RoutePolicy::Weighted(vec![0, 0]),
            ..Default::default()
        })
        .is_err());
        // A weighted fleet mixing transports needs one weight per slot,
        // local + remote.
        assert!(Fleet::start(FleetConfig {
            shards: vec![shard],
            policy: RoutePolicy::Weighted(vec![1]),
            remotes: vec![RemoteShardConfig::new("127.0.0.1:1")],
            ..Default::default()
        })
        .is_err());
    }

    #[test]
    fn noise_grid_parse_accepts_axis_prefixed_lists() {
        let g = NoiseSweepGrid::parse("K=74,160,adc=6,8").unwrap();
        assert_eq!(g.ks, vec![74, 160]);
        assert_eq!(g.adc_bits, vec![6, 8]);
        assert_eq!(g.margin_db, NoiseSweepGrid::DEFAULT_MARGIN_DB);
        assert_eq!(g.cells(), vec![(74, 6), (74, 8), (160, 6), (160, 8)]);

        let m = NoiseSweepGrid::parse("k=16,adc=4,margin=55.5").unwrap();
        assert_eq!((m.ks.clone(), m.adc_bits.clone()), (vec![16], vec![4]));
        assert!((m.margin_db - 55.5).abs() < 1e-12);

        // Malformed specs fail loudly instead of silently reshaping the
        // study — including duplicate axis values and repeated margins.
        for bad in [
            "", "64,128", "K=,adc=4", "K=0,adc=4", "K=64", "adc=8",
            "K=64,adc=0", "K=64,adc=17", "K=64,adc=8,margin=-3", "K=x,adc=4",
            "K=74,74,adc=4", "K=74,adc=4,4", "K=74,adc=4,margin=30,60",
            "K=74,adc=4,margin=30,margin=60",
        ] {
            assert!(NoiseSweepGrid::parse(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn noise_grid_builds_one_noisy_shard_per_cell() {
        let grid = NoiseSweepGrid::parse("K=74,249,adc=6,12").unwrap();
        let cfg = FleetConfig::noise_grid(CoordinatorConfig::default(), &grid);
        assert_eq!(cfg.shards.len(), 4);
        assert_eq!(cfg.labels, vec!["K74/adc6", "K74/adc12", "K249/adc6", "K249/adc12"]);
        for (i, ((k, bits), s)) in grid.cells().into_iter().zip(&cfg.shards).enumerate() {
            match &s.backend {
                BackendKind::Photonic(p) => {
                    let noise = p.noise.expect("grid shard injects noise");
                    assert_eq!(noise.adc_bits, Some(bits), "cell {i}");
                    assert!(
                        (noise.snr_db - (24.1 + NoiseSweepGrid::DEFAULT_MARGIN_DB)).abs() < 1e-9
                    );
                    // Seeds keyed by K only: the Gaussian stage of cells
                    // that differ only in ADC bits draws identically.
                    assert_eq!(p.noise_seed, 0xADC0_5EED ^ ((k as u64) << 16));
                }
                other => panic!("grid shard {i} is not photonic: {other:?}"),
            }
        }
    }

    #[test]
    fn noise_grid_drive_rejects_mismatched_fleets() {
        let (h, fleet) = two_shard_handle("gridmismatch", RoutePolicy::RoundRobin);
        let grid = NoiseSweepGrid::paper_range(); // 9 cells vs 2 shards
        assert!(grid.drive(&h, 1).is_err());
        fleet.shutdown();
    }

    #[test]
    fn noise_sweep_builds_one_photonic_shard_per_margin() {
        let cfg = FleetConfig::noise_sweep(CoordinatorConfig::default(), &[0.0, 20.0, 40.0]);
        assert_eq!(cfg.shards.len(), 3);
        assert_eq!(cfg.labels, vec!["margin+0dB", "margin+20dB", "margin+40dB"]);
        for (i, s) in cfg.shards.iter().enumerate() {
            match &s.backend {
                BackendKind::Photonic(p) => {
                    let noise = p.noise.expect("sweep shard injects noise");
                    let margin = [0.0, 20.0, 40.0][i];
                    assert!((noise.snr_db - (24.1 + margin)).abs() < 1e-9);
                }
                other => panic!("sweep shard {i} is not photonic: {other:?}"),
            }
        }
        // Distinct deterministic noise streams per shard.
        let seeds: Vec<u64> = cfg
            .shards
            .iter()
            .map(|s| match &s.backend {
                BackendKind::Photonic(p) => p.noise_seed,
                _ => unreachable!(),
            })
            .collect();
        assert_ne!(seeds[0], seeds[1]);
        assert_ne!(seeds[1], seeds[2]);
    }
}
