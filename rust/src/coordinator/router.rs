//! Fleet layer: a shard router over N coordinators.
//!
//! One process, many [`Coordinator`]s — each shard owns its own worker
//! pool, batcher and [`BackendKind`](crate::runtime::BackendKind), so a
//! fleet can mix photonic design points (SPOGA vs HOLYLIGHT vs DEAPCNN vs
//! the software interpreter) behind a single cloneable [`FleetHandle`] and
//! A/B them under identical live traffic — the fleet-level apparatus behind
//! the paper's headline numbers (many tiles serving inference concurrently,
//! not one engine).
//!
//! ## Routing
//!
//! [`RoutePolicy`] picks the shard per request:
//!
//! * [`RoutePolicy::RoundRobin`] — uniform rotation over live shards.
//! * [`RoutePolicy::LeastQueueDepth`] — the live shard with the fewest
//!   unresolved requests ([`CoordinatorStats::queue_depth`]).
//! * [`RoutePolicy::Weighted`] — deterministic proportional split (e.g.
//!   `software:photonic = 1:3` for a photonic-design experiment); over any
//!   `sum(weights)` consecutive picks the split is exact.
//!
//! ## Failover
//!
//! A shard whose worker pool died answers every job with a "no live
//! workers" error (and a stopped shard rejects submission). The handle
//! recognizes those as *shard-down* signals, marks the shard dead, and
//! retries the request on the next live shard — requests only fail once no
//! shards remain. Reply slots always resolve either way: the shard's
//! leader fails its queued jobs explicitly, never silently.
//!
//! ## Telemetry
//!
//! [`FleetHandle::telemetry`] snapshots every shard's
//! [`CoordinatorStats`] into a [`FleetTelemetry`] rollup — fleet-wide
//! sim-FPS / FPS-per-watt / noise events, each request counted exactly once
//! on the shard that served it.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::coordinator::request::{Reply, Response};
use crate::coordinator::service::{Coordinator, CoordinatorConfig, CoordinatorHandle};
use crate::coordinator::stats::CoordinatorStats;
use crate::dnn::models::CnnModel;
use crate::fidelity::NoiseParams;
use crate::metrics::{FleetTelemetry, ShardTelemetry};
use crate::runtime::backend::BackendKind;
use crate::runtime::photonic::PhotonicConfig;
use crate::{Error, Result};

/// How the fleet picks the shard that serves the next request.
#[derive(Debug, Clone, Default)]
pub enum RoutePolicy {
    /// Uniform rotation over live shards.
    #[default]
    RoundRobin,
    /// The live shard with the fewest unresolved requests.
    LeastQueueDepth,
    /// Deterministic proportional split: shard `i` receives
    /// `weights[i] / sum(weights)` of the traffic (dead shards drop out and
    /// the remainder re-normalizes). One weight per shard.
    Weighted(Vec<u32>),
}

/// Fleet configuration: one [`CoordinatorConfig`] per shard plus the
/// routing policy.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Per-shard coordinator configurations (possibly heterogeneous
    /// backends — that is the point).
    pub shards: Vec<CoordinatorConfig>,
    /// Shard selection policy.
    pub policy: RoutePolicy,
    /// Optional display labels, one per shard; missing entries fall back to
    /// `shard<i>:<backend label>`.
    pub labels: Vec<String>,
}

impl FleetConfig {
    /// A single-shard fleet — the compatibility spelling of the historical
    /// one-coordinator serving path.
    pub fn single(shard: CoordinatorConfig) -> Self {
        FleetConfig { shards: vec![shard], policy: RoutePolicy::RoundRobin, labels: Vec::new() }
    }

    /// `n` identical shards behind round-robin (horizontal scaling).
    pub fn replicated(shard: CoordinatorConfig, n: usize) -> Self {
        FleetConfig {
            shards: vec![shard; n.max(1)],
            policy: RoutePolicy::RoundRobin,
            labels: Vec::new(),
        }
    }

    /// Weighted two-shard A/B split — the photonic-design-experiment
    /// shape: identical artifacts, different backends, traffic split
    /// `wa:wb`.
    pub fn ab_split(a: CoordinatorConfig, b: CoordinatorConfig, wa: u32, wb: u32) -> Self {
        FleetConfig {
            shards: vec![a, b],
            policy: RoutePolicy::Weighted(vec![wa, wb]),
            labels: Vec::new(),
        }
    }

    /// Noise-aware serving sweep: one photonic shard per link margin, each
    /// injecting analog noise at that margin with its own deterministic
    /// stream. `base`'s backend supplies the design point (non-photonic
    /// bases sweep SPOGA_10). Drive identical traffic at every shard via
    /// [`FleetHandle::shard`] and read served-accuracy vs sim-FPS/W off
    /// [`FleetHandle::telemetry`] — the serving-path slice of the offline
    /// fidelity study.
    pub fn noise_sweep(base: CoordinatorConfig, margins_db: &[f64]) -> Self {
        let pc = match &base.backend {
            BackendKind::Photonic(p) => p.clone(),
            _ => PhotonicConfig::spoga(),
        };
        let mut shards = Vec::with_capacity(margins_db.len());
        let mut labels = Vec::with_capacity(margins_db.len());
        for (i, &margin) in margins_db.iter().enumerate() {
            let mut cfg = base.clone();
            cfg.backend = BackendKind::Photonic(pc.clone().with_noise(
                NoiseParams::from_link_margin(margin),
                0x5EED_F1EE + ((i as u64) << 16),
            ));
            shards.push(cfg);
            labels.push(format!("margin+{margin:.0}dB"));
        }
        FleetConfig { shards, policy: RoutePolicy::RoundRobin, labels }
    }

    /// Noise-aware serving *grid*: one noise-injecting photonic shard per
    /// [`NoiseSweepGrid`] cell (K × ADC bits, shared link margin), labelled
    /// `K{k}/adc{bits}`. `base`'s backend supplies the design point
    /// (non-photonic bases study SPOGA_10). Shards share the same base
    /// noise seed per K — the Gaussian stage of two cells that differ only
    /// in ADC resolution then draws identically on identical traffic, so
    /// the ADC axis of the trade table isolates quantization.
    ///
    /// Drive each cell's K-shaped traffic with [`NoiseSweepGrid::drive`]
    /// (or [`NoiseSweepGrid::drive_cell`]) and read the served-accuracy vs
    /// sim-FPS/W frontier off [`FleetHandle::telemetry`] — the full trade
    /// *curves* the ROADMAP's noise-aware study calls for, where
    /// [`FleetConfig::noise_sweep`] covers only the link-margin axis.
    pub fn noise_grid(base: CoordinatorConfig, grid: &NoiseSweepGrid) -> Self {
        let pc = match &base.backend {
            BackendKind::Photonic(p) => p.clone(),
            _ => PhotonicConfig::spoga(),
        };
        let cells = grid.cells();
        let mut shards = Vec::with_capacity(cells.len());
        let mut labels = Vec::with_capacity(cells.len());
        for (k, bits) in cells {
            let mut cfg = base.clone();
            cfg.backend = BackendKind::Photonic(pc.clone().with_noise(
                NoiseParams::from_link_margin(grid.margin_db).with_adc(bits),
                0xADC0_5EED ^ ((k as u64) << 16),
            ));
            shards.push(cfg);
            labels.push(format!("K{k}/adc{bits}"));
        }
        FleetConfig { shards, policy: RoutePolicy::RoundRobin, labels }
    }
}

/// The K × ADC-bits noise-study grid (PAPER §IV–V: link margin vs spatial
/// parallelism K and ADC resolution, here on the *serving* path).
///
/// Each cell `(k, adc_bits)` names one noise-injecting photonic shard of a
/// [`FleetConfig::noise_grid`] fleet; the cell's probe traffic is K-length
/// dot products (a single-FC CNN layer, so frames exercise the t-stacked
/// batching path that per-row noise attribution keeps exact under noise).
/// Reading served-exact fraction against projected sim-FPS/W across the
/// cells yields the accuracy-vs-efficiency frontier that HOLYLIGHT and
/// DEAP-CNN report only at fixed design points.
#[derive(Debug, Clone, PartialEq)]
pub struct NoiseSweepGrid {
    /// GEMM reduction lengths — the paper's spatial-parallelism axis K.
    pub ks: Vec<usize>,
    /// PWAB ADC resolutions, bits.
    pub adc_bits: Vec<u32>,
    /// Link margin above the 4-bit receiver sensitivity floor shared by
    /// every cell, dB.
    pub margin_db: f64,
}

impl NoiseSweepGrid {
    /// Link margin the grid defaults to: high enough that receiver noise
    /// does not drown the ADC axis, low enough that it still moves the K
    /// axis.
    pub const DEFAULT_MARGIN_DB: f64 = 40.0;

    /// Outputs per probe dot-product row (the `c` of the `1×K×c` probe
    /// GEMM each frame executes).
    pub const PROBE_OUTPUTS: usize = 8;

    /// The paper's spatial-parallelism range crossed with ADC resolutions
    /// around the design point: Table I solves the MWA rows to N = 74
    /// (5 dBm @ 10 GS/s), 160 (10 dBm @ 10 GS/s) and 249 (10 dBm @ 1 GS/s)
    /// — the K range over which the paper argues byte-size integer GEMM
    /// survives — × {4, 6, 8}-bit PWAB ADCs.
    pub fn paper_range() -> Self {
        NoiseSweepGrid {
            ks: vec![74, 160, 249],
            adc_bits: vec![4, 6, 8],
            margin_db: Self::DEFAULT_MARGIN_DB,
        }
    }

    /// Parse a grid spec such as `K=74,160,adc=6,8` (optionally with a
    /// trailing `margin=40`): comma-separated tokens where `K=` / `adc=` /
    /// `margin=` prefixes switch which list subsequent bare numbers extend.
    pub fn parse(spec: &str) -> Result<Self> {
        #[derive(Clone, Copy, PartialEq)]
        enum Axis {
            K,
            Adc,
            Margin,
        }
        let bad = |msg: String| Error::Config(format!("noise grid {spec:?}: {msg}"));
        let mut grid = NoiseSweepGrid {
            ks: Vec::new(),
            adc_bits: Vec::new(),
            margin_db: Self::DEFAULT_MARGIN_DB,
        };
        let mut axis: Option<Axis> = None;
        let mut margin_set = false;
        for raw in spec.split(',') {
            let tok = raw.trim();
            if tok.is_empty() {
                continue;
            }
            let value = if let Some(v) = tok.strip_prefix("K=").or_else(|| tok.strip_prefix("k=")) {
                axis = Some(Axis::K);
                v
            } else if let Some(v) = tok.strip_prefix("adc=") {
                axis = Some(Axis::Adc);
                v
            } else if let Some(v) = tok.strip_prefix("margin=") {
                axis = Some(Axis::Margin);
                v
            } else {
                tok
            };
            match axis {
                None => return Err(bad(format!("token {tok:?} before any K=/adc= prefix"))),
                Some(Axis::K) => {
                    let k = value
                        .parse::<usize>()
                        .ok()
                        .filter(|&k| k >= 1)
                        .ok_or_else(|| bad(format!("bad K value {value:?}")))?;
                    if grid.ks.contains(&k) {
                        return Err(bad(format!("duplicate K value {k}")));
                    }
                    grid.ks.push(k);
                }
                Some(Axis::Adc) => {
                    let bits = value
                        .parse::<u32>()
                        .ok()
                        .filter(|&b| (1..=16).contains(&b))
                        .ok_or_else(|| bad(format!("bad adc bits {value:?} (want 1..=16)")))?;
                    if grid.adc_bits.contains(&bits) {
                        return Err(bad(format!("duplicate adc value {bits}")));
                    }
                    grid.adc_bits.push(bits);
                }
                Some(Axis::Margin) => {
                    if margin_set {
                        return Err(bad(format!(
                            "margin given more than once (second value {value:?})"
                        )));
                    }
                    margin_set = true;
                    grid.margin_db = value
                        .parse::<f64>()
                        .ok()
                        .filter(|m| m.is_finite() && *m >= 0.0)
                        .ok_or_else(|| bad(format!("bad margin {value:?}")))?;
                }
            }
        }
        if grid.ks.is_empty() || grid.adc_bits.is_empty() {
            return Err(bad("need at least one K and one adc value".into()));
        }
        Ok(grid)
    }

    /// Grid cells `(k, adc_bits)` in fleet-shard order (K-major), matching
    /// [`FleetConfig::noise_grid`]'s shard layout.
    pub fn cells(&self) -> Vec<(usize, u32)> {
        let mut cells = Vec::with_capacity(self.ks.len() * self.adc_bits.len());
        for &k in &self.ks {
            for &bits in &self.adc_bits {
                cells.push((k, bits));
            }
        }
        cells
    }

    /// Drive `frames` probe CNN frames (each a `1×K×PROBE_OUTPUTS` GEMM
    /// through a single-FC model, deterministic per-K inputs) at cell
    /// `cell`'s shard, slot-based so same-model frames stack in the
    /// batching window — exercising t-stacked CNN serving under noise.
    /// Returns the number of replies served.
    pub fn drive_cell(&self, handle: &FleetHandle, cell: usize, frames: usize) -> Result<usize> {
        let cells = self.cells();
        if handle.shard_count() != cells.len() {
            return Err(Error::Config(format!(
                "fleet has {} shards but the grid has {} cells — build it with \
                 FleetConfig::noise_grid over this grid",
                handle.shard_count(),
                cells.len()
            )));
        }
        let (k, _bits) = cells[cell];
        let model = CnnModel {
            name: "noise_grid_probe",
            layers: vec![crate::dnn::Layer::fc("dot", k, Self::PROBE_OUTPUTS)],
        };
        let shard = handle.shard(cell);
        let mut rng = crate::testing::SplitMix64::new(0x6_B1D ^ ((k as u64) << 20));
        let slots: Vec<Response> = (0..frames)
            .map(|_| {
                let input: Vec<i32> = (0..k).map(|_| rng.i8() as i32).collect();
                shard.submit_cnn(model.clone(), input)
            })
            .collect::<Result<_>>()?;
        let mut served = 0usize;
        for rx in slots {
            rx.recv()
                .map_err(|_| Error::Coordinator("noise-grid reply slot dropped".into()))??;
            served += 1;
        }
        Ok(served)
    }

    /// Drive every cell's probe traffic ([`NoiseSweepGrid::drive_cell`]) in
    /// shard order; returns total replies served (`frames × cells`).
    pub fn drive(&self, handle: &FleetHandle, frames: usize) -> Result<usize> {
        let mut served = 0;
        for cell in 0..self.cells().len() {
            served += self.drive_cell(handle, cell, frames)?;
        }
        Ok(served)
    }

    /// Render the frontier readout for a fleet built over this grid: one
    /// row per cell — stacked-batch count, lanes, noise events,
    /// served-exact fraction, projected sim-FPS and sim-FPS/W. Shared by
    /// `spoga serve --noise-grid` and `examples/fleet_serve.rs` so the
    /// study's table cannot drift between surfaces.
    pub fn frontier_table(&self, handle: &FleetHandle) -> crate::report::Table {
        let telemetry = handle.telemetry();
        let mut table = crate::report::Table::new(vec![
            "cell",
            "cnn stacks",
            "lanes",
            "noise events",
            "served-exact",
            "sim FPS",
            "sim FPS/W",
        ]);
        for (i, shard) in telemetry.shards.iter().enumerate() {
            table.row(vec![
                shard.label.clone(),
                handle.shard_stats(i).cnn_batches.load(Ordering::Relaxed).to_string(),
                shard.lanes.to_string(),
                shard.noise_events.to_string(),
                format!("{:.6}", shard.served_exact_fraction()),
                crate::report::fmt_sig(shard.sim_fps(), 3),
                crate::report::fmt_sig(shard.sim_fps_per_w(), 3),
            ]);
        }
        table
    }
}

struct ShardSlot {
    label: String,
    handle: CoordinatorHandle,
    dead: AtomicBool,
}

struct FleetInner {
    slots: Vec<ShardSlot>,
    policy: RoutePolicy,
    /// Routing cursor: round-robin rotation / weighted tick counter.
    cursor: AtomicUsize,
}

/// Cloneable client handle over the whole fleet: routes each request to a
/// shard per the policy, fails over when shards die, and rolls per-shard
/// stats up into fleet telemetry.
#[derive(Clone)]
pub struct FleetHandle {
    inner: Arc<FleetInner>,
}

/// Does this error mean the shard (not the request) is broken? Only the
/// typed [`Error::ShardDown`] variant counts — worker-pool death, a stopped
/// coordinator and shutdown drains construct it. Request-level errors
/// (shape, artifact, execute failures — and a dropped reply slot, which
/// means a worker crashed *on this request* and must not send a possibly
/// poisonous payload marching across every shard) carry other variants and
/// never burn a failover.
fn is_shard_down(e: &Error) -> bool {
    matches!(e, Error::ShardDown(_))
}

impl FleetHandle {
    /// Shards still worth routing to: not marked dead AND with a live
    /// worker pool. The second check matters for slot-based traffic — a
    /// shard whose leader fast-fails every job keeps a near-zero queue
    /// depth and would otherwise *attract* least-queue-depth routing
    /// without ever tripping the dead flag.
    fn live(&self) -> Vec<usize> {
        self.inner
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                !s.dead.load(Ordering::Relaxed)
                    && s.handle.stats().live_workers.load(Ordering::Relaxed) > 0
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// Pick one of the `live` shard indices (non-empty) per the policy.
    fn pick(&self, live: &[usize]) -> usize {
        match &self.inner.policy {
            RoutePolicy::RoundRobin => {
                live[self.inner.cursor.fetch_add(1, Ordering::Relaxed) % live.len()]
            }
            RoutePolicy::LeastQueueDepth => {
                // Snapshot depths once (they move under us), then rotate
                // among the minima so an all-idle fleet still balances
                // instead of pinning shard 0.
                let depths: Vec<(usize, u64)> = live
                    .iter()
                    .map(|&i| (i, self.inner.slots[i].handle.stats().queue_depth()))
                    .collect();
                let min = depths.iter().map(|&(_, d)| d).min().expect("non-empty live set");
                let ties: Vec<usize> =
                    depths.iter().filter(|&&(_, d)| d == min).map(|&(i, _)| i).collect();
                ties[self.inner.cursor.fetch_add(1, Ordering::Relaxed) % ties.len()]
            }
            RoutePolicy::Weighted(weights) => {
                let total: u64 =
                    live.iter().map(|&i| u64::from(*weights.get(i).unwrap_or(&0))).sum();
                if total == 0 {
                    // All live weights zero: degrade to round-robin rather
                    // than starve the fleet.
                    return live[self.inner.cursor.fetch_add(1, Ordering::Relaxed) % live.len()];
                }
                let mut tick =
                    (self.inner.cursor.fetch_add(1, Ordering::Relaxed) as u64) % total;
                for &i in live {
                    let w = u64::from(*weights.get(i).unwrap_or(&0));
                    if tick < w {
                        return i;
                    }
                    tick -= w;
                }
                live[live.len() - 1]
            }
        }
    }

    /// Run `op` against policy-picked shards, failing over (and marking the
    /// shard dead) on shard-down errors until a live shard answers or none
    /// remain. Request-level errors (bad shape, unknown artifact, execute
    /// failure) return immediately.
    ///
    /// The payload moves into the attempt once no other shard could take a
    /// retry and is cloned otherwise — a clone per attempt is the price of
    /// reply-time failover, because a payload consumed by a shard that then
    /// dies is unrecoverable (its leader fails the reply slot; nothing
    /// hands the buffers back).
    fn with_failover<T, P: Clone>(
        &self,
        payload: P,
        mut op: impl FnMut(&CoordinatorHandle, P) -> Result<T>,
    ) -> Result<T> {
        let mut payload = Some(payload);
        let mut last_err: Option<Error> = None;
        for _ in 0..self.inner.slots.len() {
            let live = self.live();
            if live.is_empty() {
                break;
            }
            let idx = self.pick(&live);
            let p = (if live.len() == 1 { payload.take() } else { payload.clone() })
                .expect("payload present while attempts remain");
            match op(&self.inner.slots[idx].handle, p) {
                Ok(v) => return Ok(v),
                Err(e) if is_shard_down(&e) => {
                    self.inner.slots[idx].dead.store(true, Ordering::Relaxed);
                    last_err = Some(e);
                    if payload.is_none() {
                        break;
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Err(last_err.unwrap_or_else(|| Error::ShardDown("fleet has no live shards".into())))
    }

    /// Submit a GEMM to a policy-picked shard; returns the response slot.
    /// Failover covers submission; a shard dying *after* accepting resolves
    /// the slot with an error instead (use [`FleetHandle::gemm_reply`] for
    /// full retry semantics).
    pub fn submit_gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Response> {
        self.with_failover((a, b), |h, (a, b)| h.submit_gemm(artifact, a, b))
    }

    /// Submit one MLP row to a policy-picked shard; returns the response
    /// slot.
    pub fn submit_mlp(&self, row: Vec<i32>) -> Result<Response> {
        self.with_failover(row, |h, row| h.submit_mlp(row))
    }

    /// Submit a whole-CNN inference to a policy-picked shard; returns the
    /// response slot. Same-model frames co-pending on that shard stack into
    /// one t-dimension batch.
    pub fn submit_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Response> {
        self.with_failover((model, input), |h, (model, input)| h.submit_cnn(model, input))
    }

    /// Blocking GEMM returning the full [`Reply`]; retries on another shard
    /// if the serving shard turns out to be dead.
    pub fn gemm_reply(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Reply> {
        self.with_failover((a, b), |h, (a, b)| h.gemm_reply(artifact, a, b))
    }

    /// Blocking GEMM convenience.
    pub fn gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>) -> Result<Vec<i32>> {
        Ok(self.gemm_reply(artifact, a, b)?.outputs)
    }

    /// Blocking MLP inference with shard failover.
    pub fn infer_mlp(&self, row: Vec<i32>) -> Result<Vec<i32>> {
        self.with_failover(row, |h, row| h.infer_mlp(row))
    }

    /// Blocking CNN inference (full [`Reply`]) with shard failover.
    pub fn infer_cnn(&self, model: CnnModel, input: Vec<i32>) -> Result<Reply> {
        self.with_failover((model, input), |h, (model, input)| h.infer_cnn(model, input))
    }

    /// Number of shards (live and dead).
    pub fn shard_count(&self) -> usize {
        self.inner.slots.len()
    }

    /// Number of shards still in the rotation.
    pub fn live_shard_count(&self) -> usize {
        self.live().len()
    }

    /// Per-shard display labels, shard order.
    pub fn shard_labels(&self) -> Vec<String> {
        self.inner.slots.iter().map(|s| s.label.clone()).collect()
    }

    /// Direct handle to shard `i` — for per-shard drains
    /// ([`CoordinatorHandle::retire_workers`]) and sweep harnesses that
    /// must drive identical traffic at every shard, bypassing routing.
    pub fn shard(&self, i: usize) -> &CoordinatorHandle {
        &self.inner.slots[i].handle
    }

    /// Shard `i`'s live stats.
    pub fn shard_stats(&self, i: usize) -> &CoordinatorStats {
        self.inner.slots[i].handle.stats()
    }

    /// Take shard `i` out of the rotation (ops drain; also flipped
    /// automatically when a request observes the shard down).
    pub fn mark_dead(&self, i: usize) {
        self.inner.slots[i].dead.store(true, Ordering::Relaxed);
    }

    /// Snapshot every shard's stats into the fleet rollup. Each shard's
    /// counters are read once per snapshot, so totals equal the sum of the
    /// per-shard stats with nothing double-counted.
    pub fn telemetry(&self) -> FleetTelemetry {
        FleetTelemetry::new(
            self.inner
                .slots
                .iter()
                .map(|s| ShardTelemetry::capture(&s.label, s.handle.stats()))
                .collect(),
        )
    }
}

/// The running fleet: N coordinators behind one [`FleetHandle`]. Dropping
/// it shuts every shard down.
pub struct Fleet {
    shards: Vec<Coordinator>,
    handle: FleetHandle,
}

impl Fleet {
    /// Start every shard (workers warm per [`CoordinatorConfig::warmup`])
    /// and wire the router. Fails fast if any shard fails to start —
    /// already-started shards shut down via drop.
    pub fn start(cfg: FleetConfig) -> Result<Self> {
        if cfg.shards.is_empty() {
            return Err(Error::Config("fleet needs at least one shard".into()));
        }
        if let RoutePolicy::Weighted(w) = &cfg.policy {
            if w.len() != cfg.shards.len() {
                return Err(Error::Config(format!(
                    "weighted policy has {} weights for {} shards",
                    w.len(),
                    cfg.shards.len()
                )));
            }
            if w.iter().all(|&x| x == 0) {
                return Err(Error::Config("weighted policy needs a nonzero weight".into()));
            }
        }
        let mut shards = Vec::with_capacity(cfg.shards.len());
        let mut slots = Vec::with_capacity(cfg.shards.len());
        for (i, shard_cfg) in cfg.shards.iter().enumerate() {
            let label = cfg
                .labels
                .get(i)
                .cloned()
                .unwrap_or_else(|| format!("shard{}:{}", i, shard_cfg.backend.label()));
            let c = Coordinator::start(shard_cfg.clone())?;
            slots.push(ShardSlot { label, handle: c.handle(), dead: AtomicBool::new(false) });
            shards.push(c);
        }
        let handle = FleetHandle {
            inner: Arc::new(FleetInner {
                slots,
                policy: cfg.policy,
                cursor: AtomicUsize::new(0),
            }),
        };
        Ok(Fleet { shards, handle })
    }

    /// Convenience: the historical single-coordinator serving path as a
    /// 1-shard fleet.
    pub fn single(shard: CoordinatorConfig) -> Result<Self> {
        Self::start(FleetConfig::single(shard))
    }

    /// A cloneable fleet handle.
    pub fn handle(&self) -> FleetHandle {
        self.handle.clone()
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Graceful shutdown: drain and join every shard.
    pub fn shutdown(self) {
        for c in self.shards {
            c.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(label: &str, handle: CoordinatorHandle) -> ShardSlot {
        ShardSlot { label: label.into(), handle, dead: AtomicBool::new(false) }
    }

    fn synthetic_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("spoga-router-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "mlp_b1 m i32:1x16 i32:1x4\n").unwrap();
        dir
    }

    fn two_shard_handle(tag: &str, policy: RoutePolicy) -> (FleetHandle, Vec<Coordinator>) {
        let dir = synthetic_dir(tag);
        let cfg = CoordinatorConfig {
            artifact_dir: dir.to_string_lossy().into_owned(),
            workers: 1,
            max_batch_wait_s: 0.0,
            ..Default::default()
        };
        let a = Coordinator::start(cfg.clone()).unwrap();
        let b = Coordinator::start(cfg).unwrap();
        let handle = FleetHandle {
            inner: Arc::new(FleetInner {
                slots: vec![slot("a", a.handle()), slot("b", b.handle())],
                policy,
                cursor: AtomicUsize::new(0),
            }),
        };
        (handle, vec![a, b])
    }

    #[test]
    fn weighted_policy_splits_exactly_over_a_period() {
        let (h, shards) = two_shard_handle("weighted", RoutePolicy::Weighted(vec![1, 3]));
        let live = h.live();
        let mut counts = [0usize; 2];
        for _ in 0..8 {
            counts[h.pick(&live)] += 1;
        }
        assert_eq!(counts, [2, 6], "1:3 split over two periods");
        for c in shards {
            c.shutdown();
        }
    }

    #[test]
    fn least_queue_depth_prefers_the_idle_shard() {
        let (h, shards) = two_shard_handle("lqd", RoutePolicy::LeastQueueDepth);
        // Fake a backlog on shard 0 (requests accepted, never resolved).
        h.shard_stats(0).requests.fetch_add(50, Ordering::Relaxed);
        let live = h.live();
        for _ in 0..4 {
            assert_eq!(h.pick(&live), 1);
        }
        for c in shards {
            c.shutdown();
        }
    }

    #[test]
    fn dead_shards_leave_the_rotation() {
        let (h, shards) = two_shard_handle("dead", RoutePolicy::RoundRobin);
        assert_eq!(h.live_shard_count(), 2);
        h.mark_dead(0);
        assert_eq!(h.live_shard_count(), 1);
        let live = h.live();
        for _ in 0..4 {
            assert_eq!(h.pick(&live), 1);
        }
        for c in shards {
            c.shutdown();
        }
    }

    #[test]
    fn shard_down_classifier_spares_request_errors() {
        assert!(is_shard_down(&Error::ShardDown("no live workers (all dead)".into())));
        assert!(is_shard_down(&Error::ShardDown("coordinator stopped".into())));
        assert!(is_shard_down(&Error::ShardDown("shutdown".into())));
        // Request-level errors never retire a shard — even when their
        // caller-controlled text mentions shutdown-ish words.
        assert!(!is_shard_down(&Error::Coordinator("worker 0 execute failed: boom".into())));
        assert!(!is_shard_down(&Error::Coordinator(
            "artifact error: unknown artifact \"gemm_shutdown_probe\"".into()
        )));
        assert!(!is_shard_down(&Error::Shape("mlp row has 3 elements".into())));
        assert!(!is_shard_down(&Error::Artifact("unknown artifact".into())));
    }

    #[test]
    fn fleet_config_validation() {
        assert!(Fleet::start(FleetConfig {
            shards: Vec::new(),
            policy: RoutePolicy::RoundRobin,
            labels: Vec::new(),
        })
        .is_err());
        let shard = CoordinatorConfig::default();
        assert!(Fleet::start(FleetConfig {
            shards: vec![shard.clone(), shard.clone()],
            policy: RoutePolicy::Weighted(vec![1]),
            labels: Vec::new(),
        })
        .is_err());
        assert!(Fleet::start(FleetConfig {
            shards: vec![shard.clone(), shard],
            policy: RoutePolicy::Weighted(vec![0, 0]),
            labels: Vec::new(),
        })
        .is_err());
    }

    #[test]
    fn noise_grid_parse_accepts_axis_prefixed_lists() {
        let g = NoiseSweepGrid::parse("K=74,160,adc=6,8").unwrap();
        assert_eq!(g.ks, vec![74, 160]);
        assert_eq!(g.adc_bits, vec![6, 8]);
        assert_eq!(g.margin_db, NoiseSweepGrid::DEFAULT_MARGIN_DB);
        assert_eq!(g.cells(), vec![(74, 6), (74, 8), (160, 6), (160, 8)]);

        let m = NoiseSweepGrid::parse("k=16,adc=4,margin=55.5").unwrap();
        assert_eq!((m.ks.clone(), m.adc_bits.clone()), (vec![16], vec![4]));
        assert!((m.margin_db - 55.5).abs() < 1e-12);

        // Malformed specs fail loudly instead of silently reshaping the
        // study — including duplicate axis values and repeated margins.
        for bad in [
            "", "64,128", "K=,adc=4", "K=0,adc=4", "K=64", "adc=8",
            "K=64,adc=0", "K=64,adc=17", "K=64,adc=8,margin=-3", "K=x,adc=4",
            "K=74,74,adc=4", "K=74,adc=4,4", "K=74,adc=4,margin=30,60",
            "K=74,adc=4,margin=30,margin=60",
        ] {
            assert!(NoiseSweepGrid::parse(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn noise_grid_builds_one_noisy_shard_per_cell() {
        let grid = NoiseSweepGrid::parse("K=74,249,adc=6,12").unwrap();
        let cfg = FleetConfig::noise_grid(CoordinatorConfig::default(), &grid);
        assert_eq!(cfg.shards.len(), 4);
        assert_eq!(cfg.labels, vec!["K74/adc6", "K74/adc12", "K249/adc6", "K249/adc12"]);
        for (i, ((k, bits), s)) in grid.cells().into_iter().zip(&cfg.shards).enumerate() {
            match &s.backend {
                BackendKind::Photonic(p) => {
                    let noise = p.noise.expect("grid shard injects noise");
                    assert_eq!(noise.adc_bits, Some(bits), "cell {i}");
                    assert!(
                        (noise.snr_db - (24.1 + NoiseSweepGrid::DEFAULT_MARGIN_DB)).abs() < 1e-9
                    );
                    // Seeds keyed by K only: the Gaussian stage of cells
                    // that differ only in ADC bits draws identically.
                    assert_eq!(p.noise_seed, 0xADC0_5EED ^ ((k as u64) << 16));
                }
                other => panic!("grid shard {i} is not photonic: {other:?}"),
            }
        }
    }

    #[test]
    fn noise_grid_drive_rejects_mismatched_fleets() {
        let (h, shards) = two_shard_handle("gridmismatch", RoutePolicy::RoundRobin);
        let grid = NoiseSweepGrid::paper_range(); // 9 cells vs 2 shards
        assert!(grid.drive(&h, 1).is_err());
        for c in shards {
            c.shutdown();
        }
    }

    #[test]
    fn noise_sweep_builds_one_photonic_shard_per_margin() {
        let cfg = FleetConfig::noise_sweep(CoordinatorConfig::default(), &[0.0, 20.0, 40.0]);
        assert_eq!(cfg.shards.len(), 3);
        assert_eq!(cfg.labels, vec!["margin+0dB", "margin+20dB", "margin+40dB"]);
        for (i, s) in cfg.shards.iter().enumerate() {
            match &s.backend {
                BackendKind::Photonic(p) => {
                    let noise = p.noise.expect("sweep shard injects noise");
                    let margin = [0.0, 20.0, 40.0][i];
                    assert!((noise.snr_db - (24.1 + margin)).abs() < 1e-9);
                }
                other => panic!("sweep shard {i} is not photonic: {other:?}"),
            }
        }
        // Distinct deterministic noise streams per shard.
        let seeds: Vec<u64> = cfg
            .shards
            .iter()
            .map(|s| match &s.backend {
                BackendKind::Photonic(p) => p.noise_seed,
                _ => unreachable!(),
            })
            .collect();
        assert_ne!(seeds[0], seeds[1]);
        assert_ne!(seeds[1], seeds[2]);
    }
}
