//! Coordinator metrics: counters, log-bucket latency histogram, worker
//! service-time accounting, and photonic telemetry aggregation.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::runtime::backend::ExecReport;

/// Number of logarithmic latency buckets (1 µs × 2^i, i < BUCKETS).
const BUCKETS: usize = 24;

/// Lock-free metrics shared by leader/workers/handles.
#[derive(Debug, Default)]
pub struct CoordinatorStats {
    /// Requests accepted.
    pub requests: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests failed.
    pub failed: AtomicU64,
    /// Micro-batches executed.
    pub batches: AtomicU64,
    /// Sum of micro-batch member counts (for mean occupancy).
    pub batched_rows: AtomicU64,
    /// Sum of padded slots (wasted work due to padding).
    pub padded_rows: AtomicU64,
    /// Whole-CNN inferences served.
    pub cnn_frames: AtomicU64,
    /// Stacked same-model CNN micro-batches executed (t-dimension batching).
    pub cnn_batches: AtomicU64,
    /// Workers still in the leader's rotation (gauge, maintained by the
    /// leader: set at start, dropped as workers die or retire, restored by
    /// revival). A fleet router treats `0` as shard-down even when the
    /// shard's leader is still alive fast-failing jobs — otherwise a dead
    /// pool's near-zero queue depth would *attract* least-queue-depth
    /// traffic.
    pub live_workers: AtomicU64,
    /// Worker-pool revivals executed by the leader
    /// ([`Job::ReviveWorkers`](crate::coordinator::Job) calls that spawned
    /// at least one worker) — the shard-lifecycle counterpart of the fleet's
    /// revived/spawned counters.
    pub revivals: AtomicU64,
    /// Latency histogram (µs, log2 buckets).
    lat_hist: [AtomicU64; BUCKETS],
    /// Total latency in µs.
    lat_sum_us: AtomicU64,
    /// Worker execute (service) invocations timed.
    exec_calls: AtomicU64,
    /// Total worker execute time, µs — service time only, excluding queue
    /// and batching-window wait (which end-to-end latency includes).
    exec_sum_us: AtomicU64,
    /// Slowest single execute, µs.
    exec_max_us: AtomicU64,
    /// Executions that carried a photonic [`ExecReport`].
    pub sim_reports: AtomicU64,
    /// Total projected photonic latency, f64 seconds stored as bits (a
    /// single request can be sub-nanosecond on a 64-core fleet, so integer
    /// nanosecond accumulation would truncate to zero).
    sim_latency_bits: AtomicU64,
    /// Total projected photonic energy, f64 joules stored as bits.
    sim_energy_bits: AtomicU64,
    /// Analog dot-product lanes transduced across reported executions —
    /// the denominator of the served-exact fraction (`1 − noise/lanes`).
    pub lanes: AtomicU64,
    /// Outputs perturbed by analog noise injection.
    pub noise_events: AtomicU64,
    /// Submissions refused by admission control (full ingress queue or
    /// best-effort watermark). Sheds never enter `requests`, so
    /// [`CoordinatorStats::queue_depth`] stays truthful under overload.
    pub shed: AtomicU64,
    /// The best-effort subset of `shed` (watermark + full-queue refusals of
    /// [`Priority::BestEffort`](crate::coordinator::Priority) traffic).
    pub shed_best_effort: AtomicU64,
    /// Jobs the leader failed typed (`Error::DeadlineExceeded`) because
    /// their deadline expired before dispatch. Counted in `failed` too —
    /// this counter attributes the *cause*.
    pub deadline_expired: AtomicU64,
}

/// Lock-free f64 accumulate over an `AtomicU64` holding f64 bits
/// (`AtomicU64::default()` is bit-pattern 0 == 0.0f64, so `Default` on the
/// stats struct stays correct).
fn atomic_add_f64(cell: &AtomicU64, add: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) + add).to_bits();
        match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

impl CoordinatorStats {
    /// Record a completed request's end-to-end latency.
    pub fn record_latency(&self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0) as u64;
        self.lat_sum_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.lat_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one worker execute's service time (per batch or per job).
    pub fn record_service(&self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0) as u64;
        self.exec_calls.fetch_add(1, Ordering::Relaxed);
        self.exec_sum_us.fetch_add(us, Ordering::Relaxed);
        self.exec_max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Fold one execution's photonic telemetry into the totals.
    pub fn record_report(&self, r: &ExecReport) {
        self.sim_reports.fetch_add(1, Ordering::Relaxed);
        atomic_add_f64(&self.sim_latency_bits, r.sim_latency_s);
        atomic_add_f64(&self.sim_energy_bits, r.energy_j);
        self.lanes.fetch_add(r.lanes, Ordering::Relaxed);
        self.noise_events.fetch_add(r.noise_events, Ordering::Relaxed);
    }

    /// Requests accepted but not yet resolved (completed or failed) — the
    /// router's least-queue-depth signal. A momentary over-count is possible
    /// while a worker is between incrementing `completed` and delivering,
    /// which only makes the shard look marginally busier; safe for routing.
    pub fn queue_depth(&self) -> u64 {
        let done = self.completed.load(Ordering::Relaxed)
            + self.failed.load(Ordering::Relaxed);
        self.requests.load(Ordering::Relaxed).saturating_sub(done)
    }

    /// Fraction of transduced lanes whose served integer matched the exact
    /// result (`1.0` when nothing reported lanes — an exact digital shard).
    pub fn served_exact_fraction(&self) -> f64 {
        crate::metrics::exact_fraction(
            self.noise_events.load(Ordering::Relaxed),
            self.lanes.load(Ordering::Relaxed),
        )
    }

    /// Approximate latency percentile (bucket upper bound), seconds.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        let total: u64 = self.lat_hist.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0.0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.lat_hist.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return (1u64 << (i + 1)) as f64 * 1e-6;
            }
        }
        (1u64 << BUCKETS) as f64 * 1e-6
    }

    /// Mean end-to-end latency, seconds.
    pub fn latency_mean(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.lat_sum_us.load(Ordering::Relaxed) as f64 * 1e-6 / n as f64
    }

    /// Mean worker execute (service) time, seconds.
    pub fn service_mean(&self) -> f64 {
        let n = self.exec_calls.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.exec_sum_us.load(Ordering::Relaxed) as f64 * 1e-6 / n as f64
    }

    /// Slowest single worker execute, seconds.
    pub fn service_max(&self) -> f64 {
        self.exec_max_us.load(Ordering::Relaxed) as f64 * 1e-6
    }

    /// Total projected photonic latency across reported executions, seconds.
    pub fn sim_latency_total_s(&self) -> f64 {
        f64::from_bits(self.sim_latency_bits.load(Ordering::Relaxed))
    }

    /// Total projected photonic energy, joules.
    pub fn sim_energy_total_j(&self) -> f64 {
        f64::from_bits(self.sim_energy_bits.load(Ordering::Relaxed))
    }

    /// Projected frames/executions per second on the simulated photonic
    /// accelerator (reported executions ÷ total projected latency).
    pub fn sim_fps(&self) -> f64 {
        crate::metrics::per_unit(
            self.sim_reports.load(Ordering::Relaxed),
            self.sim_latency_total_s(),
        )
    }

    /// Projected FPS per watt (reported executions ÷ total projected energy).
    pub fn sim_fps_per_w(&self) -> f64 {
        crate::metrics::per_unit(
            self.sim_reports.load(Ordering::Relaxed),
            self.sim_energy_total_j(),
        )
    }

    /// Mean rows per micro-batch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_rows.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Fraction of executed rows that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let rows = self.batched_rows.load(Ordering::Relaxed);
        let pad = self.padded_rows.load(Ordering::Relaxed);
        if rows + pad == 0 {
            return 0.0;
        }
        pad as f64 / (rows + pad) as f64
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} completed={} failed={} batches={} occupancy={:.2} padding={:.1}% \
             lat(mean/p50/p99)={:.1}/{:.1}/{:.1} µs service(mean/max)={:.1}/{:.1} µs",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.padding_fraction() * 100.0,
            self.latency_mean() * 1e6,
            self.latency_percentile(0.5) * 1e6,
            self.latency_percentile(0.99) * 1e6,
            self.service_mean() * 1e6,
            self.service_max() * 1e6,
        );
        if self.sim_reports.load(Ordering::Relaxed) > 0 {
            s.push_str(&format!(
                " sim(fps={:.0} fps/W={:.0} noise_events={})",
                self.sim_fps(),
                self.sim_fps_per_w(),
                self.noise_events.load(Ordering::Relaxed),
            ));
        }
        let shed = self.shed.load(Ordering::Relaxed);
        let expired = self.deadline_expired.load(Ordering::Relaxed);
        if shed > 0 || expired > 0 {
            s.push_str(&format!(
                " qos(shed={} shed_be={} deadline_expired={})",
                shed,
                self.shed_best_effort.load(Ordering::Relaxed),
                expired,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles_ordered() {
        let s = CoordinatorStats::default();
        for us in [10.0, 20.0, 50.0, 100.0, 5000.0] {
            s.record_latency(us * 1e-6);
        }
        let p50 = s.latency_percentile(0.5);
        let p99 = s.latency_percentile(0.99);
        assert!(p50 <= p99);
        assert!(p99 >= 5e-3 / 2.0); // the 5 ms outlier lands in a high bucket
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = CoordinatorStats::default();
        assert_eq!(s.latency_percentile(0.99), 0.0);
        assert_eq!(s.latency_mean(), 0.0);
        assert_eq!(s.mean_batch_occupancy(), 0.0);
        assert_eq!(s.padding_fraction(), 0.0);
        assert_eq!(s.service_mean(), 0.0);
        assert_eq!(s.service_max(), 0.0);
        assert_eq!(s.sim_fps(), 0.0);
        assert_eq!(s.sim_fps_per_w(), 0.0);
    }

    #[test]
    fn occupancy_and_padding() {
        let s = CoordinatorStats::default();
        s.batches.fetch_add(2, Ordering::Relaxed);
        s.batched_rows.fetch_add(6, Ordering::Relaxed);
        s.padded_rows.fetch_add(2, Ordering::Relaxed);
        assert!((s.mean_batch_occupancy() - 3.0).abs() < 1e-9);
        assert!((s.padding_fraction() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn service_time_mean_and_max() {
        let s = CoordinatorStats::default();
        s.record_service(100e-6);
        s.record_service(300e-6);
        assert!((s.service_mean() - 200e-6).abs() < 1e-9);
        assert!((s.service_max() - 300e-6).abs() < 1e-9);
        assert!(s.summary().contains("service"));
    }

    #[test]
    fn photonic_reports_aggregate() {
        let s = CoordinatorStats::default();
        let r = ExecReport {
            sim_latency_s: 2e-3,
            energy_j: 5e-4,
            lanes: 100,
            noise_events: 3,
            row_noise: Vec::new(),
        };
        s.record_report(&r);
        s.record_report(&r);
        assert_eq!(s.sim_reports.load(Ordering::Relaxed), 2);
        assert_eq!(s.lanes.load(Ordering::Relaxed), 200);
        assert!((s.served_exact_fraction() - (1.0 - 6.0 / 200.0)).abs() < 1e-12);
        assert!((s.sim_latency_total_s() - 4e-3).abs() < 1e-9);
        assert!((s.sim_energy_total_j() - 1e-3).abs() < 1e-9);
        assert!((s.sim_fps() - 500.0).abs() < 1e-6);
        assert!((s.sim_fps_per_w() - 2000.0).abs() < 1e-3);
        assert_eq!(s.noise_events.load(Ordering::Relaxed), 6);
        assert!(s.summary().contains("sim("));
    }

    #[test]
    fn sub_nanosecond_reports_do_not_truncate_to_zero() {
        // A single GEMM on a 64-core 10 GS/s fleet projects ~1e-10 s; the
        // accumulator must not floor it away.
        let s = CoordinatorStats::default();
        for _ in 0..10 {
            s.record_report(&ExecReport {
                sim_latency_s: 1e-10,
                energy_j: 1e-13,
                lanes: 1,
                noise_events: 0,
                row_noise: Vec::new(),
            });
        }
        assert!((s.sim_latency_total_s() - 1e-9).abs() < 1e-18);
        assert!((s.sim_energy_total_j() - 1e-12).abs() < 1e-21);
        assert!(s.sim_fps() > 0.0);
        assert!(s.sim_fps_per_w() > 0.0);
    }

    #[test]
    fn queue_depth_tracks_unresolved_requests() {
        let s = CoordinatorStats::default();
        assert_eq!(s.queue_depth(), 0);
        s.requests.fetch_add(10, Ordering::Relaxed);
        s.completed.fetch_add(6, Ordering::Relaxed);
        s.failed.fetch_add(1, Ordering::Relaxed);
        assert_eq!(s.queue_depth(), 3);
        // Transient over-resolution must not underflow.
        s.completed.fetch_add(10, Ordering::Relaxed);
        assert_eq!(s.queue_depth(), 0);
    }

    #[test]
    fn exact_shard_reports_full_served_accuracy() {
        let s = CoordinatorStats::default();
        assert_eq!(s.served_exact_fraction(), 1.0);
    }

    #[test]
    fn summary_contains_counts() {
        let s = CoordinatorStats::default();
        s.requests.fetch_add(5, Ordering::Relaxed);
        assert!(s.summary().contains("requests=5"));
    }

    #[test]
    fn qos_block_appears_only_when_shedding_or_expiring() {
        let s = CoordinatorStats::default();
        assert!(!s.summary().contains("qos("));
        s.shed.fetch_add(3, Ordering::Relaxed);
        s.shed_best_effort.fetch_add(2, Ordering::Relaxed);
        assert!(s.summary().contains("qos(shed=3 shed_be=2 deadline_expired=0)"));
        // Sheds never entered `requests`, so depth stays truthful.
        assert_eq!(s.queue_depth(), 0);
    }
}
