//! Coordinator metrics: counters + log-bucket latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of logarithmic latency buckets (1 µs × 2^i, i < BUCKETS).
const BUCKETS: usize = 24;

/// Lock-free metrics shared by leader/workers/handles.
#[derive(Debug, Default)]
pub struct CoordinatorStats {
    /// Requests accepted.
    pub requests: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests failed.
    pub failed: AtomicU64,
    /// Micro-batches executed.
    pub batches: AtomicU64,
    /// Sum of micro-batch member counts (for mean occupancy).
    pub batched_rows: AtomicU64,
    /// Sum of padded slots (wasted work due to padding).
    pub padded_rows: AtomicU64,
    /// Latency histogram (µs, log2 buckets).
    lat_hist: [AtomicU64; BUCKETS],
    /// Total latency in µs.
    lat_sum_us: AtomicU64,
}

impl CoordinatorStats {
    /// Record a completed request's end-to-end latency.
    pub fn record_latency(&self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0) as u64;
        self.lat_sum_us.fetch_add(us, Ordering::Relaxed);
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.lat_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate latency percentile (bucket upper bound), seconds.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        let total: u64 = self.lat_hist.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0.0;
        }
        let target = (total as f64 * p).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.lat_hist.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return (1u64 << (i + 1)) as f64 * 1e-6;
            }
        }
        (1u64 << BUCKETS) as f64 * 1e-6
    }

    /// Mean end-to-end latency, seconds.
    pub fn latency_mean(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.lat_sum_us.load(Ordering::Relaxed) as f64 * 1e-6 / n as f64
    }

    /// Mean rows per micro-batch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_rows.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Fraction of executed rows that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let rows = self.batched_rows.load(Ordering::Relaxed);
        let pad = self.padded_rows.load(Ordering::Relaxed);
        if rows + pad == 0 {
            return 0.0;
        }
        pad as f64 / (rows + pad) as f64
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} completed={} failed={} batches={} occupancy={:.2} padding={:.1}% \
             lat(mean/p50/p99)={:.1}/{:.1}/{:.1} µs",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.padding_fraction() * 100.0,
            self.latency_mean() * 1e6,
            self.latency_percentile(0.5) * 1e6,
            self.latency_percentile(0.99) * 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles_ordered() {
        let s = CoordinatorStats::default();
        for us in [10.0, 20.0, 50.0, 100.0, 5000.0] {
            s.record_latency(us * 1e-6);
        }
        let p50 = s.latency_percentile(0.5);
        let p99 = s.latency_percentile(0.99);
        assert!(p50 <= p99);
        assert!(p99 >= 5e-3 / 2.0); // the 5 ms outlier lands in a high bucket
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = CoordinatorStats::default();
        assert_eq!(s.latency_percentile(0.99), 0.0);
        assert_eq!(s.latency_mean(), 0.0);
        assert_eq!(s.mean_batch_occupancy(), 0.0);
        assert_eq!(s.padding_fraction(), 0.0);
    }

    #[test]
    fn occupancy_and_padding() {
        let s = CoordinatorStats::default();
        s.batches.fetch_add(2, Ordering::Relaxed);
        s.batched_rows.fetch_add(6, Ordering::Relaxed);
        s.padded_rows.fetch_add(2, Ordering::Relaxed);
        assert!((s.mean_batch_occupancy() - 3.0).abs() < 1e-9);
        assert!((s.padding_fraction() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn summary_contains_counts() {
        let s = CoordinatorStats::default();
        s.requests.fetch_add(5, Ordering::Relaxed);
        assert!(s.summary().contains("requests=5"));
    }
}
