//! Dynamic batching policy.
//!
//! MLP rows are packed into the largest AOT batch variant that the pending
//! queue fills (or the batching window expires on). Remainders pad with
//! zero rows — exact for the integer models and invisible to callers.
//!
//! CNN frames batch too: same-model frames gathered in the window stack
//! along the t-dimension into a [`CnnMicroBatch`] and execute their im2col
//! GEMMs once per layer group via
//! [`run_cnn_batch`](crate::runtime::cnnrun::run_cnn_batch). No padding is
//! needed — the stacked GEMM's row count is exactly the member frames'
//! combined im2col rows, and row independence keeps every member bit-exact.

use crate::coordinator::request::{CnnJob, MlpJob};
use crate::dnn::models::CnnModel;
use crate::runtime::cnnrun::CnnRun;
use crate::{Error, Result};

/// Batch-formation policy over the available AOT batch variants.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// (artifact name, batch size), ascending by batch size.
    pub variants: Vec<(String, usize)>,
    /// Maximum time a row may wait for co-batching, seconds.
    pub max_wait_s: f64,
}

impl BatchPolicy {
    /// Policy over `variants` (ascending batch sizes). An empty variant set
    /// is a configuration error in release builds too — not just a
    /// `debug_assert` — so a coordinator misconfigured against a manifest
    /// with no `mlp_b*` artifacts fails at construction, not on the first
    /// batch.
    pub fn new(variants: Vec<(String, usize)>, max_wait_s: f64) -> Result<Self> {
        if variants.is_empty() {
            return Err(Error::Coordinator(
                "batch policy needs at least one mlp batch variant".into(),
            ));
        }
        Ok(BatchPolicy { variants, max_wait_s })
    }

    /// Largest variant batch size.
    pub fn max_batch(&self) -> usize {
        self.variants.last().map(|(_, b)| *b).unwrap_or(1)
    }

    /// Choose the variant for `pending` queued rows: the smallest variant
    /// that fits them all, else the largest (the rest waits for the next
    /// batch).
    pub fn pick_variant(&self, pending: usize) -> &(String, usize) {
        self.variants
            .iter()
            .find(|(_, b)| *b >= pending)
            .unwrap_or_else(|| self.variants.last().expect("non-empty variants"))
    }
}

/// A formed micro-batch ready for a worker.
#[derive(Debug)]
pub struct MicroBatch {
    /// Artifact to execute.
    pub artifact: String,
    /// Variant batch size (≥ jobs.len()).
    pub batch: usize,
    /// The member jobs, order preserved (row i of the output belongs to
    /// jobs[i]).
    pub jobs: Vec<MlpJob>,
}

impl MicroBatch {
    /// Pack jobs into the flat padded input buffer for the variant.
    pub fn build_input(&self, row_len: usize) -> Vec<i32> {
        let mut buf = Vec::new();
        self.build_input_into(row_len, &mut buf);
        buf
    }

    /// [`build_input`](Self::build_input) into a caller-owned scratch buffer
    /// (the worker loop reuses one across batches, so the per-batch stacking
    /// allocates nothing at the working size). Clear + re-zero first, so
    /// padding rows never leak a previous batch's rows.
    pub fn build_input_into(&self, row_len: usize, buf: &mut Vec<i32>) {
        buf.clear();
        buf.resize(self.batch * row_len, 0);
        for (i, j) in self.jobs.iter().enumerate() {
            buf[i * row_len..(i + 1) * row_len].copy_from_slice(&j.row);
        }
    }

    /// Per-output-row noise nonces for the stacked execute: row `i` carries
    /// member `i`'s request nonce, padding rows the content-keyed `0`.
    /// [`RowNonce::Content`](crate::runtime::RowNonce) when no member opted
    /// into the counter mode, so default-off serving takes the historical
    /// path untouched.
    pub fn row_nonces(&self) -> crate::runtime::RowNonce {
        if self.jobs.iter().all(|j| j.nonce == 0) {
            crate::runtime::RowNonce::Content
        } else {
            crate::runtime::RowNonce::PerRow(self.jobs.iter().map(|j| j.nonce).collect())
        }
    }

    /// Split a flat output into per-job rows (dropping padding rows) and
    /// deliver them. Members share the micro-batch's projected cost (the
    /// batch executed as one artifact invocation), but when the backend
    /// attributed noise per row, member `i` receives *its own* row's noise
    /// events and lane count
    /// ([`crate::runtime::backend::ExecReport::for_row`]) — exact
    /// per-request attribution even under stacked noisy execution.
    pub fn deliver(self, output: &[i32], report: Option<crate::runtime::backend::ExecReport>) {
        let out_len = output.len() / self.batch;
        for (i, j) in self.jobs.into_iter().enumerate() {
            let row = output[i * out_len..(i + 1) * out_len].to_vec();
            let member = report.as_ref().map(|r| r.for_row(i, out_len as u64));
            // Receiver may have hung up (caller timeout); that's their loss.
            let _ = j.reply.send(Ok(crate::coordinator::request::Reply {
                outputs: row,
                report: member,
                layers: Vec::new(),
            }));
        }
    }

    /// Fail every member with a request-level error (worker error path).
    pub fn fail(self, msg: &str) {
        self.fail_with(|| crate::Error::Coordinator(msg.to_string()));
    }

    /// Fail every member with a caller-chosen error (the dead-worker path
    /// uses [`crate::Error::ShardDown`] so the fleet router can tell shard
    /// death from request failures).
    pub fn fail_with(self, mk: impl Fn() -> crate::Error) {
        for j in self.jobs {
            let _ = j.reply.send(Err(mk()));
        }
    }
}

/// A formed same-model CNN micro-batch: the member frames stack along the
/// t-dimension and execute their layer GEMMs together, one plan lookup and
/// one kernel launch per layer group for the whole batch.
#[derive(Debug)]
pub struct CnnMicroBatch {
    /// The shared network (member jobs all submitted an equal model).
    pub model: CnnModel,
    /// Member jobs, order preserved (frame i of the batch belongs to
    /// jobs[i]).
    pub jobs: Vec<CnnJob>,
}

impl CnnMicroBatch {
    /// Member frames' request nonces in job order (all zero unless the
    /// coordinator opted into the time-indexed counter mode) — handed to
    /// [`run_cnn_batch_keyed`](crate::runtime::cnnrun::run_cnn_batch_keyed)
    /// so every stacked layer GEMM keys frame `f`'s rows by `nonces[f]`.
    pub fn frame_nonces(&self) -> Vec<u64> {
        if self.jobs.iter().all(|j| j.nonce == 0) {
            Vec::new()
        } else {
            self.jobs.iter().map(|j| j.nonce).collect()
        }
    }

    /// Deliver per-frame runs to their owners. `runs` comes from
    /// [`run_cnn_batch`](crate::runtime::cnnrun::run_cnn_batch) over the
    /// members' inputs in job order, so `runs[i]` belongs to `jobs[i]`.
    ///
    /// A run count that disagrees with the member count would silently
    /// truncate the zip — frame `i`'s owner could receive frame `j`'s
    /// logits or nothing at all — so it is a release-enforced typed error:
    /// every member is failed with `Error::Coordinator` and the mismatch is
    /// reported to the caller (PR 8's `check_frame_nonces` discipline; a
    /// `debug_assert` here vanished in release builds).
    pub fn deliver(self, runs: Vec<CnnRun>) -> crate::Result<()> {
        if runs.len() != self.jobs.len() {
            let msg = format!(
                "stacked cnn batch produced {} runs for {} member frames",
                runs.len(),
                self.jobs.len()
            );
            self.fail_with(|| crate::Error::Coordinator(msg.clone()));
            return Err(crate::Error::Coordinator(msg));
        }
        for (j, run) in self.jobs.into_iter().zip(runs) {
            let _ = j.reply.send(Ok(crate::coordinator::request::Reply {
                outputs: run.logits,
                report: run.report,
                layers: run.layers,
            }));
        }
        Ok(())
    }

    /// Fail every member with a request-level error (worker error path).
    pub fn fail(self, msg: &str) {
        self.fail_with(|| crate::Error::Coordinator(msg.to_string()));
    }

    /// Fail every member with a caller-chosen error (see
    /// [`MicroBatch::fail_with`]).
    pub fn fail_with(self, mk: impl Fn() -> crate::Error) {
        for j in self.jobs {
            let _ = j.reply.send(Err(mk()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::response_slot;
    use std::time::Instant;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(
            vec![("mlp_b1".into(), 1), ("mlp_b8".into(), 8), ("mlp_b32".into(), 32)],
            0.001,
        )
        .unwrap()
    }

    fn job(v: i32) -> (MlpJob, crate::coordinator::request::Response) {
        let (tx, rx) = response_slot();
        let qos = crate::coordinator::request::Qos::default();
        (MlpJob { row: vec![v; 4], reply: tx, enqueued: Instant::now(), nonce: 0, qos }, rx)
    }

    #[test]
    fn empty_variant_set_is_a_coordinator_error() {
        let err = BatchPolicy::new(Vec::new(), 0.001).unwrap_err();
        match err {
            Error::Coordinator(msg) => assert!(msg.contains("variant"), "{msg}"),
            other => panic!("wrong error kind: {other}"),
        }
    }

    #[test]
    fn picks_smallest_fitting_variant() {
        let p = policy();
        assert_eq!(p.pick_variant(1).1, 1);
        assert_eq!(p.pick_variant(2).1, 8);
        assert_eq!(p.pick_variant(8).1, 8);
        assert_eq!(p.pick_variant(9).1, 32);
        assert_eq!(p.max_batch(), 32);
    }

    #[test]
    fn pick_variant_with_pending_beyond_max_clamps_to_largest() {
        let p = policy();
        // pending > every variant: the largest variant serves the first 32
        // rows and the leader loops for the remainder.
        for pending in [33, 64, 1000, usize::MAX] {
            let (name, batch) = p.pick_variant(pending);
            assert_eq!((name.as_str(), *batch), ("mlp_b32", 32));
        }
        // A single-variant policy clamps everything to that variant.
        let single = BatchPolicy::new(vec![("mlp_b4".into(), 4)], 0.0).unwrap();
        assert_eq!(single.pick_variant(100).1, 4);
        assert_eq!(single.max_batch(), 4);
    }

    #[test]
    fn input_packing_pads_with_zeros() {
        let (j1, _r1) = job(7);
        let (j2, _r2) = job(9);
        let mb = MicroBatch { artifact: "mlp_b8".into(), batch: 8, jobs: vec![j1, j2] };
        let buf = mb.build_input(4);
        assert_eq!(buf.len(), 32);
        assert_eq!(&buf[0..4], &[7, 7, 7, 7]);
        assert_eq!(&buf[4..8], &[9, 9, 9, 9]);
        assert!(buf[8..].iter().all(|&v| v == 0));
    }

    #[test]
    fn input_packing_into_scratch_rezeros_padding() {
        // A dirty, larger scratch from a previous batch must not leak into
        // this batch's padding rows, and refilling must not reallocate.
        let (j1, _r1) = job(7);
        let mb = MicroBatch { artifact: "mlp_b8".into(), batch: 8, jobs: vec![j1] };
        let mut scratch = vec![-1i32; 64];
        mb.build_input_into(4, &mut scratch);
        assert_eq!(scratch, mb.build_input(4));
        let cap = scratch.capacity();
        mb.build_input_into(4, &mut scratch);
        assert_eq!(scratch.capacity(), cap, "refill must not reallocate");
    }

    #[test]
    fn delivery_routes_rows_to_owners() {
        let (j1, r1) = job(1);
        let (j2, r2) = job(2);
        let mb = MicroBatch { artifact: "mlp_b8".into(), batch: 8, jobs: vec![j1, j2] };
        // Fake output: 8 rows of 3.
        let out: Vec<i32> = (0..24).collect();
        mb.deliver(&out, None);
        assert_eq!(r1.recv().unwrap().unwrap().outputs, vec![0, 1, 2]);
        let reply2 = r2.recv().unwrap().unwrap();
        assert_eq!(reply2.outputs, vec![3, 4, 5]);
        assert!(reply2.report.is_none());
    }

    #[test]
    fn delivery_slices_row_noise_attribution_per_member() {
        use crate::runtime::backend::ExecReport;
        let (j1, r1) = job(1);
        let (j2, r2) = job(2);
        let mb = MicroBatch { artifact: "mlp_b8".into(), batch: 8, jobs: vec![j1, j2] };
        let out: Vec<i32> = (0..24).collect(); // 8 rows of 3
        let batch_report = ExecReport {
            sim_latency_s: 1e-6,
            energy_j: 2e-9,
            lanes: 24,
            noise_events: 7,
            row_noise: vec![4, 3, 0, 0, 0, 0, 0, 0],
        };
        mb.deliver(&out, Some(batch_report));
        let rep1 = r1.recv().unwrap().unwrap().report.unwrap();
        assert_eq!((rep1.lanes, rep1.noise_events), (3, 4));
        assert_eq!(rep1.row_noise, vec![4]);
        let rep2 = r2.recv().unwrap().unwrap().report.unwrap();
        assert_eq!((rep2.lanes, rep2.noise_events), (3, 3));
        // Projected cost stays the batch's — one artifact invocation.
        assert_eq!(rep2.sim_latency_s, 1e-6);
    }

    #[test]
    fn row_nonces_follow_member_order_and_default_to_content() {
        let (j1, _r1) = job(1);
        let (j2, _r2) = job(2);
        let mb = MicroBatch { artifact: "mlp_b8".into(), batch: 8, jobs: vec![j1, j2] };
        assert_eq!(mb.row_nonces(), crate::runtime::RowNonce::Content);
        let (mut j3, _r3) = job(3);
        let (mut j4, _r4) = job(4);
        j3.nonce = 7;
        j4.nonce = 9;
        let nb = MicroBatch { artifact: "mlp_b8".into(), batch: 8, jobs: vec![j3, j4] };
        match nb.row_nonces() {
            crate::runtime::RowNonce::PerRow(v) => assert_eq!(v, vec![7, 9]),
            other => panic!("expected per-row nonces, got {other:?}"),
        }
    }

    #[test]
    fn failure_propagates_to_all_members() {
        let (j1, r1) = job(1);
        let (j2, r2) = job(2);
        let mb = MicroBatch { artifact: "mlp_b8".into(), batch: 8, jobs: vec![j1, j2] };
        mb.fail("boom");
        assert!(r1.recv().unwrap().is_err());
        assert!(r2.recv().unwrap().is_err());
    }

    fn cnn_job(model: &CnnModel, fill: i32) -> (CnnJob, crate::coordinator::request::Response) {
        let (tx, rx) = response_slot();
        (
            CnnJob {
                model: model.clone(),
                input: vec![fill; 6 * 6 * 3],
                reply: tx,
                enqueued: Instant::now(),
                nonce: 0,
                qos: crate::coordinator::request::Qos::default(),
            },
            rx,
        )
    }

    fn tiny_model() -> CnnModel {
        CnnModel {
            name: "tiny",
            layers: vec![crate::dnn::layer::Layer::fc("head", 6 * 6 * 3, 5)],
        }
    }

    #[test]
    fn cnn_batch_delivery_routes_runs_to_owners() {
        let model = tiny_model();
        let (j1, r1) = cnn_job(&model, 1);
        let (j2, r2) = cnn_job(&model, 2);
        let batch = CnnMicroBatch { model, jobs: vec![j1, j2] };
        let runs = vec![
            CnnRun { logits: vec![10, 11], report: None, layers: Vec::new() },
            CnnRun { logits: vec![20, 21], report: None, layers: Vec::new() },
        ];
        batch.deliver(runs).unwrap();
        assert_eq!(r1.recv().unwrap().unwrap().outputs, vec![10, 11]);
        assert_eq!(r2.recv().unwrap().unwrap().outputs, vec![20, 21]);
    }

    #[test]
    fn cnn_batch_short_delivery_is_a_typed_error_not_a_silent_drop() {
        let model = tiny_model();
        let (j1, r1) = cnn_job(&model, 1);
        let (j2, r2) = cnn_job(&model, 2);
        let batch = CnnMicroBatch { model, jobs: vec![j1, j2] };
        // One run for two member frames: the zip would silently starve the
        // second owner. Must be a typed Coordinator error in release too.
        let runs = vec![CnnRun { logits: vec![10, 11], report: None, layers: Vec::new() }];
        let err = batch.deliver(runs).unwrap_err();
        match &err {
            crate::Error::Coordinator(m) => {
                assert!(m.contains("1 runs for 2 member frames"), "message: {m}");
            }
            other => panic!("expected Coordinator error, got {other:?}"),
        }
        // And every member observed the failure — nobody hangs, nobody
        // gets another frame's logits.
        assert!(r1.recv().unwrap().is_err());
        assert!(r2.recv().unwrap().is_err());
    }

    #[test]
    fn cnn_batch_failure_propagates_to_all_members() {
        let model = tiny_model();
        let (j1, r1) = cnn_job(&model, 1);
        let (j2, r2) = cnn_job(&model, 2);
        let batch = CnnMicroBatch { model, jobs: vec![j1, j2] };
        batch.fail("stacked execute failed");
        assert!(r1.recv().unwrap().is_err());
        assert!(r2.recv().unwrap().is_err());
    }
}
