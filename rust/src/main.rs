//! `spoga` — CLI for the SPOGA reproduction.
//!
//! Subcommands (hand-rolled parsing; no clap in the offline dep set):
//!
//! ```text
//! spoga scalability                       reproduce paper Table I
//! spoga table2                            print paper Table II constants
//! spoga fig5 [--cores N] [--metric M]     reproduce Fig 5(a/b/c) rows
//! spoga gemm [--artifact NAME]            run an AOT GEMM vs golden model
//! spoga serve [--requests N] [--workers W] [--backend B]
//!                                         self-driven serving demo; B in
//!                                         {software, photonic, holylight,
//!                                         deapcnn} (photonic backends add
//!                                         live sim-FPS/W telemetry)
//! spoga info                              artifact + platform diagnostics
//! ```

use std::collections::HashMap;

use spoga::metrics::{build_figure, Metric, FIG5_CORES};
use spoga::optics::{paper_table1, solve_table1};
use spoga::report::{fmt_sig, Table};
use spoga::units::DataRate;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_default();
            m.insert(key.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    m
}

fn cmd_scalability() {
    let solved = solve_table1();
    let paper = paper_table1();
    let mut t = Table::new(vec![
        "Architecture",
        "1 GS/s (N,M)",
        "5 GS/s (N,M)",
        "10 GS/s (N,M)",
        "paper",
    ]);
    for (s, p) in solved.rows.iter().zip(paper.rows.iter()) {
        let cell = |nm: (usize, usize)| format!("{}/{}", nm.0, nm.1);
        t.row(vec![
            s.label.clone(),
            cell(s.nm[0]),
            cell(s.nm[1]),
            cell(s.nm[2]),
            format!("{} {} {}", cell(p.nm[0]), cell(p.nm[1]), cell(p.nm[2])),
        ]);
    }
    println!("Table I — scalability analysis (solved vs paper):\n{}", t.render());
}

fn cmd_table2() {
    use spoga::devices::{Adc, Dac};
    let mut t = Table::new(vec!["Converter", "BR (GS/s)", "Area (mm2)", "Power (mW)"]);
    for dr in DataRate::ALL {
        let a = Adc::for_rate(dr);
        t.row(vec![
            "ADC".to_string(),
            dr.gs().to_string(),
            a.area_mm2.to_string(),
            a.power_mw.to_string(),
        ]);
    }
    for dr in DataRate::ALL {
        let d = Dac::for_rate(dr);
        t.row(vec![
            "DAC".to_string(),
            dr.gs().to_string(),
            d.area_mm2.to_string(),
            d.power_mw.to_string(),
        ]);
    }
    println!("Table II — ADC/DAC design points:\n{}", t.render());
}

fn cmd_fig5(flags: &HashMap<String, String>) {
    let cores: usize =
        flags.get("cores").and_then(|v| v.parse().ok()).unwrap_or(FIG5_CORES);
    let metric = match flags.get("metric").map(String::as_str) {
        Some("fpsw") => Metric::FpsPerW,
        Some("fpswmm2") => Metric::FpsPerWPerMm2,
        _ => Metric::Fps,
    };
    let fig = build_figure(metric, &DataRate::ALL, cores).expect("figure");
    let mut header = vec!["Variant".to_string()];
    header.extend(fig.models.iter().cloned());
    header.push("gmean".to_string());
    let mut t = Table::new(header);
    for v in &fig.variants {
        let mut row = vec![v.name.clone()];
        row.extend(v.per_model.iter().map(|x| fmt_sig(*x, 3)));
        row.push(fmt_sig(v.gmean, 3));
        t.row(row);
    }
    println!("{} ({cores} cores/accelerator):\n{}", metric.figure(), t.render());
}

fn cmd_gemm(flags: &HashMap<String, String>) {
    let name = flags
        .get("artifact")
        .cloned()
        .unwrap_or_else(|| "gemm_64x64x64".to_string());
    let mut eng = spoga::runtime::Engine::new(
        flags.get("artifacts").map(String::as_str).unwrap_or("artifacts"),
    )
    .expect("engine (run `make artifacts` first)");
    let meta = eng.manifest().get(&name).expect("artifact").clone();
    let (m, k) = (meta.inputs[0].dims[0], meta.inputs[0].dims[1]);
    let n = meta.inputs[1].dims[1];
    let a: Vec<i32> = (0..m * k).map(|i| ((i * 37 + 11) % 255) as i32 - 127).collect();
    let b: Vec<i32> = (0..k * n).map(|i| ((i * 53 + 7) % 255) as i32 - 127).collect();
    let t0 = std::time::Instant::now();
    let out = eng.execute_i32_single(&name, &[&a, &b]).expect("execute");
    let dt = t0.elapsed();
    let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
    let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
    let golden = spoga::bitslice::gemm_i32(&a8, &b8, m, k, n).expect("golden");
    assert_eq!(out, golden, "artifact disagrees with golden model!");
    println!("{name}: {m}x{k}x{n} in {dt:?} — matches bitslice golden model");
}

fn cmd_serve(flags: &HashMap<String, String>) {
    use spoga::coordinator::{Coordinator, CoordinatorConfig};
    use spoga::runtime::{BackendKind, PhotonicConfig};
    let requests: usize = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(256);
    let workers: usize = flags.get("workers").and_then(|v| v.parse().ok()).unwrap_or(2);
    // --backend software (default) | photonic | holylight | deapcnn
    let backend = match flags.get("backend").map(String::as_str) {
        Some("photonic") | Some("spoga") => BackendKind::Photonic(PhotonicConfig::spoga()),
        Some("holylight") => BackendKind::Photonic(PhotonicConfig::holylight()),
        Some("deapcnn") => BackendKind::Photonic(PhotonicConfig::deapcnn()),
        _ => BackendKind::Software,
    };
    println!("backend: {}", backend.label());
    let cfg = CoordinatorConfig {
        artifact_dir: flags
            .get("artifacts")
            .cloned()
            .unwrap_or_else(|| "artifacts".to_string()),
        workers,
        backend,
        ..Default::default()
    };
    let c = Coordinator::start(cfg).expect("coordinator");
    let h = c.handle();
    let t0 = std::time::Instant::now();
    let clients = 4usize;
    let per = requests / clients;
    let joins: Vec<_> = (0..clients)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                for i in 0..per {
                    let row = vec![((t * per + i) % 100) as i32; 784];
                    h.infer_mlp(row).expect("infer");
                }
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} requests in {dt:.3}s = {:.0} req/s",
        per * clients,
        per as f64 * clients as f64 / dt
    );
    println!("{}", h.stats().summary());
    c.shutdown();
}

fn cmd_trace(flags: &HashMap<String, String>) {
    use spoga::arch::accel::Accelerator;
    use spoga::optics::link_budget::ArchClass;
    use spoga::sim::engine::simulate_frame;
    let path = flags.get("file").cloned().unwrap_or_else(|| {
        "examples/traces/edge_net.trace".to_string()
    });
    let model = spoga::dnn::load_trace(&path).expect("parse trace");
    println!(
        "{}: {} layers, {:.3} GMACs/frame",
        model.name,
        model.layers.len(),
        model.total_macs() as f64 / 1e9
    );
    let cores: usize = flags.get("cores").and_then(|v| v.parse().ok()).unwrap_or(FIG5_CORES);
    let mut t = Table::new(vec!["Accelerator", "FPS", "FPS/W", "avg W"]);
    for arch in [ArchClass::Mwa, ArchClass::Maw, ArchClass::Amw] {
        for dr in DataRate::ALL {
            let accel = Accelerator::equal_cores(arch, dr, cores).unwrap();
            let f = simulate_frame(&accel, &model.workload());
            t.row(vec![
                f.accelerator.clone(),
                fmt_sig(f.fps(), 3),
                fmt_sig(f.fps_per_w(), 3),
                fmt_sig(f.avg_power_w(), 3),
            ]);
        }
    }
    println!("{}", t.render());
}

fn cmd_fidelity() {
    // Monte-Carlo sweep of dot-product fidelity vs link margin (the paper's
    // 4-bit-analog premise, quantified). See rust/src/fidelity/.
    let margins = [0.0, 10.0, 20.0, 30.0, 40.0, 60.0];
    let ks = [16usize, 64, 249];
    let pts = spoga::fidelity::fidelity_study(&margins, &ks, Some(8), 400, 99);
    let mut t = Table::new(vec!["margin dB", "K", "rel. RMSE", "exact-rate"]);
    for p in pts {
        t.row(vec![
            format!("{}", p.margin_db),
            p.k.to_string(),
            format!("{:.2e}", p.relative_rmse),
            format!("{:.2}", p.exact_rate),
        ]);
    }
    println!(
        "Analog fidelity (8-bit PWAB ADC, 400 Monte-Carlo dots/point):
{}",
        t.render()
    );
}

fn cmd_info() {
    let eng = spoga::runtime::Engine::new("artifacts");
    match eng {
        Ok(eng) => {
            println!("platform: {}", eng.platform());
            for a in &eng.manifest().artifacts {
                println!(
                    "  {} <- {:?} -> {:?}",
                    a.name,
                    a.inputs.iter().map(|t| t.dims.clone()).collect::<Vec<_>>(),
                    a.outputs.iter().map(|t| t.dims.clone()).collect::<Vec<_>>()
                );
            }
        }
        Err(e) => println!("no artifacts loaded ({e}); run `make artifacts`"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    match cmd {
        "scalability" => cmd_scalability(),
        "table2" => cmd_table2(),
        "fig5" => cmd_fig5(&flags),
        "gemm" => cmd_gemm(&flags),
        "serve" => cmd_serve(&flags),
        "trace" => cmd_trace(&flags),
        "fidelity" => cmd_fidelity(),
        "info" => cmd_info(),
        _ => {
            println!(
                "spoga — Scalable Photonic GEMM Accelerator reproduction\n\
                 usage: spoga <scalability|table2|fig5|gemm|serve|trace|fidelity|info> [flags]\n\
                 see README.md"
            );
        }
    }
}
