//! `spoga` — CLI for the SPOGA reproduction.
//!
//! Subcommands (hand-rolled parsing; no clap in the offline dep set):
//!
//! ```text
//! spoga scalability                       reproduce paper Table I
//! spoga table2                            print paper Table II constants
//! spoga fig5 [--cores N] [--metric M]     reproduce Fig 5(a/b/c) rows
//! spoga gemm [--artifact NAME]            run an AOT GEMM vs golden model
//! spoga serve [--requests N] [--workers W] [--backend B]
//!             [--shards N] [--split a:b=w1:w2] [--policy P]
//!             [--revive] [--max-shards M] [--window S]
//!             [--queue-depth N] [--shed]
//!             [--noise-grid K=..,adc=..]
//!             [--noise-margin DB] [--noise-seed N]
//!             [--listen ADDR] [--connect HOST:PORT[,HOST:PORT..]]
//!                                         self-driven serving demo over a
//!                                         shard fleet; B in {software,
//!                                         photonic, holylight, deapcnn}
//!                                         (photonic backends add live
//!                                         sim-FPS/W telemetry). --shards
//!                                         replicates; --split builds a
//!                                         heterogeneous weighted fleet,
//!                                         e.g. software:photonic=1:1;
//!                                         --policy in {rr, least}.
//!                                         --revive arms the resilience
//!                                         janitor (dead shards are health-
//!                                         probed and revived; on fleets
//!                                         with >1 shard the demo kills one
//!                                         shard's workers mid-burst to
//!                                         prove it); --max-shards M lets
//!                                         the fleet spawn shards under
//!                                         queue pressure up to M total.
//!                                         --queue-depth N bounds each
//!                                         shard's ingress queue (admission
//!                                         past it is a *typed shed*, never
//!                                         a blocked submitter); --shed
//!                                         arms a best-effort admission
//!                                         watermark and swaps the plain
//!                                         burst for the mixed-priority
//!                                         QoS demo (held-p99 vs shed
//!                                         table).
//!                                         --noise-margin arms analog noise
//!                                         injection on every photonic
//!                                         shard (content-keyed, seeded by
//!                                         --noise-seed, so two processes
//!                                         with equal seeds serve identical
//!                                         integers — the cross-process
//!                                         bit-identity contract).
//!                                         --noise-grid runs the noise-
//!                                         aware serving study instead:
//!                                         one noisy photonic shard per
//!                                         K × ADC-bits cell (self-
//!                                         contained synthetic manifest),
//!                                         emitting the served-accuracy vs
//!                                         sim-FPS/W frontier table; spec
//!                                         e.g. K=74,160,adc=6,8 (empty =
//!                                         the paper-range default grid).
//!                                         --listen exposes the fleet to
//!                                         other processes on a TCP socket
//!                                         (spoga wire protocol; first
//!                                         stdout line is
//!                                         `listening on IP:PORT` so
//!                                         callers can bind port 0);
//!                                         --connect drives the burst
//!                                         against remote shard servers
//!                                         instead of local coordinators
//!                                         (a pure-remote fleet).
//! spoga info                              artifact + platform diagnostics
//! ```

use std::collections::HashMap;

use spoga::metrics::{build_figure, Metric, FIG5_CORES};
use spoga::optics::{paper_table1, solve_table1};
use spoga::report::{fmt_sig, Table};
use spoga::units::DataRate;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            // A flag followed by another flag (or nothing) is boolean-style:
            // present with an empty value (e.g. `--revive`).
            match args.get(i + 1) {
                Some(next) if !next.starts_with("--") => {
                    m.insert(key.to_string(), next.clone());
                    i += 2;
                }
                _ => {
                    m.insert(key.to_string(), String::new());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    m
}

fn cmd_scalability() {
    let solved = solve_table1();
    let paper = paper_table1();
    let mut t = Table::new(vec![
        "Architecture",
        "1 GS/s (N,M)",
        "5 GS/s (N,M)",
        "10 GS/s (N,M)",
        "paper",
    ]);
    for (s, p) in solved.rows.iter().zip(paper.rows.iter()) {
        let cell = |nm: (usize, usize)| format!("{}/{}", nm.0, nm.1);
        t.row(vec![
            s.label.clone(),
            cell(s.nm[0]),
            cell(s.nm[1]),
            cell(s.nm[2]),
            format!("{} {} {}", cell(p.nm[0]), cell(p.nm[1]), cell(p.nm[2])),
        ]);
    }
    println!("Table I — scalability analysis (solved vs paper):\n{}", t.render());
}

fn cmd_table2() {
    use spoga::devices::{Adc, Dac};
    let mut t = Table::new(vec!["Converter", "BR (GS/s)", "Area (mm2)", "Power (mW)"]);
    for dr in DataRate::ALL {
        let a = Adc::for_rate(dr);
        t.row(vec![
            "ADC".to_string(),
            dr.gs().to_string(),
            a.area_mm2.to_string(),
            a.power_mw.to_string(),
        ]);
    }
    for dr in DataRate::ALL {
        let d = Dac::for_rate(dr);
        t.row(vec![
            "DAC".to_string(),
            dr.gs().to_string(),
            d.area_mm2.to_string(),
            d.power_mw.to_string(),
        ]);
    }
    println!("Table II — ADC/DAC design points:\n{}", t.render());
}

fn cmd_fig5(flags: &HashMap<String, String>) {
    let cores: usize =
        flags.get("cores").and_then(|v| v.parse().ok()).unwrap_or(FIG5_CORES);
    let metric = match flags.get("metric").map(String::as_str) {
        Some("fpsw") => Metric::FpsPerW,
        Some("fpswmm2") => Metric::FpsPerWPerMm2,
        _ => Metric::Fps,
    };
    let fig = build_figure(metric, &DataRate::ALL, cores).expect("figure");
    let mut header = vec!["Variant".to_string()];
    header.extend(fig.models.iter().cloned());
    header.push("gmean".to_string());
    let mut t = Table::new(header);
    for v in &fig.variants {
        let mut row = vec![v.name.clone()];
        row.extend(v.per_model.iter().map(|x| fmt_sig(*x, 3)));
        row.push(fmt_sig(v.gmean, 3));
        t.row(row);
    }
    println!("{} ({cores} cores/accelerator):\n{}", metric.figure(), t.render());
}

fn cmd_gemm(flags: &HashMap<String, String>) {
    let name = flags
        .get("artifact")
        .cloned()
        .unwrap_or_else(|| "gemm_64x64x64".to_string());
    let mut eng = spoga::runtime::Engine::new(
        flags.get("artifacts").map(String::as_str).unwrap_or("artifacts"),
    )
    .expect("engine (run `make artifacts` first)");
    let meta = eng.manifest().get(&name).expect("artifact").clone();
    let (m, k) = (meta.inputs[0].dims[0], meta.inputs[0].dims[1]);
    let n = meta.inputs[1].dims[1];
    let a: Vec<i32> = (0..m * k).map(|i| ((i * 37 + 11) % 255) as i32 - 127).collect();
    let b: Vec<i32> = (0..k * n).map(|i| ((i * 53 + 7) % 255) as i32 - 127).collect();
    let t0 = std::time::Instant::now();
    let out = eng.execute_i32_single(&name, &[&a, &b]).expect("execute");
    let dt = t0.elapsed();
    let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
    let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
    let golden = spoga::bitslice::gemm_i32(&a8, &b8, m, k, n).expect("golden");
    assert_eq!(out, golden, "artifact disagrees with golden model!");
    println!("{name}: {m}x{k}x{n} in {dt:?} — matches bitslice golden model");
}

/// `--backend` / `--split` backend names → `BackendKind`. Unknown names
/// abort: a typo in a fleet split would otherwise silently serve the wrong
/// A/B experiment (all-software, zero telemetry).
fn parse_backend(name: &str) -> spoga::runtime::BackendKind {
    use spoga::runtime::{BackendKind, PhotonicConfig};
    match name {
        "software" => BackendKind::Software,
        "photonic" | "spoga" => BackendKind::Photonic(PhotonicConfig::spoga()),
        "holylight" => BackendKind::Photonic(PhotonicConfig::holylight()),
        "deapcnn" => BackendKind::Photonic(PhotonicConfig::deapcnn()),
        other => {
            eprintln!(
                "unknown backend {other:?}: expected software|photonic|spoga|holylight|deapcnn"
            );
            std::process::exit(2);
        }
    }
}

/// Parse `--split software:photonic=1:3` into backends + optional weights.
/// Malformed weight tokens abort like unknown backend names do — a dropped
/// token would silently reshape the A/B split.
fn parse_split(spec: &str) -> (Vec<spoga::runtime::BackendKind>, Option<Vec<u32>>) {
    let (names, weights) = match spec.split_once('=') {
        Some((lhs, rhs)) => {
            let w: Vec<u32> = rhs
                .split(':')
                .map(|v| {
                    v.parse().unwrap_or_else(|_| {
                        eprintln!("bad weight {v:?} in --split {spec:?}: expected integers");
                        std::process::exit(2);
                    })
                })
                .collect();
            (lhs, Some(w))
        }
        None => (spec, None),
    };
    (names.split(':').map(parse_backend).collect(), weights)
}

/// `serve --noise-grid`: the noise-aware serving study. Builds a
/// self-contained fleet with one noise-injecting photonic shard per
/// K × ADC-bits cell (synthetic manifest in a temp dir — the study needs no
/// external artifacts), drives each cell's K-length probe traffic through
/// the t-stacked CNN path, and prints the served-accuracy vs sim-FPS/W
/// frontier table.
fn cmd_noise_grid(spec: &str, flags: &HashMap<String, String>) {
    use spoga::coordinator::{CoordinatorConfig, Fleet, FleetConfig, NoiseSweepGrid};
    use spoga::runtime::{BackendKind, PhotonicConfig};

    let grid = if spec.is_empty() {
        NoiseSweepGrid::paper_range()
    } else {
        NoiseSweepGrid::parse(spec).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        })
    };
    let frames: usize = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(16);
    let workers: usize = flags.get("workers").and_then(|v| v.parse().ok()).unwrap_or(2);

    let dir = std::env::temp_dir().join(format!("spoga-noise-grid-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp artifact dir");
    std::fs::write(dir.join("manifest.txt"), "mlp_b1 m.hlo.txt i32:1x16 i32:1x4\n")
        .expect("write manifest");
    let base = CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers,
        backend: BackendKind::Photonic(PhotonicConfig::spoga()),
        ..Default::default()
    };
    let fleet = Fleet::start(FleetConfig::noise_grid(base, &grid)).expect("noise-grid fleet");
    let h = fleet.handle();
    let served = grid.drive(&h, frames).expect("grid probe traffic");
    println!(
        "noise frontier: {} cells × {frames} t-stacked CNN probe frames ({served} replies)\n",
        grid.cells().len()
    );

    println!("{}", grid.frontier_table(&h).render());
    println!(
        "served-exact = 1 − noise_events/lanes for the traffic each cell actually\n\
         served, with per-request attribution intact through stacked batches."
    );
    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// `serve --listen ADDR`: expose the configured fleet to other processes
/// on a TCP socket speaking the spoga wire protocol. The first stdout line
/// is machine-parseable — `listening on IP:PORT` — so callers (CI, the
/// cross-process chaos suite) can bind `--listen 127.0.0.1:0` and read the
/// OS-assigned port back. Runs until a peer sends the Shutdown opcode.
fn serve_listen(addr: &str, fleet: spoga::coordinator::Fleet) {
    use spoga::net::{NetConfig, ServeTarget, ShardServer};
    let h = fleet.handle();
    let server = ShardServer::start(addr, ServeTarget::Fleet(h), NetConfig::default())
        .unwrap_or_else(|e| {
            eprintln!("serve --listen {addr}: {e}");
            std::process::exit(2);
        });
    println!("listening on {}", server.local_addr());
    while !server.stop_requested() {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("shutdown requested; draining connections");
    server.shutdown();
    fleet.shutdown();
}

/// `serve --connect HOST:PORT[,..]`: drive the client burst against remote
/// shard servers instead of local coordinators. Builds a *pure-remote*
/// fleet — every slot is a `RemoteShard` speaking the wire protocol — so
/// routing policy, retained-payload failover and the telemetry rollup are
/// exactly the local code paths (the local-vs-remote equivalence contract
/// in `coordinator::router`).
fn cmd_connect(spec: &str, flags: &HashMap<String, String>) {
    use spoga::coordinator::{Fleet, FleetConfig, RemoteShardConfig, RoutePolicy};
    let requests: usize = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(256);
    // MLP row width must match whatever artifacts the *servers* loaded;
    // --cols overrides the local default for synthetic-manifest servers.
    let cols: usize = flags.get("cols").and_then(|v| v.parse().ok()).unwrap_or(784);
    let policy = match flags.get("policy").map(String::as_str) {
        None | Some("rr") => RoutePolicy::RoundRobin,
        Some("least") => RoutePolicy::LeastQueueDepth,
        Some(other) => {
            eprintln!("unknown policy {other:?}: expected rr|least");
            std::process::exit(2);
        }
    };
    let remotes: Vec<RemoteShardConfig> =
        spec.split(',').filter(|a| !a.is_empty()).map(RemoteShardConfig::new).collect();
    if remotes.is_empty() {
        eprintln!("--connect needs at least one HOST:PORT");
        std::process::exit(2);
    }
    for r in &remotes {
        println!("remote shard: {}", r.addr);
    }
    let fleet = Fleet::start(FleetConfig { remotes, policy, ..Default::default() })
        .unwrap_or_else(|e| {
            eprintln!("connect: {e}");
            std::process::exit(2);
        });
    let h = fleet.handle();
    h.ping(std::time::Duration::from_secs(5)).expect("no shard server pongs");
    let t0 = std::time::Instant::now();
    let clients = 4usize;
    let per = requests / clients;
    let joins: Vec<_> = (0..clients)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                for i in 0..per {
                    let row = vec![((t * per + i) % 100) as i32; cols];
                    h.infer_mlp(row).expect("remote infer");
                }
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} remote requests in {dt:.3}s = {:.0} req/s over {} shard server(s)",
        per * clients,
        per as f64 * clients as f64 / dt,
        h.shard_count(),
    );
    println!("fleet rollup:\n{}", h.telemetry().summary());
    fleet.shutdown();
}

/// `serve --shed`: the QoS overload demo. With a best-effort admission
/// watermark armed (half the ingress depth), each client alternates High
/// and BestEffort rows; the readout is the held-vs-shed table — High
/// latency percentiles hold (refusals are rare and retried) while
/// BestEffort absorbs the typed sheds, and no submitting thread ever
/// blocks on a saturated queue.
fn run_shed_demo(h: &spoga::coordinator::FleetHandle, requests: usize) {
    use spoga::coordinator::Qos;
    let clients = 4usize;
    let per = (requests / clients).max(2);
    let t0 = std::time::Instant::now();
    let joins: Vec<_> = (0..clients)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                let (mut high_us, mut be_us) = (Vec::<u64>::new(), Vec::<u64>::new());
                let (mut high_retries, mut be_shed) = (0u64, 0u64);
                for i in 0..per {
                    let row = vec![((t * per + i) % 100) as i32; 784];
                    if i % 2 == 0 {
                        // High is never dropped: a refusal (only possible
                        // when the bounded ingress itself fills) is retried
                        // after a short backoff — and the wait is charged
                        // to the request's latency, honestly.
                        let s0 = std::time::Instant::now();
                        let rx = loop {
                            match h.submit_mlp_qos(row.clone(), Qos::default()) {
                                Ok(rx) => break rx,
                                Err(spoga::Error::Overloaded(_)) => {
                                    high_retries += 1;
                                    std::thread::sleep(std::time::Duration::from_micros(200));
                                }
                                Err(e) => panic!("high submit: {e}"),
                            }
                        };
                        rx.recv().expect("reply slot").expect("high infer");
                        high_us.push(s0.elapsed().as_micros() as u64);
                    } else {
                        let s0 = std::time::Instant::now();
                        match h.submit_mlp_qos(row, Qos::best_effort()) {
                            Ok(rx) => {
                                rx.recv().expect("reply slot").expect("best-effort infer");
                                be_us.push(s0.elapsed().as_micros() as u64);
                            }
                            Err(spoga::Error::Overloaded(_)) => be_shed += 1,
                            Err(e) => panic!("best-effort submit: {e}"),
                        }
                    }
                }
                (high_us, be_us, high_retries, be_shed)
            })
        })
        .collect();
    let (mut high_us, mut be_us) = (Vec::new(), Vec::new());
    let (mut high_retries, mut be_shed) = (0u64, 0u64);
    for j in joins {
        let (hu, bu, hr, bs) = j.join().unwrap();
        high_us.extend(hu);
        be_us.extend(bu);
        high_retries += hr;
        be_shed += bs;
    }
    let dt = t0.elapsed().as_secs_f64();
    high_us.sort_unstable();
    be_us.sort_unstable();
    let pct = |v: &[u64], p: f64| match v.is_empty() {
        true => "-".to_string(),
        false => v[((v.len() - 1) as f64 * p) as usize].to_string(),
    };
    let mut t = Table::new(vec!["priority", "served", "shed", "p50 us", "p99 us"]);
    t.row(vec![
        "High".to_string(),
        high_us.len().to_string(),
        format!("{high_retries} (retried)"),
        pct(&high_us, 0.50),
        pct(&high_us, 0.99),
    ]);
    t.row(vec![
        "BestEffort".to_string(),
        be_us.len().to_string(),
        format!("{be_shed} (typed)"),
        pct(&be_us, 0.50),
        pct(&be_us, 0.99),
    ]);
    println!(
        "mixed-priority burst: {} requests in {dt:.3}s — held vs shed:\n{}",
        high_us.len() as u64 + be_us.len() as u64 + be_shed,
        t.render()
    );
    println!(
        "every shed is a typed refusal (Error::Overloaded) at admission; \
         no client thread blocked on a full queue."
    );
}

fn cmd_serve(flags: &HashMap<String, String>) {
    use spoga::coordinator::{CoordinatorConfig, Fleet, FleetConfig, RoutePolicy};
    if let Some(spec) = flags.get("noise-grid") {
        // The grid study builds its own self-contained fleet; fleet-shape
        // flags would be silently discarded, so reject them like every
        // other conflicting/unknown flag combination in this command.
        for conflicting in [
            "backend", "split", "policy", "shards", "revive", "max-shards", "listen",
            "connect", "noise-margin", "noise-seed", "queue-depth", "shed",
        ] {
            if flags.contains_key(conflicting) {
                eprintln!(
                    "--noise-grid conflicts with --{conflicting}: the grid study builds \
                     one noisy photonic shard per cell itself"
                );
                std::process::exit(2);
            }
        }
        cmd_noise_grid(spec, flags);
        return;
    }
    if let Some(spec) = flags.get("connect") {
        // A pure-remote fleet has no local shard shape; shape flags would
        // be silently discarded, so reject them like every other conflict.
        for conflicting in [
            "backend", "split", "shards", "revive", "max-shards", "listen", "artifacts",
            "queue-depth", "shed",
        ] {
            if flags.contains_key(conflicting) {
                eprintln!(
                    "--connect conflicts with --{conflicting}: the shard servers own \
                     their fleet shape; only --requests/--policy/--cols apply here"
                );
                std::process::exit(2);
            }
        }
        cmd_connect(spec, flags);
        return;
    }
    if flags.contains_key("listen") && flags.contains_key("requests") {
        eprintln!(
            "--listen conflicts with --requests: a shard server serves remote clients; \
             it does not drive its own burst"
        );
        std::process::exit(2);
    }
    if flags.contains_key("listen") && flags.contains_key("shed") {
        eprintln!(
            "--listen conflicts with --shed: the shed demo drives its own burst; \
             a listening server only bounds its queue (--queue-depth applies)"
        );
        std::process::exit(2);
    }
    let requests: usize = flags.get("requests").and_then(|v| v.parse().ok()).unwrap_or(256);
    let workers: usize = flags.get("workers").and_then(|v| v.parse().ok()).unwrap_or(2);

    // Fleet shape: --split names heterogeneous backends (with optional
    // weights); --shards sets the shard count (default: one per split
    // backend, or 1). The single-coordinator path is just the 1-shard
    // fleet — there is one serving path.
    let (mut kinds, weights) = match flags.get("split") {
        Some(spec) => parse_split(spec),
        None => (
            vec![parse_backend(flags.get("backend").map(String::as_str).unwrap_or("software"))],
            None,
        ),
    };
    // --noise-margin DB arms content-keyed analog noise on every photonic
    // shard. The seed (--noise-seed, default fixed) keys the noise, so two
    // processes serving the same payloads at the same margin+seed produce
    // identical integers — what the cross-process chaos suite pins.
    if let Some(margin) = flags.get("noise-margin") {
        let margin_db: f64 = margin.parse().unwrap_or_else(|_| {
            eprintln!("bad --noise-margin {margin:?}: expected a dB value (e.g. 0 or 20)");
            std::process::exit(2);
        });
        let seed: u64 =
            flags.get("noise-seed").and_then(|v| v.parse().ok()).unwrap_or(0xDEAD_5EED);
        let noise = spoga::fidelity::NoiseParams::from_link_margin(margin_db);
        for k in &mut kinds {
            if let spoga::runtime::BackendKind::Photonic(cfg) = k {
                *k = spoga::runtime::BackendKind::Photonic(cfg.clone().with_noise(noise, seed));
            }
        }
    }
    let shards: usize = flags
        .get("shards")
        .and_then(|v| v.parse().ok())
        .unwrap_or(kinds.len())
        .max(1);
    // Count mismatches would silently reshape the experiment (dropped
    // backends or recycled weights), so reject them like backend typos.
    if let Some(w) = &weights {
        if w.len() != kinds.len() {
            eprintln!(
                "--split has {} backends but {} weights; counts must match",
                kinds.len(),
                w.len()
            );
            std::process::exit(2);
        }
    }
    if shards % kinds.len() != 0 {
        eprintln!(
            "--shards {shards} is not a multiple of the {} backend(s) in --split; \
             every backend must get the same shard count",
            kinds.len()
        );
        std::process::exit(2);
    }
    let mut base = CoordinatorConfig {
        artifact_dir: flags
            .get("artifacts")
            .cloned()
            .unwrap_or_else(|| "artifacts".to_string()),
        workers,
        ..Default::default()
    };
    // --window S overrides the dynamic-batching window (the chaos suite
    // uses a long window on child servers to hold accepted jobs mid-kill).
    if let Some(w) = flags.get("window") {
        base.max_batch_wait_s = w.parse().unwrap_or_else(|_| {
            eprintln!("bad --window {w:?}: expected seconds (e.g. 0.5)");
            std::process::exit(2);
        });
    }
    // --queue-depth N bounds each shard's ingress queue; admission past the
    // bound is a typed shed (Error::Overloaded), never a blocked submitter.
    // --shed arms a best-effort watermark at half that depth (a tight
    // default depth when unset, so the demo actually sheds) and swaps the
    // plain burst for the mixed-priority QoS demo.
    let shed_demo = flags.contains_key("shed");
    if let Some(v) = flags.get("queue-depth") {
        base.queue_depth = v.parse().ok().filter(|&d: &usize| d >= 1).unwrap_or_else(|| {
            eprintln!("bad --queue-depth {v:?}: expected an integer >= 1");
            std::process::exit(2);
        });
    } else if shed_demo {
        base.queue_depth = 4;
    }
    if shed_demo {
        base.best_effort_watermark = Some((base.queue_depth / 2).max(1));
        println!(
            "shed demo: queue-depth {} per shard, best-effort watermark {}",
            base.queue_depth,
            (base.queue_depth / 2).max(1)
        );
    }
    let shard_cfgs: Vec<CoordinatorConfig> = (0..shards)
        .map(|i| CoordinatorConfig { backend: kinds[i % kinds.len()].clone(), ..base.clone() })
        .collect();
    let policy = match (flags.get("policy").map(String::as_str), weights) {
        (None, Some(w)) => {
            RoutePolicy::Weighted((0..shards).map(|i| w[i % w.len()]).collect())
        }
        (None, None) | (Some("rr"), None) => RoutePolicy::RoundRobin,
        (Some("least"), None) => RoutePolicy::LeastQueueDepth,
        (Some("rr"), Some(_)) | (Some("least"), Some(_)) => {
            eprintln!("--policy conflicts with --split weights; use one or the other");
            std::process::exit(2);
        }
        (Some(other), _) => {
            eprintln!("unknown policy {other:?}: expected rr|least");
            std::process::exit(2);
        }
    };
    // Resilience flags: --revive arms dead-shard revival, --max-shards M
    // allows pressure-driven spawning up to M total shards. Either one
    // attaches the autoscale policy (and its janitor thread).
    let revive = flags.contains_key("revive");
    let max_shards: usize = flags
        .get("max-shards")
        .map(|v| {
            v.parse().unwrap_or_else(|_| {
                eprintln!("bad --max-shards {v:?}: expected an integer");
                std::process::exit(2);
            })
        })
        .unwrap_or(0);
    let autoscale = (revive || max_shards > shards).then(|| spoga::coordinator::FleetAutoscale {
        revive,
        max_shards,
        ..Default::default()
    });
    for (i, c) in shard_cfgs.iter().enumerate() {
        println!("shard {i}: backend {}", c.backend.label());
    }
    let fleet =
        Fleet::start(FleetConfig { shards: shard_cfgs, policy, autoscale, ..Default::default() })
            .expect("fleet");
    let h = fleet.handle();
    if let Some(addr) = flags.get("listen") {
        serve_listen(addr, fleet);
        return;
    }
    if shed_demo {
        run_shed_demo(&h, requests);
        for (i, label) in h.shard_labels().iter().enumerate() {
            println!("{label}: {}", h.shard_stats(i).summary());
        }
        println!("fleet rollup:\n{}", h.telemetry().summary());
        fleet.shutdown();
        return;
    }
    let t0 = std::time::Instant::now();
    let clients = 4usize;
    let per = requests / clients;
    let joins: Vec<_> = (0..clients)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                for i in 0..per {
                    let row = vec![((t * per + i) % 100) as i32; 784];
                    h.infer_mlp(row).expect("infer");
                }
            })
        })
        .collect();
    // With revival armed and a redundant fleet, prove the resilience layer
    // live: kill shard 0's workers mid-burst. Blocking clients fail over
    // (retained-payload retry), and the janitor probes the shard back.
    if revive && h.shard_count() > 1 {
        std::thread::sleep(std::time::Duration::from_millis(2));
        println!("chaos: retiring shard 0's workers mid-burst (janitor will revive)");
        let _ = h.shard(0).retire_workers();
    }
    for j in joins {
        j.join().unwrap();
    }
    if revive && h.shard_count() > 1 {
        // Deterministic revival before the readout (the janitor may
        // already have beaten us to it — revive_dead_shards is idempotent).
        h.revive_dead_shards();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} requests in {dt:.3}s = {:.0} req/s over {} shard(s)",
        per * clients,
        per as f64 * clients as f64 / dt,
        h.shard_count(),
    );
    for (i, label) in h.shard_labels().iter().enumerate() {
        println!("{label}: {}", h.shard_stats(i).summary());
    }
    println!("fleet rollup:\n{}", h.telemetry().summary());
    fleet.shutdown();
}

fn cmd_trace(flags: &HashMap<String, String>) {
    use spoga::arch::accel::Accelerator;
    use spoga::optics::link_budget::ArchClass;
    use spoga::sim::engine::simulate_frame;
    let path = flags.get("file").cloned().unwrap_or_else(|| {
        "examples/traces/edge_net.trace".to_string()
    });
    let model = spoga::dnn::load_trace(&path).expect("parse trace");
    println!(
        "{}: {} layers, {:.3} GMACs/frame",
        model.name,
        model.layers.len(),
        model.total_macs() as f64 / 1e9
    );
    let cores: usize = flags.get("cores").and_then(|v| v.parse().ok()).unwrap_or(FIG5_CORES);
    let mut t = Table::new(vec!["Accelerator", "FPS", "FPS/W", "avg W"]);
    for arch in [ArchClass::Mwa, ArchClass::Maw, ArchClass::Amw] {
        for dr in DataRate::ALL {
            let accel = Accelerator::equal_cores(arch, dr, cores).unwrap();
            let f = simulate_frame(&accel, &model.workload());
            t.row(vec![
                f.accelerator.clone(),
                fmt_sig(f.fps(), 3),
                fmt_sig(f.fps_per_w(), 3),
                fmt_sig(f.avg_power_w(), 3),
            ]);
        }
    }
    println!("{}", t.render());
}

fn cmd_fidelity() {
    // Monte-Carlo sweep of dot-product fidelity vs link margin (the paper's
    // 4-bit-analog premise, quantified). See rust/src/fidelity/.
    let margins = [0.0, 10.0, 20.0, 30.0, 40.0, 60.0];
    let ks = [16usize, 64, 249];
    let pts = spoga::fidelity::fidelity_study(&margins, &ks, Some(8), 400, 99);
    let mut t = Table::new(vec!["margin dB", "K", "rel. RMSE", "exact-rate"]);
    for p in pts {
        t.row(vec![
            format!("{}", p.margin_db),
            p.k.to_string(),
            format!("{:.2e}", p.relative_rmse),
            format!("{:.2}", p.exact_rate),
        ]);
    }
    println!(
        "Analog fidelity (8-bit PWAB ADC, 400 Monte-Carlo dots/point):
{}",
        t.render()
    );
}

fn cmd_info() {
    let eng = spoga::runtime::Engine::new("artifacts");
    match eng {
        Ok(eng) => {
            println!("platform: {}", eng.platform());
            for a in &eng.manifest().artifacts {
                println!(
                    "  {} <- {:?} -> {:?}",
                    a.name,
                    a.inputs.iter().map(|t| t.dims.clone()).collect::<Vec<_>>(),
                    a.outputs.iter().map(|t| t.dims.clone()).collect::<Vec<_>>()
                );
            }
        }
        Err(e) => println!("no artifacts loaded ({e}); run `make artifacts`"),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    match cmd {
        "scalability" => cmd_scalability(),
        "table2" => cmd_table2(),
        "fig5" => cmd_fig5(&flags),
        "gemm" => cmd_gemm(&flags),
        "serve" => cmd_serve(&flags),
        "trace" => cmd_trace(&flags),
        "fidelity" => cmd_fidelity(),
        "info" => cmd_info(),
        _ => {
            println!(
                "spoga — Scalable Photonic GEMM Accelerator reproduction\n\
                 usage: spoga <scalability|table2|fig5|gemm|serve|trace|fidelity|info> [flags]\n\
                 see README.md"
            );
        }
    }
}
