//! The per-rule scanners behind [`crate::analysis`]. Each rule is derived
//! from a bug class this repo has already paid for; the catalogue with
//! provenance lives in the module docs of [`crate::analysis`].

use super::lexer::{is_ident, is_ident_byte};
use super::{Finding, SourceFile};
use std::collections::BTreeSet;

/// R1 — panicking lock acquisition outside `#[cfg(test)]`.
pub const NO_POISON_PANIC: &str = "no-poison-panic";
/// R2 — `unsafe` without an adjacent `// SAFETY:` comment.
pub const SAFETY_COMMENT: &str = "safety-comment";
/// R3 — `debug_assert!` guarding serving state outside `testing/`.
pub const NO_RELEASE_SILENT_GUARDS: &str = "no-release-silent-guards";
/// R4 — opcode/codec/error-tag symmetry in the wire protocol.
pub const WIRE_CODEC_SYMMETRY: &str = "wire-codec-symmetry";
/// R5 — blocking send on the bounded coordinator ingress.
pub const NO_BLOCKING_INGRESS: &str = "no-blocking-ingress";
/// Meta-rule: `lint:allow` sites must justify themselves and suppress
/// something real.
pub const ALLOW_JUSTIFICATION: &str = "allow-justification";

/// Run every rule over one parsed file. Findings are unsorted and
/// unsuppressed; [`crate::analysis::lint_source`] applies the
/// `lint:allow` machinery.
pub fn scan(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    no_poison_panic(file, &mut out);
    safety_comment(file, &mut out);
    no_release_silent_guards(file, &mut out);
    wire_codec_symmetry(file, &mut out);
    no_blocking_ingress(file, &mut out);
    out
}

/// The panicking acquisition chains R1 bans. Matched on the condensed
/// stream, so formatting (multi-line builder chains) cannot hide them.
const POISON_CHAINS: [&str; 6] = [
    ".lock().unwrap()",
    ".read().unwrap()",
    ".write().unwrap()",
    ".lock().expect(",
    ".read().expect(",
    ".write().expect(",
];

fn no_poison_panic(file: &SourceFile, out: &mut Vec<Finding>) {
    for pat in POISON_CHAINS {
        for at in file.cond.find_all(pat) {
            let line = file.cond.line_at(at);
            if file.in_test_code(line) {
                continue;
            }
            out.push(Finding {
                rule: NO_POISON_PANIC,
                file: file.path.clone(),
                line,
                message: format!(
                    "`{pat}…` panics on a poisoned lock; map poison to a typed error \
                     (Error::Coordinator / Error::Remote) on fallible paths or recover \
                     via crate::sync::lock_recovered on must-complete paths"
                ),
            });
        }
    }
}

fn safety_comment(file: &SourceFile, out: &mut Vec<Finding>) {
    for (idx, text) in file.lines.iter().enumerate() {
        let line = (idx + 1) as u32;
        let bytes = text.as_bytes();
        let mut from = 0usize;
        while let Some(pos) = text[from..].find("unsafe") {
            let at = from + pos;
            from = at + "unsafe".len();
            let start_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
            let end = at + "unsafe".len();
            let end_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
            if !start_ok || !end_ok {
                continue;
            }
            if file.in_test_code(line) {
                continue;
            }
            if file.has_safety_comment_at(line) || has_safety_comment_above(file, idx) {
                continue;
            }
            out.push(Finding {
                rule: SAFETY_COMMENT,
                file: file.path.clone(),
                line,
                message: "`unsafe` without an adjacent `// SAFETY:` comment stating the \
                          invariant that makes it sound (doc `# Safety` sections describe \
                          the caller's obligation; the comment must state why *this* site \
                          meets it)"
                    .to_string(),
            });
        }
    }
}

/// Walk upward from the line above `idx` (0-based) through the item's
/// prologue — blank lines, attribute lines, and comment lines — looking
/// for a `SAFETY:` comment. A non-prologue code line ends the walk.
fn has_safety_comment_above(file: &SourceFile, idx: usize) -> bool {
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let line = (k + 1) as u32;
        if file.has_safety_comment_at(line) {
            return true;
        }
        let has_comment = file.scrubbed.comments.iter().any(|c| c.line == line);
        if !has_comment && !is_prologue_line(&file.lines[k]) {
            return false;
        }
    }
    false
}

/// Lines that may sit between a SAFETY comment and its `unsafe` site:
/// blanks (including comment-only lines, whose code is all spaces after
/// scrubbing) and attributes (possibly multi-line, ending `)]`).
fn is_prologue_line(scrubbed_line: &str) -> bool {
    let t = scrubbed_line.trim();
    t.is_empty() || t.starts_with('#') || t.ends_with(")]") || t.ends_with(']')
}

/// Identifiers that mark a predicate as guarding request/serving state
/// (frame lengths, nonces, rows, runs, planes, QoS bookkeeping). Paper
/// context: served GEMM must be bit-exact, so these checks must hold in
/// release builds — a `debug_assert!` silently vanishes there.
const SERVING_STATE_MARKERS: [&str; 9] =
    ["len", "nonce", "frame", "row", "run", "job", "plane", "qos", "deadline"];

fn no_release_silent_guards(file: &SourceFile, out: &mut Vec<Finding>) {
    if file.path.contains("testing/") {
        return;
    }
    let text = &file.cond.text;
    let bytes = text.as_bytes();
    for at in file.cond.find_all("debug_assert") {
        if at > 0 && is_ident_byte(bytes[at - 1]) {
            continue;
        }
        let line = file.cond.line_at(at);
        if file.in_test_code(line) {
            continue;
        }
        let Some(pred) = macro_args(text, at) else { continue };
        if SERVING_STATE_MARKERS.iter().any(|m| pred.contains(m)) {
            let shown: String = pred.chars().take(60).collect();
            out.push(Finding {
                rule: NO_RELEASE_SILENT_GUARDS,
                file: file.path.clone(),
                line,
                message: format!(
                    "release-silent `debug_assert` guards serving state (`{shown}`); \
                     enforce it in release builds with a typed Error::Shape / \
                     Error::Coordinator instead"
                ),
            });
        }
    }
}

/// Text between the macro's outermost parentheses, starting at `at`.
fn macro_args(text: &str, at: usize) -> Option<&str> {
    let bytes = text.as_bytes();
    let open = bytes[at..].iter().position(|&b| b == b'(')? + at;
    let mut depth = 0usize;
    for (off, &b) in bytes[open..].iter().enumerate() {
        match b {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(&text[open + 1..open + off]);
                }
            }
            _ => {}
        }
    }
    None
}

fn no_blocking_ingress(file: &SourceFile, out: &mut Vec<Finding>) {
    for at in file.cond.find_all(".send(Job::") {
        let line = file.cond.line_at(at);
        if file.in_test_code(line) {
            continue;
        }
        out.push(Finding {
            rule: NO_BLOCKING_INGRESS,
            file: file.path.clone(),
            line,
            message: "blocking `.send(Job::…)` on the bounded coordinator ingress can \
                      deadlock submitters when the queue is full (PR 9's bug class); \
                      admit via `try_send` and shed typed (Error::Overloaded) or bound \
                      the retry"
                .to_string(),
        });
    }
}

fn wire_codec_symmetry(file: &SourceFile, out: &mut Vec<Finding>) {
    let text = &file.cond.text;
    let Some(enum_at) = text.find("enumOpcode") else { return };
    let enum_line = file.cond.line_at(enum_at);
    let mut fail = |line: u32, message: String| {
        out.push(Finding { rule: WIRE_CODEC_SYMMETRY, file: file.path.clone(), line, message });
    };

    let Some((open, close)) = super::lexer::brace_block(text, enum_at) else {
        fail(enum_line, "could not parse the `enum Opcode` body".to_string());
        return;
    };
    let variants: Vec<String> = text[open + 1..close]
        .split(',')
        .filter_map(|seg| {
            let name: String = seg.chars().take_while(|c| is_ident(*c)).collect();
            let upper = name.chars().next().is_some_and(|c| c.is_ascii_uppercase());
            (upper).then_some(name)
        })
        .collect();
    if variants.is_empty() {
        fail(enum_line, "`enum Opcode` has no parsable variants".to_string());
        return;
    }

    // Every variant must survive the wire round trip: present in `from_u8`.
    match text.find("fnfrom_u8").and_then(|at| super::lexer::brace_block(text, at)) {
        None => fail(enum_line, "no `fn from_u8` decode map found next to `enum Opcode`".into()),
        Some((fo, fc)) => {
            let body = &text[fo..=fc];
            for v in &variants {
                if !body.contains(&format!("Opcode::{v}")) {
                    fail(
                        enum_line,
                        format!("`Opcode::{v}` is encodable but missing from `from_u8`"),
                    );
                }
            }
        }
    }

    // Codec symmetry: the set of `fn encode_*` names must pair with the
    // set of `fn decode_*` names. Test-only helpers are exempt.
    let encode = codec_suffixes(file, "fnencode_");
    let decode = codec_suffixes(file, "fndecode_");
    for s in &encode {
        if !decode.contains(s) {
            fail(enum_line, format!("`encode_{s}` has no matching `decode_{s}`"));
        }
    }
    for s in &decode {
        if !encode.contains(s) {
            fail(enum_line, format!("`decode_{s}` has no matching `encode_{s}`"));
        }
    }
    // Payload-carrying submit opcodes must have a codec pair at all;
    // control opcodes (Ping/Pong/Shutdown: empty payloads) need none.
    for v in &variants {
        if let Some(rest) = v.strip_prefix("Submit") {
            let suffix = rest.to_ascii_lowercase();
            if !(encode.contains(&suffix) && decode.contains(&suffix)) {
                fail(
                    enum_line,
                    format!("payload opcode `{v}` lacks an encode_{suffix}/decode_{suffix} pair"),
                );
            }
        }
    }

    // Error-tag round trip: every tag emitted by `encode_error`'s
    // tuple-literal arms (`=> (N, …`) must be matched by `decode_error`
    // (`N =>` or `N | M =>` arms).
    let enc_body = fn_body(file, text, "fnencode_error");
    let dec_body = fn_body(file, text, "fndecode_error");
    if let (Some(enc), Some(dec)) = (&enc_body, &dec_body) {
        let enc_tags = tuple_arm_tags(enc);
        let dec_tags = match_arm_tags(dec);
        for t in &enc_tags {
            if !dec_tags.contains(t) {
                fail(
                    enum_line,
                    format!("error tag {t} is produced by encode_error but never matched by decode_error"),
                );
            }
        }
        if enc_tags.is_empty() {
            fail(enum_line, "encode_error has no recognizable `=> (tag, …)` arms".into());
        }
    } else if enc_body.is_some() != dec_body.is_some() {
        fail(enum_line, "encode_error/decode_error are not both present".into());
    }
}

/// Suffixes of `fn {prefix}*` definitions outside test code. No
/// leading-boundary check: condensing glues visibility onto the keyword
/// (`pub fn encode_x` → `pubfnencode_x`), so the byte before `fn` is
/// routinely an identifier character.
fn codec_suffixes(file: &SourceFile, prefix: &str) -> BTreeSet<String> {
    let text = &file.cond.text;
    let mut set = BTreeSet::new();
    for at in file.cond.find_all(prefix) {
        if file.in_test_code(file.cond.line_at(at)) {
            continue;
        }
        let suffix: String = text[at + prefix.len()..].chars().take_while(|c| is_ident(*c)).collect();
        if !suffix.is_empty() {
            set.insert(suffix);
        }
    }
    set
}

/// Body text of the first non-test `fn` whose condensed header starts
/// with `marker`.
fn fn_body<'a>(file: &SourceFile, text: &'a str, marker: &str) -> Option<&'a str> {
    for at in file.cond.find_all(marker) {
        if file.in_test_code(file.cond.line_at(at)) {
            continue;
        }
        let (open, close) = super::lexer::brace_block(text, at)?;
        return Some(&text[open..=close]);
    }
    None
}

/// Tags appearing as `=> (N, …` tuple-literal match arms.
fn tuple_arm_tags(body: &str) -> BTreeSet<u64> {
    let bytes = body.as_bytes();
    let mut tags = BTreeSet::new();
    for (i, _) in body.match_indices("=>(") {
        let mut j = i + 3;
        let mut n: u64 = 0;
        let mut any = false;
        while j < bytes.len() && bytes[j].is_ascii_digit() {
            n = n * 10 + u64::from(bytes[j] - b'0');
            any = true;
            j += 1;
        }
        if any {
            tags.insert(n);
        }
    }
    tags
}

/// Tags appearing as `N =>` or `N | M =>` match-arm patterns.
fn match_arm_tags(body: &str) -> BTreeSet<u64> {
    let bytes = body.as_bytes();
    let mut tags = BTreeSet::new();
    let mut j = 0usize;
    while j < bytes.len() {
        if bytes[j].is_ascii_digit() && (j == 0 || !is_ident_byte(bytes[j - 1])) {
            let start = j;
            while j < bytes.len() && bytes[j].is_ascii_digit() {
                j += 1;
            }
            let arrow = bytes.get(j) == Some(&b'|')
                || (bytes.get(j) == Some(&b'=') && bytes.get(j + 1) == Some(&b'>'));
            if arrow {
                if let Ok(n) = body[start..j].parse::<u64>() {
                    tags.insert(n);
                }
            }
        } else {
            j += 1;
        }
    }
    tags
}
