//! # `spoga-lint` — repo-specific static invariant analysis
//!
//! Zero-dependency static analysis over this crate's own sources. The
//! serving stack promises bit-exact, panic-free, typed-error integer GEMM
//! serving end to end; the invariants behind that promise were enforced
//! only by convention until PRs 6, 8, and 9 each paid for one hand-found
//! violation. This module turns those one-off fixes into machine-checked
//! rules that run in tier-1 (`rust/tests/static_invariants.rs` walks
//! `rust/src/**/*.rs` and fails `cargo test` on any violation) and as a
//! standalone binary (`cargo run --bin spoga-lint [ROOT…]`).
//!
//! ## Rule catalogue
//!
//! | rule | invariant | provenance |
//! |---|---|---|
//! | `no-poison-panic` (R1) | no `.lock()/.read()/.write()` followed by `.unwrap()`/`.expect(` outside `#[cfg(test)]`; poison maps to the typed error taxonomy or recovers via `crate::sync::lock_recovered` | PR 6: a panicking worker poisoned the shard slot table and every later request panicked instead of getting `Error::Coordinator` |
//! | `safety-comment` (R2) | every `unsafe` occurrence in non-test code sits directly under a `// SAFETY:` comment stating the invariant that makes *this site* sound (a doc `# Safety` section states the caller's obligation — it does not discharge it) | PR 8's AVX2 micro-kernels: 8 unsafe sites, only 2 justified |
//! | `no-release-silent-guards` (R3) | no `debug_assert!` whose predicate mentions request/serving state (lengths, nonces, frames, rows, runs, planes, QoS, deadlines) outside `testing/` — served-exactness checks must hold in release builds | PR 8: `check_frame_nonces` was debug-only, so release builds silently skipped a bit-exactness guard |
//! | `wire-codec-symmetry` (R4) | every `Opcode` variant survives `from_u8`; `encode_*`/`decode_*` functions pair up; payload (`Submit*`) opcodes have a codec pair; every error tag `encode_error` emits is matched by `decode_error` | PR 6/PR 9: wire v2 grew tags 9/10 — an asymmetric codec turns a typed error into `FrameCorrupt` at the peer |
//! | `no-blocking-ingress` (R5) | no blocking `.send(Job::…)` on the bounded coordinator ingress outside `#[cfg(test)]`; admission is `try_send` + typed shedding or a bounded retry | PR 9: full-queue ingress deadlocked submitters forever instead of shedding `Error::Overloaded` |
//!
//! Rules scan *scrubbed* text (comments and string/char literal bodies
//! blanked by [`lexer::scrub`], multi-line chains normalized by
//! [`lexer::condense`]), so formatting or literal text cannot hide or
//! fake a violation.
//!
//! ## The `lint:allow` contract
//!
//! A site-local escape hatch: a comment containing
//! `lint:allow(<rule>) <justification>` on the violating line or the line
//! above suppresses that rule there. Three properties keep exceptions
//! honest — all three are themselves linted (rule `allow-justification`):
//!
//! 1. an allow **must carry a justification** (empty reason → violation,
//!    and the underlying finding is *not* suppressed);
//! 2. an allow **must suppress something** (a stale or misspelled allow is
//!    a violation, so dead exceptions cannot accumulate);
//! 3. every exception is **counted and printed** by [`LintReport::render`],
//!    so intentional deviations are visible in tier-1 output instead of
//!    invisible in review.
//!
//! Candidate future rules (see ROADMAP): error-taxonomy exhaustiveness
//! (every `Error` variant constructed somewhere reachable and carried by
//! the wire codec) and bounded-channel construction sites (every
//! `sync_channel` capacity traced to a config knob, not a bare literal).

pub mod lexer;
pub mod rules;

use lexer::{cfg_test_spans, condense, scrub, Condensed, Scrubbed};
use std::path::{Path, PathBuf};

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// One justified, counted `lint:allow` exception.
#[derive(Debug, Clone)]
pub struct Suppression {
    pub rule: String,
    pub file: String,
    pub line: u32,
    pub justification: String,
}

/// Aggregate lint outcome over one or more files.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Files scanned.
    pub files: usize,
    /// Violations (including unjustified or stale `lint:allow` sites).
    pub findings: Vec<Finding>,
    /// Justified exceptions that suppressed a real finding.
    pub suppressions: Vec<Suppression>,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report: one line per finding, then the exception
    /// ledger, then a one-line summary.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            s.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        for a in &self.suppressions {
            s.push_str(&format!(
                "{}:{}: allowed [{}]: {}\n",
                a.file, a.line, a.rule, a.justification
            ));
        }
        s.push_str(&format!(
            "spoga-lint: {} file(s), {} violation(s), {} allowed exception(s)\n",
            self.files,
            self.findings.len(),
            self.suppressions.len()
        ));
        s
    }

    fn sort(&mut self) {
        self.findings.sort_by(|a, b| {
            (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
        });
        self.suppressions.sort_by(|a, b| {
            (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line))
        });
    }
}

/// A parsed source file, shared by all rule scanners.
pub struct SourceFile {
    pub path: String,
    pub scrubbed: Scrubbed,
    pub cond: Condensed,
    /// Scrubbed code split into lines (for line-local upward walks).
    pub lines: Vec<String>,
    test_spans: Vec<(u32, u32)>,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str) -> SourceFile {
        let scrubbed = scrub(src);
        let cond = condense(&scrubbed.code);
        let test_spans = cfg_test_spans(&cond);
        let lines = scrubbed.code.lines().map(str::to_string).collect();
        SourceFile { path: path.to_string(), scrubbed, cond, lines, test_spans }
    }

    /// Is `line` inside a `#[cfg(test)]`-gated item?
    pub fn in_test_code(&self, line: u32) -> bool {
        self.test_spans.iter().any(|&(a, b)| a <= line && line <= b)
    }

    /// Does a comment on exactly `line` contain `SAFETY:`?
    pub fn has_safety_comment_at(&self, line: u32) -> bool {
        self.scrubbed.comments.iter().any(|c| c.line == line && c.text.contains("SAFETY:"))
    }
}

/// A `lint:allow(<rule>) <justification>` comment site.
struct AllowSite {
    rule: String,
    line: u32,
    justification: String,
}

fn parse_allows(scrubbed: &Scrubbed) -> Vec<AllowSite> {
    const MARKER: &str = "lint:allow(";
    let mut sites = Vec::new();
    for c in &scrubbed.comments {
        // Directives live in plain comments only; doc comments merely
        // *describe* the contract (as this module's own docs do).
        let t = c.text.trim_start();
        if t.starts_with("///") || t.starts_with("//!") || t.starts_with("/**") || t.starts_with("/*!") {
            continue;
        }
        let Some(pos) = c.text.find(MARKER) else { continue };
        let rest = &c.text[pos + MARKER.len()..];
        let Some(close) = rest.find(')') else { continue };
        let rule = rest[..close].trim().to_string();
        let justification = rest[close + 1..]
            .trim()
            .trim_start_matches(['-', ':', '—'])
            .trim()
            .to_string();
        sites.push(AllowSite { rule, line: c.line, justification });
    }
    sites
}

/// Lint one source text under the given display path. `path` matters to
/// path-scoped rules (`testing/` is exempt from R3).
pub fn lint_source(path: &str, src: &str) -> LintReport {
    let file = SourceFile::parse(path, src);
    let allows = parse_allows(&file.scrubbed);
    let mut raw = rules::scan(&file);
    raw.sort_by_key(|f| (f.line, f.rule));

    let mut report = LintReport { files: 1, ..LintReport::default() };
    let mut used = vec![false; allows.len()];
    for f in raw {
        let hit = allows
            .iter()
            .position(|a| a.rule == f.rule && (a.line == f.line || a.line + 1 == f.line));
        match hit {
            Some(i) if !allows[i].justification.is_empty() => {
                used[i] = true;
                report.suppressions.push(Suppression {
                    rule: allows[i].rule.clone(),
                    file: path.to_string(),
                    line: f.line,
                    justification: allows[i].justification.clone(),
                });
            }
            Some(i) => {
                // Unjustified allow: flag the allow AND keep the finding.
                used[i] = true;
                report.findings.push(Finding {
                    rule: rules::ALLOW_JUSTIFICATION,
                    file: path.to_string(),
                    line: allows[i].line,
                    message: format!(
                        "lint:allow({}) has no justification — explain why this \
                         exception is sound",
                        allows[i].rule
                    ),
                });
                report.findings.push(f);
            }
            None => report.findings.push(f),
        }
    }
    for (i, a) in allows.iter().enumerate() {
        if !used[i] {
            report.findings.push(Finding {
                rule: rules::ALLOW_JUSTIFICATION,
                file: path.to_string(),
                line: a.line,
                message: format!(
                    "lint:allow({}) suppresses nothing (stale, misspelled rule, or \
                     wrong line) — remove it or move it to the violating line",
                    a.rule
                ),
            });
        }
    }
    report.sort();
    report
}

/// Lint every `*.rs` file under `root` (recursive, sorted order).
pub fn lint_dir(root: &Path) -> crate::Result<LintReport> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    paths.sort();
    let mut report = LintReport::default();
    for p in &paths {
        let src = std::fs::read_to_string(p)?;
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        let one = lint_source(&rel, &src);
        report.files += 1;
        report.findings.extend(one.findings);
        report.suppressions.extend(one.suppressions);
    }
    report.sort();
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> crate::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if entry.file_type()?.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}
