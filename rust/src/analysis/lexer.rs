//! Comment- and string-aware source preparation for [`crate::analysis`].
//!
//! The rule scanners in [`crate::analysis::rules`] are substring matchers;
//! what makes them trustworthy is that they never see comment or literal
//! text. [`scrub`] produces a same-shape copy of the source in which every
//! comment and every string/char-literal body is blanked to spaces (line
//! structure preserved, so byte offsets still map to line numbers),
//! together with a per-line side table of the removed comment text — the
//! channel the `// SAFETY:` and `// lint:allow(…)` checks read.
//! [`condense`] then strips all whitespace while keeping a byte → line
//! map, which lets scanners match multi-line call chains
//! (`.lock()\n.unwrap()`) with a plain substring search. [`cfg_test_spans`]
//! finds `#[cfg(test)]`-gated items by delimiter balance so rules can
//! exempt test code.

/// One comment's text, keyed by the 1-based line it occupies. Multi-line
/// block comments contribute one entry per line so upward walks and
/// allow-site lookups stay line-local.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Scrubbed source: same line structure as the input, with comments gone
/// and literal bodies blanked; plus the comment side table.
#[derive(Debug)]
pub struct Scrubbed {
    pub code: String,
    pub comments: Vec<Comment>,
}

/// Whitespace-free scrubbed code with a byte → 1-based-line map, so
/// multi-line chains match with plain substring search.
#[derive(Debug)]
pub struct Condensed {
    pub text: String,
    /// `lines[b]` is the source line of `text.as_bytes()[b]`.
    lines: Vec<u32>,
}

pub(crate) fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

pub(crate) fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Blank comments and literal bodies out of `src` (see module docs).
pub fn scrub(src: &str) -> Scrubbed {
    let chars: Vec<char> = src.chars().collect();
    Scrubber {
        chars: &chars,
        i: 0,
        line: 1,
        code: String::with_capacity(src.len()),
        comments: Vec::new(),
    }
    .run()
}

struct Scrubber<'a> {
    chars: &'a [char],
    i: usize,
    line: u32,
    code: String,
    comments: Vec<Comment>,
}

impl Scrubber<'_> {
    fn run(mut self) -> Scrubbed {
        while self.i < self.chars.len() {
            let c = self.chars[self.i];
            let next = self.chars.get(self.i + 1).copied();
            match c {
                '/' if next == Some('/') => self.line_comment(),
                '/' if next == Some('*') => self.block_comment(),
                '"' => {
                    self.emit('"');
                    self.i += 1;
                    self.string_body();
                }
                '\'' => self.char_or_lifetime(),
                'r' | 'b' if !self.prev_is_ident() && self.try_raw_or_byte_string() => {}
                _ => {
                    self.emit(c);
                    self.i += 1;
                }
            }
        }
        Scrubbed { code: self.code, comments: self.comments }
    }

    /// Emit a kept character (structure: newlines, quotes, code).
    fn emit(&mut self, c: char) {
        if c == '\n' {
            self.line += 1;
        }
        self.code.push(c);
    }

    /// Emit the blanked form of a scrubbed character, preserving newlines.
    fn blank(&mut self, c: char) {
        if c == '\n' {
            self.line += 1;
            self.code.push('\n');
        } else {
            self.code.push(' ');
        }
    }

    fn prev_is_ident(&self) -> bool {
        self.i > 0 && is_ident(self.chars[self.i - 1])
    }

    /// `// …` to end of line (doc comments included): blank it, record it.
    fn line_comment(&mut self) {
        let start = self.line;
        let mut text = String::new();
        while self.i < self.chars.len() && self.chars[self.i] != '\n' {
            text.push(self.chars[self.i]);
            self.code.push(' ');
            self.i += 1;
        }
        self.comments.push(Comment { line: start, text });
    }

    /// `/* … */` with Rust nesting; one `Comment` entry per line spanned.
    fn block_comment(&mut self) {
        let mut depth = 0usize;
        let mut text = String::new();
        while self.i < self.chars.len() {
            let c = self.chars[self.i];
            let next = self.chars.get(self.i + 1).copied();
            if c == '/' && next == Some('*') {
                depth += 1;
                text.push_str("/*");
                self.code.push_str("  ");
                self.i += 2;
            } else if c == '*' && next == Some('/') {
                depth = depth.saturating_sub(1);
                text.push_str("*/");
                self.code.push_str("  ");
                self.i += 2;
                if depth == 0 {
                    break;
                }
            } else if c == '\n' {
                let done = std::mem::take(&mut text);
                self.comments.push(Comment { line: self.line, text: done });
                self.blank('\n');
                self.i += 1;
            } else {
                text.push(c);
                self.code.push(' ');
                self.i += 1;
            }
        }
        if !text.is_empty() {
            self.comments.push(Comment { line: self.line, text });
        }
    }

    /// Blank a string body; the opening quote is already emitted.
    fn string_body(&mut self) {
        while self.i < self.chars.len() {
            let c = self.chars[self.i];
            if c == '\\' {
                self.blank(c);
                self.i += 1;
                if self.i < self.chars.len() {
                    let escaped = self.chars[self.i];
                    self.blank(escaped);
                    self.i += 1;
                }
            } else if c == '"' {
                self.emit('"');
                self.i += 1;
                return;
            } else {
                self.blank(c);
                self.i += 1;
            }
        }
    }

    /// Distinguish `'x'` / `'\n'` char literals from `'a` lifetimes.
    fn char_or_lifetime(&mut self) {
        let one = self.chars.get(self.i + 1).copied();
        let two = self.chars.get(self.i + 2).copied();
        if one == Some('\\') {
            // Escaped char literal: blank through the closing quote.
            self.emit('\'');
            self.i += 1;
            while self.i < self.chars.len() {
                let c = self.chars[self.i];
                if c == '\\' {
                    self.blank(c);
                    self.i += 1;
                    if self.i < self.chars.len() {
                        let escaped = self.chars[self.i];
                        self.blank(escaped);
                        self.i += 1;
                    }
                } else if c == '\'' {
                    self.emit('\'');
                    self.i += 1;
                    return;
                } else {
                    self.blank(c);
                    self.i += 1;
                }
            }
        } else if two == Some('\'') && one.is_some() {
            // Plain one-char literal (covers '_' , '"' , '{').
            self.emit('\'');
            self.blank(one.unwrap());
            self.emit('\'');
            self.i += 3;
        } else {
            // Lifetime marker: keep as-is.
            self.emit('\'');
            self.i += 1;
        }
    }

    /// Handle `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#` starts. Returns false if
    /// the position is an ordinary identifier (`row`, `b`, …), in which
    /// case nothing was consumed.
    fn try_raw_or_byte_string(&mut self) -> bool {
        let mut j = self.i;
        let byte_prefixed = self.chars[j] == 'b';
        if byte_prefixed {
            j += 1;
        }
        let raw = self.chars.get(j) == Some(&'r');
        if raw {
            j += 1;
        }
        let mut hashes = 0usize;
        while raw && self.chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        if self.chars.get(j) != Some(&'"') {
            return false;
        }
        if !raw {
            if !byte_prefixed {
                return false;
            }
            // b"…": an escaped string with a byte prefix.
            self.emit('b');
            self.emit('"');
            self.i += 2;
            self.string_body();
            return true;
        }
        // Raw (possibly byte) string: keep the delimiters, blank the body
        // up to `"` followed by `hashes` hash marks.
        if byte_prefixed {
            self.emit('b');
        }
        self.emit('r');
        for _ in 0..hashes {
            self.emit('#');
        }
        self.emit('"');
        self.i = j + 1;
        'scan: while self.i < self.chars.len() {
            if self.chars[self.i] == '"' {
                for h in 0..hashes {
                    if self.chars.get(self.i + 1 + h) != Some(&'#') {
                        self.blank('"');
                        self.i += 1;
                        continue 'scan;
                    }
                }
                self.emit('"');
                for _ in 0..hashes {
                    self.emit('#');
                }
                self.i += 1 + hashes;
                return true;
            }
            let c = self.chars[self.i];
            self.blank(c);
            self.i += 1;
        }
        true
    }
}

/// Strip all whitespace from scrubbed code, keeping a per-byte line map.
pub fn condense(code: &str) -> Condensed {
    let mut text = String::new();
    let mut lines = Vec::new();
    let mut line: u32 = 1;
    for c in code.chars() {
        if c == '\n' {
            line += 1;
            continue;
        }
        if c.is_whitespace() {
            continue;
        }
        text.push(c);
        for _ in 0..c.len_utf8() {
            lines.push(line);
        }
    }
    Condensed { text, lines }
}

impl Condensed {
    /// Source line of byte offset `b` (1-based; 0 for an empty stream).
    pub fn line_at(&self, b: usize) -> u32 {
        match self.lines.get(b) {
            Some(&l) => l,
            None => self.lines.last().copied().unwrap_or(0),
        }
    }

    /// Byte offsets of every occurrence of `pat`.
    pub fn find_all(&self, pat: &str) -> Vec<usize> {
        self.text.match_indices(pat).map(|(b, _)| b).collect()
    }
}

/// Byte offsets `(open, close)` of the first `{ … }` block at or after
/// `from`, by depth counting. Exact on scrubbed/condensed text: no braces
/// survive inside comments or literals.
pub fn brace_block(text: &str, from: usize) -> Option<(usize, usize)> {
    let bytes = text.as_bytes();
    let open = bytes[from..].iter().position(|&b| b == b'{')? + from;
    let mut depth = 0usize;
    for (off, &b) in bytes[open..].iter().enumerate() {
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some((open, open + off));
                }
            }
            _ => {}
        }
    }
    None
}

/// Line spans (1-based, inclusive) of `#[cfg(test)]`-gated items. From each
/// attribute, the item runs to its first block's matching `}` — or to a
/// top-level `;` for block-less items (`#[cfg(test)] mod tests;`,
/// `#[cfg(test)] use …;`).
pub fn cfg_test_spans(cond: &Condensed) -> Vec<(u32, u32)> {
    const ATTR: &str = "#[cfg(test)]";
    let bytes = cond.text.as_bytes();
    let mut spans = Vec::new();
    for at in cond.find_all(ATTR) {
        let start_line = cond.line_at(at);
        let mut brace_depth = 0usize;
        let mut paren_depth = 0usize;
        let mut saw_block = false;
        let mut end = None;
        let mut j = at + ATTR.len();
        while j < bytes.len() {
            match bytes[j] {
                b'(' | b'[' => paren_depth += 1,
                b')' | b']' => paren_depth = paren_depth.saturating_sub(1),
                b';' if brace_depth == 0 && paren_depth == 0 && !saw_block => {
                    end = Some(cond.line_at(j));
                    break;
                }
                b'{' => {
                    brace_depth += 1;
                    saw_block = true;
                }
                b'}' => {
                    brace_depth = brace_depth.saturating_sub(1);
                    if saw_block && brace_depth == 0 {
                        end = Some(cond.line_at(j));
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let fallback = cond.line_at(bytes.len().saturating_sub(1));
        spans.push((start_line, end.unwrap_or(fallback)));
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrub_blanks_comments_and_records_them() {
        let src = "let x = 1; // trailing note\n/* block\nspans lines */ fn f() {}\n";
        let s = scrub(src);
        assert!(!s.code.contains("trailing"));
        assert!(!s.code.contains("spans"));
        assert!(s.code.contains("let x = 1;"));
        assert!(s.code.contains("fn f() {}"));
        assert_eq!(s.code.lines().count(), src.lines().count());
        assert!(s.comments.iter().any(|c| c.line == 1 && c.text.contains("trailing note")));
        assert!(s.comments.iter().any(|c| c.line == 2 && c.text.contains("block")));
        assert!(s.comments.iter().any(|c| c.line == 3 && c.text.contains("spans lines")));
    }

    #[test]
    fn scrub_blanks_string_and_char_bodies_but_keeps_lifetimes() {
        let src = "fn f<'a>(s: &'a str) { let t = \"unsafe { }\"; let c = '{'; let e = '\\n'; }\n";
        let s = scrub(src);
        assert!(!s.code.contains("unsafe"));
        // Brace balance is preserved: literal braces were blanked.
        let opens = s.code.matches('{').count();
        let closes = s.code.matches('}').count();
        assert_eq!(opens, closes);
        assert!(s.code.contains("fn f<'a>(s: &'a str)"));
    }

    #[test]
    fn scrub_handles_raw_strings() {
        let src = "let p = r#\"contains \"quotes\" and unsafe words\"#; let q = r\"plain\"; let b = b\"bytes\";\n";
        let s = scrub(src);
        assert!(!s.code.contains("unsafe"));
        assert!(!s.code.contains("plain"));
        assert!(!s.code.contains("bytes"));
        // Identifiers starting with r/b are untouched.
        let src2 = "let row = rows + b;\n";
        assert_eq!(scrub(src2).code, src2);
    }

    #[test]
    fn condense_maps_bytes_back_to_lines() {
        let src = "a.lock()\n    .unwrap()\n";
        let c = condense(&scrub(src).code);
        assert_eq!(c.text, "a.lock().unwrap()");
        let at = c.find_all(".lock().unwrap()")[0];
        assert_eq!(c.line_at(at), 1);
        let unwrap_at = c.text.find(".unwrap").unwrap();
        assert_eq!(c.line_at(unwrap_at + 1), 2);
    }

    #[test]
    fn cfg_test_spans_cover_mods_fns_and_statements() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn also_live() {}\n#[cfg(test)]\nuse std::fmt;\n";
        let c = condense(&scrub(src).code);
        let spans = cfg_test_spans(&c);
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0], (2, 5));
        assert_eq!(spans[1], (7, 8));
    }

    #[test]
    fn brace_block_matches_nested_blocks() {
        let text = "fn f(){if x{y()}else{z()}}fn g(){}";
        let (open, close) = brace_block(text, 0).unwrap();
        assert_eq!(open, text.find('{').unwrap());
        assert_eq!(&text[close..close + 1], "}");
        assert_eq!(close, 25);
    }
}
