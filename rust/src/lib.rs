//! # SPOGA — Scalable Photonic GEMM Accelerator (full-stack reproduction)
//!
//! Reproduction of *"Scaling Analog Photonic Accelerators for Byte-Size,
//! Integer General Matrix Multiply (GEMM) Kernels"* (Alo, Vatsavai, Thakkar —
//! ISVLSI 2024), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — a Pallas kernel (`python/compile/kernels/spoga_gemm.py`) that
//!   computes INT8 GEMM with the SPOGA dataflow (nibble slicing, three radix
//!   lanes, in-transduction positional weighting), AOT-lowered to HLO text.
//! * **L2** — JAX model graphs (quantized MLP / CNN forward) calling the
//!   kernel, exported once at build time by `make artifacts`.
//! * **L3** — this crate: the photonic-accelerator analytical models, the
//!   transaction-level simulator, the PJRT runtime that executes the AOT
//!   artifacts, and the request coordinator. Python never runs at runtime.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`analysis`] | zero-dependency static-analysis library (`spoga-lint`): comment/string-aware lexer + delimiter-balance machinery + per-rule scanners enforcing the repo's serving invariants (no poison panics, SAFETY-justified `unsafe`, release-enforced guards, wire-codec symmetry, non-blocking ingress) in tier-1 |
//! | [`units`] | dB/dBm/watt/time conversions used by all photonic models |
//! | [`devices`] | parametric component models (MRR, laser, BPCA, ADC/DAC, …) |
//! | [`optics`] | optical link budget + scalability solver (paper Table I) |
//! | [`bitslice`] | exact integer semantics of nibble-sliced arithmetic (+ INT16 extension); naive oracles + packed-plane tiled/threaded fast kernels with scalar/SSE2/runtime-detected AVX2 micro-kernels and a prepacked (pack-once/stream-many) operand API |
//! | [`fidelity`] | analog-noise Monte-Carlo (the 4-bit-analog premise, quantified) |
//! | [`arch`] | accelerator architectures: SPOGA (MWA), HOLYLIGHT (MAW), DEAPCNN (AMW) |
//! | [`dnn`] | CNN workload library (4 networks) + im2col GEMM conversion |
//! | [`sim`] | transaction-level simulator (mapper, scheduler, accounting) |
//! | [`metrics`] | FPS / FPS/W / FPS/W/mm² aggregation, gmean, live serving telemetry, fleet-wide stats rollup (`FleetTelemetry`) |
//! | [`runtime`] | pluggable execution backends (`ExecBackend`): software interpreter + photonic-in-the-loop simulator, both weight-stationary (plans own packed weights, scratch-reused activations); artifact manifest, engine, compile-once/stream-many whole-CNN serving (`CnnPlan` + scratch arena, single + t-stacked batch) |
//! | [`coordinator`] | sharded serving fleet: shard router (`Fleet`/`FleetHandle`, pluggable routing + failover, retained-payload mid-flight retry, shard revival/autoscaling) over per-backend coordinators with dynamic MLP batching, t-stacked CNN batching, photonic telemetry, and typed overload shedding — non-blocking admission (`Error::Overloaded` + shed counters) with per-request QoS (`Priority` class, deadline-aware batching, `Error::DeadlineExceeded` pre-dispatch reaping) |
//! | [`net`] | cross-host serving: zero-dependency checksummed wire protocol (v2: QoS envelope + shed counters on the wire), `ShardServer` (TCP front for a coordinator/fleet), `RemoteShard` client with deadlines, jittered-backoff reconnect, and typed `Error::Remote` failure taxonomy |
//! | [`testing`] | deterministic mini property-testing harness |
//! | [`benchkit`] | timing helpers for the harness-free benches |
//! | [`report`] | plain-text table rendering shared by benches/examples |

// Clippy baseline for CI's `cargo clippy --workspace -- -D warnings` gate.
// Each allow is a considered default for this codebase, not an unread
// suppression; tightening any of them is welcome as its own change.
#![allow(clippy::too_many_arguments)] // BLAS-shaped kernel entry points pass panel bounds explicitly
#![allow(clippy::needless_range_loop)] // index loops mirror the paper's GEMM notation and keep micro-kernel bodies branch-identical
#![allow(clippy::type_complexity)] // hand-rolled channel/slot plumbing: no external crates to name the types
#![allow(clippy::result_large_err)] // crate Error carries rich context strings by design (typed-error-over-panic discipline)
#![allow(clippy::new_without_default)] // constructors take required config; a Default impl would hide it

pub mod analysis;
pub mod arch;
pub mod benchkit;
pub mod bitslice;
pub mod coordinator;
pub mod devices;
pub mod dnn;
pub mod error;
pub mod fidelity;
pub mod metrics;
pub mod net;
pub mod optics;
pub mod report;
pub mod runtime;
pub mod sim;
pub(crate) mod sync;
pub mod testing;
pub mod units;

pub use error::{Error, Result};
