//! Signed nibble decomposition of INT8 operands.

/// The two 4-bit slices of an INT8 value.
///
/// Invariant: `16 * msn + lsn == original`, with `lsn ∈ [0, 15]` and
/// `msn ∈ [-8, 7]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NibblePair {
    /// Most significant nibble — signed, carries the sign of the operand.
    pub msn: i8,
    /// Least significant nibble — unsigned magnitude bits.
    pub lsn: u8,
}

/// Most significant nibble: arithmetic shift keeps the sign.
#[inline]
pub fn msn(x: i8) -> i8 {
    x >> 4
}

/// Least significant nibble: low 4 magnitude bits, always in `[0, 15]`.
#[inline]
pub fn lsn(x: i8) -> u8 {
    (x as u8) & 0x0F
}

/// Slice an INT8 value into its nibble pair.
#[inline]
pub fn slice_i8(x: i8) -> NibblePair {
    NibblePair { msn: msn(x), lsn: lsn(x) }
}

/// Recombine a nibble pair into the original INT8 value.
#[inline]
pub fn combine(p: NibblePair) -> i8 {
    (((p.msn as i16) << 4) | p.lsn as i16) as i8
}

impl NibblePair {
    /// Expand the product `x · y` into the three radix-lane contributions
    /// `(hi, mid, lo)` such that
    /// `x·y = 256·hi + 16·mid + lo`.
    #[inline]
    pub fn product_lanes(x: NibblePair, y: NibblePair) -> (i32, i32, i32) {
        let (xm, xl) = (x.msn as i32, x.lsn as i32);
        let (ym, yl) = (y.msn as i32, y.lsn as i32);
        (xm * ym, xm * yl + xl * ym, xl * yl)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_combine_roundtrip_exhaustive() {
        for x in i8::MIN..=i8::MAX {
            let p = slice_i8(x);
            assert_eq!(combine(p), x, "roundtrip failed for {x}");
            assert!(p.lsn <= 15);
            assert!((-8..=7).contains(&p.msn), "msn {} out of range for {x}", p.msn);
        }
    }

    #[test]
    fn slice_identity_16m_plus_l_exhaustive() {
        for x in i8::MIN..=i8::MAX {
            let p = slice_i8(x);
            assert_eq!(16 * p.msn as i16 + p.lsn as i16, x as i16);
        }
    }

    #[test]
    fn product_lane_identity_exhaustive() {
        // 65536 cases — the full INT8×INT8 multiplication table.
        for x in i8::MIN..=i8::MAX {
            for y in i8::MIN..=i8::MAX {
                let (hi, mid, lo) = NibblePair::product_lanes(slice_i8(x), slice_i8(y));
                let recomposed = 256 * hi + 16 * mid + lo;
                assert_eq!(recomposed, x as i32 * y as i32, "x={x} y={y}");
            }
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(slice_i8(0), NibblePair { msn: 0, lsn: 0 });
        assert_eq!(slice_i8(127), NibblePair { msn: 7, lsn: 15 });
        assert_eq!(slice_i8(-128), NibblePair { msn: -8, lsn: 0 });
        assert_eq!(slice_i8(-1), NibblePair { msn: -1, lsn: 15 });
        assert_eq!(slice_i8(16), NibblePair { msn: 1, lsn: 0 });
        assert_eq!(slice_i8(-16), NibblePair { msn: -1, lsn: 0 });
    }

    #[test]
    fn lsn_is_always_unsigned_magnitude_bits() {
        assert_eq!(lsn(-1), 15);
        assert_eq!(lsn(-16), 0);
        assert_eq!(lsn(0x0F), 15);
    }
}
