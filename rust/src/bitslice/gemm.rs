//! Reference GEMM implementations over sliced operands.
//!
//! Three equivalent ways to compute `C = A·B` for INT8 matrices, mirroring
//! the three hardware dataflows in the paper's Fig. 2:
//!
//! * [`gemm_i32`] — direct int32 GEMM (what a digital reference does).
//! * [`gemm_sliced`] — the *prior-work* dataflow: four INT4 GEMMs producing
//!   four intermediate matrices, recombined by DEAS-style shift-add.
//! * [`gemm_lanes`] — the *SPOGA* dataflow: three radix-lane accumulations
//!   (the cross terms share the 16¹ lane) weighted at "transduction" time.
//!
//! All three must agree exactly; tests and the property harness enforce it.
//!
//! ## Naive-vs-fast dispatch contract
//!
//! Each public entry point dispatches on problem size: small problems run
//! the transparent `*_naive` loop nests below (the **oracles** — the code a
//! reviewer checks against the paper), large ones run the packed-plane
//! tiled/threaded kernels in [`crate::bitslice::kernel`], which are bit-exact
//! against the oracles by property test. Call the `*_naive` functions
//! directly when you need the oracle regardless of size, or
//! `kernel::gemm_*_tiled` with an explicit [`kernel::TileConfig`]
//! (re-exported from [`crate::bitslice`]) to control blocking and threads.
//!
//! ## Prepacked entry points (pack-once / stream-many)
//!
//! Weight-stationary callers should not pay packing per call: [`pack_b`]
//! slices a B operand once into a [`PackedB`] (raw bytes + nibble planes),
//! and [`gemm_i32_prepacked`] / [`gemm_lanes_prepacked`] /
//! [`gemm_sliced_prepacked`] consume operands packed ahead of time. They
//! sit under the same bit-exactness contract as the dispatchers above: the
//! property suite pins prepacked == repack-per-call == `*_naive` for every
//! shape class.

use crate::bitslice::kernel;
use crate::bitslice::nibble::slice_i8;
use crate::bitslice::packed::{NibblePlanes, PackedB};
use crate::{Error, Result};

/// Row-major matrix dims helper: `C[m][n] = Σ_k A[m][k]·B[k][n]`.
pub(crate) fn check_dims(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Result<()> {
    if a.len() != m * k {
        return Err(Error::Shape(format!("A has {} elems, expected {}x{}", a.len(), m, k)));
    }
    if b.len() != k * n {
        return Err(Error::Shape(format!("B has {} elems, expected {}x{}", b.len(), k, n)));
    }
    Ok(())
}

/// Direct int32 reference GEMM (row-major `A: m×k`, `B: k×n` → `C: m×n`).
///
/// Dispatches to the tiled/threaded kernel for large problems; bit-exact
/// with [`gemm_i32_naive`] always.
pub fn gemm_i32(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Result<Vec<i32>> {
    match kernel::dispatch_config(m, k, n) {
        Some(cfg) => kernel::gemm_i32_tiled(a, b, m, k, n, &cfg),
        None => gemm_i32_naive(a, b, m, k, n),
    }
}

/// Pack a weight-side operand once for reuse across many
/// [`gemm_i32_prepacked`] / [`gemm_lanes_prepacked`] calls.
///
/// Thin forwarder to [`PackedB::pack`], exported here so callers that think
/// in terms of the GEMM API find it next to the entry points that consume it.
pub fn pack_b(b: &[i8], k: usize, n: usize) -> Result<PackedB> {
    PackedB::pack(b, k, n)
}

/// [`gemm_i32`] with B packed ahead of time (`K`/`N` come from the pack).
///
/// Runs the same size dispatch as [`gemm_i32`] — the direct kernel consumes
/// B's raw bytes, so holding a [`PackedB`] costs nothing on the naive path —
/// and is bit-exact with [`gemm_i32_naive`] always.
pub fn gemm_i32_prepacked(a: &[i8], b: &PackedB, m: usize) -> Result<Vec<i32>> {
    let (k, n) = (b.rows(), b.cols());
    match kernel::dispatch_config(m, k, n) {
        Some(cfg) => kernel::gemm_i32_tiled(a, b.raw(), m, k, n, &cfg),
        None => gemm_i32_naive(a, b.raw(), m, k, n),
    }
}

/// [`gemm_i32_prepacked`] writing into a caller-owned output vector (cleared
/// and resized to `m·n`) — the zero-allocation serving form: B packed in a
/// plan, A in a scratch arena, C in a reused buffer. Same size dispatch and
/// bit-exactness contract as [`gemm_i32_prepacked`].
pub fn gemm_i32_prepacked_into(a: &[i8], b: &PackedB, m: usize, c: &mut Vec<i32>) -> Result<()> {
    let (k, n) = (b.rows(), b.cols());
    match kernel::dispatch_config(m, k, n) {
        Some(cfg) => kernel::gemm_i32_tiled_into(a, b.raw(), m, k, n, &cfg, c),
        None => gemm_i32_naive_into(a, b.raw(), m, k, n, c),
    }
}

/// [`gemm_lanes`] over operands sliced ahead of time (A from a per-request
/// scratch, B from a plan). Always runs the plane kernel — both operands are
/// already planes, so there is nothing for the naive path to save — and is
/// bit-exact with [`gemm_lanes_naive`] by the dispatch contract.
pub fn gemm_lanes_prepacked(pa: &NibblePlanes, pb: &NibblePlanes) -> Result<LaneGemm> {
    kernel::gemm_lanes_packed(pa, pb, &kernel::TileConfig::auto_for(pa.rows, pa.cols, pb.cols))
}

/// [`gemm_sliced`] over operands sliced ahead of time; see
/// [`gemm_lanes_prepacked`].
pub fn gemm_sliced_prepacked(pa: &NibblePlanes, pb: &NibblePlanes) -> Result<SlicedGemm> {
    kernel::gemm_sliced_packed(pa, pb, &kernel::TileConfig::auto_for(pa.rows, pa.cols, pb.cols))
}

/// Naive oracle for [`gemm_i32`]: the transparent three-loop reference.
pub fn gemm_i32_naive(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Result<Vec<i32>> {
    let mut c = Vec::new();
    gemm_i32_naive_into(a, b, m, k, n, &mut c)?;
    Ok(c)
}

/// [`gemm_i32_naive`] into a caller-owned buffer (cleared and resized);
/// the small-problem arm of [`gemm_i32_prepacked_into`]'s dispatch.
pub fn gemm_i32_naive_into(
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    c: &mut Vec<i32>,
) -> Result<()> {
    check_dims(a, b, m, k, n)?;
    c.clear();
    c.resize(m * n, 0);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk] as i32;
            if av == 0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j] as i32;
            }
        }
    }
    Ok(())
}

/// The four intermediate matrices of the prior-work bit-sliced dataflow
/// (paper Fig. 2(a)): one INT4 GEMM per (operand-slice × operand-slice)
/// combination, before DEAS recombination.
#[derive(Debug, Clone)]
pub struct SlicedGemm {
    /// MSN(A)·MSN(B) — radix weight 16².
    pub mm: Vec<i32>,
    /// MSN(A)·LSN(B) — radix weight 16¹.
    pub ml: Vec<i32>,
    /// LSN(A)·MSN(B) — radix weight 16¹.
    pub lm: Vec<i32>,
    /// LSN(A)·LSN(B) — radix weight 16⁰.
    pub ll: Vec<i32>,
}

impl SlicedGemm {
    /// DEAS recombination: `256·mm + 16·(ml + lm) + ll`.
    pub fn recombine(&self) -> Vec<i32> {
        self.mm
            .iter()
            .zip(&self.ml)
            .zip(&self.lm)
            .zip(&self.ll)
            .map(|(((mm, ml), lm), ll)| 256 * mm + 16 * (ml + lm) + ll)
            .collect()
    }
}

/// Prior-work dataflow: compute the four INT4 GEMMs explicitly.
///
/// Each intermediate is exactly what one of the four dedicated photonic
/// cores in Fig. 2(a) would produce (before ADC/DEAS post-processing).
/// Dispatches to the packed kernel for large problems; bit-exact with
/// [`gemm_sliced_naive`] always.
pub fn gemm_sliced(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Result<SlicedGemm> {
    match kernel::dispatch_config(m, k, n) {
        Some(cfg) => kernel::gemm_sliced_tiled(a, b, m, k, n, &cfg),
        None => gemm_sliced_naive(a, b, m, k, n),
    }
}

/// Naive oracle for [`gemm_sliced`]: slices every operand element in the
/// innermost loop, exactly as the hardware description reads.
pub fn gemm_sliced_naive(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Result<SlicedGemm> {
    check_dims(a, b, m, k, n)?;
    let mut out = SlicedGemm {
        mm: vec![0; m * n],
        ml: vec![0; m * n],
        lm: vec![0; m * n],
        ll: vec![0; m * n],
    };
    for i in 0..m {
        for kk in 0..k {
            let pa = slice_i8(a[i * k + kk]);
            let (am, al) = (pa.msn as i32, pa.lsn as i32);
            for j in 0..n {
                let pb = slice_i8(b[kk * n + j]);
                let (bm, bl) = (pb.msn as i32, pb.lsn as i32);
                let idx = i * n + j;
                out.mm[idx] += am * bm;
                out.ml[idx] += am * bl;
                out.lm[idx] += al * bm;
                out.ll[idx] += al * bl;
            }
        }
    }
    Ok(out)
}

/// The three radix-lane accumulators of a SPOGA DPU (paper Fig. 2(b/c)).
///
/// `hi/mid/lo` are the charge totals of the 16²/16¹/16⁰ BPCAs *before*
/// capacitor weighting — i.e. the positionally *unweighted* partial results.
#[derive(Debug, Clone)]
pub struct LaneGemm {
    /// Σ MSN·MSN per output (λ1 lane).
    pub hi: Vec<i32>,
    /// Σ (MSN·LSN + LSN·MSN) per output (λ2+λ3 multiplexed lane).
    pub mid: Vec<i32>,
    /// Σ LSN·LSN per output (λ4 lane).
    pub lo: Vec<i32>,
}

impl LaneGemm {
    /// PWAB epilogue: capacitor weighting (×256 / ×16 / ×1) + analog adder.
    pub fn weight_and_add(&self) -> Vec<i32> {
        self.hi
            .iter()
            .zip(&self.mid)
            .zip(&self.lo)
            .map(|((h, m), l)| 256 * h + 16 * m + l)
            .collect()
    }
}

/// SPOGA dataflow: accumulate the three radix lanes directly.
///
/// Note the Mid lane merges the two cross terms *optically* (λ2 and λ3 are
/// multiplexed into the same aggregation lane set), so only three — not
/// four — accumulators exist per dot product. Dispatches to the packed
/// kernel for large problems; bit-exact with [`gemm_lanes_naive`] always.
pub fn gemm_lanes(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Result<LaneGemm> {
    match kernel::dispatch_config(m, k, n) {
        Some(cfg) => kernel::gemm_lanes_tiled(a, b, m, k, n, &cfg),
        None => gemm_lanes_naive(a, b, m, k, n),
    }
}

/// Naive oracle for [`gemm_lanes`].
pub fn gemm_lanes_naive(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Result<LaneGemm> {
    check_dims(a, b, m, k, n)?;
    let mut out = LaneGemm { hi: vec![0; m * n], mid: vec![0; m * n], lo: vec![0; m * n] };
    for i in 0..m {
        for kk in 0..k {
            let pa = slice_i8(a[i * k + kk]);
            let (am, al) = (pa.msn as i32, pa.lsn as i32);
            for j in 0..n {
                let pb = slice_i8(b[kk * n + j]);
                let (bm, bl) = (pb.msn as i32, pb.lsn as i32);
                let idx = i * n + j;
                out.hi[idx] += am * bm;
                out.mid[idx] += am * bl + al * bm;
                out.lo[idx] += al * bl;
            }
        }
    }
    Ok(out)
}

/// Worst-case magnitude of a lane accumulator after a K-length reduction.
///
/// Used to size the BPCA dynamic range and the 16-bit intermediate
/// precision claim (paper §I: ≥16-bit accumulation before rounding).
pub fn lane_accumulator_bound(k: usize) -> i64 {
    // |msn| ≤ 8, lsn ≤ 15 → hi ≤ 64, |mid| ≤ 2·8·15 = 240, lo ≤ 225 per element.
    240 * k as i64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(vals: &[i8]) -> Vec<i8> {
        vals.to_vec()
    }

    #[test]
    fn tiny_known_gemm() {
        // [[1,2],[3,4]] · [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = mat(&[1, 2, 3, 4]);
        let b = mat(&[5, 6, 7, 8]);
        let c = gemm_i32(&a, &b, 2, 2, 2).unwrap();
        assert_eq!(c, vec![19, 22, 43, 50]);
    }

    #[test]
    fn sliced_recombination_equals_direct() {
        let a = mat(&[-128, 127, 5, -7, 100, -100]);
        let b = mat(&[3, -9, 127, -128, 0, 55]);
        let direct = gemm_i32(&a, &b, 2, 3, 2).unwrap();
        let sliced = gemm_sliced(&a, &b, 2, 3, 2).unwrap().recombine();
        assert_eq!(direct, sliced);
    }

    #[test]
    fn lanes_weight_and_add_equals_direct() {
        let a = mat(&[-128, 127, 5, -7, 100, -100]);
        let b = mat(&[3, -9, 127, -128, 0, 55]);
        let direct = gemm_i32(&a, &b, 2, 3, 2).unwrap();
        let lanes = gemm_lanes(&a, &b, 2, 3, 2).unwrap().weight_and_add();
        assert_eq!(direct, lanes);
    }

    #[test]
    fn lanes_mid_is_sum_of_sliced_cross_terms() {
        let a = mat(&[1, -2, 3, 4, 5, 6, 7, 8, 9]);
        let b = mat(&[9, 8, -7, 6, 5, 4, 3, 2, 1]);
        let sliced = gemm_sliced(&a, &b, 3, 3, 3).unwrap();
        let lanes = gemm_lanes(&a, &b, 3, 3, 3).unwrap();
        assert_eq!(lanes.hi, sliced.mm);
        assert_eq!(lanes.lo, sliced.ll);
        let cross: Vec<i32> = sliced.ml.iter().zip(&sliced.lm).map(|(x, y)| x + y).collect();
        assert_eq!(lanes.mid, cross);
    }

    #[test]
    fn shape_errors_reported() {
        assert!(gemm_i32(&[1, 2, 3], &[1, 2], 2, 2, 1).is_err());
        assert!(gemm_sliced(&[1, 2], &[1, 2, 3], 1, 2, 1).is_err());
        assert!(gemm_lanes(&[1], &[1, 2], 1, 1, 1).is_err());
        assert!(gemm_i32_naive(&[1, 2, 3], &[1, 2], 2, 2, 1).is_err());
        assert!(gemm_sliced_naive(&[1, 2], &[1, 2, 3], 1, 2, 1).is_err());
        assert!(gemm_lanes_naive(&[1], &[1, 2], 1, 1, 1).is_err());
    }

    #[test]
    fn identity_matrix_preserves_input() {
        let ident = mat(&[1, 0, 0, 1]);
        let b = mat(&[42, -17, 99, -128]);
        assert_eq!(gemm_i32(&ident, &b, 2, 2, 2).unwrap(), vec![42, -17, 99, -128]);
    }

    #[test]
    fn dispatcher_crosses_threshold_bit_exact() {
        // 64×16×64 = 65536 MACs ≥ PACKED_MIN_MACS: the public entry points
        // take the packed path here; the naive oracles must agree exactly.
        let (m, k, n) = (64usize, 16usize, 64usize);
        let a: Vec<i8> = (0..m * k).map(|i| (i * 37 + 11) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|i| (i * 53 + 7) as i8).collect();
        assert!(crate::bitslice::kernel::dispatch_config(m, k, n).is_some());
        assert_eq!(gemm_i32(&a, &b, m, k, n).unwrap(), gemm_i32_naive(&a, &b, m, k, n).unwrap());
        let fast = gemm_lanes(&a, &b, m, k, n).unwrap();
        let slow = gemm_lanes_naive(&a, &b, m, k, n).unwrap();
        assert_eq!(fast.hi, slow.hi);
        assert_eq!(fast.mid, slow.mid);
        assert_eq!(fast.lo, slow.lo);
        let fs = gemm_sliced(&a, &b, m, k, n).unwrap();
        let ss = gemm_sliced_naive(&a, &b, m, k, n).unwrap();
        assert_eq!(fs.recombine(), ss.recombine());
    }

    #[test]
    fn prepacked_entry_points_match_dispatchers() {
        let (m, k, n) = (3usize, 5usize, 4usize);
        let a = mat(&[1, -2, 3, 4, 5, 6, 7, 8, 9, -128, 127, 0, -1, 2, -3]);
        let b: Vec<i8> = (0..k * n).map(|i| (i as i8).wrapping_mul(23).wrapping_sub(60)).collect();
        let pb = pack_b(&b, k, n).unwrap();
        assert_eq!(
            gemm_i32_prepacked(&a, &pb, m).unwrap(),
            gemm_i32(&a, &b, m, k, n).unwrap()
        );
        let pa = NibblePlanes::pack(&a, m, k).unwrap();
        let lanes = gemm_lanes_prepacked(&pa, pb.planes()).unwrap();
        let expect = gemm_lanes_naive(&a, &b, m, k, n).unwrap();
        assert_eq!(lanes.hi, expect.hi);
        assert_eq!(lanes.mid, expect.mid);
        assert_eq!(lanes.lo, expect.lo);
        let sliced = gemm_sliced_prepacked(&pa, pb.planes()).unwrap();
        assert_eq!(sliced.recombine(), gemm_sliced_naive(&a, &b, m, k, n).unwrap().recombine());
    }

    #[test]
    fn prepacked_into_matches_allocating_on_both_dispatch_arms() {
        // One shape below the packed threshold (naive arm), one above
        // (tiled arm); the reused buffer must match the allocating call on
        // both, including after a dirty prior fill.
        for (m, k, n) in [(3usize, 5usize, 4usize), (64, 16, 64)] {
            let a: Vec<i8> = (0..m * k).map(|i| (i * 31 + 5) as i8).collect();
            let b: Vec<i8> = (0..k * n).map(|i| (i * 17 + 3) as i8).collect();
            let pb = pack_b(&b, k, n).unwrap();
            let want = gemm_i32_prepacked(&a, &pb, m).unwrap();
            let mut c = vec![-1i32; 7];
            gemm_i32_prepacked_into(&a, &pb, m, &mut c).unwrap();
            assert_eq!(c, want);
            gemm_i32_prepacked_into(&a, &pb, m, &mut c).unwrap();
            assert_eq!(c, want);
        }
    }

    #[test]
    fn prepacked_shape_errors_reported() {
        let pb = pack_b(&[1, 2, 3, 4, 5, 6], 2, 3).unwrap();
        // A too short for m=2, k=2.
        assert!(gemm_i32_prepacked(&[1, 2, 3], &pb, 2).is_err());
        // K mismatch between packed planes.
        let pa = NibblePlanes::pack(&[1, 2, 3], 1, 3).unwrap();
        assert!(gemm_lanes_prepacked(&pa, pb.planes()).is_err());
        assert!(gemm_sliced_prepacked(&pa, pb.planes()).is_err());
    }

    #[test]
    fn accumulator_bound_holds_for_extremes() {
        // K all-extreme vectors: mid lane is the largest-magnitude lane.
        let k = 64usize;
        let a = vec![-128i8; k];
        let b = vec![127i8; k];
        let lanes = gemm_lanes(&a, &b, 1, k, 1).unwrap();
        let bound = lane_accumulator_bound(k);
        for lane in [&lanes.hi, &lanes.mid, &lanes.lo] {
            assert!((lane[0] as i64).abs() <= bound);
        }
    }

    #[test]
    fn sixteen_bit_claim_for_dpu_sized_reduction() {
        // Paper §I: intermediate accumulation needs ≥16-bit precision.
        // A full 249-element DPU reduction stays within 17 bits unweighted —
        // the paper's 16-bit figure refers to the *weighted, rounded* output.
        let bound = lane_accumulator_bound(249);
        assert!(bound < (1i64 << 17));
        assert!(bound > (1i64 << 15));
    }
}
