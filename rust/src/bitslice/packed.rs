//! Packed nibble-plane operand layout for the fast bit-sliced GEMM engine.
//!
//! The naive reference kernels ([`crate::bitslice::gemm::gemm_sliced_naive`],
//! [`crate::bitslice::gemm::gemm_lanes_naive`]) call `slice_i8` on *every*
//! operand element inside the innermost loop — the B operand is re-sliced
//! once per output row, an O(m·k·n) redundancy. This module decomposes each
//! operand **once** into flat, contiguous *nibble planes*:
//!
//! ```text
//! A (m×k, i8)  →  msn plane (m×k, i8 in [-8,7]) + lsn plane (m×k, i8 in [0,15])
//! B (k×n, i8)  →  msn plane (k×n)               + lsn plane (k×n)
//! ```
//!
//! so slicing costs O(m·k + k·n) and the micro-kernels in
//! [`crate::bitslice::kernel`] stream the planes row-contiguously (B plane
//! rows are unit-stride in `j`, exactly what the i–k–j loop order wants).
//! Planes are stored as `i8` (not a wider type) deliberately: nibble values
//! fit, the memory traffic halves versus i16, and the micro-kernel widens to
//! i32 registers only at multiply time.
//!
//! [`WidePlanes`] is the four-plane INT16 analogue used by the 7-lane
//! `wide` dataflow.
//!
//! ## Pack-once / stream-many
//!
//! Packing is separable per operand, so a caller that reuses one operand
//! across many GEMMs (weight-stationary serving: B is programmed once,
//! activations stream) should pack it **once** and hold the result:
//!
//! * [`PackedB`] — a weight-side operand packed for every kernel family
//!   (raw row-major bytes for the direct i32 kernel, nibble planes for the
//!   lane/sliced kernels), with content-checked cache refresh
//!   ([`PackedB::refresh_wire`]) for ad-hoc B operands that usually repeat.
//! * [`NibblePlanes::pack_into`] — re-slice into existing plane storage,
//!   preserving allocations: the per-request activation side packs into a
//!   reusable scratch instead of allocating.
//!
//! The prepacked entry points ([`crate::bitslice::gemm_i32_prepacked`],
//! [`crate::bitslice::gemm_lanes_prepacked`], …) consume these directly.

use crate::bitslice::nibble::{lsn, msn};
use crate::bitslice::wide::slice_i16;
use crate::{Error, Result};

/// The two nibble planes of a row-major INT8 matrix.
#[derive(Debug, Clone, Default)]
pub struct NibblePlanes {
    /// Matrix rows.
    pub rows: usize,
    /// Matrix columns (unit stride within a plane row).
    pub cols: usize,
    /// Most-significant-nibble plane, values in `[-8, 7]`.
    pub msn: Vec<i8>,
    /// Least-significant-nibble plane, values in `[0, 15]`.
    pub lsn: Vec<i8>,
}

impl NibblePlanes {
    /// Slice a row-major `rows × cols` INT8 matrix into its two planes.
    pub fn pack(data: &[i8], rows: usize, cols: usize) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "pack: {} elements for a {rows}x{cols} matrix",
                data.len()
            )));
        }
        let mut m_plane = Vec::with_capacity(data.len());
        let mut l_plane = Vec::with_capacity(data.len());
        for &v in data {
            m_plane.push(msn(v));
            l_plane.push(lsn(v) as i8);
        }
        Ok(NibblePlanes { rows, cols, msn: m_plane, lsn: l_plane })
    }

    /// Re-slice a matrix into `self`, reusing the existing plane storage
    /// (allocation-free once the vectors have grown to the working size).
    /// This is the activation-side scratch of the pack-once/stream-many
    /// split: per-request packing refills the same buffers.
    pub fn pack_into(&mut self, data: &[i8], rows: usize, cols: usize) -> Result<()> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "pack_into: {} elements for a {rows}x{cols} matrix",
                data.len()
            )));
        }
        self.rows = rows;
        self.cols = cols;
        self.msn.clear();
        self.lsn.clear();
        self.msn.reserve(data.len());
        self.lsn.reserve(data.len());
        for &v in data {
            self.msn.push(msn(v));
            self.lsn.push(lsn(v) as i8);
        }
        Ok(())
    }

    /// MSN plane row `r` (length `cols`).
    #[inline]
    pub fn msn_row(&self, r: usize) -> &[i8] {
        &self.msn[r * self.cols..(r + 1) * self.cols]
    }

    /// LSN plane row `r` (length `cols`).
    #[inline]
    pub fn lsn_row(&self, r: usize) -> &[i8] {
        &self.lsn[r * self.cols..(r + 1) * self.cols]
    }
}

/// A weight-side (B) operand packed once for pack-once/stream-many GEMM.
///
/// Holds **both** representations the kernel families stream so one cache
/// entry serves every dataflow: the raw row-major bytes (the direct i32
/// kernel reads B unsliced) and the nibble planes (the lane/sliced kernels
/// read plane rows). Build one per artifact at plan time and stream
/// activations against it via [`crate::bitslice::gemm_i32_prepacked`] /
/// [`crate::bitslice::gemm_lanes_prepacked`].
#[derive(Debug, Clone)]
pub struct PackedB {
    /// Raw row-major `rows × cols` values (direct-kernel view).
    raw: Vec<i8>,
    /// Nibble planes of the same matrix (lane/sliced-kernel view).
    planes: NibblePlanes,
}

impl PackedB {
    /// Pack a row-major `k × n` INT8 matrix.
    pub fn pack(data: &[i8], k: usize, n: usize) -> Result<Self> {
        let planes = NibblePlanes::pack(data, k, n)?;
        Ok(PackedB { raw: data.to_vec(), planes })
    }

    /// Pack from wire-format i32 lanes (each carrying an int8, wrapping —
    /// the same narrowing the AOT kernels' `convert` performs).
    pub fn pack_wire(wire: &[i32], k: usize, n: usize) -> Result<Self> {
        let raw: Vec<i8> = wire.iter().map(|&v| v as i8).collect();
        let planes = NibblePlanes::pack(&raw, k, n)?;
        Ok(PackedB { raw, planes })
    }

    /// Matrix rows (`k` of the GEMM it feeds).
    pub fn rows(&self) -> usize {
        self.planes.rows
    }

    /// Matrix columns (`n` of the GEMM it feeds).
    pub fn cols(&self) -> usize {
        self.planes.cols
    }

    /// The raw row-major values.
    pub fn raw(&self) -> &[i8] {
        &self.raw
    }

    /// The nibble planes.
    pub fn planes(&self) -> &NibblePlanes {
        &self.planes
    }

    /// Does this cache hold exactly these wire values? Full content
    /// equality — O(k·n) reads, cheaper than a repack and collision-proof
    /// where a hash key could silently serve a stale B.
    pub fn matches_wire(&self, wire: &[i32]) -> bool {
        self.raw.len() == wire.len()
            && self.raw.iter().zip(wire).all(|(&r, &w)| r == w as i8)
    }

    /// Reuse-or-repack cache refresh: return a `PackedB` holding exactly
    /// `wire`, reusing `prev` untouched on a content match and reusing its
    /// allocations on a miss. This is the per-artifact B cache of ad-hoc
    /// GEMM plans, where the weight operand arrives per request but almost
    /// always repeats.
    pub fn refresh_wire(prev: Option<PackedB>, wire: &[i32], k: usize, n: usize) -> Result<PackedB> {
        if let Some(pb) = prev {
            if pb.rows() == k && pb.cols() == n && pb.matches_wire(wire) {
                return Ok(pb);
            }
            let PackedB { mut raw, mut planes } = pb;
            raw.clear();
            raw.extend(wire.iter().map(|&v| v as i8));
            planes.pack_into(&raw, k, n)?;
            return Ok(PackedB { raw, planes });
        }
        PackedB::pack_wire(wire, k, n)
    }
}

/// The four nibble planes of a row-major INT16 matrix, least significant
/// plane first. Plane 3 is signed (`[-8, 7]`), planes 0–2 unsigned
/// (`[0, 15]`); all stored as `i8`.
#[derive(Debug, Clone)]
pub struct WidePlanes {
    /// Matrix rows.
    pub rows: usize,
    /// Matrix columns.
    pub cols: usize,
    /// `planes[p][r*cols + c]` is nibble `p` of element `(r, c)`.
    pub planes: [Vec<i8>; 4],
}

impl WidePlanes {
    /// Slice a row-major `rows × cols` INT16 matrix into its four planes.
    pub fn pack(data: &[i16], rows: usize, cols: usize) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "pack: {} elements for a {rows}x{cols} matrix",
                data.len()
            )));
        }
        let mut planes: [Vec<i8>; 4] = std::array::from_fn(|_| Vec::with_capacity(data.len()));
        for &v in data {
            let nb = slice_i16(v);
            for (p, plane) in planes.iter_mut().enumerate() {
                plane.push(nb.0[p] as i8);
            }
        }
        Ok(WidePlanes { rows, cols, planes })
    }

    /// Row `r` of plane `p` (length `cols`).
    #[inline]
    pub fn plane_row(&self, p: usize, r: usize) -> &[i8] {
        &self.planes[p][r * self.cols..(r + 1) * self.cols]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitslice::nibble::combine;
    use crate::bitslice::nibble::NibblePair;
    use crate::bitslice::wide::combine_i16;
    use crate::testing::SplitMix64;

    #[test]
    fn planes_reconstruct_every_i8() {
        let all: Vec<i8> = (i8::MIN..=i8::MAX).collect();
        let p = NibblePlanes::pack(&all, 16, 16).unwrap();
        for (i, &v) in all.iter().enumerate() {
            let pair = NibblePair { msn: p.msn[i], lsn: p.lsn[i] as u8 };
            assert_eq!(combine(pair), v);
        }
    }

    #[test]
    fn plane_rows_are_contiguous_slices() {
        let data: Vec<i8> = (0..12).map(|v| v as i8).collect();
        let p = NibblePlanes::pack(&data, 3, 4).unwrap();
        assert_eq!(p.msn_row(1).len(), 4);
        let expect: Vec<i8> = data[8..12].iter().map(|&v| lsn(v) as i8).collect();
        assert_eq!(p.lsn_row(2), &expect[..]);
    }

    #[test]
    fn plane_value_ranges() {
        let mut rng = SplitMix64::new(3);
        let data = rng.i8_vec(64);
        let p = NibblePlanes::pack(&data, 8, 8).unwrap();
        assert!(p.msn.iter().all(|&v| (-8..=7).contains(&v)));
        assert!(p.lsn.iter().all(|&v| (0..=15).contains(&v)));
    }

    #[test]
    fn bad_shape_rejected() {
        assert!(NibblePlanes::pack(&[1, 2, 3], 2, 2).is_err());
        assert!(WidePlanes::pack(&[1i16, 2], 3, 1).is_err());
        assert!(NibblePlanes::default().pack_into(&[1, 2, 3], 2, 2).is_err());
        assert!(PackedB::pack(&[1, 2, 3], 2, 2).is_err());
        assert!(PackedB::pack_wire(&[1, 2, 3], 2, 2).is_err());
    }

    #[test]
    fn pack_into_matches_pack_and_reuses_storage() {
        let mut rng = SplitMix64::new(19);
        let mut scratch = NibblePlanes::default();
        // Shrinking and growing refills: contents always equal a fresh pack.
        for (rows, cols) in [(4usize, 6usize), (2, 3), (8, 8), (0, 5), (3, 0), (5, 5)] {
            let data = rng.i8_vec(rows * cols);
            scratch.pack_into(&data, rows, cols).unwrap();
            let fresh = NibblePlanes::pack(&data, rows, cols).unwrap();
            assert_eq!((scratch.rows, scratch.cols), (rows, cols));
            assert_eq!(scratch.msn, fresh.msn);
            assert_eq!(scratch.lsn, fresh.lsn);
        }
        // After the 8x8 fill the buffers never need to grow again.
        let cap = scratch.msn.capacity();
        let data = rng.i8_vec(49);
        scratch.pack_into(&data, 7, 7).unwrap();
        assert_eq!(scratch.msn.capacity(), cap, "refill must not reallocate");
    }

    #[test]
    fn packed_b_holds_both_views_and_checks_content() {
        let mut rng = SplitMix64::new(23);
        let data = rng.i8_vec(12);
        let wire: Vec<i32> = data.iter().map(|&v| v as i32).collect();
        let pb = PackedB::pack(&data, 3, 4).unwrap();
        assert_eq!((pb.rows(), pb.cols()), (3, 4));
        assert_eq!(pb.raw(), &data[..]);
        let fresh = NibblePlanes::pack(&data, 3, 4).unwrap();
        assert_eq!(pb.planes().msn, fresh.msn);
        assert_eq!(pb.planes().lsn, fresh.lsn);
        assert!(pb.matches_wire(&wire));
        let mut other = wire.clone();
        other[5] ^= 1;
        assert!(!pb.matches_wire(&other));
        assert!(!pb.matches_wire(&wire[..11]));
        // Wire packing wraps i32 lanes exactly like `wire_to_i8`.
        let wrapped: Vec<i32> = wire.iter().map(|&v| v + 256).collect();
        assert!(pb.matches_wire(&wrapped));
        assert_eq!(PackedB::pack_wire(&wrapped, 3, 4).unwrap().raw(), &data[..]);
    }

    #[test]
    fn refresh_wire_hits_misses_and_repacks() {
        let mut rng = SplitMix64::new(29);
        let w1: Vec<i32> = (0..12).map(|_| rng.i8() as i32).collect();
        let w2: Vec<i32> = (0..12).map(|_| rng.i8() as i32).collect();
        let first = PackedB::refresh_wire(None, &w1, 3, 4).unwrap();
        assert!(first.matches_wire(&w1));
        // Hit: same content returns the same packing untouched.
        let hit = PackedB::refresh_wire(Some(first.clone()), &w1, 3, 4).unwrap();
        assert_eq!(hit.raw(), first.raw());
        assert_eq!(hit.planes().msn, first.planes().msn);
        // Miss: new content replaces, matching a from-scratch pack exactly.
        let miss = PackedB::refresh_wire(Some(first), &w2, 3, 4).unwrap();
        let scratch_pack = PackedB::pack_wire(&w2, 3, 4).unwrap();
        assert_eq!(miss.raw(), scratch_pack.raw());
        assert_eq!(miss.planes().msn, scratch_pack.planes().msn);
        assert_eq!(miss.planes().lsn, scratch_pack.planes().lsn);
        // Shape change is a miss too (same byte length, different dims).
        let reshaped = PackedB::refresh_wire(Some(miss), &w2, 4, 3).unwrap();
        assert_eq!((reshaped.rows(), reshaped.cols()), (4, 3));
        // Bad refresh shapes propagate errors.
        assert!(PackedB::refresh_wire(None, &w1, 5, 5).is_err());
        assert!(PackedB::refresh_wire(Some(reshaped), &w1, 5, 5).is_err());
    }

    #[test]
    fn wide_planes_reconstruct_i16() {
        let vals: Vec<i16> = vec![-32768, -4097, -1, 0, 1, 255, 4096, 32767];
        let p = WidePlanes::pack(&vals, 2, 4).unwrap();
        for (i, &v) in vals.iter().enumerate() {
            let nb = crate::bitslice::wide::Nibbles16([
                p.planes[0][i] as i32,
                p.planes[1][i] as i32,
                p.planes[2][i] as i32,
                p.planes[3][i] as i32,
            ]);
            assert_eq!(combine_i16(nb), v);
        }
    }

    #[test]
    fn wide_plane_ranges() {
        let mut rng = SplitMix64::new(11);
        let data: Vec<i16> = (0..64).map(|_| rng.next_u64() as i16).collect();
        let p = WidePlanes::pack(&data, 8, 8).unwrap();
        for plane in &p.planes[..3] {
            assert!(plane.iter().all(|&v| (0..=15).contains(&v)));
        }
        assert!(p.planes[3].iter().all(|&v| (-8..=7).contains(&v)));
        assert_eq!(p.plane_row(2, 3).len(), 8);
    }
}
