//! Packed nibble-plane operand layout for the fast bit-sliced GEMM engine.
//!
//! The naive reference kernels ([`crate::bitslice::gemm::gemm_sliced_naive`],
//! [`crate::bitslice::gemm::gemm_lanes_naive`]) call `slice_i8` on *every*
//! operand element inside the innermost loop — the B operand is re-sliced
//! once per output row, an O(m·k·n) redundancy. This module decomposes each
//! operand **once** into flat, contiguous *nibble planes*:
//!
//! ```text
//! A (m×k, i8)  →  msn plane (m×k, i8 in [-8,7]) + lsn plane (m×k, i8 in [0,15])
//! B (k×n, i8)  →  msn plane (k×n)               + lsn plane (k×n)
//! ```
//!
//! so slicing costs O(m·k + k·n) and the micro-kernels in
//! [`crate::bitslice::kernel`] stream the planes row-contiguously (B plane
//! rows are unit-stride in `j`, exactly what the i–k–j loop order wants).
//! Planes are stored as `i8` (not a wider type) deliberately: nibble values
//! fit, the memory traffic halves versus i16, and the micro-kernel widens to
//! i32 registers only at multiply time.
//!
//! [`WidePlanes`] is the four-plane INT16 analogue used by the 7-lane
//! `wide` dataflow.

use crate::bitslice::nibble::{lsn, msn};
use crate::bitslice::wide::slice_i16;
use crate::{Error, Result};

/// The two nibble planes of a row-major INT8 matrix.
#[derive(Debug, Clone)]
pub struct NibblePlanes {
    /// Matrix rows.
    pub rows: usize,
    /// Matrix columns (unit stride within a plane row).
    pub cols: usize,
    /// Most-significant-nibble plane, values in `[-8, 7]`.
    pub msn: Vec<i8>,
    /// Least-significant-nibble plane, values in `[0, 15]`.
    pub lsn: Vec<i8>,
}

impl NibblePlanes {
    /// Slice a row-major `rows × cols` INT8 matrix into its two planes.
    pub fn pack(data: &[i8], rows: usize, cols: usize) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "pack: {} elements for a {rows}x{cols} matrix",
                data.len()
            )));
        }
        let mut m_plane = Vec::with_capacity(data.len());
        let mut l_plane = Vec::with_capacity(data.len());
        for &v in data {
            m_plane.push(msn(v));
            l_plane.push(lsn(v) as i8);
        }
        Ok(NibblePlanes { rows, cols, msn: m_plane, lsn: l_plane })
    }

    /// MSN plane row `r` (length `cols`).
    #[inline]
    pub fn msn_row(&self, r: usize) -> &[i8] {
        &self.msn[r * self.cols..(r + 1) * self.cols]
    }

    /// LSN plane row `r` (length `cols`).
    #[inline]
    pub fn lsn_row(&self, r: usize) -> &[i8] {
        &self.lsn[r * self.cols..(r + 1) * self.cols]
    }
}

/// The four nibble planes of a row-major INT16 matrix, least significant
/// plane first. Plane 3 is signed (`[-8, 7]`), planes 0–2 unsigned
/// (`[0, 15]`); all stored as `i8`.
#[derive(Debug, Clone)]
pub struct WidePlanes {
    /// Matrix rows.
    pub rows: usize,
    /// Matrix columns.
    pub cols: usize,
    /// `planes[p][r*cols + c]` is nibble `p` of element `(r, c)`.
    pub planes: [Vec<i8>; 4],
}

impl WidePlanes {
    /// Slice a row-major `rows × cols` INT16 matrix into its four planes.
    pub fn pack(data: &[i16], rows: usize, cols: usize) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "pack: {} elements for a {rows}x{cols} matrix",
                data.len()
            )));
        }
        let mut planes: [Vec<i8>; 4] = std::array::from_fn(|_| Vec::with_capacity(data.len()));
        for &v in data {
            let nb = slice_i16(v);
            for (p, plane) in planes.iter_mut().enumerate() {
                plane.push(nb.0[p] as i8);
            }
        }
        Ok(WidePlanes { rows, cols, planes })
    }

    /// Row `r` of plane `p` (length `cols`).
    #[inline]
    pub fn plane_row(&self, p: usize, r: usize) -> &[i8] {
        &self.planes[p][r * self.cols..(r + 1) * self.cols]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitslice::nibble::combine;
    use crate::bitslice::nibble::NibblePair;
    use crate::bitslice::wide::combine_i16;
    use crate::testing::SplitMix64;

    #[test]
    fn planes_reconstruct_every_i8() {
        let all: Vec<i8> = (i8::MIN..=i8::MAX).collect();
        let p = NibblePlanes::pack(&all, 16, 16).unwrap();
        for (i, &v) in all.iter().enumerate() {
            let pair = NibblePair { msn: p.msn[i], lsn: p.lsn[i] as u8 };
            assert_eq!(combine(pair), v);
        }
    }

    #[test]
    fn plane_rows_are_contiguous_slices() {
        let data: Vec<i8> = (0..12).map(|v| v as i8).collect();
        let p = NibblePlanes::pack(&data, 3, 4).unwrap();
        assert_eq!(p.msn_row(1).len(), 4);
        let expect: Vec<i8> = data[8..12].iter().map(|&v| lsn(v) as i8).collect();
        assert_eq!(p.lsn_row(2), &expect[..]);
    }

    #[test]
    fn plane_value_ranges() {
        let mut rng = SplitMix64::new(3);
        let data = rng.i8_vec(64);
        let p = NibblePlanes::pack(&data, 8, 8).unwrap();
        assert!(p.msn.iter().all(|&v| (-8..=7).contains(&v)));
        assert!(p.lsn.iter().all(|&v| (0..=15).contains(&v)));
    }

    #[test]
    fn bad_shape_rejected() {
        assert!(NibblePlanes::pack(&[1, 2, 3], 2, 2).is_err());
        assert!(WidePlanes::pack(&[1i16, 2], 3, 1).is_err());
    }

    #[test]
    fn wide_planes_reconstruct_i16() {
        let vals: Vec<i16> = vec![-32768, -4097, -1, 0, 1, 255, 4096, 32767];
        let p = WidePlanes::pack(&vals, 2, 4).unwrap();
        for (i, &v) in vals.iter().enumerate() {
            let nb = crate::bitslice::wide::Nibbles16([
                p.planes[0][i] as i32,
                p.planes[1][i] as i32,
                p.planes[2][i] as i32,
                p.planes[3][i] as i32,
            ]);
            assert_eq!(combine_i16(nb), v);
        }
    }

    #[test]
    fn wide_plane_ranges() {
        let mut rng = SplitMix64::new(11);
        let data: Vec<i16> = (0..64).map(|_| rng.next_u64() as i16).collect();
        let p = WidePlanes::pack(&data, 8, 8).unwrap();
        for plane in &p.planes[..3] {
            assert!(plane.iter().all(|&v| (0..=15).contains(&v)));
        }
        assert!(p.planes[3].iter().all(|&v| (-8..=7).contains(&v)));
        assert_eq!(p.plane_row(2, 3).len(), 8);
    }
}
