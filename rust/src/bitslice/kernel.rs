//! Cache-blocked, multithreaded micro-kernels over packed nibble planes.
//!
//! This is the fast half of the naive-vs-fast dispatch contract (see
//! [`crate::bitslice`] module docs): every kernel here is **bit-exact**
//! against its `*_naive` oracle in [`crate::bitslice::gemm`] /
//! [`crate::bitslice::wide`] — the property suite enforces it for random
//! shapes, non-tile-multiple dimensions and extreme operands.
//!
//! Structure of every kernel:
//!
//! 1. **Pack once** — operands are sliced into flat nibble planes
//!    ([`NibblePlanes`] / [`WidePlanes`]), O(m·k + k·n) instead of the naive
//!    O(m·k·n) re-slicing.
//! 2. **Cache blocking** — i–k–j loop order with `kc × jc` panel blocking:
//!    a `kc`-deep stripe of the B planes stays hot in cache while every row
//!    of the band streams over it; `jc` bounds the C/B row segments so the
//!    accumulator rows live in L1.
//! 3. **Row-band threading** — the M dimension splits into near-equal bands,
//!    one `std::thread::scope` thread per band. Bands own disjoint slabs of
//!    the output (`split_at_mut`), so there is no synchronization on the hot
//!    path.
//! 4. **Micro-kernel selection** ([`MicroKernel`]) — the innermost j-loop
//!    runs the historical scalar axpy (`Scalar`, kept as a second oracle
//!    next to `*_naive`), a register-blocked kernel (`Simd`): fixed-width
//!    `[i32; BLOCK_W]` accumulators held across a k-panel over unit-stride
//!    `plane_row` slices — a shape LLVM's autovectorizer turns into SIMD on
//!    every target — plus a hand-written SSE2 block for the direct i32
//!    kernel on `x86_64` (SSE2 is baseline there, so no runtime feature
//!    detection), or a twice-as-wide `Avx2` variant (`AVX2_BLOCK_W = 16`
//!    outputs per block: a hand-written AVX2 block for the direct i32
//!    kernel, `[i32; 16]` register blocks compiled with
//!    `#[target_feature(enable = "avx2")]` for the plane kernels). `Avx2`
//!    is gated at runtime by `is_x86_feature_detected!` — on hosts (or
//!    targets) without AVX2 it silently resolves to `Simd`, so pinning it
//!    in a config is always safe. [`MicroKernel::preferred`] picks the
//!    widest available variant and is what the `TileConfig` constructors
//!    use. Integer addition is exactly associative, so reassociating the
//!    k-panel sums into registers — at either width — is bit-exact by
//!    construction and pinned by the property suites.
//!
//! Packing is separable from compute: the `gemm_*_packed` entry points
//! consume operands the caller packed ahead of time (see
//! [`crate::bitslice::packed`]'s pack-once/stream-many contract), which is
//! what the runtime plans use to stop re-slicing weights per request.
//!
//! [`TileConfig`] carries the knobs; [`dispatch_config`] is the policy the
//! public `gemm_*` entry points use to decide naive vs packed and how many
//! threads the problem deserves.

use std::sync::OnceLock;

use crate::bitslice::gemm::{check_dims, LaneGemm, SlicedGemm};
use crate::bitslice::packed::{NibblePlanes, WidePlanes};
use crate::bitslice::wide::{check_dims_i16, WideLanes};
use crate::{Error, Result};

/// MAC-count threshold below which the naive kernels win (packing and
/// thread setup dominate for tiny problems).
pub const PACKED_MIN_MACS: usize = 1 << 15;

/// MACs of per-thread work a band should amortize before another thread is
/// worth spawning (~0.1 ms of scalar work).
const PAR_GRAIN_MACS: usize = 1 << 17;

/// Fixed width of the register-blocked (`Simd`) micro-kernels: one block is
/// `BLOCK_W` unit-stride outputs accumulated in `[i32; BLOCK_W]` registers
/// across a k-panel (and exactly two SSE2 vectors on `x86_64`).
pub const BLOCK_W: usize = 8;

/// Width of the `Avx2` micro-kernel blocks: 16 unit-stride outputs, exactly
/// two 256-bit accumulators for the direct i32 kernel.
pub const AVX2_BLOCK_W: usize = 16;

/// Inner micro-kernel the tiled kernels run in their j-loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MicroKernel {
    /// The historical scalar axpy loops — kept as a fast second oracle next
    /// to `*_naive` (the property suites pin `Avx2 == Simd == Scalar ==
    /// naive`).
    Scalar,
    /// Register-blocked `[i32; BLOCK_W]` accumulators over plane-row slices
    /// (autovectorized everywhere; hand-written SSE2 for the direct i32
    /// kernel on `x86_64`). Bit-exact with `Scalar`: integer addition is
    /// exactly associative, so holding the k-panel sum in registers before
    /// one memory update cannot change any output. The INT16 `wide` kernel
    /// has no blocked variant yet and ignores this knob.
    #[default]
    Simd,
    /// Twice-as-wide register blocks (`AVX2_BLOCK_W = 16` outputs): a
    /// hand-written AVX2 block for the direct i32 kernel plus `[i32; 16]`
    /// blocks compiled under `#[target_feature(enable = "avx2")]` for the
    /// plane kernels. Runtime-gated: resolves to [`MicroKernel::Simd`] via
    /// [`MicroKernel::resolved`] when the host (or target) lacks AVX2, so
    /// requesting it is always safe. Same exact-associativity argument as
    /// `Simd`, so bit-exact with every other variant.
    Avx2,
}

impl MicroKernel {
    /// The variant that will actually run on this host: `Avx2` degrades to
    /// `Simd` when AVX2 is unavailable (non-`x86_64` targets, or x86_64
    /// hosts without the feature). Every band resolves its config through
    /// this before entering the j-loop.
    #[inline]
    pub fn resolved(self) -> MicroKernel {
        match self {
            MicroKernel::Avx2 if !avx2_available() => MicroKernel::Simd,
            other => other,
        }
    }

    /// The widest micro-kernel available on this host — what the
    /// [`TileConfig`] constructors install — unless a process-wide override
    /// is set via [`set_micro_override`] (the bench/CI A/B knob).
    #[inline]
    pub fn preferred() -> MicroKernel {
        match micro_override() {
            Some(m) => m,
            None if avx2_available() => MicroKernel::Avx2,
            None => MicroKernel::Simd,
        }
    }
}

/// Cached runtime AVX2 detection (`false` off `x86_64`).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static AVAIL: OnceLock<bool> = OnceLock::new();
        *AVAIL.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
    }
    #[cfg(not(target_arch = "x86_64"))]
    false
}

/// Encoded [`set_micro_override`] state: 0 = none, then variant + 1.
static MICRO_OVERRIDE: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(0);

/// Process-wide override of [`MicroKernel::preferred`], for benches and CI
/// smoke that A/B the micro-kernel through serving paths whose `TileConfig`
/// is chosen internally (the backend hot paths). `None` restores hardware
/// detection. Takes effect on the next `TileConfig` construction; configs
/// already built keep their pinned variant.
pub fn set_micro_override(micro: Option<MicroKernel>) {
    let code = match micro {
        None => 0,
        Some(MicroKernel::Scalar) => 1,
        Some(MicroKernel::Simd) => 2,
        Some(MicroKernel::Avx2) => 3,
    };
    MICRO_OVERRIDE.store(code, std::sync::atomic::Ordering::Relaxed);
}

/// The current [`set_micro_override`] setting, if any.
pub fn micro_override() -> Option<MicroKernel> {
    match MICRO_OVERRIDE.load(std::sync::atomic::Ordering::Relaxed) {
        1 => Some(MicroKernel::Scalar),
        2 => Some(MicroKernel::Simd),
        3 => Some(MicroKernel::Avx2),
        _ => None,
    }
}

/// Tiling/threading knobs for the packed kernels.
#[derive(Debug, Clone, Copy)]
pub struct TileConfig {
    /// K-dimension block depth (rows of the B panel kept hot per pass).
    pub kc: usize,
    /// J-dimension block width (C/B row segment length, bounds L1 footprint).
    pub jc: usize,
    /// Row bands to run in parallel (clamped to the row count; `1` = no
    /// threads spawned).
    pub threads: usize,
    /// Inner micro-kernel ([`MicroKernel::Simd`] by default).
    pub micro: MicroKernel,
}

impl TileConfig {
    /// Default blocking with a single band (no threads).
    pub fn single_thread() -> Self {
        TileConfig { kc: 256, jc: 1024, threads: 1, micro: MicroKernel::preferred() }
    }

    /// Default blocking using every available core.
    pub fn auto() -> Self {
        TileConfig { kc: 256, jc: 1024, threads: default_threads(), micro: MicroKernel::preferred() }
    }

    /// Blocking for a concrete problem: thread count scales with the MAC
    /// count so small problems do not pay spawn overhead.
    pub fn auto_for(m: usize, k: usize, n: usize) -> Self {
        let work = m.saturating_mul(k).saturating_mul(n);
        let threads = (work / PAR_GRAIN_MACS).clamp(1, default_threads());
        TileConfig { kc: 256, jc: 1024, threads, micro: MicroKernel::preferred() }
    }

    /// This config with a different micro-kernel (oracle cross-checks).
    pub fn with_micro(mut self, micro: MicroKernel) -> Self {
        self.micro = micro;
        self
    }
}

impl Default for TileConfig {
    fn default() -> Self {
        TileConfig::auto()
    }
}

/// Cached `std::thread::available_parallelism`.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Dispatch policy for the public `gemm_*` entry points: `None` means the
/// naive oracle is the right kernel.
///
/// Two gates must pass:
/// * the MAC count is large enough to amortize packing and setup, and
/// * packing actually removes redundancy — the naive loops re-slice A `n`
///   times and B `m` times, so each packed element must be reused a few
///   times (`m·k·n ≥ 4·(m·k + k·n)`). Vector-shaped problems (e.g. a
///   1×K×1 dot product) fail this: packing them is pure overhead.
pub fn dispatch_config(m: usize, k: usize, n: usize) -> Option<TileConfig> {
    let work = m.saturating_mul(k).saturating_mul(n);
    let pack_cost = m.saturating_mul(k).saturating_add(k.saturating_mul(n));
    if work < PACKED_MIN_MACS || work < pack_cost.saturating_mul(4) {
        None
    } else {
        Some(TileConfig::auto_for(m, k, n))
    }
}

/// Split `m` rows into at most `want` near-equal `(start, end)` bands.
fn bands(m: usize, want: usize) -> Vec<(usize, usize)> {
    let t = want.clamp(1, m.max(1));
    let base = m / t;
    let rem = m % t;
    let mut out = Vec::with_capacity(t);
    let mut r0 = 0;
    for i in 0..t {
        let r1 = r0 + base + usize::from(i < rem);
        out.push((r0, r1));
        r0 = r1;
    }
    out
}

// ---------------------------------------------------------------------------
// direct i32 GEMM
// ---------------------------------------------------------------------------

/// Tiled + threaded direct INT8→i32 GEMM (bit-exact vs `gemm_i32_naive`).
pub fn gemm_i32_tiled(
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    cfg: &TileConfig,
) -> Result<Vec<i32>> {
    let mut c = Vec::new();
    gemm_i32_tiled_into(a, b, m, k, n, cfg, &mut c)?;
    Ok(c)
}

/// [`gemm_i32_tiled`] writing into a caller-owned output vector (cleared and
/// resized to `m·n`) — allocation-free once the vector has grown to the
/// working size. The CNN serving scratch arena streams every layer GEMM
/// through this.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i32_tiled_into(
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    cfg: &TileConfig,
    c: &mut Vec<i32>,
) -> Result<()> {
    check_dims(a, b, m, k, n)?;
    c.clear();
    c.resize(m * n, 0);
    let band_list = bands(m, cfg.threads);
    if band_list.len() <= 1 {
        i32_band(a, b, k, n, 0, m, c, cfg);
    } else {
        std::thread::scope(|s| {
            let mut rest = c.as_mut_slice();
            for &(r0, r1) in &band_list {
                let (slab, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * n);
                rest = tail;
                s.spawn(move || i32_band(a, b, k, n, r0, r1, slab, cfg));
            }
        });
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn i32_band(
    a: &[i8],
    b: &[i8],
    k: usize,
    n: usize,
    r0: usize,
    r1: usize,
    c: &mut [i32],
    cfg: &TileConfig,
) {
    let kc = cfg.kc.max(1);
    let jc = cfg.jc.max(1);
    let micro = cfg.micro.resolved();
    for k0 in (0..k).step_by(kc) {
        let k1 = (k0 + kc).min(k);
        for j0 in (0..n).step_by(jc) {
            let j1 = (j0 + jc).min(n);
            for i in r0..r1 {
                let row = (i - r0) * n;
                let arow = &a[i * k..(i + 1) * k];
                let mut jb = j0;
                #[cfg(target_arch = "x86_64")]
                if micro == MicroKernel::Avx2 {
                    while jb + AVX2_BLOCK_W <= j1 {
                        // SAFETY: the target-feature contract holds —
                        // `resolved()` returns `Avx2` only after runtime
                        // detection (`avx2_available`) — and the loop bound
                        // keeps `jb + AVX2_BLOCK_W <= j1 <= n`, the bounds
                        // the callee's own assert re-establishes before any
                        // raw-pointer access.
                        unsafe {
                            i32_accum_block_avx2(
                                arow,
                                b,
                                n,
                                k0,
                                k1,
                                jb,
                                &mut c[row + jb..row + jb + AVX2_BLOCK_W],
                            );
                        }
                        jb += AVX2_BLOCK_W;
                    }
                }
                if micro != MicroKernel::Scalar {
                    while jb + BLOCK_W <= j1 {
                        i32_accum_block(arow, b, n, k0, k1, jb, &mut c[row + jb..row + jb + BLOCK_W]);
                        jb += BLOCK_W;
                    }
                }
                // Scalar micro-kernel, and the < BLOCK_W tail of the blocked ones.
                if jb < j1 {
                    let crow = &mut c[row + jb..row + j1];
                    for kk in k0..k1 {
                        let av = arow[kk] as i32;
                        if av == 0 {
                            continue;
                        }
                        let brow = &b[kk * n + jb..kk * n + j1];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += av * bv as i32;
                        }
                    }
                }
            }
        }
    }
}

/// One `BLOCK_W`-wide j-block of the direct kernel:
/// `cseg[t] += Σ_{kk∈[k0,k1)} a_row[kk] · B[kk][jb+t]`, accumulated in
/// registers across the whole k-panel and flushed to memory once.
///
/// `x86_64` variant: SSE2 intrinsics (baseline on the target, no feature
/// detection needed). Exact i32 products of i8×i8 via the widening
/// mullo/mulhi pattern: sign-extend the eight B bytes to i16, multiply by
/// the broadcast A value keeping low and high product halves, then
/// interleave halves into four+four exact i32 lanes.
#[cfg(target_arch = "x86_64")]
#[inline]
fn i32_accum_block(arow: &[i8], b: &[i8], n: usize, k0: usize, k1: usize, jb: usize, cseg: &mut [i32]) {
    use std::arch::x86_64::*;
    // Uphold the raw-pointer loads below: 8 B bytes at kk*n + jb for every
    // kk < k1 (b.len() == k*n with k1 <= k), and an 8-lane C segment.
    assert!(cseg.len() == BLOCK_W && jb + BLOCK_W <= n && k1.saturating_mul(n) <= b.len());
    // SAFETY: the assert bounds every `add` offset; loadl/loadu/storeu are
    // the unaligned-access intrinsics, so no alignment requirement exists.
    unsafe {
        let zero = _mm_setzero_si128();
        let mut acc0 = zero;
        let mut acc1 = zero;
        for kk in k0..k1 {
            let av = arow[kk];
            if av == 0 {
                continue;
            }
            let a16 = _mm_set1_epi16(av as i16);
            let x = _mm_loadl_epi64(b.as_ptr().add(kk * n + jb) as *const __m128i);
            let x16 = _mm_unpacklo_epi8(x, _mm_cmpgt_epi8(zero, x));
            let lo = _mm_mullo_epi16(x16, a16);
            let hi = _mm_mulhi_epi16(x16, a16);
            acc0 = _mm_add_epi32(acc0, _mm_unpacklo_epi16(lo, hi));
            acc1 = _mm_add_epi32(acc1, _mm_unpackhi_epi16(lo, hi));
        }
        let cp = cseg.as_mut_ptr() as *mut __m128i;
        _mm_storeu_si128(cp, _mm_add_epi32(_mm_loadu_si128(cp), acc0));
        let cp1 = cp.add(1);
        _mm_storeu_si128(cp1, _mm_add_epi32(_mm_loadu_si128(cp1), acc1));
    }
}

/// One `AVX2_BLOCK_W`-wide j-block of the direct kernel: same contract as
/// [`i32_accum_block`] at twice the width, held in two 256-bit accumulators
/// across the k-panel and flushed once.
///
/// Sixteen B bytes sign-extend to two 8-lane i32 vectors
/// (`_mm256_cvtepi8_epi32` preserves memory order), multiply by the
/// broadcast A value with `_mm256_mullo_epi32` — exact, since
/// `|a·b| ≤ 128² < 2³¹` — and accumulate. Bit-exact with the scalar
/// tail by integer-add associativity.
///
/// # Safety
/// Caller must have verified AVX2 via [`avx2_available`] (the bands only
/// take this path when `resolved()` returns `Avx2`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: `unsafe` here is the target-feature contract (callers enter only
// behind a `MicroKernel::Avx2` dispatch, which runtime detection gates);
// the raw-pointer loads below are bounded by the assert at the top of the
// body (`k1 * n <= b.len()`, a 16-lane C segment at `jb`).
unsafe fn i32_accum_block_avx2(
    arow: &[i8],
    b: &[i8],
    n: usize,
    k0: usize,
    k1: usize,
    jb: usize,
    cseg: &mut [i32],
) {
    use std::arch::x86_64::*;
    // Uphold the raw-pointer loads below: 16 B bytes at kk*n + jb for every
    // kk < k1 (b.len() == k*n with k1 <= k), and a 16-lane C segment.
    assert!(cseg.len() == AVX2_BLOCK_W && jb + AVX2_BLOCK_W <= n && k1.saturating_mul(n) <= b.len());
    // SAFETY: the assert bounds every `add` offset; loadu/storeu have no
    // alignment requirement.
    unsafe {
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        for kk in k0..k1 {
            let av = arow[kk];
            if av == 0 {
                continue;
            }
            let a32 = _mm256_set1_epi32(av as i32);
            let x = _mm_loadu_si128(b.as_ptr().add(kk * n + jb) as *const __m128i);
            let x0 = _mm256_cvtepi8_epi32(x);
            let x1 = _mm256_cvtepi8_epi32(_mm_srli_si128(x, 8));
            acc0 = _mm256_add_epi32(acc0, _mm256_mullo_epi32(x0, a32));
            acc1 = _mm256_add_epi32(acc1, _mm256_mullo_epi32(x1, a32));
        }
        let cp = cseg.as_mut_ptr() as *mut __m256i;
        _mm256_storeu_si256(cp, _mm256_add_epi32(_mm256_loadu_si256(cp), acc0));
        let cp1 = cp.add(1);
        _mm256_storeu_si256(cp1, _mm256_add_epi32(_mm256_loadu_si256(cp1), acc1));
    }
}

/// Portable variant of the block above: fixed-width `[i32; BLOCK_W]`
/// accumulators over unit-stride slices, written so the autovectorizer can
/// keep the block in vector registers.
#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn i32_accum_block(arow: &[i8], b: &[i8], n: usize, k0: usize, k1: usize, jb: usize, cseg: &mut [i32]) {
    let mut acc = [0i32; BLOCK_W];
    for kk in k0..k1 {
        let av = arow[kk] as i32;
        if av == 0 {
            continue;
        }
        let brow = &b[kk * n + jb..kk * n + jb + BLOCK_W];
        for t in 0..BLOCK_W {
            acc[t] += av * brow[t] as i32;
        }
    }
    for (cv, add) in cseg.iter_mut().zip(acc) {
        *cv += add;
    }
}

// ---------------------------------------------------------------------------
// SPOGA three-lane GEMM
// ---------------------------------------------------------------------------

/// Tiled + threaded SPOGA radix-lane GEMM over packed planes (bit-exact vs
/// `gemm_lanes_naive`).
pub fn gemm_lanes_tiled(
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    cfg: &TileConfig,
) -> Result<LaneGemm> {
    check_dims(a, b, m, k, n)?;
    let pa = NibblePlanes::pack(a, m, k)?;
    let pb = NibblePlanes::pack(b, k, n)?;
    gemm_lanes_packed(&pa, &pb, cfg)
}

/// [`gemm_lanes_tiled`] over operands the caller packed ahead of time
/// (pack-once/stream-many: B planes held in a plan, A planes packed into a
/// per-request scratch). Dimensions come from the planes.
pub fn gemm_lanes_packed(pa: &NibblePlanes, pb: &NibblePlanes, cfg: &TileConfig) -> Result<LaneGemm> {
    check_planes(pa, pb)?;
    let (m, n) = (pa.rows, pb.cols);
    let mut out = LaneGemm { hi: vec![0; m * n], mid: vec![0; m * n], lo: vec![0; m * n] };
    let band_list = bands(m, cfg.threads);
    if band_list.len() <= 1 {
        lanes_band(pa, pb, 0, m, &mut out.hi, &mut out.mid, &mut out.lo, cfg);
    } else {
        std::thread::scope(|s| {
            let mut hi = out.hi.as_mut_slice();
            let mut mid = out.mid.as_mut_slice();
            let mut lo = out.lo.as_mut_slice();
            for &(r0, r1) in &band_list {
                let take = (r1 - r0) * n;
                let (h, ht) = std::mem::take(&mut hi).split_at_mut(take);
                hi = ht;
                let (mi, mt) = std::mem::take(&mut mid).split_at_mut(take);
                mid = mt;
                let (l, lt) = std::mem::take(&mut lo).split_at_mut(take);
                lo = lt;
                s.spawn(move || lanes_band(pa, pb, r0, r1, h, mi, l, cfg));
            }
        });
    }
    Ok(out)
}

/// Shape check for prepacked plane operands (the packed entry points'
/// analogue of `check_dims`).
fn check_planes(pa: &NibblePlanes, pb: &NibblePlanes) -> Result<()> {
    if pa.cols != pb.rows {
        return Err(Error::Shape(format!(
            "packed planes disagree on K: A is {}x{}, B is {}x{}",
            pa.rows, pa.cols, pb.rows, pb.cols
        )));
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn lanes_band(
    pa: &NibblePlanes,
    pb: &NibblePlanes,
    r0: usize,
    r1: usize,
    hi: &mut [i32],
    mid: &mut [i32],
    lo: &mut [i32],
    cfg: &TileConfig,
) {
    let k = pa.cols;
    let n = pb.cols;
    let kc = cfg.kc.max(1);
    let jc = cfg.jc.max(1);
    let micro = cfg.micro.resolved();
    for k0 in (0..k).step_by(kc) {
        let k1 = (k0 + kc).min(k);
        for j0 in (0..n).step_by(jc) {
            let j1 = (j0 + jc).min(n);
            for i in r0..r1 {
                let row = (i - r0) * n;
                let am_row = pa.msn_row(i);
                let al_row = pa.lsn_row(i);
                let mut jb = j0;
                #[cfg(target_arch = "x86_64")]
                if micro == MicroKernel::Avx2 {
                    while jb + AVX2_BLOCK_W <= j1 {
                        // SAFETY: only the AVX2 target-feature contract is
                        // at stake — `resolved()` returns `Avx2` only after
                        // runtime detection (`avx2_available`); the callee
                        // body is safe slice code, bounds-checked as usual.
                        unsafe {
                            lanes_block_avx2(am_row, al_row, pb, k0, k1, jb, row, hi, mid, lo);
                        }
                        jb += AVX2_BLOCK_W;
                    }
                }
                if micro != MicroKernel::Scalar {
                    // Register-blocked: three [i32; BLOCK_W] accumulators per
                    // j-block held across the k-panel, flushed once.
                    while jb + BLOCK_W <= j1 {
                        lanes_block::<BLOCK_W>(am_row, al_row, pb, k0, k1, jb, row, hi, mid, lo);
                        jb += BLOCK_W;
                    }
                }
                // Scalar micro-kernel, and the < BLOCK_W tail of the blocked
                // ones.
                if jb < j1 {
                    for kk in k0..k1 {
                        let am = am_row[kk] as i32;
                        let al = al_row[kk] as i32;
                        if am == 0 && al == 0 {
                            continue;
                        }
                        let bm = &pb.msn_row(kk)[jb..j1];
                        let bl = &pb.lsn_row(kk)[jb..j1];
                        let hrow = &mut hi[row + jb..row + j1];
                        let mrow = &mut mid[row + jb..row + j1];
                        let lrow = &mut lo[row + jb..row + j1];
                        for jj in 0..j1 - jb {
                            let bmv = bm[jj] as i32;
                            let blv = bl[jj] as i32;
                            hrow[jj] += am * bmv;
                            mrow[jj] += am * blv + al * bmv;
                            lrow[jj] += al * blv;
                        }
                    }
                }
            }
        }
    }
}

/// One `BW`-wide j-block of the lane kernel: three `[i32; BW]` accumulators
/// held across the k-panel, flushed once. Monomorphized at `BLOCK_W` (the
/// `Simd` width) and `AVX2_BLOCK_W` (via [`lanes_block_avx2`], which
/// recompiles this body with AVX2 codegen enabled).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn lanes_block<const BW: usize>(
    am_row: &[i8],
    al_row: &[i8],
    pb: &NibblePlanes,
    k0: usize,
    k1: usize,
    jb: usize,
    row: usize,
    hi: &mut [i32],
    mid: &mut [i32],
    lo: &mut [i32],
) {
    let mut acc_h = [0i32; BW];
    let mut acc_m = [0i32; BW];
    let mut acc_l = [0i32; BW];
    for kk in k0..k1 {
        let am = am_row[kk] as i32;
        let al = al_row[kk] as i32;
        if am == 0 && al == 0 {
            continue;
        }
        let bm = &pb.msn_row(kk)[jb..jb + BW];
        let bl = &pb.lsn_row(kk)[jb..jb + BW];
        for t in 0..BW {
            let bmv = bm[t] as i32;
            let blv = bl[t] as i32;
            acc_h[t] += am * bmv;
            acc_m[t] += am * blv + al * bmv;
            acc_l[t] += al * blv;
        }
    }
    for t in 0..BW {
        hi[row + jb + t] += acc_h[t];
        mid[row + jb + t] += acc_m[t];
        lo[row + jb + t] += acc_l[t];
    }
}

/// [`lanes_block`] at `AVX2_BLOCK_W`, compiled with AVX2 enabled so LLVM
/// vectorizes the `[i32; 16]` accumulators at full ymm width. Safe code
/// inside; the attribute only changes codegen.
///
/// # Safety
/// Caller must have verified AVX2 via [`avx2_available`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
// SAFETY: `unsafe` here is only the target-feature contract — callers
// enter behind a `MicroKernel::Avx2` dispatch, which runtime detection
// gates. The body is ordinary safe slice code; the attribute changes
// codegen, not semantics.
unsafe fn lanes_block_avx2(
    am_row: &[i8],
    al_row: &[i8],
    pb: &NibblePlanes,
    k0: usize,
    k1: usize,
    jb: usize,
    row: usize,
    hi: &mut [i32],
    mid: &mut [i32],
    lo: &mut [i32],
) {
    lanes_block::<AVX2_BLOCK_W>(am_row, al_row, pb, k0, k1, jb, row, hi, mid, lo);
}

// ---------------------------------------------------------------------------
// prior-work four-slice GEMM
// ---------------------------------------------------------------------------

/// Tiled + threaded prior-work four-slice GEMM over packed planes (bit-exact
/// vs `gemm_sliced_naive`).
pub fn gemm_sliced_tiled(
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    cfg: &TileConfig,
) -> Result<SlicedGemm> {
    check_dims(a, b, m, k, n)?;
    let pa = NibblePlanes::pack(a, m, k)?;
    let pb = NibblePlanes::pack(b, k, n)?;
    gemm_sliced_packed(&pa, &pb, cfg)
}

/// [`gemm_sliced_tiled`] over operands the caller packed ahead of time.
pub fn gemm_sliced_packed(
    pa: &NibblePlanes,
    pb: &NibblePlanes,
    cfg: &TileConfig,
) -> Result<SlicedGemm> {
    check_planes(pa, pb)?;
    let (m, n) = (pa.rows, pb.cols);
    let mut out = SlicedGemm {
        mm: vec![0; m * n],
        ml: vec![0; m * n],
        lm: vec![0; m * n],
        ll: vec![0; m * n],
    };
    let band_list = bands(m, cfg.threads);
    if band_list.len() <= 1 {
        sliced_band(pa, pb, 0, m, &mut out.mm, &mut out.ml, &mut out.lm, &mut out.ll, cfg);
    } else {
        std::thread::scope(|s| {
            let mut mm = out.mm.as_mut_slice();
            let mut ml = out.ml.as_mut_slice();
            let mut lm = out.lm.as_mut_slice();
            let mut ll = out.ll.as_mut_slice();
            for &(r0, r1) in &band_list {
                let take = (r1 - r0) * n;
                let (s_mm, t_mm) = std::mem::take(&mut mm).split_at_mut(take);
                mm = t_mm;
                let (s_ml, t_ml) = std::mem::take(&mut ml).split_at_mut(take);
                ml = t_ml;
                let (s_lm, t_lm) = std::mem::take(&mut lm).split_at_mut(take);
                lm = t_lm;
                let (s_ll, t_ll) = std::mem::take(&mut ll).split_at_mut(take);
                ll = t_ll;
                s.spawn(move || sliced_band(pa, pb, r0, r1, s_mm, s_ml, s_lm, s_ll, cfg));
            }
        });
    }
    Ok(out)
}

#[allow(clippy::too_many_arguments)]
fn sliced_band(
    pa: &NibblePlanes,
    pb: &NibblePlanes,
    r0: usize,
    r1: usize,
    mm: &mut [i32],
    ml: &mut [i32],
    lm: &mut [i32],
    ll: &mut [i32],
    cfg: &TileConfig,
) {
    let k = pa.cols;
    let n = pb.cols;
    let kc = cfg.kc.max(1);
    let jc = cfg.jc.max(1);
    let micro = cfg.micro.resolved();
    for k0 in (0..k).step_by(kc) {
        let k1 = (k0 + kc).min(k);
        for j0 in (0..n).step_by(jc) {
            let j1 = (j0 + jc).min(n);
            for i in r0..r1 {
                let row = (i - r0) * n;
                let am_row = pa.msn_row(i);
                let al_row = pa.lsn_row(i);
                let mut jb = j0;
                #[cfg(target_arch = "x86_64")]
                if micro == MicroKernel::Avx2 {
                    while jb + AVX2_BLOCK_W <= j1 {
                        // SAFETY: only the AVX2 target-feature contract is
                        // at stake — `resolved()` returns `Avx2` only after
                        // runtime detection (`avx2_available`); the callee
                        // body is safe slice code, bounds-checked as usual.
                        unsafe {
                            sliced_block_avx2(am_row, al_row, pb, k0, k1, jb, row, mm, ml, lm, ll);
                        }
                        jb += AVX2_BLOCK_W;
                    }
                }
                if micro != MicroKernel::Scalar {
                    while jb + BLOCK_W <= j1 {
                        sliced_block::<BLOCK_W>(am_row, al_row, pb, k0, k1, jb, row, mm, ml, lm, ll);
                        jb += BLOCK_W;
                    }
                }
                if jb < j1 {
                    for kk in k0..k1 {
                        let am = am_row[kk] as i32;
                        let al = al_row[kk] as i32;
                        if am == 0 && al == 0 {
                            continue;
                        }
                        let bm = &pb.msn_row(kk)[jb..j1];
                        let bl = &pb.lsn_row(kk)[jb..j1];
                        let mm_row = &mut mm[row + jb..row + j1];
                        let ml_row = &mut ml[row + jb..row + j1];
                        let lm_row = &mut lm[row + jb..row + j1];
                        let ll_row = &mut ll[row + jb..row + j1];
                        for jj in 0..j1 - jb {
                            let bmv = bm[jj] as i32;
                            let blv = bl[jj] as i32;
                            mm_row[jj] += am * bmv;
                            ml_row[jj] += am * blv;
                            lm_row[jj] += al * bmv;
                            ll_row[jj] += al * blv;
                        }
                    }
                }
            }
        }
    }
}

/// One `BW`-wide j-block of the four-slice kernel; see [`lanes_block`].
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn sliced_block<const BW: usize>(
    am_row: &[i8],
    al_row: &[i8],
    pb: &NibblePlanes,
    k0: usize,
    k1: usize,
    jb: usize,
    row: usize,
    mm: &mut [i32],
    ml: &mut [i32],
    lm: &mut [i32],
    ll: &mut [i32],
) {
    let mut acc_mm = [0i32; BW];
    let mut acc_ml = [0i32; BW];
    let mut acc_lm = [0i32; BW];
    let mut acc_ll = [0i32; BW];
    for kk in k0..k1 {
        let am = am_row[kk] as i32;
        let al = al_row[kk] as i32;
        if am == 0 && al == 0 {
            continue;
        }
        let bm = &pb.msn_row(kk)[jb..jb + BW];
        let bl = &pb.lsn_row(kk)[jb..jb + BW];
        for t in 0..BW {
            let bmv = bm[t] as i32;
            let blv = bl[t] as i32;
            acc_mm[t] += am * bmv;
            acc_ml[t] += am * blv;
            acc_lm[t] += al * bmv;
            acc_ll[t] += al * blv;
        }
    }
    for t in 0..BW {
        mm[row + jb + t] += acc_mm[t];
        ml[row + jb + t] += acc_ml[t];
        lm[row + jb + t] += acc_lm[t];
        ll[row + jb + t] += acc_ll[t];
    }
}

/// [`sliced_block`] at `AVX2_BLOCK_W` with AVX2 codegen; see
/// [`lanes_block_avx2`].
///
/// # Safety
/// Caller must have verified AVX2 via [`avx2_available`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
// SAFETY: `unsafe` here is only the target-feature contract — callers
// enter behind a `MicroKernel::Avx2` dispatch, which runtime detection
// gates. The body is ordinary safe slice code; the attribute changes
// codegen, not semantics.
unsafe fn sliced_block_avx2(
    am_row: &[i8],
    al_row: &[i8],
    pb: &NibblePlanes,
    k0: usize,
    k1: usize,
    jb: usize,
    row: usize,
    mm: &mut [i32],
    ml: &mut [i32],
    lm: &mut [i32],
    ll: &mut [i32],
) {
    sliced_block::<AVX2_BLOCK_W>(am_row, al_row, pb, k0, k1, jb, row, mm, ml, lm, ll);
}

// ---------------------------------------------------------------------------
// INT16 seven-lane GEMM
// ---------------------------------------------------------------------------

/// Tiled + threaded INT16 seven-lane GEMM over packed four-nibble planes
/// (bit-exact vs `gemm_i16_lanes_naive`).
pub fn gemm_i16_lanes_tiled(
    a: &[i16],
    b: &[i16],
    m: usize,
    k: usize,
    n: usize,
    cfg: &TileConfig,
) -> Result<WideLanes> {
    check_dims_i16(a, b, m, k, n)?;
    let pa = WidePlanes::pack(a, m, k)?;
    let pb = WidePlanes::pack(b, k, n)?;
    gemm_i16_lanes_packed(&pa, &pb, cfg)
}

/// [`gemm_i16_lanes_tiled`] over four-nibble planes the caller packed ahead
/// of time. The wide kernel has no blocked micro-kernel yet, so
/// [`TileConfig::micro`] is ignored here.
pub fn gemm_i16_lanes_packed(
    pa: &WidePlanes,
    pb: &WidePlanes,
    cfg: &TileConfig,
) -> Result<WideLanes> {
    if pa.cols != pb.rows {
        return Err(Error::Shape(format!(
            "packed wide planes disagree on K: A is {}x{}, B is {}x{}",
            pa.rows, pa.cols, pb.rows, pb.cols
        )));
    }
    let (m, n) = (pa.rows, pb.cols);
    let mut out = WideLanes { lanes: std::array::from_fn(|_| vec![0i64; m * n]) };
    let band_list = bands(m, cfg.threads);
    if band_list.len() <= 1 {
        let mut slabs: Vec<&mut [i64]> = out.lanes.iter_mut().map(|v| v.as_mut_slice()).collect();
        wide_band(pa, pb, 0, m, &mut slabs, cfg);
    } else {
        std::thread::scope(|s| {
            let mut tails: Vec<&mut [i64]> =
                out.lanes.iter_mut().map(|v| v.as_mut_slice()).collect();
            for &(r0, r1) in &band_list {
                let take = (r1 - r0) * n;
                let mut slabs: Vec<&mut [i64]> = Vec::with_capacity(tails.len());
                for tail in tails.iter_mut() {
                    let (head, rest) = std::mem::take(tail).split_at_mut(take);
                    *tail = rest;
                    slabs.push(head);
                }
                s.spawn(move || wide_band(pa, pb, r0, r1, &mut slabs, cfg));
            }
        });
    }
    Ok(out)
}

fn wide_band(
    pa: &WidePlanes,
    pb: &WidePlanes,
    r0: usize,
    r1: usize,
    slabs: &mut [&mut [i64]],
    cfg: &TileConfig,
) {
    let k = pa.cols;
    let n = pb.cols;
    let kc = cfg.kc.max(1);
    let jc = cfg.jc.max(1);
    for k0 in (0..k).step_by(kc) {
        let k1 = (k0 + kc).min(k);
        for j0 in (0..n).step_by(jc) {
            let j1 = (j0 + jc).min(n);
            for i in r0..r1 {
                let row = (i - r0) * n;
                for kk in k0..k1 {
                    let na = [
                        pa.planes[0][i * k + kk] as i32,
                        pa.planes[1][i * k + kk] as i32,
                        pa.planes[2][i * k + kk] as i32,
                        pa.planes[3][i * k + kk] as i32,
                    ];
                    if na == [0, 0, 0, 0] {
                        continue;
                    }
                    for (p, &ap) in na.iter().enumerate() {
                        if ap == 0 {
                            continue;
                        }
                        for q in 0..4 {
                            let brow = &pb.plane_row(q, kk)[j0..j1];
                            let lane = &mut slabs[p + q][row + j0..row + j1];
                            for (acc, &bv) in lane.iter_mut().zip(brow) {
                                *acc += (ap * bv as i32) as i64;
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitslice::gemm::{gemm_i32_naive, gemm_lanes_naive, gemm_sliced_naive};
    use crate::bitslice::wide::gemm_i16_lanes_naive;
    use crate::testing::prop::GemmCase;
    use crate::testing::{forall, Gen, SplitMix64};

    /// Exotic tile configs that force partial blocks and multiple bands on
    /// tiny shapes.
    fn stress_cfgs() -> Vec<TileConfig> {
        vec![
            TileConfig { kc: 1, jc: 1, threads: 1, micro: MicroKernel::Scalar },
            TileConfig { kc: 3, jc: 2, threads: 2, micro: MicroKernel::Simd },
            TileConfig { kc: 2, jc: 5, threads: 3, micro: MicroKernel::Scalar },
            TileConfig { kc: 7, jc: 3, threads: 8, micro: MicroKernel::Simd },
            // Avx2 resolves to Simd on hosts without the feature, so these
            // rows are always valid and exercise 16-wide blocks where the
            // hardware has them (kc/jc sized to force partial 16-blocks).
            TileConfig { kc: 3, jc: 21, threads: 2, micro: MicroKernel::Avx2 },
            TileConfig { kc: 1024, jc: 1024, threads: 4, micro: MicroKernel::Avx2 },
            TileConfig { kc: 1024, jc: 1024, threads: 4, micro: MicroKernel::Simd },
            TileConfig { kc: 1024, jc: 1024, threads: 2, micro: MicroKernel::Scalar },
        ]
    }

    #[test]
    fn bands_cover_rows_exactly() {
        for (m, want) in [(1usize, 1usize), (1, 8), (10, 3), (7, 7), (64, 5), (3, 100)] {
            let bs = bands(m, want);
            assert!(bs.len() <= want.max(1) && bs.len() <= m);
            assert_eq!(bs.first().unwrap().0, 0);
            assert_eq!(bs.last().unwrap().1, m);
            for w in bs.windows(2) {
                assert_eq!(w[0].1, w[1].0);
                assert!(w[0].1 > w[0].0);
            }
        }
    }

    #[test]
    fn tiled_lanes_match_naive_under_stress_configs() {
        forall(101, 40, GemmCase { max_dim: 13 }, |(a, b, m, k, n)| {
            let expect = gemm_lanes_naive(a, b, *m, *k, *n).unwrap();
            stress_cfgs().iter().all(|cfg| {
                let got = gemm_lanes_tiled(a, b, *m, *k, *n, cfg).unwrap();
                got.hi == expect.hi && got.mid == expect.mid && got.lo == expect.lo
            })
        });
    }

    #[test]
    fn tiled_sliced_match_naive_under_stress_configs() {
        forall(103, 30, GemmCase { max_dim: 11 }, |(a, b, m, k, n)| {
            let expect = gemm_sliced_naive(a, b, *m, *k, *n).unwrap();
            stress_cfgs().iter().all(|cfg| {
                let got = gemm_sliced_tiled(a, b, *m, *k, *n, cfg).unwrap();
                got.mm == expect.mm
                    && got.ml == expect.ml
                    && got.lm == expect.lm
                    && got.ll == expect.ll
            })
        });
    }

    #[test]
    fn tiled_i32_matches_naive_under_stress_configs() {
        forall(107, 40, GemmCase { max_dim: 13 }, |(a, b, m, k, n)| {
            let expect = gemm_i32_naive(a, b, *m, *k, *n).unwrap();
            stress_cfgs()
                .iter()
                .all(|cfg| gemm_i32_tiled(a, b, *m, *k, *n, cfg).unwrap() == expect)
        });
    }

    #[test]
    fn tiled_wide_matches_naive_under_stress_configs() {
        forall(
            109,
            15,
            |rng: &mut SplitMix64| {
                let (m, k, n) =
                    (rng.range_usize(1, 7), rng.range_usize(1, 9), rng.range_usize(1, 7));
                let a: Vec<i16> = (0..m * k).map(|_| rng.next_u64() as i16).collect();
                let b: Vec<i16> = (0..k * n).map(|_| rng.next_u64() as i16).collect();
                (a, b, m, k, n)
            },
            |(a, b, m, k, n)| {
                let expect = gemm_i16_lanes_naive(a, b, *m, *k, *n).unwrap();
                stress_cfgs().iter().all(|cfg| {
                    let got = gemm_i16_lanes_tiled(a, b, *m, *k, *n, cfg).unwrap();
                    got.lanes == expect.lanes
                })
            },
        );
    }

    #[test]
    fn avx2_blocks_bit_exact_on_wide_shapes() {
        // max_dim in the property sweeps stays under AVX2_BLOCK_W, so the
        // 16-wide blocks need shapes that actually reach them: n spanning
        // full 16-blocks, an 8-block remainder, and a scalar tail.
        let mut rng = SplitMix64::new(2024);
        for (m, k, n) in [(3usize, 5usize, 16usize), (4, 33, 37), (7, 9, 61), (2, 129, 16 + 8 + 3)] {
            let a = rng.i8_vec(m * k);
            let b = rng.i8_vec(k * n);
            let expect = gemm_i32_naive(&a, &b, m, k, n).unwrap();
            let lanes_expect = gemm_lanes_naive(&a, &b, m, k, n).unwrap();
            let sliced_expect = gemm_sliced_naive(&a, &b, m, k, n).unwrap();
            for threads in [1usize, 3] {
                let cfg = TileConfig { kc: 16, jc: 48, threads, micro: MicroKernel::Avx2 };
                assert_eq!(gemm_i32_tiled(&a, &b, m, k, n, &cfg).unwrap(), expect);
                let lanes = gemm_lanes_tiled(&a, &b, m, k, n, &cfg).unwrap();
                assert_eq!(lanes.hi, lanes_expect.hi);
                assert_eq!(lanes.mid, lanes_expect.mid);
                assert_eq!(lanes.lo, lanes_expect.lo);
                let sliced = gemm_sliced_tiled(&a, &b, m, k, n, &cfg).unwrap();
                assert_eq!(sliced.mm, sliced_expect.mm);
                assert_eq!(sliced.ml, sliced_expect.ml);
                assert_eq!(sliced.lm, sliced_expect.lm);
                assert_eq!(sliced.ll, sliced_expect.ll);
            }
        }
    }

    #[test]
    fn avx2_resolution_is_host_consistent() {
        // On an AVX2 host the variant stays itself; elsewhere it degrades to
        // Simd. Scalar and Simd never change under resolution.
        assert_eq!(MicroKernel::Scalar.resolved(), MicroKernel::Scalar);
        assert_eq!(MicroKernel::Simd.resolved(), MicroKernel::Simd);
        let want = if avx2_available() { MicroKernel::Avx2 } else { MicroKernel::Simd };
        assert_eq!(MicroKernel::Avx2.resolved(), want);
    }

    #[test]
    fn micro_override_steers_preferred() {
        // Results stay bit-exact under any variant, so a concurrent test
        // constructing an auto config mid-override cannot be corrupted by
        // this — it would just run a different (equally exact) kernel.
        set_micro_override(Some(MicroKernel::Scalar));
        assert_eq!(MicroKernel::preferred(), MicroKernel::Scalar);
        assert_eq!(TileConfig::auto().micro, MicroKernel::Scalar);
        set_micro_override(Some(MicroKernel::Avx2));
        assert_eq!(MicroKernel::preferred(), MicroKernel::Avx2);
        set_micro_override(None);
        assert_eq!(micro_override(), None);
        assert_eq!(TileConfig::auto().micro.resolved(), TileConfig::auto().micro);
    }

    #[test]
    fn into_variant_reuses_buffer_and_matches() {
        let mut rng = SplitMix64::new(31);
        let (m, k, n) = (5usize, 17usize, 23usize);
        let a = rng.i8_vec(m * k);
        let b = rng.i8_vec(k * n);
        let want = gemm_i32_naive(&a, &b, m, k, n).unwrap();
        let cfg = TileConfig { kc: 4, jc: 7, threads: 2, micro: MicroKernel::Simd };
        // Dirty, differently-sized buffer: _into must clear and resize.
        let mut c = vec![i32::MIN; 3];
        gemm_i32_tiled_into(&a, &b, m, k, n, &cfg, &mut c).unwrap();
        assert_eq!(c, want);
        // Second call reuses capacity and stays exact.
        gemm_i32_tiled_into(&a, &b, m, k, n, &cfg, &mut c).unwrap();
        assert_eq!(c, want);
    }

    #[test]
    fn extreme_operands_bit_exact() {
        // All-(-128) by all-127 exercises the signed-MSN corner everywhere.
        let (m, k, n) = (5usize, 33usize, 9usize);
        let a = vec![-128i8; m * k];
        let b = vec![127i8; k * n];
        let cfg = TileConfig { kc: 4, jc: 4, threads: 3, micro: MicroKernel::Simd };
        let naive = gemm_lanes_naive(&a, &b, m, k, n).unwrap();
        let fast = gemm_lanes_tiled(&a, &b, m, k, n, &cfg).unwrap();
        assert_eq!(naive.weight_and_add(), fast.weight_and_add());
        assert_eq!(naive.hi, fast.hi);
        let wa = vec![i16::MIN; m * k];
        let wb = vec![i16::MAX; k * n];
        let wn = gemm_i16_lanes_naive(&wa, &wb, m, k, n).unwrap();
        let wf = gemm_i16_lanes_tiled(&wa, &wb, m, k, n, &cfg).unwrap();
        assert_eq!(wn.weight_and_add(), wf.weight_and_add());
    }

    #[test]
    fn shape_errors_propagate() {
        let cfg = TileConfig::single_thread();
        assert!(gemm_i32_tiled(&[1, 2, 3], &[1, 2], 2, 2, 1, &cfg).is_err());
        assert!(gemm_lanes_tiled(&[1, 2], &[1, 2, 3], 1, 2, 1, &cfg).is_err());
        assert!(gemm_i16_lanes_tiled(&[1i16], &[1, 2], 1, 2, 1, &cfg).is_err());
    }

    #[test]
    fn dispatch_policy_thresholds() {
        assert!(dispatch_config(4, 4, 4).is_none());
        assert!(dispatch_config(16, 16, 16).is_none()); // 4096 < 32768
        let cfg = dispatch_config(64, 64, 64).expect("64^3 uses the packed path");
        assert!(cfg.threads >= 1);
        assert!(dispatch_config(1024, 1024, 1024).unwrap().threads >= cfg.threads);
        // Vector shapes have no re-slicing redundancy: packing never pays,
        // however long the reduction.
        assert!(dispatch_config(1, 1 << 20, 1).is_none());
        assert!(dispatch_config(1 << 20, 4, 1).is_none());
        assert!(dispatch_config(1, 4, 1 << 20).is_none());
    }

    #[test]
    fn gemm_case_shrinker_stays_valid_for_tiled() {
        // Shrunk counterexamples must still be valid inputs for the tiled
        // kernels (regression guard for the shrinking path).
        let g = GemmCase { max_dim: 9 };
        let mut rng = SplitMix64::new(5);
        let case = g.gen(&mut rng);
        for (a, b, m, k, n) in g.shrink(&case) {
            let cfg = TileConfig { kc: 2, jc: 3, threads: 2, micro: MicroKernel::Simd };
            let naive = gemm_lanes_naive(&a, &b, m, k, n).unwrap();
            let fast = gemm_lanes_tiled(&a, &b, m, k, n, &cfg).unwrap();
            assert_eq!(naive.mid, fast.mid);
        }
    }
}
