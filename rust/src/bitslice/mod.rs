//! Exact integer semantics of nibble-sliced (bit-sliced) arithmetic.
//!
//! This is the **golden model** for everything numeric in the repo: the
//! Pallas kernel (L1), the PJRT artifacts (L2) and the architectural cost
//! models (L3) all decompose INT8 operands into 4-bit slices exactly the way
//! this module does, and the test suites cross-check against it.
//!
//! ## Decomposition (paper §II-C)
//!
//! An INT8 value `x` is split into a **M**ost **S**ignificant **N**ibble and
//! a **L**east **S**ignificant **N**ibble such that
//!
//! ```text
//! x = 16 · msn(x) + lsn(x),     lsn ∈ [0, 15],   msn ∈ [-8, 7]
//! ```
//!
//! The LSN is *unsigned* and the MSN carries the sign (two's complement
//! arithmetic right shift), so a product expands exactly as
//!
//! ```text
//! x·y = 256·(xₘ·yₘ) + 16·(xₘ·yₗ + xₗ·yₘ) + (xₗ·yₗ)
//! ```
//!
//! which is the paper's Fig. 2 identity with radix-position weights 16², 16¹
//! and 16⁰. The three bracketed terms are the **Hi/Mid/Lo radix lanes**
//! ([`crate::devices::bpca::RadixLane`]) that SPOGA accumulates on its three
//! BPCAs.

pub mod gemm;
pub mod nibble;
pub mod wide;

pub use gemm::{gemm_i32, gemm_lanes, gemm_sliced, LaneGemm};
pub use nibble::{combine, lsn, msn, slice_i8, NibblePair};
pub use wide::{gemm_i16_direct, gemm_i16_lanes, scheme_cost, slice_i16};
