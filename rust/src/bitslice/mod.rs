//! Exact integer semantics of nibble-sliced (bit-sliced) arithmetic.
//!
//! This is the **golden model** for everything numeric in the repo: the
//! Pallas kernel (L1), the PJRT artifacts (L2) and the architectural cost
//! models (L3) all decompose INT8 operands into 4-bit slices exactly the way
//! this module does, and the test suites cross-check against it.
//!
//! ## Decomposition (paper §II-C)
//!
//! An INT8 value `x` is split into a **M**ost **S**ignificant **N**ibble and
//! a **L**east **S**ignificant **N**ibble such that
//!
//! ```text
//! x = 16 · msn(x) + lsn(x),     lsn ∈ [0, 15],   msn ∈ [-8, 7]
//! ```
//!
//! The LSN is *unsigned* and the MSN carries the sign (two's complement
//! arithmetic right shift), so a product expands exactly as
//!
//! ```text
//! x·y = 256·(xₘ·yₘ) + 16·(xₘ·yₗ + xₗ·yₘ) + (xₗ·yₗ)
//! ```
//!
//! which is the paper's Fig. 2 identity with radix-position weights 16², 16¹
//! and 16⁰. The three bracketed terms are the **Hi/Mid/Lo radix lanes**
//! ([`crate::devices::bpca::RadixLane`]) that SPOGA accumulates on its three
//! BPCAs.
//!
//! ## Packed-plane layout and the naive-vs-fast dispatch contract
//!
//! Two implementations of every GEMM dataflow coexist:
//!
//! * **Naive oracles** (`gemm_i32_naive`, `gemm_sliced_naive`,
//!   `gemm_lanes_naive`, `gemm_i16_lanes_naive` in [`gemm`] / [`wide`]) —
//!   transparent loop nests that slice operands element-by-element inside
//!   the innermost loop, written to be checked against the paper by eye.
//! * **Packed kernels** ([`kernel`]) — each operand matrix is sliced *once*
//!   into flat nibble planes ([`packed::NibblePlanes`]: an `i8` MSN plane
//!   and an `i8` LSN plane, both row-major and unit-stride in the column
//!   index; [`packed::WidePlanes`] is the four-plane INT16 analogue). The
//!   micro-kernels then run a cache-blocked i–k–j loop over the planes and
//!   split the output into row bands executed by scoped threads.
//!
//! The **contract**: the public entry points (`gemm_i32`, `gemm_sliced`,
//! `gemm_lanes`, `gemm_i16_lanes`) dispatch by problem size
//! ([`kernel::dispatch_config`]) and are *always* bit-exact with the naive
//! oracles — the unit and property suites enforce equality for random
//! shapes, non-tile-multiple m/k/n and extreme operands. Code that needs a
//! specific implementation (benches, oracle cross-checks) calls the
//! `*_naive` functions or `kernel::gemm_*_tiled` with an explicit
//! [`kernel::TileConfig`] directly.
//!
//! ## Prepacked API (pack-once / stream-many)
//!
//! Packing is separable from compute, and weight-stationary serving exploits
//! it: pack the weight operand **once** ([`pack_b`] → [`packed::PackedB`],
//! raw bytes + nibble planes; or [`packed::NibblePlanes::pack`] /
//! [`packed::WidePlanes::pack`] directly) and stream activations against it
//! with [`gemm_i32_prepacked`], [`gemm_lanes_prepacked`],
//! [`gemm_sliced_prepacked`] and [`wide::gemm_i16_lanes_prepacked`]. The
//! activation side can reuse a caller-owned scratch via
//! [`packed::NibblePlanes::pack_into`], making the steady-state hot path
//! allocation-free. Prepacked entry points sit under the same contract:
//! bit-identical to the repack-per-call dispatchers and to the `*_naive`
//! oracles (pinned by `tests/prepacked.rs` and the property suite).
//!
//! ## SIMD dispatch policy
//!
//! [`kernel::TileConfig::micro`] selects the innermost kernel:
//! [`kernel::MicroKernel::Simd`] runs register-blocked `[i32; BLOCK_W]`
//! accumulation over unit-stride plane rows — autovectorizer-friendly on
//! every target, with a hand-written SSE2 block for the direct i32 kernel
//! on `x86_64` (SSE2 is baseline there; no feature detection needed).
//! [`kernel::MicroKernel::Avx2`] doubles the block width
//! (`AVX2_BLOCK_W = 16`: a hand-written AVX2 block for the direct i32
//! kernel, `[i32; 16]` blocks compiled under
//! `#[target_feature(enable = "avx2")]` for the plane kernels) and is gated
//! at **runtime** by `is_x86_feature_detected!` — on hosts without AVX2 it
//! resolves to `Simd` ([`kernel::MicroKernel::resolved`]), so configs may
//! pin it unconditionally. The `TileConfig` constructors install
//! [`kernel::MicroKernel::preferred`] (the widest available variant;
//! [`kernel::set_micro_override`] is the bench/CI knob that forces one
//! process-wide). Integer addition is exactly associative, so every blocked
//! kernel is bit-exact with [`kernel::MicroKernel::Scalar`] (the historical
//! loops, kept as a second oracle) and with `*_naive` — the property suites
//! run all of them against each other. The INT16 `wide` kernel ignores the
//! knob (no blocked variant yet).

pub mod gemm;
pub mod kernel;
pub mod nibble;
pub mod packed;
pub mod wide;

pub use gemm::{
    gemm_i32, gemm_i32_naive, gemm_i32_naive_into, gemm_i32_prepacked, gemm_i32_prepacked_into,
    gemm_lanes, gemm_lanes_naive, gemm_lanes_prepacked, gemm_sliced, gemm_sliced_naive,
    gemm_sliced_prepacked, pack_b, LaneGemm, SlicedGemm,
};
pub use kernel::{
    avx2_available, gemm_i16_lanes_packed, gemm_i16_lanes_tiled, gemm_i32_tiled,
    gemm_i32_tiled_into, gemm_lanes_packed, gemm_lanes_tiled, gemm_sliced_packed,
    gemm_sliced_tiled, micro_override, set_micro_override, MicroKernel, TileConfig, AVX2_BLOCK_W,
    BLOCK_W,
};
pub use nibble::{combine, lsn, msn, slice_i8, NibblePair};
pub use packed::{NibblePlanes, PackedB, WidePlanes};
pub use wide::{
    gemm_i16_direct, gemm_i16_lanes, gemm_i16_lanes_naive, gemm_i16_lanes_prepacked, scheme_cost,
    slice_i16, WideLanes,
};
