//! Extension: INT16 operands via four-nibble slicing.
//!
//! The paper's scheme (two INT4 slices per INT8 operand, three radix lanes)
//! generalizes: an INT16 operand splits into four nibbles
//! `x = 16³·n3 + 16²·n2 + 16·n1 + n0` (n3 signed, rest unsigned), and an
//! INT16×INT16 product expands into 16 nibble products that collapse onto
//! **seven** radix lanes (16⁰ … 16⁶) — a hypothetical 7-BPCA PWAB. This
//! module provides the exact integer semantics for that extension (listed
//! as the natural scale-up path in DESIGN.md §6), with i64 accumulators.

use crate::{Error, Result};

/// Nibbles of an INT16 value, least-significant first.
/// Invariant: `x = 4096·n[3] + 256·n[2] + 16·n[1] + n[0]`, `n[3] ∈ [-8,7]`,
/// others in `[0,15]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Nibbles16(pub [i32; 4]);

/// Slice an INT16 value into four nibbles (top nibble signed).
#[inline]
pub fn slice_i16(x: i16) -> Nibbles16 {
    let v = x as i32;
    Nibbles16([v & 0xF, (v >> 4) & 0xF, (v >> 8) & 0xF, v >> 12])
}

/// Recombine four nibbles into the INT16 value.
#[inline]
pub fn combine_i16(n: Nibbles16) -> i16 {
    (4096 * n.0[3] + 256 * n.0[2] + 16 * n.0[1] + n.0[0]) as i16
}

/// The seven radix-lane accumulators of the INT16 extension.
///
/// `lanes[d]` collects every nibble product `xi·yj` with `i + j == d`, the
/// lane's positional weight being `16^d`.
#[derive(Debug, Clone)]
pub struct WideLanes {
    /// Per-output lane sums: `lanes[d][out]`.
    pub lanes: [Vec<i64>; 7],
}

impl WideLanes {
    /// PWAB epilogue: weight each lane by 16^d and sum.
    pub fn weight_and_add(&self) -> Vec<i64> {
        let n = self.lanes[0].len();
        let mut out = vec![0i64; n];
        for (d, lane) in self.lanes.iter().enumerate() {
            let w = 16i64.pow(d as u32);
            for (o, v) in out.iter_mut().zip(lane) {
                *o += w * v;
            }
        }
        out
    }
}

pub(crate) fn check_dims_i16(a: &[i16], b: &[i16], m: usize, k: usize, n: usize) -> Result<()> {
    if a.len() != m * k || b.len() != k * n {
        return Err(Error::Shape(format!(
            "INT16 GEMM {m}x{k}x{n}: got {} and {} elements",
            a.len(),
            b.len()
        )));
    }
    Ok(())
}

/// Direct i64 reference GEMM for INT16 operands.
pub fn gemm_i16_direct(a: &[i16], b: &[i16], m: usize, k: usize, n: usize) -> Result<Vec<i64>> {
    check_dims_i16(a, b, m, k, n)?;
    let mut c = vec![0i64; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk] as i64;
            for j in 0..n {
                c[i * n + j] += av * b[kk * n + j] as i64;
            }
        }
    }
    Ok(c)
}

/// INT16 GEMM via the 7-lane SPOGA-style dataflow.
///
/// Dispatches to the packed four-plane kernel
/// ([`crate::bitslice::kernel::gemm_i16_lanes_tiled`]) for large problems;
/// bit-exact with [`gemm_i16_lanes_naive`] always.
pub fn gemm_i16_lanes(a: &[i16], b: &[i16], m: usize, k: usize, n: usize) -> Result<WideLanes> {
    match crate::bitslice::kernel::dispatch_config(m, k, n) {
        Some(cfg) => crate::bitslice::kernel::gemm_i16_lanes_tiled(a, b, m, k, n, &cfg),
        None => gemm_i16_lanes_naive(a, b, m, k, n),
    }
}

/// [`gemm_i16_lanes`] over four-nibble planes the caller packed ahead of
/// time (see [`crate::bitslice::packed::WidePlanes`]); the INT16 analogue of
/// [`crate::bitslice::gemm::gemm_lanes_prepacked`]. Always runs the plane
/// kernel; bit-exact with [`gemm_i16_lanes_naive`] by the dispatch contract.
pub fn gemm_i16_lanes_prepacked(
    pa: &crate::bitslice::packed::WidePlanes,
    pb: &crate::bitslice::packed::WidePlanes,
) -> Result<WideLanes> {
    let cfg = crate::bitslice::kernel::TileConfig::auto_for(pa.rows, pa.cols, pb.cols);
    crate::bitslice::kernel::gemm_i16_lanes_packed(pa, pb, &cfg)
}

/// Naive oracle for [`gemm_i16_lanes`]: four-nibble slicing of every operand
/// element inside the loop nest, as the scheme description reads.
pub fn gemm_i16_lanes_naive(
    a: &[i16],
    b: &[i16],
    m: usize,
    k: usize,
    n: usize,
) -> Result<WideLanes> {
    check_dims_i16(a, b, m, k, n)?;
    let mut lanes: [Vec<i64>; 7] = std::array::from_fn(|_| vec![0i64; m * n]);
    for i in 0..m {
        for kk in 0..k {
            let na = slice_i16(a[i * k + kk]);
            for j in 0..n {
                let nb = slice_i16(b[kk * n + j]);
                let idx = i * n + j;
                for (p, &ap) in na.0.iter().enumerate() {
                    if ap == 0 {
                        continue;
                    }
                    for (q, &bq) in nb.0.iter().enumerate() {
                        lanes[p + q][idx] += (ap as i64) * (bq as i64);
                    }
                }
            }
        }
    }
    Ok(WideLanes { lanes })
}

/// Hardware cost of the scheme for `bits`-wide operands: slices per
/// operand, nibble products per MAC, and radix lanes (BPCAs) per DPU.
pub fn scheme_cost(bits: u32) -> (u32, u32, u32) {
    let slices = bits / 4;
    (slices, slices * slices, 2 * slices - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, SplitMix64};

    #[test]
    fn slice_combine_roundtrip_int16() {
        for x in [-32768i16, -4097, -1, 0, 1, 255, 4096, 32767] {
            assert_eq!(combine_i16(slice_i16(x)), x, "{x}");
        }
        // Randomized sweep.
        forall(3, 4000, |rng: &mut SplitMix64| rng.next_u64() as i16, |&x| {
            combine_i16(slice_i16(x)) == x
        });
    }

    #[test]
    fn nibble_ranges() {
        for x in [-32768i16, -1, 0, 32767] {
            let n = slice_i16(x);
            assert!((0..16).contains(&n.0[0]));
            assert!((0..16).contains(&n.0[1]));
            assert!((0..16).contains(&n.0[2]));
            assert!((-8..8).contains(&n.0[3]));
        }
    }

    #[test]
    fn seven_lane_gemm_matches_direct() {
        forall(
            7,
            40,
            |rng: &mut SplitMix64| {
                let (m, k, n) = (rng.range_usize(1, 6), rng.range_usize(1, 8), rng.range_usize(1, 6));
                let a: Vec<i16> = (0..m * k).map(|_| rng.next_u64() as i16).collect();
                let b: Vec<i16> = (0..k * n).map(|_| rng.next_u64() as i16).collect();
                (a, b, m, k, n)
            },
            |(a, b, m, k, n)| {
                let direct = gemm_i16_direct(a, b, *m, *k, *n).unwrap();
                let lanes = gemm_i16_lanes(a, b, *m, *k, *n).unwrap().weight_and_add();
                direct == lanes
            },
        );
    }

    #[test]
    fn scheme_cost_table() {
        assert_eq!(scheme_cost(8), (2, 4, 3)); // the paper's INT8 design
        assert_eq!(scheme_cost(16), (4, 16, 7)); // this extension
        assert_eq!(scheme_cost(4), (1, 1, 1)); // plain INT4 core
    }

    #[test]
    fn shape_errors() {
        assert!(gemm_i16_direct(&[1, 2], &[3, 4], 1, 2, 1).is_ok());
        assert!(gemm_i16_direct(&[1], &[1, 2], 1, 2, 1).is_err());
        assert!(gemm_i16_lanes(&[1], &[1], 2, 1, 1).is_err());
        assert!(gemm_i16_lanes_naive(&[1], &[1], 2, 1, 1).is_err());
    }

    #[test]
    fn dispatcher_crosses_threshold_bit_exact() {
        // 32×32×32 = 32768 MACs hits the packed path exactly at threshold.
        let (m, k, n) = (32usize, 32usize, 32usize);
        let mut rng = SplitMix64::new(77);
        let a: Vec<i16> = (0..m * k).map(|_| rng.next_u64() as i16).collect();
        let b: Vec<i16> = (0..k * n).map(|_| rng.next_u64() as i16).collect();
        assert!(crate::bitslice::kernel::dispatch_config(m, k, n).is_some());
        let fast = gemm_i16_lanes(&a, &b, m, k, n).unwrap();
        let slow = gemm_i16_lanes_naive(&a, &b, m, k, n).unwrap();
        assert_eq!(fast.lanes, slow.lanes);
        assert_eq!(fast.weight_and_add(), gemm_i16_direct(&a, &b, m, k, n).unwrap());
    }
}
