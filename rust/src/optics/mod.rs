//! Optical link-budget analysis and the scalability study (paper Table I).
//!
//! The achievable parallelism of an incoherent photonic GEMM core — vector
//! size **N** (elements per dot product) and **M** (dot products per core) —
//! is bounded by the optical power budget: the laser must deliver enough
//! power *per wavelength at the photodetector* to resolve 2⁴ analog levels
//! after all splitting/propagation/device losses. This module implements the
//! parametric budget of the paper's modelling references ([1], [2], [12]),
//! calibrated against the paper's own published Table I (see DESIGN.md §5.1
//! for the over-determination argument that fixes each architecture's loss
//! slope and receiver law).

pub mod link_budget;
pub mod scalability;

pub use link_budget::{ArchClass, LinkBudget};
pub use scalability::{paper_table1, solve_table1, Table1, Table1Row};
