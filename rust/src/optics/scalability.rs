//! Scalability study — regenerates paper **Table I**.
//!
//! For each architecture and data rate the study reports the achievable
//! (N, M): baselines solve the largest square N = M at 10 dBm lasers; the
//! MWA rows fix M = 16 and solve N at 1, 5 and 10 dBm input optical power.

use crate::optics::link_budget::{ArchClass, LinkBudget};
use crate::units::DataRate;

/// One row of Table I: (N, M) per data rate.
#[derive(Debug, Clone, PartialEq)]
pub struct Table1Row {
    /// Row label as printed in the paper.
    pub label: String,
    /// Architecture class the row describes.
    pub arch: ArchClass,
    /// Laser power used for this row, dBm.
    pub laser_dbm: f64,
    /// (N, M) per data rate, indexed like [`DataRate::ALL`].
    pub nm: [(usize, usize); 3],
}

impl Table1Row {
    /// Achievable N×M product at `dr` (the paper's parallelism figure).
    pub fn parallelism(&self, dr: DataRate) -> usize {
        let (n, m) = self.cell(dr);
        n * m
    }

    /// (N, M) cell at data rate `dr`.
    pub fn cell(&self, dr: DataRate) -> (usize, usize) {
        match dr {
            DataRate::Gs1 => self.nm[0],
            DataRate::Gs5 => self.nm[1],
            DataRate::Gs10 => self.nm[2],
        }
    }
}

/// The full Table I (5 rows × 3 data-rate columns).
#[derive(Debug, Clone, PartialEq)]
pub struct Table1 {
    /// Rows in paper order: HOLYLIGHT, DEAPCNN, MWA@1dBm, MWA@5dBm, MWA@10dBm.
    pub rows: Vec<Table1Row>,
}

impl Table1 {
    /// Look up a row by label prefix (e.g. "MWA (5dBm)").
    pub fn row(&self, label: &str) -> Option<&Table1Row> {
        self.rows.iter().find(|r| r.label == label)
    }
}

/// Laser power assumed for the baseline (square-solve) rows, dBm.
pub const BASELINE_LASER_DBM: f64 = 10.0;

/// Solve the scalability study from the link-budget models.
pub fn solve_table1() -> Table1 {
    let mut rows = Vec::with_capacity(5);

    for (label, lb) in [
        ("HOLYLIGHT [3]", LinkBudget::holylight()),
        ("DEAPCNN [9]", LinkBudget::deapcnn()),
    ] {
        let mut nm = [(0, 0); 3];
        for (i, dr) in DataRate::ALL.iter().enumerate() {
            let n = lb.max_square(*dr, BASELINE_LASER_DBM);
            nm[i] = (n, n);
        }
        rows.push(Table1Row {
            label: label.to_string(),
            arch: lb.arch,
            laser_dbm: BASELINE_LASER_DBM,
            nm,
        });
    }

    let lb = LinkBudget::spoga();
    let m = lb.m_cap.expect("SPOGA fixes M");
    for dbm in [1.0, 5.0, 10.0] {
        let mut nm = [(0, 0); 3];
        for (i, dr) in DataRate::ALL.iter().enumerate() {
            nm[i] = (lb.max_n_given_m(m, *dr, dbm), m);
        }
        rows.push(Table1Row {
            label: format!("MWA ({}dBm)", dbm as i64),
            arch: ArchClass::Mwa,
            laser_dbm: dbm,
            nm,
        });
    }

    Table1 { rows }
}

/// The paper's published Table I values (ground truth for validation).
pub fn paper_table1() -> Table1 {
    let row = |label: &str, arch, dbm, nm: [(usize, usize); 3]| Table1Row {
        label: label.to_string(),
        arch,
        laser_dbm: dbm,
        nm,
    };
    Table1 {
        rows: vec![
            row("HOLYLIGHT [3]", ArchClass::Maw, 10.0, [(43, 43), (21, 21), (15, 15)]),
            row("DEAPCNN [9]", ArchClass::Amw, 10.0, [(36, 36), (17, 17), (12, 12)]),
            row("MWA (1dBm)", ArchClass::Mwa, 1.0, [(94, 16), (32, 16), (5, 16)]),
            row("MWA (5dBm)", ArchClass::Mwa, 5.0, [(163, 16), (101, 16), (74, 16)]),
            row("MWA (10dBm)", ArchClass::Mwa, 10.0, [(249, 16), (187, 16), (160, 16)]),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline validation: the solved table reproduces the paper's
    /// Table I **cell for cell**.
    #[test]
    fn solved_table_matches_paper_exactly() {
        let solved = solve_table1();
        let paper = paper_table1();
        assert_eq!(solved.rows.len(), paper.rows.len());
        for (s, p) in solved.rows.iter().zip(paper.rows.iter()) {
            assert_eq!(s.label, p.label);
            assert_eq!(s.nm, p.nm, "row {}", s.label);
        }
    }

    #[test]
    fn spoga_has_highest_parallelism_everywhere() {
        let t = solve_table1();
        let spoga10 = t.row("MWA (10dBm)").unwrap();
        for dr in DataRate::ALL {
            for label in ["HOLYLIGHT [3]", "DEAPCNN [9]"] {
                let base = t.row(label).unwrap();
                assert!(
                    spoga10.parallelism(dr) > base.parallelism(dr),
                    "{label} at {dr}: {} vs {}",
                    base.parallelism(dr),
                    spoga10.parallelism(dr)
                );
            }
        }
    }

    #[test]
    fn parallelism_shrinks_with_rate() {
        for row in solve_table1().rows {
            assert!(row.parallelism(DataRate::Gs1) >= row.parallelism(DataRate::Gs5));
            assert!(row.parallelism(DataRate::Gs5) >= row.parallelism(DataRate::Gs10));
        }
    }

    #[test]
    fn mwa_n_grows_with_laser_power() {
        let t = solve_table1();
        for dr in DataRate::ALL {
            let n1 = t.row("MWA (1dBm)").unwrap().cell(dr).0;
            let n5 = t.row("MWA (5dBm)").unwrap().cell(dr).0;
            let n10 = t.row("MWA (10dBm)").unwrap().cell(dr).0;
            assert!(n1 < n5 && n5 < n10);
        }
    }

    #[test]
    fn row_lookup_by_label() {
        let t = solve_table1();
        assert!(t.row("HOLYLIGHT [3]").is_some());
        assert!(t.row("nope").is_none());
    }
}
