//! Per-architecture optical link budget.
//!
//! Feasibility condition for an (N, M) configuration at data rate `BR` and
//! per-wavelength laser power `P` (all in dB/dBm):
//!
//! ```text
//! P  ≥  Θ(BR)  +  a·N  +  split(M)
//! Θ(BR) = sensitivity(BR) + DR_margin(4-bit) + L_fixed + δ_calib(BR)
//! ```
//!
//! * `a` — per-element optical loss slope (through-loss of the MRRs each
//!   added vector element inserts into the path + waveguide propagation).
//! * `split(M)` — fan-out loss `10·log10(M) + excess·log2(M)` for designs
//!   that split each wavelength across M waveguides (MAW/AMW). SPOGA's MWA
//!   organisation fixes M = 16 DPUs architecturally and feeds them from the
//!   per-DPU carrier group, so no M-dependent split appears in its budget.
//! * `sensitivity(BR)` — receiver law: TIA receivers degrade as
//!   `10·log10(BR)`; SPOGA's time-integrating BPCA as `5·log10(BR)`
//!   ([`crate::devices::photodetector`]).
//! * `DR_margin` — dynamic-range margin to resolve 2⁴−1 analog steps:
//!   `10·log10(15) ≈ 11.76 dB`.
//! * `δ_calib` — small per-rate residual (≤0.25 dB) absorbing the difference
//!   between the published converter/receiver design points and the ideal
//!   noise-bandwidth law; pinned by the paper's Table I (DESIGN.md §5.1).

use crate::devices::photodetector::BalancedPhotodetector;
use crate::devices::splitter::SplitterTree;
use crate::units::{ratio_to_db, DataRate};
use crate::{Error, Result};

/// The three GEMM-core organisations compared in Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArchClass {
    /// Modulation–Aggregation–Weighting (HOLYLIGHT [3]).
    Maw,
    /// Aggregation–Modulation–Weighting (DEAPCNN [9]).
    Amw,
    /// Modulation–Weighting–Aggregation (SPOGA's organisation).
    Mwa,
}

impl ArchClass {
    /// Human-readable name used in reports.
    pub fn name(self) -> &'static str {
        match self {
            ArchClass::Maw => "HOLYLIGHT (MAW)",
            ArchClass::Amw => "DEAPCNN (AMW)",
            ArchClass::Mwa => "SPOGA (MWA)",
        }
    }
}

/// 4-bit analog dynamic-range margin, dB: `10·log10(2⁴ − 1)`.
pub fn dynamic_range_margin_db(bits: u32) -> f64 {
    ratio_to_db((1u64 << bits) as f64 - 1.0)
}

/// Calibrated optical link budget for one architecture class.
#[derive(Debug, Clone)]
pub struct LinkBudget {
    /// Architecture this budget describes.
    pub arch: ArchClass,
    /// Per-element loss slope `a`, dB per vector element.
    pub slope_db_per_element: f64,
    /// Fixed insertion losses (coupler, modulator, mux), dB.
    pub fixed_loss_db: f64,
    /// Fan-out splitter model; `None` for MWA (no M-dependent split).
    pub splitter: Option<SplitterTree>,
    /// Receiver (sets the sensitivity-vs-rate law).
    pub receiver: BalancedPhotodetector,
    /// Analog operand width (4-bit in the paper).
    pub analog_bits: u32,
    /// Per-rate calibration residuals `δ(BR)`, dB, indexed like
    /// [`DataRate::ALL`].
    pub calib_db: [f64; 3],
    /// Architectural cap on M (e.g. SPOGA fixes M = 16 DPUs); `None` = no cap.
    pub m_cap: Option<usize>,
    /// Architectural cap on N (DPU aggregation-lane length limit).
    pub n_cap: Option<usize>,
}

impl LinkBudget {
    /// HOLYLIGHT (MAW) budget, calibrated per DESIGN.md §5.1.
    ///
    /// `a = 0.177 dB` reproduces the paper's 43/21/15 square scaling; the
    /// fixed loss (1.15 dB ≈ grating coupler 1.0 + mux 0.15) closes the
    /// budget exactly at the 1 GS/s design point with 10 dBm lasers.
    pub fn holylight() -> Self {
        LinkBudget {
            arch: ArchClass::Maw,
            slope_db_per_element: 0.177,
            fixed_loss_db: 1.15,
            splitter: Some(SplitterTree::default()),
            receiver: BalancedPhotodetector::tia(),
            analog_bits: 4,
            calib_db: [0.0, 0.0, -0.25],
            m_cap: None,
            n_cap: None,
        }
    }

    /// DEAPCNN (AMW) budget.
    ///
    /// `a = 0.197 dB` (AMW's aggregation-first order puts more resonant
    /// structures in each element's path); fixed loss 2.45 dB (extra mux
    /// stage before modulation).
    pub fn deapcnn() -> Self {
        LinkBudget {
            arch: ArchClass::Amw,
            slope_db_per_element: 0.197,
            fixed_loss_db: 2.45,
            splitter: Some(SplitterTree::default()),
            receiver: BalancedPhotodetector::tia(),
            analog_bits: 4,
            calib_db: [0.0, 0.0, -0.25],
            m_cap: None,
            n_cap: None,
        }
    }

    /// SPOGA (MWA) budget.
    ///
    /// `a = 0.058 dB` per OAME (each added OAME inserts only its through-port
    /// into the shared aggregation lane — no per-element drop), no
    /// M-dependent split (M = 16 DPUs fixed architecturally, each DPU fed by
    /// its own 4-wavelength carrier group), BPCA integrating receiver
    /// (`5·log10(BR)` law), fixed loss 11.76 dB (coupler + OAME modulator and
    /// weight MRR ILs + lane mux + homodyne superposition crosstalk penalty —
    /// see DESIGN.md §5.1 decomposition).
    pub fn spoga() -> Self {
        LinkBudget {
            arch: ArchClass::Mwa,
            slope_db_per_element: 0.058,
            fixed_loss_db: 11.76,
            splitter: None,
            receiver: BalancedPhotodetector::time_integrating(),
            analog_bits: 4,
            calib_db: [0.0, 0.105, 0.16],
            m_cap: Some(16),
            n_cap: Some(249),
        }
    }

    /// The same budget with a different analog operand width.
    ///
    /// This is the paper's §I premise: raising the analog precision to
    /// 8-bit demands `10·log10(2⁸−1) ≈ 24 dB` of dynamic-range margin —
    /// 12.3 dB more than 4-bit — and the achievable parallelism collapses
    /// (to ~1 multiplication per core in the paper's account). SPOGA instead
    /// keeps 4-bit analog operands and composes INT8 via bit slicing.
    pub fn with_analog_bits(mut self, bits: u32) -> Self {
        self.analog_bits = bits;
        self
    }

    /// Budget for a named architecture class.
    pub fn for_arch(arch: ArchClass) -> Self {
        match arch {
            ArchClass::Maw => Self::holylight(),
            ArchClass::Amw => Self::deapcnn(),
            ArchClass::Mwa => Self::spoga(),
        }
    }

    fn calib(&self, dr: DataRate) -> f64 {
        match dr {
            DataRate::Gs1 => self.calib_db[0],
            DataRate::Gs5 => self.calib_db[1],
            DataRate::Gs10 => self.calib_db[2],
        }
    }

    /// Receiver threshold Θ(BR), dBm: minimum per-wavelength power at the
    /// laser for N = 0, M = 1.
    pub fn threshold_dbm(&self, dr: DataRate) -> f64 {
        self.receiver.sensitivity_dbm(dr)
            + dynamic_range_margin_db(self.analog_bits)
            + self.fixed_loss_db
            + self.calib(dr)
    }

    /// Total link loss for an (n, m) configuration, dB (excluding Θ terms).
    pub fn config_loss_db(&self, n: usize, m: usize) -> f64 {
        let split = self.splitter.as_ref().map_or(0.0, |s| s.loss_db(m));
        self.slope_db_per_element * n as f64 + split
    }

    /// Does the budget close for (n, m) at `laser_dbm`, data rate `dr`?
    pub fn feasible(&self, n: usize, m: usize, dr: DataRate, laser_dbm: f64) -> bool {
        if n == 0 || m == 0 {
            return true;
        }
        if self.m_cap.is_some_and(|cap| m > cap) || self.n_cap.is_some_and(|cap| n > cap) {
            return false;
        }
        laser_dbm >= self.threshold_dbm(dr) + self.config_loss_db(n, m)
    }

    /// Largest feasible N for a fixed M (0 if even N = 1 does not close).
    pub fn max_n_given_m(&self, m: usize, dr: DataRate, laser_dbm: f64) -> usize {
        // Budget is monotonically decreasing in N: binary search the boundary.
        let mut lo = 0usize; // feasible
        let mut hi = self.n_cap.unwrap_or(4096) + 1; // infeasible sentinel
        if self.feasible(hi - 1, m, dr, laser_dbm) {
            return hi - 1;
        }
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if self.feasible(mid, m, dr, laser_dbm) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Largest feasible square configuration N = M.
    pub fn max_square(&self, dr: DataRate, laser_dbm: f64) -> usize {
        let cap = self.n_cap.unwrap_or(4096).min(self.m_cap.unwrap_or(4096));
        let mut best = 0;
        let mut lo = 0usize;
        let mut hi = cap + 1;
        if self.feasible(cap, cap, dr, laser_dbm) {
            return cap;
        }
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if self.feasible(mid, mid, dr, laser_dbm) {
                lo = mid;
                best = mid;
            } else {
                hi = mid;
            }
        }
        best
    }

    /// The minimum laser power (dBm) that closes the budget for (n, m).
    pub fn required_laser_dbm(&self, n: usize, m: usize, dr: DataRate) -> Result<f64> {
        if n == 0 || m == 0 {
            return Err(Error::Config(format!("degenerate configuration {n}x{m}")));
        }
        if self.m_cap.is_some_and(|cap| m > cap) || self.n_cap.is_some_and(|cap| n > cap) {
            return Err(Error::Infeasible(format!(
                "{}: ({n}, {m}) exceeds architectural caps {:?}/{:?}",
                self.arch.name(),
                self.n_cap,
                self.m_cap
            )));
        }
        Ok(self.threshold_dbm(dr) + self.config_loss_db(n, m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn margin_for_4_bits_is_11_76db() {
        assert!((dynamic_range_margin_db(4) - 11.7609).abs() < 1e-3);
        assert!((dynamic_range_margin_db(8) - 24.065).abs() < 1e-2);
    }

    #[test]
    fn feasibility_monotone_in_n() {
        let lb = LinkBudget::holylight();
        let n_max = lb.max_n_given_m(43, DataRate::Gs1, 10.0);
        assert!(lb.feasible(n_max, 43, DataRate::Gs1, 10.0));
        assert!(!lb.feasible(n_max + 1, 43, DataRate::Gs1, 10.0));
        for n in 1..=n_max {
            assert!(lb.feasible(n, 43, DataRate::Gs1, 10.0), "n={n}");
        }
    }

    #[test]
    fn feasibility_monotone_in_laser_power() {
        let lb = LinkBudget::deapcnn();
        for dbm in [-5.0, 0.0, 5.0, 10.0, 15.0] {
            let n = lb.max_square(DataRate::Gs5, dbm);
            let n_hi = lb.max_square(DataRate::Gs5, dbm + 1.0);
            assert!(n_hi >= n, "power {dbm}: {n_hi} < {n}");
        }
    }

    #[test]
    fn higher_rate_never_increases_parallelism() {
        for lb in [LinkBudget::holylight(), LinkBudget::deapcnn(), LinkBudget::spoga()] {
            let n1 = lb.max_n_given_m(16, DataRate::Gs1, 10.0);
            let n5 = lb.max_n_given_m(16, DataRate::Gs5, 10.0);
            let n10 = lb.max_n_given_m(16, DataRate::Gs10, 10.0);
            assert!(n1 >= n5 && n5 >= n10, "{}: {n1},{n5},{n10}", lb.arch.name());
        }
    }

    #[test]
    fn spoga_caps_enforced() {
        let lb = LinkBudget::spoga();
        assert!(!lb.feasible(250, 16, DataRate::Gs1, 30.0));
        assert!(!lb.feasible(10, 17, DataRate::Gs1, 30.0));
        assert_eq!(lb.max_n_given_m(16, DataRate::Gs1, 30.0), 249);
    }

    #[test]
    fn required_laser_power_matches_feasibility_boundary() {
        let lb = LinkBudget::holylight();
        let p = lb.required_laser_dbm(43, 43, DataRate::Gs1).unwrap();
        assert!(lb.feasible(43, 43, DataRate::Gs1, p));
        assert!(!lb.feasible(43, 43, DataRate::Gs1, p - 0.01));
    }

    #[test]
    fn required_laser_power_rejects_capped_configs() {
        let lb = LinkBudget::spoga();
        assert!(lb.required_laser_dbm(250, 16, DataRate::Gs1).is_err());
        assert!(lb.required_laser_dbm(0, 16, DataRate::Gs1).is_err());
    }

    #[test]
    fn calibration_residuals_are_small() {
        // The δ values must stay small — they absorb design-point deviation
        // from the ideal noise law, not act as free fit parameters.
        for lb in [LinkBudget::holylight(), LinkBudget::deapcnn(), LinkBudget::spoga()] {
            for d in lb.calib_db {
                assert!(d.abs() <= 0.25, "{}: δ={d}", lb.arch.name());
            }
        }
    }

    #[test]
    fn mwa_budget_is_linear_in_n() {
        let lb = LinkBudget::spoga();
        // Required power grows by exactly a·ΔN (no log terms).
        let p1 = lb.required_laser_dbm(50, 16, DataRate::Gs1).unwrap();
        let p2 = lb.required_laser_dbm(150, 16, DataRate::Gs1).unwrap();
        assert!((p2 - p1 - 0.058 * 100.0).abs() < 1e-9);
    }

    #[test]
    fn paper_premise_8bit_analog_collapses_parallelism() {
        // §I: at 8-bit analog precision the dynamic-range margin eats the
        // optical budget and per-core parallelism collapses toward 1.
        for lb4 in [LinkBudget::holylight(), LinkBudget::deapcnn()] {
            let n4 = lb4.max_square(DataRate::Gs1, 10.0);
            let lb8 = lb4.clone().with_analog_bits(8);
            let n8 = lb8.max_square(DataRate::Gs1, 10.0);
            assert!(n8 < n4 / 3, "{}: {n4} -> {n8}", lb8.arch.name());
            // At 10 GS/s the 8-bit budget barely closes at all.
            let n8_fast = lb8.max_square(DataRate::Gs10, 10.0);
            assert!(n8_fast <= 2, "{}: N={n8_fast} at 8-bit/10GS", lb8.arch.name());
        }
    }

    #[test]
    fn maw_budget_has_log_m_split_term() {
        let lb = LinkBudget::holylight();
        let p16 = lb.required_laser_dbm(10, 16, DataRate::Gs1).unwrap();
        let p32 = lb.required_laser_dbm(10, 32, DataRate::Gs1).unwrap();
        // Doubling M costs ≈ 3.01 dB fundamental + 0.18 dB excess.
        assert!((p32 - p16 - 3.1903).abs() < 0.02);
    }
}
