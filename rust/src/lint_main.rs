//! `spoga-lint`: run the crate's static invariant rules over source trees.
//!
//! Usage: `spoga-lint [ROOT…]` — each ROOT is a directory walked
//! recursively for `*.rs` files (default: this crate's own `src/`, the
//! tree tier-1 guards). Exit status: 0 clean, 1 when violations (or
//! unexplained `lint:allow`s) were found, 2 on I/O errors.
//!
//! The same rules run inside `cargo test` via
//! `rust/tests/static_invariants.rs`; this binary exists for CI jobs and
//! pre-commit hooks that want the report without building the test suite.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let roots: Vec<String> = if args.is_empty() {
        vec![concat!(env!("CARGO_MANIFEST_DIR"), "/src").to_string()]
    } else {
        args
    };
    let mut clean = true;
    for root in &roots {
        match spoga::analysis::lint_dir(Path::new(root)) {
            Ok(report) => {
                print!("{}", report.render());
                if !report.is_clean() {
                    clean = false;
                }
            }
            Err(e) => {
                eprintln!("spoga-lint: {root}: {e}");
                return ExitCode::from(2);
            }
        }
    }
    if clean {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
