//! Crate-wide error type.

/// Unified error for the SPOGA library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Optical link budget cannot be closed for the requested configuration.
    #[error("link budget infeasible: {0}")]
    Infeasible(String),

    /// A configuration value is out of its valid domain.
    #[error("invalid configuration: {0}")]
    Config(String),

    /// A GEMM/tensor shape is inconsistent.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Artifact store problems (missing manifest, unknown artifact, ...).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Errors bubbling out of the PJRT runtime (`xla` crate).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator request-path failures (queue closed, worker died, ...).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
