//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the crate builds
//! with zero external dependencies so the offline toolchain needs no
//! registry access.

/// Unified error for the SPOGA library.
#[derive(Debug)]
pub enum Error {
    /// Optical link budget cannot be closed for the requested configuration.
    Infeasible(String),

    /// A configuration value is out of its valid domain.
    Config(String),

    /// A GEMM/tensor shape is inconsistent.
    Shape(String),

    /// Artifact store problems (missing manifest, unknown artifact, ...).
    Artifact(String),

    /// Errors bubbling out of the execution runtime.
    Runtime(String),

    /// Coordinator request-path failures (bad request, execute failed, ...).
    Coordinator(String),

    /// A serving shard is down: its worker pool died, the coordinator
    /// stopped, or it is shutting down. Kept distinct from [`Error::Coordinator`]
    /// because the fleet router uses this — and only this — as its failover
    /// signal; request-level errors must never retire a shard.
    ShardDown(String),

    /// Admission control shed this request: the shard's bounded ingress
    /// queue is full (or a best-effort watermark tripped). Busy, not dead —
    /// the shard is alive and draining, so this is *never* a failover
    /// signal: routers must not retire the shard or resubmit retained
    /// payloads in a storm (at most one bounded retry on an idle survivor).
    Overloaded(String),

    /// The request's deadline expired before dispatch: the leader failed it
    /// typed instead of wasting a worker execute on a reply nobody wants.
    DeadlineExceeded(String),

    /// A cross-host remote-shard call failed. The kind decides failover:
    /// [`RemoteErrorKind::retires_shard`] is `true` only when the peer is
    /// truly unreachable (connection refused, peer gone) — a corrupt frame,
    /// a version skew, or one slow reply stays request-level so a healthy
    /// shard is never retired by a single bad exchange.
    Remote {
        /// Failure taxonomy (drives the `ShardDown` mapping in the router).
        kind: RemoteErrorKind,
        /// Human-readable context (peer address, what was in flight).
        detail: String,
    },

    /// Underlying I/O failure.
    Io(std::io::Error),
}

/// Failure taxonomy for [`Error::Remote`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RemoteErrorKind {
    /// A connect/read/write deadline (`NetConfig`) expired.
    Timeout,
    /// The peer actively refused the connection.
    ConnRefused,
    /// A frame failed its magic/length/FNV-checksum validation.
    FrameCorrupt,
    /// The peer speaks a different wire-protocol version.
    VersionMismatch,
    /// The connection died mid-stream (EOF, reset, killed process).
    PeerGone,
}

impl RemoteErrorKind {
    /// Whether this failure means the shard is truly unreachable and the
    /// fleet router should treat it like [`Error::ShardDown`] (retire the
    /// shard and fail requests over to a survivor). `Timeout` on a single
    /// reply, a corrupt frame, or a version skew are request-level: the
    /// peer process is demonstrably alive, so the shard stays in rotation
    /// (heartbeat missed-pong accounting, not one slow exchange, is what
    /// retires an unresponsive shard).
    pub fn retires_shard(&self) -> bool {
        matches!(self, RemoteErrorKind::ConnRefused | RemoteErrorKind::PeerGone)
    }
}

impl std::fmt::Display for RemoteErrorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            RemoteErrorKind::Timeout => "timeout",
            RemoteErrorKind::ConnRefused => "connection refused",
            RemoteErrorKind::FrameCorrupt => "frame corrupt",
            RemoteErrorKind::VersionMismatch => "version mismatch",
            RemoteErrorKind::PeerGone => "peer gone",
        };
        f.write_str(s)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Infeasible(msg) => write!(f, "link budget infeasible: {msg}"),
            Error::Config(msg) => write!(f, "invalid configuration: {msg}"),
            Error::Shape(msg) => write!(f, "shape mismatch: {msg}"),
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            Error::ShardDown(msg) => write!(f, "shard down: {msg}"),
            Error::Overloaded(msg) => write!(f, "overloaded: {msg}"),
            Error::DeadlineExceeded(msg) => write!(f, "deadline exceeded: {msg}"),
            Error::Remote { kind, detail } => write!(f, "remote shard error ({kind}): {detail}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_variant_prefixes() {
        assert_eq!(Error::Shape("bad".into()).to_string(), "shape mismatch: bad");
        assert_eq!(Error::Artifact("x".into()).to_string(), "artifact error: x");
        assert_eq!(Error::Coordinator("y".into()).to_string(), "coordinator error: y");
        assert_eq!(Error::ShardDown("z".into()).to_string(), "shard down: z");
        assert_eq!(Error::Overloaded("q full".into()).to_string(), "overloaded: q full");
        assert_eq!(
            Error::DeadlineExceeded("50ms".into()).to_string(),
            "deadline exceeded: 50ms"
        );
        let e = Error::Remote { kind: RemoteErrorKind::Timeout, detail: "p".into() };
        assert_eq!(e.to_string(), "remote shard error (timeout): p");
    }

    #[test]
    fn only_unreachable_kinds_retire_shards() {
        use RemoteErrorKind::*;
        assert!(ConnRefused.retires_shard());
        assert!(PeerGone.retires_shard());
        // Request-level kinds: one bad exchange must not retire a shard.
        assert!(!Timeout.retires_shard());
        assert!(!FrameCorrupt.retires_shard());
        assert!(!VersionMismatch.retires_shard());
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
        assert!(std::error::Error::source(&Error::Config("c".into())).is_none());
    }
}
