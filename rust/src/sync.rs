//! Poison-aware lock acquisition shared by the serving stack.
//!
//! A poisoned `Mutex` means some thread panicked while holding the guard.
//! The structures this crate guards with plain mutexes (pending-request
//! maps, connection slots, join-handle lists, model caches) are valid in
//! every observable state — the guarded operations are single insert /
//! remove / take calls, not multi-step invariant edits — so a panic
//! elsewhere never leaves them corrupt, and *cleanup paths must keep
//! working* after such a panic: a teardown that itself panics cascades one
//! thread's bug into a process-wide outage (the bug class PR 6's poisoned
//! slot-table fix paid for; see the `no-poison-panic` rule in
//! [`crate::analysis`]).
//!
//! Discipline, in order of preference:
//!
//! * serving entry points that can fail map poison to a **typed error** at
//!   the call site (`.lock().map_err(|_| …)?` — e.g. the remote client's
//!   connection lock surfaces `Error::Remote { kind: PeerGone }`);
//! * infallible internal paths (teardown, dispatch, expiry, telemetry)
//!   recover the guard with [`lock_recovered`] so cleanup always completes.
//!
//! Bare `.lock().unwrap()` outside `#[cfg(test)]` fails tier-1 via
//! `rust/tests/static_invariants.rs`.

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard if the mutex is poisoned.
///
/// Correct only under the module-doc contract: the guarded structure is
/// valid in every observable state, and the caller is a path that must
/// complete (cleanup, dispatch bookkeeping) rather than a fallible serving
/// entry point — those should map poison to a typed error instead.
pub(crate) fn lock_recovered<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_a_poisoned_guard_and_keeps_the_value() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_recovered(&m), 7);
        *lock_recovered(&m) += 1;
        assert_eq!(*lock_recovered(&m), 8);
    }
}
