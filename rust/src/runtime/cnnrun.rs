//! Whole-CNN serving: drive a [`CnnModel`] layer by layer through a backend.
//!
//! Each conv layer is lowered numerically with [`crate::dnn::im2col`] (one
//! GEMM per conv group) and executed through the engine's backend via
//! synthetic ad-hoc GEMM plans; fully-connected layers run as `1×k·k×c`
//! GEMMs. Between layers the int32 accumulators requantize to int8
//! deterministically, so any two backends produce bit-identical logits.
//!
//! Telemetry: backends that model the photonic datapath contribute a
//! per-layer [`ExecReport`] priced on the layer's *full grouped* GEMM shape
//! — the exact quantity [`crate::sim::engine::simulate_frame`] reports for
//! the same accelerator — plus, when noise injection is on, the frame's own
//! slice of the stacked executes' per-row noise attribution (see the
//! per-row contract in [`crate::runtime::backend`]): each frame's
//! `noise_events`/`row_noise` are exactly what its unbatched run would
//! report at the same channel seed.
//!
//! Weights are deterministic surrogates (seeded by layer index, group and
//! shape, like the MLP artifacts' surrogate weights): the repo has no baked
//! CNN weights at the Rust layer, and every cross-backend consistency
//! property only needs determinism.

use crate::dnn::im2col::{im2col_group, requantize};
use crate::dnn::layer::Layer;
use crate::dnn::models::CnnModel;
use crate::runtime::backend::{ExecReport, RowNonce};
use crate::runtime::engine::Engine;
use crate::testing::SplitMix64;
use crate::{Error, Result};

/// Telemetry for one served CNN layer.
#[derive(Debug, Clone)]
pub struct LayerReport {
    /// Layer name (trace/model naming).
    pub layer: String,
    /// Photonic projection for this layer's grouped GEMM.
    pub report: ExecReport,
}

/// Result of one whole-CNN inference through a backend.
#[derive(Debug, Clone)]
pub struct CnnRun {
    /// Raw int32 outputs of the final layer (logits).
    pub logits: Vec<i32>,
    /// Aggregate photonic telemetry (sum over layers), `None` on digital
    /// backends.
    pub report: Option<ExecReport>,
    /// Per-layer telemetry, empty on digital backends.
    pub layers: Vec<LayerReport>,
}

/// Validate that `model` forms a servable chain from an `input_len`-element
/// activation: geometry is well-formed (stride ≥ 1, kernel fits the padded
/// input, groups divide channels) and every layer's input element count
/// matches the previous layer's output.
pub fn validate_cnn_input(model: &CnnModel, input_len: usize) -> Result<()> {
    if model.layers.is_empty() {
        return Err(Error::Config(format!("{}: model has no layers", model.name)));
    }
    let mut cur = input_len;
    for layer in &model.layers {
        match layer {
            Layer::Conv { name, in_h, in_w, in_ch, out_ch, kernel, stride, pad, groups } => {
                let bad = |msg: String| Error::Shape(format!("layer {name}: {msg}"));
                if *stride == 0 || *kernel == 0 {
                    return Err(bad("kernel and stride must be >= 1".into()));
                }
                if *groups == 0 || in_ch % groups != 0 || out_ch % groups != 0 {
                    return Err(bad(format!("groups {groups} must divide {in_ch}/{out_ch}")));
                }
                if in_h + 2 * pad < *kernel || in_w + 2 * pad < *kernel {
                    return Err(bad(format!(
                        "kernel {kernel} exceeds padded input {in_h}x{in_w}+2*{pad}"
                    )));
                }
                if cur != in_h * in_w * in_ch {
                    return Err(bad(format!(
                        "expects {} activations ({in_h}x{in_w}x{in_ch}), chain carries {cur}",
                        in_h * in_w * in_ch
                    )));
                }
                let (oh, ow) = layer.out_hw();
                cur = oh * ow * out_ch;
            }
            Layer::Fc { name, in_features, out_features } => {
                if cur != *in_features {
                    return Err(Error::Shape(format!(
                        "layer {name}: expects {in_features} features, chain carries {cur}"
                    )));
                }
                cur = *out_features;
            }
        }
    }
    Ok(())
}

/// Deterministic surrogate weight matrix for layer `li`, group `g`
/// (`k×c`, row-major). Seeded by position and shape only, so every backend
/// — and every worker — agrees.
pub(crate) fn surrogate_layer_weights(li: usize, g: usize, k: usize, c: usize) -> Vec<i8> {
    let seed = 0xC44F_00D5_u64
        ^ ((li as u64) << 48)
        ^ ((g as u64) << 32)
        ^ ((k as u64) << 16)
        ^ c as u64;
    SplitMix64::new(seed).i8_vec(k * c)
}

/// Serve one CNN inference through `engine`'s backend.
///
/// `input` is the first layer's activation tensor in wire format (int8
/// values in i32 lanes; HWC layout for convs). Returns the final layer's
/// raw int32 outputs plus per-layer photonic telemetry (if the backend
/// reports any). This is the batch-of-one case of [`run_cnn_batch`], so
/// single-frame and batched serving share one code path by construction.
pub fn run_cnn(engine: &mut Engine, model: &CnnModel, input: &[i32]) -> Result<CnnRun> {
    let mut runs = run_cnn_batch(engine, model, &[input])?;
    Ok(runs.pop().expect("batch of one yields one run"))
}

/// Serve `inputs.len()` same-model CNN inferences in one pass, stacking the
/// member frames along the t-dimension: each conv layer's im2col blocks
/// concatenate into one `(B·t)×k` matrix and each FC layer's rows into a
/// `B×k` matrix, so every layer group costs one plan lookup and one kernel
/// launch for the whole batch instead of one per frame.
///
/// Row independence of GEMM makes stacking exact: every member's logits are
/// bit-identical to its own [`run_cnn`] on an exact backend. Per-frame
/// [`LayerReport`]s price each frame's *own* grouped layer shape (the same
/// quantity [`crate::sim::engine::simulate_frame`] reports), so batching
/// changes wall-clock amortization, never telemetry.
///
/// Noise injection attributes exactly too: frame `f` owns rows
/// `[f·t, (f+1)·t)` of each conv group's stacked GEMM and row `f` of an FC
/// stack, so the backend's per-row `row_noise` (order-independent by the
/// contract in [`crate::runtime::backend`]) slices back into per-frame
/// `noise_events` and per-output-row `row_noise` on every [`LayerReport`].
/// A frame's noise — and therefore its logits — is bit-identical whether it
/// serves stacked or unbatched at the same channel seed, which is why the
/// coordinator keeps CNN stacking enabled under noise.
pub fn run_cnn_batch(
    engine: &mut Engine,
    model: &CnnModel,
    inputs: &[&[i32]],
) -> Result<Vec<CnnRun>> {
    run_cnn_batch_keyed(engine, model, inputs, &[])
}

/// [`run_cnn_batch`] with one noise nonce per member frame (the
/// time-indexed counter mode): frame `f`'s rows of every stacked layer GEMM
/// are keyed by `frame_nonces[f]`, so byte-identical frames served under
/// different nonces observe decorrelated noise while each
/// `(seed, content, nonce)` run stays deterministic. An empty slice (or
/// all-zero nonces) is bit-identical to [`run_cnn_batch`] — the
/// content-keyed default.
pub fn run_cnn_batch_keyed(
    engine: &mut Engine,
    model: &CnnModel,
    inputs: &[&[i32]],
    frame_nonces: &[u64],
) -> Result<Vec<CnnRun>> {
    if inputs.is_empty() {
        return Ok(Vec::new());
    }
    debug_assert!(frame_nonces.is_empty() || frame_nonces.len() == inputs.len());
    let nonce_of = |f: usize| frame_nonces.get(f).copied().unwrap_or(0);
    let keyed = frame_nonces.iter().any(|&n| n != 0);
    for input in inputs {
        validate_cnn_input(model, input.len())?;
    }
    let b = inputs.len();
    let mut acts: Vec<Vec<i8>> =
        inputs.iter().map(|inp| inp.iter().map(|&v| v as i8).collect()).collect();
    let mut raws: Vec<Vec<i32>> = vec![Vec::new(); b];
    let mut layer_reports: Vec<Vec<LayerReport>> = vec![Vec::new(); b];
    let mut aggs: Vec<Option<ExecReport>> = vec![None; b];

    for (li, layer) in model.layers.iter().enumerate() {
        let shape = layer.gemm();
        // Per-frame noise attribution, sliced out of the stacked executes'
        // per-row `row_noise`: frame f owns rows [f·t, (f+1)·t) of every
        // conv group's stacked GEMM and row f of the FC stack.
        // `frame_rows[f][row]` accumulates row-level events across groups;
        // it stays empty (per frame) until a report carries attribution.
        let mut frame_noise = vec![0u64; b];
        let mut frame_rows: Vec<Vec<u64>> = Vec::new();
        match layer {
            Layer::Conv { in_h, in_w, in_ch, out_ch, kernel, stride, pad, groups, .. } => {
                let (oh, ow) = layer.out_hw();
                let (t, k, c) = (oh * ow, shape.k, shape.c);
                for raw in raws.iter_mut() {
                    *raw = vec![0i32; t * out_ch];
                }
                for g in 0..*groups {
                    // Stack every frame's im2col block for this group.
                    let mut a_wire: Vec<i32> = Vec::with_capacity(b * t * k);
                    for act in &acts {
                        let a8 = im2col_group(
                            act, *in_h, *in_w, *in_ch, *kernel, *stride, *pad, *groups, g,
                        );
                        a_wire.extend(a8.iter().map(|&v| v as i32));
                    }
                    let w_wire: Vec<i32> = surrogate_layer_weights(li, g, k, c)
                        .iter()
                        .map(|&v| v as i32)
                        .collect();
                    let rn = if keyed {
                        RowNonce::PerRow(
                            (0..b * t).map(|row| nonce_of(row / t)).collect(),
                        )
                    } else {
                        RowNonce::Content
                    };
                    let (out, rep) =
                        engine.execute_gemm_shape_keyed(b * t, k, c, &a_wire, &w_wire, &rn)?;
                    if let Some(r) = &rep {
                        if !r.row_noise.is_empty() {
                            if frame_rows.is_empty() {
                                frame_rows = vec![vec![0u64; t]; b];
                            }
                            for f in 0..b {
                                for row in 0..t {
                                    let e = r.row_noise[f * t + row];
                                    frame_rows[f][row] += e;
                                    frame_noise[f] += e;
                                }
                            }
                        }
                    }
                    // Scatter each frame's t×c block into its HWC output.
                    for (f, raw) in raws.iter_mut().enumerate() {
                        for row in 0..t {
                            raw[row * out_ch + g * c..row * out_ch + g * c + c]
                                .copy_from_slice(&out[(f * t + row) * c..(f * t + row + 1) * c]);
                        }
                    }
                }
                for (act, raw) in acts.iter_mut().zip(&raws) {
                    *act = raw.iter().map(|&v| requantize(v, k)).collect();
                }
            }
            Layer::Fc { in_features, out_features, .. } => {
                // Stack every frame's activation row: B×k · k×c.
                let mut a_wire: Vec<i32> = Vec::with_capacity(b * in_features);
                for act in &acts {
                    a_wire.extend(act.iter().map(|&v| v as i32));
                }
                let w_wire: Vec<i32> =
                    surrogate_layer_weights(li, 0, *in_features, *out_features)
                        .iter()
                        .map(|&v| v as i32)
                        .collect();
                let rn = if keyed {
                    RowNonce::PerRow((0..b).map(|f| nonce_of(f)).collect())
                } else {
                    RowNonce::Content
                };
                let (out, rep) = engine.execute_gemm_shape_keyed(
                    b,
                    *in_features,
                    *out_features,
                    &a_wire,
                    &w_wire,
                    &rn,
                )?;
                if let Some(r) = &rep {
                    if !r.row_noise.is_empty() {
                        frame_rows = vec![vec![0u64; 1]; b];
                        for f in 0..b {
                            frame_rows[f][0] += r.row_noise[f];
                            frame_noise[f] += r.row_noise[f];
                        }
                    }
                }
                for f in 0..b {
                    let row = &out[f * out_features..(f + 1) * out_features];
                    acts[f] = row.iter().map(|&v| requantize(v, *in_features)).collect();
                    raws[f] = row.to_vec();
                }
            }
        }
        // Per-frame projection on the frame's full grouped shape — identical
        // to the layer's record in `simulate_frame` for the same accelerator,
        // whatever the batch size — plus the frame's own slice of the
        // stacked noise attribution.
        if let Some(r) = engine.report_for(&shape) {
            for f in 0..b {
                let mut rf = r.clone();
                rf.noise_events = frame_noise[f];
                rf.row_noise = frame_rows.get(f).cloned().unwrap_or_default();
                let merged = match aggs[f].take() {
                    Some(mut a) => {
                        a.merge(&rf);
                        a
                    }
                    None => rf.clone(),
                };
                aggs[f] = Some(merged);
                layer_reports[f].push(LayerReport { layer: layer.name().to_string(), report: rf });
            }
        }
    }

    Ok(raws
        .into_iter()
        .zip(aggs)
        .zip(layer_reports)
        .map(|((logits, report), layers)| CnnRun { logits, report, layers })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::layer::Layer;
    use crate::runtime::backend::BackendKind;
    use crate::runtime::photonic::PhotonicConfig;

    fn tiny_model() -> CnnModel {
        CnnModel {
            name: "tiny",
            layers: vec![
                Layer::conv("stem", 6, 6, 3, 4, 3, 1, 1),
                Layer::dwconv("dw", 6, 6, 4, 3, 2, 1),
                Layer::fc("head", 3 * 3 * 4, 5),
            ],
        }
    }

    fn synthetic_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("spoga-cnnrun-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "mlp_b1 m i32:1x16 i32:1x4\n").unwrap();
        dir
    }

    #[test]
    fn chain_validation_catches_mismatches() {
        let m = tiny_model();
        assert!(validate_cnn_input(&m, 6 * 6 * 3).is_ok());
        assert!(validate_cnn_input(&m, 17).is_err());
        let broken = CnnModel {
            name: "broken",
            layers: vec![Layer::conv("c", 6, 6, 3, 4, 3, 1, 1), Layer::fc("f", 999, 5)],
        };
        assert!(validate_cnn_input(&broken, 6 * 6 * 3).is_err());
        let degenerate = CnnModel {
            name: "deg",
            layers: vec![Layer::conv("c", 2, 2, 1, 1, 5, 1, 0)],
        };
        assert!(validate_cnn_input(&degenerate, 4).is_err());
        assert!(validate_cnn_input(&CnnModel { name: "e", layers: vec![] }, 0).is_err());
    }

    #[test]
    fn backends_serve_bit_identical_cnn_logits() {
        let dir = synthetic_dir("identical");
        let mut sw = Engine::new(&dir).unwrap();
        let mut ph =
            Engine::with_backend(&dir, BackendKind::Photonic(PhotonicConfig::spoga())).unwrap();
        let model = tiny_model();
        let input: Vec<i32> = (0..6 * 6 * 3).map(|v| (v * 29 % 251) - 125).collect();

        let r_sw = run_cnn(&mut sw, &model, &input).unwrap();
        let r_ph = run_cnn(&mut ph, &model, &input).unwrap();
        assert_eq!(r_sw.logits.len(), 5);
        assert_eq!(r_sw.logits, r_ph.logits);
        assert!(r_sw.report.is_none() && r_sw.layers.is_empty());

        // Photonic telemetry covers every layer and sums into the aggregate.
        assert_eq!(r_ph.layers.len(), 3);
        let agg = r_ph.report.unwrap();
        assert!(agg.sim_latency_s > 0.0 && agg.energy_j > 0.0);
        let lat_sum: f64 = r_ph.layers.iter().map(|l| l.report.sim_latency_s).sum();
        assert!((agg.sim_latency_s - lat_sum).abs() < 1e-15);
        assert_eq!(agg.lanes, model.workload().total_outputs());

        // Determinism across repeat runs.
        let again = run_cnn(&mut sw, &model, &input).unwrap();
        assert_eq!(again.logits, r_sw.logits);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batched_frames_match_unbatched_runs_bit_for_bit() {
        let dir = synthetic_dir("batch");
        let model = tiny_model();
        let frames: Vec<Vec<i32>> = (0..3)
            .map(|f| (0..6 * 6 * 3).map(|v| ((v * 31 + f * 97) % 251) - 125).collect())
            .collect();
        let refs: Vec<&[i32]> = frames.iter().map(|f| f.as_slice()).collect();

        for backend in [
            BackendKind::Software,
            BackendKind::Photonic(PhotonicConfig::spoga()),
        ] {
            let mut eng = Engine::with_backend(&dir, backend.clone()).unwrap();
            let batched = run_cnn_batch(&mut eng, &model, &refs).unwrap();
            assert_eq!(batched.len(), frames.len());
            for (f, frame) in frames.iter().enumerate() {
                let single = run_cnn(&mut eng, &model, frame).unwrap();
                assert_eq!(
                    batched[f].logits, single.logits,
                    "{}: frame {f} diverged under t-stacking",
                    backend.label()
                );
                // Per-frame telemetry is identical to the unbatched run's:
                // each frame prices its own grouped layer shapes.
                assert_eq!(batched[f].layers.len(), single.layers.len());
                for (bl, sl) in batched[f].layers.iter().zip(&single.layers) {
                    assert_eq!(bl.layer, sl.layer);
                    assert_eq!(bl.report, sl.report, "{}: layer {}", backend.label(), bl.layer);
                }
                assert_eq!(batched[f].report, single.report);
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_frame_in_stack_leaves_other_members_exact() {
        // The padding-exactness property the MLP batcher relies on, pinned
        // for CNN stacking: an all-zero frame in the stack must not perturb
        // its co-batched members (GEMM rows are independent).
        let dir = synthetic_dir("zeropad");
        let model = tiny_model();
        let mut eng = Engine::new(&dir).unwrap();
        let live: Vec<i32> = (0..6 * 6 * 3).map(|v| ((v * 29) % 251) - 125).collect();
        let zero = vec![0i32; 6 * 6 * 3];

        let alone = run_cnn(&mut eng, &model, &live).unwrap();
        let padded =
            run_cnn_batch(&mut eng, &model, &[&zero, &live, &zero]).unwrap();
        assert_eq!(padded[1].logits, alone.logits, "zero co-frames perturbed a member");
        // The zero frames themselves serve deterministically too.
        assert_eq!(padded[0].logits, padded[2].logits);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let dir = synthetic_dir("empty");
        let mut eng = Engine::new(&dir).unwrap();
        assert!(run_cnn_batch(&mut eng, &tiny_model(), &[]).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn surrogate_weights_keyed_by_layer_and_group() {
        assert_eq!(surrogate_layer_weights(0, 0, 9, 4), surrogate_layer_weights(0, 0, 9, 4));
        assert_ne!(surrogate_layer_weights(0, 0, 9, 4), surrogate_layer_weights(1, 0, 9, 4));
        assert_ne!(surrogate_layer_weights(0, 0, 9, 4), surrogate_layer_weights(0, 1, 9, 4));
    }
}
