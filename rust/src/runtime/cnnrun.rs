//! Whole-CNN serving: drive a [`CnnModel`] layer by layer through a backend.
//!
//! Each conv layer is lowered numerically with [`crate::dnn::im2col`] (one
//! GEMM per conv group) and executed through the engine's backend;
//! fully-connected layers run as `B×k·k×c` GEMMs. Between layers the int32
//! accumulators requantize to int8 deterministically, so any two backends
//! produce bit-identical logits.
//!
//! ## Compile once, stream many
//!
//! Serving is plan-driven: [`CnnPlan::compile`] lowers a model **once** into
//! per-layer-per-group [`PackedB`] weights (the surrogate weights packed at
//! compile time) and the engine caches the plan by model name, revalidated
//! by full model equality — the CNN analogue of `refresh_wire`'s never-hash
//! rule. Requests then stream through
//! [`ExecBackend::execute_prepacked_i8`]: im2col writes straight into a
//! persistent [`CnnScratch`] arena (stacked `(B·t)×k` i8 activation planes,
//! reused output/row-noise/attribution buffers), so steady-state
//! content-keyed serving does **zero per-request heap allocation and zero
//! weight re-derivation** — only result materialization (logits, per-layer
//! reports) allocates. The legacy wire-format path is retained as
//! [`run_cnn_batch_keyed_reference`], the oracle `tests/cnn_plan.rs` pins
//! the plan path against bit for bit.
//!
//! Telemetry: backends that model the photonic datapath contribute a
//! per-layer [`ExecReport`] priced on the layer's *full grouped* GEMM shape
//! — the exact quantity [`crate::sim::engine::simulate_frame`] reports for
//! the same accelerator — plus, when noise injection is on, the frame's own
//! slice of the stacked executes' per-row noise attribution (see the
//! per-row contract in [`crate::runtime::backend`]): each frame's
//! `noise_events`/`row_noise` are exactly what its unbatched run would
//! report at the same channel seed.
//!
//! Weights are deterministic surrogates (seeded by layer index, group and
//! shape, like the MLP artifacts' surrogate weights): the repo has no baked
//! CNN weights at the Rust layer, and every cross-backend consistency
//! property only needs determinism.

use crate::bitslice::PackedB;
use crate::dnn::im2col::{im2col_group, im2col_group_into, requantize};
use crate::dnn::layer::{GemmShape, Layer};
use crate::dnn::models::CnnModel;
use crate::runtime::backend::{ExecBackend, ExecReport, RowNonce};
use crate::runtime::engine::Engine;
use crate::testing::SplitMix64;
use crate::{Error, Result};

/// Telemetry for one served CNN layer.
#[derive(Debug, Clone)]
pub struct LayerReport {
    /// Layer name (trace/model naming).
    pub layer: String,
    /// Photonic projection for this layer's grouped GEMM.
    pub report: ExecReport,
}

/// Result of one whole-CNN inference through a backend.
#[derive(Debug, Clone)]
pub struct CnnRun {
    /// Raw int32 outputs of the final layer (logits).
    pub logits: Vec<i32>,
    /// Aggregate photonic telemetry (sum over layers), `None` on digital
    /// backends.
    pub report: Option<ExecReport>,
    /// Per-layer telemetry, empty on digital backends.
    pub layers: Vec<LayerReport>,
}

/// Validate that `model` forms a servable chain from an `input_len`-element
/// activation: geometry is well-formed (stride ≥ 1, kernel fits the padded
/// input, groups divide channels) and every layer's input element count
/// matches the previous layer's output.
pub fn validate_cnn_input(model: &CnnModel, input_len: usize) -> Result<()> {
    if model.layers.is_empty() {
        return Err(Error::Config(format!("{}: model has no layers", model.name)));
    }
    let mut cur = input_len;
    for layer in &model.layers {
        match layer {
            Layer::Conv { name, in_h, in_w, in_ch, out_ch, kernel, stride, pad, groups } => {
                let bad = |msg: String| Error::Shape(format!("layer {name}: {msg}"));
                if *stride == 0 || *kernel == 0 {
                    return Err(bad("kernel and stride must be >= 1".into()));
                }
                if *groups == 0 || in_ch % groups != 0 || out_ch % groups != 0 {
                    return Err(bad(format!("groups {groups} must divide {in_ch}/{out_ch}")));
                }
                if in_h + 2 * pad < *kernel || in_w + 2 * pad < *kernel {
                    return Err(bad(format!(
                        "kernel {kernel} exceeds padded input {in_h}x{in_w}+2*{pad}"
                    )));
                }
                if cur != in_h * in_w * in_ch {
                    return Err(bad(format!(
                        "expects {} activations ({in_h}x{in_w}x{in_ch}), chain carries {cur}",
                        in_h * in_w * in_ch
                    )));
                }
                let (oh, ow) = layer.out_hw();
                cur = oh * ow * out_ch;
            }
            Layer::Fc { name, in_features, out_features } => {
                if cur != *in_features {
                    return Err(Error::Shape(format!(
                        "layer {name}: expects {in_features} features, chain carries {cur}"
                    )));
                }
                cur = *out_features;
            }
        }
    }
    Ok(())
}

/// Deterministic surrogate weight matrix for layer `li`, group `g`
/// (`k×c`, row-major). Seeded by position and shape only, so every backend
/// — and every worker — agrees.
pub(crate) fn surrogate_layer_weights(li: usize, g: usize, k: usize, c: usize) -> Vec<i8> {
    let seed = 0xC44F_00D5_u64
        ^ ((li as u64) << 48)
        ^ ((g as u64) << 32)
        ^ ((k as u64) << 16)
        ^ c as u64;
    SplitMix64::new(seed).i8_vec(k * c)
}

/// One layer of a compiled [`CnnPlan`]: resolved geometry plus the
/// compile-time packed weights (one [`PackedB`] per conv group, one for an
/// FC layer). Immutable after compile — shared via `Arc` across requests.
pub(crate) enum PlannedLayer {
    /// A conv layer lowered to `groups` stacked im2col GEMMs.
    Conv {
        name: String,
        in_h: usize,
        in_w: usize,
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
        groups: usize,
        /// Output pixels per frame (`oh·ow`): the per-frame GEMM row count.
        t: usize,
        /// im2col depth per group (`(in_ch/groups)·kernel²`).
        k: usize,
        /// Output channels per group.
        c: usize,
        /// The layer's full grouped shape (what telemetry prices).
        shape: GemmShape,
        /// Per-group surrogate weights, packed once at compile time.
        weights: Vec<PackedB>,
    },
    /// A fully-connected layer: one `B×k · k×c` GEMM per batch.
    Fc {
        name: String,
        in_features: usize,
        out_features: usize,
        shape: GemmShape,
        weights: PackedB,
    },
}

impl PlannedLayer {
    fn name(&self) -> &str {
        match self {
            PlannedLayer::Conv { name, .. } => name,
            PlannedLayer::Fc { name, .. } => name,
        }
    }

    fn shape(&self) -> &GemmShape {
        match self {
            PlannedLayer::Conv { shape, .. } => shape,
            PlannedLayer::Fc { shape, .. } => shape,
        }
    }
}

/// A whole-CNN execution plan: the model lowered once into per-layer packed
/// weights. Compiled by [`CnnPlan::compile`], cached on the engine by model
/// name ([`Engine::cnn_plan`]) and revalidated by full model equality, so a
/// renamed-but-different model never serves a stale plan. Backend-agnostic:
/// the packed planes feed both the digital prepacked kernel and the
/// photonic lane pipeline ([`ExecBackend::execute_prepacked_i8`]).
pub struct CnnPlan {
    model: CnnModel,
    input_len: usize,
    layers: Vec<PlannedLayer>,
}

impl CnnPlan {
    /// Lower `model` into a servable plan: validate the layer chain, derive
    /// every layer's GEMM geometry, and pack each layer's surrogate weights
    /// (per conv group) into [`PackedB`] planes. All weight derivation and
    /// packing cost is paid here, never on the request path.
    pub fn compile(model: &CnnModel) -> Result<CnnPlan> {
        let input_len = match model.layers.first() {
            Some(Layer::Conv { in_h, in_w, in_ch, .. }) => in_h * in_w * in_ch,
            Some(Layer::Fc { in_features, .. }) => *in_features,
            None => return Err(Error::Config(format!("{}: model has no layers", model.name))),
        };
        validate_cnn_input(model, input_len)?;
        let mut layers = Vec::with_capacity(model.layers.len());
        for (li, layer) in model.layers.iter().enumerate() {
            let shape = layer.gemm();
            match layer {
                Layer::Conv { name, in_h, in_w, in_ch, out_ch, kernel, stride, pad, groups } => {
                    let (oh, ow) = layer.out_hw();
                    let (t, k, c) = (oh * ow, shape.k, shape.c);
                    let weights = (0..*groups)
                        .map(|g| PackedB::pack(&surrogate_layer_weights(li, g, k, c), k, c))
                        .collect::<Result<Vec<_>>>()?;
                    layers.push(PlannedLayer::Conv {
                        name: name.clone(),
                        in_h: *in_h,
                        in_w: *in_w,
                        in_ch: *in_ch,
                        out_ch: *out_ch,
                        kernel: *kernel,
                        stride: *stride,
                        pad: *pad,
                        groups: *groups,
                        t,
                        k,
                        c,
                        shape,
                        weights,
                    });
                }
                Layer::Fc { name, in_features, out_features } => {
                    let weights = PackedB::pack(
                        &surrogate_layer_weights(li, 0, *in_features, *out_features),
                        *in_features,
                        *out_features,
                    )?;
                    layers.push(PlannedLayer::Fc {
                        name: name.clone(),
                        in_features: *in_features,
                        out_features: *out_features,
                        shape,
                        weights,
                    });
                }
            }
        }
        Ok(CnnPlan { model: model.clone(), input_len, layers })
    }

    /// The model this plan was compiled from (cache revalidation key).
    pub fn model(&self) -> &CnnModel {
        &self.model
    }

    /// Element count of the first layer's activation tensor.
    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Total packed weight matrices held by the plan (telemetry/tests).
    pub fn packed_matrices(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                PlannedLayer::Conv { weights, .. } => weights.len(),
                PlannedLayer::Fc { .. } => 1,
            })
            .sum()
    }

    pub(crate) fn layers(&self) -> &[PlannedLayer] {
        &self.layers
    }
}

/// Persistent per-engine scratch arena for plan-driven CNN serving. Every
/// buffer is `clear()`/`resize()`d to the working size and reused across
/// requests, so after the first request at a given (model, batch) shape the
/// exact content-keyed serving path performs no heap allocation.
#[derive(Default)]
pub struct CnnScratch {
    /// Stacked `(B·t)×k` im2col activation bytes (conv layers write every
    /// frame's block here via [`im2col_group_into`]).
    a8: Vec<i8>,
    /// Flat frame-major int8 activations between layers (`B` frames of the
    /// current layer's input length).
    acts: Vec<i8>,
    /// Flat frame-major int32 accumulators of the current layer
    /// (`B·t·out_ch` for convs, `B·out_features` for FC).
    raw: Vec<i32>,
    /// Backend output buffer for one stacked GEMM.
    out: Vec<i32>,
    /// Backend per-row noise attribution for one stacked GEMM.
    row_noise: Vec<u64>,
    /// Per-frame noise event totals for the current layer.
    frame_noise: Vec<u64>,
    /// Flat per-frame per-row noise attribution for the current layer
    /// (`B · rows_per_frame`, accumulated across conv groups).
    frame_rows: Vec<u64>,
}

/// Serve one CNN inference through `engine`'s backend.
///
/// `input` is the first layer's activation tensor in wire format (int8
/// values in i32 lanes; HWC layout for convs). Returns the final layer's
/// raw int32 outputs plus per-layer photonic telemetry (if the backend
/// reports any). This is the batch-of-one case of [`run_cnn_batch`], so
/// single-frame and batched serving share one code path by construction.
pub fn run_cnn(engine: &mut Engine, model: &CnnModel, input: &[i32]) -> Result<CnnRun> {
    let mut runs = run_cnn_batch(engine, model, &[input])?;
    Ok(runs.pop().expect("batch of one yields one run"))
}

/// Serve `inputs.len()` same-model CNN inferences in one pass, stacking the
/// member frames along the t-dimension: each conv layer's im2col blocks
/// concatenate into one `(B·t)×k` matrix and each FC layer's rows into a
/// `B×k` matrix, so every layer group costs one plan lookup and one kernel
/// launch for the whole batch instead of one per frame.
///
/// Row independence of GEMM makes stacking exact: every member's logits are
/// bit-identical to its own [`run_cnn`] on an exact backend. Per-frame
/// [`LayerReport`]s price each frame's *own* grouped layer shape (the same
/// quantity [`crate::sim::engine::simulate_frame`] reports), so batching
/// changes wall-clock amortization, never telemetry.
///
/// Noise injection attributes exactly too: frame `f` owns rows
/// `[f·t, (f+1)·t)` of each conv group's stacked GEMM and row `f` of an FC
/// stack, so the backend's per-row `row_noise` (order-independent by the
/// contract in [`crate::runtime::backend`]) slices back into per-frame
/// `noise_events` and per-output-row `row_noise` on every [`LayerReport`].
/// A frame's noise — and therefore its logits — is bit-identical whether it
/// serves stacked or unbatched at the same channel seed, which is why the
/// coordinator keeps CNN stacking enabled under noise.
pub fn run_cnn_batch(
    engine: &mut Engine,
    model: &CnnModel,
    inputs: &[&[i32]],
) -> Result<Vec<CnnRun>> {
    run_cnn_batch_keyed(engine, model, inputs, &[])
}

/// [`run_cnn_batch`] with one noise nonce per member frame (the
/// time-indexed counter mode): frame `f`'s rows of every stacked layer GEMM
/// are keyed by `frame_nonces[f]`, so byte-identical frames served under
/// different nonces observe decorrelated noise while each
/// `(seed, content, nonce)` run stays deterministic. An empty slice (or
/// all-zero nonces) is bit-identical to [`run_cnn_batch`] — the
/// content-keyed default.
pub fn run_cnn_batch_keyed(
    engine: &mut Engine,
    model: &CnnModel,
    inputs: &[&[i32]],
    frame_nonces: &[u64],
) -> Result<Vec<CnnRun>> {
    if inputs.is_empty() {
        return Ok(Vec::new());
    }
    check_frame_nonces(frame_nonces, inputs.len())?;
    for input in inputs {
        validate_cnn_input(model, input.len())?;
    }
    let plan = engine.cnn_plan(model)?;
    let (backend, scratch) = engine.cnn_exec_parts();
    run_planned(&plan, backend, scratch, inputs, frame_nonces)
}

/// A non-empty nonce slice must carry exactly one nonce per frame: a short
/// slice would silently serve the trailing frames content-keyed (losing the
/// decorrelation the caller asked for), a long one indicates the caller
/// paired nonces with the wrong batch.
fn check_frame_nonces(frame_nonces: &[u64], frames: usize) -> Result<()> {
    if !frame_nonces.is_empty() && frame_nonces.len() != frames {
        return Err(Error::Shape(format!(
            "cnn batch: {} frame nonces for {} frames (must be empty or one per frame)",
            frame_nonces.len(),
            frames
        )));
    }
    Ok(())
}

/// Drive one batch through a compiled plan: the steady-state hot loop.
/// Every buffer lives in `scratch`; the only allocations are the per-frame
/// result materialization (logits / layer reports) and, in keyed mode, the
/// per-layer nonce vectors.
fn run_planned(
    plan: &CnnPlan,
    backend: &mut dyn ExecBackend,
    scratch: &mut CnnScratch,
    inputs: &[&[i32]],
    frame_nonces: &[u64],
) -> Result<Vec<CnnRun>> {
    let b = inputs.len();
    let nonce_of = |f: usize| frame_nonces.get(f).copied().unwrap_or(0);
    let keyed = frame_nonces.iter().any(|&n| n != 0);
    let CnnScratch { a8, acts, raw, out, row_noise, frame_noise, frame_rows } = scratch;

    // Narrow every frame's wire input into the flat activation arena.
    let mut cur = plan.input_len();
    acts.clear();
    acts.reserve(b * cur);
    for input in inputs {
        acts.extend(input.iter().map(|&v| v as i8));
    }

    let mut layer_reports: Vec<Vec<LayerReport>> = vec![Vec::new(); b];
    let mut aggs: Vec<Option<ExecReport>> = vec![None; b];

    for planned in plan.layers() {
        // Per-frame noise attribution, sliced out of the stacked executes'
        // per-row `row_noise`: frame f owns rows [f·t, (f+1)·t) of every
        // conv group's stacked GEMM and row f of the FC stack. `frame_rows`
        // stays untouched (and unread) until a backend carries attribution.
        frame_noise.clear();
        frame_noise.resize(b, 0);
        let mut attributed = false;
        // Rows each frame owns in this layer's stacked GEMMs (for slicing
        // `frame_rows` into per-frame reports).
        let mut rpf = 1usize;
        match planned {
            PlannedLayer::Conv {
                in_h, in_w, in_ch, out_ch, kernel, stride, pad, groups, t, k, c, weights, ..
            } => {
                rpf = *t;
                raw.clear();
                raw.resize(b * t * out_ch, 0);
                a8.resize(b * t * k, 0);
                // One nonce per stacked row, identical across groups (every
                // group's GEMM carries the same frame-major row order).
                let rn = if keyed {
                    RowNonce::PerRow((0..b * t).map(|row| nonce_of(row / t)).collect())
                } else {
                    RowNonce::Content
                };
                for (g, pb) in weights.iter().enumerate() {
                    // Stack every frame's im2col block for this group,
                    // written directly into the arena.
                    for f in 0..b {
                        im2col_group_into(
                            &acts[f * cur..(f + 1) * cur],
                            *in_h,
                            *in_w,
                            *in_ch,
                            *kernel,
                            *stride,
                            *pad,
                            *groups,
                            g,
                            &mut a8[f * t * k..(f + 1) * t * k],
                        );
                    }
                    backend.execute_prepacked_i8(a8, b * t, pb, &rn, out, row_noise)?;
                    if !row_noise.is_empty() {
                        if !attributed {
                            attributed = true;
                            frame_rows.clear();
                            frame_rows.resize(b * t, 0);
                        }
                        for (i, &e) in row_noise.iter().enumerate() {
                            frame_rows[i] += e;
                            frame_noise[i / t] += e;
                        }
                    }
                    // Scatter each frame's t×c block into its HWC output.
                    for f in 0..b {
                        for row in 0..*t {
                            let dst = (f * t + row) * out_ch + g * c;
                            raw[dst..dst + c]
                                .copy_from_slice(&out[(f * t + row) * c..(f * t + row + 1) * c]);
                        }
                    }
                }
                acts.clear();
                acts.extend(raw.iter().map(|&v| requantize(v, *k)));
                cur = t * out_ch;
            }
            PlannedLayer::Fc { in_features, out_features, weights, .. } => {
                // `acts` already is the stacked B×k activation matrix.
                let rn = if keyed {
                    RowNonce::PerRow((0..b).map(nonce_of).collect())
                } else {
                    RowNonce::Content
                };
                backend.execute_prepacked_i8(acts, b, weights, &rn, out, row_noise)?;
                if !row_noise.is_empty() {
                    attributed = true;
                    frame_rows.clear();
                    frame_rows.resize(b, 0);
                    for f in 0..b {
                        frame_rows[f] += row_noise[f];
                        frame_noise[f] += row_noise[f];
                    }
                }
                raw.clear();
                raw.extend_from_slice(&out[..]);
                acts.clear();
                acts.extend(out.iter().map(|&v| requantize(v, *in_features)));
                cur = *out_features;
            }
        }
        // Per-frame projection on the frame's full grouped shape — identical
        // to the layer's record in `simulate_frame` for the same accelerator,
        // whatever the batch size — plus the frame's own slice of the
        // stacked noise attribution.
        if let Some(r) = backend.report_for(planned.shape()) {
            for f in 0..b {
                let mut rf = r.clone();
                rf.noise_events = frame_noise[f];
                rf.row_noise = if attributed {
                    frame_rows[f * rpf..(f + 1) * rpf].to_vec()
                } else {
                    Vec::new()
                };
                let merged = match aggs[f].take() {
                    Some(mut a) => {
                        a.merge(&rf);
                        a
                    }
                    None => rf.clone(),
                };
                aggs[f] = Some(merged);
                layer_reports[f]
                    .push(LayerReport { layer: planned.name().to_string(), report: rf });
            }
        }
    }

    // Result materialization: the final layer's raw accumulators, sliced
    // back into per-frame logits.
    Ok((0..b)
        .map(|f| CnnRun {
            logits: raw[f * cur..(f + 1) * cur].to_vec(),
            report: aggs[f].take(),
            layers: std::mem::take(&mut layer_reports[f]),
        })
        .collect())
}

/// The pre-plan serving path, retained as the bit-exactness oracle for
/// [`run_cnn_batch_keyed`]: lowers every layer through the engine's ad-hoc
/// wire-format GEMM entry ([`Engine::execute_gemm_shape_keyed`]), paying
/// per-request im2col allocation, i8→i32→i8 wire round-trips and per-plan
/// weight revalidation. `tests/cnn_plan.rs` pins the plan path against this
/// on both backends, exact and noisy. Semantically identical (same logits,
/// same telemetry, same noise attribution) — only the work per request
/// differs.
pub fn run_cnn_batch_keyed_reference(
    engine: &mut Engine,
    model: &CnnModel,
    inputs: &[&[i32]],
    frame_nonces: &[u64],
) -> Result<Vec<CnnRun>> {
    if inputs.is_empty() {
        return Ok(Vec::new());
    }
    check_frame_nonces(frame_nonces, inputs.len())?;
    let nonce_of = |f: usize| frame_nonces.get(f).copied().unwrap_or(0);
    let keyed = frame_nonces.iter().any(|&n| n != 0);
    for input in inputs {
        validate_cnn_input(model, input.len())?;
    }
    let b = inputs.len();
    let mut acts: Vec<Vec<i8>> =
        inputs.iter().map(|inp| inp.iter().map(|&v| v as i8).collect()).collect();
    let mut raws: Vec<Vec<i32>> = vec![Vec::new(); b];
    let mut layer_reports: Vec<Vec<LayerReport>> = vec![Vec::new(); b];
    let mut aggs: Vec<Option<ExecReport>> = vec![None; b];

    for (li, layer) in model.layers.iter().enumerate() {
        let shape = layer.gemm();
        // Per-frame noise attribution, sliced out of the stacked executes'
        // per-row `row_noise`: frame f owns rows [f·t, (f+1)·t) of every
        // conv group's stacked GEMM and row f of the FC stack.
        // `frame_rows[f][row]` accumulates row-level events across groups;
        // it stays empty (per frame) until a report carries attribution.
        let mut frame_noise = vec![0u64; b];
        let mut frame_rows: Vec<Vec<u64>> = Vec::new();
        match layer {
            Layer::Conv { in_h, in_w, in_ch, out_ch, kernel, stride, pad, groups, .. } => {
                let (oh, ow) = layer.out_hw();
                let (t, k, c) = (oh * ow, shape.k, shape.c);
                for raw in raws.iter_mut() {
                    *raw = vec![0i32; t * out_ch];
                }
                for g in 0..*groups {
                    // Stack every frame's im2col block for this group.
                    let mut a_wire: Vec<i32> = Vec::with_capacity(b * t * k);
                    for act in &acts {
                        let a8 = im2col_group(
                            act, *in_h, *in_w, *in_ch, *kernel, *stride, *pad, *groups, g,
                        );
                        a_wire.extend(a8.iter().map(|&v| v as i32));
                    }
                    let w_wire: Vec<i32> = surrogate_layer_weights(li, g, k, c)
                        .iter()
                        .map(|&v| v as i32)
                        .collect();
                    let rn = if keyed {
                        RowNonce::PerRow(
                            (0..b * t).map(|row| nonce_of(row / t)).collect(),
                        )
                    } else {
                        RowNonce::Content
                    };
                    let (out, rep) =
                        engine.execute_gemm_shape_keyed(b * t, k, c, &a_wire, &w_wire, &rn)?;
                    if let Some(r) = &rep {
                        if !r.row_noise.is_empty() {
                            if frame_rows.is_empty() {
                                frame_rows = vec![vec![0u64; t]; b];
                            }
                            for f in 0..b {
                                for row in 0..t {
                                    let e = r.row_noise[f * t + row];
                                    frame_rows[f][row] += e;
                                    frame_noise[f] += e;
                                }
                            }
                        }
                    }
                    // Scatter each frame's t×c block into its HWC output.
                    for (f, raw) in raws.iter_mut().enumerate() {
                        for row in 0..t {
                            raw[row * out_ch + g * c..row * out_ch + g * c + c]
                                .copy_from_slice(&out[(f * t + row) * c..(f * t + row + 1) * c]);
                        }
                    }
                }
                for (act, raw) in acts.iter_mut().zip(&raws) {
                    *act = raw.iter().map(|&v| requantize(v, k)).collect();
                }
            }
            Layer::Fc { in_features, out_features, .. } => {
                // Stack every frame's activation row: B×k · k×c.
                let mut a_wire: Vec<i32> = Vec::with_capacity(b * in_features);
                for act in &acts {
                    a_wire.extend(act.iter().map(|&v| v as i32));
                }
                let w_wire: Vec<i32> =
                    surrogate_layer_weights(li, 0, *in_features, *out_features)
                        .iter()
                        .map(|&v| v as i32)
                        .collect();
                let rn = if keyed {
                    RowNonce::PerRow((0..b).map(|f| nonce_of(f)).collect())
                } else {
                    RowNonce::Content
                };
                let (out, rep) = engine.execute_gemm_shape_keyed(
                    b,
                    *in_features,
                    *out_features,
                    &a_wire,
                    &w_wire,
                    &rn,
                )?;
                if let Some(r) = &rep {
                    if !r.row_noise.is_empty() {
                        frame_rows = vec![vec![0u64; 1]; b];
                        for f in 0..b {
                            frame_rows[f][0] += r.row_noise[f];
                            frame_noise[f] += r.row_noise[f];
                        }
                    }
                }
                for f in 0..b {
                    let row = &out[f * out_features..(f + 1) * out_features];
                    acts[f] = row.iter().map(|&v| requantize(v, *in_features)).collect();
                    raws[f] = row.to_vec();
                }
            }
        }
        // Per-frame projection on the frame's full grouped shape — identical
        // to the layer's record in `simulate_frame` for the same accelerator,
        // whatever the batch size — plus the frame's own slice of the
        // stacked noise attribution.
        if let Some(r) = engine.report_for(&shape) {
            for f in 0..b {
                let mut rf = r.clone();
                rf.noise_events = frame_noise[f];
                rf.row_noise = frame_rows.get(f).cloned().unwrap_or_default();
                let merged = match aggs[f].take() {
                    Some(mut a) => {
                        a.merge(&rf);
                        a
                    }
                    None => rf.clone(),
                };
                aggs[f] = Some(merged);
                layer_reports[f].push(LayerReport { layer: layer.name().to_string(), report: rf });
            }
        }
    }

    Ok(raws
        .into_iter()
        .zip(aggs)
        .zip(layer_reports)
        .map(|((logits, report), layers)| CnnRun { logits, report, layers })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::layer::Layer;
    use crate::runtime::backend::BackendKind;
    use crate::runtime::photonic::PhotonicConfig;

    fn tiny_model() -> CnnModel {
        CnnModel {
            name: "tiny",
            layers: vec![
                Layer::conv("stem", 6, 6, 3, 4, 3, 1, 1),
                Layer::dwconv("dw", 6, 6, 4, 3, 2, 1),
                Layer::fc("head", 3 * 3 * 4, 5),
            ],
        }
    }

    fn synthetic_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("spoga-cnnrun-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "mlp_b1 m i32:1x16 i32:1x4\n").unwrap();
        dir
    }

    #[test]
    fn chain_validation_catches_mismatches() {
        let m = tiny_model();
        assert!(validate_cnn_input(&m, 6 * 6 * 3).is_ok());
        assert!(validate_cnn_input(&m, 17).is_err());
        let broken = CnnModel {
            name: "broken",
            layers: vec![Layer::conv("c", 6, 6, 3, 4, 3, 1, 1), Layer::fc("f", 999, 5)],
        };
        assert!(validate_cnn_input(&broken, 6 * 6 * 3).is_err());
        let degenerate = CnnModel {
            name: "deg",
            layers: vec![Layer::conv("c", 2, 2, 1, 1, 5, 1, 0)],
        };
        assert!(validate_cnn_input(&degenerate, 4).is_err());
        assert!(validate_cnn_input(&CnnModel { name: "e", layers: vec![] }, 0).is_err());
    }

    #[test]
    fn backends_serve_bit_identical_cnn_logits() {
        let dir = synthetic_dir("identical");
        let mut sw = Engine::new(&dir).unwrap();
        let mut ph =
            Engine::with_backend(&dir, BackendKind::Photonic(PhotonicConfig::spoga())).unwrap();
        let model = tiny_model();
        let input: Vec<i32> = (0..6 * 6 * 3).map(|v| (v * 29 % 251) - 125).collect();

        let r_sw = run_cnn(&mut sw, &model, &input).unwrap();
        let r_ph = run_cnn(&mut ph, &model, &input).unwrap();
        assert_eq!(r_sw.logits.len(), 5);
        assert_eq!(r_sw.logits, r_ph.logits);
        assert!(r_sw.report.is_none() && r_sw.layers.is_empty());

        // Photonic telemetry covers every layer and sums into the aggregate.
        assert_eq!(r_ph.layers.len(), 3);
        let agg = r_ph.report.unwrap();
        assert!(agg.sim_latency_s > 0.0 && agg.energy_j > 0.0);
        let lat_sum: f64 = r_ph.layers.iter().map(|l| l.report.sim_latency_s).sum();
        assert!((agg.sim_latency_s - lat_sum).abs() < 1e-15);
        assert_eq!(agg.lanes, model.workload().total_outputs());

        // Determinism across repeat runs.
        let again = run_cnn(&mut sw, &model, &input).unwrap();
        assert_eq!(again.logits, r_sw.logits);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batched_frames_match_unbatched_runs_bit_for_bit() {
        let dir = synthetic_dir("batch");
        let model = tiny_model();
        let frames: Vec<Vec<i32>> = (0..3)
            .map(|f| (0..6 * 6 * 3).map(|v| ((v * 31 + f * 97) % 251) - 125).collect())
            .collect();
        let refs: Vec<&[i32]> = frames.iter().map(|f| f.as_slice()).collect();

        for backend in [
            BackendKind::Software,
            BackendKind::Photonic(PhotonicConfig::spoga()),
        ] {
            let mut eng = Engine::with_backend(&dir, backend.clone()).unwrap();
            let batched = run_cnn_batch(&mut eng, &model, &refs).unwrap();
            assert_eq!(batched.len(), frames.len());
            for (f, frame) in frames.iter().enumerate() {
                let single = run_cnn(&mut eng, &model, frame).unwrap();
                assert_eq!(
                    batched[f].logits, single.logits,
                    "{}: frame {f} diverged under t-stacking",
                    backend.label()
                );
                // Per-frame telemetry is identical to the unbatched run's:
                // each frame prices its own grouped layer shapes.
                assert_eq!(batched[f].layers.len(), single.layers.len());
                for (bl, sl) in batched[f].layers.iter().zip(&single.layers) {
                    assert_eq!(bl.layer, sl.layer);
                    assert_eq!(bl.report, sl.report, "{}: layer {}", backend.label(), bl.layer);
                }
                assert_eq!(batched[f].report, single.report);
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_frame_in_stack_leaves_other_members_exact() {
        // The padding-exactness property the MLP batcher relies on, pinned
        // for CNN stacking: an all-zero frame in the stack must not perturb
        // its co-batched members (GEMM rows are independent).
        let dir = synthetic_dir("zeropad");
        let model = tiny_model();
        let mut eng = Engine::new(&dir).unwrap();
        let live: Vec<i32> = (0..6 * 6 * 3).map(|v| ((v * 29) % 251) - 125).collect();
        let zero = vec![0i32; 6 * 6 * 3];

        let alone = run_cnn(&mut eng, &model, &live).unwrap();
        let padded =
            run_cnn_batch(&mut eng, &model, &[&zero, &live, &zero]).unwrap();
        assert_eq!(padded[1].logits, alone.logits, "zero co-frames perturbed a member");
        // The zero frames themselves serve deterministically too.
        assert_eq!(padded[0].logits, padded[2].logits);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let dir = synthetic_dir("empty");
        let mut eng = Engine::new(&dir).unwrap();
        assert!(run_cnn_batch(&mut eng, &tiny_model(), &[]).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn surrogate_weights_keyed_by_layer_and_group() {
        assert_eq!(surrogate_layer_weights(0, 0, 9, 4), surrogate_layer_weights(0, 0, 9, 4));
        assert_ne!(surrogate_layer_weights(0, 0, 9, 4), surrogate_layer_weights(1, 0, 9, 4));
        assert_ne!(surrogate_layer_weights(0, 0, 9, 4), surrogate_layer_weights(0, 1, 9, 4));
    }
}
