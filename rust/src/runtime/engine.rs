//! Execution engine: compile (plan) once, execute many.
//!
//! The engine owns the artifact manifest and a cache of compiled execution
//! plans. The default backend is the in-process software interpreter
//! ([`crate::runtime::software`]), which routes every artifact through the
//! packed bit-sliced GEMM fast path — see the module docs of
//! [`crate::runtime`] for the backend story.

use std::collections::HashMap;

use crate::runtime::artifact::{DType, Manifest, TensorSpec};
use crate::runtime::software::Plan;
use crate::{Error, Result};

/// A planned artifact plus the input specs needed for request validation,
/// kept together so the warm execute path is a single map lookup (no linear
/// manifest scan per request).
struct Compiled {
    plan: Plan,
    inputs: Vec<TensorSpec>,
}

/// Engine owning the manifest and the per-artifact compiled plans.
///
/// Workers each construct their own `Engine` (cheap for the software
/// backend, and it keeps the one-engine-per-worker architecture that a
/// thread-affine PJRT backend would require).
pub struct Engine {
    manifest: Manifest,
    compiled: HashMap<String, Compiled>,
}

impl Engine {
    /// Create an engine over an artifact directory (lazy compilation).
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Engine { manifest, compiled: HashMap::new() })
    }

    /// The manifest this engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Backend name (diagnostics).
    pub fn platform(&self) -> String {
        "software-bitslice (packed-plane GEMM interpreter)".to_string()
    }

    /// Ensure `name` is compiled; returns compile time in seconds.
    pub fn warmup(&mut self, name: &str) -> Result<f64> {
        let t0 = std::time::Instant::now();
        self.ensure_compiled(name)?;
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Compile every artifact in the manifest.
    pub fn warmup_all(&mut self) -> Result<()> {
        let names: Vec<String> =
            self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in names {
            self.ensure_compiled(&n)?;
        }
        Ok(())
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let meta = self.manifest.get(name)?;
        let plan = Plan::compile(meta)?;
        let inputs = meta.inputs.clone();
        self.compiled.insert(name.to_string(), Compiled { plan, inputs });
        Ok(())
    }

    /// Execute artifact `name` with positional int32 inputs.
    ///
    /// Each input must match the manifest spec's element count; outputs are
    /// returned as flat row-major int32 vectors (one per output spec).
    pub fn execute_i32(&mut self, name: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        self.ensure_compiled(name)?;
        let c = &self.compiled[name];
        if inputs.len() != c.inputs.len() {
            return Err(Error::Shape(format!(
                "{name}: {} inputs supplied, {} expected",
                inputs.len(),
                c.inputs.len()
            )));
        }
        for (i, (buf, spec)) in inputs.iter().zip(&c.inputs).enumerate() {
            if spec.dtype != DType::I32 {
                return Err(Error::Shape(format!("{name}: input {i} is not i32")));
            }
            if buf.len() != spec.elements() {
                return Err(Error::Shape(format!(
                    "{name}: input {i} has {} elements, expected {} ({:?})",
                    buf.len(),
                    spec.elements(),
                    spec.dims
                )));
            }
        }
        let out = c.plan.execute(inputs)?;
        Ok(vec![out])
    }

    /// Convenience: single-output execution.
    pub fn execute_i32_single(&mut self, name: &str, inputs: &[&[i32]]) -> Result<Vec<i32>> {
        Ok(self.execute_i32(name, inputs)?.remove(0))
    }
}

#[cfg(test)]
mod tests {
    //! Artifact-dependent engine tests live in `rust/tests/runtime_roundtrip.rs`;
    //! here we cover engine logic against a synthetic manifest directory.

    use super::*;

    #[test]
    fn missing_artifact_dir_is_artifact_error() {
        match Engine::new("/nonexistent/path") {
            Err(Error::Artifact(msg)) => assert!(msg.contains("make artifacts")),
            Err(other) => panic!("wrong error kind: {other}"),
            Ok(_) => panic!("engine should not load from a missing dir"),
        }
    }

    fn synthetic_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("spoga-engine-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8\n\
             mlp_b1 m1.hlo.txt i32:1x16 i32:1x4\n\
             mlp_b8 m8.hlo.txt i32:8x16 i32:8x4\n",
        )
        .unwrap();
        dir
    }

    #[test]
    fn software_engine_serves_synthetic_manifest() {
        let dir = synthetic_dir("serve");
        let mut eng = Engine::new(&dir).unwrap();
        assert!(eng.platform().contains("software"));

        // GEMM path: bit-exact vs the golden model.
        let a: Vec<i32> = (0..64).map(|v| (v * 7 % 255) - 127).collect();
        let b: Vec<i32> = (0..64).map(|v| (v * 11 % 255) - 127).collect();
        let out = eng.execute_i32_single("gemm_8x8x8", &[&a, &b]).unwrap();
        let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
        let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
        assert_eq!(out, crate::bitslice::gemm_i32(&a8, &b8, 8, 8, 8).unwrap());

        // Batch-variant row agreement.
        let row: Vec<i32> = (0..16).map(|v| v % 100).collect();
        let single = eng.execute_i32_single("mlp_b1", &[&row]).unwrap();
        let mut padded = vec![0i32; 8 * 16];
        padded[..16].copy_from_slice(&row);
        let batched = eng.execute_i32_single("mlp_b8", &[&padded]).unwrap();
        assert_eq!(&batched[..4], &single[..]);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn validation_and_warmup_semantics() {
        let dir = synthetic_dir("validate");
        let mut eng = Engine::new(&dir).unwrap();

        let short = vec![0i32; 3];
        assert!(eng.execute_i32_single("mlp_b1", &[&short]).is_err());
        let row = vec![0i32; 16];
        assert!(eng.execute_i32_single("mlp_b1", &[&row, &row]).is_err());
        assert!(eng.execute_i32_single("nope", &[&row]).is_err());

        let t1 = eng.warmup("gemm_8x8x8").unwrap();
        assert!(t1 >= 0.0);
        let t2 = eng.warmup("gemm_8x8x8").unwrap();
        assert!(t2 < t1.max(0.01));
        eng.warmup_all().unwrap();

        let _ = std::fs::remove_dir_all(&dir);
    }
}
