//! PJRT execution engine: compile once, execute many.

use std::collections::HashMap;

use crate::runtime::artifact::{ArtifactMeta, DType, Manifest};
use crate::{Error, Result};

/// A compiled artifact ready to run.
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

/// PJRT CPU engine owning a client and the compiled executables.
///
/// Not `Sync` (PJRT handles are thread-affine in the `xla` crate); the
/// coordinator gives each worker thread its own `Engine`.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, Compiled>,
}

impl Engine {
    /// Create an engine over an artifact directory (lazy compilation).
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, compiled: HashMap::new() })
    }

    /// The manifest this engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Ensure `name` is compiled; returns compile time in seconds.
    pub fn warmup(&mut self, name: &str) -> Result<f64> {
        let t0 = std::time::Instant::now();
        self.ensure_compiled(name)?;
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Compile every artifact in the manifest.
    pub fn warmup_all(&mut self) -> Result<()> {
        let names: Vec<String> =
            self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in names {
            self.ensure_compiled(&n)?;
        }
        Ok(())
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let meta = self.manifest.get(name)?.clone();
        let path = self.manifest.path_of(&meta);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compiled.insert(name.to_string(), Compiled { exe, meta });
        Ok(())
    }

    /// Execute artifact `name` with positional int32 inputs.
    ///
    /// Each input must match the manifest spec's element count; outputs are
    /// returned as flat row-major int32 vectors (one per output spec).
    pub fn execute_i32(&mut self, name: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        self.ensure_compiled(name)?;
        let c = &self.compiled[name];
        if inputs.len() != c.meta.inputs.len() {
            return Err(Error::Shape(format!(
                "{name}: {} inputs supplied, {} expected",
                inputs.len(),
                c.meta.inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (buf, spec)) in inputs.iter().zip(&c.meta.inputs).enumerate() {
            if spec.dtype != DType::I32 {
                return Err(Error::Shape(format!("{name}: input {i} is not i32")));
            }
            if buf.len() != spec.elements() {
                return Err(Error::Shape(format!(
                    "{name}: input {i} has {} elements, expected {} ({:?})",
                    buf.len(),
                    spec.elements(),
                    spec.dims
                )));
            }
            let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = c.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(vec![out.to_vec::<i32>()?])
    }

    /// Convenience: single-output execution.
    pub fn execute_i32_single(&mut self, name: &str, inputs: &[&[i32]]) -> Result<Vec<i32>> {
        Ok(self.execute_i32(name, inputs)?.remove(0))
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests live in `rust/tests/runtime_roundtrip.rs` (they need the
    //! artifacts built by `make artifacts`); here we only cover pure logic.

    use super::*;

    #[test]
    fn missing_artifact_dir_is_artifact_error() {
        match Engine::new("/nonexistent/path") {
            Err(Error::Artifact(msg)) => assert!(msg.contains("make artifacts")),
            Err(other) => panic!("wrong error kind: {other}"),
            Ok(_) => panic!("engine should not load from a missing dir"),
        }
    }
}
