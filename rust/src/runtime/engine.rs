//! Execution engine: compile (plan) once, execute many.
//!
//! The engine owns the artifact manifest, request validation, and a
//! [`crate::runtime::ExecBackend`] chosen by [`BackendKind`] — the backend
//! owns the compiled plans. [`Engine::new`] keeps the historical default
//! (the software interpreter); [`Engine::with_backend`] selects any in-tree
//! backend, e.g. the photonic-in-the-loop simulator. See the module docs of
//! [`crate::runtime`] for the backend story.

use std::collections::HashMap;
use std::sync::Arc;

use crate::dnn::layer::GemmShape;
use crate::dnn::models::CnnModel;
use crate::runtime::artifact::{ArtifactMeta, DType, Manifest, TensorSpec};
use crate::runtime::backend::{BackendKind, ExecBackend, ExecReport, RowNonce};
use crate::runtime::cnnrun::{CnnPlan, CnnScratch};
use crate::{Error, Result};

/// Engine owning the manifest, validation specs, and the backend.
///
/// Workers each construct their own `Engine` (cheap for the in-tree
/// backends, and it keeps the one-engine-per-worker architecture that a
/// thread-affine PJRT backend would require).
pub struct Engine {
    manifest: Manifest,
    kind: BackendKind,
    backend: Box<dyn ExecBackend>,
    /// Input specs of planned artifacts (manifest or synthetic), kept here
    /// so the warm execute path validates with one map lookup.
    planned: HashMap<String, Vec<TensorSpec>>,
    /// Compiled whole-CNN plans, keyed by model name and revalidated by
    /// full model equality (see [`Engine::cnn_plan`]). Plans are immutable
    /// after compile and shared via `Arc`.
    cnn_plans: HashMap<&'static str, Arc<CnnPlan>>,
    /// Persistent scratch arena for plan-driven CNN serving (exclusive to
    /// this engine; see [`crate::runtime::cnnrun::CnnScratch`]).
    cnn_scratch: CnnScratch,
}

impl Engine {
    /// Create an engine over an artifact directory with the default
    /// (software) backend; compilation is lazy.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::with_backend(artifact_dir, BackendKind::Software)
    }

    /// Create an engine over an artifact directory with an explicit backend.
    pub fn with_backend(
        artifact_dir: impl AsRef<std::path::Path>,
        kind: BackendKind,
    ) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let backend = kind.build()?;
        Ok(Engine {
            manifest,
            kind,
            backend,
            planned: HashMap::new(),
            cnn_plans: HashMap::new(),
            cnn_scratch: CnnScratch::default(),
        })
    }

    /// The manifest this engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Which backend this engine executes through.
    pub fn backend_kind(&self) -> &BackendKind {
        &self.kind
    }

    /// Backend name (diagnostics).
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Ensure `name` is compiled; returns compile time in seconds.
    pub fn warmup(&mut self, name: &str) -> Result<f64> {
        let t0 = std::time::Instant::now();
        self.ensure_compiled(name)?;
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Compile every artifact in the manifest.
    pub fn warmup_all(&mut self) -> Result<()> {
        let names: Vec<String> =
            self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in names {
            self.ensure_compiled(&n)?;
        }
        Ok(())
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.planned.contains_key(name) {
            return Ok(());
        }
        let meta = self.manifest.get(name)?.clone();
        self.backend.plan(&meta)?;
        self.planned.insert(name.to_string(), meta.inputs);
        Ok(())
    }

    fn validate(&self, name: &str, inputs: &[&[i32]]) -> Result<()> {
        let specs = &self.planned[name];
        if inputs.len() != specs.len() {
            return Err(Error::Shape(format!(
                "{name}: {} inputs supplied, {} expected",
                inputs.len(),
                specs.len()
            )));
        }
        for (i, (buf, spec)) in inputs.iter().zip(specs).enumerate() {
            if spec.dtype != DType::I32 {
                return Err(Error::Shape(format!("{name}: input {i} is not i32")));
            }
            if buf.len() != spec.elements() {
                return Err(Error::Shape(format!(
                    "{name}: input {i} has {} elements, expected {} ({:?})",
                    buf.len(),
                    spec.elements(),
                    spec.dims
                )));
            }
        }
        Ok(())
    }

    /// Execute artifact `name` with positional int32 inputs, returning the
    /// single flat output plus the backend's telemetry (if any).
    ///
    /// Each input must match the manifest spec's element count; the output
    /// is a flat row-major int32 vector.
    pub fn execute_reported(
        &mut self,
        name: &str,
        inputs: &[&[i32]],
    ) -> Result<(Vec<i32>, Option<ExecReport>)> {
        self.execute_reported_keyed(name, inputs, &RowNonce::Content)
    }

    /// [`Engine::execute_reported`] with per-output-row noise nonces — the
    /// coordinator's time-indexed counter mode. Digital backends and
    /// noise-off photonic backends ignore the nonces (the default trait
    /// implementation), so passing [`RowNonce::Content`] here is always
    /// bit-identical to the plain call.
    pub fn execute_reported_keyed(
        &mut self,
        name: &str,
        inputs: &[&[i32]],
        nonce: &RowNonce,
    ) -> Result<(Vec<i32>, Option<ExecReport>)> {
        self.ensure_compiled(name)?;
        self.validate(name, inputs)?;
        let ex = self.backend.execute_i32_keyed(name, inputs, nonce)?;
        Ok((ex.output, ex.report))
    }

    /// Execute artifact `name` with positional int32 inputs; outputs are
    /// returned as flat row-major int32 vectors (one per output spec).
    pub fn execute_i32(&mut self, name: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        let (out, _report) = self.execute_reported(name, inputs)?;
        Ok(vec![out])
    }

    /// Convenience: single-output execution.
    pub fn execute_i32_single(&mut self, name: &str, inputs: &[&[i32]]) -> Result<Vec<i32>> {
        Ok(self.execute_i32(name, inputs)?.remove(0))
    }

    /// Execute an ad-hoc `m×k · k×n` GEMM through the backend (outside the
    /// manifest) — the CNN serving path plans one synthetic artifact per
    /// distinct layer shape and reuses it across requests.
    pub fn execute_gemm_shape(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        a: &[i32],
        b: &[i32],
    ) -> Result<(Vec<i32>, Option<ExecReport>)> {
        self.execute_gemm_shape_keyed(m, k, n, a, b, &RowNonce::Content)
    }

    /// [`Engine::execute_gemm_shape`] with per-output-row noise nonces (see
    /// [`Engine::execute_reported_keyed`]) — the CNN batching path uses this
    /// to key each stacked frame's rows by its request nonce.
    pub fn execute_gemm_shape_keyed(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        a: &[i32],
        b: &[i32],
        nonce: &RowNonce,
    ) -> Result<(Vec<i32>, Option<ExecReport>)> {
        if m == 0 || k == 0 || n == 0 {
            return Err(Error::Shape(format!("degenerate GEMM {m}x{k}x{n}")));
        }
        let name = format!("__gemm/{m}x{k}x{n}");
        if !self.planned.contains_key(&name) {
            let spec = |r: usize, c: usize| TensorSpec { dtype: DType::I32, dims: vec![r, c] };
            let meta = ArtifactMeta {
                name: name.clone(),
                file: "<synthetic>".to_string(),
                inputs: vec![spec(m, k), spec(k, n)],
                outputs: vec![spec(m, n)],
            };
            self.backend.plan(&meta)?;
            self.planned.insert(name.clone(), meta.inputs);
        }
        self.validate(&name, &[a, b])?;
        let ex = self.backend.execute_i32_keyed(&name, &[a, b], nonce)?;
        Ok((ex.output, ex.report))
    }

    /// Backend telemetry for a GEMM shape without executing it (`None` for
    /// digital backends). The CNN path uses this to price whole grouped
    /// layers exactly as [`crate::sim::engine::simulate_frame`] would.
    pub fn report_for(&mut self, shape: &GemmShape) -> Option<ExecReport> {
        self.backend.report_for(shape)
    }

    /// The compiled plan for `model`: cache hit by model name, revalidated
    /// by **full model equality** (never a hash — the CNN analogue of the
    /// `refresh_wire` content-equality rule in [`crate::runtime::backend`]),
    /// recompiled in place when a different model reuses a name. Compiling
    /// packs every layer's surrogate weights once; requests then stream
    /// against the shared immutable plan.
    pub fn cnn_plan(&mut self, model: &CnnModel) -> Result<Arc<CnnPlan>> {
        if let Some(p) = self.cnn_plans.get(model.name) {
            if p.model() == model {
                return Ok(p.clone());
            }
        }
        let plan = Arc::new(CnnPlan::compile(model)?);
        self.cnn_plans.insert(model.name, plan.clone());
        Ok(plan)
    }

    /// Split-borrow the backend and the CNN scratch arena for the plan
    /// serving loop (the two are disjoint fields; the plan itself is shared
    /// separately via [`Engine::cnn_plan`]'s `Arc`).
    pub(crate) fn cnn_exec_parts(&mut self) -> (&mut dyn ExecBackend, &mut CnnScratch) {
        (self.backend.as_mut(), &mut self.cnn_scratch)
    }
}

#[cfg(test)]
mod tests {
    //! Artifact-dependent engine tests live in `rust/tests/runtime_roundtrip.rs`;
    //! here we cover engine logic against a synthetic manifest directory.

    use super::*;
    use crate::runtime::photonic::PhotonicConfig;

    #[test]
    fn missing_artifact_dir_is_artifact_error() {
        match Engine::new("/nonexistent/path") {
            Err(Error::Artifact(msg)) => assert!(msg.contains("make artifacts")),
            Err(other) => panic!("wrong error kind: {other}"),
            Ok(_) => panic!("engine should not load from a missing dir"),
        }
    }

    fn synthetic_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("spoga-engine-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8\n\
             mlp_b1 m1.hlo.txt i32:1x16 i32:1x4\n\
             mlp_b8 m8.hlo.txt i32:8x16 i32:8x4\n",
        )
        .unwrap();
        dir
    }

    #[test]
    fn software_engine_serves_synthetic_manifest() {
        let dir = synthetic_dir("serve");
        let mut eng = Engine::new(&dir).unwrap();
        assert!(eng.platform().contains("software"));
        assert_eq!(eng.backend_kind().label(), "software");

        // GEMM path: bit-exact vs the golden model.
        let a: Vec<i32> = (0..64).map(|v| (v * 7 % 255) - 127).collect();
        let b: Vec<i32> = (0..64).map(|v| (v * 11 % 255) - 127).collect();
        let out = eng.execute_i32_single("gemm_8x8x8", &[&a, &b]).unwrap();
        let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
        let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
        assert_eq!(out, crate::bitslice::gemm_i32(&a8, &b8, 8, 8, 8).unwrap());

        // Batch-variant row agreement.
        let row: Vec<i32> = (0..16).map(|v| v % 100).collect();
        let single = eng.execute_i32_single("mlp_b1", &[&row]).unwrap();
        let mut padded = vec![0i32; 8 * 16];
        padded[..16].copy_from_slice(&row);
        let batched = eng.execute_i32_single("mlp_b8", &[&padded]).unwrap();
        assert_eq!(&batched[..4], &single[..]);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn validation_and_warmup_semantics() {
        let dir = synthetic_dir("validate");
        let mut eng = Engine::new(&dir).unwrap();

        let short = vec![0i32; 3];
        assert!(eng.execute_i32_single("mlp_b1", &[&short]).is_err());
        let row = vec![0i32; 16];
        assert!(eng.execute_i32_single("mlp_b1", &[&row, &row]).is_err());
        assert!(eng.execute_i32_single("nope", &[&row]).is_err());

        let t1 = eng.warmup("gemm_8x8x8").unwrap();
        assert!(t1 >= 0.0);
        let t2 = eng.warmup("gemm_8x8x8").unwrap();
        assert!(t2 < t1.max(0.01));
        eng.warmup_all().unwrap();

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn backend_choice_preserves_results_and_adds_telemetry() {
        let dir = synthetic_dir("backend");
        let mut sw = Engine::new(&dir).unwrap();
        let mut ph =
            Engine::with_backend(&dir, BackendKind::Photonic(PhotonicConfig::spoga())).unwrap();
        assert!(ph.platform().contains("photonic"));

        let a: Vec<i32> = (0..64).map(|v| (v * 13 % 251) - 125).collect();
        let b: Vec<i32> = (0..64).map(|v| (v * 17 % 249) - 124).collect();
        let (o_sw, r_sw) = sw.execute_reported("gemm_8x8x8", &[&a, &b]).unwrap();
        let (o_ph, r_ph) = ph.execute_reported("gemm_8x8x8", &[&a, &b]).unwrap();
        assert_eq!(o_sw, o_ph);
        assert!(r_sw.is_none());
        let r = r_ph.unwrap();
        assert!(r.sim_latency_s > 0.0 && r.energy_j > 0.0);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn adhoc_gemm_plans_once_and_validates() {
        let dir = synthetic_dir("adhoc");
        let mut eng = Engine::new(&dir).unwrap();
        let a = vec![1i32, 2, 3, 4];
        let b = vec![5i32, 6, 7, 8];
        let (out, rep) = eng.execute_gemm_shape(2, 2, 2, &a, &b).unwrap();
        assert_eq!(out, vec![19, 22, 43, 50]);
        assert!(rep.is_none());
        // Re-execute reuses the synthetic plan; wrong sizes are rejected.
        assert!(eng.execute_gemm_shape(2, 2, 2, &a, &b).is_ok());
        assert!(eng.execute_gemm_shape(2, 2, 2, &a[..3], &b).is_err());
        assert!(eng.execute_gemm_shape(0, 2, 2, &a, &b).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
