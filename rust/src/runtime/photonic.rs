//! Photonic-in-the-loop backend: bit-exact results, simulated telemetry.
//!
//! The backend executes every artifact through the same packed bit-sliced
//! plans as the software interpreter — results stay bit-identical to the
//! golden model, and the weight side streams prepacked exactly as in
//! [`crate::runtime::software`] (plan-owned [`PackedB`] for Linear,
//! content-checked per-artifact cache for ad-hoc GEMMs, activation-side
//! scratch reuse) — but each execute *also* runs the artifact's GEMM shape
//! through the transaction-level simulator ([`crate::sim::SimEngine`]) and
//! the conversion/energy accounting ([`crate::arch::cost`]) for a chosen
//! accelerator design point. The resulting [`ExecReport`] rides back on the
//! response, so a coordinator serving live traffic can answer "what FPS/W
//! would this exact request stream see on SPOGA vs HOLYLIGHT?" without a
//! separate offline study.
//!
//! With [`PhotonicConfig::noise`] set, outputs are additionally transduced
//! through the [`crate::fidelity`] analog channel (per-lane Gaussian noise
//! scaled to the link SNR, three BPCA lanes per dot product, PWAB
//! weighting) — the served integers then carry the analog error the paper's
//! fidelity study quantifies, `noise_events` counts the outputs that
//! diverged from the exact result, and `row_noise` attributes those events
//! to individual output rows through content-keyed noise sub-streams (the
//! per-row contract in [`crate::runtime::backend`], which is what keeps
//! dynamic batching exact-attributable under noise). Leave it `None` (the
//! default) for bit-exact serving.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::arch::accel::Accelerator;
use crate::bitslice::{gemm_i32_prepacked, gemm_lanes_prepacked, PackedB};
use crate::dnn::layer::GemmShape;
use crate::fidelity::{AnalogChannel, NoiseParams};
use crate::optics::link_budget::ArchClass;
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::backend::{BackendExec, ExecBackend, ExecReport, RowNonce};
use crate::runtime::software::{wire_to_i8_into, ExecScratch, Plan};
use crate::sim::engine::SimEngine;
use crate::units::DataRate;
use crate::{Error, Result};

/// Capacity cap of the memoized shape-pricing cache: ad-hoc
/// `execute_gemm_shape` traffic can carry unbounded distinct shapes, so a
/// long-lived serving shard must not let the memo grow without limit.
/// Evicted FIFO — steady serving traffic re-uses a small working set of
/// shapes, so oldest-first is effectively LRU there.
const REPORT_CACHE_CAP: usize = 256;

/// Design point the photonic backend simulates requests against.
#[derive(Debug, Clone)]
pub struct PhotonicConfig {
    /// Core organisation (MWA = SPOGA, MAW = HOLYLIGHT, AMW = DEAPCNN).
    pub arch: ArchClass,
    /// Symbol rate of the simulated cores.
    pub rate: DataRate,
    /// Physical core count (equal-core normalization, as Fig. 5).
    pub cores: usize,
    /// Analog noise injection: `None` serves bit-exact integers; `Some`
    /// transduces every output through the fidelity channel.
    pub noise: Option<NoiseParams>,
    /// Seed of the deterministic noise stream (ignored when `noise` is
    /// `None`).
    pub noise_seed: u64,
}

impl Default for PhotonicConfig {
    fn default() -> Self {
        Self::spoga()
    }
}

impl PhotonicConfig {
    /// SPOGA_10 at the Fig. 5 core count, noise off.
    pub fn spoga() -> Self {
        PhotonicConfig {
            arch: ArchClass::Mwa,
            rate: DataRate::Gs10,
            cores: crate::metrics::FIG5_CORES,
            noise: None,
            noise_seed: 0x5906_A0_10,
        }
    }

    /// HOLYLIGHT_10 baseline (MAW organisation).
    pub fn holylight() -> Self {
        PhotonicConfig { arch: ArchClass::Maw, ..Self::spoga() }
    }

    /// DEAPCNN_10 baseline (AMW organisation).
    pub fn deapcnn() -> Self {
        PhotonicConfig { arch: ArchClass::Amw, ..Self::spoga() }
    }

    /// Enable analog noise injection with a deterministic stream.
    pub fn with_noise(mut self, params: NoiseParams, seed: u64) -> Self {
        self.noise = Some(params);
        self.noise_seed = seed;
        self
    }

    /// Variant label, e.g. `SPOGA_10x64`.
    pub fn variant_label(&self) -> String {
        let arch = match self.arch {
            ArchClass::Mwa => "SPOGA",
            ArchClass::Maw => "HOLYLIGHT",
            ArchClass::Amw => "DEAPCNN",
        };
        format!("{arch}_{}x{}", self.rate.gs(), self.cores)
    }
}

/// A planned artifact: the bit-exact execution plan, the GEMM shape the
/// simulator prices it at, and (for ad-hoc GEMM artifacts, whose B arrives
/// per request) the content-checked packed-B cache.
struct Planned {
    plan: Arc<Plan>,
    shape: GemmShape,
    /// Per-artifact [`PackedB`] cache for [`Plan::Gemm`] (`None` for Linear
    /// plans, which own their packed weights; see
    /// [`crate::runtime::backend`]'s plan-owns-packed-weights contract).
    gemm_b: Option<PackedB>,
}

/// The photonic-in-the-loop execution backend.
pub struct PhotonicBackend {
    cfg: PhotonicConfig,
    sim: SimEngine,
    plans: HashMap<String, Planned>,
    /// Pricing is deterministic per shape; memoized so the serving hot path
    /// (every execute, plus one `report_for` per CNN layer per request)
    /// runs the transaction-level simulator once per distinct shape, not
    /// once per request/group. Bounded at [`REPORT_CACHE_CAP`] entries.
    report_cache: HashMap<(usize, usize, usize, usize), ExecReport>,
    /// Insertion order of `report_cache` keys (FIFO eviction ring).
    report_order: VecDeque<(usize, usize, usize, usize)>,
    /// Reusable activation-side scratch (`wire_to_i8` bytes + planes).
    scratch: ExecScratch,
    channel: Option<AnalogChannel>,
}

impl PhotonicBackend {
    /// Build the backend for a design point (solves the accelerator's link
    /// budget once up front).
    pub fn new(cfg: PhotonicConfig) -> Result<Self> {
        if cfg.cores == 0 {
            return Err(Error::Config("photonic backend needs >= 1 core".into()));
        }
        let accel = Accelerator::equal_cores(cfg.arch, cfg.rate, cfg.cores)?;
        let channel = cfg.noise.map(|p| AnalogChannel::new(p, cfg.noise_seed));
        Ok(PhotonicBackend {
            sim: SimEngine::new(accel),
            plans: HashMap::new(),
            report_cache: HashMap::new(),
            report_order: VecDeque::new(),
            scratch: ExecScratch::default(),
            channel,
            cfg,
        })
    }

    /// Number of memoized shape reports currently held (≤
    /// [`REPORT_CACHE_CAP`]; exposed for capacity tests and telemetry).
    pub fn report_cache_len(&self) -> usize {
        self.report_cache.len()
    }

    /// The simulated accelerator.
    pub fn accelerator(&self) -> &Accelerator {
        &self.sim.accel
    }

    /// Price one GEMM shape on the simulated accelerator (memoized).
    /// Matches [`crate::sim::engine::simulate_frame`] exactly for the same
    /// shape (single-op frame via [`SimEngine::gemm_frame`]), so
    /// coordinator telemetry and offline studies agree to the bit.
    fn simulate_shape(&mut self, shape: &GemmShape) -> ExecReport {
        let key = (shape.t, shape.k, shape.c, shape.groups);
        if let Some(r) = self.report_cache.get(&key) {
            return r.clone();
        }
        let f = self.sim.gemm_frame(shape);
        let r = ExecReport {
            sim_latency_s: f.latency_s,
            energy_j: f.energy.total_j(),
            lanes: shape.outputs(),
            noise_events: 0,
            row_noise: Vec::new(),
        };
        // Bounded memo: evict the oldest distinct shape once at capacity.
        if self.report_cache.len() >= REPORT_CACHE_CAP {
            if let Some(old) = self.report_order.pop_front() {
                self.report_cache.remove(&old);
            }
        }
        self.report_cache.insert(key, r.clone());
        self.report_order.push_back(key);
        r
    }

    /// Exact (noise-off) execution through the prepacked hot path: the
    /// activation wire narrows into the backend scratch, the weight side
    /// streams from the plan-owned / cached [`PackedB`]. Zero weight-side
    /// packing, zero allocation at the working size.
    fn execute_exact(
        &mut self,
        plan: &Plan,
        packed_b: Option<&PackedB>,
        inputs: &[&[i32]],
    ) -> Result<Vec<i32>> {
        let scratch = &mut self.scratch;
        wire_to_i8_into(inputs[0], &mut scratch.a8);
        match plan {
            Plan::Gemm { m, .. } => {
                let pb = packed_b.expect("gemm plans carry a packed B");
                gemm_i32_prepacked(&scratch.a8, pb, *m)
            }
            Plan::Linear { batch, weights, .. } => {
                gemm_i32_prepacked(&scratch.a8, weights, *batch)
            }
        }
    }

    /// Execute through the analog channel: exact three-lane accumulations
    /// from the bitslice engine, transduced output row by output row through
    /// content-keyed sub-streams ([`AnalogChannel::transduce_row`]), PWAB
    /// weighting, rounded to the observed integer.
    ///
    /// Returns the outputs plus per-row noise attribution: `row_noise[r]`
    /// counts the outputs in row `r` whose observed integer diverged from
    /// the exact result (`sum == noise_events`). Because each row's noise
    /// is keyed by the channel seed and the row's exact lane charges —
    /// never by batch position or the sequential stream — a row served
    /// inside a stacked batch and the same row served alone observe
    /// bit-identical noise, which is the backend half of the per-row
    /// attribution contract in [`crate::runtime::backend`].
    ///
    /// `nonce` optionally folds a per-request counter into each row's key
    /// ([`RowNonce`], the time-indexed counter mode): byte-identical rows
    /// under different nonces decorrelate, while nonce 0 (the default every
    /// caller that never opts in gets) leaves the stream bit-identical to
    /// the plain content-keyed path.
    ///
    /// The weight side streams prepacked (`packed_b` for ad-hoc GEMMs, the
    /// plan-owned planes for Linear); only the activation side is sliced,
    /// into the backend scratch. This cannot perturb the noise: the lane
    /// charges are bit-identical to the repack-per-call path (the prepacked
    /// bit-exactness contract), and each row's noise is a pure function of
    /// the channel seed, those exact charges, `k` and the nonce.
    fn execute_noisy(
        &mut self,
        plan: &Plan,
        packed_b: Option<&PackedB>,
        inputs: &[&[i32]],
        nonce: &RowNonce,
    ) -> Result<(Vec<i32>, Vec<u64>)> {
        let scratch = &mut self.scratch;
        wire_to_i8_into(inputs[0], &mut scratch.a8);
        let (lanes, k, rows) = match plan {
            Plan::Gemm { m, k, .. } => {
                scratch.planes.pack_into(&scratch.a8, *m, *k)?;
                let pb = packed_b.expect("gemm plans carry a packed B");
                (gemm_lanes_prepacked(&scratch.planes, pb.planes())?, *k, *m)
            }
            Plan::Linear { batch, features, weights, .. } => {
                scratch.planes.pack_into(&scratch.a8, *batch, *features)?;
                (gemm_lanes_prepacked(&scratch.planes, weights.planes())?, *features, *batch)
            }
        };
        let exact = lanes.weight_and_add();
        let cols = if rows == 0 { 0 } else { exact.len() / rows };
        let ch = self.channel.as_ref().expect("noise channel present");
        let mut out = Vec::with_capacity(exact.len());
        let mut row_noise = vec![0u64; rows];
        for r in 0..rows {
            let span = r * cols..(r + 1) * cols;
            let observed = ch.transduce_row_keyed(
                &lanes.hi[span.clone()],
                &lanes.mid[span.clone()],
                &lanes.lo[span],
                k,
                nonce.for_row(r),
            )?;
            for (j, o) in observed.into_iter().enumerate() {
                let v = o.round() as i32;
                if v != exact[r * cols + j] {
                    row_noise[r] += 1;
                }
                out.push(v);
            }
        }
        Ok((out, row_noise))
    }
}

/// GEMM shape a plan is priced at (Linear plans are row-batched GEMMs).
fn plan_shape(plan: &Plan) -> GemmShape {
    match plan {
        Plan::Gemm { m, k, n } => GemmShape { t: *m, k: *k, c: *n, groups: 1 },
        Plan::Linear { batch, features, outputs, .. } => {
            GemmShape { t: *batch, k: *features, c: *outputs, groups: 1 }
        }
    }
}

impl ExecBackend for PhotonicBackend {
    fn platform(&self) -> String {
        format!(
            "photonic-sim {} ({} cores, {} GS/s{}) over packed-plane GEMM",
            self.cfg.arch.name(),
            self.cfg.cores,
            self.cfg.rate.gs(),
            if self.channel.is_some() { ", noise on" } else { ", noise off" },
        )
    }

    fn plan(&mut self, meta: &ArtifactMeta) -> Result<()> {
        if self.plans.contains_key(&meta.name) {
            return Ok(());
        }
        let plan = Plan::compile(meta)?;
        let shape = plan_shape(&plan);
        self.plans
            .insert(meta.name.clone(), Planned { plan: Arc::new(plan), shape, gemm_b: None });
        Ok(())
    }

    fn execute_i32(&mut self, name: &str, inputs: &[&[i32]]) -> Result<BackendExec> {
        self.execute_i32_keyed(name, inputs, &RowNonce::Content)
    }

    fn execute_i32_keyed(
        &mut self,
        name: &str,
        inputs: &[&[i32]],
        nonce: &RowNonce,
    ) -> Result<BackendExec> {
        let (plan, shape) = {
            let p = self
                .plans
                .get(name)
                .ok_or_else(|| Error::Runtime(format!("{name}: artifact not planned")))?;
            (p.plan.clone(), p.shape)
        };
        let mut report = self.simulate_shape(&shape);
        // Take the artifact's B cache out of the plan map, refresh it against
        // this request's wire content (reuse on match, repack in place on
        // miss), and put it back after the kernels ran against it.
        let gemm_b = match &*plan {
            Plan::Gemm { k, n, .. } => {
                let prev = self.plans.get_mut(name).and_then(|p| p.gemm_b.take());
                Some(PackedB::refresh_wire(prev, inputs[1], *k, *n)?)
            }
            Plan::Linear { .. } => None,
        };
        let result = if self.channel.is_some() {
            self.execute_noisy(&plan, gemm_b.as_ref(), inputs, nonce).map(|(out, row_noise)| {
                report.noise_events = row_noise.iter().sum();
                report.row_noise = row_noise;
                out
            })
        } else {
            self.execute_exact(&plan, gemm_b.as_ref(), inputs)
        };
        if let (Some(pb), Some(entry)) = (gemm_b, self.plans.get_mut(name)) {
            entry.gemm_b = Some(pb);
        }
        Ok(BackendExec { output: result?, report: Some(report) })
    }

    fn report_for(&mut self, shape: &GemmShape) -> Option<ExecReport> {
        Some(self.simulate_shape(shape))
    }

    /// Direct i8 entry for compiled CNN plans: noise off delegates to the
    /// exact prepacked kernel (the trait default), noise on runs the same
    /// lane/transduce flow as [`Self::execute_noisy`] — but the activation
    /// bytes arrive already narrowed (no i32 wire round-trip) and the weight
    /// side streams from the plan's compile-time [`PackedB`]. The lane
    /// charges are bit-identical to the legacy path (same a8 bytes, same
    /// packed planes), and each row's noise is a pure function of the
    /// channel seed, those charges, `k` and the row nonce — so outputs,
    /// `noise_events` and `row_noise` stay bit-for-bit what the wire path
    /// served.
    fn execute_prepacked_i8(
        &mut self,
        a8: &[i8],
        m: usize,
        weights: &PackedB,
        nonce: &RowNonce,
        out: &mut Vec<i32>,
        row_noise: &mut Vec<u64>,
    ) -> Result<()> {
        let Some(ch) = self.channel.as_ref() else {
            row_noise.clear();
            return crate::bitslice::gemm_i32_prepacked_into(a8, weights, m, out);
        };
        let k = weights.rows();
        self.scratch.planes.pack_into(a8, m, k)?;
        let lanes = gemm_lanes_prepacked(&self.scratch.planes, weights.planes())?;
        let exact = lanes.weight_and_add();
        let cols = if m == 0 { 0 } else { exact.len() / m };
        out.clear();
        out.reserve(exact.len());
        row_noise.clear();
        row_noise.resize(m, 0);
        for r in 0..m {
            let span = r * cols..(r + 1) * cols;
            let observed = ch.transduce_row_keyed(
                &lanes.hi[span.clone()],
                &lanes.mid[span.clone()],
                &lanes.lo[span],
                k,
                nonce.for_row(r),
            )?;
            for (j, o) in observed.into_iter().enumerate() {
                let v = o.round() as i32;
                if v != exact[r * cols + j] {
                    row_noise[r] += 1;
                }
                out.push(v);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Manifest;
    use crate::runtime::software::SoftwareBackend;
    use crate::testing::SplitMix64;
    use std::path::PathBuf;

    fn meta(line: &str) -> ArtifactMeta {
        Manifest::parse(line, PathBuf::from("/tmp")).unwrap().artifacts[0].clone()
    }

    fn wire(rng: &mut SplitMix64, len: usize) -> Vec<i32> {
        (0..len).map(|_| rng.i8() as i32).collect()
    }

    #[test]
    fn bit_identical_to_software_backend() {
        let gemm = meta("gemm_8x8x8 g i32:8x8,i32:8x8 i32:8x8");
        let mlp = meta("mlp_b4 m i32:4x16 i32:4x4");
        let mut sw = SoftwareBackend::new();
        let mut ph = PhotonicBackend::new(PhotonicConfig::spoga()).unwrap();
        for b in [&gemm, &mlp] {
            sw.plan(b).unwrap();
            ph.plan(b).unwrap();
        }
        let mut rng = SplitMix64::new(77);
        let (a, b) = (wire(&mut rng, 64), wire(&mut rng, 64));
        let g_sw = sw.execute_i32("gemm_8x8x8", &[&a, &b]).unwrap();
        let g_ph = ph.execute_i32("gemm_8x8x8", &[&a, &b]).unwrap();
        assert_eq!(g_sw.output, g_ph.output);
        assert!(g_sw.report.is_none());
        let r = g_ph.report.unwrap();
        assert!(r.sim_latency_s > 0.0 && r.energy_j > 0.0);
        assert_eq!((r.lanes, r.noise_events), (64, 0));

        let rows = wire(&mut rng, 4 * 16);
        let m_sw = sw.execute_i32("mlp_b4", &[&rows]).unwrap();
        let m_ph = ph.execute_i32("mlp_b4", &[&rows]).unwrap();
        assert_eq!(m_sw.output, m_ph.output);
    }

    #[test]
    fn telemetry_matches_simulate_frame() {
        use crate::dnn::workload::{GemmOp, Workload};
        let mut ph = PhotonicBackend::new(PhotonicConfig::spoga()).unwrap();
        let shape = GemmShape { t: 64, k: 147, c: 64, groups: 1 };
        let r = ph.report_for(&shape).unwrap();
        let accel =
            Accelerator::equal_cores(ArchClass::Mwa, DataRate::Gs10, crate::metrics::FIG5_CORES)
                .unwrap();
        let w = Workload {
            model: "x".into(),
            ops: vec![GemmOp { layer: "x".into(), shape }],
        };
        let f = crate::sim::engine::simulate_frame(&accel, &w);
        assert_eq!(r.sim_latency_s, f.latency_s);
        assert_eq!(r.energy_j, f.energy.total_j());
    }

    #[test]
    fn baselines_cost_more_energy_per_request() {
        let gemm = meta("gemm_16x64x16 g i32:16x64,i32:64x16 i32:16x16");
        let mut spoga = PhotonicBackend::new(PhotonicConfig::spoga()).unwrap();
        let mut holy = PhotonicBackend::new(PhotonicConfig::holylight()).unwrap();
        spoga.plan(&gemm).unwrap();
        holy.plan(&gemm).unwrap();
        let mut rng = SplitMix64::new(5);
        let a = wire(&mut rng, 16 * 64);
        let b = wire(&mut rng, 64 * 16);
        let rs = spoga.execute_i32("gemm_16x64x16", &[&a, &b]).unwrap().report.unwrap();
        let rh = holy.execute_i32("gemm_16x64x16", &[&a, &b]).unwrap().report.unwrap();
        assert!(rh.energy_j > rs.energy_j, "HOLYLIGHT {} vs SPOGA {}", rh.energy_j, rs.energy_j);
    }

    #[test]
    fn noise_injection_perturbs_outputs_deterministically() {
        let gemm = meta("gemm_8x8x8 g i32:8x8,i32:8x8 i32:8x8");
        let cfg = PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), 11);
        let mut noisy = PhotonicBackend::new(cfg.clone()).unwrap();
        let mut noisy2 = PhotonicBackend::new(cfg).unwrap();
        let mut exact = PhotonicBackend::new(PhotonicConfig::spoga()).unwrap();
        for b in [&mut noisy, &mut noisy2, &mut exact] {
            b.plan(&gemm).unwrap();
        }
        let mut rng = SplitMix64::new(13);
        let (a, b) = (wire(&mut rng, 64), wire(&mut rng, 64));
        let rn = noisy.execute_i32("gemm_8x8x8", &[&a, &b]).unwrap();
        let rn2 = noisy2.execute_i32("gemm_8x8x8", &[&a, &b]).unwrap();
        let re = exact.execute_i32("gemm_8x8x8", &[&a, &b]).unwrap();
        // 24 dB SNR on a K=8 dot product is loud: divergence is certain.
        let rep = rn.report.unwrap();
        assert!(rep.noise_events > 0);
        assert_ne!(rn.output, re.output);
        // Per-row attribution: one entry per output row, summing to the
        // scalar total, matching the observed per-row divergences.
        assert_eq!(rep.row_noise.len(), 8);
        assert_eq!(rep.row_noise.iter().sum::<u64>(), rep.noise_events);
        for r in 0..8 {
            let mism = (0..8)
                .filter(|&j| rn.output[r * 8 + j] != re.output[r * 8 + j])
                .count() as u64;
            assert_eq!(rep.row_noise[r], mism, "row {r} attribution");
        }
        // Same seed, same content-keyed streams, same observations.
        assert_eq!(rn.output, rn2.output);
        let re_rep = re.report.unwrap();
        assert_eq!(re_rep.noise_events, 0);
        assert!(re_rep.row_noise.is_empty(), "noise off reports no row attribution");
    }

    #[test]
    fn nonced_executes_decorrelate_duplicate_rows_deterministically() {
        // Two byte-identical rows in one GEMM: the content-keyed default
        // observes identical noise (perfect correlation), while distinct
        // per-row nonces decorrelate them — each still fully deterministic.
        let gemm = meta("gemm_2x8x8 g i32:2x8,i32:8x8 i32:2x8");
        let cfg = PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), 31);
        let mut noisy = PhotonicBackend::new(cfg).unwrap();
        noisy.plan(&gemm).unwrap();
        let mut rng = SplitMix64::new(9);
        let row: Vec<i32> = wire(&mut rng, 8);
        let mut a = row.clone();
        a.extend_from_slice(&row); // rows 0 and 1 byte-identical
        let b = wire(&mut rng, 64);

        let plain = noisy.execute_i32("gemm_2x8x8", &[&a, &b]).unwrap();
        assert_eq!(
            plain.output[..8],
            plain.output[8..],
            "content keying must correlate byte-identical rows"
        );
        // Keyed with nonce 0 per row == the plain path, bit for bit.
        let zeroed = noisy
            .execute_i32_keyed("gemm_2x8x8", &[&a, &b], &RowNonce::PerRow(vec![0, 0]))
            .unwrap();
        assert_eq!(zeroed.output, plain.output);

        let nonced = noisy
            .execute_i32_keyed("gemm_2x8x8", &[&a, &b], &RowNonce::PerRow(vec![1, 2]))
            .unwrap();
        assert_ne!(
            nonced.output[..8],
            nonced.output[8..],
            "distinct nonces must decorrelate duplicate rows"
        );
        // Same nonces → same draws, and equal nonces re-correlate.
        let again = noisy
            .execute_i32_keyed("gemm_2x8x8", &[&a, &b], &RowNonce::PerRow(vec![1, 2]))
            .unwrap();
        assert_eq!(nonced.output, again.output);
        let same = noisy
            .execute_i32_keyed("gemm_2x8x8", &[&a, &b], &RowNonce::PerRow(vec![5, 5]))
            .unwrap();
        assert_eq!(same.output[..8], same.output[8..]);
        // The per-row attribution contract survives the keyed path.
        let rep = nonced.report.unwrap();
        assert_eq!(rep.row_noise.len(), 2);
        assert_eq!(rep.row_noise.iter().sum::<u64>(), rep.noise_events);
    }

    #[test]
    fn report_cache_is_bounded_with_fifo_eviction() {
        let mut ph = PhotonicBackend::new(PhotonicConfig::spoga()).unwrap();
        for t in 1..=REPORT_CACHE_CAP + 10 {
            ph.report_for(&GemmShape { t, k: 4, c: 4, groups: 1 }).unwrap();
            assert!(ph.report_cache_len() <= REPORT_CACHE_CAP);
        }
        assert_eq!(ph.report_cache_len(), REPORT_CACHE_CAP);
        // A cached shape hits the memo without inserting.
        ph.report_for(&GemmShape { t: REPORT_CACHE_CAP + 10, k: 4, c: 4, groups: 1 }).unwrap();
        assert_eq!(ph.report_cache_len(), REPORT_CACHE_CAP);
        // The oldest shape was evicted; re-pricing it re-inserts at the cap
        // and stays bit-identical (pricing is deterministic per shape).
        let again = ph.report_for(&GemmShape { t: 1, k: 4, c: 4, groups: 1 }).unwrap();
        assert_eq!(ph.report_cache_len(), REPORT_CACHE_CAP);
        let mut fresh = PhotonicBackend::new(PhotonicConfig::spoga()).unwrap();
        let first = fresh.report_for(&GemmShape { t: 1, k: 4, c: 4, groups: 1 }).unwrap();
        assert_eq!(again.sim_latency_s, first.sim_latency_s);
        assert_eq!(again.energy_j, first.energy_j);
    }

    #[test]
    fn adhoc_gemm_b_cache_survives_interleaved_artifacts() {
        let gemm = meta("gemm_8x8x8 g i32:8x8,i32:8x8 i32:8x8");
        let mut ph = PhotonicBackend::new(PhotonicConfig::spoga()).unwrap();
        ph.plan(&gemm).unwrap();
        let mut rng = SplitMix64::new(41);
        let (a, b) = (wire(&mut rng, 64), wire(&mut rng, 64));
        let first = ph.execute_i32("gemm_8x8x8", &[&a, &b]).unwrap();
        assert!(ph.plans["gemm_8x8x8"].gemm_b.as_ref().unwrap().matches_wire(&b));
        // Repeat B: cache hit, bit-identical output.
        let hit = ph.execute_i32("gemm_8x8x8", &[&a, &b]).unwrap();
        assert_eq!(first.output, hit.output);
        // Different B: refresh, then the original B repacks bit-identically.
        let b2 = wire(&mut rng, 64);
        ph.execute_i32("gemm_8x8x8", &[&a, &b2]).unwrap();
        assert!(ph.plans["gemm_8x8x8"].gemm_b.as_ref().unwrap().matches_wire(&b2));
        let back = ph.execute_i32("gemm_8x8x8", &[&a, &b]).unwrap();
        assert_eq!(first.output, back.output);
    }

    #[test]
    fn prepacked_i8_entry_matches_wire_path_under_noise() {
        // The compiled-CNN entry skips the i32 wire round-trip; with the
        // same activation bytes, packed weights and nonces it must observe
        // bit-identical noise to the legacy keyed path (same lane charges,
        // same content-keyed sub-streams).
        let gemm = meta("gemm_4x8x8 g i32:4x8,i32:8x8 i32:4x8");
        let cfg = PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), 17);
        let mut noisy = PhotonicBackend::new(cfg).unwrap();
        noisy.plan(&gemm).unwrap();
        let mut rng = SplitMix64::new(23);
        let (a, b) = (wire(&mut rng, 32), wire(&mut rng, 64));
        let nonce = RowNonce::PerRow(vec![7, 0, 9, 3]);
        let wire_exec = noisy.execute_i32_keyed("gemm_4x8x8", &[&a, &b], &nonce).unwrap();
        let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
        let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
        let pb = crate::bitslice::pack_b(&b8, 8, 8).unwrap();
        // Dirty reusable buffers: the entry must fully overwrite them.
        let mut out = vec![i32::MIN; 3];
        let mut rn = vec![u64::MAX; 1];
        noisy.execute_prepacked_i8(&a8, 4, &pb, &nonce, &mut out, &mut rn).unwrap();
        assert_eq!(out, wire_exec.output);
        let rep = wire_exec.report.unwrap();
        assert_eq!(rn, rep.row_noise);
        assert_eq!(rn.iter().sum::<u64>(), rep.noise_events);
    }

    #[test]
    fn noisy_executes_are_order_independent_and_repeatable() {
        // Content-keyed sub-streams: re-executing the same request on the
        // same backend observes the same noise (no sequential stream is
        // consumed), and interleaving other traffic does not perturb it.
        let gemm = meta("gemm_8x8x8 g i32:8x8,i32:8x8 i32:8x8");
        let cfg = PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), 21);
        let mut noisy = PhotonicBackend::new(cfg).unwrap();
        noisy.plan(&gemm).unwrap();
        let mut rng = SplitMix64::new(4);
        let (a, b) = (wire(&mut rng, 64), wire(&mut rng, 64));
        let first = noisy.execute_i32("gemm_8x8x8", &[&a, &b]).unwrap();
        let (oa, ob) = (wire(&mut rng, 64), wire(&mut rng, 64));
        let _ = noisy.execute_i32("gemm_8x8x8", &[&oa, &ob]).unwrap();
        let again = noisy.execute_i32("gemm_8x8x8", &[&a, &b]).unwrap();
        assert_eq!(first.output, again.output);
        assert_eq!(first.report.unwrap().row_noise, again.report.unwrap().row_noise);
    }
}
