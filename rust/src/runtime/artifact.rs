//! Artifact manifest parsing.
//!
//! `artifacts/manifest.txt` is line-oriented (no serde in the vendored dep
//! set): `name file in0,in1,... out0,...` where a tensor spec is
//! `dtype:dim x dim x ...`, e.g. `i32:64x64`.

use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Element type of a tensor at the artifact boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit signed integer (the wire format for all SPOGA artifacts).
    I32,
    /// 32-bit float (reserved; not currently emitted).
    F32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "i32" => Ok(DType::I32),
            "f32" => Ok(DType::F32),
            other => Err(Error::Artifact(format!("unknown dtype {other:?}"))),
        }
    }
}

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Element type.
    pub dtype: DType,
    /// Dimensions (row-major).
    pub dims: Vec<usize>,
}

impl TensorSpec {
    fn parse(s: &str) -> Result<Self> {
        let (dt, dims) = s
            .split_once(':')
            .ok_or_else(|| Error::Artifact(format!("bad tensor spec {s:?}")))?;
        let dims = dims
            .split('x')
            .map(|d| {
                d.parse::<usize>()
                    .map_err(|_| Error::Artifact(format!("bad dim in {s:?}")))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { dtype: DType::parse(dt)?, dims })
    }

    /// Total element count.
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }

    /// Leading (batch) dimension, if any.
    pub fn batch(&self) -> usize {
        self.dims.first().copied().unwrap_or(1)
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Artifact name (e.g. "gemm_64x64x64", "mlp_b8").
    pub name: String,
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Input tensor specs, positional.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs (all current artifacts have exactly one).
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the artifacts live in.
    pub dir: PathBuf,
    /// All artifacts, manifest order.
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `dir/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (did you run `make artifacts`?): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.len() != 4 {
                return Err(Error::Artifact(format!(
                    "manifest line {}: expected 4 fields, got {}",
                    lineno + 1,
                    fields.len()
                )));
            }
            let parse_specs = |s: &str| -> Result<Vec<TensorSpec>> {
                s.split(',').map(TensorSpec::parse).collect()
            };
            artifacts.push(ArtifactMeta {
                name: fields[0].to_string(),
                file: fields[1].to_string(),
                inputs: parse_specs(fields[2])?,
                outputs: parse_specs(fields[3])?,
            });
        }
        if artifacts.is_empty() {
            return Err(Error::Artifact("manifest has no artifacts".into()));
        }
        Ok(Manifest { dir, artifacts })
    }

    /// Find an artifact by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {name:?}")))
    }

    /// Absolute path to an artifact's HLO file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// All MLP batch variants (name, batch), ascending by batch — used by
    /// the coordinator's dynamic batcher.
    pub fn mlp_batch_variants(&self) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = self
            .artifacts
            .iter()
            .filter(|a| a.name.starts_with("mlp_b"))
            .map(|a| (a.name.clone(), a.inputs[0].batch()))
            .collect();
        v.sort_by_key(|(_, b)| *b);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
gemm_64x64x64 gemm_64x64x64.hlo.txt i32:64x64,i32:64x64 i32:64x64
mlp_b1 mlp_b1.hlo.txt i32:1x784 i32:1x10
mlp_b8 mlp_b8.hlo.txt i32:8x784 i32:8x10
";

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let g = m.get("gemm_64x64x64").unwrap();
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[0].dims, vec![64, 64]);
        assert_eq!(g.outputs[0].elements(), 64 * 64);
    }

    #[test]
    fn unknown_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(Manifest::parse("just two fields", PathBuf::new()).is_err());
        assert!(Manifest::parse("a b c:notadim d", PathBuf::new()).is_err());
        assert!(Manifest::parse("a b q99:1 i32:1", PathBuf::new()).is_err());
        assert!(Manifest::parse("", PathBuf::new()).is_err());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let text = format!("# header\n\n{SAMPLE}");
        let m = Manifest::parse(&text, PathBuf::new()).unwrap();
        assert_eq!(m.artifacts.len(), 3);
    }

    #[test]
    fn mlp_variants_sorted_by_batch() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let v = m.mlp_batch_variants();
        assert_eq!(v, vec![("mlp_b1".into(), 1), ("mlp_b8".into(), 8)]);
    }

    #[test]
    fn tensor_spec_parsing() {
        let t = TensorSpec::parse("i32:2x3x4").unwrap();
        assert_eq!(t.dims, vec![2, 3, 4]);
        assert_eq!(t.elements(), 24);
        assert_eq!(t.batch(), 2);
        assert!(TensorSpec::parse("i32").is_err());
    }
}
