//! Pluggable execution backends: the `ExecBackend` trait and its registry.
//!
//! The engine/coordinator stack is backend-agnostic: an [`crate::runtime::Engine`]
//! owns a `Box<dyn ExecBackend>` chosen by [`BackendKind`], and everything
//! above it (workers, leader, handles) only sees the trait. Two backends
//! ship in-tree:
//!
//! * [`crate::runtime::software::SoftwareBackend`] — the packed bit-sliced
//!   GEMM interpreter (bit-exact golden-model arithmetic, no telemetry).
//! * [`crate::runtime::photonic::PhotonicBackend`] — same bit-exact
//!   arithmetic, but every execute also runs the artifact's GEMM shape
//!   through the transaction-level photonic simulator
//!   ([`crate::sim::SimEngine`] + [`crate::arch::cost`]) and reports an
//!   [`ExecReport`] (projected latency, energy, lane count), with optional
//!   [`crate::fidelity`] noise injection for photonic-in-the-loop serving.
//!
//! The trait is deliberately narrow (`plan` / `execute_i32` / `platform`,
//! plus the defaulted `execute_i32_keyed` / `execute_prepacked_i8` hot-path
//! entries and the optional `report_for` telemetry hook) so a future PJRT
//! backend (the `xla` crate compiling HLO text) can slot in behind a cargo
//! feature without touching the serving stack.
//!
//! ## Plan-owns-packed-weights contract
//!
//! `plan` is compile-once and `execute_i32` is the per-request hot path, so
//! backends split bit-slice packing accordingly (pack-once / stream-many,
//! [`crate::bitslice`]'s prepacked API):
//!
//! * **Weight-stationary plans** (`Linear`) pack their weight operand into a
//!   [`crate::bitslice::PackedB`] at `plan` time. Per-request work performs
//!   **zero weight-side packing** — only the activation operand is narrowed
//!   and (where a plane kernel runs) sliced, into a backend-owned scratch
//!   reused across requests, so the steady-state hot path performs zero
//!   heap allocation.
//! * **Ad-hoc GEMM plans** receive B per request, but B almost always
//!   repeats; backends keep a per-artifact `PackedB` cache in the plan map,
//!   refreshed by full content equality
//!   ([`crate::bitslice::PackedB::refresh_wire`]) — never a hash key, which
//!   could collide and silently serve a stale B.
//! * **CNN plans** ([`crate::runtime::cnnrun::CnnPlan`]) extend the same
//!   split to whole models: `CnnPlan::compile` packs every layer's weight
//!   matrix — one `PackedB` per conv group, one per FC layer — once per
//!   (model, engine), and the engine caches the plan by model name,
//!   revalidated by full model equality (`CnnModel: PartialEq`, the CNN
//!   analogue of `refresh_wire`'s never-hash rule). Per-frame work then
//!   runs [`ExecBackend::execute_prepacked_i8`]: activations lower via
//!   `im2col_group_into` straight into a persistent
//!   [`crate::runtime::cnnrun::CnnScratch`] arena (stacked `(B·t)×k` i8
//!   planes, ping-ponged activation/raw buffers, reused output and
//!   row-noise vectors), skipping the i32 wire round-trip, surrogate weight
//!   regeneration, and per-plan content revalidation the artifact path
//!   pays. Steady-state conv serving therefore performs **zero per-request
//!   heap allocation and zero weight re-derivation**; only result
//!   materialization (returned logits and per-layer reports) allocates.
//!   Compile/execute/scratch lifecycle: plans are immutable after compile
//!   and shared via `Arc`; the scratch arena lives on the engine and is
//!   exclusive to one serving call at a time (`&mut`); dropping the engine
//!   drops both.
//!
//! Packing placement is invisible to results: prepacked execution is
//! bit-identical to repack-per-call (property-tested in
//! `tests/prepacked.rs`, and `tests/cnn_plan.rs` for whole-model plans),
//! and under noise injection the content-keyed per-row streams depend only
//! on the exact lane charges, which prepacking preserves bit-for-bit.
//!
//! ## Per-row noise attribution contract
//!
//! When a backend injects analog noise, its [`ExecReport`] carries
//! `row_noise`: one entry per *output row* of the executed GEMM (`m` for a
//! two-operand GEMM plan, `batch` for a row-wise linear plan), counting the
//! outputs in that row whose analog-observed integer diverged from the
//! exact result. Three invariants define the contract:
//!
//! 1. `row_noise.iter().sum::<u64>() == noise_events` — the scalar total is
//!    always the sum of the per-row attribution (both are zero, and
//!    `row_noise` empty, when noise injection is off).
//! 2. **Order independence**: a row's noise is a deterministic function of
//!    the channel seed and the row's exact lane charges
//!    ([`crate::fidelity::AnalogChannel::transduce_row`] draws a
//!    content-keyed sub-stream per row), never of its position in a batch
//!    or of co-batched traffic. Serving a row inside a stacked batch and
//!    serving it alone produce bit-identical outputs and events.
//! 3. **Sliceability**: consumers may therefore cut `row_noise` along any
//!    row boundary and re-attribute exactly — the MLP batcher hands member
//!    `i` row `i`'s events ([`ExecReport::for_row`]), and the CNN runtime
//!    slices a stacked `(B·t)×k` execute back into per-frame
//!    [`crate::runtime::cnnrun::LayerReport`]s. This is what lets the
//!    coordinator keep dynamic batching enabled under noise injection with
//!    exact per-request attribution.

use crate::dnn::layer::GemmShape;
use crate::runtime::artifact::ArtifactMeta;
use crate::Result;

/// Per-request photonic telemetry attached to an execution.
///
/// Produced by backends that model the photonic datapath; the software
/// interpreter reports `None`. All fields are per-execute (one artifact
/// invocation); aggregate with [`ExecReport::merge`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExecReport {
    /// Projected latency of this execution on the simulated accelerator,
    /// seconds (transaction-level model, not wall clock).
    pub sim_latency_s: f64,
    /// Projected energy of this execution, joules.
    pub energy_j: f64,
    /// Analog dot-product lanes transduced (outputs computed optically) —
    /// each one costs the architecture its O/E + ADC conversion chain.
    pub lanes: u64,
    /// Outputs whose analog-observed value differed from the exact integer
    /// result (0 unless noise injection is enabled).
    pub noise_events: u64,
    /// Per-output-row noise attribution: `row_noise[r]` counts the noise
    /// events in output row `r` of the executed GEMM. Empty when noise
    /// injection is off; otherwise `sum == noise_events` and entries are
    /// order-independent (see the module docs' per-row contract), so
    /// consumers can slice along row boundaries for exact per-request /
    /// per-frame attribution.
    pub row_noise: Vec<u64>,
}

impl ExecReport {
    /// Component-wise accumulate (latencies add: layers execute serially).
    ///
    /// `row_noise` vectors of unequal length reconcile by zero-padding the
    /// shorter side — merging reports of different row counts is legal
    /// (a CNN aggregate folds conv layers of different output heights;
    /// row `r` of the merged vector accumulates row `r` of every merged
    /// execute, and executes with fewer rows contribute zero there).
    pub fn merge(&mut self, other: &ExecReport) {
        self.sim_latency_s += other.sim_latency_s;
        self.energy_j += other.energy_j;
        self.lanes += other.lanes;
        self.noise_events += other.noise_events;
        if self.row_noise.len() < other.row_noise.len() {
            self.row_noise.resize(other.row_noise.len(), 0);
        }
        for (dst, src) in self.row_noise.iter_mut().zip(&other.row_noise) {
            *dst += src;
        }
    }

    /// The *stats* view of a padded batch execute: when per-row attribution
    /// is present, keep only the first `rows` (member) rows' noise and
    /// price `lanes` as `rows × lanes_per_row` — padding rows beyond the
    /// members were never served to any request, so folding their events
    /// into serving stats would report noise no caller observed and skew
    /// `served_exact_fraction` below what any reply carried. Reports
    /// without attribution return unchanged.
    pub fn served_rows(&self, rows: usize, lanes_per_row: u64) -> ExecReport {
        if self.row_noise.is_empty() {
            return self.clone();
        }
        let kept: Vec<u64> = self.row_noise.iter().take(rows).copied().collect();
        ExecReport {
            sim_latency_s: self.sim_latency_s,
            energy_j: self.energy_j,
            lanes: lanes_per_row * rows as u64,
            noise_events: kept.iter().sum(),
            row_noise: kept,
        }
    }

    /// The member view of output row `row` of a batched execute: when
    /// per-row attribution is present, the member carries its own row's
    /// noise events and its own `lanes_per_row` lane count (the projected
    /// latency/energy stay the whole batch's — the batch executed as one
    /// artifact invocation and its cost is not row-separable). Without
    /// per-row attribution (noise off) the batch report is shared
    /// unchanged, preserving the historical reply shape.
    pub fn for_row(&self, row: usize, lanes_per_row: u64) -> ExecReport {
        if self.row_noise.is_empty() {
            return self.clone();
        }
        let events = self.row_noise.get(row).copied().unwrap_or(0);
        ExecReport {
            sim_latency_s: self.sim_latency_s,
            energy_j: self.energy_j,
            lanes: lanes_per_row,
            noise_events: events,
            row_noise: vec![events],
        }
    }
}

/// Per-output-row noise nonces for one execution — the serving side of the
/// time-indexed counter mode ([`crate::fidelity::AnalogChannel::transduce_row_keyed`]).
///
/// The default [`RowNonce::Content`] keys every row's noise by content
/// alone (byte-identical rows correlate perfectly, which is what makes
/// attribution order-independent); a nonzero nonce additionally folds a
/// per-request counter into the key, decorrelating duplicate rows while
/// keeping each `(seed, content, nonce)` draw deterministic. Rows without
/// an assigned nonce (padding, out-of-range) fall back to nonce `0`, i.e.
/// the content-keyed stream — so default-off serving is bit-identical to
/// the historical path.
#[derive(Debug, Clone, Default, PartialEq)]
pub enum RowNonce {
    /// Pure content keying (nonce 0 for every row) — the default.
    #[default]
    Content,
    /// One request owns every output row (unbatched GEMM jobs).
    Request(u64),
    /// Row `r` carries `nonces[r]` (micro-batches mixing requests); rows
    /// beyond the vector fall back to 0 (padding rows).
    PerRow(Vec<u64>),
}

impl RowNonce {
    /// The nonce for output row `r`. Nonce 0 keys the row by content
    /// alone, at identical cost to a nonzero key — so backends need no
    /// separate unkeyed fast path.
    pub fn for_row(&self, r: usize) -> u64 {
        match self {
            RowNonce::Content => 0,
            RowNonce::Request(n) => *n,
            RowNonce::PerRow(v) => v.get(r).copied().unwrap_or(0),
        }
    }
}

/// Result of one backend execution: the output buffer plus telemetry (if
/// the backend models the photonic datapath).
#[derive(Debug, Clone)]
pub struct BackendExec {
    /// Flat row-major int32 output (single-output artifacts).
    pub output: Vec<i32>,
    /// Photonic telemetry, `None` for purely digital backends.
    pub report: Option<ExecReport>,
}

/// An execution backend: plans artifacts once, executes them many times.
///
/// Implementations own their plan cache (keyed by artifact name); `Send`
/// because each coordinator worker constructs its engine — and therefore
/// its backend — inside the worker thread, and hands work across threads.
pub trait ExecBackend: Send {
    /// Backend name for diagnostics (`Engine::platform`).
    fn platform(&self) -> String;

    /// Compile `meta` into an execution plan (idempotent; cached by name).
    fn plan(&mut self, meta: &ArtifactMeta) -> Result<()>;

    /// Execute a previously planned artifact with positional int32 inputs.
    /// Element counts are validated by the engine against the manifest
    /// before this is called.
    fn execute_i32(&mut self, name: &str, inputs: &[&[i32]]) -> Result<BackendExec>;

    /// [`ExecBackend::execute_i32`] with per-output-row noise nonces
    /// ([`RowNonce`]) for backends that inject analog noise. Digital
    /// backends (and noise-off photonic backends) ignore the nonces — the
    /// default implementation simply executes — so only noise-injecting
    /// backends need to override.
    fn execute_i32_keyed(
        &mut self,
        name: &str,
        inputs: &[&[i32]],
        nonce: &RowNonce,
    ) -> Result<BackendExec> {
        let _ = nonce;
        self.execute_i32(name, inputs)
    }

    /// Direct prepacked-i8 execution — the CNN plan hot path. Computes
    /// `out = a8 · weights` (`a8` row-major `m×k`, `k`/`n` from the pack)
    /// into the caller's reused buffers, skipping the artifact machinery:
    /// no plan lookup, no i32 wire narrowing, no weight revalidation.
    ///
    /// `out` is cleared and resized to `m·n`; `row_noise` is cleared and,
    /// when the backend injects noise, filled with one entry per output row
    /// under the module-level per-row attribution contract (nonces resolve
    /// via [`RowNonce::for_row`], exactly as `execute_i32_keyed`). The
    /// default implementation is the exact digital path (empty `row_noise`),
    /// which is also what noise-off photonic serving runs — bit-identical
    /// across backends by the bitslice dispatch contract.
    fn execute_prepacked_i8(
        &mut self,
        a8: &[i8],
        m: usize,
        weights: &crate::bitslice::PackedB,
        nonce: &RowNonce,
        out: &mut Vec<i32>,
        row_noise: &mut Vec<u64>,
    ) -> Result<()> {
        let _ = nonce;
        row_noise.clear();
        crate::bitslice::gemm_i32_prepacked_into(a8, weights, m, out)
    }

    /// Telemetry for a GEMM shape *without* executing it — used by the CNN
    /// serving path to report per-layer projections that include conv
    /// groups. Digital backends return `None`.
    fn report_for(&mut self, shape: &GemmShape) -> Option<ExecReport> {
        let _ = shape;
        None
    }
}

/// Which backend an [`crate::runtime::Engine`] (and therefore a whole
/// coordinator worker pool) executes through. Carried by
/// [`crate::coordinator::CoordinatorConfig`].
#[derive(Debug, Clone, Default)]
pub enum BackendKind {
    /// Packed bit-sliced GEMM interpreter (digital, no telemetry).
    #[default]
    Software,
    /// Bit-exact execution plus photonic-in-the-loop simulation telemetry.
    Photonic(crate::runtime::photonic::PhotonicConfig),
}

impl BackendKind {
    /// Construct the backend this kind names.
    pub fn build(&self) -> Result<Box<dyn ExecBackend>> {
        match self {
            BackendKind::Software => {
                Ok(Box::new(crate::runtime::software::SoftwareBackend::new()))
            }
            BackendKind::Photonic(cfg) => Ok(Box::new(
                crate::runtime::photonic::PhotonicBackend::new(cfg.clone())?,
            )),
        }
    }

    /// Short label for tables and stats lines.
    pub fn label(&self) -> String {
        match self {
            BackendKind::Software => "software".to_string(),
            BackendKind::Photonic(cfg) => format!("photonic:{}", cfg.variant_label()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_report_merges_componentwise() {
        let mut a = ExecReport {
            sim_latency_s: 1.0,
            energy_j: 2.0,
            lanes: 3,
            noise_events: 1,
            row_noise: vec![1, 0],
        };
        let b = ExecReport {
            sim_latency_s: 0.5,
            energy_j: 0.25,
            lanes: 7,
            noise_events: 2,
            row_noise: vec![0, 2],
        };
        a.merge(&b);
        assert_eq!(
            a,
            ExecReport {
                sim_latency_s: 1.5,
                energy_j: 2.25,
                lanes: 10,
                noise_events: 3,
                row_noise: vec![1, 2],
            }
        );
    }

    #[test]
    fn merge_reconciles_unequal_row_noise_lengths_by_padding() {
        // Short += long: the short side grows with zeros, never panics.
        let mut short = ExecReport { row_noise: vec![5], noise_events: 5, ..Default::default() };
        let long = ExecReport {
            row_noise: vec![1, 2, 3],
            noise_events: 6,
            ..Default::default()
        };
        short.merge(&long);
        assert_eq!(short.row_noise, vec![6, 2, 3]);
        assert_eq!(short.noise_events, 11);
        assert_eq!(
            short.row_noise.iter().sum::<u64>(),
            short.noise_events,
            "sum(row_noise) == noise_events must survive merging"
        );

        // Long += short: the extra rows are untouched.
        let mut long2 = ExecReport {
            row_noise: vec![1, 2, 3],
            noise_events: 6,
            ..Default::default()
        };
        long2.merge(&ExecReport { row_noise: vec![4], noise_events: 4, ..Default::default() });
        assert_eq!(long2.row_noise, vec![5, 2, 3]);
        assert_eq!(long2.noise_events, 10);

        // Either side empty (noise off) is a no-op on the vector.
        let mut empty = ExecReport::default();
        empty.merge(&ExecReport { row_noise: vec![7, 7], noise_events: 14, ..Default::default() });
        assert_eq!(empty.row_noise, vec![7, 7]);
        let mut kept = ExecReport { row_noise: vec![9], noise_events: 9, ..Default::default() };
        kept.merge(&ExecReport::default());
        assert_eq!(kept.row_noise, vec![9]);
    }

    #[test]
    fn for_row_slices_attribution_or_shares_the_batch_report() {
        let batch = ExecReport {
            sim_latency_s: 2.0,
            energy_j: 4.0,
            lanes: 12,
            noise_events: 5,
            row_noise: vec![3, 0, 2],
        };
        let m1 = batch.for_row(0, 4);
        assert_eq!((m1.lanes, m1.noise_events), (4, 3));
        assert_eq!(m1.row_noise, vec![3]);
        // Projected cost is the whole batch's (not row-separable).
        assert_eq!((m1.sim_latency_s, m1.energy_j), (2.0, 4.0));
        let m2 = batch.for_row(2, 4);
        assert_eq!((m2.noise_events, m2.row_noise.clone()), (2, vec![2]));
        // Out-of-range rows (padding beyond attribution) carry zero events.
        assert_eq!(batch.for_row(9, 4).noise_events, 0);

        // Noise off: members share the batch report unchanged.
        let exact = ExecReport { sim_latency_s: 1.0, lanes: 12, ..Default::default() };
        assert_eq!(exact.for_row(1, 4), exact);
    }

    #[test]
    fn served_rows_trims_padding_attribution_from_stats() {
        // 2 member rows + 2 noisy padding rows in a 4-row batch.
        let batch = ExecReport {
            sim_latency_s: 2.0,
            energy_j: 4.0,
            lanes: 16,
            noise_events: 9,
            row_noise: vec![3, 1, 4, 1],
        };
        let served = batch.served_rows(2, 4);
        assert_eq!((served.lanes, served.noise_events), (8, 4));
        assert_eq!(served.row_noise, vec![3, 1]);
        // The trimmed view keeps the sum identity and equals the sum of the
        // member `for_row` views — what the replies actually carried.
        assert_eq!(served.row_noise.iter().sum::<u64>(), served.noise_events);
        let member_sum: u64 =
            (0..2).map(|i| batch.for_row(i, 4).noise_events).sum();
        assert_eq!(served.noise_events, member_sum);
        // `rows` beyond the attribution length just keeps everything.
        assert_eq!(batch.served_rows(9, 4).noise_events, 9);
        // Noise off: unchanged (padding cannot diverge).
        let exact = ExecReport { lanes: 16, ..Default::default() };
        assert_eq!(exact.served_rows(2, 4), exact);
    }

    #[test]
    fn row_nonce_resolution() {
        assert_eq!(RowNonce::Content.for_row(3), 0);
        assert_eq!(RowNonce::default().for_row(0), 0);
        assert_eq!(RowNonce::Request(7).for_row(0), 7);
        assert_eq!(RowNonce::Request(7).for_row(9), 7);
        let per = RowNonce::PerRow(vec![5, 0, 9]);
        assert_eq!((per.for_row(0), per.for_row(1), per.for_row(2)), (5, 0, 9));
        // Rows beyond the vector (padding) fall back to the content key.
        assert_eq!(per.for_row(3), 0);
    }

    #[test]
    fn default_kind_is_software() {
        assert!(matches!(BackendKind::default(), BackendKind::Software));
        assert_eq!(BackendKind::default().label(), "software");
    }

    #[test]
    fn default_prepacked_i8_entry_is_the_exact_path() {
        use crate::bitslice::{gemm_i32, pack_b};
        let (m, k, n) = (3usize, 4usize, 5usize);
        let a: Vec<i8> = (0..m * k).map(|i| (i as i8).wrapping_mul(9).wrapping_sub(30)).collect();
        let b: Vec<i8> = (0..k * n).map(|i| (i as i8).wrapping_mul(7).wrapping_add(3)).collect();
        let pb = pack_b(&b, k, n).unwrap();
        let want = gemm_i32(&a, &b, m, k, n).unwrap();
        let mut sw = BackendKind::Software.build().unwrap();
        let (mut out, mut rn) = (vec![99i32; 2], vec![7u64]);
        sw.execute_prepacked_i8(&a, m, &pb, &RowNonce::Content, &mut out, &mut rn).unwrap();
        assert_eq!(out, want);
        assert!(rn.is_empty(), "exact path reports no row noise");
        // Shape mismatch surfaces as a typed error.
        assert!(sw
            .execute_prepacked_i8(&a[..m * k - 1], m, &pb, &RowNonce::Content, &mut out, &mut rn)
            .is_err());
    }

    #[test]
    fn kinds_build_working_backends() {
        let mut sw = BackendKind::Software.build().unwrap();
        assert!(sw.platform().contains("software"));
        let cfg = crate::runtime::photonic::PhotonicConfig::spoga();
        let mut ph = BackendKind::Photonic(cfg).build().unwrap();
        assert!(ph.platform().contains("photonic"));
        // Neither backend reports telemetry... except the photonic one.
        let shape = GemmShape { t: 4, k: 16, c: 4, groups: 1 };
        assert!(sw.report_for(&shape).is_none());
        let r = ph.report_for(&shape).expect("photonic telemetry");
        assert!(r.sim_latency_s > 0.0 && r.energy_j > 0.0);
        assert_eq!(r.lanes, shape.outputs());
    }
}
