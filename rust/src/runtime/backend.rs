//! Pluggable execution backends: the `ExecBackend` trait and its registry.
//!
//! The engine/coordinator stack is backend-agnostic: an [`crate::runtime::Engine`]
//! owns a `Box<dyn ExecBackend>` chosen by [`BackendKind`], and everything
//! above it (workers, leader, handles) only sees the trait. Two backends
//! ship in-tree:
//!
//! * [`crate::runtime::software::SoftwareBackend`] — the packed bit-sliced
//!   GEMM interpreter (bit-exact golden-model arithmetic, no telemetry).
//! * [`crate::runtime::photonic::PhotonicBackend`] — same bit-exact
//!   arithmetic, but every execute also runs the artifact's GEMM shape
//!   through the transaction-level photonic simulator
//!   ([`crate::sim::SimEngine`] + [`crate::arch::cost`]) and reports an
//!   [`ExecReport`] (projected latency, energy, lane count), with optional
//!   [`crate::fidelity`] noise injection for photonic-in-the-loop serving.
//!
//! The trait is deliberately narrow (`plan` / `execute_i32` / `platform` +
//! the optional `report_for` telemetry hook) so a future PJRT backend (the
//! `xla` crate compiling HLO text) can slot in behind a cargo feature
//! without touching the serving stack.

use crate::dnn::layer::GemmShape;
use crate::runtime::artifact::ArtifactMeta;
use crate::Result;

/// Per-request photonic telemetry attached to an execution.
///
/// Produced by backends that model the photonic datapath; the software
/// interpreter reports `None`. All fields are per-execute (one artifact
/// invocation); aggregate with [`ExecReport::merge`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ExecReport {
    /// Projected latency of this execution on the simulated accelerator,
    /// seconds (transaction-level model, not wall clock).
    pub sim_latency_s: f64,
    /// Projected energy of this execution, joules.
    pub energy_j: f64,
    /// Analog dot-product lanes transduced (outputs computed optically) —
    /// each one costs the architecture its O/E + ADC conversion chain.
    pub lanes: u64,
    /// Outputs whose analog-observed value differed from the exact integer
    /// result (0 unless noise injection is enabled).
    pub noise_events: u64,
}

impl ExecReport {
    /// Component-wise accumulate (latencies add: layers execute serially).
    pub fn merge(&mut self, other: &ExecReport) {
        self.sim_latency_s += other.sim_latency_s;
        self.energy_j += other.energy_j;
        self.lanes += other.lanes;
        self.noise_events += other.noise_events;
    }
}

/// Result of one backend execution: the output buffer plus telemetry (if
/// the backend models the photonic datapath).
#[derive(Debug, Clone)]
pub struct BackendExec {
    /// Flat row-major int32 output (single-output artifacts).
    pub output: Vec<i32>,
    /// Photonic telemetry, `None` for purely digital backends.
    pub report: Option<ExecReport>,
}

/// An execution backend: plans artifacts once, executes them many times.
///
/// Implementations own their plan cache (keyed by artifact name); `Send`
/// because each coordinator worker constructs its engine — and therefore
/// its backend — inside the worker thread, and hands work across threads.
pub trait ExecBackend: Send {
    /// Backend name for diagnostics (`Engine::platform`).
    fn platform(&self) -> String;

    /// Compile `meta` into an execution plan (idempotent; cached by name).
    fn plan(&mut self, meta: &ArtifactMeta) -> Result<()>;

    /// Execute a previously planned artifact with positional int32 inputs.
    /// Element counts are validated by the engine against the manifest
    /// before this is called.
    fn execute_i32(&mut self, name: &str, inputs: &[&[i32]]) -> Result<BackendExec>;

    /// Telemetry for a GEMM shape *without* executing it — used by the CNN
    /// serving path to report per-layer projections that include conv
    /// groups. Digital backends return `None`.
    fn report_for(&mut self, shape: &GemmShape) -> Option<ExecReport> {
        let _ = shape;
        None
    }
}

/// Which backend an [`crate::runtime::Engine`] (and therefore a whole
/// coordinator worker pool) executes through. Carried by
/// [`crate::coordinator::CoordinatorConfig`].
#[derive(Debug, Clone, Default)]
pub enum BackendKind {
    /// Packed bit-sliced GEMM interpreter (digital, no telemetry).
    #[default]
    Software,
    /// Bit-exact execution plus photonic-in-the-loop simulation telemetry.
    Photonic(crate::runtime::photonic::PhotonicConfig),
}

impl BackendKind {
    /// Construct the backend this kind names.
    pub fn build(&self) -> Result<Box<dyn ExecBackend>> {
        match self {
            BackendKind::Software => {
                Ok(Box::new(crate::runtime::software::SoftwareBackend::new()))
            }
            BackendKind::Photonic(cfg) => Ok(Box::new(
                crate::runtime::photonic::PhotonicBackend::new(cfg.clone())?,
            )),
        }
    }

    /// Short label for tables and stats lines.
    pub fn label(&self) -> String {
        match self {
            BackendKind::Software => "software".to_string(),
            BackendKind::Photonic(cfg) => format!("photonic:{}", cfg.variant_label()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_report_merges_componentwise() {
        let mut a = ExecReport { sim_latency_s: 1.0, energy_j: 2.0, lanes: 3, noise_events: 1 };
        let b = ExecReport { sim_latency_s: 0.5, energy_j: 0.25, lanes: 7, noise_events: 0 };
        a.merge(&b);
        assert_eq!(
            a,
            ExecReport { sim_latency_s: 1.5, energy_j: 2.25, lanes: 10, noise_events: 1 }
        );
    }

    #[test]
    fn default_kind_is_software() {
        assert!(matches!(BackendKind::default(), BackendKind::Software));
        assert_eq!(BackendKind::default().label(), "software");
    }

    #[test]
    fn kinds_build_working_backends() {
        let mut sw = BackendKind::Software.build().unwrap();
        assert!(sw.platform().contains("software"));
        let cfg = crate::runtime::photonic::PhotonicConfig::spoga();
        let mut ph = BackendKind::Photonic(cfg).build().unwrap();
        assert!(ph.platform().contains("photonic"));
        // Neither backend reports telemetry... except the photonic one.
        let shape = GemmShape { t: 4, k: 16, c: 4, groups: 1 };
        assert!(sw.report_for(&shape).is_none());
        let r = ph.report_for(&shape).expect("photonic telemetry");
        assert!(r.sim_latency_s > 0.0 && r.energy_j > 0.0);
        assert_eq!(r.lanes, shape.outputs());
    }
}
