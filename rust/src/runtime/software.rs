//! Software interpreter backend: serves AOT artifacts with the packed
//! bit-sliced GEMM engine instead of PJRT.
//!
//! The vendored dependency set has no `xla` crate, so the default build
//! executes every artifact in software — and does it **through the fast
//! path**: all matrix math routes through [`crate::bitslice::gemm_i32`] /
//! [`crate::bitslice::gemm_i32_prepacked`], which dispatch to the
//! packed-plane tiled/threaded kernels ([`crate::bitslice::kernel`]) for
//! non-trivial shapes. The coordinator worker pool therefore exercises
//! exactly the same arithmetic the golden model defines, at engine speed.
//!
//! ## Pack-once / stream-many on the serving path
//!
//! `ExecBackend::plan` is compile-once, so the weight side of every plan is
//! packed **once** and streamed against per request:
//!
//! * [`Plan::Linear`] owns its surrogate weights as a
//!   [`PackedB`] built at compile time — steady-state requests
//!   perform zero weight-side packing.
//! * Ad-hoc [`Plan::Gemm`] artifacts receive B per request, but B almost
//!   always repeats; the backend keeps a per-artifact [`PackedB`] cache in
//!   its plan map, refreshed by full content equality
//!   ([`PackedB::refresh_wire`] — collision-proof, unlike a hash key).
//! * The activation side lands in a per-backend [`ExecScratch`]
//!   (`wire_to_i8` bytes + nibble planes), so the hot path performs zero
//!   heap allocation once the scratch has grown to the working size.
//! * Compiled CNN plans ([`crate::runtime::cnnrun::CnnPlan`]) hand this
//!   backend already-narrowed activation bytes and compile-time-packed
//!   weights through the defaulted `ExecBackend::execute_prepacked_i8`
//!   entry — the exact prepacked kernel with no i32 wire round-trip and no
//!   per-request packing on either operand.
//!
//! Artifact families are interpreted by their manifest signature:
//!
//! * **GEMM** (`gemm_*`, two 2-D i32 inputs with matching inner dims) —
//!   exact INT8 GEMM on the wire values (i32 carrying int8), bit-identical
//!   to [`crate::bitslice::gemm_i32`]: the runtime-roundtrip suite's
//!   golden-model equality gate holds by construction.
//! * **Row-wise linear** (`mlp_b*` / `cnn_b*`, one 2-D input whose leading
//!   dim matches the output's) — a deterministic surrogate weight matrix
//!   `W: f×o` (seeded by the `(f, o)` signature only, so every batch
//!   variant of a model shares weights and zero-padded rows produce zero
//!   outputs) applied per row through the fast GEMM.
//! * **Flat linear** (anything else with one input) — the same surrogate
//!   over the flattened input.
//!
//! The surrogate weights stand in for the baked-in weights of the real HLO
//! artifacts; every cross-engine consistency property (batch-variant row
//! agreement, determinism, zero-input → zero-logits) is preserved, which is
//! what the integration suites assert.

use std::collections::HashMap;

use crate::bitslice::{self, NibblePlanes, PackedB};
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::backend::{BackendExec, ExecBackend};
use crate::testing::SplitMix64;
use crate::{Error, Result};

/// A validated, ready-to-run execution plan for one artifact.
#[derive(Debug, Clone)]
pub enum Plan {
    /// `C = A·B` on int8 wire values: `A: m×k`, `B: k×n`.
    Gemm {
        /// Output rows.
        m: usize,
        /// Reduction length.
        k: usize,
        /// Output columns.
        n: usize,
    },
    /// Row-wise (or flattened) linear map through surrogate weights.
    Linear {
        /// Rows evaluated independently.
        batch: usize,
        /// Input features per row.
        features: usize,
        /// Output features per row.
        outputs: usize,
        /// Surrogate weight matrix, packed once at compile time
        /// (`features × outputs`, raw bytes + nibble planes).
        weights: PackedB,
    },
}

impl Plan {
    /// Build the plan for an artifact from its manifest signature.
    pub fn compile(meta: &ArtifactMeta) -> Result<Plan> {
        match meta.inputs.len() {
            2 => {
                let (ia, ib, out) = (&meta.inputs[0], &meta.inputs[1], &meta.outputs[0]);
                if ia.dims.len() != 2 || ib.dims.len() != 2 {
                    return Err(Error::Runtime(format!(
                        "{}: two-input artifacts must be 2-D GEMMs",
                        meta.name
                    )));
                }
                let (m, k) = (ia.dims[0], ia.dims[1]);
                let n = ib.dims[1];
                if ib.dims[0] != k || out.elements() != m * n {
                    return Err(Error::Runtime(format!(
                        "{}: inconsistent GEMM dims {:?}x{:?}->{:?}",
                        meta.name, ia.dims, ib.dims, out.dims
                    )));
                }
                Ok(Plan::Gemm { m, k, n })
            }
            1 => {
                let (inp, out) = (&meta.inputs[0], &meta.outputs[0]);
                let row_wise = inp.dims.len() == 2
                    && out.dims.len() == 2
                    && inp.dims[0] == out.dims[0];
                let (batch, features, outputs) = if row_wise {
                    (inp.dims[0], inp.dims[1], out.dims[1])
                } else {
                    (1, inp.elements(), out.elements())
                };
                Ok(Plan::Linear {
                    batch,
                    features,
                    outputs,
                    weights: PackedB::pack(&surrogate_weights(features, outputs), features, outputs)?,
                })
            }
            other => Err(Error::Runtime(format!(
                "{}: software backend supports 1 or 2 inputs, got {other}",
                meta.name
            ))),
        }
    }

    /// Execute the plan on validated inputs (element counts already checked
    /// by the engine against the manifest).
    ///
    /// Allocating convenience path (no scratch, no ad-hoc B cache) for
    /// callers without a backend; [`SoftwareBackend::execute_i32`] is the
    /// allocation-free serving path.
    pub fn execute(&self, inputs: &[&[i32]]) -> Result<Vec<i32>> {
        match self {
            Plan::Gemm { m, k, n } => {
                let a8 = wire_to_i8(inputs[0]);
                let b8 = wire_to_i8(inputs[1]);
                bitslice::gemm_i32(&a8, &b8, *m, *k, *n)
            }
            Plan::Linear { batch, weights, .. } => {
                let rows = wire_to_i8(inputs[0]);
                bitslice::gemm_i32_prepacked(&rows, weights, *batch)
            }
        }
    }
}

/// Per-backend reusable activation-side scratch: the `wire_to_i8` byte
/// buffer and (for plane-kernel backends) the activation nibble planes.
/// Refilled per request, allocation-free at the working size.
#[derive(Debug, Default)]
pub(crate) struct ExecScratch {
    /// Narrowed int8 view of the activation wire input.
    pub a8: Vec<i8>,
    /// Activation nibble planes (packed from `a8` where a plane kernel
    /// consumes them, e.g. the photonic noisy path).
    pub planes: NibblePlanes,
}

/// A compiled plan plus its per-artifact ad-hoc B cache (populated only for
/// [`Plan::Gemm`], where B arrives per request).
#[derive(Debug)]
struct PlanEntry {
    plan: Plan,
    gemm_b: Option<PackedB>,
}

/// The software execution backend: a plan cache over [`Plan`], bit-exact to
/// the bitslice golden model, with no photonic telemetry.
///
/// This is [`crate::runtime::BackendKind::Software`] — the default backend
/// for engines and coordinator workers.
#[derive(Debug, Default)]
pub struct SoftwareBackend {
    plans: HashMap<String, PlanEntry>,
    scratch: ExecScratch,
}

impl SoftwareBackend {
    /// New backend with an empty plan cache.
    pub fn new() -> Self {
        Self::default()
    }
}

impl ExecBackend for SoftwareBackend {
    fn platform(&self) -> String {
        "software-bitslice (packed-plane GEMM interpreter)".to_string()
    }

    fn plan(&mut self, meta: &ArtifactMeta) -> Result<()> {
        if self.plans.contains_key(&meta.name) {
            return Ok(());
        }
        self.plans
            .insert(meta.name.clone(), PlanEntry { plan: Plan::compile(meta)?, gemm_b: None });
        Ok(())
    }

    fn execute_i32(&mut self, name: &str, inputs: &[&[i32]]) -> Result<BackendExec> {
        let entry = self
            .plans
            .get_mut(name)
            .ok_or_else(|| Error::Runtime(format!("{name}: artifact not planned")))?;
        let scratch = &mut self.scratch;
        let output = match &entry.plan {
            Plan::Gemm { m, k, n } => {
                wire_to_i8_into(inputs[0], &mut scratch.a8);
                let pb = PackedB::refresh_wire(entry.gemm_b.take(), inputs[1], *k, *n)?;
                let out = bitslice::gemm_i32_prepacked(&scratch.a8, &pb, *m);
                entry.gemm_b = Some(pb);
                out?
            }
            Plan::Linear { batch, weights, .. } => {
                wire_to_i8_into(inputs[0], &mut scratch.a8);
                bitslice::gemm_i32_prepacked(&scratch.a8, weights, *batch)?
            }
        };
        Ok(BackendExec { output, report: None })
    }
}

/// Wire format carries int8 values in i32 lanes; recover them (wrapping, as
/// the AOT kernels' `convert` does).
pub(crate) fn wire_to_i8(wire: &[i32]) -> Vec<i8> {
    wire.iter().map(|&v| v as i8).collect()
}

/// [`wire_to_i8`] into a reusable buffer (the scratch form of the serving
/// hot path: clear + refill, no allocation at the working size).
pub(crate) fn wire_to_i8_into(wire: &[i32], buf: &mut Vec<i8>) {
    buf.clear();
    buf.extend(wire.iter().map(|&v| v as i8));
}

/// Deterministic surrogate weight matrix for a `(features → outputs)` linear
/// layer. Seeded only by the signature so all batch variants agree.
fn surrogate_weights(features: usize, outputs: usize) -> Vec<i8> {
    let seed = 0x5b06_a77e_u64 ^ ((features as u64) << 24) ^ outputs as u64;
    let mut rng = SplitMix64::new(seed);
    rng.i8_vec(features * outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Manifest;
    use std::path::PathBuf;

    fn meta(line: &str) -> ArtifactMeta {
        Manifest::parse(line, PathBuf::from("/tmp")).unwrap().artifacts[0].clone()
    }

    #[test]
    fn gemm_plan_matches_golden_model() {
        let meta = meta("gemm_4x3x2 g.hlo.txt i32:4x3,i32:3x2 i32:4x2");
        let plan = Plan::compile(&meta).unwrap();
        let a: Vec<i32> = vec![1, -2, 3, 4, 5, -6, 7, 8, 9, -128, 127, 0];
        let b: Vec<i32> = vec![1, 2, 3, -4, 5, 6];
        let out = plan.execute(&[&a, &b]).unwrap();
        let a8 = wire_to_i8(&a);
        let b8 = wire_to_i8(&b);
        assert_eq!(out, bitslice::gemm_i32(&a8, &b8, 4, 3, 2).unwrap());
    }

    #[test]
    fn linear_batch_variants_share_weights() {
        let b1 = Plan::compile(&meta("mlp_b1 m.hlo.txt i32:1x8 i32:1x3")).unwrap();
        let b4 = Plan::compile(&meta("mlp_b4 m.hlo.txt i32:4x8 i32:4x3")).unwrap();
        let row: Vec<i32> = (0..8).map(|v| v * 9 % 100).collect();
        let single = b1.execute(&[&row]).unwrap();
        let mut padded = vec![0i32; 4 * 8];
        padded[..8].copy_from_slice(&row);
        let batched = b4.execute(&[&padded]).unwrap();
        assert_eq!(&batched[..3], &single[..]);
        // Padding rows are zero → zero outputs.
        assert!(batched[3..].iter().all(|&v| v == 0));
    }

    #[test]
    fn zero_input_gives_zero_output() {
        let plan = Plan::compile(&meta("cnn_b1 c.hlo.txt i32:1x784 i32:1x10")).unwrap();
        let x = vec![0i32; 784];
        assert_eq!(plan.execute(&[&x]).unwrap(), vec![0i32; 10]);
    }

    #[test]
    fn flat_linear_for_mismatched_batch_dims() {
        let plan = Plan::compile(&meta("cnn_raw c.hlo.txt i32:28x28 i32:1x10")).unwrap();
        match &plan {
            Plan::Linear { batch, features, outputs, weights } => {
                assert_eq!((*batch, *features, *outputs), (1, 784, 10));
                assert_eq!((weights.rows(), weights.cols()), (784, 10));
            }
            other => panic!("expected flat linear, got {other:?}"),
        }
    }

    #[test]
    fn linear_plan_weights_packed_once_at_compile_time() {
        let plan = Plan::compile(&meta("mlp_b2 m.hlo.txt i32:2x8 i32:2x3")).unwrap();
        match &plan {
            Plan::Linear { weights, .. } => {
                assert_eq!(weights.raw(), &surrogate_weights(8, 3)[..]);
                let fresh = NibblePlanes::pack(&surrogate_weights(8, 3), 8, 3).unwrap();
                assert_eq!(weights.planes().msn, fresh.msn);
                assert_eq!(weights.planes().lsn, fresh.lsn);
            }
            other => panic!("expected linear, got {other:?}"),
        }
    }

    #[test]
    fn bad_signatures_rejected() {
        assert!(Plan::compile(&meta("g g.hlo.txt i32:4x3,i32:4x2 i32:4x2")).is_err());
        assert!(Plan::compile(&meta("t t.hlo.txt i32:2,i32:2,i32:2 i32:2")).is_err());
    }

    #[test]
    fn surrogate_weights_deterministic_and_signature_keyed() {
        assert_eq!(surrogate_weights(8, 3), surrogate_weights(8, 3));
        assert_ne!(surrogate_weights(8, 3), surrogate_weights(3, 8));
    }

    #[test]
    fn backend_plans_and_executes_by_name() {
        let mut be = SoftwareBackend::new();
        let m = meta("gemm_2x2x2 g.hlo.txt i32:2x2,i32:2x2 i32:2x2");
        assert!(be.execute_i32("gemm_2x2x2", &[&[], &[]]).is_err());
        be.plan(&m).unwrap();
        be.plan(&m).unwrap(); // idempotent
        let a = vec![1i32, 2, 3, 4];
        let ex = be.execute_i32("gemm_2x2x2", &[&a, &a]).unwrap();
        assert_eq!(ex.output, vec![7, 10, 15, 22]);
        assert!(ex.report.is_none());
        assert!(be.platform().contains("software"));
    }

    #[test]
    fn adhoc_gemm_b_cache_reuses_and_refreshes() {
        let mut be = SoftwareBackend::new();
        be.plan(&meta("gemm_2x2x2 g.hlo.txt i32:2x2,i32:2x2 i32:2x2")).unwrap();
        let a = vec![3i32, -1, 2, 5];
        let b1 = vec![5i32, 6, 7, 8];
        let b2 = vec![1i32, 0, 0, 1];
        let expect = |b: &[i32]| {
            bitslice::gemm_i32(&wire_to_i8(&a), &wire_to_i8(b), 2, 2, 2).unwrap()
        };
        // First request populates the cache.
        assert_eq!(be.execute_i32("gemm_2x2x2", &[&a, &b1]).unwrap().output, expect(&b1));
        let cached = be.plans["gemm_2x2x2"].gemm_b.as_ref().unwrap();
        assert!(cached.matches_wire(&b1));
        // Repeat B is a cache hit and stays bit-identical.
        assert_eq!(be.execute_i32("gemm_2x2x2", &[&a, &b1]).unwrap().output, expect(&b1));
        // Changed B refreshes the cache and serves the new content.
        assert_eq!(be.execute_i32("gemm_2x2x2", &[&a, &b2]).unwrap().output, expect(&b2));
        assert!(be.plans["gemm_2x2x2"].gemm_b.as_ref().unwrap().matches_wire(&b2));
    }
}
