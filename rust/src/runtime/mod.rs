//! PJRT runtime: load and execute the AOT artifacts from `make artifacts`.
//!
//! Python never runs here — the artifacts are HLO **text** modules lowered
//! once at build time; this module parses the manifest, compiles each module
//! on the PJRT CPU client (`xla` crate) and executes them with concrete
//! int32 buffers on the request path.

pub mod artifact;
pub mod engine;

pub use artifact::{ArtifactMeta, Manifest, TensorSpec};
pub use engine::Engine;
