//! Execution runtime: load the AOT artifact manifest and execute artifacts
//! through pluggable backends.
//!
//! Python never runs here — the artifacts are HLO **text** modules lowered
//! once at build time by `make artifacts`; this module parses the manifest
//! and executes the computations with concrete int32 buffers on the request
//! path.
//!
//! ## Backends
//!
//! Execution is backend-pluggable behind the [`ExecBackend`] trait
//! ([`backend`]): an [`Engine`] owns a `Box<dyn ExecBackend>` selected by
//! [`BackendKind`], and the whole L3 serving stack (coordinator, workers,
//! handles) is backend-agnostic — [`crate::coordinator::CoordinatorConfig`]
//! carries the `BackendKind` every worker builds its engine with. Two
//! backends ship in-tree:
//!
//! * **Software** ([`software::SoftwareBackend`], the default): artifacts
//!   are planned once from their manifest signature and executed through
//!   the packed bit-sliced GEMM fast path ([`crate::bitslice::kernel`]).
//!   Bit-exact to the golden model, zero external dependencies.
//! * **Photonic** ([`photonic::PhotonicBackend`]): the *same* bit-exact
//!   plans, but every execute also prices the artifact's GEMM shape on a
//!   simulated accelerator ([`crate::sim`] + [`crate::arch::cost`]) and
//!   attaches an [`ExecReport`] (projected latency, energy, lanes) to the
//!   response — photonic-in-the-loop serving. Optional [`crate::fidelity`]
//!   noise injection replaces exact integers with analog-observed ones.
//!
//! Whole CNN inferences are served by [`cnnrun::run_cnn`], which drives a
//! [`crate::dnn::CnnModel`] through im2col layer by layer over any backend;
//! [`cnnrun::run_cnn_batch`] stacks same-model frames along the t-dimension
//! so a batch costs one GEMM per layer group (the coordinator's CNN
//! batching path). Serving is compile-once/stream-many: the engine caches a
//! [`cnnrun::CnnPlan`] per model (weights packed at compile time) and
//! streams requests through a persistent [`cnnrun::CnnScratch`] arena and
//! the backends' direct-i8 entry ([`ExecBackend::execute_prepacked_i8`]) —
//! see the CNN-plan contract in [`backend`].
//!
//! A PJRT backend (the `xla` crate compiling the HLO text on a CPU client)
//! previously occupied the software slot and can return as a third
//! `ExecBackend` behind a cargo feature once the dependency is vendored;
//! the trait surface (compile-once `plan`, validated `execute_i32`) is
//! shaped so the swap is invisible to callers, and each coordinator worker
//! still owns its own engine exactly as a thread-affine PJRT client would
//! require.

pub mod artifact;
pub mod backend;
pub mod cnnrun;
pub mod engine;
pub mod photonic;
pub mod software;

pub use artifact::{ArtifactMeta, Manifest, TensorSpec};
pub use backend::{BackendExec, BackendKind, ExecBackend, ExecReport, RowNonce};
pub use cnnrun::{
    run_cnn, run_cnn_batch, run_cnn_batch_keyed, run_cnn_batch_keyed_reference,
    validate_cnn_input, CnnPlan, CnnRun, CnnScratch, LayerReport,
};
pub use engine::Engine;
pub use photonic::{PhotonicBackend, PhotonicConfig};
pub use software::SoftwareBackend;
