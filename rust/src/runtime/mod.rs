//! Execution runtime: load the AOT artifact manifest and execute artifacts.
//!
//! Python never runs here — the artifacts are HLO **text** modules lowered
//! once at build time by `make artifacts`; this module parses the manifest
//! and executes the computations with concrete int32 buffers on the request
//! path.
//!
//! ## Backends
//!
//! The default (and currently only in-tree) backend is the **software
//! interpreter** ([`software`]): artifacts are planned once from their
//! manifest signature and executed through the packed bit-sliced GEMM fast
//! path ([`crate::bitslice::kernel`]). That keeps the whole L3 serving stack
//! — engine, coordinator, worker pool — runnable and numerically faithful
//! to the golden model with **zero external dependencies**.
//!
//! A PJRT backend (the `xla` crate compiling the HLO text on a CPU client)
//! previously occupied this slot and can return behind a cargo feature once
//! the dependency is vendored; the [`Engine`] API (compile-once
//! `warmup`/`execute_i32` with manifest-driven validation) is shaped so the
//! swap is invisible to callers, and each coordinator worker still owns its
//! own engine exactly as a thread-affine PJRT client would require.

pub mod artifact;
pub mod engine;
pub mod software;

pub use artifact::{ArtifactMeta, Manifest, TensorSpec};
pub use engine::Engine;
