//! Per-core device inventory and GEMM execution planning.

use crate::devices::adc::Adc;
use crate::devices::bpca::Bpca;
use crate::devices::dac::Dac;
use crate::devices::deas::Deas;
use crate::devices::laser::Laser;
use crate::devices::mrr::Mrr;
use crate::devices::photodetector::BalancedPhotodetector;
use crate::devices::splitter::SplitterTree;
use crate::devices::sram::SramBuffer;
use crate::dnn::layer::GemmShape;
use crate::optics::link_budget::{ArchClass, LinkBudget};
use crate::units::DataRate;
use crate::{Error, Result};

/// Device counts of one GEMM core (drives area + standing power).
#[derive(Debug, Clone)]
pub struct CoreInventory {
    /// Laser diodes (wavelength channels generated).
    pub lasers: usize,
    /// Input modulator rings (DAC-driven every symbol).
    pub modulator_rings: usize,
    /// Weight-bank rings (reprogrammed at weight-update cadence).
    pub weight_rings: usize,
    /// Passive filter/mux rings (aggregation).
    pub filter_rings: usize,
    /// Balanced photodetectors with TIA receivers.
    pub tia_receivers: usize,
    /// BPCAs (time-integrating receivers with capacitor banks).
    pub bpcas: usize,
    /// ADCs (one per digitized output channel).
    pub adcs: usize,
    /// Input DACs (one per modulator driven per symbol).
    pub dacs: usize,
    /// DEAS shifter-adder units (baselines only).
    pub deas_units: usize,
    /// Splitter-tree fanout degree (0 = no splitting block).
    pub splitter_fanout: usize,
    /// Intermediate-result SRAM (baselines only).
    pub has_sram: bool,
}

/// Execution plan for one INT8 GEMM on one *logical* core.
///
/// A logical core is the unit that completes an INT8 GEMM by itself: one
/// SPOGA core, or a *quadruplet* of baseline INT4 cores running the four
/// slice-GEMMs of Fig. 2(a) in parallel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmPlan {
    /// Timesteps (symbol slots) the logical core is busy.
    pub timesteps: u64,
    /// Physical cores occupied while it runs (1 or 4).
    pub cores_occupied: u64,
    /// O/E → ADC conversions performed.
    pub adc_conversions: u64,
    /// Input-DAC conversions performed.
    pub dac_conversions: u64,
    /// BPCA accumulate/reset cycles (SPOGA) — 3 lanes × results.
    pub bpca_cycles: u64,
    /// Outputs that pass through DEAS shift-add (baselines).
    pub deas_outputs: u64,
    /// Bytes round-tripped through intermediate SRAM (baselines).
    pub sram_bytes: u64,
}

/// One photonic GEMM core at a fixed design point.
#[derive(Debug, Clone)]
pub struct Core {
    /// Organisation (MAW/AMW/MWA).
    pub arch: ArchClass,
    /// Symbol rate.
    pub dr: DataRate,
    /// Vector size (dot-product length) per pass.
    pub n: usize,
    /// Dot products per timestep.
    pub m: usize,
    /// Per-wavelength laser power, dBm (from the Table I design point).
    pub laser_dbm: f64,
    /// Device inventory.
    pub inventory: CoreInventory,
}

impl Core {
    /// Build a core from the architecture's link budget at (dr, laser_dbm).
    ///
    /// Baselines solve the largest square N=M; SPOGA fixes M=16 DPUs and
    /// solves N (OAMEs per DPU).
    pub fn design(arch: ArchClass, dr: DataRate, laser_dbm: f64) -> Result<Self> {
        let lb = LinkBudget::for_arch(arch);
        let (n, m) = match arch {
            ArchClass::Maw | ArchClass::Amw => {
                let s = lb.max_square(dr, laser_dbm);
                (s, s)
            }
            ArchClass::Mwa => {
                let m = lb.m_cap.expect("SPOGA fixes M");
                (lb.max_n_given_m(m, dr, laser_dbm), m)
            }
        };
        if n == 0 || m == 0 {
            return Err(Error::Infeasible(format!(
                "{} at {dr}, {laser_dbm} dBm: no feasible configuration",
                arch.name()
            )));
        }
        let inventory = Self::build_inventory(arch, n, m);
        Ok(Core { arch, dr, n, m, laser_dbm, inventory })
    }

    fn build_inventory(arch: ArchClass, n: usize, m: usize) -> CoreInventory {
        match arch {
            // MAW/AMW (paper Fig. 1(b)): N lasers, N modulators, M weight
            // banks of N rings, M BPD+TIA receivers, M ADCs, N input DACs,
            // 1:M splitting block, DEAS + SRAM for bit-slice post-processing.
            ArchClass::Maw | ArchClass::Amw => CoreInventory {
                lasers: n,
                modulator_rings: n,
                weight_rings: n * m,
                filter_rings: n, // aggregation/mux block
                tia_receivers: m,
                bpcas: 0,
                adcs: m,
                dacs: n,
                deas_units: m,
                splitter_fanout: m,
                has_sram: true,
            },
            // SPOGA (paper Fig. 3): M=16 DPUs per core. In a GEMM all 16
            // DPUs consume the SAME input vector against 16 different weight
            // columns (Fig. 1 mapping), so the input side is built ONCE per
            // core: 4 carrier lasers (λ1..λ4), 4N input modulator rings
            // (each input nibble imprinted on the two wavelengths that
            // consume it) driven by 2N nibble DACs, then a 1:16 split to the
            // DPUs — the ≈12 dB split is exactly the link budget's fixed
            // loss (see `LinkBudget::spoga`). Each DPU owns 4N weight rings,
            // 3 aggregation-lane mux sets ending in 3 BPCAs, and 1 analog
            // adder + 1 ADC.
            ArchClass::Mwa => CoreInventory {
                lasers: 4,
                modulator_rings: 4 * n,
                weight_rings: 4 * n * m,
                filter_rings: 6 * m, // 3 lane sets × (+ve/−ve) mux per DPU
                tia_receivers: 0,
                bpcas: 3 * m,
                adcs: m,
                dacs: 2 * n,
                deas_units: 0,
                splitter_fanout: m,
                has_sram: false,
            },
        }
    }

    /// Paper-style variant name, e.g. "SPOGA_10".
    pub fn variant_name(&self) -> String {
        let base = match self.arch {
            ArchClass::Maw => "HOLYLIGHT",
            ArchClass::Amw => "DEAPCNN",
            ArchClass::Mwa => "SPOGA",
        };
        format!("{base}_{}", self.dr.suffix())
    }

    /// INT8 MACs retired per timestep by one *logical* core.
    pub fn int8_macs_per_step(&self) -> u64 {
        match self.arch {
            // A quadruplet of INT4 cores retires n×m INT8 MACs per step
            // (each core does the n×m INT4 slice products of one slice pair).
            ArchClass::Maw | ArchClass::Amw => (self.n * self.m) as u64,
            // One SPOGA core: m DPUs × n INT8 elements.
            ArchClass::Mwa => (self.n * self.m) as u64,
        }
    }

    /// Plan one INT8 GEMM `shape` on one logical core (paper §III-B
    /// conversion accounting).
    pub fn plan_gemm(&self, shape: &GemmShape) -> GemmPlan {
        let t = shape.t as u64;
        let groups = shape.groups as u64;
        let k_chunks = shape.k.div_ceil(self.n) as u64;
        let c_tiles = shape.c.div_ceil(self.m) as u64;
        let steps = t * k_chunks * c_tiles * groups;
        let outputs = shape.outputs();

        match self.arch {
            ArchClass::Maw | ArchClass::Amw => {
                // Four INT4 slice-GEMMs on four cores in parallel; every
                // timestep each BPD result is digitized; K-chunk partials are
                // recombined digitally; DEAS assembles the final outputs.
                let adc = 4 * steps * self.m as u64;
                GemmPlan {
                    timesteps: steps,
                    cores_occupied: 4,
                    adc_conversions: adc,
                    dac_conversions: 4 * steps * self.n as u64,
                    bpca_cycles: 0,
                    deas_outputs: outputs,
                    // Each intermediate conversion is stored + read once
                    // (2 bytes, 16-bit intermediates).
                    sram_bytes: 2 * adc,
                }
            }
            ArchClass::Mwa => {
                // Charge accumulates across K-chunks inside the BPCAs; only
                // the final result of each output is digitized: exactly one
                // ADC conversion per output, three BPCA integrate+reset
                // cycles per output (one per radix lane). No DEAS, no SRAM.
                // Input DACs run once per step (shared across the 16 DPUs).
                GemmPlan {
                    timesteps: steps,
                    cores_occupied: 1,
                    adc_conversions: outputs,
                    dac_conversions: steps * 2 * self.n as u64,
                    bpca_cycles: 3 * outputs,
                    deas_outputs: 0,
                    sram_bytes: 0,
                }
            }
        }
    }

    /// Electronic (CMOS die) area of one core, mm²: ADCs + DACs + DEAS +
    /// SRAM — the components the paper's Table II models. This is the area
    /// that FPS/W/mm² divides by (the paper's own area data covers only the
    /// electronic converters; the photonic devices live on a separate
    /// photonic die in the assumed 2.5D integration).
    pub fn electronic_area_mm2(&self) -> f64 {
        let inv = &self.inventory;
        let adc = Adc::for_rate(self.dr);
        let dac = Dac::for_rate(self.dr);
        let deas = Deas::default();
        let mut area = inv.adcs as f64 * adc.area_mm2
            + inv.dacs as f64 * dac.area_mm2
            + inv.deas_units as f64 * deas.area_mm2;
        if inv.has_sram {
            area += SramBuffer::for_outputs(self.m).area_mm2;
        }
        area
    }

    /// Photonic-die area of one core, mm² (rings, lasers, detectors,
    /// splitter trees).
    pub fn photonic_area_mm2(&self) -> f64 {
        let inv = &self.inventory;
        let mrr = Mrr::modulator().area_mm2; // same footprint for all roles
        let laser = Laser::with_power_dbm(self.laser_dbm);
        let pd = BalancedPhotodetector::tia();
        let bpca = Bpca::default();
        let split = SplitterTree::default();
        let rings = inv.modulator_rings + inv.weight_rings + inv.filter_rings;
        rings as f64 * mrr
            + inv.lasers as f64 * laser.area_mm2
            + inv.tia_receivers as f64 * pd.area_mm2
            + inv.bpcas as f64 * bpca.area_mm2
            + split.area_mm2(inv.splitter_fanout) * inv.lasers as f64
    }

    /// Total (photonic + electronic) area of one physical core, mm².
    pub fn area_mm2(&self) -> f64 {
        self.electronic_area_mm2() + self.photonic_area_mm2()
    }

    /// Standing (workload-independent) power of one physical core, mW:
    /// lasers (wall-plug), ring tuning, receiver bias, converter standby.
    pub fn standing_power_mw(&self) -> f64 {
        let inv = &self.inventory;
        let laser = Laser::with_power_dbm(self.laser_dbm);
        let pd = BalancedPhotodetector::tia();
        let bpca = Bpca::default();
        let rings = inv.modulator_rings + inv.weight_rings + inv.filter_rings;

        let mut p = inv.lasers as f64 * laser.electrical_power_mw()
            + rings as f64 * Mrr::modulator().static_power_mw()
            + inv.tia_receivers as f64 * pd.static_power_mw
            + inv.bpcas as f64 * bpca.static_power_mw;
        if inv.has_sram {
            p += SramBuffer::for_outputs(self.m).leakage_mw;
        }
        p
    }

    /// Peak dynamic power of one physical core running flat out, mW
    /// (modulator drive + ADC + DAC + DEAS at the symbol rate).
    pub fn peak_dynamic_power_mw(&self) -> f64 {
        let inv = &self.inventory;
        let adc = Adc::for_rate(self.dr);
        let dac = Dac::for_rate(self.dr);
        let deas = Deas::default();
        let mrm = Mrr::modulator();

        let mut p = inv.adcs as f64 * adc.power_mw
            + inv.dacs as f64 * dac.power_mw
            + inv.modulator_rings as f64 * mrm.drive_power_mw(self.dr)
            + inv.deas_units as f64 * deas.power_mw(self.dr);
        if inv.has_sram {
            // Streaming M 16-bit intermediates per symbol.
            p += SramBuffer::for_outputs(self.m)
                .dynamic_power_mw(self.dr, 2.0 * self.m as f64);
        }
        p
    }

    /// Total peak power (standing + dynamic), mW.
    pub fn peak_power_mw(&self) -> f64 {
        self.standing_power_mw() + self.peak_dynamic_power_mw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(t: usize, k: usize, c: usize) -> GemmShape {
        GemmShape { t, k, c, groups: 1 }
    }

    #[test]
    fn design_points_match_table1() {
        let h = Core::design(ArchClass::Maw, DataRate::Gs1, 10.0).unwrap();
        assert_eq!((h.n, h.m), (43, 43));
        let d = Core::design(ArchClass::Amw, DataRate::Gs10, 10.0).unwrap();
        assert_eq!((d.n, d.m), (12, 12));
        let s = Core::design(ArchClass::Mwa, DataRate::Gs10, 10.0).unwrap();
        assert_eq!((s.n, s.m), (160, 16));
    }

    #[test]
    fn infeasible_design_rejected() {
        // −20 dBm lasers cannot close any budget.
        assert!(Core::design(ArchClass::Maw, DataRate::Gs10, -20.0).is_err());
    }

    #[test]
    fn spoga_single_adc_conversion_per_output() {
        let s = Core::design(ArchClass::Mwa, DataRate::Gs5, 10.0).unwrap();
        let sh = shape(64, 500, 32); // K > N forces multi-pass accumulation
        let plan = s.plan_gemm(&sh);
        assert_eq!(plan.adc_conversions, sh.outputs());
        assert_eq!(plan.deas_outputs, 0);
        assert_eq!(plan.sram_bytes, 0);
        assert_eq!(plan.cores_occupied, 1);
        assert_eq!(plan.bpca_cycles, 3 * sh.outputs());
    }

    #[test]
    fn baseline_pays_conversion_tax() {
        let h = Core::design(ArchClass::Maw, DataRate::Gs5, 10.0).unwrap();
        let sh = shape(64, 500, 32);
        let plan = h.plan_gemm(&sh);
        // 4 slice-cores, M conversions per step each.
        assert_eq!(plan.cores_occupied, 4);
        assert!(plan.adc_conversions > sh.outputs());
        assert_eq!(plan.deas_outputs, sh.outputs());
        assert!(plan.sram_bytes > 0);
    }

    #[test]
    fn plan_timesteps_scale_with_tiling() {
        let s = Core::design(ArchClass::Mwa, DataRate::Gs1, 10.0).unwrap(); // n=249,m=16
        let small = s.plan_gemm(&shape(10, 249, 16));
        assert_eq!(small.timesteps, 10); // single chunk, single tile
        let multi = s.plan_gemm(&shape(10, 250, 17));
        assert_eq!(multi.timesteps, 10 * 2 * 2);
    }

    #[test]
    fn grouped_gemm_multiplies_steps() {
        let s = Core::design(ArchClass::Mwa, DataRate::Gs1, 10.0).unwrap();
        let g1 = s.plan_gemm(&GemmShape { t: 9, k: 9, c: 1, groups: 1 });
        let g32 = s.plan_gemm(&GemmShape { t: 9, k: 9, c: 1, groups: 32 });
        assert_eq!(g32.timesteps, 32 * g1.timesteps);
    }

    #[test]
    fn spoga_inventory_counts() {
        let s = Core::design(ArchClass::Mwa, DataRate::Gs1, 10.0).unwrap(); // n=249
        let inv = &s.inventory;
        assert_eq!(inv.lasers, 4); // one carrier group, split 1:16 to DPUs
        assert_eq!(inv.modulator_rings, 4 * 249); // input block shared by DPUs
        assert_eq!(inv.weight_rings, 4 * 249 * 16); // per-DPU weight banks
        assert_eq!(inv.dacs, 2 * 249); // one DAC per input nibble
        assert_eq!(inv.bpcas, 48); // 3 × 16
        assert_eq!(inv.adcs, 16);
        assert_eq!(inv.deas_units, 0);
        assert!(!inv.has_sram);
    }

    #[test]
    fn baseline_inventory_counts() {
        let h = Core::design(ArchClass::Maw, DataRate::Gs1, 10.0).unwrap(); // 43×43
        let inv = &h.inventory;
        assert_eq!(inv.lasers, 43);
        assert_eq!(inv.weight_rings, 43 * 43);
        assert_eq!(inv.adcs, 43);
        assert_eq!(inv.deas_units, 43);
        assert!(inv.has_sram);
    }

    #[test]
    fn area_and_power_positive_for_all_designs() {
        for arch in [ArchClass::Maw, ArchClass::Amw, ArchClass::Mwa] {
            for dr in DataRate::ALL {
                let c = Core::design(arch, dr, 10.0).unwrap();
                assert!(c.area_mm2() > 0.0);
                assert!(c.standing_power_mw() > 0.0);
                assert!(c.peak_dynamic_power_mw() > 0.0);
            }
        }
    }

    #[test]
    fn variant_names_match_paper_style() {
        let s = Core::design(ArchClass::Mwa, DataRate::Gs10, 10.0).unwrap();
        assert_eq!(s.variant_name(), "SPOGA_10");
        let h = Core::design(ArchClass::Maw, DataRate::Gs1, 10.0).unwrap();
        assert_eq!(h.variant_name(), "HOLYLIGHT_1");
    }

    #[test]
    fn ring_tuning_dominates_spoga_standing_power() {
        // With only 4 carrier lasers per core, SPOGA's standing power is
        // dominated by thermal tuning of its large ring population.
        let s = Core::design(ArchClass::Mwa, DataRate::Gs10, 10.0).unwrap();
        let lasers = 4.0 * Laser::with_power_dbm(10.0).electrical_power_mw();
        assert!(lasers / s.standing_power_mw() < 0.2);
    }
}
