//! Accelerator architecture models.
//!
//! A [`Core`] captures one photonic GEMM core of a given organisation
//! (SPOGA/MWA, HOLYLIGHT/MAW, DEAPCNN/AMW) at a data rate and laser power:
//! its device inventory (→ area, standing power), and its execution plan for
//! an INT8 GEMM (→ timesteps, conversion counts, post-processing work).
//! An [`Accelerator`] is a fleet of identical cores normalized to a total
//! laser wall-plug budget (the iso-power comparison of DESIGN.md §5.2).

pub mod accel;
pub mod core;
pub mod cost;

pub use accel::Accelerator;
pub use core::{Core, CoreInventory, GemmPlan};
pub use cost::{ConversionCounts, EnergyBreakdown};
