//! Conversion counting and energy breakdown (paper §III-B accounting).

use crate::arch::core::{Core, GemmPlan};
use crate::devices::adc::Adc;
use crate::devices::bpca::Bpca;
use crate::devices::dac::Dac;
use crate::devices::deas::Deas;
use crate::devices::sram::SramBuffer;

/// Per-dot-product conversion chain of an architecture (paper §III-B: SPOGA
/// needs 3 O/E + 1 ADC; prior works need 4 O/E + 4 ADC + SRAM + DEAS).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConversionCounts {
    /// Optical-to-electrical transductions per dot product.
    pub oe_per_output: f64,
    /// ADC conversions per dot product.
    pub adc_per_output: f64,
    /// SRAM bytes round-tripped per dot product.
    pub sram_bytes_per_output: f64,
    /// DEAS shift-add operations per dot product.
    pub deas_per_output: f64,
}

impl ConversionCounts {
    /// Derive the per-output conversion chain from a concrete plan.
    pub fn from_plan(plan: &GemmPlan, outputs: u64) -> Self {
        let o = outputs.max(1) as f64;
        let oe = if plan.bpca_cycles > 0 {
            plan.bpca_cycles as f64 // each BPCA integrate+readout is one O/E
        } else {
            plan.adc_conversions as f64 // TIA: every ADC sample is an O/E
        };
        ConversionCounts {
            oe_per_output: oe / o,
            adc_per_output: plan.adc_conversions as f64 / o,
            sram_bytes_per_output: plan.sram_bytes as f64 / o,
            deas_per_output: plan.deas_outputs as f64 / o,
        }
    }
}

/// Energy components of executing some workload on an accelerator, joules.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// Laser wall-plug energy.
    pub laser_j: f64,
    /// MRR thermal tuning + receiver bias (standing, non-laser).
    pub standing_j: f64,
    /// Modulator drive + input DAC energy.
    pub dac_j: f64,
    /// ADC conversion energy.
    pub adc_j: f64,
    /// BPCA integrate/reset energy (SPOGA).
    pub bpca_j: f64,
    /// DEAS shift-add energy (baselines).
    pub deas_j: f64,
    /// Intermediate SRAM traffic energy (baselines).
    pub sram_j: f64,
}

impl EnergyBreakdown {
    /// Total energy, joules.
    pub fn total_j(&self) -> f64 {
        self.laser_j
            + self.standing_j
            + self.dac_j
            + self.adc_j
            + self.bpca_j
            + self.deas_j
            + self.sram_j
    }

    /// Energy of one GEMM plan on `core` (active-time × standing power +
    /// per-event dynamic energies).
    pub fn of_plan(core: &Core, plan: &GemmPlan) -> Self {
        let step_s = core.dr.step_seconds();
        let busy_s = plan.timesteps as f64 * step_s * plan.cores_occupied as f64;
        let adc = Adc::for_rate(core.dr);
        let dac = Dac::for_rate(core.dr);
        let deas = Deas::default();
        let bpca = Bpca::default();
        let sram = SramBuffer::for_outputs(core.m);

        // Standing power split: lasers vs the rest (tuning, bias, leakage).
        let laser_mw = core.inventory.lasers as f64
            * crate::devices::laser::Laser::with_power_dbm(core.laser_dbm)
                .electrical_power_mw();
        let other_mw = core.standing_power_mw() - laser_mw;

        EnergyBreakdown {
            laser_j: laser_mw * 1e-3 * busy_s,
            standing_j: other_mw * 1e-3 * busy_s,
            dac_j: plan.dac_conversions as f64 * dac.energy_per_conversion_pj() * 1e-12,
            adc_j: plan.adc_conversions as f64 * adc.energy_per_conversion_pj() * 1e-12,
            bpca_j: plan.bpca_cycles as f64 * bpca.energy_per_cycle_pj * 1e-12,
            deas_j: plan.deas_outputs as f64 * deas.energy_per_output_pj * 1e-12,
            sram_j: sram.roundtrip_energy_pj(plan.sram_bytes as f64) * 1e-12,
        }
    }

    /// Component-wise accumulate.
    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.laser_j += other.laser_j;
        self.standing_j += other.standing_j;
        self.dac_j += other.dac_j;
        self.adc_j += other.adc_j;
        self.bpca_j += other.bpca_j;
        self.deas_j += other.deas_j;
        self.sram_j += other.sram_j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::layer::GemmShape;
    use crate::optics::link_budget::ArchClass;
    use crate::units::DataRate;

    fn cores() -> (Core, Core) {
        (
            Core::design(ArchClass::Mwa, DataRate::Gs5, 10.0).unwrap(),
            Core::design(ArchClass::Maw, DataRate::Gs5, 10.0).unwrap(),
        )
    }

    #[test]
    fn paper_conversion_claim_single_pass() {
        // For a single-pass dot product (K ≤ N): SPOGA = 3 O/E + 1 ADC,
        // baseline = 4 O/E + 4 ADC (paper §III-B).
        let (spoga, holy) = cores();
        let sh = GemmShape { t: 1, k: spoga.n, c: spoga.m, groups: 1 };
        let sp = spoga.plan_gemm(&sh);
        let sc = ConversionCounts::from_plan(&sp, sh.outputs());
        assert_eq!(sc.oe_per_output, 3.0);
        assert_eq!(sc.adc_per_output, 1.0);
        assert_eq!(sc.deas_per_output, 0.0);
        assert_eq!(sc.sram_bytes_per_output, 0.0);

        let sh_b = GemmShape { t: 1, k: holy.n, c: holy.m, groups: 1 };
        let bp = holy.plan_gemm(&sh_b);
        let bc = ConversionCounts::from_plan(&bp, sh_b.outputs());
        assert_eq!(bc.oe_per_output, 4.0);
        assert_eq!(bc.adc_per_output, 4.0);
        assert_eq!(bc.deas_per_output, 1.0);
        assert!(bc.sram_bytes_per_output > 0.0);
    }

    #[test]
    fn multipass_widens_the_gap() {
        // K ≫ N: baselines digitize every pass; SPOGA still 1 ADC/output.
        let (spoga, holy) = cores();
        let sh = GemmShape { t: 4, k: 4 * spoga.n.max(holy.n), c: 16, groups: 1 };
        let sc = ConversionCounts::from_plan(&spoga.plan_gemm(&sh), sh.outputs());
        let bc = ConversionCounts::from_plan(&holy.plan_gemm(&sh), sh.outputs());
        assert_eq!(sc.adc_per_output, 1.0);
        assert!(bc.adc_per_output > 4.0);
    }

    #[test]
    fn energy_breakdown_totals_components() {
        let (spoga, _) = cores();
        let sh = GemmShape { t: 16, k: 100, c: 16, groups: 1 };
        let e = EnergyBreakdown::of_plan(&spoga, &spoga.plan_gemm(&sh));
        let manual = e.laser_j + e.standing_j + e.dac_j + e.adc_j + e.bpca_j + e.deas_j + e.sram_j;
        assert!((e.total_j() - manual).abs() < 1e-18);
        assert!(e.total_j() > 0.0);
        assert_eq!(e.deas_j, 0.0);
        assert_eq!(e.sram_j, 0.0);
    }

    #[test]
    fn baseline_pays_deas_and_sram_energy() {
        let (_, holy) = cores();
        let sh = GemmShape { t: 16, k: 100, c: 16, groups: 1 };
        let e = EnergyBreakdown::of_plan(&holy, &holy.plan_gemm(&sh));
        assert!(e.deas_j > 0.0);
        assert!(e.sram_j > 0.0);
    }

    #[test]
    fn add_accumulates() {
        let (spoga, _) = cores();
        let sh = GemmShape { t: 16, k: 100, c: 16, groups: 1 };
        let e1 = EnergyBreakdown::of_plan(&spoga, &spoga.plan_gemm(&sh));
        let mut acc = EnergyBreakdown::default();
        acc.add(&e1);
        acc.add(&e1);
        assert!((acc.total_j() - 2.0 * e1.total_j()).abs() < 1e-15);
    }
}
