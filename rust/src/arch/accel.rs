//! Accelerator = fleet of identical cores under an iso-power budget.

use crate::arch::core::Core;
use crate::optics::link_budget::ArchClass;
use crate::units::DataRate;
use crate::Result;

/// A complete accelerator: `cores` identical physical cores.
///
/// The paper does not publish per-accelerator core counts; following the
/// usual practice in this literature we normalize competitors to an equal
/// **total laser wall-plug budget** (DESIGN.md §5.2). Baselines allocate
/// cores in quadruplets (four INT4 slice cores complete one INT8 GEMM).
#[derive(Debug, Clone)]
pub struct Accelerator {
    /// Variant name, e.g. "SPOGA_10".
    pub name: String,
    /// Physical core count.
    pub cores: usize,
    /// The core design replicated across the fleet.
    pub core: Core,
}

/// Default iso-power laser budget, watts (wall-plug, whole accelerator).
pub const DEFAULT_LASER_BUDGET_W: f64 = 60.0;

impl Accelerator {
    /// Build an accelerator of `arch` at `dr` (10 dBm per-λ lasers) sized to
    /// `laser_budget_w` watts of total laser wall-plug power.
    pub fn iso_laser_power(arch: ArchClass, dr: DataRate, laser_budget_w: f64) -> Result<Self> {
        Self::iso_laser_power_at(arch, dr, 10.0, laser_budget_w)
    }

    /// Like [`Self::iso_laser_power`] with an explicit per-λ laser power
    /// (used for the paper's `_1 dBm` SPOGA variants).
    pub fn iso_laser_power_at(
        arch: ArchClass,
        dr: DataRate,
        laser_dbm: f64,
        laser_budget_w: f64,
    ) -> Result<Self> {
        let core = Core::design(arch, dr, laser_dbm)?;
        let per_core_w = core.inventory.lasers as f64
            * crate::devices::laser::Laser::with_power_dbm(laser_dbm).electrical_power_mw()
            * 1e-3;
        let mut cores = (laser_budget_w / per_core_w).floor() as usize;
        // Baselines work in slice quadruplets: round down to a multiple of 4.
        if matches!(arch, ArchClass::Maw | ArchClass::Amw) {
            cores -= cores % 4;
        }
        let cores = cores.max(match arch {
            ArchClass::Maw | ArchClass::Amw => 4,
            ArchClass::Mwa => 1,
        });
        Ok(Accelerator { name: core.variant_name(), cores, core })
    }

    /// Fixed-size accelerator (used by ablations).
    pub fn with_cores(core: Core, cores: usize) -> Self {
        Accelerator { name: core.variant_name(), cores, core }
    }

    /// Equal-core-count normalization (DESIGN.md §5.2): every competitor
    /// fields the same number of physical GEMM cores, as the paper's prior
    /// works do when comparing accelerators built from the same photonic
    /// real estate. This is the default for the Fig. 5 reproduction.
    pub fn equal_cores(arch: ArchClass, dr: DataRate, cores: usize) -> Result<Self> {
        let core = Core::design(arch, dr, 10.0)?;
        Ok(Accelerator { name: core.variant_name(), cores, core })
    }

    /// Equal-core variant at an explicit laser power (SPOGA `_1 dBm` rows).
    pub fn equal_cores_at(
        arch: ArchClass,
        dr: DataRate,
        laser_dbm: f64,
        cores: usize,
    ) -> Result<Self> {
        let core = Core::design(arch, dr, laser_dbm)?;
        Ok(Accelerator { name: core.variant_name(), cores, core })
    }

    /// Whole-accelerator die area (photonic + electronic), mm².
    pub fn area_mm2(&self) -> f64 {
        self.cores as f64 * self.core.area_mm2()
    }

    /// Electronic (CMOS) die area, mm² — the denominator of the paper's
    /// FPS/W/mm² metric (see [`Core::electronic_area_mm2`]).
    pub fn electronic_area_mm2(&self) -> f64 {
        self.cores as f64 * self.core.electronic_area_mm2()
    }

    /// Whole-accelerator peak power, W.
    pub fn peak_power_w(&self) -> f64 {
        self.cores as f64 * self.core.peak_power_mw() * 1e-3
    }

    /// Logical cores (units that retire whole INT8 GEMMs concurrently).
    pub fn logical_cores(&self) -> usize {
        match self.core.arch {
            ArchClass::Maw | ArchClass::Amw => self.cores / 4,
            ArchClass::Mwa => self.cores,
        }
    }

    /// Peak INT8 MAC throughput, ops/s.
    pub fn peak_int8_macs_per_s(&self) -> f64 {
        self.logical_cores() as f64 * self.core.int8_macs_per_step() as f64 * self.core.dr.hz()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iso_power_gives_spoga_more_cores() {
        let s = Accelerator::iso_laser_power(ArchClass::Mwa, DataRate::Gs10, 60.0).unwrap();
        let h = Accelerator::iso_laser_power(ArchClass::Maw, DataRate::Gs10, 60.0).unwrap();
        // SPOGA cores carry only 4 lasers each; HOLYLIGHT_10 needs 15.
        assert!(s.cores > h.cores);
    }

    #[test]
    fn equal_cores_normalization_exact() {
        for arch in [ArchClass::Mwa, ArchClass::Maw, ArchClass::Amw] {
            let a = Accelerator::equal_cores(arch, DataRate::Gs5, 64).unwrap();
            assert_eq!(a.cores, 64);
        }
        let s1 = Accelerator::equal_cores_at(ArchClass::Mwa, DataRate::Gs1, 1.0, 64).unwrap();
        assert_eq!(s1.core.n, 94); // Table I MWA (1dBm) @ 1 GS/s
    }

    #[test]
    fn baseline_core_count_is_quadruplet_aligned() {
        for arch in [ArchClass::Maw, ArchClass::Amw] {
            let a = Accelerator::iso_laser_power(arch, DataRate::Gs5, 60.0).unwrap();
            assert_eq!(a.cores % 4, 0, "{}", a.name);
            assert!(a.logical_cores() >= 1);
        }
    }

    #[test]
    fn spoga_peak_throughput_beats_baselines_iso_power() {
        // The headline mechanism: per unit laser power SPOGA retires far
        // more INT8 MACs (no ×4 slice-core tax, higher N).
        let budget = 60.0;
        let s = Accelerator::iso_laser_power(ArchClass::Mwa, DataRate::Gs10, budget).unwrap();
        let h = Accelerator::iso_laser_power(ArchClass::Maw, DataRate::Gs10, budget).unwrap();
        let d = Accelerator::iso_laser_power(ArchClass::Amw, DataRate::Gs10, budget).unwrap();
        assert!(s.peak_int8_macs_per_s() > 5.0 * h.peak_int8_macs_per_s());
        assert!(s.peak_int8_macs_per_s() > 5.0 * d.peak_int8_macs_per_s());
    }

    #[test]
    fn area_and_power_scale_with_cores() {
        let core = Core::design(ArchClass::Mwa, DataRate::Gs5, 10.0).unwrap();
        let a1 = Accelerator::with_cores(core.clone(), 1);
        let a4 = Accelerator::with_cores(core, 4);
        assert!((a4.area_mm2() / a1.area_mm2() - 4.0).abs() < 1e-9);
        assert!((a4.peak_power_w() / a1.peak_power_w() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn minimum_core_counts_respected() {
        // Tiny budget still yields a functional accelerator.
        let h = Accelerator::iso_laser_power(ArchClass::Maw, DataRate::Gs1, 0.1).unwrap();
        assert_eq!(h.cores, 4);
        let s = Accelerator::iso_laser_power(ArchClass::Mwa, DataRate::Gs1, 0.1).unwrap();
        assert_eq!(s.cores, 1);
    }
}
