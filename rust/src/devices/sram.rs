//! SRAM buffer model for intermediate bit-slice matrices (baselines only).
//!
//! In the prior-work dataflow (paper Fig. 2(a)) the four INT4 intermediate
//! result matrices are digitized and **stored** before DEAS post-processing.
//! SPOGA's extended optical-analog dataflow removes this storage entirely
//! (paper §III-B). The model charges read+write energy per byte and a
//! banked-array area.

use crate::units::DataRate;

/// Small on-chip SRAM scratch buffer.
#[derive(Debug, Clone, Copy)]
pub struct SramBuffer {
    /// Capacity in KiB (per core, sized to hold intermediate tiles).
    pub capacity_kib: f64,
    /// Access energy, pJ/byte (read or write). ~0.08 pJ/B for small arrays
    /// in 28–45 nm nodes (CACTI-class figure used by refs [1][2]).
    pub energy_per_byte_pj: f64,
    /// Leakage power, mW.
    pub leakage_mw: f64,
    /// Area, mm² (≈0.06 mm² per 8 KiB bank in 28 nm).
    pub area_mm2: f64,
}

impl SramBuffer {
    /// Rows of intermediate results buffered before DEAS recombination —
    /// one output feature-map row at the largest post-stem resolution
    /// (112×112) of the benchmark CNNs.
    pub const TILE_ROWS: usize = 112;

    /// Buffer sized for a DEAS working tile: `m` output channels × 16-bit
    /// intermediates × 4 slices × [`Self::TILE_ROWS`] rows.
    pub fn for_outputs(m: usize) -> Self {
        let bytes = (m * 2 * 4 * Self::TILE_ROWS) as f64;
        let capacity_kib = (bytes / 1024.0).max(1.0);
        SramBuffer {
            capacity_kib,
            energy_per_byte_pj: 0.08,
            leakage_mw: 0.05 * capacity_kib,
            area_mm2: 0.0075 * capacity_kib,
        }
    }

    /// Dynamic power when writing+reading `bytes_per_symbol` every symbol, mW.
    pub fn dynamic_power_mw(&self, dr: DataRate, bytes_per_symbol: f64) -> f64 {
        // write + read = 2 accesses; pJ × GHz = mW.
        2.0 * self.energy_per_byte_pj * bytes_per_symbol * dr.gs()
    }

    /// Energy to store + load one intermediate matrix of `bytes` bytes, pJ.
    pub fn roundtrip_energy_pj(&self, bytes: f64) -> f64 {
        2.0 * self.energy_per_byte_pj * bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sized_for_outputs_minimum_1kib() {
        let s = SramBuffer::for_outputs(16);
        assert!(s.capacity_kib >= 1.0);
    }

    #[test]
    fn dynamic_power_scales_linearly() {
        let s = SramBuffer::for_outputs(64);
        let p = s.dynamic_power_mw(DataRate::Gs1, 10.0);
        let p2 = s.dynamic_power_mw(DataRate::Gs1, 20.0);
        assert!((p2 / p - 2.0).abs() < 1e-9);
    }

    #[test]
    fn roundtrip_energy_counts_two_accesses() {
        let s = SramBuffer::for_outputs(16);
        assert!((s.roundtrip_energy_pj(100.0) - 2.0 * 0.08 * 100.0).abs() < 1e-9);
    }

    #[test]
    fn bigger_buffer_bigger_area_and_leakage() {
        let a = SramBuffer::for_outputs(16);
        let b = SramBuffer::for_outputs(1024);
        assert!(b.area_mm2 > a.area_mm2);
        assert!(b.leakage_mw > a.leakage_mw);
    }
}
