//! Laser-diode model.
//!
//! Each GEMM core employs N (baselines) or 4 (SPOGA OAME carrier set) laser
//! diodes generating distinct wavelength channels (paper §II-A). Wall-plug
//! efficiency converts the *optical* power demanded by the link budget into
//! the *electrical* power the FPS/W metric charges.

use crate::units::{dbm_to_mw, mw_to_dbm};

/// Parametric laser-diode model.
#[derive(Debug, Clone, Copy)]
pub struct Laser {
    /// Optical output power per wavelength channel, dBm.
    pub power_dbm: f64,
    /// Wall-plug efficiency (optical out / electrical in). Refs [1][12]
    /// assume 0.2 for integrated DFB combs.
    pub wall_plug_efficiency: f64,
    /// Footprint per diode, mm² (hybrid-integrated III-V on Si).
    pub area_mm2: f64,
}

impl Laser {
    /// Laser with literature-default efficiency/footprint at `power_dbm`.
    pub fn with_power_dbm(power_dbm: f64) -> Self {
        Laser { power_dbm, wall_plug_efficiency: 0.2, area_mm2: 2.5e-2 }
    }

    /// Optical output power, mW.
    pub fn optical_power_mw(&self) -> f64 {
        dbm_to_mw(self.power_dbm)
    }

    /// Electrical power drawn, mW.
    pub fn electrical_power_mw(&self) -> f64 {
        self.optical_power_mw() / self.wall_plug_efficiency
    }

    /// Build the laser that *just closes* a link budget requiring
    /// `required_optical_mw` at the chip input.
    pub fn for_required_optical_mw(required_optical_mw: f64) -> Self {
        Self::with_power_dbm(mw_to_dbm(required_optical_mw))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn electrical_exceeds_optical_by_efficiency() {
        let l = Laser::with_power_dbm(10.0);
        assert!((l.optical_power_mw() - 10.0).abs() < 1e-9);
        assert!((l.electrical_power_mw() - 50.0).abs() < 1e-6);
    }

    #[test]
    fn for_required_optical_roundtrips() {
        let l = Laser::for_required_optical_mw(3.2);
        assert!((l.optical_power_mw() - 3.2).abs() < 1e-9);
    }

    #[test]
    fn zero_dbm_is_one_mw() {
        let l = Laser::with_power_dbm(0.0);
        assert!((l.optical_power_mw() - 1.0).abs() < 1e-12);
    }
}
