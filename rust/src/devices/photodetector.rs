//! Balanced photodetector (BPD) model.
//!
//! The summation block of every incoherent GEMM core couples a BPD with
//! either a trans-impedance (TIA) or a time-integrating receiver
//! (paper §II-A, block 5). The BPD subtracts the +ve and −ve rail
//! photocurrents, which is how signed values are represented optically.
//!
//! The *sensitivity* (minimum received optical power for the target analog
//! resolution) anchors the link budget. Two receiver families matter here:
//!
//! * **TIA receiver** (HOLYLIGHT, DEAPCNN): noise bandwidth tracks the symbol
//!   rate, so sensitivity degrades as `10·log10(BR)` — doubling the rate
//!   costs 3 dB.
//! * **Time-integrating receiver / BPCA** (SPOGA): charge integration over
//!   the symbol slot narrows the effective noise bandwidth; the sensitivity
//!   penalty empirically follows `≈5·log10(BR)` (see DESIGN.md §5.1 — this is
//!   the slope the paper's own Table I implies for the MWA rows).

use crate::units::DataRate;

/// Receiver family attached to a balanced photodetector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReceiverKind {
    /// Trans-impedance amplifier front end (baseline architectures).
    Tia,
    /// Time-integrating front end (SPOGA's BPCA).
    TimeIntegrating,
}

/// Balanced photodetector + receiver front-end model.
#[derive(Debug, Clone, Copy)]
pub struct BalancedPhotodetector {
    /// Receiver family (sets the sensitivity-vs-rate law).
    pub kind: ReceiverKind,
    /// Sensitivity at 1 GS/s for 4-bit analog resolution, dBm.
    /// Ref [2] assumes −28 dBm-class APD/TIA receivers at 1 GS/s.
    pub sensitivity_1gs_dbm: f64,
    /// Responsivity, A/W (for charge-domain energy accounting).
    pub responsivity_a_per_w: f64,
    /// Footprint (photodiode pair + analog front end), mm².
    pub area_mm2: f64,
    /// Static analog power of the front end, mW.
    pub static_power_mw: f64,
}

impl BalancedPhotodetector {
    /// TIA-receiver BPD with literature-default parameters.
    pub fn tia() -> Self {
        BalancedPhotodetector {
            kind: ReceiverKind::Tia,
            sensitivity_1gs_dbm: -28.0,
            responsivity_a_per_w: 1.2,
            area_mm2: 6.0e-3,
            static_power_mw: 1.1, // TIA bias, ref [2]
        }
    }

    /// Time-integrating BPD (the front half of a BPCA).
    pub fn time_integrating() -> Self {
        BalancedPhotodetector {
            kind: ReceiverKind::TimeIntegrating,
            sensitivity_1gs_dbm: -28.0,
            responsivity_a_per_w: 1.2,
            area_mm2: 6.0e-3,
            static_power_mw: 0.4, // no TIA; integrator bias only
        }
    }

    /// Sensitivity at data rate `dr`, dBm.
    ///
    /// `Tia`: `S(BR) = S(1) + 10·log10(BR)` (thermal-noise bandwidth ∝ BR).
    /// `TimeIntegrating`: `S(BR) = S(1) + 5·log10(BR)` (integration gain).
    pub fn sensitivity_dbm(&self, dr: DataRate) -> f64 {
        let br = dr.gs();
        match self.kind {
            ReceiverKind::Tia => self.sensitivity_1gs_dbm + 10.0 * br.log10(),
            ReceiverKind::TimeIntegrating => self.sensitivity_1gs_dbm + 5.0 * br.log10(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tia_sensitivity_degrades_10log10() {
        let pd = BalancedPhotodetector::tia();
        let s1 = pd.sensitivity_dbm(DataRate::Gs1);
        let s5 = pd.sensitivity_dbm(DataRate::Gs5);
        let s10 = pd.sensitivity_dbm(DataRate::Gs10);
        assert!((s5 - s1 - 10.0 * 5f64.log10()).abs() < 1e-9);
        assert!((s10 - s1 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn integrating_sensitivity_degrades_half_as_fast() {
        let tia = BalancedPhotodetector::tia();
        let bpca = BalancedPhotodetector::time_integrating();
        let d_tia = tia.sensitivity_dbm(DataRate::Gs10) - tia.sensitivity_dbm(DataRate::Gs1);
        let d_int = bpca.sensitivity_dbm(DataRate::Gs10) - bpca.sensitivity_dbm(DataRate::Gs1);
        assert!((d_tia - 2.0 * d_int).abs() < 1e-9);
    }

    #[test]
    fn sensitivity_at_1gs_is_base_value() {
        for pd in [BalancedPhotodetector::tia(), BalancedPhotodetector::time_integrating()] {
            assert!((pd.sensitivity_dbm(DataRate::Gs1) - pd.sensitivity_1gs_dbm).abs() < 1e-12);
        }
    }

    #[test]
    fn integrating_front_end_draws_less_static_power() {
        assert!(
            BalancedPhotodetector::time_integrating().static_power_mw
                < BalancedPhotodetector::tia().static_power_mw
        );
    }
}
