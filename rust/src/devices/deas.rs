//! DEAS — Digital Electronic Shifter-and-Adder block (baseline architectures).
//!
//! Prior bit-sliced designs (paper §II-C/D, Fig. 2(a)) post-process the four
//! INT4 intermediate matrices digitally: each of the four values is shifted
//! by its radix weight (<<8, <<4, <<4, <<0) and the four are summed. SPOGA
//! eliminates this block entirely; it exists here so the baselines pay its
//! latency/energy/area, and for the `ablation_dataflow` bench which forces it
//! back onto SPOGA.

use crate::units::DataRate;

/// Parametric shifter+adder post-processing unit (per output channel).
#[derive(Debug, Clone, Copy)]
pub struct Deas {
    /// Energy per final output assembled (4 shifts + 3 adds at 16-bit), pJ.
    /// ~45 nm-class digital logic: ≈0.05 pJ per 16-bit add/shift pair.
    pub energy_per_output_pj: f64,
    /// Area per DEAS unit, mm².
    pub area_mm2: f64,
    /// Pipeline latency through the unit, cycles of the symbol clock.
    pub latency_cycles: u64,
}

impl Default for Deas {
    fn default() -> Self {
        Deas { energy_per_output_pj: 0.35, area_mm2: 4.0e-4, latency_cycles: 2 }
    }
}

impl Deas {
    /// Power when assembling one output per symbol at rate `dr`, mW.
    pub fn power_mw(&self, dr: DataRate) -> f64 {
        // pJ × GHz = mW.
        self.energy_per_output_pj * dr.gs()
    }

    /// Latency contribution in seconds for a pipeline of `outputs` results
    /// (pipelined: fill latency + one output per cycle is already counted by
    /// the core schedule; only the fill is extra).
    pub fn fill_latency_s(&self, dr: DataRate) -> f64 {
        self.latency_cycles as f64 * dr.step_seconds()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_scales_with_rate() {
        let d = Deas::default();
        assert!((d.power_mw(DataRate::Gs10) / d.power_mw(DataRate::Gs1) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn fill_latency_is_cycles_over_rate() {
        let d = Deas::default();
        assert!((d.fill_latency_s(DataRate::Gs1) - 2e-9).abs() < 1e-15);
        assert!((d.fill_latency_s(DataRate::Gs10) - 0.2e-9).abs() < 1e-15);
    }

    #[test]
    fn default_magnitudes_sane() {
        let d = Deas::default();
        assert!(d.energy_per_output_pj > 0.0 && d.energy_per_output_pj < 10.0);
        assert!(d.area_mm2 > 0.0 && d.area_mm2 < 0.01);
    }
}
