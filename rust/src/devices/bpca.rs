//! Balanced photo-charge accumulator (BPCA) — the paper's key enhanced device.
//!
//! A BPCA (paper §III-A-3, Fig. 3(b)) is a balanced photodetector feeding a
//! time-integrating receiver with a **bank of selectable accumulation
//! capacitors**. Two properties make it the heart of SPOGA:
//!
//! 1. **Homodyne analog summation** — all optical signals arriving on the
//!    same carrier wavelength superpose incoherently on the photodiode; their
//!    photocurrents integrate onto the selected capacitor. Summation over
//!    both the spatial dimension (many OAMEs sharing a lane) and the temporal
//!    dimension (multi-pass K-chunk accumulation) is therefore *free* in the
//!    charge domain.
//! 2. **In-transduction positional weighting** — selecting a capacitor of
//!    `C₀/16²`, `C₀/16` or `C₀` scales the output voltage (`V = Q/C`) by
//!    `16²`, `16` or `1` for the same accumulated charge, implementing the
//!    radix weights of the INT4 nibble products without any digital shifter.

use crate::units::DataRate;

/// Radix position of a nibble-product lane (paper Fig. 2(c)).
///
/// `Hi` = MSN·MSN (weight 16²), `Mid` = cross terms (16¹), `Lo` = LSN·LSN (16⁰).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RadixLane {
    /// 16² lane — λ1 (MSN × MSN).
    Hi,
    /// 16¹ lane — λ2 and λ3 multiplexed (MSN × LSN, LSN × MSN).
    Mid,
    /// 16⁰ lane — λ4 (LSN × LSN).
    Lo,
}

impl RadixLane {
    /// All three lanes, most-significant first.
    pub const ALL: [RadixLane; 3] = [RadixLane::Hi, RadixLane::Mid, RadixLane::Lo];

    /// Integer positional weight (16^k).
    #[inline]
    pub fn weight(self) -> i64 {
        match self {
            RadixLane::Hi => 256,
            RadixLane::Mid => 16,
            RadixLane::Lo => 1,
        }
    }

    /// Capacitor ratio `C/C₀` that realizes [`Self::weight`] as voltage gain.
    #[inline]
    pub fn capacitor_ratio(self) -> f64 {
        1.0 / self.weight() as f64
    }
}

/// Parametric BPCA model.
#[derive(Debug, Clone, Copy)]
pub struct Bpca {
    /// Base accumulation capacitance C₀, fF. Ref [1] uses ~50 fF class
    /// integration caps for GS/s photo-charge accumulation.
    pub base_cap_ff: f64,
    /// Static power of the integrator front end, mW.
    pub static_power_mw: f64,
    /// Energy per accumulate-and-reset cycle, pJ (switching + reset).
    pub energy_per_cycle_pj: f64,
    /// Footprint (PD pair + cap bank + switches), mm².
    pub area_mm2: f64,
}

impl Default for Bpca {
    fn default() -> Self {
        Bpca {
            base_cap_ff: 50.0,
            static_power_mw: 0.4,
            energy_per_cycle_pj: 0.18, // CV² at ~1V swing + reset, ref [1]
            area_mm2: 8.0e-3,          // PD + 3-cap bank + switch matrix
        }
    }
}

impl Bpca {
    /// Voltage gain realized by selecting the capacitor for `lane`.
    pub fn voltage_gain(&self, lane: RadixLane) -> f64 {
        lane.weight() as f64
    }

    /// Dynamic power at symbol rate `dr` when one accumulate/reset happens
    /// per `cycles_per_result` symbols (a dot product integrates for the
    /// whole K-pass before resetting).
    pub fn dynamic_power_mw(&self, dr: DataRate, cycles_per_result: usize) -> f64 {
        let results_per_s = dr.hz() / cycles_per_result.max(1) as f64;
        // pJ * results/s = µW * 1e-6 ... : pJ/result × results/s = 1e-12 J × Hz = W.
        self.energy_per_cycle_pj * 1e-12 * results_per_s * 1e3
    }

    /// Total power (static + dynamic), mW.
    pub fn power_mw(&self, dr: DataRate, cycles_per_result: usize) -> f64 {
        self.static_power_mw + self.dynamic_power_mw(dr, cycles_per_result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_weights_are_radix_powers() {
        assert_eq!(RadixLane::Hi.weight(), 256);
        assert_eq!(RadixLane::Mid.weight(), 16);
        assert_eq!(RadixLane::Lo.weight(), 1);
    }

    #[test]
    fn capacitor_ratio_inverts_weight() {
        for lane in RadixLane::ALL {
            let v = Bpca::default().voltage_gain(lane);
            assert!((lane.capacitor_ratio() * v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn longer_integration_lowers_dynamic_power() {
        let b = Bpca::default();
        let p1 = b.dynamic_power_mw(DataRate::Gs10, 1);
        let p249 = b.dynamic_power_mw(DataRate::Gs10, 249);
        assert!(p249 < p1);
        assert!((p1 / p249 - 249.0).abs() < 1e-6);
    }

    #[test]
    fn dynamic_power_magnitude_sane() {
        // 0.18 pJ per cycle at 1 GS/s, reset every cycle → 0.18 mW.
        let b = Bpca::default();
        assert!((b.dynamic_power_mw(DataRate::Gs1, 1) - 0.18).abs() < 1e-9);
    }

    #[test]
    fn zero_cycles_clamped() {
        let b = Bpca::default();
        assert_eq!(
            b.dynamic_power_mw(DataRate::Gs1, 0),
            b.dynamic_power_mw(DataRate::Gs1, 1)
        );
    }
}
