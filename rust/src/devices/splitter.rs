//! Splitter/combiner tree model (fan-out and aggregation blocks).
//!
//! The splitting block copies the N wavelength signals into M waveguides
//! (fan-out M), paying the fundamental `10·log10(M)` power division plus an
//! excess loss per 1×2 stage; the aggregation block multiplexes N signals
//! per waveguide (paper §II-A, blocks 1–2).

use crate::units::ratio_to_db;

/// Binary-tree optical splitter with per-stage excess loss.
#[derive(Debug, Clone, Copy)]
pub struct SplitterTree {
    /// Excess (non-fundamental) loss per 1×2 stage, dB. ~0.1–0.2 dB for
    /// MMI/Y-branch splitters; refs [2][12] use 0.18 dB.
    pub excess_loss_per_stage_db: f64,
    /// Area per 1×2 element, mm².
    pub element_area_mm2: f64,
}

impl Default for SplitterTree {
    fn default() -> Self {
        SplitterTree { excess_loss_per_stage_db: 0.18, element_area_mm2: 1.0e-4 }
    }
}

impl SplitterTree {
    /// Total insertion loss for a 1×`fanout` split, dB
    /// (fundamental `10·log10(fanout)` + excess per stage).
    pub fn loss_db(&self, fanout: usize) -> f64 {
        if fanout <= 1 {
            return 0.0;
        }
        let stages = (fanout as f64).log2().ceil();
        ratio_to_db(fanout as f64) + self.excess_loss_per_stage_db * stages
    }

    /// Number of 1×2 elements in a 1×`fanout` tree.
    pub fn element_count(&self, fanout: usize) -> usize {
        fanout.saturating_sub(1)
    }

    /// Total tree area, mm².
    pub fn area_mm2(&self, fanout: usize) -> f64 {
        self.element_count(fanout) as f64 * self.element_area_mm2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unity_fanout_is_lossless() {
        assert_eq!(SplitterTree::default().loss_db(1), 0.0);
        assert_eq!(SplitterTree::default().loss_db(0), 0.0);
    }

    #[test]
    fn fanout_two_is_3db_plus_excess() {
        let t = SplitterTree::default();
        assert!((t.loss_db(2) - (3.0103 + 0.18)).abs() < 1e-3);
    }

    #[test]
    fn loss_monotonic_in_fanout() {
        let t = SplitterTree::default();
        let mut prev = 0.0;
        for m in [2usize, 4, 8, 16, 32, 64] {
            let l = t.loss_db(m);
            assert!(l > prev);
            prev = l;
        }
    }

    #[test]
    fn element_count_is_fanout_minus_one() {
        let t = SplitterTree::default();
        assert_eq!(t.element_count(16), 15);
        assert_eq!(t.element_count(1), 0);
        assert!((t.area_mm2(16) - 15.0 * 1.0e-4).abs() < 1e-12);
    }
}
