//! Microring resonator (MRR) model.
//!
//! MRRs appear in two roles in incoherent photonic GEMM cores (paper §II-A):
//! as **modulators** (MRMs) imprinting input values onto wavelength channels,
//! and as **weight-bank** elements applying the weight factor. Both roles
//! share the same physical footprint/tuning model; they differ in drive
//! electronics (an MRM needs a DAC at the symbol rate, a weight MRR is
//! reprogrammed only when weights change).

use crate::units::DataRate;

/// Role an MRR plays in a GEMM core; affects drive power accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MrrRole {
    /// Input modulator (MRM) — driven by a DAC every symbol.
    Modulator,
    /// Weight-bank ring — reprogrammed at weight-update cadence only.
    Weight,
    /// Passive filter/mux ring (aggregation blocks).
    Filter,
}

/// Parametric microring model.
///
/// Loss figures feed [`crate::optics::link_budget`]; power/area figures feed
/// the per-core inventories in [`crate::arch`].
#[derive(Debug, Clone, Copy)]
pub struct Mrr {
    /// Footprint including heater + drive pads, in mm².
    /// ~20 µm pitch ring with thermal tuner ≈ 1.5e-4 mm² (ref [12] assumes
    /// 10 µm radius rings; we include pad overhead).
    pub area_mm2: f64,
    /// Average thermal-tuning power per ring, mW. Refs [1][2] budget
    /// 0.06–0.3 mW/ring for stabilization; we use the mid value.
    pub tuning_power_mw: f64,
    /// Insertion loss when the signal is *dropped/modulated* by this ring, dB.
    pub insertion_loss_db: f64,
    /// Through (pass-by) loss for non-resonant wavelengths, dB.
    /// This is the term that multiplies with vector size N in the budget.
    pub through_loss_db: f64,
    /// Role (affects drive-energy accounting, not optics).
    pub role: MrrRole,
}

impl Mrr {
    /// Modulator-role MRR with literature-default parameters.
    pub fn modulator() -> Self {
        Mrr {
            area_mm2: 1.5e-4,
            tuning_power_mw: 0.12,
            insertion_loss_db: 1.0, // OOK/PAM MRM IL, ref [2]
            through_loss_db: 0.02,
            role: MrrRole::Modulator,
        }
    }

    /// Weight-bank MRR with literature-default parameters.
    pub fn weight() -> Self {
        Mrr {
            area_mm2: 1.5e-4,
            tuning_power_mw: 0.12,
            insertion_loss_db: 1.0,
            through_loss_db: 0.02,
            role: MrrRole::Weight,
        }
    }

    /// Passive filter ring (mux/demux) with lower drop loss.
    pub fn filter() -> Self {
        Mrr {
            area_mm2: 1.5e-4,
            tuning_power_mw: 0.06,
            insertion_loss_db: 0.5,
            through_loss_db: 0.02,
            role: MrrRole::Filter,
        }
    }

    /// Dynamic drive power in mW for this ring at symbol rate `dr`.
    ///
    /// Modulators pay CV²f drive power scaling linearly with the symbol rate
    /// (≈0.05 mW per GS/s for a depletion-mode MRM, ref [2]); weight/filter
    /// rings only pay tuning power, which is already accounted separately.
    pub fn drive_power_mw(&self, dr: DataRate) -> f64 {
        match self.role {
            MrrRole::Modulator => 0.05 * dr.gs(),
            MrrRole::Weight | MrrRole::Filter => 0.0,
        }
    }

    /// Total standing power (tuning + static bias), mW.
    pub fn static_power_mw(&self) -> f64 {
        self.tuning_power_mw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_losses_are_positive_and_small() {
        for m in [Mrr::modulator(), Mrr::weight(), Mrr::filter()] {
            assert!(m.insertion_loss_db > 0.0 && m.insertion_loss_db < 3.0);
            assert!(m.through_loss_db > 0.0 && m.through_loss_db < 0.1);
            assert!(m.area_mm2 > 0.0);
        }
    }

    #[test]
    fn modulator_drive_power_scales_with_rate() {
        let m = Mrr::modulator();
        let p1 = m.drive_power_mw(DataRate::Gs1);
        let p10 = m.drive_power_mw(DataRate::Gs10);
        assert!(p10 > p1);
        assert!((p10 / p1 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn weight_ring_has_no_symbol_rate_drive_power() {
        assert_eq!(Mrr::weight().drive_power_mw(DataRate::Gs10), 0.0);
        assert_eq!(Mrr::filter().drive_power_mw(DataRate::Gs10), 0.0);
    }

    #[test]
    fn filter_drop_loss_below_modulator_loss() {
        assert!(Mrr::filter().insertion_loss_db < Mrr::modulator().insertion_loss_db);
    }
}
