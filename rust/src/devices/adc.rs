//! Analog-to-digital converter model — paper **Table II** (ADC rows).
//!
//! Each architecture digitizes dot-product results with one ADC per output
//! channel at the symbol rate. The paper sources three design points:
//!
//! | BR (GS/s) | Area (mm²) | Power (mW) | source |
//! |---|---|---|---|
//! | 1  | 0.002 | 2.55 | [13] Oh et al., 8b 1GS/s SAR-flash |
//! | 5  | 0.021 | 11   | [14] Shu, 6b 3GS/s dynamic flash (scaled) |
//! | 10 | 0.103 | 29   | [15] Guo et al., 5GS/s TI-SAR (interleaved ×2) |

use crate::units::DataRate;

/// ADC design point (one of the paper's Table II rows).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Adc {
    /// Sample rate this converter design point supports.
    pub rate: DataRate,
    /// Die area, mm².
    pub area_mm2: f64,
    /// Power, mW.
    pub power_mw: f64,
    /// Nominal output resolution, bits.
    pub bits: u32,
}

impl Adc {
    /// Table II design point for data rate `dr`.
    pub fn for_rate(dr: DataRate) -> Self {
        match dr {
            DataRate::Gs1 => Adc { rate: dr, area_mm2: 0.002, power_mw: 2.55, bits: 8 },
            DataRate::Gs5 => Adc { rate: dr, area_mm2: 0.021, power_mw: 11.0, bits: 8 },
            DataRate::Gs10 => Adc { rate: dr, area_mm2: 0.103, power_mw: 29.0, bits: 8 },
        }
    }

    /// Energy per conversion, pJ.
    pub fn energy_per_conversion_pj(&self) -> f64 {
        // mW / GHz = pJ.
        self.power_mw / self.rate.gs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_adc_rows_pinned() {
        let a1 = Adc::for_rate(DataRate::Gs1);
        assert_eq!((a1.area_mm2, a1.power_mw), (0.002, 2.55));
        let a5 = Adc::for_rate(DataRate::Gs5);
        assert_eq!((a5.area_mm2, a5.power_mw), (0.021, 11.0));
        let a10 = Adc::for_rate(DataRate::Gs10);
        assert_eq!((a10.area_mm2, a10.power_mw), (0.103, 29.0));
    }

    #[test]
    fn faster_adcs_cost_more_power_and_area() {
        let (a1, a5, a10) = (
            Adc::for_rate(DataRate::Gs1),
            Adc::for_rate(DataRate::Gs5),
            Adc::for_rate(DataRate::Gs10),
        );
        assert!(a1.power_mw < a5.power_mw && a5.power_mw < a10.power_mw);
        assert!(a1.area_mm2 < a5.area_mm2 && a5.area_mm2 < a10.area_mm2);
    }

    #[test]
    fn energy_per_conversion_reasonable() {
        // 2.55 mW / 1 GS/s = 2.55 pJ.
        assert!((Adc::for_rate(DataRate::Gs1).energy_per_conversion_pj() - 2.55).abs() < 1e-9);
        // 29 mW / 10 GS/s = 2.9 pJ.
        assert!((Adc::for_rate(DataRate::Gs10).energy_per_conversion_pj() - 2.9).abs() < 1e-9);
    }
}
