//! Parametric models of the photonic and electronic components that compose
//! the accelerators compared in the paper.
//!
//! Every model here is *behavioural + parametric*: it exposes the area, power
//! and (where relevant) latency/energy figures that the transaction-level
//! simulator ([`crate::sim`]) aggregates, plus the loss/sensitivity figures
//! the link-budget solver ([`crate::optics`]) consumes. Default parameter
//! values come from the paper (Table II for converters) and from the device
//! assumptions of its modelling references ([1] SCONNA, [2] TCAD'22,
//! [12] Al-Qadasi et al.); each constant documents its provenance.

pub mod adc;
pub mod bpca;
pub mod dac;
pub mod deas;
pub mod laser;
pub mod mrr;
pub mod photodetector;
pub mod splitter;
pub mod sram;

pub use adc::Adc;
pub use bpca::Bpca;
pub use dac::Dac;
pub use deas::Deas;
pub use laser::Laser;
pub use mrr::{Mrr, MrrRole};
pub use photodetector::BalancedPhotodetector;
pub use splitter::SplitterTree;
pub use sram::SramBuffer;
