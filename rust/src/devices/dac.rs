//! Digital-to-analog converter model — paper **Table II** (DAC rows).
//!
//! DACs drive the MRR modulators (one per modulated value per symbol) and
//! reprogram weight banks. Table II design points:
//!
//! | BR (GS/s) | Area (mm²) | Power (mW) | source |
//! |---|---|---|---|
//! | 1  | 0.00007 | 0.12 | [16] Eslahi et al., 4b 22nm FDSOI |
//! | 5  | 0.06    | 26   | [17] Sedighi et al., 8b 5GS/s |
//! | 10 | 0.06    | 30   | [18] Juanda et al., 4b 10GS/s single-core |

use crate::units::DataRate;

/// DAC design point (one of the paper's Table II rows).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Dac {
    /// Sample rate this converter design point supports.
    pub rate: DataRate,
    /// Die area, mm².
    pub area_mm2: f64,
    /// Power, mW.
    pub power_mw: f64,
    /// Nominal resolution, bits (4-bit analog operands).
    pub bits: u32,
}

impl Dac {
    /// Table II design point for data rate `dr`.
    pub fn for_rate(dr: DataRate) -> Self {
        match dr {
            DataRate::Gs1 => Dac { rate: dr, area_mm2: 0.00007, power_mw: 0.12, bits: 4 },
            DataRate::Gs5 => Dac { rate: dr, area_mm2: 0.06, power_mw: 26.0, bits: 8 },
            DataRate::Gs10 => Dac { rate: dr, area_mm2: 0.06, power_mw: 30.0, bits: 4 },
        }
    }

    /// Energy per conversion, pJ.
    pub fn energy_per_conversion_pj(&self) -> f64 {
        self.power_mw / self.rate.gs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_dac_rows_pinned() {
        let d1 = Dac::for_rate(DataRate::Gs1);
        assert_eq!((d1.area_mm2, d1.power_mw), (0.00007, 0.12));
        let d5 = Dac::for_rate(DataRate::Gs5);
        assert_eq!((d5.area_mm2, d5.power_mw), (0.06, 26.0));
        let d10 = Dac::for_rate(DataRate::Gs10);
        assert_eq!((d10.area_mm2, d10.power_mw), (0.06, 30.0));
    }

    #[test]
    fn one_gs_dac_is_tiny() {
        let d = Dac::for_rate(DataRate::Gs1);
        assert!(d.area_mm2 < 1e-4);
        assert!(d.power_mw < 1.0);
    }

    #[test]
    fn energy_per_conversion_monotonic_sane() {
        // 0.12 pJ at 1 GS/s; 3 pJ at 10 GS/s.
        assert!((Dac::for_rate(DataRate::Gs1).energy_per_conversion_pj() - 0.12).abs() < 1e-9);
        assert!((Dac::for_rate(DataRate::Gs10).energy_per_conversion_pj() - 3.0).abs() < 1e-9);
    }
}
