//! Evaluation metrics and Fig. 5 data assembly.
//!
//! Builds the paper's three figures — FPS, FPS/W, FPS/W/mm² — over the
//! 4 CNNs × {SPOGA, HOLYLIGHT, DEAPCNN} × data-rate grid, with geometric
//! means matching the paper's gmean bars.

use crate::arch::accel::Accelerator;
use crate::dnn::models::CnnModel;
use crate::optics::link_budget::ArchClass;
use crate::sim::engine::simulate_frame;
use crate::units::DataRate;
use crate::Result;

/// `count ÷ denom`, 0.0 when the denominator is not positive — the shared
/// shape of every sim-FPS / FPS-per-watt identity (reported executions over
/// projected latency or energy). One definition, used by
/// [`CoordinatorStats`](crate::coordinator::CoordinatorStats),
/// [`LiveTelemetry`], [`ShardTelemetry`] and [`FleetTelemetry`] alike.
pub fn per_unit(count: u64, denom: f64) -> f64 {
    if denom <= 0.0 {
        return 0.0;
    }
    count as f64 / denom
}

/// Fraction of transduced lanes whose served integer matched the exact
/// result (1.0 when nothing reported lanes — an exact digital path).
pub fn exact_fraction(noise_events: u64, lanes: u64) -> f64 {
    if lanes == 0 {
        return 1.0;
    }
    1.0 - noise_events as f64 / lanes as f64
}

/// Geometric mean of a nonempty slice.
pub fn gmean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Which of the paper's three metrics a figure reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Fig. 5(a): frames per second.
    Fps,
    /// Fig. 5(b): FPS per watt.
    FpsPerW,
    /// Fig. 5(c): FPS per watt per mm².
    FpsPerWPerMm2,
}

impl Metric {
    /// Figure label in the paper.
    pub fn figure(self) -> &'static str {
        match self {
            Metric::Fps => "Fig. 5(a) FPS",
            Metric::FpsPerW => "Fig. 5(b) FPS/W",
            Metric::FpsPerWPerMm2 => "Fig. 5(c) FPS/W/mm2",
        }
    }
}

/// One accelerator variant's results across the benchmark CNNs.
#[derive(Debug, Clone)]
pub struct VariantResults {
    /// Variant name ("SPOGA_10", ...).
    pub name: String,
    /// Per-model metric values, in [`CnnModel::paper_benchmarks`] order.
    pub per_model: Vec<f64>,
    /// Geometric mean across models (the paper's gmean bar).
    pub gmean: f64,
}

/// A full figure: all variants at the requested data rates.
#[derive(Debug, Clone)]
pub struct Figure {
    /// Which metric this figure reports.
    pub metric: Metric,
    /// Model names, column order.
    pub models: Vec<String>,
    /// One row per accelerator variant.
    pub variants: Vec<VariantResults>,
}

impl Figure {
    /// Look up a variant row by name.
    pub fn variant(&self, name: &str) -> Option<&VariantResults> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// gmean ratio `a / b` between two variants.
    pub fn gmean_ratio(&self, a: &str, b: &str) -> Option<f64> {
        Some(self.variant(a)?.gmean / self.variant(b)?.gmean)
    }
}

/// Physical cores per accelerator in the Fig. 5 reproduction (equal-core
/// normalization; baselines group theirs into 16 slice quadruplets).
pub const FIG5_CORES: usize = 64;

/// Evaluate `metric` for all three architectures at the given `rates` under
/// the equal-core-count normalization (DESIGN.md §5.2).
pub fn build_figure(metric: Metric, rates: &[DataRate], cores: usize) -> Result<Figure> {
    let models = CnnModel::paper_benchmarks();
    let mut variants = Vec::new();
    for arch in [ArchClass::Mwa, ArchClass::Maw, ArchClass::Amw] {
        for &dr in rates {
            let accel = Accelerator::equal_cores(arch, dr, cores)?;
            variants.push(evaluate_variant(&accel, metric, &models));
        }
    }
    Ok(Figure {
        metric,
        models: models.iter().map(|m| m.name.to_string()).collect(),
        variants,
    })
}

/// Evaluate one accelerator variant across the benchmark models.
pub fn evaluate_variant(
    accel: &Accelerator,
    metric: Metric,
    models: &[CnnModel],
) -> VariantResults {
    // Fig. 5(c) divides by the electronic (CMOS) die area — the area the
    // paper's Table II models (see Core::electronic_area_mm2).
    let area = accel.electronic_area_mm2();
    let per_model: Vec<f64> = models
        .iter()
        .map(|m| {
            let f = simulate_frame(accel, &m.workload());
            match metric {
                Metric::Fps => f.fps(),
                Metric::FpsPerW => f.fps_per_w(),
                Metric::FpsPerWPerMm2 => f.fps_per_w_per_mm2(area),
            }
        })
        .collect();
    VariantResults { name: accel.name.clone(), gmean: gmean(&per_model), per_model }
}

/// Live serving telemetry aggregated from per-request
/// [`ExecReport`](crate::runtime::ExecReport)s — the bridge between the
/// coordinator's photonic-in-the-loop responses and the paper's headline
/// metrics: feed it the reports a traffic run produced and read off the
/// FPS / FPS-per-watt *that exact traffic* would see on the simulated
/// accelerator (vs. [`build_figure`]'s fixed benchmark suite).
#[derive(Debug, Clone, Copy, Default)]
pub struct LiveTelemetry {
    /// Reported executions folded in.
    pub frames: u64,
    /// Total projected latency, seconds.
    pub sim_latency_s: f64,
    /// Total projected energy, joules.
    pub energy_j: f64,
    /// Total analog lanes transduced.
    pub lanes: u64,
    /// Total noise-perturbed outputs.
    pub noise_events: u64,
}

impl LiveTelemetry {
    /// Fold in one execution's report.
    pub fn add(&mut self, r: &crate::runtime::ExecReport) {
        self.frames += 1;
        self.sim_latency_s += r.sim_latency_s;
        self.energy_j += r.energy_j;
        self.lanes += r.lanes;
        self.noise_events += r.noise_events;
    }

    /// Projected executions per second (frames ÷ projected latency).
    pub fn fps(&self) -> f64 {
        per_unit(self.frames, self.sim_latency_s)
    }

    /// Projected executions per joule — the paper's FPS/W identity.
    pub fn fps_per_w(&self) -> f64 {
        per_unit(self.frames, self.energy_j)
    }
}

/// One shard's stats, snapshotted for the fleet rollup. All counters are
/// read once per capture, so a [`FleetTelemetry`] built from distinct
/// shards sums each served request exactly once.
#[derive(Debug, Clone)]
pub struct ShardTelemetry {
    /// Shard display label (e.g. `shard0:software`, `shard1:photonic:SPOGA_10x64`).
    pub label: String,
    /// Requests accepted.
    pub requests: u64,
    /// Requests completed successfully.
    pub completed: u64,
    /// Requests failed.
    pub failed: u64,
    /// MLP micro-batches executed.
    pub batches: u64,
    /// Whole-CNN inferences served.
    pub cnn_frames: u64,
    /// Stacked same-model CNN micro-batches executed.
    pub cnn_batches: u64,
    /// Executions that carried photonic telemetry.
    pub sim_reports: u64,
    /// Total projected photonic latency, seconds.
    pub sim_latency_s: f64,
    /// Total projected photonic energy, joules.
    pub energy_j: f64,
    /// Analog lanes transduced.
    pub lanes: u64,
    /// Noise-perturbed outputs.
    pub noise_events: u64,
    /// Workers still in the shard leader's rotation (gauge — recovers when
    /// a revival respawns the pool).
    pub live_workers: u64,
    /// Worker-pool revivals the shard's leader has executed.
    pub revivals: u64,
    /// Submissions shed by admission control (full ingress queue or
    /// best-effort watermark). Sheds never enter `requests`, so
    /// `requests − (completed + failed)` stays the true in-flight depth.
    pub shed: u64,
    /// The best-effort subset of `shed` (QoS class accounting).
    pub shed_best_effort: u64,
    /// Requests failed typed ([`crate::Error::DeadlineExceeded`]) because
    /// their deadline expired before dispatch; a subset of `failed`.
    pub deadline_expired: u64,
}

impl ShardTelemetry {
    /// Snapshot one shard's live stats.
    pub fn capture(
        label: impl Into<String>,
        stats: &crate::coordinator::CoordinatorStats,
    ) -> Self {
        use std::sync::atomic::Ordering::Relaxed;
        ShardTelemetry {
            label: label.into(),
            requests: stats.requests.load(Relaxed),
            completed: stats.completed.load(Relaxed),
            failed: stats.failed.load(Relaxed),
            batches: stats.batches.load(Relaxed),
            cnn_frames: stats.cnn_frames.load(Relaxed),
            cnn_batches: stats.cnn_batches.load(Relaxed),
            sim_reports: stats.sim_reports.load(Relaxed),
            sim_latency_s: stats.sim_latency_total_s(),
            energy_j: stats.sim_energy_total_j(),
            lanes: stats.lanes.load(Relaxed),
            noise_events: stats.noise_events.load(Relaxed),
            live_workers: stats.live_workers.load(Relaxed),
            revivals: stats.revivals.load(Relaxed),
            shed: stats.shed.load(Relaxed),
            shed_best_effort: stats.shed_best_effort.load(Relaxed),
            deadline_expired: stats.deadline_expired.load(Relaxed),
        }
    }

    /// This shard's projected sim-FPS for the traffic it served.
    pub fn sim_fps(&self) -> f64 {
        if self.sim_latency_s <= 0.0 {
            return 0.0;
        }
        self.sim_reports as f64 / self.sim_latency_s
    }

    /// This shard's projected FPS per watt.
    pub fn sim_fps_per_w(&self) -> f64 {
        if self.energy_j <= 0.0 {
            return 0.0;
        }
        self.sim_reports as f64 / self.energy_j
    }

    /// Fraction of transduced lanes served exactly (1.0 for digital shards).
    pub fn served_exact_fraction(&self) -> f64 {
        if self.lanes == 0 {
            return 1.0;
        }
        1.0 - self.noise_events as f64 / self.lanes as f64
    }
}

/// Fleet-wide serving telemetry: per-shard
/// [`CoordinatorStats`](crate::coordinator::CoordinatorStats) snapshots
/// summed into one rollup. Each shard's counters are snapshotted once, so
/// totals equal the sum of the per-shard stats. Counting is per submission
/// attempt: a mid-flight resubmission shows up as a `failed` on the dead
/// shard plus a fresh `requests`/`completed` pair on the survivor, and
/// [`FleetTelemetry::resubmits`] records how many logical requests did so
/// (`requests() − resubmits` = logical requests accepted).
#[derive(Debug, Clone, Default)]
pub struct FleetTelemetry {
    /// Per-shard snapshots, shard order.
    pub shards: Vec<ShardTelemetry>,
    /// Mid-flight requests resubmitted on a survivor after their shard died
    /// (the fleet's retained-payload retry layer).
    pub resubmits: u64,
    /// Dead shards probed back into the rotation.
    pub shards_revived: u64,
    /// Shards dynamically spawned under queue-depth pressure.
    pub shards_spawned: u64,
    /// Revival probes that failed.
    pub failed_probes: u64,
    /// Submissions rerouted at submit time after a (possibly remote) shard
    /// refused — the drain-to-survivors counter.
    pub submit_reroutes: u64,
    /// Retrying submissions that exhausted the fleet (terminal shard-down
    /// dispositions, one per logical request).
    pub terminal_failures: u64,
}

impl FleetTelemetry {
    /// Rollup over per-shard snapshots (lifecycle counters start at zero;
    /// [`crate::coordinator::FleetHandle::telemetry`] fills them).
    pub fn new(shards: Vec<ShardTelemetry>) -> Self {
        FleetTelemetry { shards, ..Default::default() }
    }

    /// Total requests accepted across the fleet.
    pub fn requests(&self) -> u64 {
        self.shards.iter().map(|s| s.requests).sum()
    }

    /// Total requests completed.
    pub fn completed(&self) -> u64 {
        self.shards.iter().map(|s| s.completed).sum()
    }

    /// Total requests failed.
    pub fn failed(&self) -> u64 {
        self.shards.iter().map(|s| s.failed).sum()
    }

    /// Total whole-CNN frames served.
    pub fn cnn_frames(&self) -> u64 {
        self.shards.iter().map(|s| s.cnn_frames).sum()
    }

    /// Total reported (photonic) executions.
    pub fn sim_reports(&self) -> u64 {
        self.shards.iter().map(|s| s.sim_reports).sum()
    }

    /// Total projected photonic latency, seconds.
    pub fn sim_latency_total_s(&self) -> f64 {
        self.shards.iter().map(|s| s.sim_latency_s).sum()
    }

    /// Total projected photonic energy, joules.
    pub fn sim_energy_total_j(&self) -> f64 {
        self.shards.iter().map(|s| s.energy_j).sum()
    }

    /// Total analog lanes transduced.
    pub fn lanes(&self) -> u64 {
        self.shards.iter().map(|s| s.lanes).sum()
    }

    /// Total noise-perturbed outputs.
    pub fn noise_events(&self) -> u64 {
        self.shards.iter().map(|s| s.noise_events).sum()
    }

    /// Total submissions shed by admission control across the fleet.
    pub fn shed(&self) -> u64 {
        self.shards.iter().map(|s| s.shed).sum()
    }

    /// Best-effort subset of [`FleetTelemetry::shed`].
    pub fn shed_best_effort(&self) -> u64 {
        self.shards.iter().map(|s| s.shed_best_effort).sum()
    }

    /// Total requests failed typed because their deadline expired before
    /// dispatch.
    pub fn deadline_expired(&self) -> u64 {
        self.shards.iter().map(|s| s.deadline_expired).sum()
    }

    /// Fleet-wide projected sim-FPS (reported executions ÷ total projected
    /// latency) — the live-traffic analogue of the paper's FPS figures.
    pub fn sim_fps(&self) -> f64 {
        per_unit(self.sim_reports(), self.sim_latency_total_s())
    }

    /// Fleet-wide projected FPS per watt.
    pub fn sim_fps_per_w(&self) -> f64 {
        per_unit(self.sim_reports(), self.sim_energy_total_j())
    }

    /// Fleet-wide fraction of transduced lanes served exactly.
    pub fn served_exact_fraction(&self) -> f64 {
        exact_fraction(self.noise_events(), self.lanes())
    }

    /// Multi-line human-readable rollup (one line per shard + totals).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for sh in &self.shards {
            s.push_str(&format!(
                "  {:28} requests={} completed={} failed={} cnn_frames={}",
                sh.label, sh.requests, sh.completed, sh.failed, sh.cnn_frames
            ));
            if sh.sim_reports > 0 {
                s.push_str(&format!(
                    " sim(fps={:.0} fps/W={:.0} exact={:.4})",
                    sh.sim_fps(),
                    sh.sim_fps_per_w(),
                    sh.served_exact_fraction()
                ));
            }
            s.push('\n');
        }
        s.push_str(&format!(
            "  fleet: requests={} completed={} failed={} cnn_frames={}",
            self.requests(),
            self.completed(),
            self.failed(),
            self.cnn_frames()
        ));
        if self.sim_reports() > 0 {
            s.push_str(&format!(
                " sim(fps={:.0} fps/W={:.0} noise_events={} exact={:.4})",
                self.sim_fps(),
                self.sim_fps_per_w(),
                self.noise_events(),
                self.served_exact_fraction()
            ));
        }
        if self.shed() > 0 || self.deadline_expired() > 0 {
            s.push_str(&format!(
                " qos(shed={} shed_be={} deadline_expired={})",
                self.shed(),
                self.shed_best_effort(),
                self.deadline_expired()
            ));
        }
        let lifecycle_total = self.resubmits
            + self.shards_revived
            + self.shards_spawned
            + self.failed_probes
            + self.submit_reroutes
            + self.terminal_failures;
        if lifecycle_total > 0 {
            s.push_str(&format!(
                "\n  lifecycle: resubmits={} reroutes={} revived={} spawned={} \
                 failed_probes={} terminal_failures={}",
                self.resubmits,
                self.submit_reroutes,
                self.shards_revived,
                self.shards_spawned,
                self.failed_probes,
                self.terminal_failures
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gmean_basics() {
        assert!((gmean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((gmean(&[7.0]) - 7.0).abs() < 1e-12);
        assert_eq!(gmean(&[]), 0.0);
    }

    #[test]
    fn figure_contains_all_variants() {
        let fig = build_figure(Metric::Fps, &[DataRate::Gs10], FIG5_CORES).unwrap();
        assert_eq!(fig.variants.len(), 3);
        assert!(fig.variant("SPOGA_10").is_some());
        assert!(fig.variant("HOLYLIGHT_10").is_some());
        assert!(fig.variant("DEAPCNN_10").is_some());
        assert_eq!(fig.models.len(), 4);
    }

    #[test]
    fn spoga_wins_fps_gmean_at_10gs() {
        let fig = build_figure(Metric::Fps, &[DataRate::Gs10], FIG5_CORES).unwrap();
        let r_deap = fig.gmean_ratio("SPOGA_10", "DEAPCNN_10").unwrap();
        let r_holy = fig.gmean_ratio("SPOGA_10", "HOLYLIGHT_10").unwrap();
        assert!(r_deap > 1.0, "SPOGA/DEAPCNN = {r_deap}");
        assert!(r_holy > 1.0, "SPOGA/HOLYLIGHT = {r_holy}");
        // Paper: 14.4× and 11.1× — require the same ordering.
        assert!(r_deap > r_holy, "DEAPCNN should lose by more than HOLYLIGHT");
    }

    #[test]
    fn per_model_values_positive() {
        let fig = build_figure(Metric::FpsPerW, &[DataRate::Gs5], FIG5_CORES).unwrap();
        for v in &fig.variants {
            for (i, x) in v.per_model.iter().enumerate() {
                assert!(*x > 0.0, "{} model {i}", v.name);
            }
            assert!(v.gmean > 0.0);
        }
    }

    #[test]
    fn gmean_ratio_missing_variant_is_none() {
        let fig = build_figure(Metric::Fps, &[DataRate::Gs10], FIG5_CORES).unwrap();
        assert!(fig.gmean_ratio("SPOGA_10", "nonexistent").is_none());
    }

    #[test]
    fn fleet_rollup_totals_equal_sum_of_shards() {
        use crate::coordinator::CoordinatorStats;
        use std::sync::atomic::Ordering::Relaxed;
        let a = CoordinatorStats::default();
        let b = CoordinatorStats::default();
        a.requests.fetch_add(10, Relaxed);
        a.completed.fetch_add(9, Relaxed);
        a.failed.fetch_add(1, Relaxed);
        b.requests.fetch_add(4, Relaxed);
        b.completed.fetch_add(4, Relaxed);
        b.cnn_frames.fetch_add(2, Relaxed);
        let r = crate::runtime::ExecReport {
            sim_latency_s: 1e-3,
            energy_j: 2e-4,
            lanes: 50,
            noise_events: 5,
            row_noise: Vec::new(),
        };
        b.record_report(&r);
        b.record_report(&r);
        a.shed.fetch_add(3, Relaxed);
        a.shed_best_effort.fetch_add(2, Relaxed);
        b.shed.fetch_add(1, Relaxed);
        b.deadline_expired.fetch_add(1, Relaxed);

        let fleet = FleetTelemetry::new(vec![
            ShardTelemetry::capture("a", &a),
            ShardTelemetry::capture("b", &b),
        ]);
        assert_eq!(fleet.requests(), 14);
        assert_eq!(fleet.completed(), 13);
        assert_eq!(fleet.failed(), 1);
        assert_eq!(fleet.cnn_frames(), 2);
        assert_eq!(fleet.sim_reports(), 2);
        assert_eq!(fleet.lanes(), 100);
        assert_eq!(fleet.noise_events(), 10);
        assert!((fleet.sim_latency_total_s() - 2e-3).abs() < 1e-15);
        assert!((fleet.sim_energy_total_j() - 4e-4).abs() < 1e-15);
        assert!((fleet.sim_fps() - 1000.0).abs() < 1e-9);
        assert!((fleet.sim_fps_per_w() - 5000.0).abs() < 1e-6);
        assert!((fleet.served_exact_fraction() - 0.9).abs() < 1e-12);
        // Per-shard views survive in the rollup (A/B readout).
        assert_eq!(fleet.shards[0].label, "a");
        assert_eq!(fleet.shards[1].sim_reports, 2);
        assert_eq!(fleet.shards[0].served_exact_fraction(), 1.0);
        // QoS counters roll up shard-by-shard too.
        assert_eq!(fleet.shed(), 4);
        assert_eq!(fleet.shed_best_effort(), 2);
        assert_eq!(fleet.deadline_expired(), 1);
        let s = fleet.summary();
        assert!(s.contains("fleet: requests=14"), "{s}");
        assert!(s.contains("exact=0.9000"), "{s}");
        assert!(s.contains("qos(shed=4 shed_be=2 deadline_expired=1)"), "{s}");
    }

    #[test]
    fn empty_fleet_rollup_is_zero() {
        let fleet = FleetTelemetry::default();
        assert_eq!(fleet.requests(), 0);
        assert_eq!(fleet.sim_fps(), 0.0);
        assert_eq!(fleet.sim_fps_per_w(), 0.0);
        assert_eq!(fleet.served_exact_fraction(), 1.0);
        // No lifecycle noise in a quiet fleet's summary.
        assert!(!fleet.summary().contains("lifecycle:"));
    }

    #[test]
    fn lifecycle_counters_surface_in_capture_and_summary() {
        use crate::coordinator::CoordinatorStats;
        use std::sync::atomic::Ordering::Relaxed;
        let s = CoordinatorStats::default();
        s.live_workers.store(3, Relaxed);
        s.revivals.fetch_add(2, Relaxed);
        let shard = ShardTelemetry::capture("s", &s);
        assert_eq!((shard.live_workers, shard.revivals), (3, 2));

        let mut fleet = FleetTelemetry::new(vec![shard]);
        fleet.resubmits = 4;
        fleet.shards_revived = 1;
        fleet.shards_spawned = 2;
        let sum = fleet.summary();
        assert!(sum.contains("lifecycle: resubmits=4 reroutes=0 revived=1 spawned=2"), "{sum}");
        // A fleet that never shed keeps its summary free of QoS noise.
        assert!(!sum.contains("qos("), "{sum}");
    }

    #[test]
    fn live_telemetry_matches_frame_stats_identities() {
        let mut t = LiveTelemetry::default();
        assert_eq!(t.fps(), 0.0);
        assert_eq!(t.fps_per_w(), 0.0);
        let r = crate::runtime::ExecReport {
            sim_latency_s: 0.01,
            energy_j: 0.5,
            lanes: 42,
            noise_events: 1,
            row_noise: Vec::new(),
        };
        t.add(&r);
        t.add(&r);
        assert!((t.fps() - 100.0).abs() < 1e-9); // 2 frames / 0.02 s
        assert!((t.fps_per_w() - 2.0).abs() < 1e-9); // 2 frames / 1 J
        assert_eq!((t.frames, t.lanes, t.noise_events), (2, 84, 2));
    }
}
