//! Evaluation metrics and Fig. 5 data assembly.
//!
//! Builds the paper's three figures — FPS, FPS/W, FPS/W/mm² — over the
//! 4 CNNs × {SPOGA, HOLYLIGHT, DEAPCNN} × data-rate grid, with geometric
//! means matching the paper's gmean bars.

use crate::arch::accel::Accelerator;
use crate::dnn::models::CnnModel;
use crate::optics::link_budget::ArchClass;
use crate::sim::engine::simulate_frame;
use crate::units::DataRate;
use crate::Result;

/// Geometric mean of a nonempty slice.
pub fn gmean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Which of the paper's three metrics a figure reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// Fig. 5(a): frames per second.
    Fps,
    /// Fig. 5(b): FPS per watt.
    FpsPerW,
    /// Fig. 5(c): FPS per watt per mm².
    FpsPerWPerMm2,
}

impl Metric {
    /// Figure label in the paper.
    pub fn figure(self) -> &'static str {
        match self {
            Metric::Fps => "Fig. 5(a) FPS",
            Metric::FpsPerW => "Fig. 5(b) FPS/W",
            Metric::FpsPerWPerMm2 => "Fig. 5(c) FPS/W/mm2",
        }
    }
}

/// One accelerator variant's results across the benchmark CNNs.
#[derive(Debug, Clone)]
pub struct VariantResults {
    /// Variant name ("SPOGA_10", ...).
    pub name: String,
    /// Per-model metric values, in [`CnnModel::paper_benchmarks`] order.
    pub per_model: Vec<f64>,
    /// Geometric mean across models (the paper's gmean bar).
    pub gmean: f64,
}

/// A full figure: all variants at the requested data rates.
#[derive(Debug, Clone)]
pub struct Figure {
    /// Which metric this figure reports.
    pub metric: Metric,
    /// Model names, column order.
    pub models: Vec<String>,
    /// One row per accelerator variant.
    pub variants: Vec<VariantResults>,
}

impl Figure {
    /// Look up a variant row by name.
    pub fn variant(&self, name: &str) -> Option<&VariantResults> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// gmean ratio `a / b` between two variants.
    pub fn gmean_ratio(&self, a: &str, b: &str) -> Option<f64> {
        Some(self.variant(a)?.gmean / self.variant(b)?.gmean)
    }
}

/// Physical cores per accelerator in the Fig. 5 reproduction (equal-core
/// normalization; baselines group theirs into 16 slice quadruplets).
pub const FIG5_CORES: usize = 64;

/// Evaluate `metric` for all three architectures at the given `rates` under
/// the equal-core-count normalization (DESIGN.md §5.2).
pub fn build_figure(metric: Metric, rates: &[DataRate], cores: usize) -> Result<Figure> {
    let models = CnnModel::paper_benchmarks();
    let mut variants = Vec::new();
    for arch in [ArchClass::Mwa, ArchClass::Maw, ArchClass::Amw] {
        for &dr in rates {
            let accel = Accelerator::equal_cores(arch, dr, cores)?;
            variants.push(evaluate_variant(&accel, metric, &models));
        }
    }
    Ok(Figure {
        metric,
        models: models.iter().map(|m| m.name.to_string()).collect(),
        variants,
    })
}

/// Evaluate one accelerator variant across the benchmark models.
pub fn evaluate_variant(
    accel: &Accelerator,
    metric: Metric,
    models: &[CnnModel],
) -> VariantResults {
    // Fig. 5(c) divides by the electronic (CMOS) die area — the area the
    // paper's Table II models (see Core::electronic_area_mm2).
    let area = accel.electronic_area_mm2();
    let per_model: Vec<f64> = models
        .iter()
        .map(|m| {
            let f = simulate_frame(accel, &m.workload());
            match metric {
                Metric::Fps => f.fps(),
                Metric::FpsPerW => f.fps_per_w(),
                Metric::FpsPerWPerMm2 => f.fps_per_w_per_mm2(area),
            }
        })
        .collect();
    VariantResults { name: accel.name.clone(), gmean: gmean(&per_model), per_model }
}

/// Live serving telemetry aggregated from per-request
/// [`ExecReport`](crate::runtime::ExecReport)s — the bridge between the
/// coordinator's photonic-in-the-loop responses and the paper's headline
/// metrics: feed it the reports a traffic run produced and read off the
/// FPS / FPS-per-watt *that exact traffic* would see on the simulated
/// accelerator (vs. [`build_figure`]'s fixed benchmark suite).
#[derive(Debug, Clone, Copy, Default)]
pub struct LiveTelemetry {
    /// Reported executions folded in.
    pub frames: u64,
    /// Total projected latency, seconds.
    pub sim_latency_s: f64,
    /// Total projected energy, joules.
    pub energy_j: f64,
    /// Total analog lanes transduced.
    pub lanes: u64,
    /// Total noise-perturbed outputs.
    pub noise_events: u64,
}

impl LiveTelemetry {
    /// Fold in one execution's report.
    pub fn add(&mut self, r: &crate::runtime::ExecReport) {
        self.frames += 1;
        self.sim_latency_s += r.sim_latency_s;
        self.energy_j += r.energy_j;
        self.lanes += r.lanes;
        self.noise_events += r.noise_events;
    }

    /// Projected executions per second (frames ÷ projected latency).
    pub fn fps(&self) -> f64 {
        if self.sim_latency_s <= 0.0 {
            return 0.0;
        }
        self.frames as f64 / self.sim_latency_s
    }

    /// Projected executions per joule — the paper's FPS/W identity.
    pub fn fps_per_w(&self) -> f64 {
        if self.energy_j <= 0.0 {
            return 0.0;
        }
        self.frames as f64 / self.energy_j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gmean_basics() {
        assert!((gmean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((gmean(&[7.0]) - 7.0).abs() < 1e-12);
        assert_eq!(gmean(&[]), 0.0);
    }

    #[test]
    fn figure_contains_all_variants() {
        let fig = build_figure(Metric::Fps, &[DataRate::Gs10], FIG5_CORES).unwrap();
        assert_eq!(fig.variants.len(), 3);
        assert!(fig.variant("SPOGA_10").is_some());
        assert!(fig.variant("HOLYLIGHT_10").is_some());
        assert!(fig.variant("DEAPCNN_10").is_some());
        assert_eq!(fig.models.len(), 4);
    }

    #[test]
    fn spoga_wins_fps_gmean_at_10gs() {
        let fig = build_figure(Metric::Fps, &[DataRate::Gs10], FIG5_CORES).unwrap();
        let r_deap = fig.gmean_ratio("SPOGA_10", "DEAPCNN_10").unwrap();
        let r_holy = fig.gmean_ratio("SPOGA_10", "HOLYLIGHT_10").unwrap();
        assert!(r_deap > 1.0, "SPOGA/DEAPCNN = {r_deap}");
        assert!(r_holy > 1.0, "SPOGA/HOLYLIGHT = {r_holy}");
        // Paper: 14.4× and 11.1× — require the same ordering.
        assert!(r_deap > r_holy, "DEAPCNN should lose by more than HOLYLIGHT");
    }

    #[test]
    fn per_model_values_positive() {
        let fig = build_figure(Metric::FpsPerW, &[DataRate::Gs5], FIG5_CORES).unwrap();
        for v in &fig.variants {
            for (i, x) in v.per_model.iter().enumerate() {
                assert!(*x > 0.0, "{} model {i}", v.name);
            }
            assert!(v.gmean > 0.0);
        }
    }

    #[test]
    fn gmean_ratio_missing_variant_is_none() {
        let fig = build_figure(Metric::Fps, &[DataRate::Gs10], FIG5_CORES).unwrap();
        assert!(fig.gmean_ratio("SPOGA_10", "nonexistent").is_none());
    }

    #[test]
    fn live_telemetry_matches_frame_stats_identities() {
        let mut t = LiveTelemetry::default();
        assert_eq!(t.fps(), 0.0);
        assert_eq!(t.fps_per_w(), 0.0);
        let r = crate::runtime::ExecReport {
            sim_latency_s: 0.01,
            energy_j: 0.5,
            lanes: 42,
            noise_events: 1,
        };
        t.add(&r);
        t.add(&r);
        assert!((t.fps() - 100.0).abs() < 1e-9); // 2 frames / 0.02 s
        assert!((t.fps_per_w() - 2.0).abs() < 1e-9); // 2 frames / 1 J
        assert_eq!((t.frames, t.lanes, t.noise_events), (2, 84, 2));
    }
}
