//! SplitMix64 — tiny, fast, deterministic PRNG (public-domain algorithm).
//!
//! Chosen because it is seedable, passes BigCrush for our purposes, and
//! needs no external crate. NOT for cryptography.

/// SplitMix64 generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Equal seeds ⇒ equal streams.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform value in `[0, bound)`. `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Rejection-free multiply-shift (Lemire); bias negligible for tests.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform i8 over the full domain.
    #[inline]
    pub fn i8(&mut self) -> i8 {
        self.next_u64() as i8
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Vector of `len` uniform i8 values.
    pub fn i8_vec(&mut self, len: usize) -> Vec<i8> {
        (0..len).map(|_| self.i8()).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_equal_seeds() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_respects_bound() {
        let mut g = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(g.below(17) < 17);
        }
    }

    #[test]
    fn range_usize_inclusive_hits_endpoints() {
        let mut g = SplitMix64::new(3);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            match g.range_usize(2, 5) {
                2 => saw_lo = true,
                5 => saw_hi = true,
                v => assert!((2..=5).contains(&v)),
            }
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = SplitMix64::new(11);
        for _ in 0..10_000 {
            let v = g.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn i8_covers_negative_and_positive() {
        let mut g = SplitMix64::new(5);
        let vs = g.i8_vec(10_000);
        assert!(vs.iter().any(|&v| v < -100));
        assert!(vs.iter().any(|&v| v > 100));
    }
}
