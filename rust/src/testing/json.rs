//! Minimal JSON parser for test fixtures and bench snapshots.
//!
//! The offline vendored dependency set has no `serde`/`serde_json`, but the
//! repo commits machine-written `BENCH_*.json` trajectory records whose
//! schema must stay parseable (the bench emitters hand-format them, so a
//! formatting slip would otherwise surface only on the toolchain host).
//! This is a strict-enough recursive-descent parser for that job: objects,
//! arrays, strings (with escapes), numbers (including exponents), booleans
//! and null. It is not a streaming parser and not tuned for large inputs —
//! the snapshots are a few kilobytes.

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (parsed as f64 — the snapshots carry nothing that
    /// needs more than 53 bits of integer precision).
    Num(f64),
    /// A string literal.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, insertion-ordered (duplicate keys keep the last value on
    /// lookup, like serde_json's default).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document; trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field lookup (last duplicate wins); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Is this `null`?
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("json parse error at byte {}: {msg}", self.pos)
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit:?}")))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("dangling escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // BMP only — snapshot files are ASCII; surrogate
                            // pairs degrade to the replacement character
                            // rather than failing the whole parse.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => {
                            return Err(self.err(&format!("bad escape \\{}", other as char)))
                        }
                    }
                }
                Some(_) => {
                    // Copy one UTF-8 scalar (multi-byte sequences included).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let ch = s.chars().next().expect("non-empty by peek");
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .expect("ascii number bytes");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number {text:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("1.5e-3").unwrap(), Json::Num(0.0015));
        assert_eq!(
            Json::parse(r#""a\"b\\c\nd\u0041""#).unwrap(),
            Json::Str("a\"b\\c\nA".to_string())
        );
        let doc = Json::parse(r#"{"a": [1, null, {"b": "x"}], "a": 2}"#).unwrap();
        assert_eq!(doc.get("a"), Some(&Json::Num(2.0))); // last duplicate wins
        assert!(Json::parse("[]").unwrap().as_arr().unwrap().is_empty());
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Vec::new()));
    }

    #[test]
    fn accessors_type_check() {
        let doc = Json::parse(r#"{"s": "str", "n": 3, "z": null, "a": [1]}"#).unwrap();
        assert_eq!(doc.get("s").unwrap().as_str(), Some("str"));
        assert_eq!(doc.get("n").unwrap().as_num(), Some(3.0));
        assert!(doc.get("z").unwrap().is_null());
        assert_eq!(doc.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(doc.get("missing").is_none());
        assert!(doc.get("s").unwrap().as_num().is_none());
        assert!(Json::Null.get("x").is_none());
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "{\"a\":}", "\"unterminated",
            "[1,]", "{,}", "nul", "--3", "\"\\q\"",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn round_trips_a_bench_style_snapshot() {
        let doc = Json::parse(
            r#"{
  "bench": "x",
  "status": "pending-first-run",
  "results": [
    {"k": 74, "served_exact": null, "sim_fps": 1.2e6}
  ]
}"#,
        )
        .unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("x"));
        let rows = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].get("served_exact").unwrap().is_null());
        assert_eq!(rows[0].get("sim_fps").unwrap().as_num(), Some(1.2e6));
    }
}
