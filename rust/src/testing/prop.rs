//! `forall` property driver with first-failure reporting and shrinking-lite.

use crate::testing::prng::SplitMix64;

/// A value generator: draws a case from the PRNG.
pub trait Gen {
    /// The generated case type.
    type Output;
    /// Draw one case.
    fn gen(&self, rng: &mut SplitMix64) -> Self::Output;
    /// Try to produce *smaller* variants of a failing case (for shrinking).
    /// Default: no shrinking.
    fn shrink(&self, _case: &Self::Output) -> Vec<Self::Output> {
        Vec::new()
    }
}

impl<T, F: Fn(&mut SplitMix64) -> T> Gen for F {
    type Output = T;
    fn gen(&self, rng: &mut SplitMix64) -> T {
        self(rng)
    }
}

/// Run `prop` over `cases` generated cases; panic with the (possibly shrunk)
/// counterexample on first failure.
///
/// `seed` makes failures reproducible; tests fix it per property.
pub fn forall<G, P>(seed: u64, cases: usize, generator: G, prop: P)
where
    G: Gen,
    G::Output: std::fmt::Debug,
    P: Fn(&G::Output) -> bool,
{
    let mut rng = SplitMix64::new(seed);
    for i in 0..cases {
        let case = generator.gen(&mut rng);
        if !prop(&case) {
            // Greedy shrink: repeatedly take the first shrunk variant that
            // still fails, up to a bounded number of rounds.
            let mut smallest = case;
            'outer: for _ in 0..64 {
                for cand in generator.shrink(&smallest) {
                    if !prop(&cand) {
                        smallest = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed at case {i}/{cases} (seed {seed}).\n\
                 counterexample: {smallest:?}"
            );
        }
    }
}

/// Generator for random GEMM problem instances `(a, b, m, k, n)` with
/// dimensions in `[1, max_dim]`.
pub struct GemmCase {
    /// Maximum value for each of m, k, n.
    pub max_dim: usize,
}

impl Gen for GemmCase {
    type Output = (Vec<i8>, Vec<i8>, usize, usize, usize);

    fn gen(&self, rng: &mut SplitMix64) -> Self::Output {
        let m = rng.range_usize(1, self.max_dim);
        let k = rng.range_usize(1, self.max_dim);
        let n = rng.range_usize(1, self.max_dim);
        (rng.i8_vec(m * k), rng.i8_vec(k * n), m, k, n)
    }

    fn shrink(&self, case: &Self::Output) -> Vec<Self::Output> {
        let (a, b, m, k, n) = case;
        let mut out = Vec::new();
        // Halve each dimension (keeping the top-left submatrix).
        for (nm, nk, nn) in [(m / 2, *k, *n), (*m, k / 2, *n), (*m, *k, n / 2)] {
            if nm == 0 || nk == 0 || nn == 0 || (nm, nk, nn) == (*m, *k, *n) {
                continue;
            }
            let sub_a: Vec<i8> =
                (0..nm).flat_map(|i| a[i * k..i * k + nk].to_vec()).collect();
            let sub_b: Vec<i8> =
                (0..nk).flat_map(|i| b[i * n..i * n + nn].to_vec()).collect();
            out.push((sub_a, sub_b, nm, nk, nn));
        }
        // Zero out operand values (simplest counterexample data).
        if a.iter().any(|&v| v != 0) {
            out.push((vec![0; a.len()], b.clone(), *m, *k, *n));
        }
        if b.iter().any(|&v| v != 0) {
            out.push((a.clone(), vec![0; b.len()], *m, *k, *n));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitslice::{gemm_i32, gemm_lanes, gemm_sliced};

    #[test]
    fn trivially_true_property_passes() {
        forall(1, 100, |rng: &mut SplitMix64| rng.i8(), |_| true);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        forall(2, 100, |rng: &mut SplitMix64| rng.i8(), |&x| x >= -100);
    }

    #[test]
    fn prop_sliced_dataflow_equals_direct_gemm() {
        forall(1234, 60, GemmCase { max_dim: 12 }, |(a, b, m, k, n)| {
            let direct = gemm_i32(a, b, *m, *k, *n).unwrap();
            let sliced = gemm_sliced(a, b, *m, *k, *n).unwrap().recombine();
            direct == sliced
        });
    }

    #[test]
    fn prop_spoga_lanes_equal_direct_gemm() {
        forall(5678, 60, GemmCase { max_dim: 12 }, |(a, b, m, k, n)| {
            let direct = gemm_i32(a, b, *m, *k, *n).unwrap();
            let lanes = gemm_lanes(a, b, *m, *k, *n).unwrap().weight_and_add();
            direct == lanes
        });
    }

    #[test]
    fn gemm_case_generator_respects_dims() {
        let mut rng = SplitMix64::new(9);
        let g = GemmCase { max_dim: 8 };
        for _ in 0..100 {
            let (a, b, m, k, n) = g.gen(&mut rng);
            assert!(m >= 1 && m <= 8 && k >= 1 && k <= 8 && n >= 1 && n <= 8);
            assert_eq!(a.len(), m * k);
            assert_eq!(b.len(), k * n);
        }
    }

    #[test]
    fn shrink_produces_smaller_cases() {
        let g = GemmCase { max_dim: 8 };
        let case = (vec![1i8; 4 * 6], vec![2i8; 6 * 8], 4usize, 6usize, 8usize);
        for (a, b, m, k, n) in g.shrink(&case) {
            assert_eq!(a.len(), m * k);
            assert_eq!(b.len(), k * n);
            assert!(m * k * n <= 4 * 6 * 8);
        }
    }
}
