//! Deterministic mini property-testing harness.
//!
//! The offline vendored dependency set has no `proptest`/`quickcheck`, so
//! this module provides the small subset we need: a fast deterministic PRNG
//! (SplitMix64), generators for the value domains used across the crate, and
//! a `forall` driver with first-failure reporting and linear input shrinking
//! for integer-vector cases.

pub mod prng;
pub mod prop;

pub use prng::SplitMix64;
pub use prop::{forall, Gen};
