//! Deterministic mini property-testing harness.
//!
//! The offline vendored dependency set has no `proptest`/`quickcheck`, so
//! this module provides the small subset we need: a fast deterministic PRNG
//! (SplitMix64), generators for the value domains used across the crate,
//! a `forall` driver with first-failure reporting and linear input shrinking
//! for integer-vector cases, and a minimal JSON parser ([`json`]) for the
//! committed `BENCH_*.json` snapshot schema guards (no `serde` offline).

pub mod json;
pub mod prng;
pub mod prop;

pub use json::Json;
pub use prng::SplitMix64;
pub use prop::{forall, Gen};
