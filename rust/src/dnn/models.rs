//! Layer-exact tables for the paper's four benchmark CNNs
//! (224×224×3 ImageNet inference, batch 1).
//!
//! Only GEMM-bearing layers (convolutions, fully-connected) are listed —
//! the paper accelerates GEMM kernels; pooling/activation/shuffle run on the
//! host and are outside the photonic cores' critical resource (and are also
//! excluded by the paper, §II-A last paragraph).

use crate::dnn::layer::Layer;
use crate::dnn::workload::Workload;

/// A named CNN model: ordered GEMM-bearing layers.
///
/// `PartialEq`/`Eq` so the coordinator's batcher can co-batch requests that
/// submitted equal models (same-model CNN frames stack along the
/// t-dimension).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CnnModel {
    /// Model name as used in the paper's Fig. 5 ("MobileNetV2", ...).
    pub name: &'static str,
    /// Ordered layers.
    pub layers: Vec<Layer>,
}

impl CnnModel {
    /// Total multiply-accumulates per frame.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Lower to the per-frame GEMM workload.
    pub fn workload(&self) -> Workload {
        Workload::from_model(self)
    }

    /// All four paper benchmarks, in the paper's Fig. 5 order.
    pub fn paper_benchmarks() -> Vec<CnnModel> {
        vec![mobilenet_v2(), shufflenet_v2(), resnet50(), googlenet()]
    }
}

/// ResNet-50 (He et al. 2016): conv1 + 4 bottleneck stages [3,4,6,3] + fc.
pub fn resnet50() -> CnnModel {
    let mut layers = vec![Layer::conv("conv1", 224, 224, 3, 64, 7, 2, 3)];
    // After conv1 (112×112) and 3×3/2 max-pool → 56×56×64.
    let stage_specs: [(usize, usize, usize, usize, usize); 4] = [
        // (blocks, mid_ch, out_ch, spatial_in, stride_of_first_block)
        (3, 64, 256, 56, 1),
        (4, 128, 512, 56, 2),
        (6, 256, 1024, 28, 2),
        (3, 512, 2048, 14, 2),
    ];
    let mut in_ch = 64;
    for (si, (blocks, mid, out, sp_in, first_stride)) in stage_specs.into_iter().enumerate() {
        let stage = si + 2; // paper naming: res2..res5
        let mut h = sp_in;
        for b in 0..blocks {
            let stride = if b == 0 { first_stride } else { 1 };
            let h_out = h / stride;
            let pre = format!("res{stage}{}", (b'a' + b as u8) as char);
            // 1×1 reduce (stride lives on the 3×3 per torchvision/v1.5).
            layers.push(Layer::conv(&format!("{pre}_branch2a"), h, h, in_ch, mid, 1, 1, 0));
            layers.push(Layer::conv(&format!("{pre}_branch2b"), h, h, mid, mid, 3, stride, 1));
            layers.push(Layer::conv(&format!("{pre}_branch2c"), h_out, h_out, mid, out, 1, 1, 0));
            if b == 0 {
                // Projection shortcut.
                layers.push(Layer::conv(&format!("{pre}_branch1"), h, h, in_ch, out, 1, stride, 0));
            }
            in_ch = out;
            h = h_out;
        }
    }
    layers.push(Layer::fc("fc1000", 2048, 1000));
    CnnModel { name: "ResNet50", layers }
}

/// MobileNet V2 (Sandler et al. 2018): conv1 + 17 inverted-residual blocks +
/// conv 1×1×1280 + fc.
pub fn mobilenet_v2() -> CnnModel {
    let mut layers = vec![Layer::conv("conv1", 224, 224, 3, 32, 3, 2, 1)];
    // (expansion t, out channels c, repeats n, first stride s) — Table 2 of
    // the MobileNetV2 paper.
    let specs: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut in_ch = 32;
    let mut h = 112;
    let mut blk = 0;
    for (t, c, n, s) in specs {
        for r in 0..n {
            blk += 1;
            let stride = if r == 0 { s } else { 1 };
            let hidden = in_ch * t;
            let pre = format!("block{blk}");
            if t != 1 {
                layers.push(Layer::conv(&format!("{pre}_expand"), h, h, in_ch, hidden, 1, 1, 0));
            }
            let h_out = h / stride;
            layers.push(Layer::dwconv(&format!("{pre}_dw"), h, h, hidden, 3, stride, 1));
            layers.push(Layer::conv(&format!("{pre}_project"), h_out, h_out, hidden, c, 1, 1, 0));
            in_ch = c;
            h = h_out;
        }
    }
    layers.push(Layer::conv("conv_last", 7, 7, 320, 1280, 1, 1, 0));
    layers.push(Layer::fc("fc", 1280, 1000));
    CnnModel { name: "MobileNetV2", layers }
}

/// ShuffleNet V2 ×1.0 (Ma et al. 2018): conv1 + stages {4, 8, 4} with
/// 116/232/464 channels + conv5 + fc.
pub fn shufflenet_v2() -> CnnModel {
    let mut layers = vec![Layer::conv("conv1", 224, 224, 3, 24, 3, 2, 1)];
    // After conv1 (112×112) and max-pool → 56×56×24.
    let mut in_ch = 24;
    let mut h = 56;
    for (stage, (out_ch, repeats)) in [(116usize, 4usize), (232, 8), (464, 4)].iter().enumerate() {
        let stage = stage + 2;
        let half = out_ch / 2;
        for u in 0..*repeats {
            let pre = format!("stage{stage}_u{}", u + 1);
            if u == 0 {
                // Spatial-down unit (stride 2): both branches are convolved.
                let h_out = h / 2;
                // Branch 1: 3×3 dw /2 on the full input + 1×1 → half.
                layers.push(Layer::dwconv(&format!("{pre}_b1_dw"), h, h, in_ch, 3, 2, 1));
                layers.push(Layer::conv(&format!("{pre}_b1_pw"), h_out, h_out, in_ch, half, 1, 1, 0));
                // Branch 2: 1×1 → half, 3×3 dw /2, 1×1 → half.
                layers.push(Layer::conv(&format!("{pre}_b2_pw1"), h, h, in_ch, half, 1, 1, 0));
                layers.push(Layer::dwconv(&format!("{pre}_b2_dw"), h, h, half, 3, 2, 1));
                layers.push(Layer::conv(&format!("{pre}_b2_pw2"), h_out, h_out, half, half, 1, 1, 0));
                h = h_out;
            } else {
                // Basic unit: channel split — only half the channels convolve.
                layers.push(Layer::conv(&format!("{pre}_pw1"), h, h, half, half, 1, 1, 0));
                layers.push(Layer::dwconv(&format!("{pre}_dw"), h, h, half, 3, 1, 1));
                layers.push(Layer::conv(&format!("{pre}_pw2"), h, h, half, half, 1, 1, 0));
            }
            in_ch = *out_ch;
        }
    }
    layers.push(Layer::conv("conv5", 7, 7, 464, 1024, 1, 1, 0));
    layers.push(Layer::fc("fc", 1024, 1000));
    CnnModel { name: "ShuffleNetV2", layers }
}

/// GoogLeNet / Inception v1 (Szegedy et al. 2015): stem + 9 inception
/// modules + fc. Auxiliary classifiers (training-only) are excluded.
pub fn googlenet() -> CnnModel {
    let mut layers = vec![
        Layer::conv("conv1", 224, 224, 3, 64, 7, 2, 3), // → 112
        // max-pool → 56
        Layer::conv("conv2_reduce", 56, 56, 64, 64, 1, 1, 0),
        Layer::conv("conv2", 56, 56, 64, 192, 3, 1, 1),
        // max-pool → 28
    ];
    // (name, spatial, in, 1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj)
    let modules: [(&str, usize, usize, [usize; 6]); 9] = [
        ("3a", 28, 192, [64, 96, 128, 16, 32, 32]),
        ("3b", 28, 256, [128, 128, 192, 32, 96, 64]),
        // max-pool → 14
        ("4a", 14, 480, [192, 96, 208, 16, 48, 64]),
        ("4b", 14, 512, [160, 112, 224, 24, 64, 64]),
        ("4c", 14, 512, [128, 128, 256, 24, 64, 64]),
        ("4d", 14, 512, [112, 144, 288, 32, 64, 64]),
        ("4e", 14, 528, [256, 160, 320, 32, 128, 128]),
        // max-pool → 7
        ("5a", 7, 832, [256, 160, 320, 32, 128, 128]),
        ("5b", 7, 832, [384, 192, 384, 48, 128, 128]),
    ];
    for (name, sp, in_ch, [b1, b3r, b3, b5r, b5, pp]) in modules {
        layers.push(Layer::conv(&format!("inc{name}_1x1"), sp, sp, in_ch, b1, 1, 1, 0));
        layers.push(Layer::conv(&format!("inc{name}_3x3r"), sp, sp, in_ch, b3r, 1, 1, 0));
        layers.push(Layer::conv(&format!("inc{name}_3x3"), sp, sp, b3r, b3, 3, 1, 1));
        layers.push(Layer::conv(&format!("inc{name}_5x5r"), sp, sp, in_ch, b5r, 1, 1, 0));
        layers.push(Layer::conv(&format!("inc{name}_5x5"), sp, sp, b5r, b5, 5, 1, 2));
        layers.push(Layer::conv(&format!("inc{name}_pool"), sp, sp, in_ch, pp, 1, 1, 0));
    }
    layers.push(Layer::fc("fc", 1024, 1000));
    CnnModel { name: "GoogleNet", layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Published MAC counts (±15% tolerance — counting conventions differ
    /// slightly on shortcut/stem layers): ResNet-50 ≈ 4.1 G, MobileNetV2 ≈
    /// 0.30 G, ShuffleNetV2×1.0 ≈ 0.146 G, GoogLeNet ≈ 1.5 G.
    fn assert_macs_near(model: &CnnModel, expected: f64) {
        let macs = model.total_macs() as f64;
        let lo = expected * 0.85;
        let hi = expected * 1.15;
        assert!(
            macs >= lo && macs <= hi,
            "{}: {macs:.3e} MACs outside [{lo:.3e}, {hi:.3e}]",
            model.name
        );
    }

    #[test]
    fn resnet50_macs_match_literature() {
        assert_macs_near(&resnet50(), 4.1e9);
    }

    #[test]
    fn mobilenet_v2_macs_match_literature() {
        assert_macs_near(&mobilenet_v2(), 0.30e9);
    }

    #[test]
    fn shufflenet_v2_macs_match_literature() {
        assert_macs_near(&shufflenet_v2(), 0.146e9);
    }

    #[test]
    fn googlenet_macs_match_literature() {
        assert_macs_near(&googlenet(), 1.5e9);
    }

    #[test]
    fn resnet50_has_53_convs_plus_fc() {
        let m = resnet50();
        let convs = m.layers.iter().filter(|l| matches!(l, Layer::Conv { .. })).count();
        assert_eq!(convs, 53);
        assert_eq!(m.layers.len(), 54);
    }

    #[test]
    fn mobilenet_blocks_expand() {
        let m = mobilenet_v2();
        // 1 stem + block1 (2 convs, t=1) + 16 blocks × 3 convs + conv_last + fc.
        assert_eq!(m.layers.len(), 1 + 2 + 16 * 3 + 1 + 1);
    }

    #[test]
    fn googlenet_module_count() {
        let m = googlenet();
        // stem 3 + 9 modules × 6 convs + fc.
        assert_eq!(m.layers.len(), 3 + 54 + 1);
    }

    #[test]
    fn shufflenet_channel_bookkeeping() {
        let m = shufflenet_v2();
        // conv5 must consume 464 channels.
        let conv5 = m.layers.iter().find(|l| l.name() == "conv5").unwrap();
        if let Layer::Conv { in_ch, out_ch, .. } = conv5 {
            assert_eq!((*in_ch, *out_ch), (464, 1024));
        }
    }

    #[test]
    fn all_models_have_unique_layer_names() {
        for m in CnnModel::paper_benchmarks() {
            let mut names: Vec<&str> = m.layers.iter().map(|l| l.name()).collect();
            let before = names.len();
            names.sort();
            names.dedup();
            assert_eq!(before, names.len(), "{} has duplicate layer names", m.name);
        }
    }

    #[test]
    fn all_spatial_dims_divide_cleanly() {
        // Every layer's GEMM must have nonzero dims.
        for m in CnnModel::paper_benchmarks() {
            for l in &m.layers {
                let g = l.gemm();
                assert!(g.t > 0 && g.k > 0 && g.c > 0 && g.groups > 0, "{}", l.name());
            }
        }
    }

    #[test]
    fn paper_benchmark_order_matches_fig5() {
        let names: Vec<&str> =
            CnnModel::paper_benchmarks().iter().map(|m| m.name).collect();
        assert_eq!(names, vec!["MobileNetV2", "ShuffleNetV2", "ResNet50", "GoogleNet"]);
    }
}
