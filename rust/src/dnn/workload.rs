//! Per-frame GEMM workload extracted from a CNN model.

use crate::dnn::layer::GemmShape;
use crate::dnn::models::CnnModel;

/// One GEMM invocation in a frame's execution trace.
#[derive(Debug, Clone)]
pub struct GemmOp {
    /// Originating layer name.
    pub layer: String,
    /// GEMM dimensions.
    pub shape: GemmShape,
}

/// Ordered list of GEMM operations one inference frame requires.
#[derive(Debug, Clone)]
pub struct Workload {
    /// Model name.
    pub model: String,
    /// Ops in execution order.
    pub ops: Vec<GemmOp>,
}

impl Workload {
    /// Build a workload from a model's layer list.
    pub fn from_model(model: &CnnModel) -> Self {
        Workload {
            model: model.name.to_string(),
            ops: model
                .layers
                .iter()
                .map(|l| GemmOp { layer: l.name().to_string(), shape: l.gemm() })
                .collect(),
        }
    }

    /// Total MACs per frame.
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.shape.macs()).sum()
    }

    /// Total dot products (outputs) per frame — each one costs the
    /// architecture its O/E + ADC conversion chain.
    pub fn total_outputs(&self) -> u64 {
        self.ops.iter().map(|o| o.shape.outputs()).sum()
    }

    /// Largest reduction dimension across ops (sizes the DPU vector length).
    pub fn max_k(&self) -> usize {
        self.ops.iter().map(|o| o.shape.k).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use crate::dnn::models::{resnet50, CnnModel};

    #[test]
    fn workload_preserves_layer_order_and_macs() {
        let m = resnet50();
        let w = m.workload();
        assert_eq!(w.ops.len(), m.layers.len());
        assert_eq!(w.total_macs(), m.total_macs());
        assert_eq!(w.ops[0].layer, "conv1");
    }

    #[test]
    fn outputs_are_positive_for_all_models() {
        for m in CnnModel::paper_benchmarks() {
            let w = m.workload();
            assert!(w.total_outputs() > 0);
            assert!(w.total_outputs() < w.total_macs());
        }
    }

    #[test]
    fn max_k_reasonable_for_resnet() {
        // ResNet-50's biggest reduction: 512×3×3 = 4608 (res5 3×3 convs).
        assert_eq!(resnet50().workload().max_k(), 4608);
    }
}
