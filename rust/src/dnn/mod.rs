//! CNN workload library — the four networks the paper evaluates
//! (MobileNet V2, ShuffleNet V2, ResNet-50, GoogLeNet), described layer by
//! layer and lowered to GEMM shapes via im2col (paper §I: convolutions are
//! converted to GEMMs between input and Toeplitz matrices).
//!
//! Layer tables follow the original architecture papers exactly (224×224×3
//! ImageNet inference, batch 1). Each network exposes its [`Workload`]: the
//! ordered list of GEMM invocations one frame requires.

pub mod im2col;
pub mod layer;
pub mod models;
pub mod trace;
pub mod workload;

pub use im2col::{im2col_group, im2col_group_into, requantize};
pub use layer::{conv_out_dim, GemmShape, Layer};
pub use models::{googlenet, mobilenet_v2, resnet50, shufflenet_v2, CnnModel};
pub use trace::{load_trace, parse_trace, to_trace};
pub use workload::Workload;
