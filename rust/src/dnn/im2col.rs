//! Numeric im2col lowering: turn convolution inputs into the Toeplitz
//! matrices the photonic cores multiply (paper §I).
//!
//! [`crate::dnn::layer`] lowers layers to GEMM *shapes* for the analytical
//! models; this module does the same lowering on concrete int8 activation
//! tensors so whole CNN inferences can be *served* — layer by layer, one
//! GEMM per conv group — through any [`crate::runtime::ExecBackend`].
//!
//! Activation layout is HWC row-major: element `(y, x, c)` of an
//! `h×w×ch` tensor lives at `(y*w + x)*ch + c`. The im2col matrix row for
//! output pixel `(oy, ox)` concatenates the receptive field in
//! `(ky, kx, c_in_group)` order; surrogate weight matrices are generated in
//! the same `k`-ordering, so the pairing is self-consistent (the real
//! model's baked weights would adopt whatever ordering its exporter used).

use crate::dnn::layer::conv_out_dim;

/// Build the im2col matrix (`t×k`, `t = oh·ow`, `k = (in_ch/groups)·kernel²`)
/// for one conv group over an HWC int8 activation tensor. Out-of-bounds
/// taps (zero padding) contribute 0.
///
/// Caller guarantees `input.len() == in_h*in_w*in_ch`, `groups` divides
/// `in_ch`, `group < groups`, `stride >= 1`, and the conv is geometrically
/// valid (`in + 2·pad >= kernel`) — the serving path validates all of this
/// up front via [`crate::runtime::cnnrun::validate_cnn_input`].
#[allow(clippy::too_many_arguments)]
pub fn im2col_group(
    input: &[i8],
    in_h: usize,
    in_w: usize,
    in_ch: usize,
    kernel: usize,
    stride: usize,
    pad: usize,
    groups: usize,
    group: usize,
) -> Vec<i8> {
    let cpg = in_ch / groups;
    let oh = conv_out_dim(in_h, kernel, stride, pad);
    let ow = conv_out_dim(in_w, kernel, stride, pad);
    let k = cpg * kernel * kernel;
    let mut out = vec![0i8; oh * ow * k];
    im2col_group_into(input, in_h, in_w, in_ch, kernel, stride, pad, groups, group, &mut out);
    out
}

/// [`im2col_group`] writing into a caller-owned `t×k` slice instead of
/// allocating — the CNN plan's scratch-arena entry point. Frame `f` of a
/// t-stacked batch lowers into `scratch[f*t*k..(f+1)*t*k]`, so a whole
/// `(B·t)×k` activation operand builds with zero allocations.
///
/// `out.len()` must be exactly `oh·ow·(in_ch/groups)·kernel²`; the slice is
/// zeroed first so padding taps contribute 0 regardless of prior contents.
#[allow(clippy::too_many_arguments)]
pub fn im2col_group_into(
    input: &[i8],
    in_h: usize,
    in_w: usize,
    in_ch: usize,
    kernel: usize,
    stride: usize,
    pad: usize,
    groups: usize,
    group: usize,
    out: &mut [i8],
) {
    let cpg = in_ch / groups;
    let oh = conv_out_dim(in_h, kernel, stride, pad);
    let ow = conv_out_dim(in_w, kernel, stride, pad);
    let k = cpg * kernel * kernel;
    assert_eq!(out.len(), oh * ow * k, "im2col_group_into: scratch slice sized t*k");
    out.fill(0);
    for oy in 0..oh {
        for ox in 0..ow {
            let base = (oy * ow + ox) * k;
            for ky in 0..kernel {
                let y = (oy * stride + ky) as isize - pad as isize;
                if y < 0 || y as usize >= in_h {
                    continue; // padding row: stays 0
                }
                for kx in 0..kernel {
                    let x = (ox * stride + kx) as isize - pad as isize;
                    if x < 0 || x as usize >= in_w {
                        continue; // padding column: stays 0
                    }
                    let src = (y as usize * in_w + x as usize) * in_ch + group * cpg;
                    let dst = base + (ky * kernel + kx) * cpg;
                    for c in 0..cpg {
                        out[dst + c] = input[src + c];
                    }
                }
            }
        }
    }
}

/// Requantize an int32 GEMM accumulator back to an int8 activation for the
/// next layer: arithmetic shift sized to the reduction length (worst case
/// `|acc| <= 127·127·k`), then clamp. Deterministic and backend-independent,
/// so software and photonic backends chain identically.
pub fn requantize(acc: i32, k: usize) -> i8 {
    // floor(log2 k) + 1 bits for the reduction, 7 for the second operand.
    let kbits = usize::BITS - k.max(1).leading_zeros();
    let shift = (7 + kbits).min(24);
    (acc >> shift).clamp(-128, 127) as i8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitslice::gemm_i32;
    use crate::testing::SplitMix64;

    /// Naive direct convolution (HWC, zero pad) — the oracle im2col+GEMM
    /// must reproduce.
    #[allow(clippy::too_many_arguments)]
    fn conv_direct(
        input: &[i8],
        w: &[i8], // k×out_c per group ordering: ((ky*kernel+kx)*cpg + c_in) row, out_c col
        in_h: usize,
        in_w: usize,
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
    ) -> Vec<i32> {
        let oh = conv_out_dim(in_h, kernel, stride, pad);
        let ow = conv_out_dim(in_w, kernel, stride, pad);
        let k = in_ch * kernel * kernel;
        let mut out = vec![0i32; oh * ow * out_ch];
        for oy in 0..oh {
            for ox in 0..ow {
                for oc in 0..out_ch {
                    let mut acc = 0i32;
                    for ky in 0..kernel {
                        for kx in 0..kernel {
                            let y = (oy * stride + ky) as isize - pad as isize;
                            let x = (ox * stride + kx) as isize - pad as isize;
                            if y < 0 || x < 0 || y as usize >= in_h || x as usize >= in_w {
                                continue;
                            }
                            for c in 0..in_ch {
                                let a = input[(y as usize * in_w + x as usize) * in_ch + c];
                                let b = w[((ky * kernel + kx) * in_ch + c) * out_ch + oc];
                                acc += a as i32 * b as i32;
                            }
                        }
                    }
                    out[(oy * ow + ox) * out_ch + oc] = acc;
                }
            }
        }
        out
    }

    #[test]
    fn pointwise_conv_im2col_is_identity() {
        // 1×1 kernel, stride 1, no pad: the im2col matrix IS the input.
        let mut rng = SplitMix64::new(3);
        let input = rng.i8_vec(4 * 5 * 6);
        let m = im2col_group(&input, 4, 5, 6, 1, 1, 0, 1, 0);
        assert_eq!(m, input);
    }

    #[test]
    fn im2col_gemm_matches_direct_convolution() {
        let (in_h, in_w, in_ch, out_ch, kernel, stride, pad) = (7, 6, 3, 4, 3, 2, 1);
        let mut rng = SplitMix64::new(11);
        let input = rng.i8_vec(in_h * in_w * in_ch);
        let k = in_ch * kernel * kernel;
        let w = rng.i8_vec(k * out_ch);
        let oh = conv_out_dim(in_h, kernel, stride, pad);
        let ow = conv_out_dim(in_w, kernel, stride, pad);

        let a = im2col_group(&input, in_h, in_w, in_ch, kernel, stride, pad, 1, 0);
        let got = gemm_i32(&a, &w, oh * ow, k, out_ch).unwrap();
        let want =
            conv_direct(&input, &w, in_h, in_w, in_ch, out_ch, kernel, stride, pad);
        assert_eq!(got, want);
    }

    #[test]
    fn grouped_im2col_selects_group_channels() {
        // 2 groups over 4 channels: group 1's 1×1 im2col picks channels 2..4.
        let mut rng = SplitMix64::new(21);
        let input = rng.i8_vec(2 * 2 * 4);
        let m = im2col_group(&input, 2, 2, 4, 1, 1, 0, 2, 1);
        let want: Vec<i8> = (0..4).flat_map(|px| input[px * 4 + 2..px * 4 + 4].to_vec()).collect();
        assert_eq!(m, want);
    }

    #[test]
    fn padding_taps_are_zero() {
        // All-ones input, 3×3 kernel, pad 1: the corner output row has 4
        // in-bounds taps, so exactly 5 zeros.
        let input = vec![1i8; 3 * 3];
        let m = im2col_group(&input, 3, 3, 1, 3, 1, 1, 1, 0);
        let corner = &m[0..9];
        assert_eq!(corner.iter().filter(|&&v| v == 0).count(), 5);
        assert_eq!(corner.iter().filter(|&&v| v == 1).count(), 4);
    }

    #[test]
    fn into_variant_matches_allocating_variant_over_dirty_scratch() {
        // The scratch arena reuses buffers across layers and frames; the
        // into-variant must be insensitive to whatever the slice held.
        let mut rng = SplitMix64::new(77);
        for (in_h, in_w, in_ch, kernel, stride, pad, groups) in
            [(7, 6, 4, 3, 2, 1, 1), (5, 5, 6, 3, 1, 1, 2), (4, 4, 3, 1, 1, 0, 3), (3, 3, 1, 3, 1, 1, 1)]
        {
            let input = rng.i8_vec(in_h * in_w * in_ch);
            for group in 0..groups {
                let want = im2col_group(&input, in_h, in_w, in_ch, kernel, stride, pad, groups, group);
                let mut scratch = rng.i8_vec(want.len()); // deliberately dirty
                im2col_group_into(
                    &input, in_h, in_w, in_ch, kernel, stride, pad, groups, group, &mut scratch,
                );
                assert_eq!(scratch, want);
            }
        }
    }

    #[test]
    fn requantize_bounds_and_monotonicity() {
        for k in [1usize, 9, 147, 4608] {
            let hi = requantize(127 * 127 * k as i32, k);
            let lo = requantize(-127 * 127 * (k as i32), k);
            assert!(hi >= 0 && lo <= 0);
            assert!(requantize(1000, k) >= requantize(-1000, k));
        }
        assert_eq!(requantize(0, 9), 0);
    }
}
