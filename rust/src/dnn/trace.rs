//! Workload traces: run *custom* DNNs through the simulator.
//!
//! A trace is a plain-text layer list (one layer per line), so downstream
//! users can evaluate their own models on the photonic architectures
//! without touching code:
//!
//! ```text
//! # comment            (blank lines ignored)
//! model my_net
//! conv conv1 224 224 3 64 7 2 3 1    # in_h in_w in_ch out_ch k stride pad groups
//! dwconv dw1 112 112 64 3 1 1       # in_h in_w channels k stride pad
//! fc classifier 1024 1000           # in_features out_features
//! ```

use crate::dnn::layer::Layer;
use crate::dnn::models::CnnModel;
use crate::{Error, Result};

/// Parse a workload trace into a [`CnnModel`].
pub fn parse_trace(text: &str) -> Result<CnnModel> {
    let mut name: Option<String> = None;
    let mut layers = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut f = line.split_whitespace();
        let kind = f.next().unwrap();
        let rest: Vec<&str> = f.collect();
        let bad = |msg: &str| {
            Error::Config(format!("trace line {}: {msg}: {raw:?}", lineno + 1))
        };
        let nums = |from: usize| -> Result<Vec<usize>> {
            rest[from..]
                .iter()
                .map(|s| s.parse::<usize>().map_err(|_| bad("bad integer")))
                .collect()
        };
        match kind {
            "model" => {
                name = Some(rest.join(" "));
            }
            "conv" => {
                if rest.len() != 9 {
                    return Err(bad("conv needs name + 8 integers"));
                }
                let v = nums(1)?;
                let (in_h, in_w, in_ch, out_ch, k, s, p, g) =
                    (v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7]);
                if g == 0 || in_ch % g != 0 || out_ch % g != 0 {
                    return Err(bad("groups must divide channels"));
                }
                layers.push(Layer::Conv {
                    name: rest[0].to_string(),
                    in_h,
                    in_w,
                    in_ch,
                    out_ch,
                    kernel: k,
                    stride: s,
                    pad: p,
                    groups: g,
                });
            }
            "dwconv" => {
                if rest.len() != 7 {
                    return Err(bad("dwconv needs name + 6 integers"));
                }
                let v = nums(1)?;
                layers.push(Layer::dwconv(rest[0], v[0], v[1], v[2], v[3], v[4], v[5]));
            }
            "fc" => {
                if rest.len() != 3 {
                    return Err(bad("fc needs name + 2 integers"));
                }
                let v = nums(1)?;
                layers.push(Layer::fc(rest[0], v[0], v[1]));
            }
            other => return Err(bad(&format!("unknown layer kind {other:?}"))),
        }
    }
    if layers.is_empty() {
        return Err(Error::Config("trace has no layers".into()));
    }
    // Leak the name: CnnModel carries &'static str (the built-in tables are
    // static); traces are loaded once per process.
    let name: &'static str =
        Box::leak(name.unwrap_or_else(|| "trace".into()).into_boxed_str());
    Ok(CnnModel { name, layers })
}

/// Load a trace file.
pub fn load_trace(path: impl AsRef<std::path::Path>) -> Result<CnnModel> {
    parse_trace(&std::fs::read_to_string(path)?)
}

/// Serialize a model back to trace text (round-trip support).
pub fn to_trace(model: &CnnModel) -> String {
    let mut out = format!("model {}\n", model.name);
    for l in &model.layers {
        match l {
            Layer::Conv { name, in_h, in_w, in_ch, out_ch, kernel, stride, pad, groups } => {
                if *groups == *in_ch && in_ch == out_ch {
                    out.push_str(&format!(
                        "dwconv {name} {in_h} {in_w} {in_ch} {kernel} {stride} {pad}\n"
                    ));
                } else {
                    out.push_str(&format!(
                        "conv {name} {in_h} {in_w} {in_ch} {out_ch} {kernel} {stride} {pad} {groups}\n"
                    ));
                }
            }
            Layer::Fc { name, in_features, out_features } => {
                out.push_str(&format!("fc {name} {in_features} {out_features}\n"));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::models::{resnet50, shufflenet_v2};

    const SAMPLE: &str = "\
# tiny example net
model tiny
conv stem 32 32 3 16 3 1 1 1
dwconv dw 32 32 16 3 2 1
fc head 4096 10
";

    #[test]
    fn parses_sample_trace() {
        let m = parse_trace(SAMPLE).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.layers.len(), 3);
        assert_eq!(m.layers[0].gemm().k, 27);
        assert_eq!(m.layers[1].gemm().groups, 16);
        assert!(m.total_macs() > 0);
    }

    #[test]
    fn roundtrip_builtin_models() {
        for m in [resnet50(), shufflenet_v2()] {
            let text = to_trace(&m);
            let back = parse_trace(&text).unwrap();
            assert_eq!(back.layers, m.layers, "{} trace roundtrip", m.name);
            assert_eq!(back.total_macs(), m.total_macs());
        }
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_trace("conv missing_fields 1 2").is_err());
        assert!(parse_trace("warp w 1 2 3").is_err());
        assert!(parse_trace("fc head ten 10").is_err());
        assert!(parse_trace("").is_err());
        assert!(parse_trace("conv c 8 8 6 6 3 1 1 4").is_err()); // groups∤ch
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let m = parse_trace("# a\n\nfc f 4 2  # trailing\n").unwrap();
        assert_eq!(m.layers.len(), 1);
    }

    #[test]
    fn trace_runs_through_simulator() {
        use crate::arch::accel::Accelerator;
        use crate::optics::link_budget::ArchClass;
        use crate::sim::engine::simulate_frame;
        use crate::units::DataRate;
        let m = parse_trace(SAMPLE).unwrap();
        let a = Accelerator::equal_cores(ArchClass::Mwa, DataRate::Gs5, 8).unwrap();
        let f = simulate_frame(&a, &m.workload());
        assert!(f.fps() > 0.0);
    }
}
