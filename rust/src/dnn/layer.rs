//! Layer descriptions and their lowering to GEMM shapes.

/// Spatial output size of a convolution along one axis.
#[inline]
pub fn conv_out_dim(in_dim: usize, kernel: usize, stride: usize, pad: usize) -> usize {
    (in_dim + 2 * pad - kernel) / stride + 1
}

/// A single GEMM invocation: `C[t×c] = A[t×k] · B[k×c]`, possibly repeated
/// `groups` times (grouped/depthwise convolutions run one GEMM per group).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    /// Rows of the input matrix (im2col: output pixels; FC: batch).
    pub t: usize,
    /// Reduction dimension (im2col: in_ch/groups × kh × kw).
    pub k: usize,
    /// Columns of the weight matrix (output channels per group).
    pub c: usize,
    /// Number of independent GEMMs of this shape (conv groups).
    pub groups: usize,
}

impl GemmShape {
    /// Multiply-accumulate operations for all groups.
    pub fn macs(&self) -> u64 {
        self.t as u64 * self.k as u64 * self.c as u64 * self.groups as u64
    }

    /// Output elements produced (dot products computed).
    pub fn outputs(&self) -> u64 {
        self.t as u64 * self.c as u64 * self.groups as u64
    }
}

/// One network layer, as described in the architecture papers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Layer {
    /// 2-D convolution on an `in_h×in_w×in_ch` input.
    Conv {
        /// Layer name for traces/reports (e.g. "conv1", "res2a_branch2b").
        name: String,
        /// Input feature-map height.
        in_h: usize,
        /// Input feature-map width.
        in_w: usize,
        /// Input channels.
        in_ch: usize,
        /// Output channels.
        out_ch: usize,
        /// Kernel height = width.
        kernel: usize,
        /// Stride.
        stride: usize,
        /// Zero padding.
        pad: usize,
        /// Conv groups (`in_ch` for depthwise).
        groups: usize,
    },
    /// Fully connected layer (GEMV for batch 1).
    Fc {
        /// Layer name.
        name: String,
        /// Input features.
        in_features: usize,
        /// Output features.
        out_features: usize,
    },
}

impl Layer {
    /// Convenience constructor for a dense convolution.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        name: &str,
        in_h: usize,
        in_w: usize,
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        Layer::Conv {
            name: name.to_string(),
            in_h,
            in_w,
            in_ch,
            out_ch,
            kernel,
            stride,
            pad,
            groups: 1,
        }
    }

    /// Depthwise convolution (groups = channels).
    pub fn dwconv(
        name: &str,
        in_h: usize,
        in_w: usize,
        channels: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        Layer::Conv {
            name: name.to_string(),
            in_h,
            in_w,
            in_ch: channels,
            out_ch: channels,
            kernel,
            stride,
            pad,
            groups: channels,
        }
    }

    /// Fully connected layer.
    pub fn fc(name: &str, in_features: usize, out_features: usize) -> Self {
        Layer::Fc { name: name.to_string(), in_features, out_features }
    }

    /// Layer name.
    pub fn name(&self) -> &str {
        match self {
            Layer::Conv { name, .. } => name,
            Layer::Fc { name, .. } => name,
        }
    }

    /// Output spatial size `(h, w)`; FC layers are 1×1.
    pub fn out_hw(&self) -> (usize, usize) {
        match self {
            Layer::Conv { in_h, in_w, kernel, stride, pad, .. } => (
                conv_out_dim(*in_h, *kernel, *stride, *pad),
                conv_out_dim(*in_w, *kernel, *stride, *pad),
            ),
            Layer::Fc { .. } => (1, 1),
        }
    }

    /// Lower this layer to its GEMM shape (im2col for convs, paper Fig. 1).
    pub fn gemm(&self) -> GemmShape {
        match self {
            Layer::Conv { in_ch, out_ch, kernel, groups, .. } => {
                let (oh, ow) = self.out_hw();
                GemmShape {
                    t: oh * ow,
                    k: (in_ch / groups) * kernel * kernel,
                    c: out_ch / groups,
                    groups: *groups,
                }
            }
            Layer::Fc { in_features, out_features, .. } => {
                GemmShape { t: 1, k: *in_features, c: *out_features, groups: 1 }
            }
        }
    }

    /// MACs this layer costs per frame.
    pub fn macs(&self) -> u64 {
        self.gemm().macs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_out_dim_standard_cases() {
        // 224, k7, s2, p3 → 112 (ResNet/GoogLeNet conv1).
        assert_eq!(conv_out_dim(224, 7, 2, 3), 112);
        // 56, k3, s1, p1 → 56 (same-size conv).
        assert_eq!(conv_out_dim(56, 3, 1, 1), 56);
        // 56, k1, s1, p0 → 56 (pointwise).
        assert_eq!(conv_out_dim(56, 1, 1, 0), 56);
        // 112, k3, s2, p1 → 56.
        assert_eq!(conv_out_dim(112, 3, 2, 1), 56);
    }

    #[test]
    fn conv1_resnet_gemm_shape() {
        let l = Layer::conv("conv1", 224, 224, 3, 64, 7, 2, 3);
        let g = l.gemm();
        assert_eq!(g.t, 112 * 112);
        assert_eq!(g.k, 3 * 7 * 7);
        assert_eq!(g.c, 64);
        assert_eq!(g.groups, 1);
        assert_eq!(g.macs(), 112 * 112 * 147 * 64);
    }

    #[test]
    fn depthwise_conv_is_grouped_per_channel() {
        let l = Layer::dwconv("dw", 112, 112, 32, 3, 1, 1);
        let g = l.gemm();
        assert_eq!(g.groups, 32);
        assert_eq!(g.k, 9); // 1 channel × 3×3
        assert_eq!(g.c, 1);
        assert_eq!(g.macs(), (112 * 112 * 9 * 32) as u64);
    }

    #[test]
    fn fc_layer_is_gemv() {
        let l = Layer::fc("fc1000", 2048, 1000);
        let g = l.gemm();
        assert_eq!((g.t, g.k, g.c, g.groups), (1, 2048, 1000, 1));
        assert_eq!(l.macs(), 2_048_000);
    }

    #[test]
    fn outputs_counts_dot_products() {
        let g = GemmShape { t: 10, k: 100, c: 5, groups: 2 };
        assert_eq!(g.outputs(), 100);
    }
}
