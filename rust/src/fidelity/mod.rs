//! Analog fidelity substrate: noise Monte-Carlo for the photonic datapath.
//!
//! The paper's premise (§I) is that analog photonic cores cannot resolve
//! more than 4-bit operands at useful parallelism because the optical power
//! budget must cover the analog dynamic range. This module provides the
//! behavioural noise model that underlies that claim and lets us *measure*
//! it: each analog dot product is perturbed by receiver noise scaled to the
//! link budget's SNR, then digitized by the PWAB ADC; Monte-Carlo sweeps
//! report the bit-error behaviour vs laser power, vector size and ADC
//! resolution.
//!
//! The model is deliberately simple (additive Gaussian at the accumulator,
//! variance from the noise-equivalent power implied by the receiver
//! sensitivity) — the same abstraction level the paper's own modelling
//! references use.

pub mod noise;
pub mod study;

pub use noise::{AnalogChannel, NoiseParams};
pub use study::{fidelity_study, FidelityPoint};
