//! Monte-Carlo fidelity study: dot-product accuracy vs link margin,
//! vector size and ADC resolution.

use crate::bitslice::gemm_lanes;
use crate::fidelity::noise::{AnalogChannel, NoiseParams};
use crate::testing::SplitMix64;

/// One point of the fidelity sweep.
#[derive(Debug, Clone, Copy)]
pub struct FidelityPoint {
    /// Link margin above the 4-bit sensitivity floor, dB.
    pub margin_db: f64,
    /// Dot-product length.
    pub k: usize,
    /// PWAB ADC bits (None = ideal).
    pub adc_bits: Option<u32>,
    /// Root-mean-square error relative to the exact INT8 dot product,
    /// normalized by the RMS of the exact values.
    pub relative_rmse: f64,
    /// Fraction of trials whose rounded result equals the exact integer.
    pub exact_rate: f64,
}

/// Run a Monte-Carlo sweep: `trials` random INT8 dot products per point.
pub fn fidelity_study(
    margins_db: &[f64],
    ks: &[usize],
    adc_bits: Option<u32>,
    trials: usize,
    seed: u64,
) -> Vec<FidelityPoint> {
    let mut out = Vec::new();
    let mut rng = SplitMix64::new(seed);
    for &margin in margins_db {
        for &k in ks {
            let mut params = NoiseParams::from_link_margin(margin);
            if let Some(b) = adc_bits {
                params = params.with_adc(b);
            }
            let mut ch = AnalogChannel::new(params, seed ^ (k as u64) << 20);
            let mut se = 0.0f64;
            let mut ref_sq = 0.0f64;
            let mut exact_hits = 0usize;
            for _ in 0..trials {
                let a = rng.i8_vec(k);
                let b = rng.i8_vec(k);
                // One pass through the dispatching bitslice engine yields the
                // three exact lane charges; both the exact reference and the
                // noisy observation derive from them (the naive path sliced
                // the same operands twice per trial).
                let lanes = gemm_lanes(&a, &b, 1, k, 1).unwrap();
                let (hi, mid, lo) =
                    (lanes.hi[0] as i64, lanes.mid[0] as i64, lanes.lo[0] as i64);
                let exact = (256 * hi + 16 * mid + lo) as f64;
                let got = ch.transduce_lanes(hi, mid, lo, k);
                se += (got - exact) * (got - exact);
                ref_sq += exact * exact;
                if (got.round() - exact).abs() < 0.5 {
                    exact_hits += 1;
                }
            }
            let relative_rmse = if ref_sq > 0.0 { (se / ref_sq).sqrt() } else { 0.0 };
            out.push(FidelityPoint {
                margin_db: margin,
                k,
                adc_bits,
                relative_rmse,
                exact_rate: exact_hits as f64 / trials as f64,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_shrinks_with_link_margin() {
        let pts = fidelity_study(&[0.0, 10.0, 30.0], &[16], None, 200, 7);
        assert!(pts[0].relative_rmse > pts[1].relative_rmse);
        assert!(pts[1].relative_rmse > pts[2].relative_rmse);
    }

    #[test]
    fn high_margin_recovers_exact_integers() {
        // Note the 16² capacitor weighting amplifies Hi-lane noise ×256, so
        // exact integer recovery needs a very quiet link (≈100 dB margin) —
        // which is itself evidence for the paper's 4-bit analog ceiling.
        let pts = fidelity_study(&[100.0], &[8], None, 200, 11);
        assert!(pts[0].exact_rate > 0.95, "exact rate {}", pts[0].exact_rate);
    }

    #[test]
    fn longer_vectors_are_harder() {
        // Same margin, larger K → absolute lane noise scales with K while
        // the signal grows only ~√K for random operands: fidelity drops.
        let pts = fidelity_study(&[20.0], &[4, 64], None, 300, 13);
        assert!(pts[1].relative_rmse >= pts[0].relative_rmse);
    }

    #[test]
    fn coarse_adc_dominates_at_high_margin() {
        let ideal = fidelity_study(&[50.0], &[16], None, 200, 17);
        let coarse = fidelity_study(&[50.0], &[16], Some(6), 200, 17);
        assert!(coarse[0].relative_rmse > ideal[0].relative_rmse);
    }

    #[test]
    fn study_covers_grid() {
        let pts = fidelity_study(&[0.0, 5.0], &[4, 8, 16], Some(8), 20, 19);
        assert_eq!(pts.len(), 6);
        for p in pts {
            assert!(p.relative_rmse.is_finite());
            assert!((0.0..=1.0).contains(&p.exact_rate));
        }
    }
}
