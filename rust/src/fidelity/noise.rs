//! Analog channel noise model.

use crate::testing::SplitMix64;
use crate::units::db_to_ratio;

/// Noise configuration of one analog lane (BPCA accumulator).
///
/// `PartialEq` so backend configurations embedding noise settings (e.g.
/// [`crate::runtime::PhotonicConfig`]) can be compared in tests/config
/// plumbing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NoiseParams {
    /// Signal-to-noise ratio at the accumulator for a *full-scale* single
    /// product, dB. Derived from the margin between received power and
    /// receiver sensitivity.
    pub snr_db: f64,
    /// ADC resolution applied at the PWAB output (None = ideal).
    pub adc_bits: Option<u32>,
}

impl NoiseParams {
    /// SNR implied by a link with `margin_db` of power above the receiver's
    /// 4-bit sensitivity floor. At 0 dB margin the lane just resolves 2⁴
    /// levels: SNR ≈ 20·log10(2⁴) ≈ 24 dB; margin adds linearly (optical dB
    /// = electrical-current dB on a square-law detector biased linear).
    pub fn from_link_margin(margin_db: f64) -> Self {
        NoiseParams { snr_db: 24.1 + margin_db, adc_bits: None }
    }

    /// Attach a PWAB ADC model.
    pub fn with_adc(mut self, bits: u32) -> Self {
        self.adc_bits = Some(bits);
        self
    }

    /// Noise standard deviation relative to a unit full-scale signal.
    pub fn sigma(&self) -> f64 {
        // SNR(dB) = 20·log10(fullscale/σ)  →  σ = fs / 10^(SNR/20).
        1.0 / db_to_ratio(self.snr_db / 2.0)
    }
}

/// A noisy analog accumulation channel (one radix lane ending in a BPCA).
///
/// Two transduction disciplines coexist:
///
/// * the **sequential stream** ([`AnalogChannel::transduce`],
///   [`AnalogChannel::transduce_lanes`], [`AnalogChannel::dot_i8`]) mutates
///   the channel's RNG — each call consumes the next draws, the Monte-Carlo
///   shape the offline [`crate::fidelity::fidelity_study`] wants;
/// * the **content-keyed row path** ([`AnalogChannel::transduce_row`])
///   derives a fresh sub-stream per output row from the channel's
///   construction seed and the row's exact lane charges, leaving the
///   sequential stream untouched. A row's noise then depends only on
///   `(seed, row content)` — never on serving order, batch position or
///   co-batched traffic — which is what gives the serving path exact,
///   order-independent per-row noise attribution.
#[derive(Debug)]
pub struct AnalogChannel {
    params: NoiseParams,
    /// Construction seed, kept for deriving content-keyed row sub-streams.
    seed: u64,
    rng: SplitMix64,
}

impl AnalogChannel {
    /// New channel with deterministic noise stream `seed`.
    pub fn new(params: NoiseParams, seed: u64) -> Self {
        AnalogChannel { params, seed, rng: SplitMix64::new(seed) }
    }

    /// Approximate standard Gaussian via the Irwin–Hall sum of 12 uniforms
    /// (adequate for Monte-Carlo fidelity sweeps; no external crates).
    fn gauss(&mut self) -> f64 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.rng.f64();
        }
        s - 6.0
    }

    /// Transduce an exact lane accumulation `value` whose worst-case
    /// magnitude is `full_scale`: add receiver noise, clip, optionally
    /// quantize with the PWAB ADC. Returns the analog-observed value.
    pub fn transduce(&mut self, value: f64, full_scale: f64) -> f64 {
        let noisy = value + self.gauss() * self.params.sigma() * full_scale;
        let clipped = noisy.clamp(-full_scale, full_scale);
        match self.params.adc_bits {
            None => clipped,
            Some(bits) => {
                let lsb = 2.0 * full_scale / (1u64 << bits) as f64;
                (clipped / lsb).round() * lsb
            }
        }
    }

    /// Transduce the three exact lane accumulations of a K-length dot
    /// product — one transduction per BPCA — and apply the PWAB weighting.
    ///
    /// Taking pre-computed lanes lets callers that already ran the bitslice
    /// engine (e.g. [`crate::fidelity::fidelity_study`]) reuse them for both
    /// the exact reference and the noisy observation, instead of slicing the
    /// operands twice.
    pub fn transduce_lanes(&mut self, hi: i64, mid: i64, lo: i64, k: usize) -> f64 {
        let kf = k as f64;
        // Per-lane worst case magnitudes (see bitslice::lane_accumulator_bound).
        256.0 * self.transduce(hi as f64, 64.0 * kf)
            + 16.0 * self.transduce(mid as f64, 240.0 * kf)
            + self.transduce(lo as f64, 225.0 * kf)
    }

    /// Transduce one output row's exact lane accumulations — `hi[i]`,
    /// `mid[i]`, `lo[i]` are the three BPCA charges of the row's `i`-th
    /// K-length dot product — through a *content-keyed* sub-stream, and
    /// return the analog-observed (PWAB-weighted) values.
    ///
    /// The sub-stream seed hashes `(k, row width, lane charges)` into the
    /// channel's construction seed, so two calls with equal row content
    /// draw identical noise wherever and whenever they happen: inside a
    /// stacked batch, alone, or on a different channel instance built with
    /// the same seed. `&self` — the sequential stream is not advanced.
    /// (The flip side: byte-identical rows co-served in one batch correlate
    /// perfectly; that determinism is the price of order-independent
    /// attribution, and distinct traffic decorrelates. To decorrelate
    /// duplicates too, key the row with a nonzero per-request nonce via
    /// [`AnalogChannel::transduce_row_keyed`].)
    ///
    /// Errors with [`Error::Shape`](crate::Error::Shape) when the three
    /// lane planes disagree in length — a mis-sliced row would otherwise
    /// key noise off truncated content and serve wrong-noise values.
    pub fn transduce_row(
        &self,
        hi: &[i32],
        mid: &[i32],
        lo: &[i32],
        k: usize,
    ) -> crate::Result<Vec<f64>> {
        self.transduce_row_keyed(hi, mid, lo, k, 0)
    }

    /// [`AnalogChannel::transduce_row`] with an additional caller-supplied
    /// `nonce` folded into the sub-stream key — the ROADMAP's time-indexed
    /// counter mode. A nonzero nonce (e.g. a per-request counter carried
    /// through the batcher) decorrelates byte-identical rows served under
    /// different nonces while keeping each `(seed, content, nonce)` triple
    /// fully deterministic; `nonce == 0` is bit-identical to the plain
    /// content-keyed path, so default-off serving never changes outputs.
    pub fn transduce_row_keyed(
        &self,
        hi: &[i32],
        mid: &[i32],
        lo: &[i32],
        k: usize,
        nonce: u64,
    ) -> crate::Result<Vec<f64>> {
        // Release-enforced: the sub-stream key hashes all three planes, so
        // disagreeing lengths would serve deterministic-but-wrong noise. A
        // debug_assert here would vanish exactly where it matters (release
        // serving) — the bug class PR 8's check_frame_nonces fix paid for.
        if hi.len() != mid.len() || mid.len() != lo.len() {
            return Err(crate::Error::Shape(format!(
                "lane planes of one output row must agree: hi={}, mid={}, lo={}",
                hi.len(),
                mid.len(),
                lo.len()
            )));
        }
        // FNV-1a over the row signature; collisions merely correlate two
        // rows' noise, which the Monte-Carlo statistics shrug off.
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;
        let fold = |h: u64, v: u64| (h ^ v).wrapping_mul(FNV_PRIME);
        let mut h = fold(FNV_OFFSET, k as u64);
        h = fold(h, hi.len() as u64);
        for lane in [hi, mid, lo] {
            for &v in lane {
                h = fold(h, v as u32 as u64);
            }
        }
        if nonce != 0 {
            // Folded only when set, so the nonce-off stream stays exactly
            // the historical content-keyed stream (seeded tests pin this).
            h = fold(h, nonce);
        }
        let mut sub = AnalogChannel::new(self.params, self.seed ^ h);
        Ok((0..hi.len())
            .map(|i| sub.transduce_lanes(hi[i] as i64, mid[i] as i64, lo[i] as i64, k))
            .collect())
    }

    /// Noisy SPOGA dot product of INT8 vectors: three lanes accumulated in
    /// charge, weighted (16²/16¹/16⁰), summed, transduced once per lane.
    ///
    /// The exact lane accumulation runs through the dispatching bitslice
    /// engine (`gemm_lanes` as a 1×K×1 problem). The engine accumulates in
    /// i32, which is exact while `240·k ≤ i32::MAX`; beyond that (k ≈ 8.9M)
    /// this falls back to a local i64 accumulation so the exact charges
    /// never wrap.
    pub fn dot_i8(&mut self, a: &[i8], b: &[i8]) -> f64 {
        assert_eq!(a.len(), b.len());
        let k = a.len();
        // Largest K whose worst-case lane magnitude (mid bound 240·k) still
        // fits the engine's i32 accumulators.
        const I32_SAFE_K: usize = (i32::MAX / 240) as usize;
        if k > I32_SAFE_K {
            use crate::bitslice::nibble::{slice_i8, NibblePair};
            let (mut hi, mut mid, mut lo) = (0i64, 0i64, 0i64);
            for (&x, &y) in a.iter().zip(b) {
                let (h, m, l) = NibblePair::product_lanes(slice_i8(x), slice_i8(y));
                hi += h as i64;
                mid += m as i64;
                lo += l as i64;
            }
            return self.transduce_lanes(hi, mid, lo, k);
        }
        let lanes = crate::bitslice::gemm_lanes(a, b, 1, k, 1).expect("1xKx1 dot");
        self.transduce_lanes(lanes.hi[0] as i64, lanes.mid[0] as i64, lanes.lo[0] as i64, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitslice::gemm_i32;

    #[test]
    fn sigma_decreases_with_snr() {
        let lo = NoiseParams { snr_db: 20.0, adc_bits: None };
        let hi = NoiseParams { snr_db: 40.0, adc_bits: None };
        assert!(hi.sigma() < lo.sigma());
        assert!((NoiseParams { snr_db: 20.0, adc_bits: None }.sigma() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn infinite_snr_recovers_exact_dot() {
        let mut ch = AnalogChannel::new(NoiseParams { snr_db: 400.0, adc_bits: None }, 1);
        let a: Vec<i8> = vec![-128, 55, 7, -3];
        let b: Vec<i8> = vec![127, -1, 9, 22];
        let exact = gemm_i32(&a, &b, 1, 4, 1).unwrap()[0] as f64;
        let got = ch.dot_i8(&a, &b);
        assert!((got - exact).abs() < 1e-6, "{got} vs {exact}");
    }

    #[test]
    fn noise_is_deterministic_per_seed() {
        let p = NoiseParams { snr_db: 30.0, adc_bits: None };
        let a: Vec<i8> = (0..16).map(|i| (i * 7 - 50) as i8).collect();
        let b: Vec<i8> = (0..16).map(|i| (i * 11 - 80) as i8).collect();
        let x = AnalogChannel::new(p, 9).dot_i8(&a, &b);
        let y = AnalogChannel::new(p, 9).dot_i8(&a, &b);
        assert_eq!(x, y);
        let z = AnalogChannel::new(p, 10).dot_i8(&a, &b);
        assert!((x - z).abs() > 0.0);
    }

    #[test]
    fn transduce_clips_to_full_scale() {
        let mut ch = AnalogChannel::new(NoiseParams { snr_db: 300.0, adc_bits: None }, 3);
        assert_eq!(ch.transduce(1e12, 100.0), 100.0);
        assert_eq!(ch.transduce(-1e12, 100.0), -100.0);
    }

    #[test]
    fn adc_quantizes_to_lsb_grid() {
        let mut ch = AnalogChannel::new(
            NoiseParams { snr_db: 300.0, adc_bits: None }.with_adc(4),
            3,
        );
        let v = ch.transduce(13.0, 64.0);
        let lsb = 128.0 / 16.0;
        assert!((v / lsb - (v / lsb).round()).abs() < 1e-9);
    }

    #[test]
    fn transduce_row_is_content_keyed_not_order_keyed() {
        let p = NoiseParams { snr_db: 24.1, adc_bits: None };
        let (hi, mid, lo) = (vec![40i32, -12, 7], vec![3i32, 0, -9], vec![11i32, 2, 5]);

        // Same content, same seed → same observations, regardless of how
        // much of the channel's sequential stream was consumed first.
        let fresh = AnalogChannel::new(p, 42).transduce_row(&hi, &mid, &lo, 8).unwrap();
        let mut advanced = AnalogChannel::new(p, 42);
        for _ in 0..17 {
            let _ = advanced.transduce(1.0, 64.0); // burn sequential draws
        }
        assert_eq!(advanced.transduce_row(&hi, &mid, &lo, 8).unwrap(), fresh);

        // Different seeds or different content → different observations.
        let other_seed = AnalogChannel::new(p, 43).transduce_row(&hi, &mid, &lo, 8).unwrap();
        assert_ne!(other_seed, fresh);
        let mut hi2 = hi.clone();
        hi2[1] += 1;
        let other_row = AnalogChannel::new(p, 42).transduce_row(&hi2, &mid, &lo, 8).unwrap();
        assert_ne!(other_row, fresh);
    }

    #[test]
    fn transduce_row_recovers_exact_weighted_sums_at_infinite_snr() {
        let ch = AnalogChannel::new(NoiseParams { snr_db: 400.0, adc_bits: None }, 5);
        let (hi, mid, lo) = (vec![9i32, -4], vec![1i32, 6], vec![-2i32, 3]);
        let obs = ch.transduce_row(&hi, &mid, &lo, 4).unwrap();
        for i in 0..2 {
            let exact = 256.0 * hi[i] as f64 + 16.0 * mid[i] as f64 + lo[i] as f64;
            assert!((obs[i] - exact).abs() < 1e-6, "{} vs {exact}", obs[i]);
        }
        // Empty rows are a no-op.
        assert!(ch.transduce_row(&[], &[], &[], 4).unwrap().is_empty());
    }

    #[test]
    fn mismatched_lane_planes_are_a_shape_error() {
        let ch = AnalogChannel::new(NoiseParams { snr_db: 24.1, adc_bits: None }, 7);
        let (hi, mid, lo) = (vec![1i32, 2], vec![3i32], vec![4i32, 5]);
        for err in [
            ch.transduce_row(&hi, &mid, &lo, 8).unwrap_err(),
            ch.transduce_row_keyed(&hi, &mid, &lo, 8, 9).unwrap_err(),
        ] {
            match err {
                crate::Error::Shape(m) => {
                    assert!(m.contains("hi=2, mid=1, lo=2"), "message: {m}");
                }
                other => panic!("expected Shape error, got {other:?}"),
            }
        }
    }

    #[test]
    fn nonce_zero_is_bit_identical_and_nonzero_decorrelates() {
        let p = NoiseParams { snr_db: 24.1, adc_bits: None };
        let ch = AnalogChannel::new(p, 77);
        let (hi, mid, lo) = (vec![40i32, -12, 7], vec![3i32, 0, -9], vec![11i32, 2, 5]);

        // nonce 0 ≡ the plain content-keyed path, bit for bit.
        assert_eq!(
            ch.transduce_row_keyed(&hi, &mid, &lo, 8, 0).unwrap(),
            ch.transduce_row(&hi, &mid, &lo, 8).unwrap()
        );

        // Distinct nonces decorrelate the same row content; equal nonces
        // stay deterministic (same draws every time, any channel instance
        // with the same construction seed).
        let n1 = ch.transduce_row_keyed(&hi, &mid, &lo, 8, 1).unwrap();
        let n2 = ch.transduce_row_keyed(&hi, &mid, &lo, 8, 2).unwrap();
        assert_ne!(n1, n2, "different nonces must draw different noise");
        assert_ne!(n1, ch.transduce_row(&hi, &mid, &lo, 8).unwrap());
        assert_eq!(n1, ch.transduce_row_keyed(&hi, &mid, &lo, 8, 1).unwrap());
        assert_eq!(
            n1,
            AnalogChannel::new(p, 77).transduce_row_keyed(&hi, &mid, &lo, 8, 1).unwrap(),
            "keyed draws depend only on (seed, content, nonce)"
        );
    }

    #[test]
    fn gauss_moments_sane() {
        let mut ch = AnalogChannel::new(NoiseParams { snr_db: 0.0, adc_bits: None }, 5);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| ch.gauss()).collect();
        let mean: f64 = samples.iter().sum::<f64>() / n as f64;
        let var: f64 = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
