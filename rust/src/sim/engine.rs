//! The transaction-level simulation engine.

use crate::arch::accel::Accelerator;
use crate::arch::cost::EnergyBreakdown;
use crate::dnn::layer::GemmShape;
use crate::dnn::workload::{GemmOp, Workload};
use crate::sim::stats::{FrameStats, LayerStats};

/// Simulation engine over one accelerator.
///
/// Layers execute sequentially (each consumes the previous one's output);
/// within a layer the GEMM's tiles spread across all logical cores — the
/// standard "perfectly divisible work" transaction-level approximation, with
/// the fill/drain captured by the ceil() and the DEAS pipeline-fill latency
/// for the baselines.
#[derive(Debug, Clone)]
pub struct SimEngine {
    /// The accelerator being simulated.
    pub accel: Accelerator,
}

impl SimEngine {
    /// New engine for an accelerator.
    pub fn new(accel: Accelerator) -> Self {
        SimEngine { accel }
    }

    /// Simulate one inference frame of `workload`.
    pub fn frame(&self, workload: &Workload) -> FrameStats {
        let core = &self.accel.core;
        let logical = self.accel.logical_cores().max(1) as u64;
        let step_s = core.dr.step_seconds();
        // The DEAS fill latency is shape-independent: one unit, one rate.
        // Construct it once for the frame rather than per layer.
        let deas_fill_s = crate::devices::deas::Deas::default().fill_latency_s(core.dr);
        let mut layers = Vec::with_capacity(workload.ops.len());
        let mut total_latency = 0.0f64;
        let mut total_energy = EnergyBreakdown::default();

        for op in &workload.ops {
            let plan = core.plan_gemm(&op.shape);
            // Tiles of this layer spread over every logical core.
            let steps_across_fleet = plan.timesteps.div_ceil(logical);
            let mut latency = steps_across_fleet as f64 * step_s;
            if plan.deas_outputs > 0 {
                latency += deas_fill_s;
            }
            let energy = EnergyBreakdown::of_plan(core, &plan);
            let utilization = plan.timesteps as f64 / (steps_across_fleet * logical) as f64;
            total_latency += latency;
            total_energy.add(&energy);
            layers.push(LayerStats {
                layer: op.layer.clone(),
                latency_s: latency,
                energy,
                core_timesteps: plan.timesteps * plan.cores_occupied,
                utilization,
            });
        }

        FrameStats {
            accelerator: self.accel.name.clone(),
            model: workload.model.clone(),
            latency_s: total_latency,
            energy: total_energy,
            layers,
        }
    }

    /// Price a single GEMM shape: a one-op frame, so the result is exactly
    /// the layer record [`Self::frame`] would produce for the same shape.
    /// The photonic serving backend derives its per-request telemetry here,
    /// which is what keeps live `ExecReport`s and offline `simulate_frame`
    /// studies bit-consistent.
    pub fn gemm_frame(&self, shape: &GemmShape) -> FrameStats {
        self.frame(&Workload {
            model: "gemm".to_string(),
            ops: vec![GemmOp { layer: "gemm".to_string(), shape: *shape }],
        })
    }
}

/// One-shot convenience: simulate `workload` on `accel`.
pub fn simulate_frame(accel: &Accelerator, workload: &Workload) -> FrameStats {
    SimEngine::new(accel.clone()).frame(workload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::accel::Accelerator;
    use crate::dnn::models::{mobilenet_v2, resnet50};
    use crate::optics::link_budget::ArchClass;
    use crate::units::DataRate;

    fn accel(arch: ArchClass, dr: DataRate) -> Accelerator {
        Accelerator::iso_laser_power(arch, dr, 60.0).unwrap()
    }

    #[test]
    fn frame_stats_cover_all_layers() {
        let a = accel(ArchClass::Mwa, DataRate::Gs10);
        let w = resnet50().workload();
        let f = simulate_frame(&a, &w);
        assert_eq!(f.layers.len(), w.ops.len());
        assert!(f.latency_s > 0.0);
        assert!(f.energy.total_j() > 0.0);
    }

    #[test]
    fn spoga_faster_than_baselines_iso_power() {
        let w = resnet50().workload();
        let s = simulate_frame(&accel(ArchClass::Mwa, DataRate::Gs10), &w);
        let h = simulate_frame(&accel(ArchClass::Maw, DataRate::Gs10), &w);
        let d = simulate_frame(&accel(ArchClass::Amw, DataRate::Gs10), &w);
        assert!(s.fps() > h.fps(), "SPOGA {} vs HOLYLIGHT {}", s.fps(), h.fps());
        assert!(s.fps() > d.fps(), "SPOGA {} vs DEAPCNN {}", s.fps(), d.fps());
    }

    #[test]
    fn higher_rate_means_higher_fps_same_arch() {
        let w = mobilenet_v2().workload();
        let f5 = simulate_frame(&accel(ArchClass::Mwa, DataRate::Gs5), &w);
        let f10 = simulate_frame(&accel(ArchClass::Mwa, DataRate::Gs10), &w);
        assert!(f10.fps() > f5.fps());
    }

    #[test]
    fn latency_is_sum_of_layers() {
        let a = accel(ArchClass::Amw, DataRate::Gs5);
        let f = simulate_frame(&a, &mobilenet_v2().workload());
        let sum: f64 = f.layers.iter().map(|l| l.latency_s).sum();
        assert!((f.latency_s - sum).abs() < 1e-12);
    }

    #[test]
    fn utilization_bounded() {
        let a = accel(ArchClass::Mwa, DataRate::Gs5);
        let f = simulate_frame(&a, &resnet50().workload());
        let u = f.utilization();
        assert!(u > 0.0 && u <= 1.0, "utilization {u}");
    }

    #[test]
    fn baseline_energy_includes_deas_and_sram() {
        let f = simulate_frame(&accel(ArchClass::Maw, DataRate::Gs5), &mobilenet_v2().workload());
        assert!(f.energy.deas_j > 0.0);
        assert!(f.energy.sram_j > 0.0);
        let s = simulate_frame(&accel(ArchClass::Mwa, DataRate::Gs5), &mobilenet_v2().workload());
        assert_eq!(s.energy.deas_j, 0.0);
        assert_eq!(s.energy.sram_j, 0.0);
    }
}
