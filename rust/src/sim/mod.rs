//! Transaction-level simulator.
//!
//! Mirrors the paper's custom Python simulator (§IV-B): each CNN layer's
//! GEMM is planned on the accelerator's cores ([`crate::arch`]), layer
//! latencies accumulate sequentially (inference is layer-dependent), and
//! energy components accumulate from the per-plan breakdowns. The output is
//! the paper's metric triple: FPS, FPS/W, FPS/W/mm².

pub mod engine;
pub mod mapper;
pub mod stats;

pub use engine::{simulate_frame, SimEngine};
pub use mapper::{best_mapping, evaluate as evaluate_mapping, Mapping, MappingCost};
pub use stats::{FrameStats, LayerStats};
