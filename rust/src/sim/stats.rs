//! Simulation result records.

use crate::arch::cost::EnergyBreakdown;

/// Per-layer simulation record.
#[derive(Debug, Clone)]
pub struct LayerStats {
    /// Layer name.
    pub layer: String,
    /// Latency contribution, seconds.
    pub latency_s: f64,
    /// Energy, joules.
    pub energy: EnergyBreakdown,
    /// Core-timesteps of photonic work.
    pub core_timesteps: u64,
    /// Fraction of the fleet busy during this layer (0..1).
    pub utilization: f64,
}

/// Whole-frame simulation result.
#[derive(Debug, Clone)]
pub struct FrameStats {
    /// Accelerator variant name ("SPOGA_10", ...).
    pub accelerator: String,
    /// Model name ("ResNet50", ...).
    pub model: String,
    /// End-to-end frame latency, seconds.
    pub latency_s: f64,
    /// Total frame energy, joules.
    pub energy: EnergyBreakdown,
    /// Per-layer records.
    pub layers: Vec<LayerStats>,
}

impl FrameStats {
    /// Frames per second (single-frame latency reciprocal).
    pub fn fps(&self) -> f64 {
        1.0 / self.latency_s
    }

    /// Average power over the frame, watts.
    pub fn avg_power_w(&self) -> f64 {
        self.energy.total_j() / self.latency_s
    }

    /// FPS per watt = 1 / energy-per-frame.
    pub fn fps_per_w(&self) -> f64 {
        1.0 / self.energy.total_j()
    }

    /// FPS per watt per mm² given the accelerator area.
    pub fn fps_per_w_per_mm2(&self, area_mm2: f64) -> f64 {
        self.fps_per_w() / area_mm2
    }

    /// Mean fleet utilization across layers (time-weighted).
    pub fn utilization(&self) -> f64 {
        let total: f64 = self.layers.iter().map(|l| l.latency_s).sum();
        if total == 0.0 {
            return 0.0;
        }
        self.layers.iter().map(|l| l.utilization * l.latency_s).sum::<f64>() / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(latency: f64, laser_j: f64) -> FrameStats {
        FrameStats {
            accelerator: "X".into(),
            model: "Y".into(),
            latency_s: latency,
            energy: EnergyBreakdown { laser_j, ..Default::default() },
            layers: vec![],
        }
    }

    #[test]
    fn fps_is_latency_reciprocal() {
        assert!((frame(0.01, 1.0).fps() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn fps_per_w_is_inverse_energy() {
        let f = frame(0.01, 0.5);
        assert!((f.fps_per_w() - 2.0).abs() < 1e-9);
        // Identity: FPS/W == FPS / avg_power.
        assert!((f.fps_per_w() - f.fps() / f.avg_power_w()).abs() < 1e-9);
    }

    #[test]
    fn area_efficiency_divides_area() {
        let f = frame(0.01, 0.5);
        assert!((f.fps_per_w_per_mm2(10.0) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn empty_frame_utilization_zero() {
        assert_eq!(frame(1.0, 1.0).utilization(), 0.0);
    }
}
